//! Quickstart: load the AOT artifacts, serve one prompt with LAVa
//! compression, print the result.
//!
//!   make artifacts            # once (trains the tiny model + lowers HLO)
//!   cargo run --release --example quickstart

use anyhow::Result;
use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::model::backend::PjrtBackend;
use lava::util::rng::Rng;
use lava::workloads;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let backend = PjrtBackend::load(&dir).map_err(|e| {
        eprintln!("could not load artifacts from {dir}/ — run `make artifacts` first");
        e
    })?;

    // LAVa with a 32-entries-per-head budget (vs the 200-token prompt below,
    // a ~2.5x compression of the KV cache).
    let opts = EngineOptions::new(Policy::by_name("lava").unwrap(), 32);
    let mut engine = Engine::new(backend, opts);

    // A needle-retrieval prompt: the model must find `key -> value` planted
    // in 200 tokens of noise, after its KV cache has been compressed.
    let mut rng = Rng::new(7);
    let inst = workloads::needle_qa(&mut rng, 200, 4);
    println!("prompt: {} tokens, expecting {:?}", inst.prompt.len(), inst.target);

    let result = engine.generate(&GenerateRequest {
        prompt: inst.prompt.clone(),
        max_new_tokens: inst.target.len(),
    })?;

    println!("generated: {:?}", result.tokens);
    println!("score:     {:.2}", inst.score(&result.tokens));
    println!(
        "prefill:   {:.1} ms   decode: {:.1} ms   kv after prefill: {:.1} KiB",
        result.prefill_secs * 1e3,
        result.decode_secs * 1e3,
        result.kv_bytes_after_prefill as f64 / 1024.0
    );
    println!("dynamic layer budgets (entries): {:?}", result.budgets);
    Ok(())
}
