//! Policy explorer: inspect *what* each eviction policy keeps.
//!
//! Prefills the same prompt under several policies and prints, per layer,
//! the kept-position map of one kv head plus the dynamic budget split —
//! makes the difference between fixed/dynamic head and layer budgets
//! visible at a glance.
//!
//!   cargo run --release --example policy_explorer            # real model
//!   cargo run --release --example policy_explorer -- --mock  # no artifacts

use anyhow::Result;
use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions};
use lava::model::backend::{MockBackend, ModelBackend, PjrtBackend};
use lava::util::cli::Args;
use lava::util::rng::Rng;
use lava::workloads;

fn explore<B: ModelBackend>(engine: &mut Engine<B>) -> Result<()> {
    let mut rng = Rng::new(3);
    let ctx = 200;
    let inst = workloads::needle_qa(&mut rng, ctx, 4);
    // where is the needle?
    let needle_pos = inst
        .prompt
        .windows(2)
        .position(|w| w[0] == workloads::SEP)
        .unwrap();
    println!("prompt {} tokens; needle at ~{}\n", inst.prompt.len(), needle_pos);

    for name in ["snapkv", "ada-snapkv", "pyramidkv", "cake", "lava"] {
        engine.opts.policy = Policy::by_name(name).unwrap();
        engine.opts.budget_per_head = 24;
        let (sess, _) = engine.prefill_only(&inst.prompt)?;
        println!("policy {name}: layer budgets {:?}", sess.budgets);
        for (l, cache) in sess.caches.iter().enumerate() {
            let lens: Vec<usize> = (0..4).map(|h| cache.head_len(h)).collect();
            // render head 0's keep map
            let mut map = vec!['.'; inst.prompt.len()];
            for i in 0..cache.head_len(0) {
                let p = cache.position(0, i) as usize;
                map[p] = '#';
            }
            let m: String = map.chunks(4).map(|c| if c.contains(&'#') { '#' } else { '.' }).collect();
            println!("  L{l} head lens {lens:?}  keep[h0]: {m}");
        }
        println!();
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    if args.bool("mock") {
        let mut mock = MockBackend::new(MockBackend::default_config());
        mock.hot_positions = vec![60, 61];
        let mut engine = Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        explore(&mut engine)
    } else {
        let dir = args.str_or("artifacts", "artifacts");
        let backend = PjrtBackend::load(&dir)?;
        let mut engine =
            Engine::new(backend, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        explore(&mut engine)
    }
}
