//! Long-context serving over TCP: starts the JSON-lines server with LAVa
//! compression, then (from a client thread) streams a long needle prompt
//! and prints the response — the deployment shape of the paper's system.
//!
//!   cargo run --release --example serve_longcontext            # real model
//!   cargo run --release --example serve_longcontext -- --mock

use std::io::{BufRead, BufReader, Write};

use anyhow::Result;
use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions};
use lava::coordinator::server::Server;
use lava::model::backend::{MockBackend, PjrtBackend};
use lava::util::cli::Args;
use lava::util::json::Json;
use lava::util::rng::Rng;
use lava::workloads;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let addr = args.str_or("addr", "127.0.0.1:7171");
    let policy = Policy::by_name(&args.str_or("policy", "lava")).expect("policy");
    let budget = args.usize_or("budget", 32);
    let ctx = args.usize_or("ctx", 400);
    let opts = EngineOptions::new(policy, budget);

    let addr_srv = addr.clone();
    let mock = args.bool("mock");
    let artifacts = args.str_or("artifacts", "artifacts");
    let server_thread = std::thread::spawn(move || -> Result<()> {
        if mock {
            let backend = MockBackend::new(MockBackend::default_config());
            Server::new(Engine::new(backend, opts)).serve(&addr_srv)
        } else {
            let backend = PjrtBackend::load(&artifacts)?;
            Server::new(Engine::new(backend, opts)).serve(&addr_srv)
        }
    });

    // client: wait for bind, then send a long-context request
    let mut conn = None;
    for _ in 0..200 {
        if let Ok(c) = std::net::TcpStream::connect(&addr) {
            conn = Some(c);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let mut c = conn.expect("server did not bind");
    let mut rng = Rng::new(11);
    let inst = workloads::needle_qa(&mut rng, ctx, 4);
    let prompt: Vec<String> = inst.prompt.iter().map(|t| t.to_string()).collect();
    writeln!(
        c,
        "{{\"prompt\": [{}], \"max_new_tokens\": {}}}",
        prompt.join(","),
        inst.target.len()
    )?;
    let mut reader = BufReader::new(c.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(line.trim())?;
    println!("expected : {:?}", inst.target);
    println!("response : {}", line.trim());
    let tokens: Vec<i32> = j
        .get("tokens")
        .and_then(|t| t.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_f64().map(|f| f as i32)).collect())
        .unwrap_or_default();
    println!("score    : {:.2}", inst.score(&tokens));

    writeln!(c, "{{\"cmd\": \"metrics\"}}")?;
    line.clear();
    reader.read_line(&mut line)?;
    println!("metrics  : {}", line.trim());

    writeln!(c, "{{\"cmd\": \"shutdown\"}}")?;
    line.clear();
    reader.read_line(&mut line)?;
    server_thread.join().expect("server thread")?;
    Ok(())
}
