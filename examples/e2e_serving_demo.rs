//! END-TO-END VALIDATION (DESIGN.md / EXPERIMENTS.md §E2E): load the real
//! AOT-compiled model and serve a batched mixed workload through the full
//! stack — batcher -> scheduler (admission control + continuous batching)
//! -> engine (Algorithm 2 prefill + decode) -> PJRT — reporting
//! latency/throughput/memory *and* task accuracy under compression.
//!
//!   make artifacts && cargo run --release --example e2e_serving_demo
//!   (options: --requests 12 --ctx 192 --budget 32 --policy lava --mock)

use anyhow::Result;
use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::model::backend::{MockBackend, ModelBackend, PjrtBackend};
use lava::util::cli::Args;
use lava::util::rng::Rng;
use lava::workloads::{self, Instance};

fn run<B: ModelBackend>(engine: Engine<B>, args: &Args) -> Result<()> {
    let n_requests = args.usize_or("requests", 12);
    let ctx = args.usize_or("ctx", 160);
    let seed = args.usize_or("seed", 0) as u64;

    // mixed workload at three retrieval depths (echo-resume is the
    // calibrated probe for the build-time model; see EXPERIMENTS.md §Model)
    let mut rng = Rng::new(seed);
    let mut instances: Vec<(String, Instance)> = Vec::new();
    for i in 0..n_requests {
        let (name, inst) = match i % 3 {
            0 => ("echo-deep", workloads::echo_resume(&mut rng, ctx, 0.15, 6)),
            1 => ("echo-mid", workloads::echo_resume(&mut rng, ctx, 0.5, 6)),
            _ => ("echo-late", workloads::echo_resume(&mut rng, ctx, 0.85, 6)),
        };
        instances.push((name.to_string(), inst));
    }

    let mut sched = Scheduler::new(
        engine,
        SchedulerOptions {
            kv_mem_limit: Some(args.usize_or("mem-limit", 8 * 1024 * 1024)),
            max_active: args.usize_or("max-active", 4),
            prefill_every: args.usize_or("prefill-every", 2),
            max_prefill_batch: args.usize_or("prefill-batch", 4),
            ..Default::default()
        },
    );

    let t0 = std::time::Instant::now();
    let mut id_map = Vec::new();
    for (name, inst) in &instances {
        let id = sched
            .submit(GenerateRequest {
                prompt: inst.prompt.clone(),
                max_new_tokens: inst.target.len(),
            })
            .unwrap_or_else(|e| panic!("submit refused: {e}"));
        id_map.push((id, name.clone(), inst.clone()));
    }
    let mut finished = sched.run_to_completion()?;
    // completion order != submit order under continuous batching; the id
    // submit() returned is the id on the result, so sorting re-pairs exactly
    finished.sort_by_key(|(id, _)| *id);
    let wall = t0.elapsed().as_secs_f64();

    let mut total_score = 0.0;
    let mut per_task: std::collections::BTreeMap<String, (f64, usize)> = Default::default();
    for ((id, result), (want_id, name, inst)) in finished.iter().zip(&id_map) {
        assert_eq!(id, want_id, "request identity lost in the scheduler");
        let s = inst.score(&result.tokens);
        total_score += s;
        let e = per_task.entry(name.clone()).or_insert((0.0, 0));
        e.0 += s;
        e.1 += 1;
    }

    println!("== e2e serving demo ==");
    println!(
        "requests={} ctx={} policy={} budget={}/head",
        n_requests,
        ctx,
        sched.engine.opts.policy.name,
        sched.engine.opts.budget_per_head
    );
    println!("wall time        : {:.2} s", wall);
    println!("metrics          : {}", sched.engine.metrics.report());
    for (name, (sum, cnt)) in &per_task {
        println!("accuracy[{name:<12}]: {:.3} (n={cnt})", sum / *cnt as f64);
    }
    println!("accuracy[all]    : {:.3}", total_score / n_requests as f64);
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let policy = Policy::by_name(&args.str_or("policy", "lava")).expect("policy");
    let budget = args.usize_or("budget", 32);
    let opts = EngineOptions::new(policy, budget);
    if args.bool("mock") {
        let mock = MockBackend::new(MockBackend::default_config());
        run(Engine::new(mock, opts), &args)
    } else {
        let dir = args.str_or("artifacts", "artifacts");
        run(Engine::new(PjrtBackend::load(&dir)?, opts), &args)
    }
}
