//! Worker-count equivalence suite (ISSUE 4).
//!
//! The scheduler plans every decode round (bucket groups, the sequential
//! tiered arm, spill victims) on the serving thread before fanning units
//! out over the worker pool, so the pool width must be *unobservable* in
//! the results: for workers ∈ {1, 2, 4}, a mixed same+cross-bucket
//! workload must produce bit-identical tokens, statuses, per-request KV
//! sizes and budgets, and identical eviction/tier decision counters
//! (decode steps, per-bucket dispatch counts, spills, prefetches,
//! deferrals) — with tiering off and with tiering on under a limit tight
//! enough that layers spill mid-run.

use std::collections::BTreeMap;

use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, FinishStatus, GenerateRequest};
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::model::backend::MockBackend;

fn sched(workers: usize, limit: Option<usize>, policy: &str) -> Scheduler<MockBackend> {
    let mut mock = MockBackend::new(MockBackend::default_config());
    mock.hot_positions = vec![30, 31, 32];
    mock.seed = 5;
    let engine = Engine::new(mock, EngineOptions::new(Policy::by_name(policy).unwrap(), 24));
    Scheduler::new(
        engine,
        SchedulerOptions {
            kv_mem_limit: limit,
            max_active: 8,
            prefill_every: 2,
            max_prefill_batch: 4,
            workers,
            ..Default::default()
        },
    )
}

/// Mixed workload: four prompts in one shape/capacity bucket (distinct
/// contents, so caches and scores genuinely differ within a group) plus
/// four longer prompts across other buckets.
fn requests() -> Vec<GenerateRequest> {
    let lens = [100usize, 104, 96, 100, 300, 280, 200, 200];
    lens.iter()
        .enumerate()
        .map(|(i, &n)| GenerateRequest {
            prompt: (0..n).map(|t| ((t * (i + 2) + i) % 251) as i32).collect(),
            max_new_tokens: 6,
        })
        .collect()
}

/// One request's width-independent outcome.
#[derive(Debug, PartialEq)]
struct ResultRow {
    id: u64,
    status: FinishStatus,
    tokens: Vec<i32>,
    kv_after: usize,
    budgets: Vec<usize>,
}

/// Everything about a run that must not depend on the pool width.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    results: Vec<ResultRow>,
    decode_steps: u64,
    decode_batches: u64,
    decode_batch_sessions: u64,
    dispatches: BTreeMap<usize, u64>,
    spills: u64,
    prefetches: u64,
    deferred: u64,
    finished: u64,
}

fn run(workers: usize, limit: Option<usize>, policy: &str) -> Fingerprint {
    let mut s = sched(workers, limit, policy);
    for req in requests() {
        s.submit(req).unwrap();
    }
    let mut done = s.run_to_completion().unwrap();
    done.sort_by_key(|(id, _)| *id);
    let results = done
        .into_iter()
        .map(|(id, r)| ResultRow {
            id,
            status: r.status,
            tokens: r.tokens,
            kv_after: r.kv_bytes_after_prefill,
            budgets: r.budgets,
        })
        .collect();
    let m = &s.engine.metrics;
    Fingerprint {
        results,
        decode_steps: m.decode_steps,
        decode_batches: m.decode_batches,
        decode_batch_sessions: m.decode_batch_sessions,
        dispatches: m.decode_dispatches.clone(),
        spills: m.spills,
        prefetches: m.prefetches,
        deferred: m.requests_deferred,
        finished: m.requests_finished,
    }
}

/// A kv_mem_limit tight enough that the workload must spill mid-run, big
/// enough that the largest request still fits, derived from the
/// scheduler's own projection accounting (stays calibrated if the
/// formulas change).
fn tight_limit(policy: &str) -> usize {
    let probe = sched(1, None, policy);
    let max_len = requests().iter().map(|r| r.prompt.len()).max().unwrap();
    probe.projected_bytes(max_len) + probe.retained_bytes(max_len)
}

#[test]
fn sharded_decode_is_bit_identical_without_tiering_pressure() {
    for policy in ["lava", "h2o", "snapkv"] {
        let base = run(1, None, policy);
        assert_eq!(base.finished, 8, "{policy}: all requests complete");
        assert_eq!(base.spills, 0, "{policy}: no limit, no spills");
        for workers in [2usize, 4] {
            let sharded = run(workers, None, policy);
            assert_eq!(base, sharded, "{policy}: workers={workers} changed the results");
        }
    }
}

#[test]
fn sharded_decode_is_bit_identical_with_spills_mid_run() {
    let limit = tight_limit("lava");
    let base = run(1, Some(limit), "lava");
    assert_eq!(base.finished, 8, "all requests complete under pressure");
    assert!(base.spills > 0, "limit {limit} must force spills mid-run");
    assert!(base.prefetches > 0, "spilled layers must come back before decode");
    for workers in [2usize, 4] {
        let sharded = run(workers, Some(limit), "lava");
        assert_eq!(
            base, sharded,
            "workers={workers}: tiering decisions or tokens diverged"
        );
    }
}

#[test]
fn wide_pools_actually_fan_out() {
    // sanity check that width > 1 really exercises the pool (otherwise the
    // equivalence above would be vacuous)
    let mut s = sched(4, None, "lava");
    for req in requests() {
        s.submit(req).unwrap();
    }
    s.run_to_completion().unwrap();
    let m = &s.engine.metrics;
    assert_eq!(m.workers, 4);
    assert!(m.worker_rounds > 0, "decode rounds must go through the pool");
    assert!(m.worker_busy_secs.iter().sum::<f64>() > 0.0);
}
