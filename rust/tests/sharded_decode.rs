//! Worker-count and pool-mode equivalence suite (ISSUEs 4, 10).
//!
//! The scheduler plans every decode round (bucket groups, the sequential
//! tiered arm, spill victims) on the serving thread before fanning units
//! out over the worker pool, so neither the pool width nor the dispatcher
//! may be observable in the results: for workers ∈ {1, 2, 4} and for both
//! pool modes (persistent injector vs the scoped oracle), a mixed
//! same+cross-bucket workload must produce bit-identical tokens, statuses,
//! per-request KV sizes and budgets, and identical eviction/tier decision
//! counters (decode steps, per-bucket dispatch counts, spills, prefetches,
//! deferrals) — with tiering off, with tiering on under a limit tight
//! enough that layers spill mid-run, and with chunk-major streaming
//! prefill + Q8 carries on top.
//!
//! The suite also covers the persistent pool's failure-domain contract
//! (one poisoned unit fails its own request; the round, the pool, and
//! later submissions keep working) and per-worker device pinning.

use std::collections::BTreeMap;

use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, FinishStatus, GenerateRequest};
use lava::coordinator::pool::PoolMode;
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::model::backend::MockBackend;

fn sched(workers: usize, limit: Option<usize>, policy: &str) -> Scheduler<MockBackend> {
    let mut mock = MockBackend::new(MockBackend::default_config());
    mock.hot_positions = vec![30, 31, 32];
    mock.seed = 5;
    let engine = Engine::new(mock, EngineOptions::new(Policy::by_name(policy).unwrap(), 24));
    Scheduler::new(
        engine,
        SchedulerOptions {
            kv_mem_limit: limit,
            max_active: 8,
            prefill_every: 2,
            max_prefill_batch: 4,
            workers,
            ..Default::default()
        },
    )
}

/// Mixed workload: four prompts in one shape/capacity bucket (distinct
/// contents, so caches and scores genuinely differ within a group) plus
/// four longer prompts across other buckets.
fn requests() -> Vec<GenerateRequest> {
    let lens = [100usize, 104, 96, 100, 300, 280, 200, 200];
    lens.iter()
        .enumerate()
        .map(|(i, &n)| GenerateRequest {
            prompt: (0..n).map(|t| ((t * (i + 2) + i) % 251) as i32).collect(),
            max_new_tokens: 6,
        })
        .collect()
}

/// One request's width-independent outcome.
#[derive(Debug, PartialEq)]
struct ResultRow {
    id: u64,
    status: FinishStatus,
    tokens: Vec<i32>,
    kv_after: usize,
    budgets: Vec<usize>,
}

/// Everything about a run that must not depend on the pool width.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    results: Vec<ResultRow>,
    decode_steps: u64,
    decode_batches: u64,
    decode_batch_sessions: u64,
    dispatches: BTreeMap<usize, u64>,
    spills: u64,
    prefetches: u64,
    deferred: u64,
    finished: u64,
}

/// Streaming-prefill scheduler with everything env-sensitive pinned
/// explicitly (pool mode, chunking, streaming eviction, Q8 carries,
/// chunk-major order) so the persistent-vs-scoped comparison cannot be
/// perturbed by the CI matrix's env knobs.
fn sched_stream(workers: usize, limit: Option<usize>, mode: PoolMode) -> Scheduler<MockBackend> {
    let mut mock = MockBackend::new(MockBackend::default_config());
    mock.hot_positions = vec![30, 31, 32];
    mock.seed = 5;
    let mut eopts = EngineOptions::new(Policy::by_name("lava").unwrap(), 24);
    eopts.stream_layer_major = false;
    eopts.carry_q8 = true;
    let engine = Engine::new(mock, eopts);
    Scheduler::new(
        engine,
        SchedulerOptions {
            kv_mem_limit: limit,
            max_active: 8,
            prefill_every: 2,
            max_prefill_batch: 4,
            workers,
            prefill_chunk: Some(96),
            prefill_chunk_budget: None,
            prefill_stream_evict: true,
            pool_mode: mode,
            ..Default::default()
        },
    )
}

fn run(workers: usize, limit: Option<usize>, policy: &str) -> Fingerprint {
    finish(sched(workers, limit, policy))
}

fn finish(mut s: Scheduler<MockBackend>) -> Fingerprint {
    for req in requests() {
        s.submit(req).unwrap();
    }
    let mut done = s.run_to_completion().unwrap();
    done.sort_by_key(|(id, _)| *id);
    let results = done
        .into_iter()
        .map(|(id, r)| ResultRow {
            id,
            status: r.status,
            tokens: r.tokens,
            kv_after: r.kv_bytes_after_prefill,
            budgets: r.budgets,
        })
        .collect();
    let m = &s.engine.metrics;
    Fingerprint {
        results,
        decode_steps: m.decode_steps,
        decode_batches: m.decode_batches,
        decode_batch_sessions: m.decode_batch_sessions,
        dispatches: m.decode_dispatches.clone(),
        spills: m.spills,
        prefetches: m.prefetches,
        deferred: m.requests_deferred,
        finished: m.requests_finished,
    }
}

/// A kv_mem_limit tight enough that the workload must spill mid-run, big
/// enough that the largest request still fits, derived from the
/// scheduler's own projection accounting (stays calibrated if the
/// formulas change).
fn tight_limit(policy: &str) -> usize {
    let probe = sched(1, None, policy);
    let max_len = requests().iter().map(|r| r.prompt.len()).max().unwrap();
    probe.projected_bytes(max_len) + probe.retained_bytes(max_len)
}

#[test]
fn sharded_decode_is_bit_identical_without_tiering_pressure() {
    for policy in ["lava", "h2o", "snapkv"] {
        let base = run(1, None, policy);
        assert_eq!(base.finished, 8, "{policy}: all requests complete");
        assert_eq!(base.spills, 0, "{policy}: no limit, no spills");
        for workers in [2usize, 4] {
            let sharded = run(workers, None, policy);
            assert_eq!(base, sharded, "{policy}: workers={workers} changed the results");
        }
    }
}

#[test]
fn sharded_decode_is_bit_identical_with_spills_mid_run() {
    let limit = tight_limit("lava");
    let base = run(1, Some(limit), "lava");
    assert_eq!(base.finished, 8, "all requests complete under pressure");
    assert!(base.spills > 0, "limit {limit} must force spills mid-run");
    assert!(base.prefetches > 0, "spilled layers must come back before decode");
    for workers in [2usize, 4] {
        let sharded = run(workers, Some(limit), "lava");
        assert_eq!(
            base, sharded,
            "workers={workers}: tiering decisions or tokens diverged"
        );
    }
}

#[test]
fn wide_pools_actually_fan_out() {
    // sanity check that width > 1 really exercises the pool (otherwise the
    // equivalence above would be vacuous)
    let mut s = sched(4, None, "lava");
    for req in requests() {
        s.submit(req).unwrap();
    }
    s.run_to_completion().unwrap();
    let m = &s.engine.metrics;
    assert_eq!(m.workers, 4);
    assert!(m.worker_rounds > 0, "decode rounds must go through the pool");
    assert!(m.worker_busy_secs.iter().sum::<f64>() > 0.0);
}

#[test]
fn persistent_and_scoped_pools_are_bit_identical_with_streaming_and_tiering() {
    // the hardest configuration: tiering under mid-run spill pressure,
    // chunk-major streaming prefill, Q8 carries — the whole worker-scratch
    // surface (score buffers, dequant slots) is live, and the persistent
    // injector must still reproduce the scoped oracle bit for bit
    // calibrate the limit from the streaming configuration's own
    // projection (the plain-path tight_limit would be env-insensitive but
    // looser under streaming's flat transients)
    let probe = sched_stream(1, None, PoolMode::Scoped);
    let limit = probe.projected_bytes(300) + probe.retained_bytes(300);
    let base = finish(sched_stream(1, Some(limit), PoolMode::Scoped));
    assert_eq!(base.finished, 8, "all requests complete under pressure");
    assert!(base.spills > 0, "limit {limit} must force spills mid-run");
    for workers in [1usize, 2, 4] {
        for mode in [PoolMode::Persistent, PoolMode::Scoped] {
            let fp = finish(sched_stream(workers, Some(limit), mode));
            assert_eq!(
                base, fp,
                "workers={workers} mode={mode:?} diverged from the scoped width-1 oracle"
            );
        }
    }
}

#[test]
fn prefill_panic_fails_only_the_poisoned_request() {
    // four same-bucket prompts admit as one prefill batch fan-out; one
    // contains the poison token, so exactly its unit panics inside the
    // mock's embed — the pool must surface that as one Failed result while
    // the other units of the same round complete
    let poison = 999i32;
    let mut mock = MockBackend::new(MockBackend::default_config());
    mock.seed = 5;
    mock.panic_on_embed_token = Some(poison);
    let engine = Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
    let mut s = Scheduler::new(
        engine,
        SchedulerOptions {
            max_active: 8,
            prefill_every: 1,
            max_prefill_batch: 4,
            workers: 2,
            pool_mode: PoolMode::Persistent,
            prefill_chunk: None,
            ..Default::default()
        },
    );
    for (i, &n) in [100usize, 104, 96].iter().enumerate() {
        s.submit(GenerateRequest {
            prompt: (0..n).map(|t| ((t * (i + 2) + i) % 251) as i32).collect(),
            max_new_tokens: 4,
        })
        .unwrap();
    }
    let mut bad: Vec<i32> = (0..100).map(|t| (t % 251) as i32).collect();
    bad[50] = poison;
    let poisoned_id = s.submit(GenerateRequest { prompt: bad, max_new_tokens: 4 }).unwrap();
    let mut done = s.run_to_completion().unwrap();
    done.sort_by_key(|(id, _)| *id);
    assert_eq!(done.len(), 4, "every request must come back, failed or not");
    for (id, r) in &done {
        if *id == poisoned_id {
            assert_eq!(r.status, FinishStatus::Failed);
            let err = r.error.as_deref().unwrap_or_default();
            assert!(err.contains("panicked"), "error must name the panic: {err}");
            assert!(err.contains("mock poison"), "panic message must survive: {err}");
        } else {
            assert_eq!(r.status, FinishStatus::Completed, "{:?}", r.error);
            assert_eq!(r.tokens.len(), 4, "healthy batch members decode fully");
        }
    }
    assert_eq!(s.engine.metrics.requests_failed, 1);
    assert_eq!(s.engine.metrics.requests_finished, 3);

    // the pool must keep serving after containment: a clean request
    // submitted afterwards goes through the same workers and completes
    s.submit(GenerateRequest {
        prompt: (0..100).map(|t| ((t * 7 + 3) % 251) as i32).collect(),
        max_new_tokens: 3,
    })
    .unwrap();
    let done2 = s.run_to_completion().unwrap();
    assert_eq!(done2.len(), 1);
    assert_eq!(done2[0].1.status, FinishStatus::Completed, "{:?}", done2[0].1.error);
    assert_eq!(done2[0].1.tokens.len(), 3);
}

#[test]
fn decode_panic_fails_only_the_crossing_session() {
    // three prompts in distinct capacity buckets decode as three units per
    // round; the mock panics when a decode crosses position 102, which
    // only the 100-token session ever reaches
    let mut mock = MockBackend::new(MockBackend::default_config());
    mock.seed = 5;
    mock.panic_at_decode_pos = Some(102);
    let engine = Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
    let mut s = Scheduler::new(
        engine,
        SchedulerOptions {
            max_active: 8,
            prefill_every: 1,
            max_prefill_batch: 1,
            workers: 2,
            pool_mode: PoolMode::Persistent,
            prefill_chunk: None,
            ..Default::default()
        },
    );
    let mut doomed_id = 0;
    for (i, &n) in [100usize, 200, 300].iter().enumerate() {
        let id = s
            .submit(GenerateRequest {
                prompt: (0..n).map(|t| ((t * (i + 2) + i) % 251) as i32).collect(),
                max_new_tokens: 6,
            })
            .unwrap();
        if n == 100 {
            doomed_id = id;
        }
    }
    let mut done = s.run_to_completion().unwrap();
    done.sort_by_key(|(id, _)| *id);
    assert_eq!(done.len(), 3);
    for (id, r) in &done {
        if *id == doomed_id {
            assert_eq!(r.status, FinishStatus::Failed);
            let err = r.error.as_deref().unwrap_or_default();
            assert!(err.contains("mock poison: decode"), "panic message must survive: {err}");
        } else {
            assert_eq!(r.status, FinishStatus::Completed, "{:?}", r.error);
            assert_eq!(r.tokens.len(), 6, "the other units of the round keep decoding");
        }
    }
    assert_eq!(s.engine.metrics.requests_failed, 1);
    assert_eq!(s.engine.metrics.requests_finished, 2);
}

#[test]
fn persistent_workers_pin_devices_consistently() {
    // the mock backend *asserts* the pinning contract (a thread that
    // rebinds a different device panics, which the fingerprint tests would
    // surface as Failed results) — here we additionally check the pool
    // really bound multiple threads across the mock's two device slots
    let mut s = sched_stream(4, None, PoolMode::Persistent);
    for req in requests() {
        s.submit(req).unwrap();
    }
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 8);
    let bindings = s.engine.backend.device_bindings();
    assert!(!bindings.is_empty(), "workers must bind their device slot");
    let device_count = 2;
    assert!(bindings.iter().all(|(_, d)| *d < device_count), "slots map into device_count");
    // each thread appears once: the mock records a thread on first bind
    // and *panics* if it ever rebinds a different device, so consistency
    // is enforced by the run itself — here we check the fan-out really
    // bound more than the serving thread
    let threads: std::collections::BTreeSet<_> = bindings.iter().map(|(t, _)| *t).collect();
    assert_eq!(threads.len(), bindings.len(), "one binding per thread");
    assert!(threads.len() >= 2, "a width-4 run must bind more than one thread");
}
