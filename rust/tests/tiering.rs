//! Tiered KV store acceptance suite.
//!
//! The contract (ISSUE 2): under a `kv_mem_limit` small enough that the
//! seed scheduler defers at least half of a mixed workload, the tiered
//! scheduler completes every request, hot-tier bytes never exceed the
//! limit (asserted via metrics), and decode outputs match the untiered
//! baseline within the documented Q8 tolerance — with the deterministic
//! mock backend the decode logits are unchanged by Q8 K/V error, so
//! "within tolerance" is asserted as exact token equality, while the K/V
//! numeric tolerance itself is property-tested in `kvcache::warm`.

use std::collections::BTreeMap;

use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, FinishStatus, GenerateRequest};
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::model::backend::MockBackend;

fn sched(limit: Option<usize>, tiering: bool) -> Scheduler<MockBackend> {
    let mock = MockBackend::new(MockBackend::default_config());
    let engine = Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
    Scheduler::new(
        engine,
        SchedulerOptions { kv_mem_limit: limit, tiering, ..Default::default() },
    )
}

/// Mixed workload: three shape buckets (prompt lengths 100/200/400).
fn mixed_workload() -> Vec<GenerateRequest> {
    (0..8)
        .map(|i| {
            let n = match i % 3 {
                0 => 100,
                1 => 200,
                _ => 400,
            };
            GenerateRequest {
                prompt: (0..n).map(|t| ((t + i * 7) % 251) as i32).collect(),
                max_new_tokens: 6,
            }
        })
        .collect()
}

/// Tight enough that the seed scheduler defers most of the workload, big
/// enough that the largest request's prefill peak still fits (so nothing
/// is rejected outright): one len-400 projected peak plus one retained
/// session, derived from the same accounting admission uses so the limit
/// tracks the pricing model (carries + observation panels + hidden rows).
fn limit() -> usize {
    let probe = sched(None, false);
    probe.projected_bytes(400) + probe.retained_bytes(400)
}

fn run(
    s: &mut Scheduler<MockBackend>,
) -> (BTreeMap<u64, Vec<i32>>, BTreeMap<u64, FinishStatus>) {
    let mut tokens = BTreeMap::new();
    let mut statuses = BTreeMap::new();
    for req in mixed_workload() {
        s.submit(req).unwrap();
    }
    for (id, r) in s.run_to_completion().unwrap() {
        tokens.insert(id, r.tokens.clone());
        statuses.insert(id, r.status);
    }
    (tokens, statuses)
}

#[test]
fn tiered_completes_workload_the_seed_defers() {
    // seed behavior (tiering off): everything eventually completes, but at
    // least half the workload bounces off admission at least once
    let limit = limit();
    let mut seed = sched(Some(limit), false);
    let (_, seed_status) = run(&mut seed);
    assert_eq!(seed_status.len(), 8);
    assert!(
        seed_status.values().all(|s| *s == FinishStatus::Completed),
        "seed must defer, not reject, this workload"
    );
    assert!(
        seed.engine.metrics.requests_deferred >= 4,
        "limit must be tight enough to defer at least half the workload, got {} deferrals",
        seed.engine.metrics.requests_deferred
    );
    assert_eq!(seed.engine.metrics.spills, 0);

    // tiered: same limit, all requests complete, hot tier stays bounded
    let mut tiered = sched(Some(limit), true);
    let (tiered_tokens, tiered_status) = run(&mut tiered);
    assert_eq!(tiered_status.len(), 8);
    for (id, status) in &tiered_status {
        assert_eq!(
            *status,
            FinishStatus::Completed,
            "tiered request {id} must complete"
        );
    }
    let m = &tiered.engine.metrics;
    assert!(
        m.peak_hot_kv_bytes <= limit,
        "hot-tier bytes exceeded kv_mem_limit: {} > {limit}",
        m.peak_hot_kv_bytes
    );
    assert!(m.spills > 0, "pressure must move layers to the warm tier");
    assert!(m.prefetches > 0, "spilled layers must come back before decode");
    assert!(m.peak_warm_kv_bytes > 0);
    assert!(
        m.requests_deferred <= seed.engine.metrics.requests_deferred,
        "spilling must absorb pressure the seed paid for in deferrals: {} vs {}",
        m.requests_deferred,
        seed.engine.metrics.requests_deferred
    );

    // decode outputs must match the untiered, unlimited baseline within the
    // documented Q8 tolerance; the mock backend's logits are independent of
    // the (quantization-perturbed) hidden state, so equality is exact here
    let mut baseline = sched(None, false);
    let (base_tokens, base_status) = run(&mut baseline);
    assert!(base_status.values().all(|s| *s == FinishStatus::Completed));
    assert_eq!(
        tiered_tokens, base_tokens,
        "tiered decode outputs diverged from the untiered baseline"
    );
}

#[test]
fn hot_tier_bounded_throughout_not_just_at_peaks() {
    // drive tick-by-tick and check the live hot gauge after every tick
    let limit = limit();
    let mut s = sched(Some(limit), true);
    for req in mixed_workload() {
        s.submit(req).unwrap();
    }
    let mut ticks = 0;
    while (s.pending_count() > 0 || s.active_count() > 0) && ticks < 10_000 {
        s.tick().unwrap();
        ticks += 1;
        assert!(
            s.engine.metrics.hot_kv_bytes <= limit,
            "tick {ticks}: hot gauge {} over limit {limit}",
            s.engine.metrics.hot_kv_bytes
        );
    }
    assert!(ticks < 10_000, "scheduler failed to drain");
    assert_eq!(s.tier.warm_bytes(), 0, "drained scheduler must hold no warm blocks");
    assert_eq!(s.engine.metrics.requests_finished, 8);
}

#[test]
fn cancel_mid_flight_releases_warm_blocks() {
    let mut s = sched(Some(limit()), true);
    let mut ids = Vec::new();
    for req in mixed_workload() {
        ids.push(s.submit(req).unwrap());
    }
    // run until something has spilled, then cancel every in-flight request
    // (each tick drains its own completions, so collect as we go)
    let mut done = Vec::new();
    let mut ticks = 0;
    while s.engine.metrics.spills == 0 && ticks < 10_000 {
        done.extend(s.tick().unwrap().finished);
        ticks += 1;
    }
    assert!(s.engine.metrics.spills > 0, "workload must generate spills");
    for id in &ids {
        s.cancel(*id);
    }
    assert_eq!(s.active_count(), 0);
    assert_eq!(
        s.tier.warm_bytes(),
        0,
        "canceled sessions must not leak warm blocks"
    );
    done.extend(s.run_to_completion().unwrap());
    assert_eq!(done.len(), 8, "every id must resolve");
}

#[test]
fn tiering_without_limit_is_inert() {
    let mut s = sched(None, true);
    for req in mixed_workload() {
        s.submit(req).unwrap();
    }
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 8);
    let m = &s.engine.metrics;
    assert_eq!(m.spills, 0, "no limit, no pressure, no spills");
    assert_eq!(m.prefetches, 0);
    assert_eq!(m.requests_deferred, 0);
}
