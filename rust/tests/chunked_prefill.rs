//! Chunked-prefill acceptance suite (ISSUE 7): end-to-end scheduler
//! fingerprints. With `prefill_chunk` set and no interleave budget, a tiered
//! workload under memory pressure must reproduce the monolithic run exactly —
//! per-request tokens, per-layer budgets, retained KV bytes, and the
//! spill/prefetch counters — at every chunk size (one full bucket, a
//! misaligned chunk, a tiny chunk). With a decode-interleave budget the
//! per-request results must still match (only dispatch timing changes).
//!
//! Engine-level bit-identity of the caches themselves (keep-sets, scores,
//! positions) is covered by the in-module tests in `coordinator::engine`;
//! this file checks the scheduler composition on top.

use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, FinishStatus, GenerateRequest};
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::model::backend::MockBackend;

fn engine(policy: &str) -> Engine<MockBackend> {
    let mock = MockBackend::new(MockBackend::default_config());
    Engine::new(mock, EngineOptions::new(Policy::by_name(policy).unwrap(), 24))
}

fn req(len: usize, offset: usize, max_new: usize) -> GenerateRequest {
    GenerateRequest {
        prompt: (0..len).map(|t| ((t + offset) % 251) as i32).collect(),
        max_new_tokens: max_new,
    }
}

/// Everything a chunked run must reproduce from the monolithic baseline.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    tokens: Vec<Vec<i32>>,
    budgets: Vec<Vec<usize>>,
    kv_bytes: Vec<usize>,
    spills: u64,
    prefetches: u64,
}

/// The `tiering_spills_under_pressure` workload (4 same-shape requests under
/// a ~2-session memory limit) so the fingerprint includes real tier traffic.
fn run_pressured(
    policy: &str,
    chunk: Option<usize>,
    budget: Option<usize>,
) -> Fingerprint {
    let mut s = Scheduler::new(
        engine(policy),
        SchedulerOptions {
            prefill_chunk: chunk,
            prefill_chunk_budget: budget,
            // bit-identity fingerprints are exactly what streaming eviction
            // trades away — pin it off even under LAVA_PREFILL_STREAM=1
            prefill_stream_evict: false,
            ..Default::default()
        },
    );
    // one prefill peak + ~1 retained session, from admission's own pricing:
    // identical across chunk settings (the plain-path projection does not
    // depend on the chunk), so the fingerprints stay comparable while the
    // limit keeps forcing real spill/prefetch traffic
    s.opts.kv_mem_limit = Some(s.projected_bytes(200) + s.retained_bytes(200) * 5 / 4);
    for i in 0..4 {
        s.submit(req(200, i, 6)).unwrap();
    }
    let mut done = s.run_to_completion().unwrap();
    done.sort_by_key(|(id, _)| *id);
    assert_eq!(done.len(), 4);
    for (_, r) in &done {
        assert_eq!(r.status, FinishStatus::Completed, "{:?}", r.error);
    }
    Fingerprint {
        tokens: done.iter().map(|(_, r)| r.tokens.clone()).collect(),
        budgets: done.iter().map(|(_, r)| r.budgets.clone()).collect(),
        kv_bytes: done.iter().map(|(_, r)| r.kv_bytes_after_prefill).collect(),
        spills: s.engine.metrics.spills,
        prefetches: s.engine.metrics.prefetches,
    }
}

#[test]
fn chunked_fingerprint_matches_monolithic_under_tier_pressure() {
    // chunk sizes: exactly one (smallest) bucket, misaligned, and tiny
    for policy in ["lava", "h2o", "snapkv"] {
        let mono = run_pressured(policy, None, None);
        if policy == "lava" {
            // same recipe as the in-module tiering test: the baseline must
            // actually exercise the tier or the spill fingerprint is vacuous
            assert!(mono.spills > 0, "pressure workload must spill");
            assert!(mono.prefetches > 0, "spilled layers must prefetch back");
        }
        for chunk in [128usize, 96, 17] {
            let chunked = run_pressured(policy, Some(chunk), None);
            assert_eq!(
                chunked, mono,
                "{policy}/chunk={chunk} diverged from the monolithic fingerprint"
            );
        }
    }
}

#[test]
fn budgeted_chunked_results_match_monolithic_without_pressure() {
    // no memory limit: spill timing cannot perturb results, so even the
    // decode-interleaved schedule must reproduce per-request outputs exactly
    let run = |chunk: Option<usize>, budget: Option<usize>| {
        let mut s = Scheduler::new(
            engine("lava"),
            SchedulerOptions {
                prefill_chunk: chunk,
                prefill_chunk_budget: budget,
                // monolithic-equality test: streaming must stay off here
                prefill_stream_evict: false,
                ..Default::default()
            },
        );
        let lens = [100usize, 200, 420, 64];
        for (i, len) in lens.iter().enumerate() {
            s.submit(req(*len, i * 3, 3 + i)).unwrap();
        }
        let mut done = s.run_to_completion().unwrap();
        done.sort_by_key(|(id, _)| *id);
        done.into_iter()
            .map(|(_, r)| {
                assert_eq!(r.status, FinishStatus::Completed, "{:?}", r.error);
                (r.tokens, r.budgets, r.kv_bytes_after_prefill)
            })
            .collect::<Vec<_>>()
    };
    let mono = run(None, None);
    for (chunk, budget) in [(128usize, Some(32)), (96, Some(64)), (17, Some(200))] {
        assert_eq!(
            run(Some(chunk), budget),
            mono,
            "chunk={chunk} budget={budget:?} diverged from monolithic results"
        );
    }
}

#[test]
fn chunked_run_reports_prefill_fill_gauges() {
    let mut s = Scheduler::new(
        engine("lava"),
        SchedulerOptions {
            prefill_chunk: Some(96),
            prefill_chunk_budget: Some(64),
            ..Default::default()
        },
    );
    s.submit(req(300, 0, 4)).unwrap();
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    let m = &s.engine.metrics;
    assert!(!m.prefill_fills.is_empty(), "chunk dispatches must be observed");
    let util = m.prefill_bucket_utilization();
    assert!(util > 0.0 && util <= 1.0, "utilization out of range: {util}");
    // 300 tokens in 96-chunks at the 128 bucket: every dispatch pads, so
    // padded tokens must be visible in the gauge
    assert!(m.prefill_padded_tokens > 0, "misaligned chunks must report padding");
}
