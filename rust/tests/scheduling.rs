//! Scheduler lifecycle tests: stable request ids under admission deferral,
//! FIFO fairness, batched same-bucket admission, cancellation, and the
//! livelock regression — the contract the serving loop gives later PRs.

use std::collections::BTreeMap;

use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, FinishStatus, GenerateRequest};
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::model::backend::MockBackend;

fn sched(opts: SchedulerOptions) -> Scheduler<MockBackend> {
    let mock = MockBackend::new(MockBackend::default_config());
    let engine = Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
    Scheduler::new(engine, opts)
}

fn req(n: usize, out: usize) -> GenerateRequest {
    GenerateRequest { prompt: (0..n).map(|i| (i % 251) as i32).collect(), max_new_tokens: out }
}

#[test]
fn ids_stable_under_memory_pressure_and_deferral() {
    // more requests than max_active, under a memory limit: every result must
    // map back to the id submit() returned, even for deferred requests
    let mut s = sched(SchedulerOptions { max_active: 2, ..Default::default() });
    // one prefill peak plus ~2 retained sessions, from admission's own
    // pricing so the squeeze survives accounting-model changes
    s.opts.kv_mem_limit = Some(s.projected_bytes(200) + 2 * s.retained_bytes(200));
    let mut expected: BTreeMap<u64, usize> = BTreeMap::new();
    for i in 0..6 {
        let out = i + 2; // distinct generation length per request
        let id = s.submit(req(200, out)).unwrap();
        assert!(expected.insert(id, out).is_none(), "ids must be unique");
    }
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 6);
    for (id, r) in &done {
        assert_eq!(r.id, *id, "result.id must match the key");
        assert_eq!(r.status, FinishStatus::Completed);
        let want = expected.remove(id).expect("unknown or duplicated id");
        assert_eq!(
            r.tokens.len(),
            want,
            "id {id} got a different request's result (deferral must not re-id)"
        );
    }
    assert!(expected.is_empty(), "every submitted id must come back");
}

#[test]
fn fifo_order_preserved_across_deferrals() {
    // limit admits ~2 sessions at a time (one prefill peak + ~1 retained
    // session, priced by admission's own accounting); deferred requests are
    // requeued at their original position and admission stops at the first
    // deferral, so completion order == submission order
    let mut s = sched(SchedulerOptions::default());
    s.opts.kv_mem_limit = Some(s.projected_bytes(200) + s.retained_bytes(200) * 5 / 4);
    let mut ids = Vec::new();
    for _ in 0..4 {
        ids.push(s.submit(req(200, 6)).unwrap());
    }
    let done = s.run_to_completion().unwrap();
    let finished_order: Vec<u64> = done.iter().map(|(id, _)| *id).collect();
    assert_eq!(finished_order, ids, "deferral must not reorder a uniform FIFO workload");
}

#[test]
fn same_bucket_requests_prefill_as_one_group() {
    // two bucket-128 prompts around a bucket-512 prompt: the first admission
    // round takes the 128s together and leaves the 512 queued
    let mut s = sched(SchedulerOptions::default());
    let a = s.submit(req(100, 8)).unwrap();
    let b = s.submit(req(400, 8)).unwrap();
    let c = s.submit(req(110, 8)).unwrap();
    s.tick().unwrap();
    assert_eq!(s.active_count(), 2, "same-bucket pair admitted together");
    assert_eq!(s.pending_count(), 1, "other-bucket request stays queued");
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 3);
    for want in [a, b, c] {
        assert!(done.iter().any(|(id, _)| *id == want));
    }
}

#[test]
fn warm_bucket_preference_cannot_starve_other_buckets() {
    // steady bucket-128 traffic with one old bucket-512 request at the queue
    // head: warm preference may bypass it only a bounded number of admission
    // rounds, so the 512 must complete even while 128s keep arriving
    let mut s = sched(SchedulerOptions {
        max_active: 1,
        max_prefill_batch: 1,
        prefill_every: 1,
        ..Default::default()
    });
    // prime the warm bucket with one 128 request, then queue the victim
    s.submit(req(100, 2)).unwrap();
    let victim = s.submit(req(400, 2)).unwrap();
    let mut victim_done = false;
    for _ in 0..200 {
        // keep warm-bucket work always available
        if s.pending_count() < 3 {
            s.submit(req(100, 2)).unwrap();
        }
        let report = s.tick().unwrap();
        if report.finished.iter().any(|(id, _)| *id == victim) {
            victim_done = true;
            break;
        }
    }
    assert!(victim_done, "warm-bucket preference starved the other bucket");
}

#[test]
fn cancel_mid_decode_returns_partial_result() {
    let mut s = sched(SchedulerOptions::default());
    let id1 = s.submit(req(100, 20)).unwrap();
    let id2 = s.submit(req(100, 20)).unwrap();
    s.tick().unwrap(); // prefill both (same bucket) + one decode round
    s.tick().unwrap();
    assert_eq!(s.active_count(), 2);
    assert!(s.cancel(id1));
    assert_eq!(s.active_count(), 1);
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    let r1 = &done.iter().find(|(id, _)| *id == id1).unwrap().1;
    let r2 = &done.iter().find(|(id, _)| *id == id2).unwrap().1;
    assert_eq!(r1.status, FinishStatus::Canceled);
    assert!(
        !r1.tokens.is_empty() && r1.tokens.len() < 20,
        "canceled mid-decode keeps partial output, got {} tokens",
        r1.tokens.len()
    );
    assert_eq!(r2.status, FinishStatus::Completed);
    assert_eq!(r2.tokens.len(), 20);
    assert_eq!(s.engine.metrics.requests_canceled, 1);
}

#[test]
fn livelock_repro_terminates_with_rejection() {
    // Regression: a single request larger than kv_mem_limit used to make
    // run_to_completion spin forever (empty active set, non-empty queue).
    let mut s = sched(SchedulerOptions {
        kv_mem_limit: Some(2_000),
        ..Default::default()
    });
    // push directly so the admission-time guard (not submit's) is on trial
    s.queue.push(req(300, 4)).unwrap();
    let ok = s.submit(req(300, 4));
    assert!(ok.is_err(), "submit-time guard should also refuse it");
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 1, "the queued oversized request must terminate");
    assert_eq!(done[0].1.status, FinishStatus::Rejected);
}

#[test]
fn scheduler_metrics_cover_all_steps() {
    let mut s = sched(SchedulerOptions::default());
    for _ in 0..3 {
        s.submit(req(100, 5)).unwrap();
    }
    s.run_to_completion().unwrap();
    let m = &s.engine.metrics;
    assert_eq!(m.requests_finished, 3);
    assert_eq!(m.ttft_secs.len(), 3, "one TTFT sample per admitted request");
    assert_eq!(m.queue_wait_secs.len(), 3);
    assert!(m.admission_rounds >= 1);
    // prefill yields the first token; the remaining 4 per request decode
    assert_eq!(m.decode_steps, 3 * 4);
    assert!(m.decode_tok_per_sec() > 0.0);
    assert!(m.mean_ttft_ms() >= m.mean_queue_wait_ms());
}
