//! Cross-module integration tests on the mock backend: policies x engine x
//! scheduler x workloads compose correctly, with the paper's invariants
//! (budget conservation, window protection, cascade monotonicity) holding
//! end to end.

use lava::bench::eval;
use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::model::backend::MockBackend;
use lava::util::prop;
use lava::util::rng::Rng;
use lava::workloads;

fn engine_with(policy: &str, budget: usize, hot: Vec<usize>) -> Engine<MockBackend> {
    let mut mock = MockBackend::new(MockBackend::default_config());
    mock.hot_positions = hot;
    Engine::new(mock, EngineOptions::new(Policy::by_name(policy).unwrap(), budget))
}

#[test]
fn every_policy_serves_every_task() {
    for policy in Policy::all_names() {
        let mut engine = engine_with(policy, 24, vec![50]);
        for spec in workloads::longbench_suite() {
            let mut rng = Rng::new(9);
            let insts = workloads::generate(spec.name, &mut rng, 160, 1);
            let score = eval::run_instances(&mut engine, &insts).unwrap();
            assert!((0.0..=1.0).contains(&score), "{policy}/{}", spec.name);
        }
    }
}

#[test]
fn budget_conservation_across_policies() {
    // total kept entries never exceed 𝔹, and dynamic budgets sum to 𝔹
    for policy in ["snapkv", "ada-snapkv", "pyramidkv", "cake", "lava", "vatp"] {
        let mut engine = engine_with(policy, 32, vec![]);
        let prompt: Vec<i32> = (0..300).map(|i| (i % 251) as i32).collect();
        let (sess, _) = engine.prefill_only(&prompt).unwrap();
        let total: usize = sess.caches.iter().map(|c| c.total_entries()).sum();
        let budget_total = 32 * 4 * 4;
        assert!(total <= budget_total, "{policy}: {total}");
        assert_eq!(sess.budgets.iter().sum::<usize>(), budget_total, "{policy}");
        for c in &sess.caches {
            c.check_invariants().unwrap();
        }
    }
}

#[test]
fn cascade_recompression_is_monotone() {
    // After Algorithm 2, no layer may exceed its final budget, and every
    // head keeps at least the protected window.
    let mut engine = engine_with("lava", 40, vec![10, 200]);
    let prompt: Vec<i32> = (0..400).map(|i| (i % 250) as i32).collect();
    let (sess, _) = engine.prefill_only(&prompt).unwrap();
    for (l, c) in sess.caches.iter().enumerate() {
        assert!(
            c.total_entries() <= sess.budgets[l],
            "layer {l}: {} > {}",
            c.total_entries(),
            sess.budgets[l]
        );
        for h in 0..4 {
            assert!(c.head_len(h) >= 16, "window must survive recompression");
            // window = positions 384..400 present
            let positions: Vec<i32> = (0..c.head_len(h)).map(|i| c.position(h, i)).collect();
            for p in 395..400 {
                assert!(positions.contains(&p), "recent {p} missing in layer {l}");
            }
        }
    }
}

#[test]
fn decode_after_compression_is_stable() {
    // generate well past the prefill budget; caches stay consistent
    let mut engine = engine_with("lava", 24, vec![]);
    let req = GenerateRequest {
        prompt: (0..200).map(|i| (i % 13) as i32).collect(),
        max_new_tokens: 40,
    };
    let mut sess = engine.new_session(&req);
    engine.prefill(&mut sess).unwrap();
    for _ in 0..40 {
        if sess.is_done() {
            break;
        }
        engine.decode_step(&mut sess).unwrap();
        for c in &sess.caches {
            c.check_invariants().unwrap();
        }
    }
    assert_eq!(sess.generated.len(), 40);
}

#[test]
fn scheduler_matches_sequential_results() {
    // continuous batching must not change outputs (same tokens as running
    // each request alone)
    let mut rng = Rng::new(5);
    let instances: Vec<_> = (0..4).map(|_| workloads::needle_qa(&mut rng, 160, 4)).collect();

    // sequential
    let mut seq_tokens = Vec::new();
    {
        let mut engine = engine_with("lava", 24, vec![]);
        for inst in &instances {
            let r = engine
                .generate(&GenerateRequest {
                    prompt: inst.prompt.clone(),
                    max_new_tokens: 4,
                })
                .unwrap();
            seq_tokens.push(r.tokens);
        }
    }

    // scheduled
    let engine = engine_with("lava", 24, vec![]);
    let mut sched = Scheduler::new(engine, SchedulerOptions::default());
    for inst in &instances {
        sched
            .submit(GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 4 })
            .unwrap();
    }
    let mut done = sched.run_to_completion().unwrap();
    done.sort_by_key(|(id, _)| *id);
    for ((_, r), expect) in done.iter().zip(&seq_tokens) {
        assert_eq!(&r.tokens, expect, "batching changed results");
    }
}

#[test]
fn dynamic_head_budgets_follow_attention() {
    // make kv-head 3's group attend overwhelmingly to hot positions; flat
    // selection should give it more slots than the mean policy would
    let mut engine = engine_with("ada-snapkv", 24, (40..80).collect());
    let prompt: Vec<i32> = (0..300).map(|i| (i % 251) as i32).collect();
    let (sess, _) = engine.prefill_only(&prompt).unwrap();
    let lens: Vec<usize> = (0..4).map(|h| sess.caches[0].head_len(h)).collect();
    // mock gives later q-heads stronger hot bumps -> later kv heads win slots
    assert!(lens[3] >= lens[0], "expected dynamic skew, got {lens:?}");
}

#[test]
fn prop_engine_total_entries_bounded() {
    prop::check(15, |rng| {
        let budget = 16 + rng.below(48);
        let n = 100 + rng.below(300);
        let policy = *rng.choice(&["lava", "cake", "ada-snapkv", "snapkv"]);
        let mut engine = engine_with(policy, budget, vec![]);
        let prompt: Vec<i32> = (0..n).map(|_| rng.below(256) as i32).collect();
        let (sess, _) = engine.prefill_only(&prompt).unwrap();
        let total: usize = sess.caches.iter().map(|c| c.total_entries()).sum();
        let cap = (budget * 4 * 4).min(n * 4 * 4);
        prop::assert_prop(total <= cap, "entries within budget", &(policy, total, cap))
    });
}

#[test]
fn metrics_accumulate_across_requests() {
    let mut engine = engine_with("lava", 24, vec![]);
    for _ in 0..3 {
        engine
            .generate(&GenerateRequest {
                prompt: (0..150).map(|i| i % 200).collect(),
                max_new_tokens: 5,
            })
            .unwrap();
    }
    assert_eq!(engine.metrics.requests_finished, 3);
    assert_eq!(engine.metrics.tokens_generated, 15);
    assert!(engine.metrics.peak_kv_bytes > 0);
}
