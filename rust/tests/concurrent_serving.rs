//! Concurrent serving acceptance suite (ISSUE 6): many TCP connections
//! share one serving loop; connections make progress concurrently; every
//! request gets exactly one terminal response; streamed token lines are
//! bit-identical to the non-streamed (and direct-scheduler) outputs;
//! cross-connection cancel releases hot and warm bytes; a flooded queue
//! rejects with backpressure.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};

use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::coordinator::server::Server;
use lava::model::backend::MockBackend;
use lava::util::json::Json;

fn engine() -> Engine<MockBackend> {
    let mock = MockBackend::new(MockBackend::default_config());
    Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24))
}

/// Bind an ephemeral port, move the server onto its acceptor thread, and
/// return the address clients should dial.
fn spawn_server(opts: SchedulerOptions) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv = Server::with_options(engine(), opts);
    std::thread::spawn(move || {
        let _ = srv.serve_on(listener);
    });
    addr
}

struct Client {
    reader: BufReader<TcpStream>,
    sock: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let sock = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(sock.try_clone().unwrap());
        Client { reader, sock }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.sock, "{line}").unwrap();
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "server closed the connection unexpectedly");
        Json::parse(line.trim()).unwrap()
    }

    fn metrics(&mut self) -> Json {
        self.send(r#"{"cmd": "metrics"}"#);
        self.recv().get("metrics").expect("metrics reply").clone()
    }
}

/// Deterministic request: prompt token t is `(t + offset) % 251`.
fn req(len: usize, offset: usize, max_new: usize) -> GenerateRequest {
    GenerateRequest {
        prompt: (0..len).map(|t| ((t + offset) % 251) as i32).collect(),
        max_new_tokens: max_new,
    }
}

/// The same request as a protocol object (no surrounding line framing).
fn req_obj(len: usize, offset: usize, max_new: usize, stream: bool) -> String {
    let prompt: Vec<String> = (0..len).map(|t| ((t + offset) % 251).to_string()).collect();
    format!(
        "{{\"prompt\": [{}], \"max_new_tokens\": {max_new}, \"stream\": {stream}}}",
        prompt.join(",")
    )
}

fn tokens_of(v: &Json) -> Vec<i32> {
    v.get("tokens")
        .expect("terminal response with tokens")
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i32)
        .collect()
}

fn status_of(v: &Json) -> &str {
    v.get("status").expect("terminal response with status").as_str().unwrap()
}

/// The serial seed path: the same request alone on a fresh scheduler,
/// driven by `run_to_completion`. The deterministic mock backend makes this
/// the ground truth any serving-loop schedule must reproduce exactly.
fn serial_tokens(r: &GenerateRequest) -> Vec<i32> {
    let mut s = Scheduler::new(engine(), SchedulerOptions::default());
    s.submit(r.clone()).unwrap();
    let done = s.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    done.into_iter().next().unwrap().1.tokens
}

#[test]
fn short_request_completes_while_long_request_still_decodes() {
    let addr = spawn_server(SchedulerOptions::default());

    // connection A: a long streamed generation; the first token line both
    // proves it is mid-decode and tells us its id
    let mut a = Client::connect(addr);
    a.send(&req_obj(64, 0, 500, true));
    let first = a.recv();
    assert!(first.get("token").is_some(), "streaming must start with a token line");
    assert_eq!(first.get("index").unwrap().as_usize().unwrap(), 0);
    let long_id = first.get("id").unwrap().as_usize().unwrap() as u64;

    // connection B: a short request completes while A decodes
    let mut b = Client::connect(addr);
    b.send(&req_obj(64, 5, 2, false));
    let short = b.recv();
    assert_eq!(status_of(&short), "completed");
    assert_eq!(tokens_of(&short).len(), 2);

    // A is still in flight at a moment strictly after B finished: the two
    // connections made progress concurrently
    let m = b.metrics();
    assert!(
        m.get("active_sessions").unwrap().as_usize().unwrap() >= 1,
        "the long request must still be decoding when the short one is done"
    );

    // cross-connection cancel: B cancels A's generation mid-flight, and
    // A's stream still terminates with its (canceled) response
    b.send(&format!("{{\"cmd\": \"cancel\", \"id\": {long_id}}}"));
    assert_eq!(b.recv().get("ok").unwrap().as_bool(), Some(true));
    let terminal = loop {
        let v = a.recv();
        if v.get("status").is_some() {
            break v;
        }
    };
    assert_eq!(status_of(&terminal), "canceled");
}

#[test]
fn cancel_terminates_the_stream_with_a_partial_result() {
    let addr = spawn_server(SchedulerOptions::default());
    let mut a = Client::connect(addr);
    a.send(&req_obj(64, 0, 500, true));
    let first = a.recv();
    let id = first.get("id").unwrap().as_usize().unwrap() as u64;

    let mut b = Client::connect(addr);
    b.send(&format!("{{\"cmd\": \"cancel\", \"id\": {id}}}"));
    assert_eq!(b.recv().get("ok").unwrap().as_bool(), Some(true));

    // A's stream ends with the canceled terminal carrying partial output
    let terminal = loop {
        let v = a.recv();
        if v.get("status").is_some() {
            break v;
        }
    };
    assert_eq!(status_of(&terminal), "canceled");
    let n = tokens_of(&terminal).len();
    assert!((1..500).contains(&n), "partial output expected, got {n} tokens");

    // the id is retired now, so a second cancel must report a miss
    b.send(&format!("{{\"cmd\": \"cancel\", \"id\": {id}}}"));
    assert_eq!(
        b.recv().get("ok").unwrap().as_bool(),
        Some(false),
        "double-cancel of a finished id must report false"
    );
}

#[test]
fn cancel_from_second_connection_releases_hot_and_warm_bytes() {
    // the tiering workload: tight enough to spill (one len-400 prefill
    // peak + one retained session, priced by admission's own accounting),
    // eight long generations
    let probe = Scheduler::new(engine(), SchedulerOptions::default());
    let limit = probe.projected_bytes(400) + probe.retained_bytes(400);
    let addr = spawn_server(SchedulerOptions {
        kv_mem_limit: Some(limit),
        tiering: true,
        ..Default::default()
    });
    let mut a = Client::connect(addr);
    let reqs: Vec<String> = (0..8)
        .map(|i| {
            let n = match i % 3 {
                0 => 100,
                1 => 200,
                _ => 400,
            };
            req_obj(n, i * 7, 200, false)
        })
        .collect();
    a.send(&format!("[{}]", reqs.join(",")));

    // second connection: wait for memory pressure to reach the warm tier
    let mut b = Client::connect(addr);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let m = b.metrics();
        if m.get("spills").unwrap().as_usize().unwrap() > 0 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "workload never spilled");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }

    // a fresh server assigns ids 1..=8 to the batch in submission order;
    // every one is still queued or decoding (200 tokens each), so every
    // cancel must land
    for id in 1..=8u64 {
        b.send(&format!("{{\"cmd\": \"cancel\", \"id\": {id}}}"));
        assert_eq!(
            b.recv().get("ok").unwrap().as_bool(),
            Some(true),
            "request {id} must be live when canceled"
        );
    }

    // A's batch reply arrives once all eight terminals exist: all canceled
    let reply = a.recv();
    let arr = reply.as_arr().expect("batch reply is an array");
    assert_eq!(arr.len(), 8);
    for r in arr {
        assert_eq!(status_of(r), "canceled");
    }

    // both tiers fully released, nothing left in flight
    let m = b.metrics();
    assert_eq!(m.get("active_sessions").unwrap().as_usize().unwrap(), 0);
    assert_eq!(m.get("queued_requests").unwrap().as_usize().unwrap(), 0);
    assert_eq!(m.get("canceled").unwrap().as_usize().unwrap(), 8);
    assert_eq!(
        m.get("warm_kv_mb").unwrap().as_f64().unwrap(),
        0.0,
        "canceled sessions must not leak warm blocks"
    );
    assert_eq!(
        m.get("hot_kv_mb").unwrap().as_f64().unwrap(),
        0.0,
        "canceled sessions must not leak hot bytes"
    );
}

#[test]
fn streamed_tokens_bit_identical_to_non_streamed_and_serial() {
    let addr = spawn_server(SchedulerOptions::default());
    let mut c = Client::connect(addr);
    let r = req(64, 3, 8);

    c.send(&req_obj(64, 3, 8, false));
    let plain = tokens_of(&c.recv());
    assert_eq!(plain.len(), 8);

    c.send(&req_obj(64, 3, 8, true));
    let mut streamed = Vec::new();
    let terminal = loop {
        let v = c.recv();
        if v.get("status").is_some() {
            break v;
        }
        assert_eq!(v.get("index").unwrap().as_usize().unwrap(), streamed.len());
        streamed.push(v.get("token").unwrap().as_f64().unwrap() as i32);
    };
    assert_eq!(status_of(&terminal), "completed");
    assert_eq!(streamed, plain, "streamed tokens must be bit-identical to non-streamed");
    assert_eq!(tokens_of(&terminal), plain);
    assert_eq!(plain, serial_tokens(&r), "serving loop must reproduce the serial seed path");
}

#[test]
fn interleaved_clients_each_request_exactly_one_terminal_reply() {
    let addr = spawn_server(SchedulerOptions::default());
    let mut clients = Vec::new();
    for t in 0..4usize {
        clients.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            let single = req(64, t, 3 + t);
            let b1 = req(64, t + 11, 2);
            let b2 = req(200, t + 23, 4);
            let streaming = req(64, t + 37, 5);

            // pipeline all three lines before reading anything, so this
            // connection's replies interleave with its own token stream
            c.send(&req_obj(64, t, 3 + t, false));
            c.send(&format!(
                "[{}, {}]",
                req_obj(64, t + 11, 2, false),
                req_obj(200, t + 23, 4, false)
            ));
            c.send(&req_obj(64, t + 37, 5, true));

            let mut single_reply: Option<Json> = None;
            let mut batch_reply: Option<Json> = None;
            let mut stream_reply: Option<Json> = None;
            let mut stream_id: Option<u64> = None;
            let mut stream_tokens: Vec<i32> = Vec::new();
            while single_reply.is_none() || batch_reply.is_none() || stream_reply.is_none() {
                let v = c.recv();
                if v.as_arr().is_some() {
                    assert!(
                        batch_reply.replace(v).is_none(),
                        "the batch line must get exactly one reply"
                    );
                    continue;
                }
                if v.get("token").is_some() {
                    let id = v.get("id").unwrap().as_usize().unwrap() as u64;
                    if let Some(sid) = stream_id {
                        assert_eq!(sid, id, "only the streaming request emits token lines");
                    } else {
                        stream_id = Some(id);
                    }
                    assert_eq!(v.get("index").unwrap().as_usize().unwrap(), stream_tokens.len());
                    stream_tokens.push(v.get("token").unwrap().as_f64().unwrap() as i32);
                    continue;
                }
                // a terminal response: the stream's (matched by id) or the
                // single request's — each exactly once
                let id = v.get("id").unwrap().as_usize().unwrap() as u64;
                if stream_id == Some(id) {
                    assert!(
                        stream_reply.replace(v).is_none(),
                        "the streaming request must get exactly one terminal"
                    );
                } else {
                    assert!(
                        single_reply.replace(v).is_none(),
                        "the single request must get exactly one terminal"
                    );
                }
            }

            let sr = single_reply.unwrap();
            assert_eq!(status_of(&sr), "completed");
            assert_eq!(tokens_of(&sr), serial_tokens(&single));

            let br = batch_reply.unwrap();
            let arr = br.as_arr().unwrap();
            assert_eq!(arr.len(), 2, "batch reply in submission order");
            assert_eq!(status_of(&arr[0]), "completed");
            assert_eq!(status_of(&arr[1]), "completed");
            assert_eq!(tokens_of(&arr[0]), serial_tokens(&b1));
            assert_eq!(tokens_of(&arr[1]), serial_tokens(&b2));

            let tr = stream_reply.unwrap();
            assert_eq!(status_of(&tr), "completed");
            assert_eq!(stream_tokens, tokens_of(&tr));
            assert_eq!(stream_tokens, serial_tokens(&streaming));

            // a metrics round trip proves no stray reply is queued ahead
            let m = c.metrics();
            assert!(m.get("requests").unwrap().as_usize().unwrap() >= 4);
        }));
    }
    for h in clients {
        h.join().unwrap();
    }
}

#[test]
fn batch_line_replies_in_submission_order_across_buckets() {
    let addr = spawn_server(SchedulerOptions::default());
    let mut c = Client::connect(addr);
    // mixed buckets with distinct output lengths: the reply array must map
    // 1:1 onto submission order, not completion order
    let reqs: Vec<GenerateRequest> =
        (0..5).map(|i| req(if i % 2 == 0 { 64 } else { 300 }, i, i + 1)).collect();
    let line: Vec<String> =
        (0..5).map(|i| req_obj(if i % 2 == 0 { 64 } else { 300 }, i, i + 1, false)).collect();
    c.send(&format!("[{}]", line.join(",")));
    let reply = c.recv();
    let arr = reply.as_arr().unwrap();
    assert_eq!(arr.len(), 5);
    let mut ids = Vec::new();
    for (i, r) in arr.iter().enumerate() {
        assert_eq!(status_of(r), "completed");
        assert_eq!(tokens_of(r).len(), i + 1, "reply {i} out of submission order");
        assert_eq!(tokens_of(r), serial_tokens(&reqs[i]));
        ids.push(r.get("id").unwrap().as_usize().unwrap());
    }
    let unique: std::collections::BTreeSet<usize> = ids.iter().copied().collect();
    assert_eq!(unique.len(), 5, "ids must be unique: {ids:?}");
}

#[test]
fn flooded_queue_rejects_new_submissions_with_backpressure() {
    // one session decodes at a time and admission happens only when the
    // active set drains, so the flood keeps the queue non-empty for the
    // whole test; the SLO is 50 ms
    let addr = spawn_server(SchedulerOptions {
        max_active: 1,
        prefill_every: 1_000_000,
        max_queue_wait_secs: Some(0.05),
        ..Default::default()
    });
    let mut c = Client::connect(addr);
    for i in 0..20 {
        c.send(&req_obj(64, i, 2000, false));
    }
    std::thread::sleep(std::time::Duration::from_millis(120));

    // by now the oldest queued request has waited well past the SLO; some
    // of the flood may already have completed, so count as we scan
    let mut completed = 0;
    c.send(&req_obj(64, 99, 2, false));
    let rejected = loop {
        let v = c.recv();
        match status_of(&v) {
            "rejected" => break v,
            "completed" => completed += 1,
            s => panic!("unexpected terminal status {s}"),
        }
    };
    assert_eq!(rejected.get("id"), Some(&Json::Null), "refused before an id was assigned");
    assert!(
        rejected.get("error").unwrap().as_str().unwrap().contains("queue saturated"),
        "rejection must carry the backpressure reason"
    );

    // shutdown drains the one active request and rejects the queued flood;
    // its reply comes after the drained/rejected terminals
    c.send(r#"{"cmd": "shutdown"}"#);
    let mut shutdown_rejected = 0;
    loop {
        let v = c.recv();
        if let Some(ok) = v.get("ok").and_then(|o| o.as_bool()) {
            assert!(ok);
            break;
        }
        match status_of(&v) {
            "completed" => completed += 1,
            "rejected" => shutdown_rejected += 1,
            s => panic!("unexpected terminal status {s}"),
        }
    }
    assert!(completed >= 1, "in-flight work must drain, not be dropped");
    assert!(shutdown_rejected >= 1, "queued work must be rejected on shutdown");
    assert_eq!(completed + shutdown_rejected, 20, "every request resolves exactly once");
}

#[test]
fn chunked_prefill_keeps_decode_streaming_during_long_prefill() {
    // ISSUE 7 regression: with decode-interleaved chunked prefill, a long
    // prompt's prefill no longer head-of-line-blocks an active decode. The
    // 1500-token prompt needs ~24 ticks at 64 tokens/tick, and every tick
    // runs the decode round first, so A must keep emitting token lines the
    // whole time B is mid-prefill.
    let addr = spawn_server(SchedulerOptions {
        prefill_chunk: Some(64),
        prefill_chunk_budget: Some(64),
        prefill_every: 1,
        ..Default::default()
    });

    // A: a streamed decode, already past its prefill
    let mut a = Client::connect(addr);
    a.send(&req_obj(64, 0, 400, true));
    let first = a.recv();
    assert!(first.get("token").is_some(), "streaming must start with a token line");

    // B: submit the long prompt from this thread (so it is in flight before
    // we resume reading A's stream), then let a helper thread block on its
    // terminal so the recv overlaps A's stream
    let mut b = Client::connect(addr);
    b.send(&req_obj(1500, 1, 2, false));
    let finished = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let fin2 = finished.clone();
    let b_thread = std::thread::spawn(move || {
        let v = b.recv();
        fin2.store(true, std::sync::atomic::Ordering::SeqCst);
        v
    });

    // count A's tokens that arrive while B is still mid-flight
    let mut streamed = Vec::new();
    let mut during = 0usize;
    let terminal = loop {
        let v = a.recv();
        if v.get("status").is_some() {
            break v;
        }
        streamed.push(v.get("token").unwrap().as_f64().unwrap() as i32);
        if !finished.load(std::sync::atomic::Ordering::SeqCst) {
            during += 1;
        }
    };
    assert_eq!(status_of(&terminal), "completed");
    assert_eq!(streamed.len(), 400);

    let bv = b_thread.join().unwrap();
    assert_eq!(status_of(&bv), "completed");
    assert_eq!(tokens_of(&bv).len(), 2);

    // the head-of-line regression guard: A made real progress during B's
    // prefill window instead of stalling until it finished
    assert!(
        during >= 5,
        "decode stalled during the long prefill: only {during} tokens overlapped"
    );

    // chunking must not perturb outputs: both match the serial seed path
    assert_eq!(streamed, serial_tokens(&req(64, 0, 400)));
    assert_eq!(tokens_of(&bv), serial_tokens(&req(1500, 1, 2)));
}

#[test]
fn concurrent_results_match_the_serial_seed_path_exactly() {
    // every request fired concurrently from 3 connections must produce the
    // same tokens as the serial one-request-at-a-time path
    let addr = spawn_server(SchedulerOptions::default());
    let mut handles = Vec::new();
    for t in 0..3usize {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            let mut got: BTreeMap<usize, Vec<i32>> = BTreeMap::new();
            for i in 0..4usize {
                let len = [64, 200, 300, 64][i];
                c.send(&req_obj(len, t * 10 + i, 3 + i, false));
                let v = c.recv();
                assert_eq!(status_of(&v), "completed");
                got.insert(i, tokens_of(&v));
            }
            (t, got)
        }));
    }
    for h in handles {
        let (t, got) = h.join().unwrap();
        for (i, tokens) in got {
            let len = [64, 200, 300, 64][i];
            assert_eq!(
                tokens,
                serial_tokens(&req(len, t * 10 + i, 3 + i)),
                "connection {t} request {i} diverged from the serial path"
            );
        }
    }
}
