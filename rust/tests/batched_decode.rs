//! Batched vs serial decode equivalence suite (ISSUE 3).
//!
//! For a mixed session set — one four-session same-bucket group plus two
//! longer prompts — `Engine::decode_step_batch` must produce bit-identical
//! tokens, eviction scores, and cache contents to looping
//! `Engine::decode_step`, for a dynamic-budget policy (lava), a
//! decode-evicting policy (h2o), and a static grow-during-decode policy
//! (snapkv). The batched side groups sessions by capacity signature exactly
//! the way `Scheduler::decode_round` does.

use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::coordinator::session::Session;
use lava::kvcache::HotStore;
use lava::model::backend::MockBackend;

fn engine(policy: &str) -> Engine<MockBackend> {
    let mut mock = MockBackend::new(MockBackend::default_config());
    mock.hot_positions = vec![30, 31, 32];
    mock.seed = 5;
    Engine::new(mock, EngineOptions::new(Policy::by_name(policy).unwrap(), 48))
}

/// Mixed workload: four same-bucket prompts (length ~100, distinct
/// contents, so caches and scores genuinely differ within the group) plus
/// two longer prompts that land in other capacity buckets.
fn requests() -> Vec<GenerateRequest> {
    let lens = [100usize, 104, 96, 100, 300, 280];
    lens.iter()
        .enumerate()
        .map(|(i, &n)| GenerateRequest {
            prompt: (0..n).map(|t| ((t * (i + 2) + i) % 251) as i32).collect(),
            max_new_tokens: 8,
        })
        .collect()
}

fn assert_cache_eq(a: &HotStore, b: &HotStore, ctx: &str) {
    assert_eq!(a.capacity(), b.capacity(), "{ctx}: capacity");
    assert_eq!(a.n_kv_heads(), b.n_kv_heads(), "{ctx}: heads");
    for h in 0..a.n_kv_heads() {
        assert_eq!(a.head_len(h), b.head_len(h), "{ctx}: head {h} len");
        for i in 0..a.head_len(h) {
            assert_eq!(a.position(h, i), b.position(h, i), "{ctx}: head {h} slot {i} position");
            assert_eq!(
                a.score(h, i).to_bits(),
                b.score(h, i).to_bits(),
                "{ctx}: head {h} slot {i} score"
            );
            assert_eq!(a.key(h, i), b.key(h, i), "{ctx}: head {h} slot {i} key");
            assert_eq!(a.value(h, i), b.value(h, i), "{ctx}: head {h} slot {i} value");
        }
    }
}

fn assert_sessions_eq(a: &Session, b: &Session, ctx: &str) {
    assert_eq!(a.id, b.id, "{ctx}: id");
    assert_eq!(a.generated, b.generated, "{ctx}: generated tokens");
    assert_eq!(a.next_pos, b.next_pos, "{ctx}: next_pos");
    assert_eq!(a.caches.len(), b.caches.len(), "{ctx}: layer count");
    for (l, (ca, cb)) in a.caches.iter().zip(&b.caches).enumerate() {
        assert_cache_eq(ca, cb, &format!("{ctx} layer {l}"));
    }
}

/// Group-wise batched round, exactly as the scheduler packs it: pop the
/// front session's capacity signature, batch every session matching it,
/// repeat; then restore submission order for comparison.
fn batched_round(engine: &mut Engine<MockBackend>, sessions: Vec<Session>) -> Vec<Session> {
    let mut remaining = sessions;
    let mut done = Vec::with_capacity(remaining.len());
    while !remaining.is_empty() {
        let sig = remaining[0].capacity_signature();
        let (mut group, rest): (Vec<Session>, Vec<Session>) =
            remaining.into_iter().partition(|s| s.capacity_signature() == sig);
        engine.decode_step_batch(&mut group).unwrap();
        done.extend(group);
        remaining = rest;
    }
    done.sort_by_key(|s| s.id);
    done
}

#[test]
fn batched_decode_is_bit_identical_to_serial() {
    for policy in ["lava", "h2o", "snapkv"] {
        let mut serial = engine(policy);
        let mut batched = engine(policy);
        let mut ss: Vec<Session> = Vec::new();
        let mut bs: Vec<Session> = Vec::new();
        for req in requests() {
            let mut a = serial.new_session(&req);
            serial.prefill(&mut a).unwrap();
            ss.push(a);
            let mut b = batched.new_session(&req);
            batched.prefill(&mut b).unwrap();
            bs.push(b);
        }
        for (a, b) in ss.iter().zip(&bs) {
            assert_sessions_eq(a, b, &format!("{policy} prefill id {}", a.id));
        }
        // the set must actually exercise grouping: the four short prompts
        // share one capacity signature (same-bucket group), and for the
        // static policies the long prompts land in a different bucket
        let sigs: Vec<Vec<usize>> = bs.iter().map(|s| s.capacity_signature()).collect();
        assert!(
            sigs[..4].windows(2).all(|w| w[0] == w[1]),
            "{policy}: short prompts must share a capacity bucket"
        );
        if policy != "lava" {
            assert_ne!(sigs[4], sigs[0], "{policy}: long prompts must be cross-bucket");
        }

        // 7 rounds: max_new_tokens=8 minus the prefill token
        for round in 0..7 {
            for s in ss.iter_mut() {
                serial.decode_step(s).unwrap();
            }
            bs = batched_round(&mut batched, bs);
            for (a, b) in ss.iter().zip(&bs) {
                assert_sessions_eq(a, b, &format!("{policy} round {round} id {}", a.id));
            }
        }
        for s in ss.iter().chain(&bs) {
            assert!(s.is_done(), "{policy}: every session must finish in 7 rounds");
        }
        // amortization really happened: the serial engine paid one dispatch
        // per session per layer, the batched engine one per group per layer
        assert!(
            batched.metrics.decode_dispatches_total() < serial.metrics.decode_dispatches_total(),
            "{policy}: batching must issue fewer backend dispatches"
        );
        assert!(batched.metrics.batch_occupancy() > 1.0, "{policy}: occupancy must exceed 1");
    }
}
