//! PJRT end-to-end tests against the real AOT artifacts. Skipped (with a
//! loud note) when `artifacts/manifest.json` is absent — run
//! `make artifacts` first. These are the tests that prove the three layers
//! (Pallas kernels -> JAX model -> rust coordinator) compose.

use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::model::backend::{ModelBackend, PjrtBackend};
use lava::model::Manifest;
use lava::util::rng::Rng;
use lava::workloads;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

fn engine(policy: &str, budget: usize) -> Option<Engine<PjrtBackend>> {
    let dir = artifacts_dir()?;
    let backend = PjrtBackend::load(&dir).expect("load artifacts");
    Some(Engine::new(backend, EngineOptions::new(Policy::by_name(policy).unwrap(), budget)))
}

#[test]
fn manifest_matches_workload_specials() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.model.bos_id, workloads::BOS);
    assert_eq!(m.model.sep_id, workloads::SEP);
    assert_eq!(m.model.query_id, workloads::QUERY);
}

#[test]
fn full_cache_generation_runs() {
    let Some(mut e) = engine("full", 64) else { return };
    let mut rng = Rng::new(0);
    let inst = workloads::needle_qa(&mut rng, 100, 4);
    let r = e
        .generate(&GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 4 })
        .unwrap();
    assert_eq!(r.tokens.len(), 4);
    assert!(r.tokens.iter().all(|&t| (0..260).contains(&t)));
}

#[test]
fn compressed_equals_full_when_budget_covers() {
    // With a budget >= prompt length, LAVa must keep everything -> outputs
    // identical to the full cache.
    let Some(mut e) = engine("full", 999) else { return };
    let mut rng = Rng::new(1);
    let inst = workloads::needle_qa(&mut rng, 90, 4);
    let full = e
        .generate(&GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 4 })
        .unwrap();
    let mut e2 = engine("lava", 999).unwrap();
    let lava = e2
        .generate(&GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 4 })
        .unwrap();
    assert_eq!(full.tokens, lava.tokens, "no-eviction must be lossless");
}

#[test]
fn all_policies_generate_on_real_model() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = PjrtBackend::load(&dir).unwrap();
    let mut e = Engine::new(backend, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
    let mut rng = Rng::new(2);
    let inst = workloads::needle_qa(&mut rng, 120, 4);
    for policy in ["snapkv", "ada-snapkv", "pyramidkv", "cake", "vatp", "lava", "h2o", "tova", "streaming"] {
        e.opts.policy = Policy::by_name(policy).unwrap();
        let r = e
            .generate(&GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 3 })
            .unwrap();
        assert_eq!(r.tokens.len(), 3, "{policy}");
    }
}

#[test]
fn fused_lava_score_matches_host_path() {
    // the L1 Pallas fused-score artifact and the rust host scorer must
    // select the same keep sets (scores equal within float tolerance)
    let Some(dir) = artifacts_dir() else { return };
    let backend = PjrtBackend::load(&dir).unwrap();
    let cfg = backend.config().clone();
    let mut rng = Rng::new(3);
    let inst = workloads::needle_qa(&mut rng, 120, 4);
    let n = inst.prompt.len();
    let bucket = lava::runtime::Runtime::pick_bucket(backend.prefill_buckets(), n).unwrap();
    let x = backend.embed(&inst.prompt, bucket).unwrap();
    let out = backend.layer_prefill(0, &x, n).unwrap();

    let fused = backend
        .fused_lava_score(&out.obs.win_attn, &out.v, n)
        .unwrap()
        .expect("fused artifact available");
    let host = lava::compress::score::kv_head_scores(
        lava::compress::ScoreKind::Lava,
        lava::compress::GroupReduce::Max,
        &out.obs,
        7,
    );
    assert_eq!(fused.len(), host.len());
    for (hf, hh) in fused.iter().zip(&host) {
        assert_eq!(hf.len(), hh.len());
        for (a, b) in hf.iter().zip(hh) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "fused {a} vs host {b}");
        }
    }
    let _ = cfg;
}

#[test]
fn decode_positions_progress() {
    let Some(mut e) = engine("lava", 32) else { return };
    let mut rng = Rng::new(4);
    let inst = workloads::kv_retrieve(&mut rng, 150);
    let req = GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 6 };
    let mut sess = e.new_session(&req);
    e.prefill(&mut sess).unwrap();
    let n = inst.prompt.len();
    for step in 0..5 {
        e.decode_step(&mut sess).unwrap();
        assert_eq!(sess.next_pos, n + step + 1);
    }
    // decoded entries appended with correct positions
    let c = &sess.caches[0];
    let last = c.position(0, c.head_len(0) - 1);
    assert_eq!(last as usize, n + 4);
}
