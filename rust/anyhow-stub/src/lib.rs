//! Offline stand-in for the `anyhow` crate: the exact subset the lava crate
//! uses (`Result`, `Error`, `anyhow!`, `bail!`, `Context`), so the workspace
//! builds with zero registry dependencies. Error messages eagerly fold the
//! source chain into one string — `{e}` and `{e:#}` both print the chain,
//! which matches how the real crate is used here (reporting, not recovery).
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error`: that is what makes the blanket `From<E>` (the `?`
//! conversion) coherent.

use std::fmt;

pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {}", context, e.into().msg) })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {}", f(), e.into().msg) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/file/anywhere")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!format!("{e}").is_empty());
        assert!(!format!("{e:#}").is_empty());
    }

    #[test]
    fn macros_and_context() {
        let n = 3;
        let e = anyhow!("bad count {n}");
        assert_eq!(format!("{e}"), "bad count 3");
        let e2 = anyhow!("{} of {}", 1, 2);
        assert_eq!(format!("{e2}"), "1 of 2");

        fn bails() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope 7");

        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e3 = r.context("outer").unwrap_err();
        assert_eq!(format!("{e3}"), "outer: inner");

        let none: Option<u32> = None;
        let e4 = none.with_context(|| "missing").unwrap_err();
        assert_eq!(format!("{e4}"), "missing");
    }
}
