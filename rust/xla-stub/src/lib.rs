//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The lava coordinator is written against the real bindings
//! (`PjRtClient::cpu()` -> compile HLO text -> `execute_b`); this crate
//! mirrors exactly that API surface so the whole workspace type-checks and
//! every mock-backend path (unit tests, scheduler, server, benches) runs
//! without an accelerator runtime. Every entry point that would touch PJRT
//! returns `Error::unavailable()` — `PjrtBackend::load` therefore fails fast
//! with a clear message, and callers that probe artifacts first (the e2e
//! tests, the benches) skip gracefully.
//!
//! To run the real model, replace this path dependency with the actual xla
//! bindings; no coordinator code changes.

use std::fmt;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT unavailable (built against the offline xla stub; \
                 swap rust/xla-stub for the real xla bindings to execute artifacts)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the host tensors convert through.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    F16,
    F32,
    F64,
    Tuple,
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }
}

#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_fails_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("offline xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.to_vec::<f32>().is_err());
    }
}
