//! Serving-level benchmarks.
//!
//! Part 1 — engine: end-to-end prefill/decode timing per policy. Runs on the
//! mock backend by default (isolating coordinator overhead — scoring,
//! selection, cascade, cache maintenance); pass --pjrt to measure the real
//! model path (requires `make artifacts`).
//!
//! Part 2 — scheduler: a mixed-shape-bucket workload driven through the
//! continuous-batching scheduler, reporting TTFT, queue wait, and decode
//! tokens/s for one-at-a-time admission (max_prefill_batch=1, the old
//! behavior) vs batched same-bucket admission (the pop_batch path).
//!
//! Part 3 — tiering: the same mixed workload under a kv_mem_limit tight
//! enough to force deferrals, with hot/warm tiering off (the old
//! defer-and-wait scheduler) vs on (spill idle layers to Q8 warm blocks,
//! prefetch before decode), reporting wall time, deferrals, spill/prefetch
//! counts, and peak hot-tier bytes.
//!
//! Part 4 — batched decode: 1/4/8 same-bucket sessions decoding
//! concurrently with capacity-bucket grouping off (one `layer_decode`
//! dispatch per session per layer, the old path) vs on (one
//! `layer_decode_batched` dispatch per group per layer), reporting wall
//! time, decode tok/s, batch occupancy, and total backend dispatches.
//!
//! Part 5 — engine sharding: a memory-pressured *imbalanced* workload
//! (half the requests share one capacity bucket — one heavy batched-decode
//! unit — while the rest spread across distinct scales as many light
//! units) swept over worker-pool widths 1/2/4 × pool modes
//! scoped/persistent, reporting wall time, decode tok/s, worker
//! utilization, and the mean per-round dispatch overhead the persistent
//! injector pool exists to shrink.
//!
//! Part 6 — serving loop: the mixed workload submitted over real TCP
//! connections into the continuous serving loop (acceptor → command
//! channel → serving thread), 1 vs 8 concurrent connections, reporting
//! TTFT mean/p99, steady-state decode tok/s, and end-to-end throughput.
//!
//! Part 7 — chunked prefill: short sessions are mid-decode when a flood of
//! long prompts arrives, monolithic prefill vs chunked+decode-interleaved
//! (`prefill_chunk`/`prefill_chunk_budget`), reporting the decode sessions'
//! inter-token gap (mean/p99/max — the head-of-line-blocking signal),
//! long-prompt TTFT, prefill tok/s, peak KV bytes incl. the prefill
//! transient, and the bucket-padding gauges. Two memory sweeps ride along:
//! the carry-only transient sweep (streamed carry flat vs plain chunked
//! linear) and the full resident sweep (layer-major vs chunk-major f32 vs
//! chunk-major Q8 — the whole prefill working set must stay flat in prompt
//! length on the chunk-major arms while layer-major grows linearly).
//!
//! In `--smoke` mode the worker sweep, the serving-loop sweep, and the
//! chunked-prefill sweep are written to machine-readable
//! `BENCH_serving.json` at the *repo root* — a committed artifact, so the
//! perf trajectory lives in history as well as in CI uploads.
//!
//!   cargo bench --bench serving [-- --pjrt] [-- --ctx 512] [-- --requests 24]
//!
//! `--smoke` runs every mock-backend section with tiny iteration counts so
//! CI can compile-and-exercise the whole bench path in seconds.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use lava::bench::harness::bench_for;
use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::coordinator::pool::PoolMode;
use lava::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lava::coordinator::server::Server;
use lava::model::backend::{MockBackend, ModelBackend, PjrtBackend};
use lava::util::cli::Args;
use lava::util::json::{self, Json};
use lava::util::rng::Rng;
use lava::workloads;

fn run<B: ModelBackend>(engine: &mut Engine<B>, ctx: usize, budget_secs: f64) {
    let mut rng = Rng::new(0);
    let inst = workloads::needle_qa(&mut rng, ctx, 4);

    for policy in ["full", "snapkv", "ada-snapkv", "cake", "lava"] {
        engine.opts.policy = Policy::by_name(policy).unwrap();
        engine.opts.budget_per_head = 32;

        let r = bench_for(&format!("prefill/{policy}/ctx{ctx}"), budget_secs, 3, || {
            let (sess, _) = engine.prefill_only(&inst.prompt).unwrap();
            std::hint::black_box(&sess);
        });
        println!("{}", r.line());

        // decode: prefill once, then time steps
        let req = GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 10_000 };
        let mut sess = engine.new_session(&req);
        engine.prefill(&mut sess).unwrap();
        let r = bench_for(&format!("decode/{policy}/ctx{ctx}"), budget_secs, 5, || {
            engine.decode_step(&mut sess).unwrap();
        });
        println!("{}", r.line());
    }
}

/// Mixed-bucket request list: one third each of three context scales.
fn mixed_workload(ctx: usize, n_requests: usize) -> Vec<GenerateRequest> {
    let mut rng = Rng::new(7);
    (0..n_requests)
        .map(|i| {
            let scale = match i % 3 {
                0 => ctx / 4,
                1 => ctx / 2,
                _ => ctx,
            };
            let inst = workloads::needle_qa(&mut rng, scale.max(64), 4);
            GenerateRequest { prompt: inst.prompt, max_new_tokens: 8 }
        })
        .collect()
}

fn run_scheduler_bench(ctx: usize, n_requests: usize, reps: usize) {
    for (label, batch) in [("serial-admission", 1usize), ("batched-admission", 4usize)] {
        let mut walls = Vec::new();
        let mut last_report = String::new();
        for _ in 0..reps {
            let mock = MockBackend::new(MockBackend::default_config());
            let engine =
                Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
            let mut sched = Scheduler::new(
                engine,
                SchedulerOptions {
                    max_active: 8,
                    prefill_every: 2,
                    max_prefill_batch: batch,
                    ..Default::default()
                },
            );
            let reqs = mixed_workload(ctx, n_requests);
            let t0 = std::time::Instant::now();
            for req in reqs {
                sched.submit(req).unwrap();
            }
            let done = sched.run_to_completion().unwrap();
            walls.push(t0.elapsed().as_secs_f64());
            assert_eq!(done.len(), n_requests);
            let m = &sched.engine.metrics;
            last_report = format!(
                "ttft_ms(mean)={:.3} ttft_ms(p99)={:.3} queue_wait_ms(mean)={:.3} \
                 decode_tok_s={:.1} admission_rounds={}",
                m.mean_ttft_ms(),
                m.p99_ttft_ms(),
                m.mean_queue_wait_ms(),
                m.decode_tok_per_sec(),
                m.admission_rounds,
            );
        }
        let mean_wall: f64 = walls.iter().sum::<f64>() / walls.len() as f64;
        println!(
            "{:<40} {:>10.2} ms wall ({} reqs) | {}",
            format!("sched/{label}/ctx{ctx}"),
            mean_wall * 1e3,
            n_requests,
            last_report
        );
    }
}

fn tiering_sched(tiering: bool, limit: Option<usize>) -> Scheduler<MockBackend> {
    let mock = MockBackend::new(MockBackend::default_config());
    let engine = Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
    Scheduler::new(
        engine,
        SchedulerOptions {
            kv_mem_limit: limit,
            max_active: 8,
            prefill_every: 2,
            max_prefill_batch: 4,
            tiering,
            ..Default::default()
        },
    )
}

fn run_tiering_bench(ctx: usize, n_requests: usize, reps: usize) {
    // A kv_mem_limit tight enough that the seed scheduler must defer most
    // of the mixed workload, derived from the scheduler's own projection
    // accounting (stays calibrated if the formulas change): one
    // largest-request peak plus one retained budget.
    let limit = {
        let probe = tiering_sched(false, None);
        let max_len = mixed_workload(ctx, n_requests)
            .iter()
            .map(|r| r.prompt.len())
            .max()
            .unwrap_or(ctx);
        probe.projected_bytes(max_len) + probe.retained_bytes(max_len)
    };
    for (label, tiering) in [("tiering-off", false), ("tiering-on", true)] {
        let mut walls = Vec::new();
        let mut last_report = String::new();
        for _ in 0..reps {
            let mut sched = tiering_sched(tiering, Some(limit));
            let reqs = mixed_workload(ctx, n_requests);
            let t0 = std::time::Instant::now();
            for req in reqs {
                sched.submit(req).unwrap();
            }
            let done = sched.run_to_completion().unwrap();
            walls.push(t0.elapsed().as_secs_f64());
            assert_eq!(done.len(), n_requests);
            let m = &sched.engine.metrics;
            if tiering {
                assert!(
                    m.peak_hot_kv_bytes <= limit,
                    "hot tier exceeded the limit: {} > {limit}",
                    m.peak_hot_kv_bytes
                );
            }
            last_report = format!(
                "completed={} deferrals={} spills={} prefetches={} \
                 peak_hot_mb={:.2} peak_warm_mb={:.2} ttft_ms(mean)={:.3}",
                m.requests_finished,
                m.requests_deferred,
                m.spills,
                m.prefetches,
                m.peak_hot_kv_bytes as f64 / 1e6,
                m.peak_warm_kv_bytes as f64 / 1e6,
                m.mean_ttft_ms(),
            );
        }
        let mean_wall: f64 = walls.iter().sum::<f64>() / walls.len() as f64;
        println!(
            "{:<40} {:>10.2} ms wall ({} reqs, limit {:.2} MB) | {}",
            format!("tiering/{label}/ctx{ctx}"),
            mean_wall * 1e3,
            n_requests,
            limit as f64 / 1e6,
            last_report
        );
    }
}

/// Part 4: N same-bucket sessions decoding concurrently, capacity-bucket
/// grouping off vs on. The same prompt is submitted N times so every
/// session provably shares one capacity signature (content does not change
/// decode cost on the mock backend).
fn run_batched_decode_bench(ctx: usize, max_new: usize, reps: usize) {
    for &nsess in &[1usize, 4, 8] {
        for (label, batched) in [("batch-off", false), ("batch-on", true)] {
            let mut walls = Vec::new();
            let mut last_report = String::new();
            for _ in 0..reps {
                let mock = MockBackend::new(MockBackend::default_config());
                let engine =
                    Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
                let mut sched = Scheduler::new(
                    engine,
                    SchedulerOptions {
                        max_active: 8,
                        max_prefill_batch: 8,
                        prefill_every: 2,
                        batched_decode: batched,
                        ..Default::default()
                    },
                );
                let mut rng = Rng::new(11);
                let inst = workloads::needle_qa(&mut rng, ctx, 4);
                let t0 = std::time::Instant::now();
                for _ in 0..nsess {
                    sched
                        .submit(GenerateRequest {
                            prompt: inst.prompt.clone(),
                            max_new_tokens: max_new,
                        })
                        .unwrap();
                }
                let done = sched.run_to_completion().unwrap();
                walls.push(t0.elapsed().as_secs_f64());
                assert_eq!(done.len(), nsess);
                let m = &sched.engine.metrics;
                last_report = format!(
                    "decode_tok_s={:.1} occupancy={:.2} dispatches={}",
                    m.decode_tok_per_sec(),
                    m.batch_occupancy(),
                    m.decode_dispatches_total(),
                );
            }
            let mean_wall: f64 = walls.iter().sum::<f64>() / walls.len() as f64;
            println!(
                "{:<40} {:>10.2} ms wall ({} sessions) | {}",
                format!("batched-decode/{label}/B{nsess}/ctx{ctx}"),
                mean_wall * 1e3,
                nsess,
                last_report
            );
        }
    }
}

/// Imbalanced request list for the Part 5 sweep: half the requests share
/// one full-ctx shape (one heavy same-bucket decode group), the rest
/// spread across four distinct smaller scales (many light units). Static
/// contiguous chunking strands the light units behind whichever worker
/// drew the heavy group; the persistent injector's dynamic pulls keep the
/// rest of the pool busy.
fn imbalanced_workload(ctx: usize, n_requests: usize) -> Vec<GenerateRequest> {
    let mut rng = Rng::new(9);
    (0..n_requests)
        .map(|i| {
            let scale = if i < n_requests / 2 {
                ctx
            } else {
                (ctx / 8).max(64) * ((i - n_requests / 2) % 4 + 1)
            };
            let inst = workloads::needle_qa(&mut rng, scale.max(64), 4);
            GenerateRequest { prompt: inst.prompt, max_new_tokens: 8 }
        })
        .collect()
}

/// Part 5: worker-count × pool-mode sweep. The imbalanced workload runs
/// under the same tiering-pressure recipe as Part 3, so the sweep
/// exercises exactly the overlap the sharded engine is for: bucket groups
/// decoding on the pool while the tier thread rehydrates next-round
/// sessions — with the scoped spawn-per-round oracle against the
/// persistent injector pool, whose dispatch-overhead column is the
/// tentpole number. Returns the per-config report rows plus the limit
/// used, for `BENCH_serving.json`.
fn run_worker_sweep(ctx: usize, n_requests: usize, reps: usize) -> (Vec<Json>, usize) {
    let limit = {
        let probe = tiering_sched(false, None);
        let max_len = imbalanced_workload(ctx, n_requests)
            .iter()
            .map(|r| r.prompt.len())
            .max()
            .unwrap_or(ctx);
        probe.projected_bytes(max_len) + probe.retained_bytes(max_len)
    };
    let mut rows: Vec<Json> = Vec::new();
    for &workers in &[1usize, 2, 4] {
        for (mode_label, mode) in
            [("scoped", PoolMode::Scoped), ("persistent", PoolMode::Persistent)]
        {
            let mut walls = Vec::new();
            let mut tok_s_sum = 0.0;
            let mut util_sum = 0.0;
            let mut dispatch_sum = 0.0;
            let mut queue_peak = 0usize;
            // spill/prefetch decisions are deterministic per workload, so
            // the last rep's counters equal every rep's
            let mut spills = 0u64;
            let mut prefetches = 0u64;
            for _ in 0..reps {
                let mock = MockBackend::new(MockBackend::default_config());
                let engine = Engine::new(
                    mock,
                    EngineOptions::new(Policy::by_name("lava").unwrap(), 32),
                );
                let mut sched = Scheduler::new(
                    engine,
                    SchedulerOptions {
                        kv_mem_limit: Some(limit),
                        max_active: 8,
                        prefill_every: 2,
                        max_prefill_batch: 4,
                        workers,
                        pool_mode: mode,
                        ..Default::default()
                    },
                );
                let reqs = imbalanced_workload(ctx, n_requests);
                let t0 = std::time::Instant::now();
                for req in reqs {
                    sched.submit(req).unwrap();
                }
                let done = sched.run_to_completion().unwrap();
                walls.push(t0.elapsed().as_secs_f64());
                assert_eq!(done.len(), n_requests);
                let m = &sched.engine.metrics;
                assert!(
                    m.peak_hot_kv_bytes <= limit,
                    "hot tier exceeded the limit: {} > {limit}",
                    m.peak_hot_kv_bytes
                );
                tok_s_sum += m.decode_tok_per_sec();
                util_sum += m.worker_utilization();
                dispatch_sum += m.mean_dispatch_overhead_ms();
                queue_peak = queue_peak.max(m.pool_queue_depth_peak);
                spills = m.spills;
                prefetches = m.prefetches;
            }
            let mean_wall: f64 = walls.iter().sum::<f64>() / walls.len() as f64;
            let decode_tok_s = tok_s_sum / reps as f64;
            let utilization = util_sum / reps as f64;
            let dispatch_ms = dispatch_sum / reps as f64;
            println!(
                "{:<40} {:>10.2} ms wall ({} reqs, limit {:.2} MB) | decode_tok_s={:.1} \
                 worker_util={:.2} dispatch_ms(mean)={:.3} pool_q_peak={} spills={} \
                 prefetches={}",
                format!("sharding/workers-{workers}/{mode_label}/ctx{ctx}"),
                mean_wall * 1e3,
                n_requests,
                limit as f64 / 1e6,
                decode_tok_s,
                utilization,
                dispatch_ms,
                queue_peak,
                spills,
                prefetches,
            );
            rows.push(Json::obj(vec![
                ("workers", Json::num(workers as f64)),
                ("pool_mode", Json::str(mode_label)),
                ("wall_ms", Json::num(mean_wall * 1e3)),
                ("decode_tok_s", Json::num(decode_tok_s)),
                ("worker_utilization", Json::num(utilization)),
                ("dispatch_ms_mean", Json::num(dispatch_ms)),
                ("pool_queue_depth_peak", Json::num(queue_peak as f64)),
                ("spills", Json::num(spills as f64)),
                ("prefetches", Json::num(prefetches as f64)),
            ]));
        }
    }
    (rows, limit)
}

/// Part 6: the serving loop under concurrent TCP connections. Each
/// connection submits its share of the mixed workload request-by-request
/// (send, await terminal reply) against one shared scheduler, so the sweep
/// measures what concurrency buys end to end: admission batching across
/// connections, decode grouping, and per-connection TTFT. Returns the
/// per-connection-count report rows for `BENCH_serving.json`.
fn run_serving_loop_bench(ctx: usize, n_requests: usize, max_new: usize) -> Vec<Json> {
    let mut rows = Vec::new();
    for &conns in &[1usize, 8] {
        let mock = MockBackend::new(MockBackend::default_config());
        let engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
        let srv = Server::with_options(
            engine,
            SchedulerOptions {
                max_active: 8,
                prefill_every: 2,
                max_prefill_batch: 4,
                ..Default::default()
            },
        );
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let acceptor = std::thread::spawn(move || {
            let _ = srv.serve_on(listener);
        });
        let per_conn = n_requests.div_ceil(conns);
        let t0 = std::time::Instant::now();
        let mut clients = Vec::new();
        for c in 0..conns {
            clients.push(std::thread::spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(sock.try_clone().unwrap());
                let mut rng = Rng::new(100 + c as u64);
                let mut tokens = 0usize;
                for i in 0..per_conn {
                    let scale = match i % 3 {
                        0 => ctx / 4,
                        1 => ctx / 2,
                        _ => ctx,
                    };
                    let inst = workloads::needle_qa(&mut rng, scale.max(64), 4);
                    let prompt: Vec<String> =
                        inst.prompt.iter().map(|t| t.to_string()).collect();
                    writeln!(
                        sock,
                        "{{\"prompt\": [{}], \"max_new_tokens\": {max_new}}}",
                        prompt.join(",")
                    )
                    .unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let v = Json::parse(line.trim()).unwrap();
                    assert_eq!(v.get("status").unwrap().as_str(), Some("completed"));
                    tokens += v.get("tokens").unwrap().as_arr().unwrap().len();
                }
                tokens
            }));
        }
        let total_tokens: usize = clients.into_iter().map(|h| h.join().unwrap()).sum();
        let wall = t0.elapsed().as_secs_f64();

        // read the server-side latency metrics, then drain the loop
        let mut ctrl = TcpStream::connect(addr).unwrap();
        let mut creader = BufReader::new(ctrl.try_clone().unwrap());
        writeln!(ctrl, "{{\"cmd\": \"metrics\"}}").unwrap();
        let mut mline = String::new();
        creader.read_line(&mut mline).unwrap();
        let reply = Json::parse(mline.trim()).unwrap();
        let m = reply.get("metrics").expect("metrics reply").clone();
        writeln!(ctrl, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut sline = String::new();
        creader.read_line(&mut sline).unwrap();
        acceptor.join().unwrap();

        let ttft_mean = m.get("ttft_ms_mean").unwrap().as_f64().unwrap();
        let ttft_p99 = m.get("ttft_ms_p99").unwrap().as_f64().unwrap();
        let decode_tok_s = m.get("decode_tok_s").unwrap().as_f64().unwrap();
        let throughput = total_tokens as f64 / wall.max(1e-9);
        println!(
            "{:<40} {:>10.2} ms wall ({} reqs) | ttft_ms(mean)={:.3} ttft_ms(p99)={:.3} \
             decode_tok_s={:.1} throughput_tok_s={:.1}",
            format!("serving/conns-{conns}/ctx{ctx}"),
            wall * 1e3,
            conns * per_conn,
            ttft_mean,
            ttft_p99,
            decode_tok_s,
            throughput,
        );
        rows.push(Json::obj(vec![
            ("connections", Json::num(conns as f64)),
            ("requests", Json::num((conns * per_conn) as f64)),
            ("wall_ms", Json::num(wall * 1e3)),
            ("ttft_ms_mean", Json::num(ttft_mean)),
            ("ttft_ms_p99", Json::num(ttft_p99)),
            ("decode_tok_s", Json::num(decode_tok_s)),
            ("throughput_tok_s", Json::num(throughput)),
        ]));
    }
    rows
}

/// Part 7: chunked prefill vs monolithic under a long-prompt flood. Short
/// sessions are already mid-decode when the long prompts arrive; per-tick
/// `Instant` stamps on their token events measure how badly prefill stalls
/// decode. The monolithic arm prefills each admitted long prompt to
/// completion inside its admission tick (one huge inter-token gap for every
/// decoder); the chunked arm advances at most `prefill_chunk_budget` prefill
/// tokens per tick after the decode round, so the gap stays near the
/// per-tick decode cost. The third arm turns on streaming eviction
/// (`prefill_stream_evict`): same interleaving, but the per-layer carry is
/// evicted down to the working cap after every chunk, so the prefill
/// transient stays flat in prompt length (measured directly by the
/// `transient_sweep` rows). Returns the report rows for
/// `BENCH_serving.json`.
fn run_chunked_prefill_bench(ctx: usize, decode_new: usize) -> Vec<Json> {
    use std::collections::{BTreeMap, BTreeSet};

    let long_len = (ctx * 4).max(512);
    let n_decode = 4usize;
    let n_long = 4usize;
    let mut rows = Vec::new();
    let mut max_gaps: BTreeMap<&str, f64> = BTreeMap::new();
    for (label, chunk, budget, stream) in [
        ("monolithic", None, None, false),
        ("chunked", Some(64usize), Some(64usize), false),
        ("stream_evict", Some(64), Some(64), true),
    ] {
        let mock = MockBackend::new(MockBackend::default_config());
        let engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
        let mut sched = Scheduler::new(
            engine,
            SchedulerOptions {
                max_active: 8,
                prefill_every: 1,
                max_prefill_batch: 4,
                prefill_chunk: chunk,
                prefill_chunk_budget: budget,
                prefill_stream_evict: stream,
                ..Default::default()
            },
        );
        let mut rng = Rng::new(21);
        for _ in 0..n_decode {
            let inst = workloads::needle_qa(&mut rng, 64, 4);
            sched
                .submit(GenerateRequest { prompt: inst.prompt, max_new_tokens: decode_new })
                .unwrap();
        }
        // run until every decode session is streaming, so the flood lands on
        // a steady decode cadence
        let mut last_token_at: BTreeMap<u64, std::time::Instant> = BTreeMap::new();
        while last_token_at.len() < n_decode {
            let rep = sched.tick().unwrap();
            let now = std::time::Instant::now();
            for (id, _) in &rep.tokens {
                last_token_at.insert(*id, now);
            }
        }

        // the flood: long prompts arrive while the short sessions decode
        let flood_at = std::time::Instant::now();
        let mut long_ids: BTreeSet<u64> = BTreeSet::new();
        for _ in 0..n_long {
            let inst = workloads::needle_qa(&mut rng, long_len, 4);
            long_ids.insert(
                sched
                    .submit(GenerateRequest { prompt: inst.prompt, max_new_tokens: 4 })
                    .unwrap(),
            );
        }
        let mut gaps: Vec<f64> = Vec::new();
        let mut long_ttft: BTreeMap<u64, f64> = BTreeMap::new();
        let mut finished = 0usize;
        while sched.has_work() {
            let rep = sched.tick().unwrap();
            let now = std::time::Instant::now();
            for (id, _) in &rep.tokens {
                if long_ids.contains(id) {
                    long_ttft.entry(*id).or_insert_with(|| flood_at.elapsed().as_secs_f64());
                } else if let Some(prev) = last_token_at.insert(*id, now) {
                    gaps.push(now.duration_since(prev).as_secs_f64());
                }
            }
            finished += rep.finished.len();
        }
        assert_eq!(finished, n_decode + n_long, "every request must complete");
        assert_eq!(long_ttft.len(), n_long, "every long prompt must emit a first token");

        gaps.sort_by(|a, b| a.total_cmp(b));
        let gap_mean = gaps.iter().sum::<f64>() / gaps.len().max(1) as f64;
        let gap_p99 = gaps[((gaps.len() - 1) as f64 * 0.99) as usize];
        let gap_max = *gaps.last().unwrap();
        max_gaps.insert(label, gap_max);
        let ttft_mean =
            long_ttft.values().sum::<f64>() / long_ttft.len().max(1) as f64;
        let ttft_max = long_ttft.values().fold(0.0f64, |a, &b| a.max(b));
        // prefill throughput: all long-prompt tokens were prefilled by the
        // time the last long prompt produced its first token
        let prefill_tok_s = (n_long * long_len) as f64 / ttft_max.max(1e-9);
        let m = &sched.engine.metrics;
        println!(
            "{:<40} gap_ms(mean)={:.3} gap_ms(p99)={:.3} gap_ms(max)={:.3} | \
             long_ttft_ms(mean)={:.2} long_ttft_ms(max)={:.2} prefill_tok_s={:.0} \
             peak_kv_mb={:.2} transient_kb(peak)={:.1} padded_tok={} bucket_util={:.2}",
            format!("chunked-prefill/{label}/long{long_len}"),
            gap_mean * 1e3,
            gap_p99 * 1e3,
            gap_max * 1e3,
            ttft_mean * 1e3,
            ttft_max * 1e3,
            prefill_tok_s,
            m.peak_kv_bytes as f64 / 1e6,
            m.peak_prefill_transient_bytes as f64 / 1e3,
            m.prefill_padded_tokens,
            m.prefill_bucket_utilization(),
        );
        rows.push(Json::obj(vec![
            ("mode", Json::str(label)),
            ("long_prompt_len", Json::num(long_len as f64)),
            ("decode_gap_ms_mean", Json::num(gap_mean * 1e3)),
            ("decode_gap_ms_p99", Json::num(gap_p99 * 1e3)),
            ("decode_gap_ms_max", Json::num(gap_max * 1e3)),
            ("long_ttft_ms_mean", Json::num(ttft_mean * 1e3)),
            ("long_ttft_ms_max", Json::num(ttft_max * 1e3)),
            ("prefill_tok_s", Json::num(prefill_tok_s)),
            ("peak_kv_bytes", Json::num(m.peak_kv_bytes as f64)),
            (
                "peak_prefill_transient_bytes",
                Json::num(m.peak_prefill_transient_bytes as f64),
            ),
            ("prefill_chunk_batches", Json::num(m.prefill_chunk_batches as f64)),
            ("prefill_chunk_occupancy", Json::num(m.prefill_chunk_batch_occupancy())),
            (
                "prefill_chunk_dispatches",
                Json::num(m.prefill_chunk_batch_dispatches as f64),
            ),
            ("prefill_padded_tokens", Json::num(m.prefill_padded_tokens as f64)),
            ("prefill_bucket_util", Json::num(m.prefill_bucket_utilization())),
        ]));
    }
    // the point of the feature: the worst decode stall must shrink when
    // prefill is chunked and interleaved (structurally: one 64-token chunk
    // of layer work per tick vs four full prompts prefilled in one tick)
    let (mono, chunked) = (max_gaps["monolithic"], max_gaps["chunked"]);
    assert!(
        chunked < mono,
        "chunking must cut the worst decode stall: chunked {:.3} ms vs monolithic {:.3} ms",
        chunked * 1e3,
        mono * 1e3,
    );

    // Transient sweep: the bounded-carry claim measured directly. One
    // prefill per (mode, prompt length); the plain chunked carry grows
    // linearly with the prompt while the streamed carry is pinned at the
    // working cap — flat at every length.
    let mut chunked_peaks = Vec::new();
    let mut stream_peaks = Vec::new();
    for mult in [1usize, 2, 4] {
        let len = long_len * mult;
        let chunked_peak = one_prefill_carry_peak(len, false);
        let stream_peak = one_prefill_carry_peak(len, true);
        println!(
            "{:<40} chunked_carry_kb={:.1} stream_carry_kb={:.1}",
            format!("chunked-prefill/transient/len{len}"),
            chunked_peak as f64 / 1e3,
            stream_peak as f64 / 1e3,
        );
        rows.push(Json::obj(vec![
            ("mode", Json::str("transient_sweep")),
            ("prompt_len", Json::num(len as f64)),
            ("chunked_carry_peak_bytes", Json::num(chunked_peak as f64)),
            ("stream_carry_peak_bytes", Json::num(stream_peak as f64)),
        ]));
        chunked_peaks.push(chunked_peak);
        stream_peaks.push(stream_peak);
    }
    assert!(
        chunked_peaks[2] > chunked_peaks[0] * 3,
        "plain chunked carry must grow with the prompt: {chunked_peaks:?}"
    );
    assert!(
        stream_peaks.iter().all(|&p| p == stream_peaks[0]),
        "streamed carry must stay flat in prompt length: {stream_peaks:?}"
    );
    assert!(
        stream_peaks[0] < chunked_peaks[0],
        "streamed carry must undercut the plain chunked carry: {} vs {}",
        stream_peaks[0],
        chunked_peaks[0],
    );

    // Resident sweep: the chunk-major claim measured on the *whole* prefill
    // working set (carry lanes + observation panels + hidden rows), not
    // just the carry the transient sweep tracks. Prompt length doubles
    // three times; both chunk-major arms must stay flat (Q8 strictly under
    // f32) while the layer-major path grows linearly with its O(prompt)
    // hidden rows.
    let mut lm_peaks = Vec::new();
    let mut cm_peaks = Vec::new();
    let mut q8_peaks = Vec::new();
    for mult in [1usize, 2, 4, 8] {
        let len = long_len * mult;
        let layer_major = one_prefill_resident_peak(len, true, false);
        let chunk_major = one_prefill_resident_peak(len, false, false);
        let chunk_major_q8 = one_prefill_resident_peak(len, false, true);
        println!(
            "{:<40} layer_major_kb={:.1} chunk_major_kb={:.1} chunk_major_q8_kb={:.1}",
            format!("chunked-prefill/resident/len{len}"),
            layer_major as f64 / 1e3,
            chunk_major as f64 / 1e3,
            chunk_major_q8 as f64 / 1e3,
        );
        rows.push(Json::obj(vec![
            ("mode", Json::str("resident_sweep")),
            ("prompt_len", Json::num(len as f64)),
            ("layer_major_resident_bytes", Json::num(layer_major as f64)),
            ("chunk_major_resident_bytes", Json::num(chunk_major as f64)),
            ("chunk_major_q8_resident_bytes", Json::num(chunk_major_q8 as f64)),
        ]));
        lm_peaks.push(layer_major);
        cm_peaks.push(chunk_major);
        q8_peaks.push(chunk_major_q8);
    }
    assert!(
        lm_peaks[3] > lm_peaks[0] * 4,
        "layer-major resident set must grow linearly with the prompt: {lm_peaks:?}"
    );
    for peaks in [&cm_peaks, &q8_peaks] {
        assert!(
            peaks[3] <= peaks[0] + peaks[0] / 10,
            "chunk-major resident set must stay flat as the prompt doubles: {peaks:?}"
        );
    }
    assert!(
        q8_peaks[0] < cm_peaks[0],
        "Q8 carries must undercut the f32 lanes: {} vs {}",
        q8_peaks[0],
        cm_peaks[0],
    );
    rows
}

/// Peak carry K/V bytes of one chunked prefill (chunk 64) at `len` prompt
/// tokens — the `prefill_transient_bytes` gauge after a single session.
fn one_prefill_carry_peak(len: usize, stream: bool) -> usize {
    let mock = MockBackend::new(MockBackend::default_config());
    let mut engine =
        Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
    let mut rng = Rng::new(33);
    let inst = workloads::needle_qa(&mut rng, len, 4);
    let req = GenerateRequest { prompt: inst.prompt, max_new_tokens: 1 };
    let mut sess = engine.new_session_with_id(1, &req);
    if stream {
        engine.prefill_chunked_stream(&mut sess, 64).unwrap();
    } else {
        engine.prefill_chunked(&mut sess, 64).unwrap();
    }
    engine.metrics.peak_prefill_transient_bytes
}

/// Peak *resident* prefill bytes (carry lanes + observation panels + hidden
/// rows) of one streaming prefill (chunk 64) at `len` prompt tokens — the
/// `prefill_resident_bytes` gauge after a single session, per stream order
/// and carry representation.
fn one_prefill_resident_peak(len: usize, layer_major: bool, q8: bool) -> usize {
    let mock = MockBackend::new(MockBackend::default_config());
    let mut engine =
        Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
    engine.opts.stream_layer_major = layer_major;
    engine.opts.carry_q8 = q8;
    let mut rng = Rng::new(33);
    let inst = workloads::needle_qa(&mut rng, len, 4);
    let req = GenerateRequest { prompt: inst.prompt, max_new_tokens: 1 };
    let mut sess = engine.new_session_with_id(1, &req);
    engine.prefill_chunked_stream(&mut sess, 64).unwrap();
    engine.metrics.peak_prefill_resident_bytes
}

fn main() {
    let args = Args::parse_env();
    let smoke = args.bool("smoke");
    let ctx = args.usize_or("ctx", if smoke { 128 } else { 512 });
    let budget_secs = args.f64_or("secs", if smoke { 0.02 } else { 0.5 });
    let n_requests = args.usize_or("requests", if smoke { 6 } else { 24 });
    let reps = if smoke { 1 } else { 3 };
    println!("== serving benchmarks (ctx {ctx}{}) ==", if smoke { ", smoke" } else { "" });
    if args.bool("pjrt") {
        let dir = args.str_or("artifacts", "artifacts");
        match PjrtBackend::load(&dir) {
            Ok(backend) => {
                let mut engine =
                    Engine::new(backend, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
                run(&mut engine, ctx, budget_secs);
            }
            Err(e) => println!("SKIP pjrt serving bench: {e:#}"),
        }
    } else {
        let mock = MockBackend::new(MockBackend::default_config());
        let mut engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
        run(&mut engine, ctx, budget_secs);
        println!("-- scheduler: mixed buckets, serial vs batched prefill admission --");
        run_scheduler_bench(ctx, n_requests, reps);
        println!("-- tiering: memory pressure, hot/warm spill off vs on --");
        run_tiering_bench(ctx, n_requests, reps);
        println!("-- batched decode: same-bucket grouping off vs on --");
        run_batched_decode_bench(ctx, if smoke { 8 } else { 64 }, reps);
        println!("-- engine sharding: worker x pool-mode sweep, imbalanced units --");
        let (worker_rows, limit) = run_worker_sweep(ctx, n_requests, reps);
        println!("-- serving loop: 1 vs 8 concurrent TCP connections --");
        let serving_rows =
            run_serving_loop_bench(ctx, n_requests, if smoke { 8 } else { 32 });
        println!("-- chunked prefill: long-prompt flood, monolithic vs interleaved --");
        let chunked_rows = run_chunked_prefill_bench(ctx, if smoke { 64 } else { 160 });
        if smoke {
            let doc = Json::obj(vec![
                ("bench", Json::str("serving")),
                ("mode", Json::str("smoke")),
                ("ctx", Json::num(ctx as f64)),
                ("requests", Json::num(n_requests as f64)),
                ("kv_mem_limit", Json::num(limit as f64)),
                ("worker_sweep", Json::Arr(worker_rows)),
                ("serving_sweep", Json::Arr(serving_rows)),
                ("chunked_sweep", Json::Arr(chunked_rows)),
            ]);
            // repo root (one above the cargo package), independent of the
            // invocation CWD — the artifact is committed, not just uploaded
            let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
            std::fs::write(path, json::to_string(&doc) + "\n")
                .unwrap_or_else(|e| panic!("write {path}: {e}"));
            println!("wrote {path}");
        }
        println!("(mock backend; pass -- --pjrt for the real model)");
    }
    println!("serving OK");
}
