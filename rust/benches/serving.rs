//! Serving-level benchmarks: end-to-end prefill/decode timing per policy.
//! Runs on the mock backend by default (isolating coordinator overhead —
//! scoring, selection, cascade, cache maintenance); pass --pjrt to measure
//! the real model path (requires `make artifacts`).
//!
//!   cargo bench --bench serving [-- --pjrt] [-- --ctx 512]

use lava::bench::harness::bench_for;
use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::model::backend::{MockBackend, ModelBackend, PjrtBackend};
use lava::util::cli::Args;
use lava::util::rng::Rng;
use lava::workloads;

fn run<B: ModelBackend>(engine: &mut Engine<B>, ctx: usize, budget_secs: f64) {
    let mut rng = Rng::new(0);
    let inst = workloads::needle_qa(&mut rng, ctx, 4);

    for policy in ["full", "snapkv", "ada-snapkv", "cake", "lava"] {
        engine.opts.policy = Policy::by_name(policy).unwrap();
        engine.opts.budget_per_head = 32;

        let r = bench_for(&format!("prefill/{policy}/ctx{ctx}"), budget_secs, 3, || {
            let (sess, _) = engine.prefill_only(&inst.prompt).unwrap();
            std::hint::black_box(&sess);
        });
        println!("{}", r.line());

        // decode: prefill once, then time steps
        let req = GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 10_000 };
        let mut sess = engine.new_session(&req);
        engine.prefill(&mut sess).unwrap();
        let r = bench_for(&format!("decode/{policy}/ctx{ctx}"), budget_secs, 5, || {
            engine.decode_step(&mut sess).unwrap();
        });
        println!("{}", r.line());
    }
}

fn main() {
    let args = Args::parse_env();
    let ctx = args.usize_or("ctx", 512);
    let budget_secs = args.f64_or("secs", 0.5);
    println!("== serving benchmarks (ctx {ctx}) ==");
    if args.bool("pjrt") {
        let dir = args.str_or("artifacts", "artifacts");
        match PjrtBackend::load(&dir) {
            Ok(backend) => {
                let mut engine =
                    Engine::new(backend, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
                run(&mut engine, ctx, budget_secs);
            }
            Err(e) => println!("SKIP pjrt serving bench: {e:#}"),
        }
    } else {
        let mock = MockBackend::new(MockBackend::default_config());
        let mut engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32));
        run(&mut engine, ctx, budget_secs);
        println!("(mock backend; pass -- --pjrt for the real model)");
    }
    println!("serving OK");
}
