//! Hot-path micro-benchmarks (criterion is not vendored; bench::harness
//! provides warmup+stats). Covers the paper's §5.3 overhead claims:
//! scoring + selection + cache compaction must be a negligible fraction of
//! layer compute.
//!
//!   cargo bench --bench hotpath

use lava::bench::harness::{bench, BenchResult};
use lava::compress::select::{select_prefill, select_recompress};
use lava::compress::{score, GroupReduce, HeadAlloc, LayerObs, ScoreKind};
use lava::coordinator::pool::{PoolMode, WorkerPool};
use lava::kvcache::LayerCache;
use lava::runtime::Tensor;
use lava::util::rng::Rng;

fn synth_obs(h: usize, hk: usize, w: usize, n: usize, seed: u64) -> LayerObs {
    let mut rng = Rng::new(seed);
    let win: Vec<f32> = (0..h * w * n).map(|_| rng.f32()).collect();
    let acc: Vec<f32> = (0..h * n).map(|_| rng.f32()).collect();
    let vn: Vec<f32> = (0..hk * n).map(|_| 0.5 + rng.f32()).collect();
    LayerObs {
        win_attn: Tensor::f32(win, &[h, w, n]),
        acc_attn: Tensor::f32(acc, &[h, n]),
        vnorm: Tensor::f32(vn, &[hk, n]),
        length: n,
    }
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    println!("== hotpath micro-benchmarks ==");

    // 1. scoring, per kind, n = 1024 (the per-layer prefill overhead)
    for n in [256usize, 1024, 2048] {
        let obs = synth_obs(8, 4, 16, n, 1);
        for (label, kind, reduce) in [
            ("snapkv", ScoreKind::SnapKv, GroupReduce::Mean),
            ("h2o", ScoreKind::H2o, GroupReduce::Mean),
            ("cake", ScoreKind::Cake { gamma: 5.0 }, GroupReduce::Mean),
            ("vatp", ScoreKind::Vatp, GroupReduce::Mean),
            ("lava", ScoreKind::Lava, GroupReduce::Max),
        ] {
            let r = bench(&format!("score/{label}/n{n}"), 3, 30, || {
                let s = score::kv_head_scores(kind, reduce, &obs, 7);
                std::hint::black_box(&s);
            });
            println!("{}", r.line());
            results.push(r);
        }
    }

    // 1b. maxpool with per-call allocation vs reusable scratch — the
    // score-path allocation the ScoreScratch refactor removed from every
    // (q-head x layer) row; the pair's delta is the win per row
    {
        let mut rng = Rng::new(6);
        let base: Vec<f32> = (0..4096).map(|_| rng.f32()).collect();
        let mut row = base.clone();
        let r = bench("score/maxpool_alloc/n4096", 3, 200, || {
            row.copy_from_slice(&base);
            score::maxpool_row(&mut row, 7);
            std::hint::black_box(&row);
        });
        println!("{}", r.line());
        results.push(r);
        let mut scratch = Vec::new();
        let r = bench("score/maxpool_scratch/n4096", 3, 200, || {
            row.copy_from_slice(&base);
            score::maxpool_row_scratch(&mut row, 7, &mut scratch);
            std::hint::black_box(&row);
        });
        println!("{}", r.line());
        results.push(r);
    }

    // 1c. chunked-prefill observation panels: fresh allocation per layer vs
    // the zero-and-reuse the chunked state machine does when a layer
    // completes (it reclaims the f32 buffers from the scored LayerObs and
    // clears them for the next layer instead of reallocating — one panel is
    // H·w·n + H·n + Hk·n floats, touched once per layer per session)
    {
        let (h, hk, w, n) = (8usize, 4usize, 16usize, 2048usize);
        let r = bench("prefill/panel_alloc/n2048", 3, 100, || {
            let win = vec![0.0f32; h * w * n];
            let acc = vec![0.0f32; h * n];
            let vn = vec![0.0f32; hk * n];
            std::hint::black_box((&win, &acc, &vn));
        });
        println!("{}", r.line());
        results.push(r);
        let mut win = vec![0.0f32; h * w * n];
        let mut acc = vec![0.0f32; h * n];
        let mut vn = vec![0.0f32; hk * n];
        let r = bench("prefill/panel_scratch/n2048", 3, 100, || {
            win.fill(0.0);
            acc.fill(0.0);
            vn.fill(0.0);
            std::hint::black_box((&win, &acc, &vn));
        });
        println!("{}", r.line());
        results.push(r);
    }

    // 2. top-B selection (Algorithm 1), flat vs fixed
    for n in [1024usize, 4096] {
        let mut rng = Rng::new(2);
        let scores: Vec<Vec<f32>> =
            (0..4).map(|_| (0..n).map(|_| rng.f32()).collect()).collect();
        for (label, mode) in [("flat", HeadAlloc::Flat), ("fixed", HeadAlloc::Fixed)] {
            let r = bench(&format!("select/{label}/n{n}"), 3, 50, || {
                let ks = select_prefill(&scores, n, 4 * 64, 16, mode);
                std::hint::black_box(&ks);
            });
            println!("{}", r.line());
            results.push(r);
        }
    }

    // 3. recompression (Algorithm 2 inner step)
    {
        let mut rng = Rng::new(3);
        let stored: Vec<Vec<f32>> =
            (0..4).map(|_| (0..256).map(|_| rng.f32()).collect()).collect();
        let r = bench("recompress/256->128", 3, 200, || {
            let refs: Vec<&[f32]> = stored.iter().map(|v| v.as_slice()).collect();
            let keep = select_recompress(&refs, 128 * 4 / 2, HeadAlloc::Flat);
            std::hint::black_box(&keep);
        });
        println!("{}", r.line());
        results.push(r);
    }

    // 4. cache ops: load_from_prefill, re_evict, append, decode_tensors
    {
        let mut rng = Rng::new(4);
        let n = 1024;
        let (hk, dh) = (4, 16);
        let kdata: Vec<f32> = (0..hk * n * dh).map(|_| rng.f32()).collect();
        let k = Tensor::f32(kdata.clone(), &[hk, n, dh]);
        let v = Tensor::f32(kdata, &[hk, n, dh]);
        let keep: Vec<Vec<usize>> = (0..hk).map(|_| rng.sample_indices(n, 128)).collect();
        let sc: Vec<Vec<f32>> = keep.iter().map(|k| k.iter().map(|_| rng.f32()).collect()).collect();

        let r = bench("kvcache/load_from_prefill/128of1024", 3, 100, || {
            let mut c = LayerCache::new(hk, dh, 256);
            c.load_from_prefill(&k, &v, &keep, &sc);
            std::hint::black_box(&c);
        });
        println!("{}", r.line());
        results.push(r);

        let mut c = LayerCache::new(hk, dh, 256);
        c.load_from_prefill(&k, &v, &keep, &sc);
        // borrowed views now: this measures the (zero-copy) handoff, the
        // old full-buffer clone is gone from the decode path entirely
        let r = bench("kvcache/decode_tensors/cap256", 3, 100, || {
            let t = c.decode_tensors();
            std::hint::black_box(&t);
        });
        println!("{}", r.line());
        results.push(r);

        let knew = vec![0.5f32; hk * dh];
        let r = bench("kvcache/append", 3, 200, || {
            let mut c2 = c.clone();
            c2.append(&knew, &knew, 2000, 0.1);
            std::hint::black_box(&c2);
        });
        println!("{}", r.line());
        results.push(r);

        // single-entry decode eviction: compacts one head in place (used to
        // rebuild keep-lists for every head and funnel through re_evict).
        // remove+push keeps occupancy constant so the clone stays outside
        // the timed closure and the number reflects the compaction itself.
        let mut c2 = c.clone();
        let mut next_pos = 100_000i32;
        let row = vec![0.5f32; dh];
        let r = bench("kvcache/remove_one/128of256", 3, 200, || {
            c2.remove_one(0, 0);
            c2.push_entry(0, &row, &row, next_pos, 0.1);
            next_pos += 1;
            std::hint::black_box(&c2);
        });
        println!("{}", r.line());
        results.push(r);

        // spill/prefetch round trip (Q8 dehydrate + rehydrate, one layer)
        let r = bench("kvcache/warm_round_trip/128of256", 3, 100, || {
            let block = lava::kvcache::WarmBlock::from_hot(&c);
            let back = block.to_hot();
            std::hint::black_box(&back);
        });
        println!("{}", r.line());
        results.push(r);

        // Q8 block codec with a fresh Vec per block vs the preallocated
        // *_into variants — the pair's delta is what the allocation-free
        // rewrite saves per block on the spill path and per column on the
        // streaming-prefill Q8 carry (one block = one head's live row here)
        let mut rng = Rng::new(7);
        let block: Vec<f32> = (0..128 * dh).map(|_| rng.f32() - 0.5).collect();
        let r = bench("kvcache/q8_codec_alloc/2048", 3, 200, || {
            let max = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = if max == 0.0 { 1.0 } else { max / 127.0 };
            let codes: Vec<i8> = block
                .iter()
                .map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8)
                .collect();
            let back: Vec<f32> = codes.iter().map(|&q| scale * q as f32).collect();
            std::hint::black_box(&back);
        });
        println!("{}", r.line());
        results.push(r);
        let mut codes = vec![0i8; block.len()];
        let mut back = vec![0.0f32; block.len()];
        let r = bench("kvcache/q8_codec_scratch/2048", 3, 200, || {
            let scale = lava::kvcache::warm::quantize_block_into(&block, &mut codes);
            lava::kvcache::warm::dequantize_block_into(&codes, scale, &mut back);
            std::hint::black_box(&back);
        });
        println!("{}", r.line());
        results.push(r);
    }

    // 5. layer-entropy (the dynamic budget overhead, Eq. 7)
    {
        let mut rng = Rng::new(5);
        let scores: Vec<Vec<f32>> =
            (0..4).map(|_| (0..2048).map(|_| rng.f32()).collect()).collect();
        let r = bench("alloc/lava_entropy/n2048", 3, 100, || {
            let e = lava::compress::alloc::lava_layer_entropy(&scores);
            std::hint::black_box(e);
        });
        println!("{}", r.line());
        results.push(r);
    }

    // 6. worker-pool dispatch: spawn-per-round (scoped) vs the persistent
    // injector pool. 64 near-zero units at width 4, so the pair is almost
    // pure dispatch cost — thread spawn/join per round vs wake/park of
    // long-lived workers; the delta is what every scheduler tick saves
    {
        for (label, mode) in
            [("scoped", PoolMode::Scoped), ("persistent", PoolMode::Persistent)]
        {
            let pool = WorkerPool::with_mode(4, mode);
            let r = bench(&format!("pool/dispatch64/{label}/w4"), 3, 50, || {
                let units: Vec<usize> = (0..64).collect();
                let (out, _stats) = pool.run(units, |_ctx, u| u * 2 + 1);
                std::hint::black_box(&out);
            });
            println!("{}", r.line());
            results.push(r);
        }
    }

    // sanity: fail loudly if anything is absurdly slow (>50ms) — these are
    // supposed to be negligible next to layer compute
    for r in &results {
        assert!(
            r.mean_secs < 0.05,
            "{} unexpectedly slow: {:.1} ms",
            r.name,
            r.mean_secs * 1e3
        );
    }
    println!("hotpath OK ({} benchmarks)", results.len());
}
