//! Needle-In-A-Haystack sweep (paper Table 9): context length x needle
//! depth grid; each cell averages several seeds.

use super::{needle_at_depth, Instance};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct NiahCell {
    pub ctx: usize,
    pub depth_frac: f64,
    pub instances: Vec<Instance>,
}

/// Build the full sweep grid.
pub fn grid(ctx_lens: &[usize], depths: &[f64], per_cell: usize, seed: u64) -> Vec<NiahCell> {
    let mut out = Vec::new();
    for (ci, &ctx) in ctx_lens.iter().enumerate() {
        for (di, &depth) in depths.iter().enumerate() {
            let mut rng = Rng::new(seed ^ ((ci as u64) << 32) ^ di as u64);
            out.push(NiahCell {
                ctx,
                depth_frac: depth,
                instances: (0..per_cell).map(|_| needle_at_depth(&mut rng, ctx, depth, 4)).collect(),
            });
        }
    }
    out
}

/// Standard depth fractions used by NIAH plots.
pub fn standard_depths() -> Vec<f64> {
    vec![0.0, 0.25, 0.5, 0.75, 1.0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shape() {
        let g = grid(&[128, 256], &standard_depths(), 3, 0);
        assert_eq!(g.len(), 10);
        assert!(g.iter().all(|c| c.instances.len() == 3));
    }

    #[test]
    fn cells_are_reproducible() {
        let a = grid(&[128], &[0.5], 2, 42);
        let b = grid(&[128], &[0.5], 2, 42);
        assert_eq!(a[0].instances[0].prompt, b[0].instances[0].prompt);
    }
}
