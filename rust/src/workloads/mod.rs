//! Synthetic benchmark suite: the LongBench / NIAH / Ruler / InfiniteBench
//! proxies (DESIGN.md §3 documents the substitution). Byte-level tasks with
//! exact expected continuations, mirroring python/compile/data.py (the
//! training distribution) plus held-out variants the model never saw.
//!
//! Task taxonomy follows the paper's analysis axis:
//!   * extraction tasks — answers are copied from a specific context
//!     location (QA, few-shot recall, synthetic retrieval);
//!   * generation tasks — answers reproduce/extend structure (summarization
//!     proxy, code-completion proxy).

use crate::util::rng::Rng;

pub mod niah;
pub mod ruler;

/// Special token ids (mirrors python config; validated against the manifest
/// at engine start).
pub const BOS: i32 = 256;
pub const SEP: i32 = 257;
pub const QUERY: i32 = 258;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    SingleDocQa,
    MultiDocQa,
    Summarization,
    FewShot,
    Synthetic,
    Code,
}

impl Category {
    pub fn is_extraction(&self) -> bool {
        matches!(
            self,
            Category::SingleDocQa | Category::MultiDocQa | Category::FewShot | Category::Synthetic
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            Category::SingleDocQa => "single-doc-qa",
            Category::MultiDocQa => "multi-doc-qa",
            Category::Summarization => "summarization",
            Category::FewShot => "few-shot",
            Category::Synthetic => "synthetic",
            Category::Code => "code",
        }
    }
}

/// One benchmark instance: a prompt and the exact expected continuation.
#[derive(Debug, Clone)]
pub struct Instance {
    pub prompt: Vec<i32>,
    pub target: Vec<i32>,
}

impl Instance {
    pub fn score(&self, generated: &[i32]) -> f64 {
        score_match(&self.target, generated)
    }
}

/// Per-token exact-match rate in [0, 1] (the suite's uniform metric; the
/// paper mixes F1/Rouge/Acc — exact match preserves the comparisons).
pub fn score_match(target: &[i32], generated: &[i32]) -> f64 {
    if target.is_empty() {
        return 0.0;
    }
    let hits = target
        .iter()
        .zip(generated.iter())
        .filter(|(t, g)| t == g)
        .count();
    hits as f64 / target.len() as f64
}

/// Random filler bytes.
pub fn fill(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(256) as i32).collect()
}

// ------------------------------------------------------------ generators

/// Single needle at a random depth; query by key. (single-doc QA proxy)
pub fn needle_qa(rng: &mut Rng, ctx: usize, needle_len: usize) -> Instance {
    let key = rng.below(256) as i32;
    let val = fill(rng, needle_len);
    let mut needle = vec![SEP, key];
    needle.extend(&val);
    needle.push(SEP);
    let tail = {
        let mut t = vec![QUERY, key];
        t.extend(&val);
        t
    };
    let n_fill = ctx.saturating_sub(needle.len() + tail.len() + 1);
    let depth = rng.below(n_fill.max(1));
    let mut prompt = vec![BOS];
    prompt.extend(fill(rng, depth));
    prompt.extend(&needle);
    prompt.extend(fill(rng, n_fill - depth));
    prompt.push(QUERY);
    prompt.push(key);
    Instance { prompt, target: val }
}

/// Needle at a fixed fractional depth (NIAH sweeps).
pub fn needle_at_depth(rng: &mut Rng, ctx: usize, depth_frac: f64, needle_len: usize) -> Instance {
    let key = rng.below(256) as i32;
    let val = fill(rng, needle_len);
    let mut needle = vec![SEP, key];
    needle.extend(&val);
    needle.push(SEP);
    let tail_len = 2;
    let n_fill = ctx.saturating_sub(needle.len() + tail_len + 1);
    let depth = ((n_fill as f64) * depth_frac.clamp(0.0, 1.0)) as usize;
    let mut prompt = vec![BOS];
    prompt.extend(fill(rng, depth));
    prompt.extend(&needle);
    prompt.extend(fill(rng, n_fill - depth));
    prompt.push(QUERY);
    prompt.push(key);
    Instance { prompt, target: val }
}

/// Several needles with distinct keys; query one. (multi-doc QA proxy)
pub fn multi_needle(rng: &mut Rng, ctx: usize, n_needles: usize, needle_len: usize) -> Instance {
    let mut keys: Vec<i32> = Vec::new();
    while keys.len() < n_needles {
        let k = rng.below(256) as i32;
        if !keys.contains(&k) {
            keys.push(k);
        }
    }
    let vals: Vec<Vec<i32>> = (0..n_needles).map(|_| fill(rng, needle_len)).collect();
    let needle_sz = needle_len + 3;
    let n_fill = ctx.saturating_sub(n_needles * needle_sz + 3);
    // split filler into n_needles+1 chunks
    let mut cuts: Vec<usize> = (0..n_needles).map(|_| rng.below(n_fill + 1)).collect();
    cuts.sort_unstable();
    let mut prompt = vec![BOS];
    let mut prev = 0;
    for i in 0..n_needles {
        prompt.extend(fill(rng, cuts[i] - prev));
        prompt.push(SEP);
        prompt.push(keys[i]);
        prompt.extend(&vals[i]);
        prompt.push(SEP);
        prev = cuts[i];
    }
    prompt.extend(fill(rng, n_fill - prev));
    let qi = rng.below(n_needles);
    prompt.push(QUERY);
    prompt.push(keys[qi]);
    Instance { prompt, target: vals[qi].clone() }
}

/// Key-value store retrieval. (synthetic / passage-retrieval proxy)
pub fn kv_retrieve(rng: &mut Rng, ctx: usize) -> Instance {
    let n_pairs = ((ctx - 4) / 5).max(1);
    let mut prompt = vec![BOS];
    let mut pairs = Vec::with_capacity(n_pairs);
    for _ in 0..n_pairs {
        let k = [rng.below(256) as i32, rng.below(256) as i32];
        let v = [rng.below(256) as i32, rng.below(256) as i32];
        prompt.extend_from_slice(&k);
        prompt.extend_from_slice(&v);
        prompt.push(SEP);
        pairs.push((k, v));
    }
    let (k, v) = pairs[rng.below(pairs.len())];
    prompt.push(QUERY);
    prompt.extend_from_slice(&k);
    Instance { prompt, target: v.to_vec() }
}

/// Few-shot recall: the queried pair also appears several times as
/// "examples" earlier in the context. (few-shot learning proxy)
pub fn fewshot_recall(rng: &mut Rng, ctx: usize, shots: usize) -> Instance {
    let k = [rng.below(256) as i32, rng.below(256) as i32];
    let v = [rng.below(256) as i32, rng.below(256) as i32];
    let n_pairs = ((ctx - 4) / 5).max(shots + 1);
    let shot_slots: Vec<usize> = rng.sample_indices(n_pairs, shots.min(n_pairs));
    let mut prompt = vec![BOS];
    for i in 0..n_pairs {
        if prompt.len() + 8 > ctx {
            break;
        }
        if shot_slots.contains(&i) {
            prompt.extend_from_slice(&k);
            prompt.extend_from_slice(&v);
        } else {
            prompt.extend(fill(rng, 4));
        }
        prompt.push(SEP);
    }
    prompt.push(QUERY);
    prompt.extend_from_slice(&k);
    Instance { prompt, target: v.to_vec() }
}

/// Passkey: digit-bytes value. (synthetic)
pub fn passkey(rng: &mut Rng, ctx: usize) -> Instance {
    let key = rng.below(256) as i32;
    let val: Vec<i32> = (0..5).map(|_| (b'0' + rng.below(10) as u8) as i32).collect();
    let mut needle = vec![SEP, key];
    needle.extend(&val);
    needle.push(SEP);
    let n_fill = ctx.saturating_sub(needle.len() + 3);
    let depth = rng.below(n_fill.max(1));
    let mut prompt = vec![BOS];
    prompt.extend(fill(rng, depth));
    prompt.extend(&needle);
    prompt.extend(fill(rng, n_fill - depth));
    prompt.push(QUERY);
    prompt.push(key);
    Instance { prompt, target: val }
}

/// Salient-content reproduction: payload early, echo at the end.
/// (summarization proxy: reproduce the salient span)
pub fn summarize_echo(rng: &mut Rng, ctx: usize, payload_len: usize) -> Instance {
    let m = payload_len.min((ctx - 3) / 2);
    let payload = fill(rng, m);
    let n_fill = ctx.saturating_sub(m + 3);
    let mut prompt = vec![BOS];
    prompt.extend(&payload);
    prompt.push(SEP);
    prompt.extend(fill(rng, n_fill));
    prompt.push(QUERY);
    Instance { prompt, target: payload }
}

/// Echo-resume: `[BOS] payload [SEP] payload[..k]` — continue the echo.
/// The build-time model is an induction machine (echo is the one task the
/// 1M-param LM masters; see EXPERIMENTS.md §Model), so this family is the
/// *calibrated* eviction-quality probe: producing the next tokens requires
/// the cache to still hold payload positions around depth k. `depth_frac`
/// controls how deep into the (old, evictable) payload the required tokens
/// sit — low depth = deep retrieval (extraction-like), high depth = near
/// the recent window (generation-like).
pub fn echo_resume(rng: &mut Rng, ctx: usize, depth_frac: f64, target_len: usize) -> Instance {
    // training geometry: payload always fills half the context ([BOS] p
    // [SEP] p); only the echo progress k varies with depth. The prompt is
    // therefore shorter than ctx for low depth — intentional, the model's
    // induction solution is offset-sensitive.
    let m = (ctx - 2) / 2;
    let k = ((m as f64) * depth_frac.clamp(0.0, 0.95)) as usize;
    let payload = fill(rng, m);
    let mut prompt = vec![BOS];
    prompt.extend(&payload);
    prompt.push(SEP);
    prompt.extend(&payload[..k]);
    let t = target_len.min(m - k.min(m));
    let target: Vec<i32> = payload[k..(k + t.max(1)).min(m)].to_vec();
    Instance { prompt, target }
}

/// Periodic structure continuation. (code-completion proxy: RepoBench/LCC)
pub fn code_motif(rng: &mut Rng, ctx: usize, period: usize) -> Instance {
    let motif = fill(rng, period);
    let reps = ctx / period + 2;
    let mut body: Vec<i32> = Vec::with_capacity(reps * period);
    for _ in 0..reps {
        body.extend(&motif);
    }
    let mut prompt = vec![BOS];
    // cut at a random phase so the continuation is not aligned
    let offset = rng.below(period);
    prompt.extend(&body[offset..offset + ctx - 1]);
    let start = (ctx - 1 + offset) % period;
    let target: Vec<i32> = (0..period).map(|i| motif[(start + i) % period]).collect();
    Instance { prompt, target }
}

// ------------------------------------------------------------ the suite

/// One named dataset in the LongBench-proxy suite.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: &'static str,
    pub category: Category,
}

/// The 10-dataset LongBench-proxy (Table 2 columns, scaled).
pub fn longbench_suite() -> Vec<TaskSpec> {
    use Category::*;
    vec![
        TaskSpec { name: "needle-qa", category: SingleDocQa },
        TaskSpec { name: "needle-deep", category: SingleDocQa },
        TaskSpec { name: "multi-needle-2", category: MultiDocQa },
        TaskSpec { name: "multi-needle-4", category: MultiDocQa },
        TaskSpec { name: "summ-echo", category: Summarization },
        TaskSpec { name: "summ-echo-long", category: Summarization },
        TaskSpec { name: "fewshot-recall", category: FewShot },
        TaskSpec { name: "kv-retrieve", category: Synthetic },
        TaskSpec { name: "passkey", category: Synthetic },
        TaskSpec { name: "code-motif", category: Code },
        TaskSpec { name: "code-motif-long", category: Code },
        // echo-resume family: the calibrated probes for the build-time
        // model (see `echo_resume`); deep = extraction, late = generation.
        TaskSpec { name: "echo-deep", category: SingleDocQa },
        TaskSpec { name: "echo-mid", category: Synthetic },
        TaskSpec { name: "echo-late", category: Code },
    ]
}

/// Instantiate `count` instances of a named task at context length `ctx`.
pub fn generate(name: &str, rng: &mut Rng, ctx: usize, count: usize) -> Vec<Instance> {
    (0..count)
        .map(|_| match name {
            "needle-qa" => needle_qa(rng, ctx, 4),
            "needle-deep" => needle_at_depth(rng, ctx, 0.15, 4),
            "multi-needle-2" => multi_needle(rng, ctx, 2, 4),
            "multi-needle-4" => multi_needle(rng, ctx, 4, 4),
            "summ-echo" => summarize_echo(rng, ctx, 24),
            "summ-echo-long" => summarize_echo(rng, ctx, 48),
            "fewshot-recall" => fewshot_recall(rng, ctx, 3),
            "kv-retrieve" => kv_retrieve(rng, ctx),
            "passkey" => passkey(rng, ctx),
            "code-motif" => code_motif(rng, ctx, 12),
            "code-motif-long" => code_motif(rng, ctx, 20),
            "echo-deep" => echo_resume(rng, ctx, 0.15, 6),
            "echo-mid" => echo_resume(rng, ctx, 0.5, 6),
            "echo-late" => echo_resume(rng, ctx, 0.85, 6),
            other => panic!("unknown task {other}"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_instance(inst: &Instance, ctx: usize, name: &str) {
        assert!(inst.prompt.len() <= ctx + 2, "prompt {} ctx {}", inst.prompt.len(), ctx);
        if !name.starts_with("echo-") {
            assert!(inst.prompt.len() + 8 >= ctx, "prompt too short: {}", inst.prompt.len());
        }
        assert!(!inst.target.is_empty());
        assert!(inst.prompt.iter().all(|&t| (0..260).contains(&t)));
        assert_eq!(inst.prompt[0], BOS);
    }

    #[test]
    fn all_tasks_generate_valid_instances() {
        let mut rng = Rng::new(1);
        for spec in longbench_suite() {
            for ctx in [128usize, 256, 512] {
                for inst in generate(spec.name, &mut rng, ctx, 3) {
                    check_instance(&inst, ctx, spec.name);
                }
            }
        }
    }

    #[test]
    fn needle_answer_present_in_context() {
        let mut rng = Rng::new(2);
        let inst = needle_qa(&mut rng, 256, 4);
        let key = *inst.prompt.last().unwrap();
        // find [SEP] key val... in the body
        let pos = inst
            .prompt
            .windows(2)
            .position(|w| w[0] == SEP && w[1] == key)
            .expect("needle missing");
        assert_eq!(&inst.prompt[pos + 2..pos + 6], inst.target.as_slice());
    }

    #[test]
    fn needle_depth_is_controlled() {
        let mut rng = Rng::new(3);
        let shallow = needle_at_depth(&mut rng, 512, 0.05, 4);
        let deep = needle_at_depth(&mut rng, 512, 0.95, 4);
        let pos = |inst: &Instance| {
            inst.prompt.iter().position(|&t| t == SEP).unwrap()
        };
        assert!(pos(&shallow) < pos(&deep));
    }

    #[test]
    fn multi_needle_has_all_keys() {
        let mut rng = Rng::new(4);
        let inst = multi_needle(&mut rng, 512, 4, 4);
        let seps = inst.prompt.iter().filter(|&&t| t == SEP).count();
        assert_eq!(seps, 8, "4 needles x 2 delimiters");
    }

    #[test]
    fn motif_target_continues_pattern() {
        let mut rng = Rng::new(5);
        let inst = code_motif(&mut rng, 256, 12);
        // the target must equal the continuation implied by periodicity
        let body = &inst.prompt[1..];
        for (i, &t) in inst.target.iter().enumerate() {
            assert_eq!(t, body[body.len() - 12 + (i % 12)], "periodic continuation");
        }
    }

    #[test]
    fn score_match_rates() {
        assert_eq!(score_match(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(score_match(&[1, 2, 3], &[1, 9, 3]), 2.0 / 3.0);
        assert_eq!(score_match(&[1, 2], &[]), 0.0);
        assert_eq!(score_match(&[], &[1]), 0.0);
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let a = needle_qa(&mut Rng::new(7), 128, 4);
        let b = needle_qa(&mut Rng::new(7), 128, 4);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.target, b.target);
    }
}
