//! Ruler-proxy tasks (paper Table 11): retrieval difficulty scaled along
//! two axes the Ruler benchmark isolates — number of needles (multi-key)
//! and chained lookups (variable tracking / multi-hop).

use super::{fill, Instance, BOS, QUERY, SEP};
use crate::util::rng::Rng;

/// Multi-hop: k1 -> k2 stored in one needle, k2 -> v in another; query k1,
/// expect v. Exercises two dependent retrievals (Ruler's variable tracking).
pub fn multi_hop(rng: &mut Rng, ctx: usize) -> Instance {
    let k1 = rng.below(256) as i32;
    let k2 = rng.below(256) as i32;
    let val = vec![rng.below(256) as i32, rng.below(256) as i32];
    let mut hop1 = vec![SEP, k1, k2, SEP];
    let mut hop2 = vec![SEP, k2];
    hop2.extend(&val);
    hop2.push(SEP);
    let n_fill = ctx.saturating_sub(hop1.len() + hop2.len() + 4);
    let c1 = rng.below(n_fill / 2 + 1);
    let c2 = n_fill / 2 + rng.below(n_fill / 2 + 1).min(n_fill - n_fill / 2);
    let mut prompt = vec![BOS];
    prompt.extend(fill(rng, c1));
    prompt.append(&mut hop1);
    prompt.extend(fill(rng, c2 - c1));
    prompt.append(&mut hop2);
    prompt.extend(fill(rng, n_fill - c2));
    prompt.push(QUERY);
    prompt.push(k1);
    prompt.push(k2);
    Instance { prompt, target: val }
}

/// The Ruler-proxy task set at one context length.
pub fn suite(rng: &mut Rng, ctx: usize, per_task: usize) -> Vec<(&'static str, Vec<Instance>)> {
    vec![
        (
            "niah-single",
            (0..per_task).map(|_| super::needle_qa(rng, ctx, 4)).collect(),
        ),
        (
            "niah-multikey",
            (0..per_task).map(|_| super::multi_needle(rng, ctx, 4, 4)).collect(),
        ),
        (
            "multi-hop",
            (0..per_task).map(|_| multi_hop(rng, ctx)).collect(),
        ),
        (
            "kv-retrieve",
            (0..per_task).map(|_| super::kv_retrieve(rng, ctx)).collect(),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_hop_layout() {
        let mut rng = Rng::new(0);
        let inst = multi_hop(&mut rng, 512);
        assert!(inst.prompt.len() <= 514);
        assert_eq!(inst.target.len(), 2);
        // query carries both hops' keys so retrieval is attention-bound, not
        // reasoning-bound (the model is tiny)
        let n = inst.prompt.len();
        assert_eq!(inst.prompt[n - 3], QUERY);
    }

    #[test]
    fn suite_contains_four_tasks() {
        let mut rng = Rng::new(1);
        let s = suite(&mut rng, 256, 2);
        assert_eq!(s.len(), 4);
        assert!(s.iter().all(|(_, v)| v.len() == 2));
    }
}
