//! Token-scoring functions: from a layer's observation statistics to
//! kv-head-level eviction scores [Hk, length].
//!
//! Pipeline (matches the fused L1 `lava_score` kernel exactly for LAVa):
//!   per-q-head base score -> maxpool(pool_kernel) -> GQA group reduce.
//!
//! All scores are computed over valid positions [0, length); positions in
//! the protected recent window never reach the selector anyway, but their
//! scores are still defined (the paper computes s only for i < N - w; we
//! compute them everywhere and let the selector enforce the window).

use super::{GroupReduce, LayerObs, ScoreKind};

/// Reusable buffers for the per-head scoring pipeline. One scratch serves
/// any number of [`kv_head_row`] calls sequentially: `row` holds the
/// current q-head's base scores, `pool` the maxpool source copy. Scoring a
/// layer used to allocate two fresh `Vec`s per q-head per call
/// (`base_row`'s output and `maxpool_row`'s source snapshot); with the
/// scratch the only per-row allocation left is the returned aggregate.
#[derive(Debug, Default)]
pub struct ScoreScratch {
    row: Vec<f32>,
    pool: Vec<f32>,
}

impl ScoreScratch {
    pub fn new() -> ScoreScratch {
        ScoreScratch::default()
    }
}

/// Same-padding max pool along a row (allocating convenience wrapper over
/// [`maxpool_row_scratch`]).
pub fn maxpool_row(row: &mut [f32], kernel: usize) {
    let mut src = Vec::new();
    maxpool_row_scratch(row, kernel, &mut src);
}

/// Same-padding max pool along a row; `src` is a reusable scratch buffer
/// that receives a copy of the input (grown on demand, never shrunk).
pub fn maxpool_row_scratch(row: &mut [f32], kernel: usize, src: &mut Vec<f32>) {
    if kernel <= 1 || row.is_empty() {
        return;
    }
    let half = kernel / 2;
    let n = row.len();
    src.clear();
    src.extend_from_slice(row);
    for i in 0..n {
        let lo = i.saturating_sub(half);
        let hi = (i + half + 1).min(n);
        let mut m = f32::NEG_INFINITY;
        for &x in &src[lo..hi] {
            m = m.max(x);
        }
        row[i] = m;
    }
}

/// Max valid value norm of one kv head (the Lava vbar of Theorem 1).
fn lava_vbar(obs: &LayerObs, kv: usize) -> f32 {
    let n = obs.bucket();
    let vnorm = obs.vnorm.as_f32().expect("vnorm");
    let mut vbar = 0.0f32;
    for i in 0..obs.length {
        vbar = vbar.max(vnorm[kv * n + i]);
    }
    vbar
}

/// Base scores for one q-head `hh` over [0, length), written into `out`
/// (resized to `length`; previous contents discarded). `vbar` is the
/// precomputed per-kv-head Lava scale (computed once per group, not per
/// q-head); ignored by every other score kind.
fn base_row_into(
    kind: ScoreKind,
    obs: &LayerObs,
    hh: usize,
    group: usize,
    vbar: f32,
    out: &mut Vec<f32>,
) {
    let w = obs.window();
    let n = obs.bucket();
    let len = obs.length;
    let win = obs.win_attn.as_f32().expect("win_attn");

    // helpers over this head's [w, N] window panel
    let at = |r: usize, i: usize| win[(hh * w + r) * n + i];
    let mean_window = |i: usize| -> f32 {
        let mut s = 0.0;
        for r in 0..w {
            s += at(r, i);
        }
        s / w as f32
    };

    out.clear();
    out.resize(len, 0.0f32);
    match kind {
        ScoreKind::SnapKv => {
            for (i, o) in out.iter_mut().enumerate() {
                *o = mean_window(i);
            }
        }
        ScoreKind::H2o => {
            let acc = obs.acc_attn.as_f32().expect("acc_attn");
            out.copy_from_slice(&acc[hh * n..hh * n + len]);
        }
        ScoreKind::Tova => {
            // last window row = the current (N-th) query's attention
            for (i, o) in out.iter_mut().enumerate() {
                *o = at(w - 1, i);
            }
        }
        ScoreKind::Cake { gamma } => {
            for (i, o) in out.iter_mut().enumerate() {
                let m = mean_window(i);
                let mut var = 0.0;
                for r in 0..w {
                    let d = at(r, i) - m;
                    var += d * d;
                }
                *o = m + gamma * var / w as f32;
            }
        }
        ScoreKind::Vatp => {
            let vnorm = obs.vnorm.as_f32().expect("vnorm");
            let kv = hh / group;
            for (i, o) in out.iter_mut().enumerate() {
                *o = mean_window(i) * vnorm[kv * n + i];
            }
        }
        ScoreKind::Lava => {
            for (i, o) in out.iter_mut().enumerate() {
                *o = mean_window(i) * vbar;
            }
        }
        ScoreKind::Streaming { sinks } => {
            // deterministic recency score: sinks get +inf, otherwise the
            // position itself (later = larger). Selector's top-k then keeps
            // sinks + the most recent tokens.
            for (i, o) in out.iter_mut().enumerate() {
                *o = if i < sinks { f32::MAX } else { i as f32 };
            }
        }
    }
}

/// One kv head's full pipeline: base scores for its q-head group ->
/// maxpool smoothing (paper App. D; skipped for the position-based
/// streaming score where it would be meaningless) -> GQA group reduce.
/// `scratch` carries the reusable per-row buffers across calls.
fn kv_head_row(
    kind: ScoreKind,
    reduce: GroupReduce,
    obs: &LayerObs,
    pool_kernel: usize,
    kv: usize,
    group: usize,
    scratch: &mut ScoreScratch,
) -> Vec<f32> {
    let len = obs.length;
    let vbar = if kind == ScoreKind::Lava { lava_vbar(obs, kv) } else { 0.0 };
    let mut agg = match reduce {
        GroupReduce::Mean => vec![0.0f32; len],
        GroupReduce::Max => vec![f32::NEG_INFINITY; len],
    };
    for g in 0..group {
        base_row_into(kind, obs, kv * group + g, group, vbar, &mut scratch.row);
        if !matches!(kind, ScoreKind::Streaming { .. }) {
            maxpool_row_scratch(&mut scratch.row, pool_kernel, &mut scratch.pool);
        }
        for (a, v) in agg.iter_mut().zip(&scratch.row) {
            match reduce {
                GroupReduce::Mean => *a += v,
                GroupReduce::Max => *a = a.max(*v),
            }
        }
    }
    if reduce == GroupReduce::Mean {
        for a in agg.iter_mut() {
            *a /= group as f32;
        }
    }
    agg
}

/// Below this many (q-head x position) cells the whole layer is scored
/// serially — thread spawn costs more than the arithmetic.
const PAR_MIN_CELLS: usize = 8192;

/// Full scoring pipeline -> [Hk][length] kv-head scores. Each kv head is an
/// independent unit of work, so large layers fan out across scoped threads.
pub fn kv_head_scores(
    kind: ScoreKind,
    reduce: GroupReduce,
    obs: &LayerObs,
    pool_kernel: usize,
) -> Vec<Vec<f32>> {
    let h = obs.n_heads();
    let hk = obs.n_kv_heads();
    let group = h / hk;
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); hk];
    if hk > 1 && h * obs.length >= PAR_MIN_CELLS {
        // one scratch per unit of work: heads run on different threads
        crate::util::par::scoped_for_each(out.iter_mut().enumerate(), |(kv, row)| {
            let mut scratch = ScoreScratch::new();
            *row = kv_head_row(kind, reduce, obs, pool_kernel, kv, group, &mut scratch);
        });
    } else {
        // serial arm: every head reuses the same buffers
        let mut scratch = ScoreScratch::new();
        for (kv, row) in out.iter_mut().enumerate() {
            *row = kv_head_row(kind, reduce, obs, pool_kernel, kv, group, &mut scratch);
        }
    }
    out
}

/// [`kv_head_scores`] scoring every head serially with a caller-owned
/// scratch — the worker-pool hot paths call this with their
/// [`WorkerContext`](crate::coordinator::pool::WorkerContext) arena so a
/// streamed chunk round allocates no per-call row buffers. (These call
/// sites run *inside* a pool unit; nesting another scoped fan-out there
/// would oversubscribe the cores, so serial is also the right shape.)
pub fn kv_head_scores_with(
    kind: ScoreKind,
    reduce: GroupReduce,
    obs: &LayerObs,
    pool_kernel: usize,
    scratch: &mut ScoreScratch,
) -> Vec<Vec<f32>> {
    let h = obs.n_heads();
    let hk = obs.n_kv_heads();
    let group = h / hk;
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); hk];
    for (kv, row) in out.iter_mut().enumerate() {
        *row = kv_head_row(kind, reduce, obs, pool_kernel, kv, group, scratch);
    }
    out
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::runtime::Tensor;
    use crate::util::rng::Rng;

    /// Build a synthetic LayerObs with a known peaked position.
    pub fn synth_obs(h: usize, hk: usize, w: usize, n: usize, len: usize, peak: usize,
                     seed: u64) -> LayerObs {
        let mut rng = Rng::new(seed);
        let mut win = vec![0.0f32; h * w * n];
        for hh in 0..h {
            for r in 0..w {
                // near-uniform over valid prefix + a spike at `peak`
                let row_len = len;
                let base = 1.0 / row_len as f32;
                for i in 0..row_len {
                    win[(hh * w + r) * n + i] = base * (0.5 + rng.f32());
                }
                win[(hh * w + r) * n + peak] += 0.5;
                // renormalize
                let s: f32 = win[(hh * w + r) * n..(hh * w + r) * n + row_len].iter().sum();
                for i in 0..row_len {
                    win[(hh * w + r) * n + i] /= s;
                }
            }
        }
        let mut acc = vec![0.0f32; h * n];
        for hh in 0..h {
            for i in 0..len {
                acc[hh * n + i] = rng.f32();
            }
            acc[hh * n + peak] += 2.0;
        }
        let mut vn = vec![0.0f32; hk * n];
        for kv in 0..hk {
            for i in 0..len {
                vn[kv * n + i] = 0.5 + rng.f32();
            }
        }
        LayerObs {
            win_attn: Tensor::f32(win, &[h, w, n]),
            acc_attn: Tensor::f32(acc, &[h, n]),
            vnorm: Tensor::f32(vn, &[hk, n]),
            length: len,
        }
    }

    #[test]
    fn maxpool_basics() {
        let mut r = vec![0.0, 1.0, 0.0, 0.0, 5.0, 0.0];
        maxpool_row(&mut r, 3);
        assert_eq!(r, vec![1.0, 1.0, 1.0, 5.0, 5.0, 5.0]);
        let mut r2 = vec![3.0, 1.0];
        maxpool_row(&mut r2, 1);
        assert_eq!(r2, vec![3.0, 1.0]); // kernel 1 = identity
    }

    #[test]
    fn maxpool_scratch_reuse_across_lengths() {
        let mut src = Vec::new();
        let mut long = vec![0.0, 1.0, 0.0, 0.0, 5.0, 0.0];
        maxpool_row_scratch(&mut long, 3, &mut src);
        assert_eq!(long, vec![1.0, 1.0, 1.0, 5.0, 5.0, 5.0]);
        // a shorter row through the same (larger) scratch must not see the
        // previous call's tail values
        let mut short = vec![2.0, 0.0];
        maxpool_row_scratch(&mut short, 3, &mut src);
        assert_eq!(short, vec![2.0, 2.0]);
    }

    #[test]
    fn all_kinds_rank_the_peak_high() {
        let peak = 17;
        let obs = synth_obs(4, 2, 8, 64, 50, peak, 0);
        for kind in [
            ScoreKind::SnapKv,
            ScoreKind::H2o,
            ScoreKind::Tova,
            ScoreKind::Cake { gamma: 5.0 },
            ScoreKind::Vatp,
            ScoreKind::Lava,
        ] {
            let s = kv_head_scores(kind, GroupReduce::Mean, &obs, 1);
            for kv in 0..2 {
                let argmax = s[kv]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                assert_eq!(argmax, peak, "{kind:?} head {kv}");
            }
        }
    }

    #[test]
    fn parallel_scores_match_serial() {
        // above PAR_MIN_CELLS the fan-out path runs; it must be bit-identical
        // to scoring each kv head directly
        let obs = synth_obs(8, 4, 8, 2048, 1200, 37, 6);
        assert!(8 * obs.length >= PAR_MIN_CELLS, "test must exercise the parallel path");
        for kind in [ScoreKind::Lava, ScoreKind::SnapKv, ScoreKind::H2o] {
            for reduce in [GroupReduce::Mean, GroupReduce::Max] {
                let fanned = kv_head_scores(kind, reduce, &obs, 7);
                let mut scratch = ScoreScratch::new();
                for kv in 0..4 {
                    let serial = kv_head_row(kind, reduce, &obs, 7, kv, 2, &mut scratch);
                    assert_eq!(fanned[kv], serial, "{kind:?}/{reduce:?} head {kv}");
                }
            }
        }
    }

    #[test]
    fn lava_scales_with_value_norm() {
        let mut obs = synth_obs(4, 2, 8, 64, 50, 10, 1);
        let s1 = kv_head_scores(ScoreKind::Lava, GroupReduce::Max, &obs, 7);
        let vn = obs.vnorm.as_f32_mut().unwrap();
        for x in vn.iter_mut() {
            *x *= 3.0;
        }
        let s2 = kv_head_scores(ScoreKind::Lava, GroupReduce::Max, &obs, 7);
        for kv in 0..2 {
            for i in 0..50 {
                assert!((s2[kv][i] - 3.0 * s1[kv][i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn group_max_dominates_mean() {
        let obs = synth_obs(4, 2, 8, 64, 40, 5, 2);
        let smax = kv_head_scores(ScoreKind::SnapKv, GroupReduce::Max, &obs, 7);
        let smean = kv_head_scores(ScoreKind::SnapKv, GroupReduce::Mean, &obs, 7);
        for kv in 0..2 {
            for i in 0..40 {
                assert!(smax[kv][i] >= smean[kv][i] - 1e-7);
            }
        }
    }

    #[test]
    fn streaming_scores_are_positional() {
        let obs = synth_obs(4, 2, 8, 64, 40, 5, 3);
        let s = kv_head_scores(ScoreKind::Streaming { sinks: 4 }, GroupReduce::Mean,
                               &obs, 7);
        // sinks are pinned at +big (mean-reduce over the group may take
        // f32::MAX to +inf; any value >= f32::MAX means "always keep")
        assert!(s[0][0] >= f32::MAX);
        assert!(s[0][3] >= f32::MAX);
        assert!(s[0][4] < s[0][39]);
    }

    #[test]
    fn tova_is_last_row() {
        let obs = synth_obs(2, 2, 4, 32, 20, 7, 4);
        let s = kv_head_scores(ScoreKind::Tova, GroupReduce::Mean, &obs, 1);
        let win = obs.win_attn.as_f32().unwrap();
        let w = 4usize;
        let n = 32usize;
        // head 0 == kv head 0 (group size 1)
        assert!((s[0][7] - win[(0 * w + 3) * n + 7]).abs() < 1e-7);
    }

    #[test]
    fn vatp_uses_per_token_norm_lava_uses_max() {
        let mut obs = synth_obs(2, 2, 4, 32, 20, 7, 5);
        // make vnorm strongly non-uniform: token 3 has huge value norm
        {
            let vn = obs.vnorm.as_f32_mut().unwrap();
            for kv in 0..2 {
                vn[kv * 32 + 3] = 100.0;
            }
        }
        let vatp = kv_head_scores(ScoreKind::Vatp, GroupReduce::Mean, &obs, 1);
        let lava = kv_head_scores(ScoreKind::Lava, GroupReduce::Mean, &obs, 1);
        // VATP boosts token 3 by its own norm; LAVa scales all tokens equally
        let ratio_vatp = vatp[0][3] / vatp[0][7];
        let ratio_lava = lava[0][3] / lava[0][7];
        assert!(ratio_vatp > ratio_lava * 10.0);
    }
}
