//! Token-scoring functions: from a layer's observation statistics to
//! kv-head-level eviction scores [Hk, length].
//!
//! Pipeline (matches the fused L1 `lava_score` kernel exactly for LAVa):
//!   per-q-head base score -> maxpool(pool_kernel) -> GQA group reduce.
//!
//! All scores are computed over valid positions [0, length); positions in
//! the protected recent window never reach the selector anyway, but their
//! scores are still defined (the paper computes s only for i < N - w; we
//! compute them everywhere and let the selector enforce the window).

use super::{GroupReduce, LayerObs, ScoreKind};

/// Same-padding max pool along a row.
pub fn maxpool_row(row: &mut [f32], kernel: usize) {
    if kernel <= 1 || row.is_empty() {
        return;
    }
    let half = kernel / 2;
    let n = row.len();
    let src = row.to_vec();
    for i in 0..n {
        let lo = i.saturating_sub(half);
        let hi = (i + half + 1).min(n);
        let mut m = f32::NEG_INFINITY;
        for &x in &src[lo..hi] {
            m = m.max(x);
        }
        row[i] = m;
    }
}

/// Per-q-head base scores [H][length] for a score kind.
fn base_scores(kind: ScoreKind, obs: &LayerObs, group: usize) -> Vec<Vec<f32>> {
    let h = obs.n_heads();
    let w = obs.window();
    let n = obs.bucket();
    let len = obs.length;
    let win = obs.win_attn.as_f32().expect("win_attn");
    let acc = obs.acc_attn.as_f32().expect("acc_attn");
    let vnorm = obs.vnorm.as_f32().expect("vnorm");

    // helpers over the [H, w, N] window panel
    let at = |hh: usize, r: usize, i: usize| win[(hh * w + r) * n + i];
    let mean_window = |hh: usize, i: usize| -> f32 {
        let mut s = 0.0;
        for r in 0..w {
            s += at(hh, r, i);
        }
        s / w as f32
    };

    let mut out = vec![vec![0.0f32; len]; h];
    match kind {
        ScoreKind::SnapKv => {
            for hh in 0..h {
                for i in 0..len {
                    out[hh][i] = mean_window(hh, i);
                }
            }
        }
        ScoreKind::H2o => {
            for hh in 0..h {
                for i in 0..len {
                    out[hh][i] = acc[hh * n + i];
                }
            }
        }
        ScoreKind::Tova => {
            // last window row = the current (N-th) query's attention
            for hh in 0..h {
                for i in 0..len {
                    out[hh][i] = at(hh, w - 1, i);
                }
            }
        }
        ScoreKind::Cake { gamma } => {
            for hh in 0..h {
                for i in 0..len {
                    let m = mean_window(hh, i);
                    let mut var = 0.0;
                    for r in 0..w {
                        let d = at(hh, r, i) - m;
                        var += d * d;
                    }
                    out[hh][i] = m + gamma * var / w as f32;
                }
            }
        }
        ScoreKind::Vatp => {
            for hh in 0..h {
                let kv = hh / group;
                for i in 0..len {
                    out[hh][i] = mean_window(hh, i) * vnorm[kv * n + i];
                }
            }
        }
        ScoreKind::Lava => {
            // vbar per kv head = max valid value norm (Theorem 1)
            let hk = obs.n_kv_heads();
            let mut vbar = vec![0.0f32; hk];
            for kv in 0..hk {
                for i in 0..len {
                    vbar[kv] = vbar[kv].max(vnorm[kv * n + i]);
                }
            }
            for hh in 0..h {
                let kv = hh / group;
                for i in 0..len {
                    out[hh][i] = mean_window(hh, i) * vbar[kv];
                }
            }
        }
        ScoreKind::Streaming { sinks } => {
            // deterministic recency score: sinks get +inf, otherwise the
            // position itself (later = larger). Selector's top-k then keeps
            // sinks + the most recent tokens.
            for hh in 0..h {
                for (i, o) in out[hh].iter_mut().enumerate() {
                    *o = if i < sinks { f32::MAX } else { i as f32 };
                }
            }
        }
    }
    out
}

/// Full scoring pipeline -> [Hk][length] kv-head scores.
pub fn kv_head_scores(
    kind: ScoreKind,
    reduce: GroupReduce,
    obs: &LayerObs,
    pool_kernel: usize,
) -> Vec<Vec<f32>> {
    let h = obs.n_heads();
    let hk = obs.n_kv_heads();
    let group = h / hk;
    let len = obs.length;
    let mut per_head = base_scores(kind, obs, group);
    // pooling smooths per-q-head scores (paper App. D; skipped for the
    // position-based streaming score where it would be meaningless)
    if !matches!(kind, ScoreKind::Streaming { .. }) {
        for row in per_head.iter_mut() {
            maxpool_row(row, pool_kernel);
        }
    }
    let mut out = vec![vec![0.0f32; len]; hk];
    for kv in 0..hk {
        for i in 0..len {
            let mut agg: f32 = match reduce {
                GroupReduce::Mean => 0.0,
                GroupReduce::Max => f32::NEG_INFINITY,
            };
            for g in 0..group {
                let v = per_head[kv * group + g][i];
                agg = match reduce {
                    GroupReduce::Mean => agg + v,
                    GroupReduce::Max => agg.max(v),
                };
            }
            out[kv][i] = match reduce {
                GroupReduce::Mean => agg / group as f32,
                GroupReduce::Max => agg,
            };
        }
    }
    out
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::runtime::Tensor;
    use crate::util::rng::Rng;

    /// Build a synthetic LayerObs with a known peaked position.
    pub fn synth_obs(h: usize, hk: usize, w: usize, n: usize, len: usize, peak: usize,
                     seed: u64) -> LayerObs {
        let mut rng = Rng::new(seed);
        let mut win = vec![0.0f32; h * w * n];
        for hh in 0..h {
            for r in 0..w {
                // near-uniform over valid prefix + a spike at `peak`
                let row_len = len;
                let base = 1.0 / row_len as f32;
                for i in 0..row_len {
                    win[(hh * w + r) * n + i] = base * (0.5 + rng.f32());
                }
                win[(hh * w + r) * n + peak] += 0.5;
                // renormalize
                let s: f32 = win[(hh * w + r) * n..(hh * w + r) * n + row_len].iter().sum();
                for i in 0..row_len {
                    win[(hh * w + r) * n + i] /= s;
                }
            }
        }
        let mut acc = vec![0.0f32; h * n];
        for hh in 0..h {
            for i in 0..len {
                acc[hh * n + i] = rng.f32();
            }
            acc[hh * n + peak] += 2.0;
        }
        let mut vn = vec![0.0f32; hk * n];
        for kv in 0..hk {
            for i in 0..len {
                vn[kv * n + i] = 0.5 + rng.f32();
            }
        }
        LayerObs {
            win_attn: Tensor::f32(win, &[h, w, n]),
            acc_attn: Tensor::f32(acc, &[h, n]),
            vnorm: Tensor::f32(vn, &[hk, n]),
            length: len,
        }
    }

    #[test]
    fn maxpool_basics() {
        let mut r = vec![0.0, 1.0, 0.0, 0.0, 5.0, 0.0];
        maxpool_row(&mut r, 3);
        assert_eq!(r, vec![1.0, 1.0, 1.0, 5.0, 5.0, 5.0]);
        let mut r2 = vec![3.0, 1.0];
        maxpool_row(&mut r2, 1);
        assert_eq!(r2, vec![3.0, 1.0]); // kernel 1 = identity
    }

    #[test]
    fn all_kinds_rank_the_peak_high() {
        let peak = 17;
        let obs = synth_obs(4, 2, 8, 64, 50, peak, 0);
        for kind in [
            ScoreKind::SnapKv,
            ScoreKind::H2o,
            ScoreKind::Tova,
            ScoreKind::Cake { gamma: 5.0 },
            ScoreKind::Vatp,
            ScoreKind::Lava,
        ] {
            let s = kv_head_scores(kind, GroupReduce::Mean, &obs, 1);
            for kv in 0..2 {
                let argmax = s[kv]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                assert_eq!(argmax, peak, "{kind:?} head {kv}");
            }
        }
    }

    #[test]
    fn lava_scales_with_value_norm() {
        let mut obs = synth_obs(4, 2, 8, 64, 50, 10, 1);
        let s1 = kv_head_scores(ScoreKind::Lava, GroupReduce::Max, &obs, 7);
        let vn = obs.vnorm.as_f32_mut().unwrap();
        for x in vn.iter_mut() {
            *x *= 3.0;
        }
        let s2 = kv_head_scores(ScoreKind::Lava, GroupReduce::Max, &obs, 7);
        for kv in 0..2 {
            for i in 0..50 {
                assert!((s2[kv][i] - 3.0 * s1[kv][i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn group_max_dominates_mean() {
        let obs = synth_obs(4, 2, 8, 64, 40, 5, 2);
        let smax = kv_head_scores(ScoreKind::SnapKv, GroupReduce::Max, &obs, 7);
        let smean = kv_head_scores(ScoreKind::SnapKv, GroupReduce::Mean, &obs, 7);
        for kv in 0..2 {
            for i in 0..40 {
                assert!(smax[kv][i] >= smean[kv][i] - 1e-7);
            }
        }
    }

    #[test]
    fn streaming_scores_are_positional() {
        let obs = synth_obs(4, 2, 8, 64, 40, 5, 3);
        let s = kv_head_scores(ScoreKind::Streaming { sinks: 4 }, GroupReduce::Mean,
                               &obs, 7);
        // sinks are pinned at +big (mean-reduce over the group may take
        // f32::MAX to +inf; any value >= f32::MAX means "always keep")
        assert!(s[0][0] >= f32::MAX);
        assert!(s[0][3] >= f32::MAX);
        assert!(s[0][4] < s[0][39]);
    }

    #[test]
    fn tova_is_last_row() {
        let obs = synth_obs(2, 2, 4, 32, 20, 7, 4);
        let s = kv_head_scores(ScoreKind::Tova, GroupReduce::Mean, &obs, 1);
        let win = obs.win_attn.as_f32().unwrap();
        let w = 4usize;
        let n = 32usize;
        // head 0 == kv head 0 (group size 1)
        assert!((s[0][7] - win[(0 * w + 3) * n + 7]).abs() < 1e-7);
    }

    #[test]
    fn vatp_uses_per_token_norm_lava_uses_max() {
        let mut obs = synth_obs(2, 2, 4, 32, 20, 7, 5);
        // make vnorm strongly non-uniform: token 3 has huge value norm
        {
            let vn = obs.vnorm.as_f32_mut().unwrap();
            for kv in 0..2 {
                vn[kv * 32 + 3] = 100.0;
            }
        }
        let vatp = kv_head_scores(ScoreKind::Vatp, GroupReduce::Mean, &obs, 1);
        let lava = kv_head_scores(ScoreKind::Lava, GroupReduce::Mean, &obs, 1);
        // VATP boosts token 3 by its own norm; LAVa scales all tokens equally
        let ratio_vatp = vatp[0][3] / vatp[0][7];
        let ratio_lava = lava[0][3] / lava[0][7];
        assert!(ratio_vatp > ratio_lava * 10.0);
    }
}
