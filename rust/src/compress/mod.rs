//! Cache compression policies: LAVa (the paper's contribution) and every
//! baseline it is evaluated against, expressed in one shared vocabulary so
//! comparisons are apples-to-apples (DESIGN.md §4):
//!
//!   score kind      x  GQA group reduce  x  head-budget mode  x  layer-budget mode
//!   (Table 1/4)        (§4.3)               (Alg. 1)             (§4.2)
//!
//! All policies consume the same `LayerObs` produced by the
//! `layer_prefill_{N}` artifact (recent-window attention, accumulated
//! attention mass, value norms).

pub mod alloc;
pub mod score;
pub mod select;

use crate::runtime::Tensor;

/// Per-layer observation statistics from the prefill pass.
#[derive(Debug, Clone)]
pub struct LayerObs {
    /// [H, w, N] attention of the last w queries over all positions.
    pub win_attn: Tensor,
    /// [H, N] accumulated column attention mass over all valid rows (H2O).
    pub acc_attn: Tensor,
    /// [Hk, N] per-token value L1 norms.
    pub vnorm: Tensor,
    /// Valid token count (<= N bucket).
    pub length: usize,
}

impl LayerObs {
    pub fn n_heads(&self) -> usize {
        self.win_attn.shape[0]
    }

    pub fn window(&self) -> usize {
        self.win_attn.shape[1]
    }

    pub fn bucket(&self) -> usize {
        self.win_attn.shape[2]
    }

    pub fn n_kv_heads(&self) -> usize {
        self.vnorm.shape[0]
    }
}

/// Token-scoring rule (Table 1 / Table 4 of the paper).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScoreKind {
    /// Mean recent-window attention (SnapKV; also AdaKV / PyramidKV).
    SnapKv,
    /// Accumulated attention over all past queries (H2O).
    H2o,
    /// Last-token attention (TOVA).
    Tova,
    /// SnapKV + gamma * temporal variance over the window (CAKE).
    Cake { gamma: f32 },
    /// Per-token value-norm-weighted window attention (VATP).
    Vatp,
    /// max value norm per head x window attention (LAVa, Definition 1).
    Lava,
    /// Position-based sink + recency (StreamingLLM); needs no statistics.
    Streaming { sinks: usize },
}

/// How per-query-head scores collapse onto the (GQA-shared) kv heads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupReduce {
    /// Average over the group (baseline implementations).
    Mean,
    /// Max over the group — the paper's conservative rule (§4.3).
    Max,
}

/// Head-budget mode (Algorithm 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HeadAlloc {
    /// B_l / H_k per head, head-local top-k.
    Fixed,
    /// Flatten scores across heads; one layer-wide top-B_l (dynamic).
    Flat,
}

/// Layer-budget mode (§4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerAlloc {
    Uniform,
    /// PyramidKV Eq. 21, parameterized by beta.
    Pyramid { beta: f32 },
    /// CAKE Eq. 22-23: spatial entropy ^ (1/g1) * temporal variance ^ (1/g2).
    CakeHv { g1: f32, g2: f32 },
    /// LAVa Eq. 6-7: normalized entropy of the layer's score distribution.
    Entropy,
}

/// A complete eviction policy.
#[derive(Debug, Clone, PartialEq)]
pub struct Policy {
    pub name: &'static str,
    pub score: ScoreKind,
    pub group_reduce: GroupReduce,
    pub head_alloc: HeadAlloc,
    pub layer_alloc: LayerAlloc,
    /// Evict one entry per head per decode step once over budget (how H2O
    /// and TOVA operate at decode time).
    pub decode_evict: bool,
    /// No compression at all (the Full Cache reference row).
    pub full_cache: bool,
}

impl Policy {
    /// Whether the layer budgets depend on the prompt (dynamic -> requires
    /// Algorithm 2's cascading recompression during layer-wise prefill).
    pub fn dynamic_layer(&self) -> bool {
        matches!(self.layer_alloc, LayerAlloc::CakeHv { .. } | LayerAlloc::Entropy)
    }

    fn base(name: &'static str, score: ScoreKind) -> Policy {
        Policy {
            name,
            score,
            group_reduce: GroupReduce::Mean,
            head_alloc: HeadAlloc::Fixed,
            layer_alloc: LayerAlloc::Uniform,
            decode_evict: false,
            full_cache: false,
        }
    }

    /// The policy registry: every method from DESIGN.md §4 by name.
    pub fn by_name(name: &str) -> Option<Policy> {
        let p = match name {
            "full" => Policy { full_cache: true, ..Policy::base("full", ScoreKind::SnapKv) },
            "streaming" => Policy::base("streaming", ScoreKind::Streaming { sinks: 4 }),
            "h2o" => Policy { decode_evict: true, ..Policy::base("h2o", ScoreKind::H2o) },
            "tova" => Policy { decode_evict: true, ..Policy::base("tova", ScoreKind::Tova) },
            "snapkv" => Policy::base("snapkv", ScoreKind::SnapKv),
            "pyramidkv" => Policy {
                layer_alloc: LayerAlloc::Pyramid { beta: 10.0 },
                ..Policy::base("pyramidkv", ScoreKind::SnapKv)
            },
            "ada-snapkv" | "adakv" => Policy {
                name: "ada-snapkv",
                head_alloc: HeadAlloc::Flat,
                ..Policy::base("ada-snapkv", ScoreKind::SnapKv)
            },
            "ada-pyramidkv" => Policy {
                head_alloc: HeadAlloc::Flat,
                layer_alloc: LayerAlloc::Pyramid { beta: 10.0 },
                ..Policy::base("ada-pyramidkv", ScoreKind::SnapKv)
            },
            "cake" => Policy {
                layer_alloc: LayerAlloc::CakeHv { g1: 2.0, g2: 2.0 },
                ..Policy::base("cake", ScoreKind::Cake { gamma: 5.0 })
            },
            "vatp" => Policy::base("vatp", ScoreKind::Vatp),
            "lava" => Policy {
                group_reduce: GroupReduce::Max,
                head_alloc: HeadAlloc::Flat,
                layer_alloc: LayerAlloc::Entropy,
                ..Policy::base("lava", ScoreKind::Lava)
            },
            // ablations (Fig. 4) and layer-allocation variants (Table 13)
            "lava-nolayer" | "lava-uniform" => Policy {
                group_reduce: GroupReduce::Max,
                head_alloc: HeadAlloc::Flat,
                layer_alloc: LayerAlloc::Uniform,
                ..Policy::base("lava-uniform", ScoreKind::Lava)
            },
            "lava-nohead" => Policy {
                group_reduce: GroupReduce::Max,
                head_alloc: HeadAlloc::Fixed,
                layer_alloc: LayerAlloc::Entropy,
                ..Policy::base("lava-nohead", ScoreKind::Lava)
            },
            "lava-pyramid" => Policy {
                group_reduce: GroupReduce::Max,
                head_alloc: HeadAlloc::Flat,
                layer_alloc: LayerAlloc::Pyramid { beta: 10.0 },
                ..Policy::base("lava-pyramid", ScoreKind::Lava)
            },
            _ => return None,
        };
        Some(p)
    }

    pub fn all_names() -> &'static [&'static str] {
        &[
            "full", "streaming", "h2o", "tova", "snapkv", "pyramidkv", "ada-snapkv",
            "ada-pyramidkv", "cake", "vatp", "lava", "lava-uniform", "lava-nohead",
            "lava-pyramid",
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all() {
        for name in Policy::all_names() {
            let p = Policy::by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            if *name != "ada-snapkv" {
                assert_eq!(&p.name, name);
            }
        }
        assert!(Policy::by_name("nope").is_none());
    }

    #[test]
    fn lava_is_fully_dynamic() {
        let p = Policy::by_name("lava").unwrap();
        assert_eq!(p.head_alloc, HeadAlloc::Flat);
        assert_eq!(p.layer_alloc, LayerAlloc::Entropy);
        assert_eq!(p.group_reduce, GroupReduce::Max);
        assert!(p.dynamic_layer());
    }

    #[test]
    fn table1_budget_combinations() {
        // Table 1: SnapKV fixed/fixed, CAKE fixed/dynamic, AdaKV dyn/fixed,
        // LAVa dyn/dyn.
        let snap = Policy::by_name("snapkv").unwrap();
        assert_eq!((snap.head_alloc, snap.dynamic_layer()), (HeadAlloc::Fixed, false));
        let cake = Policy::by_name("cake").unwrap();
        assert_eq!((cake.head_alloc, cake.dynamic_layer()), (HeadAlloc::Fixed, true));
        let ada = Policy::by_name("ada-snapkv").unwrap();
        assert_eq!((ada.head_alloc, ada.dynamic_layer()), (HeadAlloc::Flat, false));
        let lava = Policy::by_name("lava").unwrap();
        assert_eq!((lava.head_alloc, lava.dynamic_layer()), (HeadAlloc::Flat, true));
    }

    #[test]
    fn adakv_alias() {
        assert_eq!(Policy::by_name("adakv"), Policy::by_name("ada-snapkv"));
    }
}
