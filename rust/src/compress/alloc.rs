//! Layer-budget allocators (§4.2 / Table 1).
//!
//! All allocators map a total budget 𝔹 (cache entries across all layers) to
//! per-layer budgets B_l, with a floor of `min_per_layer` (the protected
//! window) per layer:
//!
//!   Uniform   B_l = 𝔹 / L                       (SnapKV, AdaKV, H2O, ...)
//!   Pyramid   Eq. 21, shape parameter beta       (PyramidKV)
//!   CakeHv    P_l = H_l^{1/g1} * V_l^{1/g2}      (CAKE Eq. 22-23)
//!   Entropy   e_l = normalized score entropy     (LAVa Eq. 6-7)
//!
//! The dynamic allocators (CakeHv, Entropy) are used inside Algorithm 2's
//! cascade: after prefilling layer l, `proportional` re-splits the full 𝔹
//! over the l+1 layers seen so far, so earlier layers shrink monotonically
//! as later layers arrive.

use super::LayerObs;
use crate::util::stats;

/// Largest-remainder proportional split of `total` by `weights`, with a
/// per-layer floor. Guarantees: sum == total always, and every budget
/// >= floor whenever total >= L * floor (with less than that there is not
/// enough budget to honor the floor, so the split degrades to near-even).
pub fn proportional(weights: &[f64], total: usize, floor: usize) -> Vec<usize> {
    let l = weights.len();
    if l == 0 {
        return vec![];
    }
    if total <= l * floor {
        // not enough for the floor everywhere: near-even split, remainder
        // to the earliest layers, so `sum == total` still holds
        let base = total / l;
        let rem = total - base * l;
        let mut out = vec![base; l];
        for b in out.iter_mut().take(rem) {
            *b += 1;
        }
        return out;
    }
    let spread = total - l * floor;
    let wsum: f64 = weights.iter().map(|w| w.max(0.0)).sum();
    if wsum <= 0.0 {
        // degenerate weights -> uniform
        let mut out = vec![floor + spread / l; l];
        let mut rem = spread - (spread / l) * l;
        for b in out.iter_mut() {
            if rem == 0 {
                break;
            }
            *b += 1;
            rem -= 1;
        }
        return out;
    }
    let mut out = vec![floor; l];
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(l);
    let mut assigned = 0usize;
    for (i, w) in weights.iter().enumerate() {
        let exact = w.max(0.0) / wsum * spread as f64;
        let fl = exact.floor() as usize;
        out[i] += fl;
        assigned += fl;
        fracs.push((exact - fl as f64, i));
    }
    let mut rem = spread - assigned;
    fracs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    for (_, i) in fracs {
        if rem == 0 {
            break;
        }
        out[i] += 1;
        rem -= 1;
    }
    out
}

/// Uniform split (integer floor; remainder to the earliest layers).
pub fn uniform(total: usize, n_layers: usize) -> Vec<usize> {
    proportional(&vec![1.0; n_layers], total, 0)
}

/// PyramidKV Eq. 21: linearly descending budgets controlled by beta.
/// B_{L-1} = 𝔹/(beta*L); B_0 = 2𝔹/L - B_{L-1}; linear in between.
pub fn pyramid(total: usize, n_layers: usize, beta: f32, floor: usize) -> Vec<usize> {
    let l = n_layers as f64;
    let b_last = total as f64 / (beta as f64 * l);
    let b_first = 2.0 * total as f64 / l - b_last;
    let weights: Vec<f64> = (0..n_layers)
        .map(|i| {
            let t = if n_layers == 1 { 0.0 } else { i as f64 / (l - 1.0) };
            (b_first + (b_last - b_first) * t).max(0.0)
        })
        .collect();
    proportional(&weights, total, floor)
}

/// LAVa Eq. 6-7: normalized entropy of a layer's (kv-head) score
/// distribution. Constant H*N factors cancel in `proportional`, but we keep
/// the paper's normalization for reportability.
pub fn lava_layer_entropy(scores: &[Vec<f32>]) -> f64 {
    let count: usize = scores.iter().map(|s| s.len()).sum();
    if count == 0 {
        return 0.0;
    }
    let flat: Vec<f32> = scores.iter().flatten().copied().collect();
    stats::entropy(&flat) / count as f64
}

/// CAKE Eq. 22: spatial entropy H_l of the window-attention distributions
/// and temporal variance V_l of per-token attention across window steps.
pub fn cake_hv(obs: &LayerObs) -> (f64, f64) {
    let h = obs.n_heads();
    let w = obs.window();
    let n = obs.bucket();
    let len = obs.length;
    let win = obs.win_attn.as_f32().expect("win_attn");
    // spatial: mean entropy of each window row's attention distribution
    let mut hsum = 0.0;
    for hh in 0..h {
        for r in 0..w {
            let row = &win[(hh * w + r) * n..(hh * w + r) * n + len];
            hsum += stats::entropy(row);
        }
    }
    let spatial = hsum / (h * w) as f64;
    // temporal: sum over tokens of the variance of attention across rows
    let mut vsum = 0.0;
    for hh in 0..h {
        for i in 0..len {
            let xs: Vec<f64> = (0..w).map(|r| win[(hh * w + r) * n + i] as f64).collect();
            vsum += stats::variance(&xs);
        }
    }
    let temporal = vsum / h as f64;
    (spatial, temporal)
}

/// CAKE Eq. 23 preference weight.
pub fn cake_preference(spatial: f64, temporal: f64, g1: f32, g2: f32) -> f64 {
    spatial.max(1e-12).powf(1.0 / g1 as f64) * temporal.max(1e-12).powf(1.0 / g2 as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn proportional_sums_and_floors() {
        let b = proportional(&[1.0, 2.0, 3.0], 60, 5);
        assert_eq!(b.iter().sum::<usize>(), 60);
        assert!(b.iter().all(|&x| x >= 5));
        assert!(b[2] > b[1] && b[1] > b[0]);
    }

    #[test]
    fn proportional_exact_thirds() {
        assert_eq!(proportional(&[1.0, 1.0, 1.0], 9, 0), vec![3, 3, 3]);
    }

    #[test]
    fn proportional_below_floor_keeps_sum() {
        // regression: total=7 < l*floor=12 used to return [2,2,2] (sum 6)
        let b = proportional(&[1.0, 1.0, 1.0], 7, 4);
        assert_eq!(b, vec![3, 2, 2]);
        assert_eq!(b.iter().sum::<usize>(), 7);
        // boundary: exactly l*floor gives the floor everywhere
        assert_eq!(proportional(&[3.0, 1.0, 2.0], 12, 4), vec![4, 4, 4]);
        assert_eq!(proportional(&[1.0], 0, 5), vec![0]);
    }

    #[test]
    fn uniform_remainder_goes_early() {
        assert_eq!(uniform(10, 4), vec![3, 3, 2, 2]);
    }

    #[test]
    fn pyramid_descends() {
        let b = pyramid(1000, 8, 10.0, 0);
        assert_eq!(b.iter().sum::<usize>(), 1000);
        for w in b.windows(2) {
            assert!(w[0] >= w[1], "pyramid must descend: {:?}", b);
        }
        // beta controls steepness: larger beta -> smaller last layer
        let steep = pyramid(1000, 8, 20.0, 0);
        assert!(steep[7] <= b[7]);
    }

    #[test]
    fn entropy_allocator_prefers_uncertain_layers() {
        // layer A: all mass on one token (low entropy) vs layer B: uniform
        let low = vec![vec![1.0f32, 0.0, 0.0, 0.0], vec![1.0, 0.0, 0.0, 0.0]];
        let high = vec![vec![0.25f32; 4], vec![0.25; 4]];
        let ea = lava_layer_entropy(&low);
        let eb = lava_layer_entropy(&high);
        assert!(eb > ea);
        let budgets = proportional(&[ea, eb], 100, 10);
        assert!(budgets[1] > budgets[0]);
        assert_eq!(budgets.iter().sum::<usize>(), 100);
    }

    #[test]
    fn cake_hv_detects_shape() {
        use crate::compress::score::tests::synth_obs;
        // peaked obs has lower spatial entropy than uniform-ish obs
        let peaked = synth_obs(2, 2, 4, 32, 24, 3, 0);
        let (h1, _) = cake_hv(&peaked);
        assert!(h1 > 0.0 && h1 < (24f64).ln());
    }

    #[test]
    fn cake_preference_monotone() {
        let p1 = cake_preference(1.0, 1.0, 2.0, 2.0);
        let p2 = cake_preference(2.0, 1.0, 2.0, 2.0);
        let p3 = cake_preference(2.0, 2.0, 2.0, 2.0);
        assert!(p2 > p1 && p3 > p2);
    }

    #[test]
    fn prop_proportional_invariants() {
        prop::check(100, |rng| {
            let l = 1 + rng.below(12);
            let floor = rng.below(8);
            // cover the degenerate branch too: total may fall below l*floor
            let total = rng.below(l * floor + 500);
            let weights: Vec<f64> = (0..l).map(|_| rng.f64()).collect();
            let b = proportional(&weights, total, floor);
            prop::assert_prop(b.len() == l, "len", &b)?;
            prop::assert_prop(b.iter().sum::<usize>() == total, "sum", &(total, &b))?;
            if total >= l * floor {
                prop::assert_prop(b.iter().all(|&x| x >= floor), "floor", &(floor, &b))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_proportional_monotone_in_weight() {
        prop::check(50, |rng| {
            let l = 2 + rng.below(6);
            let total = 100 + rng.below(400);
            let mut weights: Vec<f64> = (0..l).map(|_| 0.1 + rng.f64()).collect();
            weights.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let b = proportional(&weights, total, 0);
            // allow off-by-one from largest-remainder rounding
            for w in b.windows(2) {
                prop::assert_prop(w[1] + 1 >= w[0], "monotone-ish", &b)?;
            }
            Ok(())
        });
    }
}
