//! Top-B selection: from kv-head scores to per-head keep lists.
//!
//! Implements Algorithm 1 (LayerEvict): flatten scores across heads and keep
//! the layer-wide top-B_l (dynamic head budgets fall out of the ranking), or
//! the fixed-budget variant (head-local top-(B_l/H_k)). The most recent
//! `window` tokens of every head are always retained (the final constraint
//! of Eq. 1) and are stored with score = +inf so that Algorithm 2's
//! recompression (which reuses stored scores with a shrunken budget) keeps
//! them too.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::HeadAlloc;

/// Keep-decision for one layer: sorted original indices + aligned scores.
#[derive(Debug, Clone, PartialEq)]
pub struct KeepSet {
    pub keep: Vec<Vec<usize>>,
    pub scores: Vec<Vec<f32>>,
}

impl KeepSet {
    pub fn total(&self) -> usize {
        self.keep.iter().map(|k| k.len()).sum()
    }
}

#[derive(PartialEq)]
struct HeapItem(f32, usize, usize); // (score, head, idx) min-heap by score

impl Eq for HeapItem {}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse for min-heap-of-top-k semantics,
        // breaking score ties by (head, idx) for determinism.
        other
            .0
            .partial_cmp(&self.0)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.1.cmp(&self.1))
            .then_with(|| other.2.cmp(&self.2))
    }
}

/// Top-k (index, score) pairs from an iterator of candidates via a bounded
/// min-heap: O(C log k) for C candidates.
fn top_k<I: Iterator<Item = (f32, usize, usize)>>(cands: I, k: usize) -> Vec<(f32, usize, usize)> {
    if k == 0 {
        return vec![];
    }
    let mut heap: BinaryHeap<HeapItem> = BinaryHeap::with_capacity(k + 1);
    for (s, h, i) in cands {
        if heap.len() < k {
            heap.push(HeapItem(s, h, i));
        } else if let Some(top) = heap.peek() {
            // top is the *smallest* kept score
            if s > top.0 || (s == top.0 && (h, i) < (top.1, top.2)) {
                heap.pop();
                heap.push(HeapItem(s, h, i));
            }
        }
    }
    heap.into_iter().map(|HeapItem(s, h, i)| (s, h, i)).collect()
}

/// Select entries to keep at prefill time.
///
/// * `scores[h]` — kv-head scores over [0, length).
/// * `budget` — total entries for this layer across all kv heads, including
///   the protected window.
/// * `window` — number of most recent tokens always kept per head.
pub fn select_prefill(
    scores: &[Vec<f32>],
    length: usize,
    budget: usize,
    window: usize,
    mode: HeadAlloc,
) -> KeepSet {
    let hk = scores.len();
    let win_start = length.saturating_sub(window);

    // Budget >= everything: keep all (window entries still pinned with +inf).
    if budget >= hk * length {
        let keep: Vec<Vec<usize>> = (0..hk).map(|_| (0..length).collect()).collect();
        let sc = (0..hk)
            .map(|h| {
                (0..length)
                    .map(|i| if i >= win_start { f32::MAX } else { scores[h][i] })
                    .collect()
            })
            .collect();
        return KeepSet { keep, scores: sc };
    }

    let protected_per_head = length - win_start; // == min(window, length)
    let protected_total = hk * protected_per_head;

    if budget <= protected_total {
        // degenerate: budget smaller than the protected window — keep only
        // the most recent tokens, splitting the budget across heads (the
        // old `(budget / hk).max(1)` kept hk entries even when budget < hk).
        // Every head still keeps >= 1 entry so decode has something to
        // attend to, so total() <= max(budget, hk).
        let base = budget / hk;
        let rem = budget - base * hk;
        let mut keep: Vec<Vec<usize>> = Vec::with_capacity(hk);
        let mut sc: Vec<Vec<f32>> = Vec::with_capacity(hk);
        for h in 0..hk {
            let per = (base + usize::from(h < rem)).max(1).min(length);
            keep.push((length - per..length).collect());
            sc.push(vec![f32::MAX; per]);
        }
        return KeepSet { keep, scores: sc };
    }

    let extra = budget - protected_total; // entries chosen by score

    let mut keep: Vec<Vec<usize>> = vec![Vec::new(); hk];
    let mut kept_scores: Vec<Vec<f32>> = vec![Vec::new(); hk];

    let mut chosen: Vec<(f32, usize, usize)> = match mode {
        HeadAlloc::Flat => top_k(
            (0..hk).flat_map(|h| (0..win_start).map(move |i| (h, i)))
                .map(|(h, i)| (scores[h][i], h, i)),
            extra,
        ),
        HeadAlloc::Fixed => {
            let per_head = extra / hk;
            let mut all = Vec::new();
            for h in 0..hk {
                all.extend(top_k(
                    (0..win_start).map(|i| (scores[h][i], h, i)),
                    per_head,
                ));
            }
            all
        }
    };
    chosen.sort_by(|a, b| (a.1, a.2).cmp(&(b.1, b.2)));

    for (s, h, i) in chosen {
        keep[h].push(i);
        kept_scores[h].push(s);
    }
    for h in 0..hk {
        for i in win_start..length {
            keep[h].push(i);
            kept_scores[h].push(f32::MAX);
        }
    }
    KeepSet { keep, scores: kept_scores }
}

/// Algorithm 2 recompression: given the *stored* per-entry scores of a
/// compacted cache, pick the new top-`budget` (window entries carry +inf so
/// they always survive). Returns per-head keep lists of compact-slot
/// indices, sorted.
pub fn select_recompress(stored: &[&[f32]], budget: usize, mode: HeadAlloc) -> Vec<Vec<usize>> {
    let hk = stored.len();
    let total: usize = stored.iter().map(|s| s.len()).sum();
    if budget >= total {
        return stored.iter().map(|s| (0..s.len()).collect()).collect();
    }
    let mut chosen: Vec<(f32, usize, usize)> = match mode {
        HeadAlloc::Flat => top_k(
            (0..hk).flat_map(|h| stored[h].iter().copied().enumerate().map(move |(i, s)| (s, h, i))),
            budget,
        ),
        HeadAlloc::Fixed => {
            let per_head = budget / hk;
            let mut all = Vec::new();
            for h in 0..hk {
                all.extend(top_k(
                    stored[h].iter().copied().enumerate().map(|(i, s)| (s, h, i)),
                    per_head,
                ));
            }
            all
        }
    };
    chosen.sort_by(|a, b| (a.1, a.2).cmp(&(b.1, b.2)));
    let mut keep: Vec<Vec<usize>> = vec![Vec::new(); hk];
    for (_, h, i) in chosen {
        keep[h].push(i);
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn flat(scores: Vec<Vec<f32>>, len: usize, budget: usize, win: usize) -> KeepSet {
        select_prefill(&scores, len, budget, win, HeadAlloc::Flat)
    }

    #[test]
    fn window_always_kept() {
        let scores = vec![vec![0.0; 20], vec![0.0; 20]];
        let ks = flat(scores, 20, 12, 4);
        for h in 0..2 {
            for i in 16..20 {
                assert!(ks.keep[h].contains(&i), "head {h} missing window pos {i}");
            }
        }
        assert_eq!(ks.total(), 12);
    }

    #[test]
    fn flat_mode_is_dynamic_per_head() {
        // head 0 has all the mass outside the window -> gets all extra slots
        let mut s0 = vec![0.0f32; 32];
        for i in 0..16 {
            s0[i] = 10.0 + i as f32;
        }
        let s1 = vec![0.001f32; 32];
        let ks = flat(vec![s0, s1], 32, 2 * 4 + 6, 4);
        assert_eq!(ks.keep[0].len() - 4, 6, "head 0 should win all extra");
        assert_eq!(ks.keep[1].len(), 4, "head 1 only keeps its window");
    }

    #[test]
    fn fixed_mode_splits_evenly() {
        let s0: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let s1 = s0.clone();
        let ks = select_prefill(&[s0, s1].to_vec(), 32, 2 * 4 + 8, 4, HeadAlloc::Fixed);
        assert_eq!(ks.keep[0].len(), 8);
        assert_eq!(ks.keep[1].len(), 8);
        // top non-window scores are 24..27 (window is 28..31)
        assert_eq!(ks.keep[0], vec![24, 25, 26, 27, 28, 29, 30, 31]);
    }

    #[test]
    fn keeps_highest_scores() {
        let mut s = vec![0.0f32; 64];
        s[3] = 9.0;
        s[40] = 8.0;
        s[10] = 7.0;
        let ks = flat(vec![s], 64, 8 + 3, 8);
        assert_eq!(ks.keep[0][..3], [3, 10, 40]);
        // stored scores align with keep order; window pinned at +inf
        assert_eq!(ks.scores[0][0], 9.0);
        assert_eq!(ks.scores[0][3], f32::MAX);
    }

    #[test]
    fn budget_larger_than_cache_keeps_all() {
        let ks = flat(vec![vec![1.0; 10], vec![1.0; 10]], 10, 1000, 4);
        assert_eq!(ks.total(), 20);
    }

    #[test]
    fn degenerate_budget_below_window() {
        let ks = flat(vec![vec![1.0; 32], vec![1.0; 32]], 32, 6, 8);
        assert_eq!(ks.total(), 6);
        assert_eq!(ks.keep[0], vec![29, 30, 31]);
    }

    #[test]
    fn tiny_budget_clamps_total() {
        // regression: budget < hk used to return hk entries (one per head),
        // silently exceeding the layer budget
        let scores = vec![vec![1.0f32; 32]; 4];
        let ks = flat(scores.clone(), 32, 2, 8);
        assert_eq!(ks.total(), 4, "minimum viable is one entry per head");
        // budget between hk and the protected window: split across heads,
        // earliest heads take the remainder
        let ks6 = flat(scores.clone(), 32, 6, 8);
        assert_eq!(ks6.total(), 6);
        assert_eq!(ks6.keep[0], vec![30, 31]);
        assert_eq!(ks6.keep[2], vec![31]);
        // the bound holds across the whole small-budget range
        for budget in 1..40 {
            let ks = flat(scores.clone(), 32, budget, 8);
            assert!(
                ks.total() <= budget.max(4),
                "budget {budget}: kept {} > max(budget, hk)",
                ks.total()
            );
        }
    }

    #[test]
    fn recompress_respects_pinned_window() {
        let stored: Vec<Vec<f32>> = vec![
            vec![0.5, 0.9, f32::MAX, f32::MAX],
            vec![0.8, 0.1, f32::MAX, f32::MAX],
        ];
        let refs: Vec<&[f32]> = stored.iter().map(|v| v.as_slice()).collect();
        let keep = select_recompress(&refs, 6, HeadAlloc::Flat);
        // 4 pinned + top-2 of {0.5, 0.9, 0.8, 0.1} = idx1 head0, idx0 head1
        assert_eq!(keep[0], vec![1, 2, 3]);
        assert_eq!(keep[1], vec![0, 2, 3]);
    }

    #[test]
    fn recompress_noop_when_budget_covers() {
        let stored = vec![vec![0.1f32, 0.2], vec![0.3f32]];
        let refs: Vec<&[f32]> = stored.iter().map(|v| v.as_slice()).collect();
        let keep = select_recompress(&refs, 10, HeadAlloc::Flat);
        assert_eq!(keep[0], vec![0, 1]);
        assert_eq!(keep[1], vec![0]);
    }

    #[test]
    fn prop_selection_invariants() {
        prop::check(100, |rng| {
            let hk = 1 + rng.below(4);
            let len = 16 + rng.below(100);
            let win = 1 + rng.below(8.min(len));
            let budget = hk * win + rng.below(hk * len);
            let scores: Vec<Vec<f32>> =
                (0..hk).map(|_| (0..len).map(|_| rng.f32()).collect()).collect();
            let mode = if rng.below(2) == 0 { HeadAlloc::Flat } else { HeadAlloc::Fixed };
            let ks = select_prefill(&scores, len, budget, win, mode);

            prop::assert_prop(ks.total() <= budget, "within budget", &(ks.total(), budget))?;
            for h in 0..hk {
                prop::assert_prop(
                    ks.keep[h].windows(2).all(|w| w[0] < w[1]),
                    "sorted unique",
                    &ks.keep[h],
                )?;
                prop::assert_prop(
                    ks.keep[h].iter().all(|&i| i < len),
                    "in range",
                    &ks.keep[h],
                )?;
                prop::assert_prop(
                    ks.keep[h].len() == ks.scores[h].len(),
                    "scores aligned",
                    &h,
                )?;
                // window suffix present whenever budget allows
                if budget >= hk * win {
                    for i in len - win..len {
                        prop::assert_prop(
                            ks.keep[h].contains(&i),
                            "window kept",
                            &(h, i, win, budget),
                        )?;
                    }
                }
            }
            // Flat mode uses the budget exactly; Fixed mode may leave up to
            // hk-1 entries on the table (integer division of the extra).
            let used = ks.total();
            let cap = budget.min(hk * len);
            match mode {
                HeadAlloc::Flat => {
                    prop::assert_prop(used == cap, "budget fully used", &(used, cap))?
                }
                HeadAlloc::Fixed => prop::assert_prop(
                    used <= cap && cap - used < hk,
                    "budget used modulo per-head rounding",
                    &(used, cap, hk),
                )?,
            }
            Ok(())
        });
    }

    #[test]
    fn prop_flat_keeps_global_top() {
        prop::check(50, |rng| {
            let len = 32 + rng.below(64);
            let win = 4;
            let hk = 2;
            let extra = 1 + rng.below(16);
            let scores: Vec<Vec<f32>> =
                (0..hk).map(|_| (0..len).map(|_| rng.f32()).collect()).collect();
            let ks = select_prefill(&scores, len, hk * win + extra, win, HeadAlloc::Flat);
            // min kept non-window score >= max dropped score
            let mut kept_min = f32::MAX;
            for h in 0..hk {
                for (j, &i) in ks.keep[h].iter().enumerate() {
                    if ks.scores[h][j] != f32::MAX {
                        kept_min = kept_min.min(scores[h][i]);
                    }
                }
            }
            let mut dropped_max = f32::MIN;
            for h in 0..hk {
                for i in 0..len - win {
                    if !ks.keep[h].contains(&i) {
                        dropped_max = dropped_max.max(scores[h][i]);
                    }
                }
            }
            prop::assert_prop(
                kept_min >= dropped_max || kept_min == f32::MAX,
                "greedy optimality",
                &(kept_min, dropped_max),
            )
        });
    }
}
