//! Table 14 oracle: exact layer attention output loss under an eviction
//! mask (Lemma 1),
//!
//!   ||y - ŷ||_1,   y = Cat_h(A^N_h V_h) W^O,
//!                  ŷ = Cat_h( (A^N_h ⊙ I_h / ||A^N_h ⊙ I_h||_1) V_h ) W^O
//!
//! computed host-side from the prefill observation (the last window row is
//! exactly A^N) + the layer's V cache + W^O. This is the paper's only fully
//! model-faithful quantitative claim we can measure *exactly*, with no
//! scale substitution.

use crate::compress::LayerObs;
use crate::runtime::Tensor;

/// A^N per q-head over valid positions: the last row of the window panel.
pub fn last_row_attention(obs: &LayerObs) -> Vec<Vec<f32>> {
    let h = obs.n_heads();
    let w = obs.window();
    let n = obs.bucket();
    let len = obs.length;
    let win = obs.win_attn.as_f32().expect("win_attn");
    (0..h)
        .map(|hh| win[(hh * w + (w - 1)) * n..(hh * w + (w - 1)) * n + len].to_vec())
        .collect()
}

/// ||y - ŷ||_1 for one layer.
///
/// * `attn` — [H][len] current-step attention (see `last_row_attention`).
/// * `v` — [Hk, N, dh] value cache tensor from prefill.
/// * `wo` — [H*dh, d] output projection.
/// * `keep` — per-kv-head kept indices (the eviction mask I).
pub fn layer_output_loss(
    attn: &[Vec<f32>],
    v: &Tensor,
    wo: &Tensor,
    keep: &[Vec<usize>],
    length: usize,
) -> f64 {
    let h = attn.len();
    let hk = v.shape[0];
    let n = v.shape[1];
    let dh = v.shape[2];
    let group = h / hk;
    let d = wo.shape[1];
    let vf = v.as_f32().expect("v");
    let wof = wo.as_f32().expect("wo");

    // per-head context vectors with and without the mask
    let mut cat_full = vec![0.0f32; h * dh];
    let mut cat_masked = vec![0.0f32; h * dh];
    for hh in 0..h {
        let kv = hh / group;
        // full
        for i in 0..length {
            let a = attn[hh][i];
            if a == 0.0 {
                continue;
            }
            let base = (kv * n + i) * dh;
            for j in 0..dh {
                cat_full[hh * dh + j] += a * vf[base + j];
            }
        }
        // masked + renormalized
        let mass: f32 = keep[kv].iter().map(|&i| attn[hh][i]).sum();
        if mass > 0.0 {
            for &i in &keep[kv] {
                let a = attn[hh][i] / mass;
                let base = (kv * n + i) * dh;
                for j in 0..dh {
                    cat_masked[hh * dh + j] += a * vf[base + j];
                }
            }
        }
    }

    // y - ŷ = (cat_full - cat_masked) @ Wo ; L1 norm
    let mut loss = 0.0f64;
    for col in 0..d {
        let mut acc = 0.0f32;
        for row in 0..h * dh {
            acc += (cat_full[row] - cat_masked[row]) * wof[row * d + col];
        }
        loss += acc.abs() as f64;
    }
    loss
}

/// Theorem 1 upper bound: 2 * ||Wo^T||_1 * sum_h sum_{evicted} A[i] * Vbar_h.
pub fn theorem1_upper_bound(
    attn: &[Vec<f32>],
    v: &Tensor,
    wo: &Tensor,
    keep: &[Vec<usize>],
    length: usize,
) -> f64 {
    let h = attn.len();
    let hk = v.shape[0];
    let n = v.shape[1];
    let dh = v.shape[2];
    let group = h / hk;
    let d = wo.shape[1];
    let vf = v.as_f32().expect("v");
    let wof = wo.as_f32().expect("wo");

    // C = ||Wo^T||_1 = max over columns of sum of |entries| in that column
    // (matrix 1-norm of Wo^T = max row-sum of |Wo| ... the paper uses the
    // largest column-absolute-sum of Wo^T, i.e. largest row sum of Wo^T's
    // columns = max_j sum_i |Wo[i][j]| over ... we follow Lemma 2: max
    // column abs sum of W^T = max row abs sum of W.)
    let mut c = 0.0f64;
    for row in 0..h * dh {
        let mut s = 0.0f64;
        for col in 0..d {
            s += wof[row * d + col].abs() as f64;
        }
        c = c.max(s);
    }

    let mut bound = 0.0f64;
    for kv in 0..hk {
        // Vbar = max_i ||V[i]||_1
        let mut vbar = 0.0f64;
        for i in 0..length {
            let mut s = 0.0f64;
            for j in 0..dh {
                s += vf[(kv * n + i) * dh + j].abs() as f64;
            }
            vbar = vbar.max(s);
        }
        for g in 0..group {
            let hh = kv * group + g;
            let mut evicted_mass = 0.0f64;
            for i in 0..length {
                if !keep[kv].contains(&i) {
                    evicted_mass += attn[hh][i] as f64;
                }
            }
            bound += evicted_mass * vbar;
        }
    }
    2.0 * c * bound
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Vec<Vec<f32>>, Tensor, Tensor, usize) {
        let mut rng = Rng::new(seed);
        let (h, hk, n, dh, d, len) = (4usize, 2usize, 32usize, 4usize, 16usize, 24usize);
        let mut attn = vec![vec![0.0f32; len]; h];
        for row in attn.iter_mut() {
            let mut s = 0.0;
            for x in row.iter_mut() {
                *x = rng.f32();
                s += *x;
            }
            for x in row.iter_mut() {
                *x /= s;
            }
        }
        let v = Tensor::f32((0..hk * n * dh).map(|_| rng.normal() as f32).collect(), &[hk, n, dh]);
        let wo = Tensor::f32((0..h * dh * d).map(|_| rng.normal() as f32).collect(), &[h * dh, d]);
        (attn, v, wo, len)
    }

    #[test]
    fn zero_loss_when_nothing_evicted() {
        let (attn, v, wo, len) = setup(0);
        let keep: Vec<Vec<usize>> = vec![(0..len).collect(), (0..len).collect()];
        let loss = layer_output_loss(&attn, &v, &wo, &keep, len);
        assert!(loss < 1e-4, "loss {loss}");
    }

    #[test]
    fn loss_positive_when_evicting() {
        let (attn, v, wo, len) = setup(1);
        let keep: Vec<Vec<usize>> = vec![(0..len / 2).collect(), (0..len / 2).collect()];
        let loss = layer_output_loss(&attn, &v, &wo, &keep, len);
        assert!(loss > 0.0);
    }

    #[test]
    fn bound_holds() {
        // Theorem 1: the exact loss never exceeds the upper bound.
        for seed in 0..10 {
            let (attn, v, wo, len) = setup(seed);
            let mut rng = Rng::new(seed + 100);
            let keep: Vec<Vec<usize>> = (0..2)
                .map(|_| {
                    let k = 4 + rng.below(len - 4);
                    rng.sample_indices(len, k)
                })
                .collect();
            let loss = layer_output_loss(&attn, &v, &wo, &keep, len);
            let bound = theorem1_upper_bound(&attn, &v, &wo, &keep, len);
            assert!(
                loss <= bound + 1e-6,
                "seed {seed}: loss {loss} > bound {bound}"
            );
        }
    }

    #[test]
    fn keeping_high_attention_tokens_reduces_loss() {
        let (attn, v, wo, len) = setup(2);
        // keep-top-attention vs keep-bottom-attention (head-0 ranking)
        let mut order: Vec<usize> = (0..len).collect();
        order.sort_by(|&a, &b| attn[0][b].partial_cmp(&attn[0][a]).unwrap());
        let top: Vec<usize> = {
            let mut t = order[..len / 2].to_vec();
            t.sort_unstable();
            t
        };
        let bottom: Vec<usize> = {
            let mut t = order[len / 2..].to_vec();
            t.sort_unstable();
            t
        };
        let loss_top = layer_output_loss(&attn, &v, &wo, &vec![top.clone(), top], len);
        let loss_bottom =
            layer_output_loss(&attn, &v, &wo, &vec![bottom.clone(), bottom], len);
        assert!(loss_top < loss_bottom);
    }
}
