//! Report rendering: aligned console tables, markdown, and JSON dumps so
//! every experiment driver prints the same row/series structure the paper's
//! tables/figures use and archives machine-readable results.

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values));
    }

    /// Column-wise best (max) markers like the paper's bold entries.
    fn best_per_column(&self) -> Vec<Option<usize>> {
        (0..self.columns.len())
            .map(|c| {
                let mut best: Option<(usize, f64)> = None;
                for (r, (_, vals)) in self.rows.iter().enumerate() {
                    if best.map(|(_, b)| vals[c] > b).unwrap_or(true) {
                        best = Some((r, vals[c]));
                    }
                }
                best.map(|(r, _)| r)
            })
            .collect()
    }

    pub fn render(&self, mark_best: bool) -> String {
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(self.title.len().min(24)))
            .max()
            .unwrap_or(8)
            .max(8);
        let col_w = self.columns.iter().map(|c| c.len().max(9)).collect::<Vec<_>>();
        let best = if mark_best { self.best_per_column() } else { vec![None; self.columns.len()] };

        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&format!("{:<label_w$}", ""));
        for (c, w) in self.columns.iter().zip(&col_w) {
            out.push_str(&format!(" {:>w$}", c, w = w));
        }
        out.push('\n');
        for (r, (label, vals)) in self.rows.iter().enumerate() {
            out.push_str(&format!("{:<label_w$}", label));
            for ((&v, w), b) in vals.iter().zip(&col_w).zip(&best) {
                let cell = format!("{:.2}", v);
                let marked = if *b == Some(r) { format!("*{cell}") } else { cell };
                out.push_str(&format!(" {:>w$}", marked, w = w));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n| |", self.title);
        for c in &self.columns {
            out.push_str(&format!(" {c} |"));
        }
        out.push_str("\n|---|");
        for _ in &self.columns {
            out.push_str("---|");
        }
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(&format!("| {label} |"));
            for v in vals {
                out.push_str(&format!(" {v:.2} |"));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut rows = BTreeMap::new();
        for (label, vals) in &self.rows {
            rows.insert(
                label.clone(),
                Json::Arr(vals.iter().map(|&v| Json::num(v)).collect()),
            );
        }
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| Json::str(c.clone())).collect()),
            ),
            ("rows", Json::Obj(rows)),
        ])
    }

    /// Append the JSON form to `path` (one table per line).
    pub fn save_jsonl(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        writeln!(f, "{}", json::to_string(&self.to_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_marks_best() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row("x", vec![1.0, 5.0]);
        t.row("y", vec![2.0, 3.0]);
        let s = t.render(true);
        assert!(s.contains("*2.00"), "{s}");
        assert!(s.contains("*5.00"), "{s}");
        let md = t.to_markdown();
        assert!(md.contains("| x | 1.00 | 5.00 |"));
    }

    #[test]
    fn json_round_trip() {
        let mut t = Table::new("demo", &["c1"]);
        t.row("r1", vec![1.5]);
        let j = t.to_json();
        assert_eq!(j.path("rows.r1").unwrap().as_arr().unwrap()[0].as_f64(), Some(1.5));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row("x", vec![1.0]);
    }
}
