//! Measurement harness + experiment drivers (criterion is not in the
//! offline vendor set, so `cargo bench` targets use this harness via
//! `harness = false`).
//!
//! * [`harness`] — warmup/iteration timing with mean/stddev/percentiles.
//! * [`table`] — aligned-table + markdown + JSON report rendering.
//! * [`eval`] — shared evaluation loops: run a policy over a task suite and
//!   aggregate per-category scores (drives Table 2 / Fig 2 / Fig 4 / ...).
//! * [`output_loss`] — the Table 14 oracle: exact layer attention output
//!   loss ||y - ŷ||_1 under an eviction mask.

pub mod driver;
pub mod eval;
pub mod experiments;
pub mod harness;
pub mod output_loss;
pub mod table;
