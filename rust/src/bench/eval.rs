//! Shared evaluation loops: run (policy x budget) over task suites and
//! aggregate the numbers the paper's tables report.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::compress::Policy;
use crate::coordinator::engine::{Engine, GenerateRequest};
use crate::model::backend::ModelBackend;
use crate::workloads::{self, Category, Instance};
use crate::util::rng::Rng;

/// Run one instance: greedy-generate exactly `target.len()` tokens, score by
/// exact-match rate.
pub fn run_instance<B: ModelBackend>(engine: &mut Engine<B>, inst: &Instance) -> Result<f64> {
    let req = GenerateRequest {
        prompt: inst.prompt.clone(),
        max_new_tokens: inst.target.len(),
    };
    let out = engine.generate(&req)?;
    Ok(inst.score(&out.tokens))
}

/// Mean score of a policy over a set of instances.
pub fn run_instances<B: ModelBackend>(
    engine: &mut Engine<B>,
    instances: &[Instance],
) -> Result<f64> {
    let mut total = 0.0;
    for inst in instances {
        total += run_instance(engine, inst)?;
    }
    Ok(total / instances.len().max(1) as f64)
}

/// Switch the engine to a named policy + per-head budget.
pub fn set_policy<B: ModelBackend>(engine: &mut Engine<B>, policy: &str, budget: usize) {
    engine.opts.policy = Policy::by_name(policy).unwrap_or_else(|| panic!("policy {policy}"));
    engine.opts.budget_per_head = budget;
}

/// Per-task and per-category results of one (policy, budget) suite run.
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub policy: String,
    pub budget: usize,
    pub per_task: Vec<(String, f64)>,
    pub by_category: BTreeMap<&'static str, f64>,
    pub extraction_avg: f64,
    pub generation_avg: f64,
    pub overall_avg: f64,
}

/// Evaluate one policy at one budget over the LongBench-proxy suite.
pub fn run_suite<B: ModelBackend>(
    engine: &mut Engine<B>,
    policy: &str,
    budget: usize,
    ctx: usize,
    per_task: usize,
    seed: u64,
) -> Result<SuiteResult> {
    set_policy(engine, policy, budget);
    let specs = workloads::longbench_suite();
    let mut per_task_scores = Vec::new();
    let mut cat_scores: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
    let mut extraction = Vec::new();
    let mut generation = Vec::new();

    for (ti, spec) in specs.iter().enumerate() {
        // fixed seed per (task): all policies see identical instances
        let mut rng = Rng::new(seed ^ ((ti as u64) << 16));
        let instances = workloads::generate(spec.name, &mut rng, ctx, per_task);
        let score = run_instances(engine, &instances)?;
        per_task_scores.push((spec.name.to_string(), score));
        cat_scores.entry(spec.category.name()).or_default().push(score);
        if spec.category.is_extraction() {
            extraction.push(score);
        } else {
            generation.push(score);
        }
    }

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let by_category =
        cat_scores.iter().map(|(k, v)| (*k, mean(v))).collect::<BTreeMap<_, _>>();
    let overall = mean(&per_task_scores.iter().map(|(_, s)| *s).collect::<Vec<_>>());
    Ok(SuiteResult {
        policy: policy.to_string(),
        budget,
        extraction_avg: mean(&extraction),
        generation_avg: mean(&generation),
        overall_avg: overall,
        per_task: per_task_scores,
        by_category,
    })
}

/// Count head-to-head wins between two policies over the suite tasks at one
/// budget (Fig. 5's win-rate comparison).
pub fn win_rate<B: ModelBackend>(
    engine: &mut Engine<B>,
    policy_a: &str,
    policy_b: &str,
    budget: usize,
    ctx: usize,
    per_task: usize,
    seed: u64,
) -> Result<(usize, usize, usize)> {
    let ra = run_suite(engine, policy_a, budget, ctx, per_task, seed)?;
    let rb = run_suite(engine, policy_b, budget, ctx, per_task, seed)?;
    let (mut wins_a, mut wins_b, mut ties) = (0, 0, 0);
    for ((_, sa), (_, sb)) in ra.per_task.iter().zip(rb.per_task.iter()) {
        if (sa - sb).abs() < 1e-9 {
            ties += 1;
        } else if sa > sb {
            wins_a += 1;
        } else {
            wins_b += 1;
        }
    }
    Ok((wins_a, wins_b, ties))
}

/// Category axis used by Fig. 2 / Fig. 4.
pub fn category_axis() -> Vec<Category> {
    vec![
        Category::SingleDocQa,
        Category::MultiDocQa,
        Category::Summarization,
        Category::FewShot,
        Category::Synthetic,
        Category::Code,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineOptions;
    use crate::model::backend::MockBackend;

    fn engine() -> Engine<MockBackend> {
        let mock = MockBackend::new(MockBackend::default_config());
        Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24))
    }

    #[test]
    fn suite_runs_on_mock() {
        let mut e = engine();
        let r = run_suite(&mut e, "snapkv", 24, 128, 1, 0).unwrap();
        assert_eq!(r.per_task.len(), workloads::longbench_suite().len());
        assert!(r.overall_avg >= 0.0 && r.overall_avg <= 1.0);
        assert!(r.by_category.len() == 6);
    }

    #[test]
    fn policies_see_identical_instances() {
        // determinism check: same seed -> same instance stream regardless of
        // which policy ran first
        let mut e = engine();
        let a1 = run_suite(&mut e, "snapkv", 24, 128, 1, 7).unwrap();
        let a2 = run_suite(&mut e, "snapkv", 24, 128, 1, 7).unwrap();
        for ((_, x), (_, y)) in a1.per_task.iter().zip(a2.per_task.iter()) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn win_rate_sums_to_task_count() {
        let mut e = engine();
        let (a, b, t) = win_rate(&mut e, "lava", "ada-snapkv", 24, 128, 1, 3).unwrap();
        assert_eq!(a + b + t, workloads::longbench_suite().len());
    }
}
