//! Experiment drivers: one function per paper table/figure, generic over
//! the backend. The `bench_*` binaries are thin CLI wrappers around these
//! (see DESIGN.md §5 for the experiment index).

use anyhow::Result;

use super::eval::{self, SuiteResult};
use super::output_loss;
use super::table::Table;
use crate::compress::select::select_prefill;
use crate::compress::{score, Policy};
use crate::coordinator::engine::{Engine, GenerateRequest};
use crate::model::backend::ModelBackend;
use crate::util::rng::Rng;
use crate::workloads::{self, niah, ruler};

pub struct ExpParams {
    pub ctx: usize,
    pub per_task: usize,
    pub budgets: Vec<usize>,
    pub policies: Vec<String>,
    pub seed: u64,
}

impl Default for ExpParams {
    fn default() -> Self {
        ExpParams {
            ctx: 256,
            per_task: 3,
            budgets: vec![24, 32, 48, 64],
            policies: vec![
                "full".into(),
                "pyramidkv".into(),
                "snapkv".into(),
                "ada-pyramidkv".into(),
                "ada-snapkv".into(),
                "cake".into(),
                "lava".into(),
            ],
            seed: 0,
        }
    }
}

/// Table 2 (+ per-budget category breakdown): the LongBench-proxy grid.
pub fn table2<B: ModelBackend>(
    engine: &mut Engine<B>,
    p: &ExpParams,
) -> Result<(Vec<Table>, Vec<SuiteResult>)> {
    let mut tables = Vec::new();
    let mut all = Vec::new();
    for &budget in &p.budgets {
        let task_names: Vec<String> = workloads::longbench_suite()
            .iter()
            .map(|s| s.name.to_string())
            .collect();
        let mut cols: Vec<&str> = task_names.iter().map(|s| s.as_str()).collect();
        cols.push("avg");
        let mut t = Table::new(&format!("Table 2 proxy — budget {budget}/head, ctx {}", p.ctx), &cols);
        for pol in &p.policies {
            let r = eval::run_suite(engine, pol, budget, p.ctx, p.per_task, p.seed)?;
            let mut vals: Vec<f64> = r.per_task.iter().map(|(_, s)| *s * 100.0).collect();
            vals.push(r.overall_avg * 100.0);
            t.row(pol, vals);
            all.push(r);
        }
        tables.push(t);
    }
    Ok((tables, all))
}

/// Fig. 2: extraction vs generation averages per budget per policy.
pub fn figure2(results: &[SuiteResult], budgets: &[usize], policies: &[String]) -> Table {
    let mut cols = Vec::new();
    for b in budgets {
        cols.push(format!("extr@{b}"));
        cols.push(format!("gen@{b}"));
    }
    let colrefs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 2 proxy — extraction vs generation", &colrefs);
    for pol in policies {
        let mut vals = Vec::new();
        for &b in budgets {
            let r = results
                .iter()
                .find(|r| &r.policy == pol && r.budget == b)
                .expect("missing suite result");
            vals.push(r.extraction_avg * 100.0);
            vals.push(r.generation_avg * 100.0);
        }
        t.row(pol, vals);
    }
    t
}

/// Fig. 4 / Table 10 ablation: lava vs -layer vs -head.
pub fn figure4<B: ModelBackend>(engine: &mut Engine<B>, p: &ExpParams) -> Result<Table> {
    let mut cols = Vec::new();
    for b in &p.budgets {
        cols.push(format!("extr@{b}"));
        cols.push(format!("gen@{b}"));
        cols.push(format!("avg@{b}"));
    }
    let colrefs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 4 / Table 10 — ablation", &colrefs);
    for pol in ["lava", "lava-uniform", "lava-nohead"] {
        let mut vals = Vec::new();
        for &b in &p.budgets {
            let r = eval::run_suite(engine, pol, b, p.ctx, p.per_task, p.seed)?;
            vals.push(r.extraction_avg * 100.0);
            vals.push(r.generation_avg * 100.0);
            vals.push(r.overall_avg * 100.0);
        }
        t.row(pol, vals);
    }
    Ok(t)
}

/// Fig. 5: win-rates of the LAVa score vs the AdaKV score under matched
/// allocation (uniform + pyramid).
pub fn figure5<B: ModelBackend>(engine: &mut Engine<B>, p: &ExpParams) -> Result<Table> {
    let mut t = Table::new(
        "Figure 5 — LAVa score vs AdaKV score (wins / losses / ties)",
        &["wins", "losses", "ties"],
    );
    for &b in &p.budgets {
        let (w, l, ti) =
            eval::win_rate(engine, "lava-uniform", "ada-snapkv", b, p.ctx, p.per_task, p.seed)?;
        t.row(&format!("uniform@{b}"), vec![w as f64, l as f64, ti as f64]);
        let (w2, l2, t2) =
            eval::win_rate(engine, "lava-pyramid", "ada-pyramidkv", b, p.ctx, p.per_task, p.seed)?;
        t.row(&format!("pyramid@{b}"), vec![w2 as f64, l2 as f64, t2 as f64]);
    }
    Ok(t)
}

/// Table 5: VATP vs LAVa vs LAVa(-layer).
pub fn table5<B: ModelBackend>(engine: &mut Engine<B>, p: &ExpParams) -> Result<Table> {
    let cols: Vec<String> = p.budgets.iter().map(|b| format!("@{b}")).collect();
    let colrefs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 5 — VATP comparison (overall avg)", &colrefs);
    for pol in ["snapkv", "vatp", "lava", "lava-uniform"] {
        let mut vals = Vec::new();
        for &b in &p.budgets {
            let r = eval::run_suite(engine, pol, b, p.ctx, p.per_task, p.seed)?;
            vals.push(r.overall_avg * 100.0);
        }
        t.row(pol, vals);
    }
    Ok(t)
}

/// Table 9: NIAH average score at small + large budgets.
pub fn table9<B: ModelBackend>(
    engine: &mut Engine<B>,
    p: &ExpParams,
    ctx_lens: &[usize],
) -> Result<Table> {
    let cols: Vec<String> = p.budgets.iter().map(|b| format!("@{b}")).collect();
    let colrefs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 9 — Needle-In-A-Haystack avg", &colrefs);
    let depths = niah::standard_depths();
    for pol in &p.policies {
        let mut vals = Vec::new();
        for &b in &p.budgets {
            eval::set_policy(engine, pol, b);
            let grid = niah::grid(ctx_lens, &depths, p.per_task, p.seed);
            let mut sum = 0.0;
            let mut cnt = 0usize;
            for cell in &grid {
                sum += eval::run_instances(engine, &cell.instances)?;
                cnt += 1;
            }
            vals.push(sum / cnt as f64 * 100.0);
        }
        t.row(pol, vals);
    }
    Ok(t)
}

/// Table 11: Ruler-proxy at several context lengths (one budget).
pub fn table11<B: ModelBackend>(
    engine: &mut Engine<B>,
    p: &ExpParams,
    ctx_lens: &[usize],
    budget: usize,
) -> Result<Table> {
    let cols: Vec<String> = ctx_lens.iter().map(|c| format!("{c}")).collect();
    let colrefs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&format!("Table 11 — Ruler proxy (budget {budget}/head)"), &colrefs);
    for pol in &p.policies {
        let mut vals = Vec::new();
        for (ci, &ctx) in ctx_lens.iter().enumerate() {
            eval::set_policy(engine, pol, budget);
            let mut rng = Rng::new(p.seed ^ ((ci as u64) << 8));
            let mut sum = 0.0;
            let mut cnt = 0;
            for (_, instances) in ruler::suite(&mut rng, ctx, p.per_task) {
                sum += eval::run_instances(engine, &instances)?;
                cnt += 1;
            }
            vals.push(sum / cnt as f64 * 100.0);
        }
        t.row(pol, vals);
    }
    Ok(t)
}

/// Table 12: InfiniteBench-proxy — the longest contexts we support.
pub fn table12<B: ModelBackend>(
    engine: &mut Engine<B>,
    p: &ExpParams,
    ctx: usize,
    budget: usize,
) -> Result<Table> {
    let mut t = Table::new(
        &format!("Table 12 — InfiniteBench proxy (ctx {ctx}, budget {budget}/head)"),
        &["Sum", "MC", "Dia"],
    );
    for pol in &p.policies {
        eval::set_policy(engine, pol, budget);
        let mut rng = Rng::new(p.seed ^ 0xD1A);
        // Sum -> long salient-span echo; MC -> multi-needle; Dia -> kv chat
        let sum_insts: Vec<_> =
            (0..p.per_task).map(|_| workloads::summarize_echo(&mut rng, ctx, 48)).collect();
        let mc_insts: Vec<_> =
            (0..p.per_task).map(|_| workloads::multi_needle(&mut rng, ctx, 4, 4)).collect();
        let dia_insts: Vec<_> =
            (0..p.per_task).map(|_| workloads::kv_retrieve(&mut rng, ctx)).collect();
        t.row(
            pol,
            vec![
                eval::run_instances(engine, &sum_insts)? * 100.0,
                eval::run_instances(engine, &mc_insts)? * 100.0,
                eval::run_instances(engine, &dia_insts)? * 100.0,
            ],
        );
    }
    Ok(t)
}

/// Table 13: layer-allocation comparison for the LAVa score.
pub fn table13<B: ModelBackend>(engine: &mut Engine<B>, p: &ExpParams) -> Result<Table> {
    let cols: Vec<String> = p.budgets.iter().map(|b| format!("@{b}")).collect();
    let colrefs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 13 — layer allocation (overall avg)", &colrefs);
    for pol in ["lava-pyramid", "lava-uniform", "lava"] {
        let mut vals = Vec::new();
        for &b in &p.budgets {
            let r = eval::run_suite(engine, pol, b, p.ctx, p.per_task, p.seed)?;
            vals.push(r.overall_avg * 100.0);
        }
        t.row(pol, vals);
    }
    Ok(t)
}

/// Table 14: exact layer attention output loss, AdaKV-score vs LAVa-score,
/// at the first and last layers.
pub fn table14<B: ModelBackend>(
    engine: &mut Engine<B>,
    wo_per_layer: &[crate::runtime::Tensor],
    p: &ExpParams,
    budget: usize,
) -> Result<Table> {
    let cfg = engine.config().clone();
    let tasks = ["needle-qa", "summ-echo", "kv-retrieve", "code-motif"];
    let cols: Vec<&str> = tasks.to_vec();
    let mut t = Table::new(
        &format!("Table 14 — layer attention output loss (budget {budget}/head)"),
        &cols,
    );
    let score_variants: Vec<(&str, Policy)> = vec![
        ("adakv-L0", Policy::by_name("ada-snapkv").unwrap()),
        ("lava-L0", Policy::by_name("lava-uniform").unwrap()),
        ("adakv-Llast", Policy::by_name("ada-snapkv").unwrap()),
        ("lava-Llast", Policy::by_name("lava-uniform").unwrap()),
    ];
    for (vi, (label, pol)) in score_variants.iter().enumerate() {
        let layer = if vi < 2 { 0 } else { cfg.n_layers - 1 };
        let mut vals = Vec::new();
        for (ti, task) in tasks.iter().enumerate() {
            let mut rng = Rng::new(p.seed ^ ((ti as u64) << 24));
            let insts = workloads::generate(task, &mut rng, p.ctx, p.per_task);
            let mut total = 0.0;
            for inst in &insts {
                // run the layers up to `layer` to get its observation
                let n = inst.prompt.len();
                let bucket =
                    crate::runtime::Runtime::pick_bucket(engine.backend.prefill_buckets(), n)
                        .unwrap();
                let mut x = engine.backend.embed(&inst.prompt, bucket)?;
                let mut out = None;
                for l in 0..=layer {
                    let o = engine.backend.layer_prefill(l, &x, n)?;
                    x = o.x_out.clone();
                    out = Some(o);
                }
                let out = out.unwrap();
                let scores =
                    score::kv_head_scores(pol.score, pol.group_reduce, &out.obs, 7);
                let keep = select_prefill(
                    &scores,
                    n,
                    budget * cfg.n_kv_heads,
                    cfg.window,
                    pol.head_alloc,
                );
                let attn = output_loss::last_row_attention(&out.obs);
                total += output_loss::layer_output_loss(
                    &attn,
                    &out.v,
                    &wo_per_layer[layer],
                    &keep.keep,
                    n,
                );
            }
            vals.push(total / insts.len() as f64);
        }
        t.row(label, vals);
    }
    Ok(t)
}

/// Fig. 3: decode latency + peak KV memory vs context length.
pub fn figure3<B: ModelBackend>(
    engine: &mut Engine<B>,
    ctx_lens: &[usize],
    policies: &[String],
    budget: usize,
    out_tokens: usize,
    seed: u64,
) -> Result<(Table, Table)> {
    let cols: Vec<String> = ctx_lens.iter().map(|c| format!("{c}")).collect();
    let colrefs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut lat = Table::new("Figure 3a — decode latency ms/token", &colrefs);
    let mut mem = Table::new("Figure 3b — peak KV MiB", &colrefs);
    for pol in policies {
        let mut lat_vals = Vec::new();
        let mut mem_vals = Vec::new();
        for (ci, &ctx) in ctx_lens.iter().enumerate() {
            eval::set_policy(engine, pol, budget);
            engine.metrics = crate::coordinator::metrics::Metrics::new();
            let mut rng = Rng::new(seed ^ ((ci as u64) << 4));
            let inst = workloads::needle_qa(&mut rng, ctx, 4);
            let req = GenerateRequest { prompt: inst.prompt, max_new_tokens: out_tokens };
            let r = engine.generate(&req)?;
            lat_vals.push(r.decode_secs * 1e3 / out_tokens as f64);
            mem_vals.push(engine.metrics.peak_kv_bytes as f64 / (1024.0 * 1024.0));
        }
        lat.row(pol, lat_vals);
        mem.row(pol, mem_vals);
    }
    Ok((lat, mem))
}
