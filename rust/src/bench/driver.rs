//! Shared glue for the `bench_*` binaries: CLI -> ExpParams, backend
//! construction (real PJRT artifacts or the mock), report output.

use crate::compress::Policy;
use crate::coordinator::engine::{Engine, EngineOptions};
use crate::model::backend::{MockBackend, PjrtBackend};
use crate::util::cli::Args;

use super::experiments::ExpParams;
use super::table::Table;

pub fn params_from_args(args: &Args) -> ExpParams {
    let d = ExpParams::default();
    ExpParams {
        ctx: args.usize_or("ctx", d.ctx),
        per_task: args.usize_or("per-task", d.per_task),
        budgets: args.usize_list_or("budgets", &d.budgets),
        policies: args
            .str_list_or("policies", &d.policies.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
        seed: args.usize_or("seed", 0) as u64,
    }
}

pub fn mock_engine(args: &Args) -> Engine<MockBackend> {
    let mut mock = MockBackend::new(MockBackend::default_config());
    mock.seed = args.usize_or("seed", 0) as u64;
    Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 32))
}

pub fn pjrt_engine(args: &Args) -> anyhow::Result<Engine<PjrtBackend>> {
    let dir = args.str_or("artifacts", "artifacts");
    let backend = PjrtBackend::load(&dir)?;
    Ok(Engine::new(backend, EngineOptions::new(Policy::by_name("lava").unwrap(), 32)))
}

/// Print tables and optionally archive to --out (jsonl).
pub fn emit(args: &Args, tables: &[Table]) {
    for t in tables {
        println!("{}", t.render(true));
        if let Some(path) = args.get("out") {
            if let Err(e) = t.save_jsonl(path) {
                eprintln!("warn: could not save to {path}: {e}");
            }
        }
    }
}

/// Dispatch an experiment body over the mock (`--mock`) or PJRT backend.
#[macro_export]
macro_rules! with_engine {
    ($args:expr, |$engine:ident| $body:expr) => {{
        if $args.bool("mock") {
            let mut $engine = $crate::bench::driver::mock_engine(&$args);
            $body
        } else {
            let mut $engine = $crate::bench::driver::pjrt_engine(&$args)?;
            $body
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_parse() {
        let args = Args::parse(
            "--ctx 128 --budgets 16,32 --policies lava,snapkv --per-task 1"
                .split_whitespace()
                .map(String::from),
        );
        let p = params_from_args(&args);
        assert_eq!(p.ctx, 128);
        assert_eq!(p.budgets, vec![16, 32]);
        assert_eq!(p.policies, vec!["lava", "snapkv"]);
    }
}
