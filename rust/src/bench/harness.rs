//! Micro-benchmark harness: warmup + timed iterations + robust stats.

use std::time::Instant;

use crate::util::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
    pub min_secs: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_secs * 1e3
    }

    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10.4} ms/iter (sd {:>8.4}, p50 {:>8.4}, p99 {:>8.4}, n={})",
            self.name,
            self.mean_secs * 1e3,
            self.stddev_secs * 1e3,
            self.p50_secs * 1e3,
            self.p99_secs * 1e3,
            self.iters
        )
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &samples)
}

/// Adaptive: run until `budget_secs` is spent (at least `min_iters`).
pub fn bench_for<F: FnMut()>(name: &str, budget_secs: f64, min_iters: usize, mut f: F) -> BenchResult {
    // warmup once
    f();
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while samples.len() < min_iters || t0.elapsed().as_secs_f64() < budget_secs {
        let s = Instant::now();
        f();
        samples.push(s.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    summarize(name, &samples)
}

fn summarize(name: &str, samples: &[f64]) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_secs: stats::mean(samples),
        stddev_secs: stats::stddev(samples),
        p50_secs: stats::percentile(samples, 50.0),
        p99_secs: stats::percentile(samples, 99.0),
        min_secs: samples.iter().copied().fold(f64::INFINITY, f64::min),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut x = 0u64;
        let r = bench("spin", 2, 10, || {
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_secs > 0.0);
        assert!(r.p99_secs >= r.p50_secs);
        assert!(r.min_secs <= r.mean_secs);
        assert!(x > 0);
    }

    #[test]
    fn adaptive_respects_min_iters() {
        let r = bench_for("fast", 0.0, 5, || {});
        assert!(r.iters >= 5);
    }
}
