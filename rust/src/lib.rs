//! # LAVa — Layer-wise KV Cache Eviction with Dynamic Budget Allocation
//!
//! A full serving-stack reproduction of *LAVa* (Shen et al., Findings of
//! EMNLP 2025): a rust coordinator (request router, dynamic batcher,
//! layer-wise prefill with cascading compression, decode loop) executing a
//! GQA transformer that was AOT-compiled from JAX + Pallas to HLO text and
//! runs through the PJRT C API — python is never on the request path.
//!
//! Crate map (see DESIGN.md for the full inventory):
//! * [`runtime`] — PJRT client, artifact loading, host tensors
//! * [`model`] — manifest + weights from `artifacts/`
//! * [`kvcache`] — tiered KV store: hot (padded f32) / warm (Q8 spill
//!   blocks) with per-session, per-layer residency
//! * [`compress`] — LAVa + all baseline eviction policies
//! * [`coordinator`] — engine front + worker pool, batcher, scheduler,
//!   sessions, server
//! * [`workloads`] — synthetic benchmark suite + scorers
//! * [`bench`] — measurement harness + table regeneration drivers
//! * [`util`] — offline substrates (JSON, RNG, stats, CLI, prop-testing)

pub mod bench;
pub mod compress;
pub mod coordinator;
pub mod kvcache;
pub mod model;
pub mod runtime;
pub mod util;
pub mod workloads;
