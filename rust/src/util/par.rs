//! Scoped-thread fan-out for the scoring / recompression hot paths.
//!
//! rayon is not in the offline vendor set, so this is the minimal shape the
//! engine needs: run a closure over a set of items on `std::thread::scope`
//! workers, with round-robin sharding (each item is touched by exactly one
//! worker, so `&mut` items are fine). Callers gate on a work-size threshold
//! and fall back to a serial loop below it — thread spawn is ~tens of
//! microseconds, which dwarfs small layers.

use std::num::NonZeroUsize;

/// Worker cap: one thread per available core.
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
}

/// Apply `f` to every item, fanning out across up to `max_threads()` scoped
/// workers. Items are sharded round-robin; ordering of side effects across
/// items is unspecified, so `f` must be independent per item (it is handed
/// each item exactly once). Serial when one worker or one item.
pub fn scoped_for_each<T, I, F>(items: I, f: F)
where
    I: Iterator<Item = T>,
    T: Send,
    F: Fn(T) + Sync,
{
    let items: Vec<T> = items.collect();
    let workers = max_threads().min(items.len());
    if workers <= 1 {
        for item in items {
            f(item);
        }
        return;
    }
    let mut shards: Vec<Vec<T>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        shards[i % workers].push(item);
    }
    std::thread::scope(|s| {
        for shard in shards {
            let f = &f;
            s.spawn(move || {
                for item in shard {
                    f(item);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_item_once() {
        let hits = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        scoped_for_each(0..100usize, |i| {
            hits.fetch_add(1, Ordering::SeqCst);
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 100);
        assert_eq!(sum.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn mutates_disjoint_items() {
        let mut xs = vec![0usize; 64];
        scoped_for_each(xs.iter_mut().enumerate(), |(i, x)| *x = i * 2);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(x, i * 2);
        }
    }

    #[test]
    fn empty_and_single() {
        scoped_for_each(std::iter::empty::<usize>(), |_| panic!("no items"));
        let mut one = vec![0];
        scoped_for_each(one.iter_mut(), |x| *x = 7);
        assert_eq!(one[0], 7);
    }
}
