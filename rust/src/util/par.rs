//! Scoped-thread fan-out for small intra-unit hot paths, and the legacy
//! round-dispatch oracle.
//!
//! rayon is not in the offline vendor set, so this is the minimal shape the
//! code base needs: run a closure over a set of items on
//! `std::thread::scope` workers. Items are sharded in *contiguous chunks*
//! (worker w takes one consecutive run of items), which keeps neighboring
//! items — adjacent layers of one cache, adjacent kv heads of one score
//! pass — on the same core's cache instead of interleaving them round-robin
//! across workers. Each item is touched by exactly one worker, so `&mut`
//! items are fine. Callers gate on a work-size threshold and fall back to a
//! serial loop below it — thread spawn is ~tens of microseconds, which
//! dwarfs small layers; `scoped_map_timed` also short-circuits to a serial
//! loop for one worker or one item.
//!
//! Two distinct roles remain after the persistent-pool rewrite
//! ([`crate::coordinator::pool`]):
//!
//! * [`scoped_for_each`] still serves *intra-unit* fan-outs whose width is
//!   data-dependent and short-lived (per-kv-head scoring, recompression
//!   cascades) — spawning there is rare and amortized over real arithmetic.
//! * [`scoped_map_timed`] is no longer the scheduler's round dispatcher;
//!   per-tick rounds run on the persistent pool's long-lived workers. It is
//!   kept, chunking and all, as the `LAVA_POOL=scoped` *bit-equivalence
//!   oracle*: the pool's scoped mode routes every round through this exact
//!   static contiguous-chunk sharding, and the fingerprint tests assert the
//!   two dispatchers produce identical results at every width.

use std::num::NonZeroUsize;
use std::sync::OnceLock;
use std::time::Instant;

/// Worker cap: one thread per available core. The `available_parallelism`
/// syscall result is cached process-wide — this is called on fan-out hot
/// paths (per layer, per score pass), not just at pool construction.
pub fn max_threads() -> usize {
    static MAX_THREADS: OnceLock<usize> = OnceLock::new();
    *MAX_THREADS.get_or_init(|| {
        std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
    })
}

/// Split `len` items into at most `workers` contiguous chunk lengths, the
/// remainder spread over the leading chunks (chunk sizes differ by <= 1).
fn chunk_lens(len: usize, workers: usize) -> Vec<usize> {
    let workers = workers.min(len).max(1);
    let base = len / workers;
    let rem = len % workers;
    (0..workers).map(|w| base + usize::from(w < rem)).collect()
}

/// Apply `f` to every item, fanning out across up to `max_threads()` scoped
/// workers in contiguous chunks. Ordering of side effects across items is
/// unspecified, so `f` must be independent per item (it is handed each item
/// exactly once). Serial when one worker or one item.
pub fn scoped_for_each<T, I, F>(items: I, f: F)
where
    I: Iterator<Item = T>,
    T: Send,
    F: Fn(T) + Sync,
{
    let items: Vec<T> = items.collect();
    let workers = max_threads().min(items.len());
    if workers <= 1 {
        for item in items {
            f(item);
        }
        return;
    }
    let lens = chunk_lens(items.len(), workers);
    let mut items = items.into_iter();
    std::thread::scope(|s| {
        for len in lens {
            let shard: Vec<T> = items.by_ref().take(len).collect();
            let f = &f;
            s.spawn(move || {
                for item in shard {
                    f(item);
                }
            });
        }
    });
}

/// Ordered map over scoped workers: `f` runs once per item, results come
/// back **in item order** (chunking is contiguous, so concatenating the
/// chunks' outputs restores the input order). Uses up to `max_threads()`
/// workers; see [`scoped_map_timed`] for an explicit worker cap.
pub fn scoped_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    scoped_map_timed(items, f, max_threads()).0
}

/// [`scoped_map`] with an explicit worker cap, reporting each worker's busy
/// seconds (index = worker slot, one entry per worker actually spawned) —
/// the pool's utilization gauge. `max_workers` is honored even beyond
/// `max_threads()` so a configured pool size behaves identically on any
/// host. Serial (no spawns, one busy entry) for one worker or one item.
pub fn scoped_map_timed<T, R, F>(items: Vec<T>, f: F, max_workers: usize) -> (Vec<R>, Vec<f64>)
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let workers = max_workers.min(items.len()).max(1);
    if workers <= 1 {
        let t0 = Instant::now();
        let out: Vec<R> = items.into_iter().map(f).collect();
        return (out, vec![t0.elapsed().as_secs_f64()]);
    }
    let lens = chunk_lens(items.len(), workers);
    let mut items = items.into_iter();
    let shards: Vec<Vec<T>> =
        lens.into_iter().map(|len| items.by_ref().take(len).collect()).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = shards
            .into_iter()
            .map(|shard| {
                let f = &f;
                s.spawn(move || {
                    let t0 = Instant::now();
                    let out: Vec<R> = shard.into_iter().map(f).collect();
                    (out, t0.elapsed().as_secs_f64())
                })
            })
            .collect();
        let mut results = Vec::new();
        let mut busy = Vec::with_capacity(handles.len());
        for h in handles {
            let (out, secs) = h.join().expect("scoped_map worker panicked");
            results.extend(out);
            busy.push(secs);
        }
        (results, busy)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_item_once() {
        let hits = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        scoped_for_each(0..100usize, |i| {
            hits.fetch_add(1, Ordering::SeqCst);
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 100);
        assert_eq!(sum.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn mutates_disjoint_items() {
        let mut xs = vec![0usize; 64];
        scoped_for_each(xs.iter_mut().enumerate(), |(i, x)| *x = i * 2);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(x, i * 2);
        }
    }

    #[test]
    fn empty_and_single() {
        scoped_for_each(std::iter::empty::<usize>(), |_| panic!("no items"));
        let mut one = vec![0];
        scoped_for_each(one.iter_mut(), |x| *x = 7);
        assert_eq!(one[0], 7);
    }

    #[test]
    fn chunking_is_contiguous_and_covers() {
        assert_eq!(chunk_lens(10, 3), vec![4, 3, 3]);
        assert_eq!(chunk_lens(3, 8), vec![1, 1, 1]);
        assert_eq!(chunk_lens(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(chunk_lens(1, 1), vec![1]);
        for (len, w) in [(17usize, 4usize), (5, 2), (100, 7)] {
            assert_eq!(chunk_lens(len, w).iter().sum::<usize>(), len);
        }
    }

    #[test]
    fn map_preserves_order() {
        for workers in [1usize, 2, 3, 8] {
            let items: Vec<usize> = (0..37).collect();
            let (out, busy) = scoped_map_timed(items, |i| i * 3, workers);
            assert_eq!(out, (0..37).map(|i| i * 3).collect::<Vec<_>>(), "workers={workers}");
            assert!(!busy.is_empty() && busy.len() <= workers.max(1));
        }
        let out = scoped_map((0..10usize).collect(), |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty_and_single() {
        let (out, busy) = scoped_map_timed(Vec::<usize>::new(), |i| i, 4);
        assert!(out.is_empty());
        assert_eq!(busy.len(), 1, "serial fallback still reports one slot");
        let (out, _) = scoped_map_timed(vec![9usize], |i| i * 2, 4);
        assert_eq!(out, vec![18]);
    }

    #[test]
    fn map_moves_mutable_items_through() {
        // the pool's usage shape: units are owned, mutated, and handed back
        let units: Vec<Vec<usize>> = (0..6).map(|i| vec![i]).collect();
        let (out, _) = scoped_map_timed(
            units,
            |mut u| {
                u.push(u[0] * 10);
                u
            },
            3,
        );
        for (i, u) in out.iter().enumerate() {
            assert_eq!(u, &vec![i, i * 10]);
        }
    }
}
