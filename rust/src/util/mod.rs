//! Self-contained substrates the coordinator depends on.
//!
//! The build is fully offline against the `xla` crate's vendored closure, so
//! everything that would normally be a crates.io dependency (JSON, RNG,
//! stats, CLI parsing, property testing) is implemented here and unit-tested
//! like any other module.

pub mod cli;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
