//! Deterministic PRNG (SplitMix64 core + helpers). Replaces `rand`, which is
//! not in the offline vendor set. Every workload generator and the mock
//! backend take an explicit seed so benchmark runs are reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free-enough for our sizes.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }

    /// Fork a child stream (stable per label) — lets parallel generators
    /// stay deterministic regardless of call order.
    pub fn fork(&self, label: u64) -> Rng {
        Rng::new(self.state ^ label.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xA5A5A5A5A5A5A5A5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {}", mean);
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_differ() {
        let r = Rng::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
