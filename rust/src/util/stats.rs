//! Small numeric helpers shared by the bench harness and the compressor
//! (means, percentiles, entropy, variance).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Shannon entropy (nats) of a non-negative weight vector, normalizing to a
/// distribution first. Zero weights contribute zero. This is the e_l
/// numerator in the paper's Eq. 7.
pub fn entropy(weights: &[f32]) -> f64 {
    let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &w in weights {
        let p = w.max(0.0) as f64 / total;
        if p > 0.0 {
            h -= p * p.ln();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        assert_eq!(percentile(&xs, 50.0), 30.0);
        assert_eq!(percentile(&xs, 25.0), 20.0);
    }

    #[test]
    fn entropy_limits() {
        // uniform over n -> ln(n); point mass -> 0
        let u = [1.0f32; 8];
        assert!((entropy(&u) - (8.0f64).ln()).abs() < 1e-9);
        let p = [1.0f32, 0.0, 0.0, 0.0];
        assert!(entropy(&p).abs() < 1e-12);
    }

    #[test]
    fn entropy_ignores_negatives_and_zeros() {
        assert_eq!(entropy(&[]), 0.0);
        assert_eq!(entropy(&[0.0, 0.0]), 0.0);
        let h = entropy(&[1.0, 1.0, -5.0]);
        assert!((h - (2.0f64).ln()).abs() < 1e-9);
    }
}
