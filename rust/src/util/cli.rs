//! Tiny flag parser (clap is not in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters with defaults keep the launcher/bench binaries
//! terse.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list, e.g. `--budgets 128,256,512`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn kv_forms() {
        // note: `--flag value` binds value to flag (so boolean flags must be
        // last or use `--flag=true`); positionals come before flags, the
        // `lava <subcommand> --opts` convention.
        let a = parse("run --a 1 --b=2 --c 3.5 --flag");
        assert_eq!(a.usize_or("a", 0), 1);
        assert_eq!(a.usize_or("b", 0), 2);
        assert!(a.bool("flag"));
        assert_eq!(a.f64_or("c", 0.0), 3.5);
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("m", "x"), "x");
        assert!(!a.bool("m"));
    }

    #[test]
    fn lists() {
        let a = parse("--budgets 128,256,512 --methods lava,snapkv");
        assert_eq!(a.usize_list_or("budgets", &[]), vec![128, 256, 512]);
        assert_eq!(a.str_list_or("methods", &[]), vec!["lava", "snapkv"]);
        assert_eq!(a.usize_list_or("other", &[1]), vec![1]);
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("--verbose");
        assert!(a.bool("verbose"));
    }
}
