//! Property-based testing helper (proptest is not in the offline vendor
//! set). `check` runs a property over many seeded random cases and, on
//! failure, retries the failing case with progressively "smaller" sizes to
//! report a reduced counterexample seed.
//!
//! Usage:
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = rng.range(1, 64);
//!     let xs: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
//!     prop::assert_prop(invariant_holds(&xs), "invariant", &xs)
//! });
//! ```

use super::rng::Rng;

pub struct CaseFailure {
    pub message: String,
}

pub type PropResult = Result<(), CaseFailure>;

/// Assert inside a property; carries a debuggable payload into the failure.
pub fn assert_prop<D: std::fmt::Debug>(cond: bool, what: &str, payload: &D) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(CaseFailure { message: format!("property '{}' failed for {:?}", what, payload) })
    }
}

/// Run `cases` random trials of `f`. Panics with seed + message on failure
/// so the exact case can be replayed with `replay(seed, f)`.
pub fn check<F: FnMut(&mut Rng) -> PropResult>(cases: u64, mut f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(fail) = f(&mut rng) {
            panic!("prop case {} (seed {:#x}) failed: {}", case, seed, fail.message);
        }
    }
}

/// Replay a single failing case.
pub fn replay<F: FnMut(&mut Rng) -> PropResult>(seed: u64, mut f: F) -> PropResult {
    let mut rng = Rng::new(seed);
    f(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |rng| {
            let x = rng.f64();
            assert_prop((0.0..1.0).contains(&x), "unit interval", &x)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn fails_loudly() {
        check(5, |rng| {
            let x = rng.f64();
            assert_prop(false, "always false", &x)
        });
    }

    #[test]
    fn replay_reproduces() {
        // find behaviour is deterministic per seed
        let mut first = None;
        let r = replay(1234, |rng| {
            let v = rng.next_u64();
            if first.is_none() {
                first = Some(v);
            }
            assert_prop(first == Some(v), "stable", &v)
        });
        assert!(r.is_ok());
    }
}
