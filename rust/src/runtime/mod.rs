//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `client.compile` -> `execute` (the /opt/xla-example/load_hlo pattern),
//! wrapped with:
//!   * an executable cache keyed by entrypoint name (compile once per
//!     (entrypoint, shape-bucket)),
//!   * persistent device buffers for weights (uploaded once, passed by
//!     reference on every call — python is never on this path),
//!   * host `Tensor` conversion at the boundary,
//!   * per-entrypoint call/latency counters for the perf pass.

pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

pub use tensor::{Tensor, TensorData};

/// An argument to an entrypoint: either host data (converted per call) or a
/// persistent device buffer (weights).
pub enum Arg<'a> {
    Host(&'a Tensor),
    Device(&'a xla::PjRtBuffer),
}

#[derive(Debug, Default, Clone)]
pub struct CallStats {
    pub calls: u64,
    pub total_secs: f64,
}

pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    exes: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<HashMap<String, CallStats>>,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
            exes: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload host data as a persistent device buffer (used for weights).
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let buf = match &t.data {
            TensorData::F32(v) => self.client.buffer_from_host_buffer(v, &t.shape, None)?,
            TensorData::I32(v) => self.client.buffer_from_host_buffer(v, &t.shape, None)?,
        };
        Ok(buf)
    }

    /// Compile (or fetch from cache) the executable for an entrypoint.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        let dt = t0.elapsed().as_secs_f64();
        self.record(&format!("compile:{name}"), dt);
        self.exes.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// True if the artifact file for `name` exists.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Execute an entrypoint. All jax entrypoints are lowered with
    /// `return_tuple=True`, so the single output is a tuple that we
    /// decompose into host tensors.
    pub fn execute(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        let t0 = Instant::now();

        // Mixed host/device args: upload host tensors, then execute_b.
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut idx: Vec<usize> = Vec::with_capacity(args.len()); // usize::MAX = device
        for a in args {
            match a {
                Arg::Host(t) => {
                    owned.push(self.upload(t)?);
                    idx.push(owned.len() - 1);
                }
                Arg::Device(_) => idx.push(usize::MAX),
            }
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for (a, &i) in args.iter().zip(&idx) {
            match a {
                Arg::Host(_) => refs.push(&owned[i]),
                Arg::Device(b) => refs.push(b),
            }
        }
        let result = exe.execute_b(&refs)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output from {name}"))?
            .to_literal_sync()?;
        let parts = lit.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in &parts {
            out.push(Tensor::from_literal(p)?);
        }
        self.record(name, t0.elapsed().as_secs_f64());
        Ok(out)
    }

    fn record(&self, name: &str, secs: f64) {
        let mut stats = self.stats.lock().unwrap();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += secs;
    }

    pub fn stats_snapshot(&self) -> Vec<(String, CallStats)> {
        let mut v: Vec<_> = self
            .stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        v
    }

    /// Pick the smallest bucket >= `n` from a sorted bucket list.
    pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
        buckets.iter().copied().find(|&b| b >= n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let b = [128, 256, 512, 1024, 2048];
        assert_eq!(Runtime::pick_bucket(&b, 1), Some(128));
        assert_eq!(Runtime::pick_bucket(&b, 128), Some(128));
        assert_eq!(Runtime::pick_bucket(&b, 129), Some(256));
        assert_eq!(Runtime::pick_bucket(&b, 2048), Some(2048));
        assert_eq!(Runtime::pick_bucket(&b, 4000), None);
    }
}
