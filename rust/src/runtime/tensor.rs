//! Host tensors: the coordinator-side representation of model inputs and
//! outputs. Row-major f32/i32 with explicit shape; converts to/from the
//! `xla` crate's `Literal`/`PjRtBuffer` at the runtime boundary.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::f32(vec![0.0; shape.iter().product()], shape)
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(vec![v], &[1])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Row-major strides for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Value at a multi-index (f32 tensors).
    pub fn at(&self, idx: &[usize]) -> f32 {
        let strides = self.strides();
        assert_eq!(idx.len(), self.shape.len());
        let flat: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.as_f32().expect("at() on non-f32")[flat]
    }

    /// Memory footprint in bytes (host side).
    pub fn nbytes(&self) -> usize {
        self.len() * 4
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.primitive_type() {
            xla::PrimitiveType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::PrimitiveType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            t => bail!("unsupported literal type {:?}", t),
        };
        let t = Tensor { shape: dims, data };
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_at() {
        let t = Tensor::f32((0..24).map(|x| x as f32).collect(), &[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.at(&[1, 2, 3]), 23.0);
        assert_eq!(t.at(&[0, 1, 0]), 4.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_bad_shape() {
        Tensor::f32(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn scalar_helpers() {
        let s = Tensor::scalar_i32(7);
        assert_eq!(s.as_i32().unwrap(), &[7]);
        assert_eq!(s.shape, vec![1]);
    }

    #[test]
    fn zeros() {
        let z = Tensor::zeros(&[4, 8]);
        assert_eq!(z.len(), 32);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
