//! The continuous serving loop: one dedicated thread owns the scheduler
//! and drives [`Scheduler::tick`] while draining a submit-queue of commands
//! (submit / cancel / metrics / shutdown) from any number of front-end
//! threads.
//!
//! Front ends talk to the loop through a cloneable [`ServeHandle`]; every
//! command carries its own reply channel, so callers block only on their
//! own request, never on each other or on a decode round. Each submitted
//! request registers a subscriber sink that receives [`Event`]s:
//!
//! * `Event::Token` — one per generated token, in production order, for
//!   subscribers that opted into streaming (`stream: true`); `index` is the
//!   token's 0-based position in the request's output, so a client can
//!   detect gaps or reassemble out-of-order transports.
//! * `Event::Finished` — the terminal [`GenerateResult`]; always the last
//!   event a subscriber sees, streaming or not.
//!
//! Because the loop interleaves command handling with single ticks, a
//! `cancel` lands at the next tick boundary (mid-generation, releasing hot
//! and warm bytes through the scheduler's retire path), and `metrics`
//! returns a [`MetricsSnapshot`] copy without stopping the world. A
//! `shutdown` flips the loop into *draining*: queued-but-unadmitted
//! requests are parked with rejection results, in-flight sessions keep
//! ticking to completion, new submissions are refused with
//! [`SubmitError::ShuttingDown`], and the shutdown reply is sent only after
//! the last session retires — so a front end can report "drained" truthfully.
//!
//! When the loop is idle (no queued or active work, not draining) it parks
//! in a blocking `recv`, so an idle server burns no CPU. Dropping every
//! `ServeHandle` ends the loop after remaining work drains.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

use super::engine::{GenerateRequest, GenerateResult};
use super::metrics::MetricsSnapshot;
use super::scheduler::{Scheduler, SubmitError, TickReport};
use crate::model::backend::ModelBackend;

/// One serving-loop event delivered to a request's subscriber sink.
#[derive(Debug)]
pub enum Event {
    /// A newly produced token (sent to streaming subscribers only).
    Token { id: u64, token: i32, index: usize },
    /// The terminal result; always the subscriber's last event.
    Finished { id: u64, result: GenerateResult },
}

/// Where a request's events go. Sinks run on the serving thread, so they
/// must not block — send into a channel or another non-blocking queue.
pub type EventSink = Box<dyn FnMut(Event) + Send>;

/// One request of an atomic submission group: a batch line's requests are
/// handed to the scheduler together, before the next tick, so same-bucket
/// members can be admitted (and prefill/decode) as one group — exactly the
/// grouping a batch driven through `run_to_completion` would get.
pub struct SubmitItem {
    pub req: GenerateRequest,
    pub stream: bool,
    pub sink: EventSink,
}

enum Command {
    Submit { items: Vec<SubmitItem>, reply: Sender<Vec<Result<u64, SubmitError>>> },
    Cancel { id: u64, reply: Sender<bool> },
    Metrics { reply: Sender<MetricsSnapshot> },
    Shutdown { reply: Sender<()> },
}

/// Cloneable front-end handle to the serving thread. Every method is safe
/// to call from any thread; each blocks only on its own reply.
#[derive(Clone)]
pub struct ServeHandle {
    tx: Sender<Command>,
}

impl ServeHandle {
    /// Submit one atomic group of requests (one per batch-line entry); the
    /// returned vector maps 1:1 to `items`. Each Ok holds the id the
    /// request's terminal result will carry.
    pub fn submit_many(&self, items: Vec<SubmitItem>) -> Vec<Result<u64, SubmitError>> {
        let n = items.len();
        let (reply_tx, reply_rx) = channel();
        if self.tx.send(Command::Submit { items, reply: reply_tx }).is_err() {
            return (0..n).map(|_| Err(SubmitError::ShuttingDown)).collect();
        }
        reply_rx
            .recv()
            .unwrap_or_else(|_| (0..n).map(|_| Err(SubmitError::ShuttingDown)).collect())
    }

    /// Submit a request with a custom event sink. Returns the request id
    /// the terminal result will carry, or why the loop refused it.
    pub fn submit(
        &self,
        req: GenerateRequest,
        stream: bool,
        sink: EventSink,
    ) -> Result<u64, SubmitError> {
        self.submit_many(vec![SubmitItem { req, stream, sink }])
            .pop()
            .unwrap_or(Err(SubmitError::ShuttingDown))
    }

    /// Submit with a channel sink: events arrive on the returned receiver
    /// (ending with `Event::Finished`). The common embedder entry point.
    pub fn submit_channel(
        &self,
        req: GenerateRequest,
        stream: bool,
    ) -> Result<(u64, Receiver<Event>), SubmitError> {
        let (ev_tx, ev_rx) = channel();
        let id = self.submit(
            req,
            stream,
            Box::new(move |ev| {
                // a hung-up subscriber must not poison the serving thread
                let _ = ev_tx.send(ev);
            }),
        )?;
        Ok((id, ev_rx))
    }

    /// Cancel a request by id, queued or mid-decode. True if the id was
    /// live; the subscriber still gets its terminal (Canceled) event.
    pub fn cancel(&self, id: u64) -> bool {
        let (reply_tx, reply_rx) = channel();
        if self.tx.send(Command::Cancel { id, reply: reply_tx }).is_err() {
            return false;
        }
        reply_rx.recv().unwrap_or(false)
    }

    /// Snapshot the serving metrics without pausing decode. None only when
    /// the serving thread is gone.
    pub fn metrics(&self) -> Option<MetricsSnapshot> {
        let (reply_tx, reply_rx) = channel();
        self.tx.send(Command::Metrics { reply: reply_tx }).ok()?;
        reply_rx.recv().ok()
    }

    /// Begin shutdown and block until in-flight sessions have drained:
    /// queued requests are rejected, active sessions tick to completion,
    /// new submissions are refused.
    pub fn shutdown(&self) {
        let (reply_tx, reply_rx) = channel();
        if self.tx.send(Command::Shutdown { reply: reply_tx }).is_ok() {
            let _ = reply_rx.recv();
        }
    }
}

/// Move `sched` onto a dedicated serving thread and return the handle front
/// ends submit through. The thread exits after `shutdown` drains, or when
/// every handle has been dropped and no work remains.
pub fn spawn<B: ModelBackend + 'static>(sched: Scheduler<B>) -> ServeHandle {
    let (tx, rx) = channel();
    std::thread::Builder::new()
        .name("lava-serve".to_string())
        .spawn(move || serve_loop(sched, rx))
        .expect("spawn serving thread");
    ServeHandle { tx }
}

struct Subscriber {
    sink: EventSink,
    stream: bool,
    /// Tokens seen for this request so far (== the next token's index).
    emitted: usize,
}

fn serve_loop<B: ModelBackend>(mut sched: Scheduler<B>, rx: Receiver<Command>) {
    let mut subs: HashMap<u64, Subscriber> = HashMap::new();
    let mut draining = false;
    let mut shutdown_replies: Vec<Sender<()>> = Vec::new();
    'serve: loop {
        // Idle and not draining: park until the next command (no busy wait).
        if !sched.has_work() && !draining {
            match rx.recv() {
                Ok(cmd) => {
                    handle_command(&mut sched, &mut subs, &mut draining, &mut shutdown_replies, cmd)
                }
                // every handle dropped, nothing left to do
                Err(_) => break 'serve,
            }
        }
        // Absorb whatever else is pending without blocking a decode round.
        loop {
            match rx.try_recv() {
                Ok(cmd) => {
                    handle_command(&mut sched, &mut subs, &mut draining, &mut shutdown_replies, cmd)
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // keep ticking until in-flight work retires, then exit
                    // through the idle recv above
                    break;
                }
            }
        }
        if draining {
            sched.drain_queue_rejecting("server shutting down: request rejected before admission");
        }
        if sched.has_work() {
            match sched.tick() {
                Ok(report) => dispatch(&mut sched, &mut subs, report),
                Err(e) => {
                    // Defensive: the scheduler parks engine errors as Failed
                    // results, so a tick-level error means the loop itself
                    // cannot make progress. Cancel in-flight work (each
                    // subscriber still gets a terminal event) instead of
                    // spinning or hanging clients.
                    eprintln!("[lava] serving tick failed, canceling in-flight work: {e:#}");
                    for id in sched.active_ids() {
                        sched.cancel(id);
                    }
                    sched.drain_queue_rejecting(&format!("serving tick failed: {e:#}"));
                }
            }
        }
        // Results parked outside a tick (cancel-while-queued, shutdown
        // rejections on an otherwise idle loop) still need delivering.
        let parked = sched.take_finished();
        if !parked.is_empty() {
            let report = TickReport { worked: true, tokens: vec![], finished: parked };
            dispatch(&mut sched, &mut subs, report);
        }
        if draining && !sched.has_work() {
            for reply in shutdown_replies.drain(..) {
                let _ = reply.send(());
            }
            break 'serve;
        }
    }
}

/// Deliver a tick's produce to subscribers: token events to streaming
/// sinks (with their per-request index), terminal results to everyone.
fn dispatch<B: ModelBackend>(
    sched: &mut Scheduler<B>,
    subs: &mut HashMap<u64, Subscriber>,
    report: TickReport,
) {
    let mut streamed = 0u64;
    for (id, token) in report.tokens {
        if let Some(sub) = subs.get_mut(&id) {
            if sub.stream {
                let index = sub.emitted;
                (sub.sink)(Event::Token { id, token, index });
                streamed += 1;
            }
            sub.emitted += 1;
        }
    }
    sched.engine.metrics.streamed_tokens += streamed;
    for (id, result) in report.finished {
        if let Some(mut sub) = subs.remove(&id) {
            (sub.sink)(Event::Finished { id, result });
        }
    }
}

fn handle_command<B: ModelBackend>(
    sched: &mut Scheduler<B>,
    subs: &mut HashMap<u64, Subscriber>,
    draining: &mut bool,
    shutdown_replies: &mut Vec<Sender<()>>,
    cmd: Command,
) {
    match cmd {
        Command::Submit { items, reply } => {
            let mut results = Vec::with_capacity(items.len());
            for item in items {
                if *draining {
                    results.push(Err(SubmitError::ShuttingDown));
                    continue;
                }
                match sched.submit(item.req) {
                    Ok(id) => {
                        subs.insert(
                            id,
                            Subscriber { sink: item.sink, stream: item.stream, emitted: 0 },
                        );
                        results.push(Ok(id));
                    }
                    Err(e) => results.push(Err(e)),
                }
            }
            let _ = reply.send(results);
        }
        Command::Cancel { id, reply } => {
            let _ = reply.send(sched.cancel(id));
        }
        Command::Metrics { reply } => {
            let _ = reply.send(sched.metrics_snapshot());
        }
        Command::Shutdown { reply } => {
            *draining = true;
            shutdown_replies.push(reply);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Policy;
    use crate::coordinator::engine::{Engine, EngineOptions, FinishStatus};
    use crate::coordinator::scheduler::SchedulerOptions;
    use crate::model::backend::MockBackend;

    fn handle(opts: SchedulerOptions) -> ServeHandle {
        let mock = MockBackend::new(MockBackend::default_config());
        let engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        spawn(Scheduler::new(engine, opts))
    }

    fn req(n: usize, out: usize) -> GenerateRequest {
        GenerateRequest { prompt: (0..n).map(|i| (i % 251) as i32).collect(), max_new_tokens: out }
    }

    #[test]
    fn streamed_tokens_match_terminal_result() {
        let h = handle(SchedulerOptions::default());
        let (id, rx) = h.submit_channel(req(100, 6), true).unwrap();
        let mut streamed = Vec::new();
        let mut result = None;
        for ev in rx {
            match ev {
                Event::Token { id: eid, token, index } => {
                    assert_eq!(eid, id);
                    assert_eq!(index, streamed.len(), "indices must be gapless");
                    streamed.push(token);
                }
                Event::Finished { id: eid, result: r } => {
                    assert_eq!(eid, id);
                    result = Some(r);
                }
            }
        }
        let r = result.expect("terminal event");
        assert_eq!(r.status, FinishStatus::Completed);
        assert_eq!(streamed, r.tokens, "stream must equal the final token list");
        let snap = h.metrics().unwrap();
        assert_eq!(snap.metrics.streamed_tokens, 6);
        h.shutdown();
    }

    #[test]
    fn non_streaming_subscriber_gets_only_the_terminal_event() {
        let h = handle(SchedulerOptions::default());
        let (_, rx) = h.submit_channel(req(100, 4), false).unwrap();
        let events: Vec<Event> = rx.into_iter().collect();
        assert_eq!(events.len(), 1);
        assert!(matches!(&events[0], Event::Finished { result, .. }
            if result.tokens.len() == 4));
        h.shutdown();
    }

    #[test]
    fn cancel_of_a_queued_request_delivers_a_terminal_event() {
        // max_active 1: the second request waits in the queue while the
        // first decodes, so the cancel hits the queued path
        let h = handle(SchedulerOptions { max_active: 1, ..Default::default() });
        let (_, rx_a) = h.submit_channel(req(100, 50), false).unwrap();
        let (id_b, rx_b) = h.submit_channel(req(100, 50), false).unwrap();
        assert!(h.cancel(id_b));
        match rx_b.recv().expect("terminal event for the canceled request") {
            Event::Finished { result, .. } => {
                assert_eq!(result.status, FinishStatus::Canceled)
            }
            ev => panic!("unexpected event {ev:?}"),
        }
        assert!(!h.cancel(id_b), "double-cancel is a no-op");
        drop(rx_a);
        h.shutdown();
    }

    #[test]
    fn shutdown_drains_active_and_rejects_queued_and_new() {
        let h = handle(SchedulerOptions { max_active: 1, ..Default::default() });
        // stream A so we can wait until it is provably mid-decode before
        // shutting down (otherwise shutdown could race its admission)
        let (_, rx_a) = h.submit_channel(req(100, 30), true).unwrap();
        match rx_a.recv().unwrap() {
            Event::Token { .. } => {}
            ev => panic!("expected a token first, got {ev:?}"),
        }
        let (_, rx_b) = h.submit_channel(req(100, 30), false).unwrap();
        h.shutdown();
        // in-flight session drained to completion
        let ra = match rx_a.into_iter().last().expect("terminal event") {
            Event::Finished { result, .. } => result,
            ev => panic!("unexpected event {ev:?}"),
        };
        assert_eq!(ra.status, FinishStatus::Completed);
        assert_eq!(ra.tokens.len(), 30);
        // queued-but-unadmitted request rejected with the shutdown reason
        let rb = match rx_b.recv().unwrap() {
            Event::Finished { result, .. } => result,
            ev => panic!("unexpected event {ev:?}"),
        };
        assert_eq!(rb.status, FinishStatus::Rejected);
        assert!(rb.error.as_deref().unwrap().contains("shutting down"));
        // new submissions bounce off the dead loop
        assert!(matches!(
            h.submit_channel(req(100, 2), false),
            Err(SubmitError::ShuttingDown)
        ));
    }
}
