//! Continuous-batching scheduler.
//!
//! Owns the engine + the request queue and interleaves work:
//!   * admission control — a new prefill is admitted only if projected KV
//!     memory (existing live bytes + new request's budget + one
//!     uncompressed layer) fits the configured limit;
//!   * prefill/decode interleaving — decode-first with a prefill every
//!     `prefill_every` scheduler ticks (bounds TTFT without starving
//!     decodes), the standard continuous-batching compromise;
//!   * round-robin decode across active sessions.

use std::collections::VecDeque;

use anyhow::Result;

use super::batcher::Batcher;
use super::engine::{Engine, GenerateRequest, GenerateResult};
use super::session::Session;
use crate::model::backend::ModelBackend;

#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    /// Cap on total live KV bytes across sessions (None = unlimited).
    pub kv_mem_limit: Option<usize>,
    /// Max concurrently decoding sessions.
    pub max_active: usize,
    /// Attempt one prefill admission every this many ticks.
    pub prefill_every: usize,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { kv_mem_limit: None, max_active: 8, prefill_every: 4 }
    }
}

pub struct Scheduler<B: ModelBackend> {
    pub engine: Engine<B>,
    pub queue: Batcher,
    pub opts: SchedulerOptions,
    active: VecDeque<Session>,
    finished: Vec<(u64, GenerateResult)>,
    tick: usize,
    /// request-id remap: batcher id -> session id
    id_map: Vec<(u64, u64)>,
}

impl<B: ModelBackend> Scheduler<B> {
    pub fn new(engine: Engine<B>, opts: SchedulerOptions) -> Scheduler<B> {
        let queue = Batcher::new(engine.backend.prefill_buckets());
        Scheduler { engine, queue, opts, active: VecDeque::new(), finished: Vec::new(), tick: 0, id_map: Vec::new() }
    }

    pub fn submit(&mut self, req: GenerateRequest) -> Option<u64> {
        self.queue.push(req)
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn pending_count(&self) -> usize {
        self.queue.len()
    }

    fn live_kv_bytes(&self) -> usize {
        self.active.iter().map(|s| s.kv_bytes()).sum()
    }

    /// Projected bytes a request will hold after prefill (its budget) plus
    /// the transient uncompressed layer during prefill.
    fn projected_bytes(&self, prompt_len: usize) -> usize {
        let cfg = self.engine.config();
        let budget_entries =
            self.engine.opts.budget_per_head * cfg.n_kv_heads * cfg.n_layers;
        let retained = budget_entries.min(prompt_len * cfg.n_kv_heads * cfg.n_layers)
            * cfg.d_head * 2 * 4;
        let transient = 2 * cfg.n_kv_heads * prompt_len * cfg.d_head * 4;
        retained + transient
    }

    fn can_admit(&self, prompt_len: usize) -> bool {
        if self.active.len() >= self.opts.max_active {
            return false;
        }
        match self.opts.kv_mem_limit {
            None => true,
            Some(limit) => self.live_kv_bytes() + self.projected_bytes(prompt_len) <= limit,
        }
    }

    /// One scheduler tick: either admit+prefill one request or advance every
    /// active session by one decode step. Returns true if any work was done.
    pub fn tick(&mut self) -> Result<bool> {
        self.tick += 1;
        let want_prefill = self.active.is_empty()
            || (self.tick % self.opts.prefill_every == 0 && !self.queue.is_empty());

        if want_prefill {
            // peek oldest; admit if memory allows
            if let Some(q) = self.queue.pop() {
                if self.can_admit(q.request.prompt.len()) {
                    let mut sess = self.engine.new_session(&q.request);
                    self.id_map.push((q.id, sess.id));
                    self.engine.prefill(&mut sess)?;
                    if sess.is_done() {
                        self.retire(sess);
                    } else {
                        self.active.push_back(sess);
                    }
                    return Ok(true);
                } else {
                    // no capacity: requeue at the front by re-pushing last
                    // (simplest backpressure: defer)
                    let id = q.id;
                    self.queue.push(q.request);
                    let _ = id;
                }
            }
        }

        if self.active.is_empty() {
            return Ok(false);
        }
        // round-robin: one decode step per active session
        let mut still_active = VecDeque::new();
        while let Some(mut sess) = self.active.pop_front() {
            self.engine.decode_step(&mut sess)?;
            if sess.is_done() {
                self.retire(sess);
            } else {
                still_active.push_back(sess);
            }
        }
        self.active = still_active;
        Ok(true)
    }

    fn retire(&mut self, sess: Session) {
        self.engine.metrics.finish_request(
            sess.prefill_secs,
            sess.decode_secs,
            sess.generated.len(),
        );
        let result = GenerateResult {
            tokens: sess.generated.clone(),
            prefill_secs: sess.prefill_secs,
            decode_secs: sess.decode_secs,
            kv_bytes_after_prefill: sess.kv_bytes(),
            peak_kv_bytes: self.engine.metrics.peak_kv_bytes,
            budgets: sess.budgets.clone(),
        };
        self.finished.push((sess.id, result));
    }

    /// Drive everything to completion; returns finished (session-id, result)
    /// pairs in completion order.
    pub fn run_to_completion(&mut self) -> Result<Vec<(u64, GenerateResult)>> {
        while !self.queue.is_empty() || !self.active.is_empty() {
            self.tick()?;
        }
        Ok(std::mem::take(&mut self.finished))
    }

    pub fn take_finished(&mut self) -> Vec<(u64, GenerateResult)> {
        std::mem::take(&mut self.finished)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Policy;
    use crate::coordinator::engine::EngineOptions;
    use crate::model::backend::MockBackend;

    fn sched(limit: Option<usize>) -> Scheduler<MockBackend> {
        let mock = MockBackend::new(MockBackend::default_config());
        let engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        Scheduler::new(engine, SchedulerOptions { kv_mem_limit: limit, ..Default::default() })
    }

    fn req(n: usize, out: usize) -> GenerateRequest {
        GenerateRequest { prompt: (0..n).map(|i| (i % 251) as i32).collect(), max_new_tokens: out }
    }

    #[test]
    fn runs_all_requests() {
        let mut s = sched(None);
        for _ in 0..5 {
            s.submit(req(100, 4)).unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
        for (_, r) in &done {
            assert_eq!(r.tokens.len(), 4);
        }
        assert_eq!(s.engine.metrics.requests_finished, 5);
    }

    #[test]
    fn interleaves_decodes_and_prefills() {
        let mut s = sched(None);
        for _ in 0..3 {
            s.submit(req(100, 12)).unwrap();
        }
        // after a few ticks there should be >1 active session (continuous
        // batching, not sequential draining)
        let mut max_active = 0;
        for _ in 0..8 {
            s.tick().unwrap();
            max_active = max_active.max(s.active_count());
        }
        assert!(max_active >= 2, "expected interleaving, got {max_active}");
        s.run_to_completion().unwrap();
    }

    #[test]
    fn memory_limit_defers_admission() {
        // limit allows roughly one session's budget
        let mut s = sched(Some(300_000));
        for _ in 0..4 {
            s.submit(req(200, 6)).unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 4, "deferred requests must still finish");
    }

    #[test]
    fn rejects_oversized() {
        let mut s = sched(None);
        assert!(s.submit(req(1 << 20, 1)).is_none());
    }
}
