//! Continuous-batching scheduler over a sharded engine worker pool.
//!
//! Owns the engine front + the request queue and interleaves work through
//! three explicit steps, composed by [`Scheduler::tick`]:
//!   * [`Scheduler::admit`] — pull a same-shape-bucket batch off the queue
//!     (compile-warm buckets preferred) and apply admission control: a
//!     request is admitted only if projected KV memory (existing live bytes
//!     + its budget + one uncompressed layer) fits the configured limit.
//!     Requests that do not fit *now* are requeued at their original FIFO
//!     position with their original id; requests that can *never* fit are
//!     rejected with an explicit error result (no livelock).
//!   * [`Scheduler::prefill_batch`] — run Algorithm 2 prefill for each
//!     admitted request. With no hot-tier limit, the batch members fan out
//!     across the worker pool (prefills are per-session independent); under
//!     a limit they prefill sequentially, because admission's peak check
//!     budgets exactly one transient uncompressed layer at a time.
//!   * [`Scheduler::decode_round`] — one decode step per active session,
//!     in two stages. **Plan** (serving thread, worker-count independent):
//!     fully-hot sessions are packed into capacity-bucket groups (equal
//!     `Session::capacity_signature`; singleton units with `batched_decode`
//!     off) and sessions needing tier I/O go to a sequential arm. **Run**:
//!     the planned units fan out across the [`WorkerPool`] — different
//!     bucket groups decode concurrently against the shared backend — then
//!     the sequential arm steps in order with tier fetches. Because every
//!     decision is made before the fan-out, results are bit-identical at
//!     any worker count (`tests/sharded_decode.rs` enforces it).
//!
//! Prefill admission is attempted every `prefill_every` ticks (bounds TTFT
//! without starving decodes — the standard continuous-batching compromise).
//! One request id, assigned by the batcher at `submit`, names the request
//! end-to-end: queue entry, session, and `GenerateResult`.
//!
//! ## Worker pool dataflow
//!
//! All three fan-outs (prefill batches, stream lockstep groups, decode
//! round units) run on one persistent [`WorkerPool`], built once at
//! [`Scheduler::new`] and joined on drop — no per-tick thread spawns. A
//! round is: **submit** the planned units into the pool's injector (an
//! atomic cursor over the plan), wake the parked workers, and let each
//! worker **pull** the next un-taken unit whenever it finishes one —
//! dynamic load balancing, so an imbalanced plan (one fat bucket group +
//! many small ones) never idles a worker behind a static chunk. Every
//! worker owns a `WorkerContext` — stable id, pinned backend device slot,
//! reusable score/dequant scratch — threaded into each engine call. Results
//! are written back into pre-sized **slots by unit index**, so merge order
//! is plan order and outputs are bit-identical at every width and in both
//! pool modes (`SchedulerOptions::pool_mode`; `LAVA_POOL=scoped` keeps the
//! legacy scoped fan-out as the equivalence oracle). A unit that panics
//! poisons only itself: its request fails with an explicit result
//! ([`Scheduler::fail_lost`]) and the round's other units keep serving.
//! Serial arms (width 1, tiered decode, budgeted chunked advances) run the
//! same engine calls under the pool's serving-thread context
//! (`WorkerPool::with_serial_ctx`), so scratch reuse and device binding
//! behave identically on and off the pool.
//!
//! ## KV tiering and the tier thread
//!
//! With `tiering` on (the default), `kv_mem_limit` bounds only the *hot*
//! tier. The scheduler owns a [`TierClient`] and drives both transitions of
//! the residency state machine; the Q8 quantize/dequantize itself runs on
//! the client's background tier thread, off the serving path:
//!
//! * **Spill** — when admission would defer a request for memory, idle
//!   active sessions' lowest-LAVa-weight layers (smallest per-layer budget
//!   from Algorithm 2) are handed to the tier thread, so the request is
//!   admitted instead of deferred. The serving thread only takes the
//!   buffers; quantization overlaps subsequent decode work.
//! * **Prefetch** — at round planning, every spilled layer of a
//!   sequential-arm session gets a *prefetch-ahead* hint, so the tier
//!   thread rehydrates it while the parallel stage decodes (and, for next
//!   round's sessions, while this round finishes — double buffering). The
//!   blocking fetch right before the session's step then mostly finds the
//!   staged result. The engine still only ever sees hot caches.
//!
//! ## Incremental hot-byte accounting
//!
//! `kv_mem_limit` decisions read a single counter, maintained at every
//! transition (prefill admit, decode append/evict via check-out/check-in
//! around the engine step, spill, fetch, retire) instead of re-walking
//! every session × layer per tick; `live_kv_bytes` debug-asserts the
//! counter against the full walk at stable points.
//!
//! The hot-tier bound holds whenever `kv_mem_limit` covers any single
//! session's retained bytes plus its decode growth
//! (`max_new_tokens * n_layers * n_kv_heads * d_head * 8`): a decoding
//! session must be fully resident, so only *other* sessions are spill
//! victims. This is the same per-session headroom the admission contract
//! already assumed before tiering (decode growth was never part of
//! `projected_bytes`).
//!
//! ## Chunked prefill (`prefill_chunk`)
//!
//! With `prefill_chunk` set, admission installs the engine's resumable
//! chunked-prefill state machine (`EngineWorker::begin_chunked_prefill` /
//! `advance_chunked_prefill`) instead of running a monolithic prefill:
//! every chunk dispatches at its own *tight* prefill bucket, carry-in K/V
//! and window observations accumulate per layer, and Algorithm 2 runs on
//! each completed layer exactly as the monolithic path — tokens, per-layer
//! budgets, and keep-sets are bit-identical at every chunk size. Prompts
//! longer than the largest prefill bucket become servable (the batcher
//! files them under its largest bucket). With `prefill_chunk_budget` also
//! set, mid-prefill sessions live in `prefilling` and advance at most that
//! many prompt tokens per tick *after* the decode round, so a long prompt
//! no longer head-of-line-blocks the inter-token latency of active
//! decodes. Mid-prefill sessions hold admission slots and reserve their
//! full projected bytes ([`Scheduler::prefilling_reserved_bytes`]), stay
//! out of the incremental `hot_bytes` counter until their first token, and
//! are never spill victims.
//!
//! ## Streaming prefill compression (`prefill_stream_evict`)
//!
//! With `prefill_stream_evict` also set, admission routes chunk-servable
//! prompts through the engine's streaming state machine
//! (`EngineWorker::begin_chunked_prefill_stream`): after every non-final
//! chunk the layer's live columns are LAVa-scored (trailing window pinned)
//! and evicted down to the per-head budget union, so each carry lane is
//! bounded by the fixed working cap `hk·max(budget, w) + chunk bucket + w`
//! columns regardless of prompt length. The default order is *chunk-major*:
//! each chunk runs through all L layers in one pass, every layer keeps its
//! own bounded lane, and the hidden-state rows shrink to one chunk — so
//! the *whole* prefill resident set (carries + observation panels + hidden
//! rows) is flat in prompt length, and admission prices it that way: the
//! transient term in [`Scheduler::projected_bytes`] becomes
//! prompt-length-independent, so long prompts that could never prefill
//! under a tight `kv_mem_limit` become admissible at a fixed cost.
//! `EngineOptions::stream_layer_major` keeps the PR 8 layer-major order
//! (one lane reset between layers, but O(prompt) hidden rows);
//! `EngineOptions::carry_q8` Q8-quantizes the chunk-major lanes between
//! dispatches, roughly halving their bytes for one shared f32
//! dequantization scratch. The trade is explicit: mid-prefill eviction
//! sees only the tokens so far, so tokens and keep-sets are *not*
//! bit-identical to the monolithic pass (the keep-set overlap on retrieval
//! workloads is regression-tested in the engine); prompts whose chunk
//! shapes have no evict support fall back to the plain chunked path per
//! request.
//!
//! Mid-stream sessions also batch *across sessions*: each
//! [`Scheduler::advance_prefills`] round groups `prefilling` sessions by
//! their lockstep key (layer, chunk cursor, chunk shape, cap), fans the
//! groups over the worker pool, and advances every group member through
//! batched backend dispatches (`advance_stream_group`; one dispatch per
//! pass layer-major, one per layer per pass chunk-major) — the prefill
//! analogue of batched decode, counted by the `prefill_chunk_batches` /
//! `prefill_chunk_dispatches` metrics.

use std::collections::VecDeque;
use std::fmt;

use anyhow::{anyhow, Result};

use super::batcher::{Batcher, QueuedRequest};
use super::engine::{Engine, FinishStatus, GenerateRequest, GenerateResult, PrefillReport};
use super::metrics::{Metrics, MetricsSnapshot};
use super::pool::{PoolMode, WorkerPool};
use super::session::Session;
use crate::kvcache::tier::{Residency, TierClient};
use crate::model::backend::ModelBackend;
use crate::runtime::Runtime;

#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    /// Cap on total live KV bytes across sessions (None = unlimited).
    pub kv_mem_limit: Option<usize>,
    /// Max concurrently decoding sessions.
    pub max_active: usize,
    /// Attempt one prefill admission every this many ticks.
    pub prefill_every: usize,
    /// Max prefills admitted as one same-bucket batch per admission round
    /// (1 = the old one-at-a-time behavior).
    pub max_prefill_batch: usize,
    /// Backpressure: refuse new submissions once the oldest queued request
    /// has waited longer than this (None = accept until memory runs out).
    pub max_queue_wait_secs: Option<f64>,
    /// Hot/warm KV tiering: under memory pressure, spill idle sessions'
    /// lowest-weight layers to Q8 warm blocks instead of deferring
    /// admission, and prefetch them back before decode. With this off,
    /// `kv_mem_limit` reverts to the old defer-or-reject behavior.
    pub tiering: bool,
    /// Batched decode: group fully-hot active sessions by capacity bucket
    /// and advance each group with one `layer_decode_batched` dispatch per
    /// layer. Off reverts to one dispatch per session per layer (kept for
    /// the bench comparison and as an escape hatch).
    pub batched_decode: bool,
    /// Engine worker threads the decode/prefill fan-out may use (1 = fully
    /// serial on the scheduling thread). Read at [`Scheduler::new`]. The
    /// default honors `LAVA_WORKERS` (CI pins 1 to flush nondeterminism)
    /// and otherwise uses min(cores, 4). Results are bit-identical at
    /// every width — all decisions are planned before the fan-out — only
    /// wall time changes.
    pub workers: usize,
    /// Chunked prefill: split every prompt's prefill into chunks of this
    /// many tokens, each dispatched at its own *tight* prefill bucket
    /// (`None` = the old monolithic one-bucket prefill). Makes prompts
    /// beyond the largest prefill bucket servable. Tokens, budgets, and
    /// keep-sets are bit-identical to monolithic at every chunk size —
    /// only dispatch shapes and scheduling change. The default honors
    /// `LAVA_PREFILL_CHUNK` (unset or 0 = off).
    pub prefill_chunk: Option<usize>,
    /// Decode-interleaved chunked prefill: advance at most this many
    /// tokens of prefill work per tick (one chunk through one layer counts
    /// its chunk length), *after* the decode round, so long prompts do not
    /// head-of-line-block active decodes. `None` = finish each admitted
    /// prefill within its admission tick (chunked compute, monolithic
    /// scheduling); 0 is treated as 1 so mid-prefill sessions always make
    /// progress. Ignored without `prefill_chunk`.
    pub prefill_chunk_budget: Option<usize>,
    /// Streaming prefill compression: score and evict mid-prefill after
    /// every chunk, bounding the per-layer carry K/V to a fixed working cap
    /// (budget union + one chunk + window) instead of O(prompt), and
    /// advance same-shape mid-stream sessions through one batched backend
    /// dispatch (cross-session chunk batching). Results are *not*
    /// bit-identical to monolithic prefill — eviction decisions see only
    /// the prompt so far — so this is opt-in. Prompts the backend has no
    /// evict shapes for fall back to the plain chunked path per request.
    /// Ignored without `prefill_chunk`. The default honors
    /// `LAVA_PREFILL_STREAM` (unset or 0 = off).
    pub prefill_stream_evict: bool,
    /// Which dispatcher the worker pool uses: the persistent spawn-free
    /// pool (the default) or the legacy per-round `std::thread::scope`
    /// fan-out kept as the bit-equivalence oracle. Results are identical
    /// in both modes at every width; only dispatch overhead changes. The
    /// default honors `LAVA_POOL` (CI runs the suite once more with
    /// `scoped`).
    pub pool_mode: PoolMode,
}

fn default_workers() -> usize {
    let auto = crate::util::par::max_threads().min(4);
    match std::env::var("LAVA_WORKERS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(w) if w >= 1 => w,
            // an unparsable or zero override must not silently serialize
            // the pool: warn and keep the cores default
            _ => {
                eprintln!("[lava] ignoring invalid LAVA_WORKERS={v:?}; using {auto}");
                auto
            }
        },
        Err(_) => auto,
    }
}

/// `LAVA_PREFILL_CHUNK` override for [`SchedulerOptions::prefill_chunk`]
/// (CI runs the suite a second time with it set to exercise the chunked
/// path everywhere). Unset or `0` leaves chunking off; an unparsable value
/// warns and stays off rather than silently changing serving behavior.
fn default_prefill_chunk() -> Option<usize> {
    match std::env::var("LAVA_PREFILL_CHUNK") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(0) => None,
            Ok(c) => Some(c),
            Err(_) => {
                eprintln!("[lava] ignoring invalid LAVA_PREFILL_CHUNK={v:?}; chunking stays off");
                None
            }
        },
        Err(_) => None,
    }
}

/// `LAVA_PREFILL_STREAM` override for
/// [`SchedulerOptions::prefill_stream_evict`] (CI runs the suite once more
/// with it set to exercise the streaming path everywhere). Unset or `0`
/// leaves streaming off; an unparsable value warns and stays off rather
/// than silently changing serving results.
fn default_prefill_stream() -> bool {
    match std::env::var("LAVA_PREFILL_STREAM") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(0) => false,
            Ok(_) => true,
            Err(_) => {
                eprintln!(
                    "[lava] ignoring invalid LAVA_PREFILL_STREAM={v:?}; streaming stays off"
                );
                false
            }
        },
        Err(_) => false,
    }
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            kv_mem_limit: None,
            max_active: 8,
            prefill_every: 4,
            max_prefill_batch: 4,
            max_queue_wait_secs: None,
            tiering: true,
            batched_decode: true,
            workers: default_workers(),
            prefill_chunk: default_prefill_chunk(),
            prefill_chunk_budget: None,
            prefill_stream_evict: default_prefill_stream(),
            pool_mode: PoolMode::from_env(),
        }
    }
}

/// Why `submit` refused a request (queue state is unchanged on refusal).
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// Prompt exceeds the largest prefill shape bucket.
    PromptTooLong { len: usize },
    /// Projected KV for this request alone exceeds `kv_mem_limit`.
    OverMemoryLimit { projected: usize, limit: usize },
    /// Backpressure: the queue is already missing its wait SLO.
    QueueSaturated { oldest_wait_secs: f64 },
    /// The serving loop is draining for shutdown and takes no new work.
    ShuttingDown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::PromptTooLong { len } => {
                write!(f, "prompt length {len} exceeds the largest prefill bucket")
            }
            SubmitError::OverMemoryLimit { projected, limit } => write!(
                f,
                "projected KV bytes {projected} exceed kv_mem_limit {limit}: can never be admitted"
            ),
            SubmitError::QueueSaturated { oldest_wait_secs } => write!(
                f,
                "queue saturated: oldest request has waited {oldest_wait_secs:.3}s"
            ),
            SubmitError::ShuttingDown => {
                write!(f, "server shutting down: submissions are no longer accepted")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// How many consecutive admission rounds may jump the queue head for a
/// compile-warm bucket before the head is served unconditionally. Bounds
/// cross-bucket starvation: a queued request is bypassed at most this many
/// rounds before its bucket becomes the batch seed.
const MAX_WARM_BYPASS_ROUNDS: usize = 4;

/// One planned unit of a decode round, owned by exactly one worker during
/// the fan-out.
enum RoundUnit {
    /// A capacity-bucket group advanced through the batched decode path.
    Group(Vec<Session>),
    /// A single session advanced through the serial decode path
    /// (`batched_decode` off).
    One(Session),
}

impl RoundUnit {
    fn sessions(&self) -> &[Session] {
        match self {
            RoundUnit::Group(g) => g,
            RoundUnit::One(s) => std::slice::from_ref(s),
        }
    }

    fn into_sessions(self) -> Vec<Session> {
        match self {
            RoundUnit::Group(g) => g,
            RoundUnit::One(s) => vec![s],
        }
    }
}

/// What one [`Scheduler::tick`] produced, for incremental drivers (the
/// serving loop): every token generated this round tagged with its request
/// id, and every request that reached a terminal state. Batch drivers can
/// ignore it — [`Scheduler::run_to_completion`] accumulates the finished
/// results across ticks itself.
#[derive(Debug, Default)]
pub struct TickReport {
    /// True if the tick admitted, prefilled, decoded, or parked anything.
    pub worked: bool,
    /// `(request id, token)` pairs in the order the tokens were produced
    /// this tick (prefill first tokens, then the decode round's).
    pub tokens: Vec<(u64, i32)>,
    /// Requests that reached a terminal result this tick, in completion
    /// order — including results parked since the previous tick (e.g. a
    /// cancel of a queued request).
    pub finished: Vec<(u64, GenerateResult)>,
}

pub struct Scheduler<B: ModelBackend> {
    pub engine: Engine<B>,
    pub queue: Batcher,
    pub opts: SchedulerOptions,
    /// Hot/warm residency client (bookkeeping here, Q8 work on its thread).
    pub tier: TierClient,
    /// Engine worker pool the decode/prefill fan-out runs on.
    pub pool: WorkerPool,
    active: VecDeque<Session>,
    /// Mid-prefill sessions of the decode-interleaved chunked path: begun
    /// at admission, advanced after each decode round, moved to `active`
    /// (or retired) when the first token lands. Always empty without
    /// `prefill_chunk` + `prefill_chunk_budget`.
    prefilling: VecDeque<Session>,
    finished: Vec<(u64, GenerateResult)>,
    /// `(id, token)` pairs produced since the last tick drained them.
    token_events: Vec<(u64, i32)>,
    tick: usize,
    /// Bucket of the most recent prefill: its executable is compile-warm,
    /// so admission prefers queued requests sharing it.
    warm_bucket: Option<usize>,
    /// Consecutive admission rounds in which warm preference bypassed an
    /// older request at the queue head.
    warm_bypass_streak: usize,
    /// The queue head was deferred for memory: suspend warm preference so
    /// freed memory goes to the oldest request, not younger warm-bucket
    /// arrivals (unbounded-TTFT starvation otherwise).
    head_memory_blocked: bool,
    /// Incremental Σ hot KV bytes over all owned sessions, updated at every
    /// transition (debug-asserted against the full walk in
    /// [`Scheduler::live_kv_bytes`]).
    hot_bytes: usize,
}

impl<B: ModelBackend> Scheduler<B> {
    pub fn new(engine: Engine<B>, opts: SchedulerOptions) -> Scheduler<B> {
        let queue = Batcher::new(engine.backend.prefill_buckets());
        let pool = WorkerPool::with_mode(opts.workers, opts.pool_mode);
        Scheduler {
            engine,
            queue,
            opts,
            tier: TierClient::spawn(),
            pool,
            active: VecDeque::new(),
            prefilling: VecDeque::new(),
            finished: Vec::new(),
            token_events: Vec::new(),
            tick: 0,
            warm_bucket: None,
            warm_bypass_streak: 0,
            head_memory_blocked: false,
            hot_bytes: 0,
        }
    }

    /// Enqueue a request; the returned id is the one its `GenerateResult`
    /// will carry, no matter how often admission defers it.
    pub fn submit(&mut self, req: GenerateRequest) -> Result<u64, SubmitError> {
        // keep the batcher's oversize policy in sync with the chunking knob
        // (opts are public and may be flipped between submissions)
        self.queue.set_allow_oversize(self.opts.prefill_chunk.is_some());
        if let Some(limit) = self.opts.kv_mem_limit {
            let projected = self.projected_bytes(req.prompt.len());
            if projected > limit {
                self.engine.metrics.requests_rejected += 1;
                return Err(SubmitError::OverMemoryLimit { projected, limit });
            }
        }
        if let Some(max_wait) = self.opts.max_queue_wait_secs {
            let oldest_wait_secs = self.queue.oldest_wait_secs();
            if oldest_wait_secs > max_wait {
                self.engine.metrics.requests_rejected += 1;
                return Err(SubmitError::QueueSaturated { oldest_wait_secs });
            }
        }
        let len = req.prompt.len();
        match self.queue.push(req) {
            Some(id) => Ok(id),
            None => {
                self.engine.metrics.requests_rejected += 1;
                Err(SubmitError::PromptTooLong { len })
            }
        }
    }

    /// Cancel a request by id: dequeues it if still waiting, or retires the
    /// session mid-decode with whatever it generated so far. Returns false
    /// for unknown / already-finished ids.
    pub fn cancel(&mut self, id: u64) -> bool {
        if self.queue.remove(id).is_some() {
            self.engine.metrics.requests_canceled += 1;
            self.finished.push((
                id,
                GenerateResult {
                    id,
                    status: FinishStatus::Canceled,
                    error: Some("canceled while queued".to_string()),
                    tokens: vec![],
                    prefill_secs: 0.0,
                    decode_secs: 0.0,
                    kv_bytes_after_prefill: 0,
                    peak_kv_bytes: self.engine.metrics.peak_kv_bytes,
                    budgets: vec![],
                },
            ));
            return true;
        }
        if let Some(pos) = self.prefilling.iter().position(|s| s.id == id) {
            let mut sess = self.prefilling.remove(pos).expect("position just found");
            // Drop the fat mid-prefill state right now: the carry K/V,
            // hidden-state rows, and any partially compressed layers are
            // dead the moment the cancel lands, and none of it was ever
            // checked into `hot_bytes` — the result must report zero
            // retained bytes, not a half-built cache.
            sess.prefill = None;
            sess.caches.clear();
            sess.residency.clear();
            self.retire_unaccounted(
                sess,
                FinishStatus::Canceled,
                Some("canceled mid-prefill".to_string()),
            );
            return true;
        }
        if let Some(pos) = self.active.iter().position(|s| s.id == id) {
            let sess = self.active.remove(pos).expect("position just found");
            self.retire(sess, FinishStatus::Canceled, Some("canceled mid-decode".to_string()));
            return true;
        }
        false
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Sessions mid-chunked-prefill (admitted, no first token yet).
    pub fn prefilling_count(&self) -> usize {
        self.prefilling.len()
    }

    pub fn pending_count(&self) -> usize {
        self.queue.len()
    }

    /// Ids of every session the scheduler owns outside the queue: decoding
    /// sessions in round order, then mid-prefill (chunked) sessions.
    pub fn active_ids(&self) -> Vec<u64> {
        self.active
            .iter()
            .map(|s| s.id)
            .chain(self.prefilling.iter().map(|s| s.id))
            .collect()
    }

    /// Current hot KV bytes: the incremental counter, debug-asserted
    /// against the full session × layer walk it replaced. Call only at
    /// stable points (every owned session back in `active`).
    fn live_kv_bytes(&self) -> usize {
        debug_assert_eq!(
            self.hot_bytes,
            self.active.iter().map(|s| s.kv_bytes()).sum::<usize>(),
            "incremental hot-bytes counter drifted from the session walk"
        );
        self.hot_bytes
    }

    /// Bytes a request's compressed caches hold after prefill (its budget).
    /// Public so benches/tests can calibrate `kv_mem_limit` from the same
    /// accounting admission uses instead of re-deriving the formulas.
    pub fn retained_bytes(&self, prompt_len: usize) -> usize {
        let cfg = self.engine.config();
        let budget_entries =
            self.engine.opts.budget_per_head * cfg.n_kv_heads * cfg.n_layers;
        budget_entries.min(prompt_len * cfg.n_kv_heads * cfg.n_layers) * cfg.d_head * 2 * 4
    }

    /// Bytes of the full prefill working set live *during* prefill only:
    /// carry K/V, observation panels (attention mass, window rows, value
    /// norms, positions), and hidden-state rows — everything the engine
    /// measures into `PrefillReport::resident_peak_bytes` beyond the
    /// retained caches. Path-dependent:
    ///
    /// * plain chunked / monolithic — one O(prompt) uncompressed layer
    ///   plus O(prompt) panels and hidden rows;
    /// * layer-major streaming (`stream_layer_major`) — one lane bounded
    ///   at the working cap, but still O(prompt) hidden rows;
    /// * chunk-major streaming (the streaming default) — L lanes bounded
    ///   at the cap plus one chunk of hidden rows: flat in prompt length.
    ///   With `carry_q8` the lanes shrink to int8 codes + scales (the f32
    ///   dequantization buffer lives on the executing worker's context,
    ///   amortized across every session, so it is not priced per request).
    ///
    /// Per-column constants mirror the engine's stream-lane accounting;
    /// the chunk/prefill *buckets* are approximated by the configured
    /// chunk and prompt length (pricing, not measurement).
    fn transient_bytes(&self, prompt_len: usize) -> usize {
        let cfg = self.engine.config();
        let (h, hk, dh, d) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model);
        // f32 carry K/V per live column, and the observation panels per
        // column (acc `[H]`, up to w window rows `[H]`, vnorm `[Hk]`, pos)
        let carry_col = 2 * hk * dh * 4;
        let panel_col = (h * (cfg.window + 1) + hk + 1) * 4;
        let streamed_cap = match (self.opts.prefill_stream_evict, self.opts.prefill_chunk) {
            (true, Some(chunk)) => self
                .engine
                .worker()
                .stream_evict_cap(prompt_len, chunk)
                .map(|cap| cap.min(prompt_len)),
            _ => None,
        };
        match streamed_cap {
            Some(cap) if !self.engine.opts.stream_layer_major => {
                let chunk_rows = self.opts.prefill_chunk.unwrap_or(0).min(prompt_len);
                let lane_carry = if self.engine.opts.carry_q8 {
                    2 * hk * cap * (dh + 4)
                } else {
                    cap * carry_col
                };
                cfg.n_layers * (lane_carry + cap * panel_col) + 2 * chunk_rows * d * 4
            }
            Some(cap) => cap * (carry_col + panel_col) + 2 * prompt_len * d * 4,
            None => prompt_len * (carry_col + panel_col) + 2 * prompt_len * d * 4,
        }
    }

    /// Peak bytes a request needs while prefilling: retained caches plus
    /// the full transient working set ([`Scheduler::transient_bytes`]).
    /// Public for the same calibration reason as
    /// [`Scheduler::retained_bytes`].
    pub fn projected_bytes(&self, prompt_len: usize) -> usize {
        self.retained_bytes(prompt_len) + self.transient_bytes(prompt_len)
    }

    /// Bytes admission must hold back for mid-prefill (chunked) sessions:
    /// their caches stay out of `hot_bytes` until the first token, so each
    /// reserves its full projected footprint (retained budget + the
    /// transient working set, which is O(prompt) even under plain chunking
    /// — chunking shrinks the dispatch working set, not the per-layer
    /// carry or the hidden rows. Chunk-major streaming eviction is what
    /// makes the whole working set flat, and
    /// [`Scheduler::transient_bytes`] prices each path accordingly).
    fn prefilling_reserved_bytes(&self) -> usize {
        self.prefilling.iter().map(|s| self.projected_bytes(s.prompt.len())).sum()
    }

    /// Admission step: pull up to one same-bucket batch off the queue and
    /// split it into admitted requests (returned, in FIFO order), deferred
    /// requests (requeued at their original position, same id), and
    /// impossible requests (rejected with an error result).
    pub fn admit(&mut self) -> Vec<QueuedRequest> {
        let slots = self
            .opts
            .max_active
            .saturating_sub(self.active.len() + self.prefilling.len());
        if slots == 0 || self.queue.is_empty() {
            return vec![];
        }
        let k = slots.min(self.opts.max_prefill_batch).max(1);

        // Prefer the compile-warm bucket when it has queued work, but never
        // bypass the queue head more than MAX_WARM_BYPASS_ROUNDS rounds in a
        // row, and not at all while the head is blocked on memory —
        // otherwise a steady stream of warm-bucket traffic starves other
        // buckets (and, with max_queue_wait_secs set, the starved head would
        // shed all new load).
        let head_bucket = self.queue.front_bucket();
        let batch = match self.warm_bucket {
            Some(b)
                if !self.head_memory_blocked
                    && self.queue.has_bucket(b)
                    && (head_bucket == Some(b)
                        || self.warm_bypass_streak < MAX_WARM_BYPASS_ROUNDS) =>
            {
                if head_bucket == Some(b) {
                    self.warm_bypass_streak = 0;
                } else {
                    self.warm_bypass_streak += 1;
                }
                self.queue.pop_batch_preferring(b, k)
            }
            _ => {
                self.warm_bypass_streak = 0;
                self.queue.pop_batch(k)
            }
        };
        // is this batch seeded by the true queue head?
        let head_seeded = batch.first().map(|q| Some(q.bucket) == head_bucket).unwrap_or(false);
        let head_seed_id = batch.first().map(|q| q.id);

        let mut admitted: Vec<QueuedRequest> = Vec::new();
        let mut deferred: Vec<QueuedRequest> = Vec::new();
        // The batch prefills with at most one transient uncompressed layer
        // resident under a memory limit (the parallel prefill arm is gated
        // on limit-free runs), so peak-check each request, then accumulate
        // only its retained bytes. With tiering, "memory" means hot-tier
        // bytes: spilling idle layers lowers `projected` and rescues the
        // admission.
        let mut projected = self.live_kv_bytes() + self.prefilling_reserved_bytes();
        for q in batch {
            let len = q.request.prompt.len();
            let peak = self.projected_bytes(len);
            match self.opts.kv_mem_limit {
                // a request that can never fit even with every other session
                // fully spilled must not spin in the queue
                Some(limit) if peak > limit => {
                    let reason = format!(
                        "projected KV bytes {peak} exceed kv_mem_limit {limit}: rejected"
                    );
                    self.park_queued(q, FinishStatus::Rejected, reason);
                }
                Some(limit) => {
                    let mut over = (projected + peak).saturating_sub(limit);
                    if over > 0 && self.opts.tiering && deferred.is_empty() {
                        // spill-aware deferral: dehydrate idle sessions'
                        // lowest-weight layers before giving up the slot —
                        // but only when spilling can actually cover the
                        // shortfall, else a futile full spill would be
                        // prefetched right back next decode round (churn)
                        if self.live_kv_bytes() >= over {
                            let freed = self.spill_active_until(over);
                            projected = projected.saturating_sub(freed);
                            over = (projected + peak).saturating_sub(limit);
                        }
                    }
                    // once one request defers, defer the rest of the batch
                    // too: a younger request must not overtake an older one
                    // that was only short on memory (FIFO fairness)
                    if over > 0 || !deferred.is_empty() {
                        self.engine.metrics.observe_deferral();
                        deferred.push(q);
                    } else {
                        projected += self.retained_bytes(len);
                        admitted.push(q);
                    }
                }
                None => {
                    projected += self.retained_bytes(len);
                    admitted.push(q);
                }
            }
        }
        // If the oldest request itself was just deferred for memory, freeze
        // warm preference until a head-seeded round admits (or rejects) it —
        // freed memory must reach the head, not younger warm arrivals.
        if head_seeded {
            self.head_memory_blocked = head_seed_id
                .map(|id| deferred.iter().any(|q| q.id == id))
                .unwrap_or(false);
        }
        for q in deferred.into_iter().rev() {
            self.queue.requeue(q);
        }
        self.engine.metrics.admission_rounds += 1;
        admitted
    }

    /// Prefill every admitted request (they share a shape bucket, so after
    /// the first the executable is compile-warm). With no hot-tier limit,
    /// the batch fans out across the worker pool — prefills are per-session
    /// independent; under a limit it runs sequentially, because admission
    /// budgets exactly one transient uncompressed layer at a time. A
    /// per-request prefill failure parks that request with an error result
    /// instead of poisoning the serving loop.
    pub fn prefill_batch(&mut self, batch: Vec<QueuedRequest>) -> Result<usize> {
        if batch.is_empty() {
            return Ok(0);
        }
        let mut done = 0;
        if self.opts.prefill_chunk.is_some() {
            // Chunked serving routes each request individually: chunk
            // dispatches use their own tight buckets (not the batch's
            // bucket), and unsupported chunk shapes fall back to the
            // monolithic path per request.
            for q in batch {
                done += self.prefill_one_chunked(q);
            }
            return Ok(done);
        }
        if batch.len() > 1 && self.pool.workers() > 1 && self.opts.kv_mem_limit.is_none() {
            // fan out, then merge in submission order so metrics,
            // retirement, and the active queue are identical to the
            // sequential arm
            let units: Vec<(QueuedRequest, f64, Session)> = batch
                .into_iter()
                .map(|q| {
                    let wait_secs = q.enqueued_at.elapsed().as_secs_f64();
                    let sess = self.engine.new_session_with_id(q.id, &q.request);
                    (q, wait_secs, sess)
                })
                .collect();
            // a panicking unit drops its request + session in the unwind;
            // only the id survives to name the failure result
            let ids: Vec<u64> = units.iter().map(|(q, _, _)| q.id).collect();
            let worker = self.engine.worker();
            let (results, stats) = self.pool.run(units, |ctx, (q, wait_secs, mut sess)| {
                let res = worker.prefill(ctx, &mut sess);
                (q, wait_secs, sess, res)
            });
            self.engine.metrics.observe_worker_round(self.pool.workers(), &stats);
            for (id, unit) in ids.into_iter().zip(results) {
                match unit {
                    Ok((q, wait_secs, sess, res)) => {
                        done += self.merge_prefill(q, wait_secs, sess, res);
                    }
                    Err(reason) => self.fail_lost(id, &reason),
                }
            }
        } else {
            for q in batch {
                let wait_secs = q.enqueued_at.elapsed().as_secs_f64();
                let mut sess = self.engine.new_session_with_id(q.id, &q.request);
                let worker = self.engine.worker();
                let res = self.pool.with_serial_ctx(|ctx| worker.prefill(ctx, &mut sess));
                done += self.merge_prefill(q, wait_secs, sess, res);
            }
        }
        Ok(done)
    }

    /// Admit one request through the chunked-prefill state machine, with a
    /// per-request monolithic fallback when the backend cannot serve its
    /// chunk shapes. Without `prefill_chunk_budget` the prefill is driven
    /// to completion right here — the monolithic path's tick placement,
    /// chunked compute. With a budget only the cheap `begin` (embedding +
    /// state install) happens now; [`Scheduler::advance_prefills`] does the
    /// layer work *after* each decode round. Returns 1 when the request was
    /// started or finished successfully.
    fn prefill_one_chunked(&mut self, q: QueuedRequest) -> usize {
        let chunk = self.opts.prefill_chunk.expect("chunked admission requires prefill_chunk");
        let len = q.request.prompt.len();
        if !self.engine.worker().chunked_prefill_supported(len, chunk) {
            if Runtime::pick_bucket(self.engine.backend.prefill_buckets(), len).is_none() {
                // over-bucket prompts are servable only through chunks
                self.park_queued(
                    q,
                    FinishStatus::Rejected,
                    format!(
                        "prompt length {len} exceeds the largest prefill bucket and the \
                         backend has no chunked prefill for its chunk shapes"
                    ),
                );
                return 0;
            }
            let wait_secs = q.enqueued_at.elapsed().as_secs_f64();
            let mut sess = self.engine.new_session_with_id(q.id, &q.request);
            let worker = self.engine.worker();
            let res = self.pool.with_serial_ctx(|ctx| worker.prefill(ctx, &mut sess));
            return self.merge_prefill(q, wait_secs, sess, res);
        }
        let wait_secs = q.enqueued_at.elapsed().as_secs_f64();
        let mut sess = self.engine.new_session_with_id(q.id, &q.request);
        // streaming eviction is best-effort per request: prompts whose chunk
        // shapes have no evict support take the plain chunked path instead
        let stream = self.opts.prefill_stream_evict
            && self.engine.worker().stream_evict_cap(len, chunk).is_some();
        if self.opts.prefill_chunk_budget.is_none() {
            let worker = self.engine.worker();
            let begun = if stream {
                worker.begin_chunked_prefill_stream(&mut sess, chunk)
            } else {
                worker.begin_chunked_prefill(&mut sess, chunk)
            };
            let res = begun.and_then(|()| {
                let (_, report) = self
                    .pool
                    .with_serial_ctx(|ctx| worker.advance_chunked_prefill(ctx, &mut sess, None))?;
                report.ok_or_else(|| anyhow!("unbounded advance must complete the prefill"))
            });
            return self.merge_prefill(q, wait_secs, sess, res);
        }
        let begun = if stream {
            self.engine.worker().begin_chunked_prefill_stream(&mut sess, chunk)
        } else {
            self.engine.worker().begin_chunked_prefill(&mut sess, chunk)
        };
        match begun {
            Ok(()) => {
                if let Some(st) = sess.prefill.as_mut() {
                    st.wait_secs = wait_secs;
                }
                self.warm_bucket = Some(q.bucket);
                self.prefilling.push_back(sess);
                1
            }
            Err(e) => {
                drop(sess);
                self.park_queued(q, FinishStatus::Failed, format!("prefill failed: {e:#}"));
                0
            }
        }
    }

    /// Advance every mid-prefill session, front of the queue first, within
    /// this tick's shared `prefill_chunk_budget` (at least one chunk always
    /// dispatches, so progress is guaranteed). Runs *after* the decode
    /// round — see [`Scheduler::tick`]. A session whose final chunk lands
    /// gets its first token merged exactly as [`Scheduler::merge_prefill`]
    /// does: metrics, token event, hot-byte check-in, retire-or-activate.
    /// Returns the prefill tokens advanced.
    fn advance_prefills(&mut self) -> usize {
        if self.prefilling.is_empty() {
            return 0;
        }
        let mut budget = self.opts.prefill_chunk_budget.unwrap_or(usize::MAX).max(1);
        let mut advanced = 0usize;
        // Split the round: mid-stream sessions advance in lockstep groups
        // (one batched backend dispatch per group — cross-session chunk
        // batching), everything else through the serial loop below.
        let mut stream: Vec<Session> = Vec::new();
        let mut rest: VecDeque<Session> = VecDeque::new();
        while let Some(sess) = self.prefilling.pop_front() {
            if self.engine.worker().stream_lockstep_key(&sess).is_some() {
                stream.push(sess);
            } else {
                rest.push_back(sess);
            }
        }
        while !stream.is_empty() && budget > 0 {
            let (survivors, worked) = self.advance_stream_round(stream);
            stream = survivors;
            advanced += worked;
            budget = budget.saturating_sub(worked);
            if worked == 0 {
                // every group errored out this round; survivors is empty,
                // but never risk spinning here
                break;
            }
        }
        self.prefilling = rest;
        let mut still: VecDeque<Session> = VecDeque::new();
        while let Some(mut sess) = self.prefilling.pop_front() {
            if budget == 0 {
                still.push_back(sess);
                continue;
            }
            let (wait_secs, admitted_at) = sess
                .prefill
                .as_ref()
                .map(|st| (st.wait_secs, st.enqueued_at))
                .unwrap_or((0.0, sess.queued_at));
            let worker = self.engine.worker();
            let res = self.pool.with_serial_ctx(|ctx| {
                worker.advance_chunked_prefill(ctx, &mut sess, Some(budget))
            });
            match res {
                Ok((worked, report)) => {
                    budget = budget.saturating_sub(worked);
                    advanced += worked;
                    match report {
                        Some(report) => {
                            self.engine.absorb_prefill(&report);
                            // TTFT spans the decode rounds interleaved
                            // between advances: measure admission → now
                            let ttft = wait_secs + admitted_at.elapsed().as_secs_f64();
                            self.engine.metrics.observe_admission(wait_secs, ttft);
                            self.token_events.push((sess.id, report.token));
                            self.hot_bytes += sess.kv_bytes();
                            self.engine.metrics.observe_hot(self.hot_bytes);
                            if sess.is_done() {
                                self.retire(sess, FinishStatus::Completed, None);
                            } else {
                                self.active.push_back(sess);
                            }
                        }
                        None => still.push_back(sess),
                    }
                }
                Err(e) => {
                    // never checked into `hot_bytes`, so retire unaccounted
                    self.retire_unaccounted(
                        sess,
                        FinishStatus::Failed,
                        Some(format!("prefill failed: {e:#}")),
                    );
                }
            }
        }
        // stream survivors rejoin at the back: they already had this tick's
        // lockstep advance, so the serial sessions keep queue-order priority
        still.extend(stream);
        self.prefilling = still;
        advanced
    }

    /// One lockstep round over the mid-stream sessions: group them by
    /// [`EngineWorker::stream_lockstep_key`] preserving arrival order, fan
    /// the groups over the worker pool, advance every group one chunk
    /// through a single batched backend dispatch
    /// ([`EngineWorker::advance_stream_group`]), then merge completions
    /// exactly as the serial arm of [`Scheduler::advance_prefills`] does.
    /// A failed group retires as a unit (its caches are partially advanced,
    /// same contract as a batched decode error). Returns the sessions still
    /// mid-prefill plus the prompt tokens advanced.
    fn advance_stream_round(&mut self, sessions: Vec<Session>) -> (Vec<Session>, usize) {
        type Key = (usize, usize, usize, usize, usize);
        let mut groups: Vec<(Key, Vec<Session>)> = Vec::new();
        for sess in sessions {
            let key = self
                .engine
                .worker()
                .stream_lockstep_key(&sess)
                .expect("stream round over a non-stream session");
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, g)) => g.push(sess),
                None => groups.push((key, vec![sess])),
            }
        }
        // TTFT baselines must be read before the fan-out — a completing
        // advance tears down the prefill state that carries them
        let timings: Vec<Vec<(f64, std::time::Instant)>> = groups
            .iter()
            .map(|(_, g)| {
                g.iter()
                    .map(|s| {
                        s.prefill
                            .as_ref()
                            .map(|st| (st.wait_secs, st.enqueued_at))
                            .unwrap_or((0.0, s.queued_at))
                    })
                    .collect()
            })
            .collect();
        // a panicking group drops its sessions in the unwind (they were
        // never in `hot_bytes` mid-prefill); keep the ids for the results
        let group_ids: Vec<Vec<u64>> =
            groups.iter().map(|(_, g)| g.iter().map(|s| s.id).collect()).collect();
        let worker = self.engine.worker();
        let (outcomes, stats) = self.pool.run(groups, |ctx, (_key, mut group)| {
            let res = worker.advance_stream_group(ctx, &mut group);
            (group, res)
        });
        self.engine.metrics.observe_worker_round(self.pool.workers(), &stats);
        let mut survivors: Vec<Session> = Vec::new();
        let mut advanced = 0usize;
        for ((group_timings, ids), unit) in timings.into_iter().zip(group_ids).zip(outcomes) {
            let (group, res) = match unit {
                Ok(pair) => pair,
                Err(reason) => {
                    for id in ids {
                        self.fail_lost(id, &reason);
                    }
                    continue;
                }
            };
            match res {
                Ok((results, dispatches)) => {
                    self.engine.metrics.observe_prefill_chunk_batch(group.len(), dispatches);
                    for ((sess, (worked, report)), (wait_secs, admitted_at)) in
                        group.into_iter().zip(results).zip(group_timings)
                    {
                        advanced += worked;
                        match report {
                            Some(report) => {
                                self.engine.absorb_prefill(&report);
                                let ttft = wait_secs + admitted_at.elapsed().as_secs_f64();
                                self.engine.metrics.observe_admission(wait_secs, ttft);
                                self.token_events.push((sess.id, report.token));
                                self.hot_bytes += sess.kv_bytes();
                                self.engine.metrics.observe_hot(self.hot_bytes);
                                if sess.is_done() {
                                    self.retire(sess, FinishStatus::Completed, None);
                                } else {
                                    self.active.push_back(sess);
                                }
                            }
                            None => survivors.push(sess),
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("prefill failed: {e:#}");
                    for sess in group {
                        self.retire_unaccounted(sess, FinishStatus::Failed, Some(msg.clone()));
                    }
                }
            }
        }
        (survivors, advanced)
    }

    /// Merge one prefilled request back into the scheduler: metrics,
    /// hot-byte accounting, and retirement/activation. Shared by the
    /// sequential and fanned-out prefill arms so the two cannot diverge.
    /// Returns 1 when the prefill succeeded.
    fn merge_prefill(
        &mut self,
        q: QueuedRequest,
        wait_secs: f64,
        sess: Session,
        res: Result<PrefillReport>,
    ) -> usize {
        self.warm_bucket = Some(q.bucket);
        let done = match res {
            Ok(report) => {
                self.engine.absorb_prefill(&report);
                self.engine
                    .metrics
                    .observe_admission(wait_secs, wait_secs + sess.prefill_secs);
                self.token_events.push((sess.id, report.token));
                self.hot_bytes += sess.kv_bytes();
                if sess.is_done() {
                    self.retire(sess, FinishStatus::Completed, None);
                } else {
                    self.active.push_back(sess);
                }
                1
            }
            Err(e) => {
                drop(sess);
                self.park_queued(q, FinishStatus::Failed, format!("prefill failed: {e:#}"));
                0
            }
        };
        self.engine.metrics.observe_hot(self.hot_bytes);
        done
    }

    /// One decode step per active session: plan bucket groups + the
    /// sequential tiered arm on the serving thread, fan the plan out across
    /// the worker pool, then step the tiered arm with tier fetches. A
    /// decode error kills only its execution unit — the single session on a
    /// `One` unit, the whole group on a `Group` (its caches are partially
    /// advanced) — and the rest keep serving. With tiering on, the engine
    /// still never sees warm layers: parallel units contain only fully-hot
    /// sessions and the sequential arm fetches (with victim spills) before
    /// stepping.
    pub fn decode_round(&mut self) -> usize {
        if self.active.is_empty() {
            return 0;
        }
        // ---- plan (worker-count independent, serving thread only)
        let mut parallel: Vec<RoundUnit> = Vec::new();
        let mut sequential: VecDeque<Session> = VecDeque::new();
        while let Some(sess) = self.active.pop_front() {
            if !sess.is_fully_hot() {
                // tier I/O required: the sequential arm fetches before it
                sequential.push_back(sess);
            } else if self.opts.batched_decode {
                // gather this session's capacity-bucket group from the rest
                // of the round's queue (fully-hot members only — a spilled
                // session stays behind for the sequential arm)
                let sig = sess.capacity_signature();
                let mut group = vec![sess];
                let mut rest = VecDeque::with_capacity(self.active.len());
                while let Some(s) = self.active.pop_front() {
                    if s.is_fully_hot() && s.matches_capacity_signature(&sig) {
                        group.push(s);
                    } else {
                        rest.push_back(s);
                    }
                }
                self.active = rest;
                parallel.push(RoundUnit::Group(group));
            } else {
                parallel.push(RoundUnit::One(sess));
            }
        }

        if self.opts.tiering {
            self.reserve_parallel_headroom(&mut parallel, &mut sequential);
            // double buffering, half one: the tiered arm's spilled layers —
            // including victims the headroom reservation just spilled —
            // start rehydrating on the tier thread while the parallel stage
            // below decodes. Hints come *after* the reservation so a layer
            // spilled for headroom still gets staged before its fetch.
            for sess in &sequential {
                for l in self.tier.spilled_layers(sess.id) {
                    self.tier.prefetch_ahead(sess.id, l);
                }
            }
        }

        // ---- parallel stage: bucket groups (and `One` units) fan out
        let mut stepped: usize = 0;
        let mut decoded: VecDeque<Session> = VecDeque::new();
        if !parallel.is_empty() {
            // check the stage's sessions out of the hot counter: their
            // bytes change on the workers (append + decode eviction)
            for unit in &parallel {
                for s in unit.sessions() {
                    self.hot_bytes -= s.kv_bytes();
                }
            }
            // a panicking unit drops its sessions in the unwind — their
            // bytes are already checked out, so nothing re-enters
            // `hot_bytes`; the ids name the failure results
            let unit_ids: Vec<Vec<u64>> = parallel
                .iter()
                .map(|u| u.sessions().iter().map(|s| s.id).collect())
                .collect();
            let worker = self.engine.worker();
            let (results, stats) = self.pool.run(parallel, |ctx, unit| match unit {
                RoundUnit::Group(mut group) => {
                    let res = worker.decode_step_batch(ctx, &mut group);
                    (RoundUnit::Group(group), res)
                }
                RoundUnit::One(mut sess) => {
                    let res = worker.decode_step(ctx, &mut sess);
                    (RoundUnit::One(sess), res)
                }
            });
            self.engine.metrics.observe_worker_round(self.pool.workers(), &stats);
            for (ids, outcome) in unit_ids.into_iter().zip(results) {
                let (unit, res) = match outcome {
                    Ok(pair) => pair,
                    Err(reason) => {
                        for id in ids {
                            self.fail_lost(id, &reason);
                        }
                        continue;
                    }
                };
                let sessions = unit.into_sessions();
                match res {
                    Ok(report) => {
                        // check back in from the report's per-session sizes
                        // — the worker already walked the caches
                        self.hot_bytes += report.kv_after.iter().sum::<usize>();
                        self.engine.absorb_step(&report);
                        stepped += sessions.len();
                        for (sess, tok) in sessions.iter().zip(&report.tokens) {
                            self.token_events.push((sess.id, *tok));
                        }
                        for sess in sessions {
                            if sess.is_done() {
                                self.retire(sess, FinishStatus::Completed, None);
                            } else {
                                decoded.push_back(sess);
                            }
                        }
                    }
                    Err(e) => {
                        // the unit is its failure domain: a group's caches
                        // may be partially advanced, so every member retires
                        // (check in by walking — no report exists)
                        let msg = format!("decode failed: {e:#}");
                        for sess in sessions {
                            self.hot_bytes += sess.kv_bytes();
                            self.retire(sess, FinishStatus::Failed, Some(msg.clone()));
                        }
                    }
                }
            }
            if self.opts.tiering && self.opts.kv_mem_limit.is_some() {
                self.engine.metrics.observe_hot(self.hot_bytes);
            }
        }

        // ---- sequential arm: tier fetches + per-session steps, in order
        while let Some(mut sess) = sequential.pop_front() {
            if self.opts.tiering {
                self.make_resident(&mut sess, &mut decoded, &mut sequential);
            }
            self.hot_bytes -= sess.kv_bytes();
            let res = self.engine.decode_step(&mut sess);
            self.hot_bytes += sess.kv_bytes();
            match res {
                Ok(tok) => {
                    self.token_events.push((sess.id, tok));
                    stepped += 1;
                    if sess.is_done() {
                        self.retire(sess, FinishStatus::Completed, None);
                    } else {
                        // per-step gauge fidelity only matters when a limit
                        // is being enforced
                        if self.opts.tiering && self.opts.kv_mem_limit.is_some() {
                            self.engine.metrics.observe_hot(self.hot_bytes);
                        }
                        decoded.push_back(sess);
                    }
                }
                Err(e) => {
                    self.retire(sess, FinishStatus::Failed, Some(format!("decode failed: {e:#}")));
                }
            }
        }

        if self.opts.tiering {
            // double buffering, half two: sessions leaving this round with
            // spilled layers (this round's victims) start rehydrating now,
            // so next round's fetches hit the staging area
            for sess in &decoded {
                for l in self.tier.spilled_layers(sess.id) {
                    self.tier.prefetch_ahead(sess.id, l);
                }
            }
        }

        self.active = decoded;
        self.engine.metrics.decode_steps += stepped as u64;
        stepped
    }

    /// Reserve one-step append headroom for every parallel unit under a
    /// hot-tier limit, spilling victims from the sequential arm (back of
    /// the queue first — their steps are farthest away, and they rehydrate
    /// through their own `make_resident`). When even a full spill of the
    /// sequential arm cannot cover the stage's growth, the last-planned
    /// unit is demoted to the sequential arm — its members then step with
    /// per-session victim spills between steps, the bound
    /// [`Scheduler::make_resident`] maintains — and the check repeats.
    fn reserve_parallel_headroom(
        &mut self,
        parallel: &mut Vec<RoundUnit>,
        sequential: &mut VecDeque<Session>,
    ) {
        let Some(limit) = self.opts.kv_mem_limit else { return };
        loop {
            let growth: usize = parallel
                .iter()
                .flat_map(|u| u.sessions().iter())
                .map(|s| s.step_growth_bytes())
                .sum();
            let mut over = (self.hot_bytes + growth).saturating_sub(limit);
            if over > 0 {
                let freed = spill_from_sessions(
                    &mut self.tier,
                    &mut self.engine.metrics,
                    &mut self.hot_bytes,
                    sequential.make_contiguous(),
                    u64::MAX,
                    over,
                );
                over = over.saturating_sub(freed);
            }
            if over == 0 {
                return;
            }
            match parallel.pop() {
                Some(unit) => {
                    // demoted members step before the already-planned
                    // sequential sessions, mirroring the old per-session
                    // fallback order
                    for sess in unit.into_sessions().into_iter().rev() {
                        sequential.push_front(sess);
                    }
                }
                None => return,
            }
        }
    }

    /// Fetch `sess`'s spilled layers back to hot, first spilling other
    /// sessions' layers when hot bytes would overshoot the limit. Victims
    /// are taken from the sessions whose next decode step is farthest away:
    /// the back of `decoded` (already stepped this round), then the back of
    /// the not-yet-stepped sequential arm.
    fn make_resident(
        &mut self,
        sess: &mut Session,
        decoded: &mut VecDeque<Session>,
        upcoming: &mut VecDeque<Session>,
    ) {
        let needed = self.tier.pending_hot_bytes(sess.id);
        if let Some(limit) = self.opts.kv_mem_limit {
            // reserve headroom for the entries this decode step will append
            // (one per head per layer), so the post-step hot size still
            // respects the limit
            let growth = sess.step_growth_bytes();
            let over = (self.hot_bytes + needed + growth).saturating_sub(limit);
            if over > 0 {
                let freed = spill_from_sessions(
                    &mut self.tier,
                    &mut self.engine.metrics,
                    &mut self.hot_bytes,
                    decoded.make_contiguous(),
                    sess.id,
                    over,
                );
                if freed < over {
                    spill_from_sessions(
                        &mut self.tier,
                        &mut self.engine.metrics,
                        &mut self.hot_bytes,
                        upcoming.make_contiguous(),
                        sess.id,
                        over - freed,
                    );
                }
                // If victims could not cover `over` (every other session is
                // already fully warm), we still proceed: the decoding session
                // must be resident, and its own footprint was admission-
                // checked against the limit. The observe_hot below records
                // the true value, so any overshoot shows in peak_hot.
            }
        }
        if needed == 0 {
            return;
        }
        // one observe_prefetch per layer, mirroring per-layer observe_spill,
        // so the spill/prefetch counters and latencies share units; the
        // latency is the *blocking* time the serving thread paid — near
        // zero when the prefetch-ahead staging already rehydrated the layer
        for l in self.tier.spilled_layers(sess.id) {
            let t0 = std::time::Instant::now();
            if let Some(hot) = self.tier.fetch(sess.id, l) {
                let restored = hot.live_bytes();
                sess.caches[l] = hot;
                sess.residency[l] = Residency::Hot;
                self.hot_bytes += restored;
                self.engine.metrics.observe_prefetch(restored, t0.elapsed().as_secs_f64());
            }
        }
        self.engine.metrics.observe_warm(self.tier.warm_bytes());
        self.engine.metrics.observe_hot(self.hot_bytes);
    }

    /// Spill layers from active sessions (back of the queue first — their
    /// next decode is farthest away) until `need` hot bytes are freed or
    /// nothing spillable remains. Returns the bytes actually freed.
    fn spill_active_until(&mut self, need: usize) -> usize {
        // no session is mid-decode during admission, so every active
        // session is an eligible victim (protect an id no session carries)
        spill_from_sessions(
            &mut self.tier,
            &mut self.engine.metrics,
            &mut self.hot_bytes,
            self.active.make_contiguous(),
            u64::MAX,
            need,
        )
    }

    /// One scheduler tick: admit+prefill a batch when due, then advance every
    /// active session by one decode step. Returns what the round produced —
    /// newly generated `(id, token)` pairs and newly finished results — so
    /// an incremental driver (the serving loop) can stream tokens and
    /// dispatch terminal responses between rounds.
    pub fn tick(&mut self) -> Result<TickReport> {
        self.tick += 1;
        let idle = self.active.is_empty() && self.prefilling.is_empty();
        let want_prefill =
            idle || (self.tick % self.opts.prefill_every == 0 && !self.queue.is_empty());

        let finished_before = self.finished.len();
        let mut worked = false;
        if want_prefill {
            let batch = self.admit();
            worked |= self.prefill_batch(batch)? > 0;
        }
        worked |= self.decode_round() > 0;
        // budgeted chunked prefills advance *after* the decode round, so a
        // long prompt costs every tick at most `prefill_chunk_budget`
        // tokens of prefill work and active decodes keep their cadence
        worked |= self.advance_prefills() > 0;
        self.engine.metrics.observe_hot(self.live_kv_bytes());
        let snap = self.tier.thread_snapshot();
        self.engine.metrics.observe_tier_thread(
            snap.spill_queue_depth,
            snap.prefetch_queue_depth,
            snap.staged_bytes,
            snap.busy_secs,
        );
        // a tick that only rejected requests still made progress
        worked |= self.finished.len() > finished_before;
        Ok(TickReport {
            worked,
            tokens: std::mem::take(&mut self.token_events),
            finished: std::mem::take(&mut self.finished),
        })
    }

    /// True while the scheduler still owns unfinished work (queued or
    /// active requests) — the serving loop's "keep ticking" condition.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty() || !self.prefilling.is_empty()
    }

    /// Shutdown path: park every queued (not yet admitted) request with a
    /// rejection result carrying `reason`. Active sessions are untouched —
    /// the serving loop keeps ticking them to completion (draining).
    /// Returns how many requests were rejected.
    pub fn drain_queue_rejecting(&mut self, reason: &str) -> usize {
        let drained = self.queue.drain();
        let n = drained.len();
        for q in drained {
            self.park_queued(q, FinishStatus::Rejected, reason.to_string());
        }
        n
    }

    /// Cheap point-in-time metrics copy plus in-flight gauges; never blocks
    /// on or mutates scheduler state.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            metrics: self.engine.metrics.clone(),
            // mid-prefill sessions hold admission slots, so they count
            active_sessions: self.active.len() + self.prefilling.len(),
            queued_requests: self.queue.len(),
        }
    }

    /// Terminal result for a request whose session was lost inside a
    /// panicking work unit. The unwind already dropped the session — its
    /// bytes were checked out of `hot_bytes` before the fan-out (decode)
    /// or never checked in (prefill) — so only the bookkeeping that needs
    /// no session runs: tier teardown, gauge refresh, and a `Failed`
    /// result. The rest of the round keeps serving
    /// (`tests/sharded_decode.rs` regression-tests one poisoned session
    /// among healthy ones).
    fn fail_lost(&mut self, id: u64, reason: &str) {
        self.tier.drop_session(id);
        self.engine.metrics.observe_warm(self.tier.warm_bytes());
        self.engine.metrics.observe_hot(self.hot_bytes);
        self.engine.metrics.requests_failed += 1;
        self.finished.push((
            id,
            GenerateResult {
                id,
                status: FinishStatus::Failed,
                error: Some(format!("work unit panicked: {reason}")),
                tokens: vec![],
                prefill_secs: 0.0,
                decode_secs: 0.0,
                kv_bytes_after_prefill: 0,
                peak_kv_bytes: self.engine.metrics.peak_kv_bytes,
                budgets: vec![],
            },
        ));
    }

    /// Park a queued request with a terminal non-completed result.
    fn park_queued(&mut self, q: QueuedRequest, status: FinishStatus, reason: String) {
        match status {
            FinishStatus::Failed => self.engine.metrics.requests_failed += 1,
            _ => self.engine.metrics.requests_rejected += 1,
        }
        self.finished.push((
            q.id,
            GenerateResult {
                id: q.id,
                status,
                error: Some(reason),
                tokens: vec![],
                prefill_secs: 0.0,
                decode_secs: 0.0,
                kv_bytes_after_prefill: 0,
                peak_kv_bytes: self.engine.metrics.peak_kv_bytes,
                budgets: vec![],
            },
        ));
    }

    fn retire(&mut self, sess: Session, status: FinishStatus, error: Option<String>) {
        // the leaving session's bytes exit both tiers' accounting; refresh
        // both gauges now so a cancel's release is visible in the very next
        // metrics snapshot, without waiting for another tick
        self.hot_bytes -= sess.kv_bytes();
        self.retire_unaccounted(sess, status, error);
    }

    /// [`Scheduler::retire`] for sessions whose bytes were never checked
    /// into `hot_bytes` — mid-chunked-prefill sessions join the hot counter
    /// only at their first token, so canceling or failing one must not
    /// subtract bytes it never added.
    fn retire_unaccounted(&mut self, sess: Session, status: FinishStatus, error: Option<String>) {
        self.tier.drop_session(sess.id);
        self.engine.metrics.observe_warm(self.tier.warm_bytes());
        self.engine.metrics.observe_hot(self.hot_bytes);
        match status {
            FinishStatus::Completed => self.engine.metrics.finish_request(
                sess.prefill_secs,
                sess.decode_secs,
                sess.generated.len(),
            ),
            FinishStatus::Canceled => self.engine.metrics.requests_canceled += 1,
            FinishStatus::Failed => self.engine.metrics.requests_failed += 1,
            FinishStatus::Rejected => self.engine.metrics.requests_rejected += 1,
        }
        let result = GenerateResult {
            id: sess.id,
            status,
            error,
            tokens: sess.generated.clone(),
            prefill_secs: sess.prefill_secs,
            decode_secs: sess.decode_secs,
            kv_bytes_after_prefill: sess.kv_bytes(),
            peak_kv_bytes: self.engine.metrics.peak_kv_bytes,
            budgets: sess.budgets.clone(),
        };
        self.finished.push((sess.id, result));
    }

    /// Drive everything to completion; returns finished (request-id, result)
    /// pairs in completion order. Terminates even when some requests can
    /// never be admitted — those come back with `FinishStatus::Rejected`.
    pub fn run_to_completion(&mut self) -> Result<Vec<(u64, GenerateResult)>> {
        // results parked since the last tick (e.g. cancel-while-queued)
        // come first; each tick then drains its own completions
        let mut done = std::mem::take(&mut self.finished);
        self.token_events.clear();
        while self.has_work() {
            done.extend(self.tick()?.finished);
        }
        Ok(done)
    }

    pub fn take_finished(&mut self) -> Vec<(u64, GenerateResult)> {
        std::mem::take(&mut self.finished)
    }
}

/// Spill hot layers from `sessions` (iterated back to front) until `need`
/// bytes are freed, skipping the protected session. Within one victim
/// session, lowest-LAVa-weight layers (smallest Algorithm 2 budget) go
/// first. Free functions over disjoint scheduler fields keep the borrow
/// checker happy while the round's sessions live outside `active`.
fn spill_from_sessions(
    tier: &mut TierClient,
    metrics: &mut Metrics,
    hot_bytes: &mut usize,
    sessions: &mut [Session],
    protect: u64,
    need: usize,
) -> usize {
    let mut freed = 0;
    for sess in sessions.iter_mut().rev() {
        if freed >= need {
            break;
        }
        if sess.id == protect {
            continue;
        }
        freed += spill_session_layers(tier, metrics, hot_bytes, sess, need - freed);
    }
    freed
}

/// Spill one session's hot layers, lowest-budget first, until `need` bytes
/// are freed or the session is fully warm. Returns the bytes freed. The
/// spill latency recorded here is the serving-thread cost only (take the
/// buffers + enqueue); the Q8 quantization runs on the tier thread.
fn spill_session_layers(
    tier: &mut TierClient,
    metrics: &mut Metrics,
    hot_bytes: &mut usize,
    sess: &mut Session,
    need: usize,
) -> usize {
    let mut freed = 0;
    let mut order: Vec<usize> = (0..sess.caches.len()).collect();
    order.sort_by_key(|&l| sess.budgets.get(l).copied().unwrap_or(usize::MAX));
    for l in order {
        if freed >= need {
            break;
        }
        if sess.residency[l] == Residency::Hot && sess.caches[l].total_entries() > 0 {
            let t0 = std::time::Instant::now();
            let bytes = tier.spill(sess.id, l, &mut sess.caches[l]);
            sess.residency[l] = Residency::Warm;
            *hot_bytes -= bytes;
            metrics.observe_spill(bytes, t0.elapsed().as_secs_f64());
            freed += bytes;
        }
    }
    if freed > 0 {
        metrics.observe_warm(tier.warm_bytes());
    }
    freed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Policy;
    use crate::coordinator::engine::EngineOptions;
    use crate::model::backend::MockBackend;

    fn sched(limit: Option<usize>) -> Scheduler<MockBackend> {
        let mock = MockBackend::new(MockBackend::default_config());
        let engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        Scheduler::new(engine, SchedulerOptions { kv_mem_limit: limit, ..Default::default() })
    }

    fn sched_with_workers(limit: Option<usize>, workers: usize) -> Scheduler<MockBackend> {
        let mock = MockBackend::new(MockBackend::default_config());
        let engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        Scheduler::new(
            engine,
            SchedulerOptions { kv_mem_limit: limit, workers, ..Default::default() },
        )
    }

    /// Scheduler with the chunked-prefill knobs pinned explicitly (the
    /// plain helpers inherit `LAVA_PREFILL_CHUNK` through the defaults, by
    /// design — CI's second suite run exercises the chunked path that way).
    /// Streaming eviction is pinned *off* too: tests built on this helper
    /// assert bit-identity with the monolithic path, which streaming
    /// deliberately trades away. Stream tests flip the flag on explicitly.
    fn sched_chunked(
        chunk: Option<usize>,
        budget: Option<usize>,
        limit: Option<usize>,
    ) -> Scheduler<MockBackend> {
        let mock = MockBackend::new(MockBackend::default_config());
        let engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        Scheduler::new(
            engine,
            SchedulerOptions {
                kv_mem_limit: limit,
                prefill_chunk: chunk,
                prefill_chunk_budget: budget,
                prefill_stream_evict: false,
                ..Default::default()
            },
        )
    }

    fn req(n: usize, out: usize) -> GenerateRequest {
        GenerateRequest { prompt: (0..n).map(|i| (i % 251) as i32).collect(), max_new_tokens: out }
    }

    #[test]
    fn runs_all_requests() {
        let mut s = sched(None);
        for _ in 0..5 {
            s.submit(req(100, 4)).unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
        for (_, r) in &done {
            assert_eq!(r.tokens.len(), 4);
            assert_eq!(r.status, FinishStatus::Completed);
        }
        assert_eq!(s.engine.metrics.requests_finished, 5);
    }

    #[test]
    fn interleaves_decodes_and_prefills() {
        let mut s = sched(None);
        for _ in 0..3 {
            s.submit(req(100, 12)).unwrap();
        }
        // after a few ticks there should be >1 active session (continuous
        // batching, not sequential draining)
        let mut max_active = 0;
        for _ in 0..8 {
            s.tick().unwrap();
            max_active = max_active.max(s.active_count());
        }
        assert!(max_active >= 2, "expected interleaving, got {max_active}");
        s.run_to_completion().unwrap();
    }

    #[test]
    fn memory_limit_defers_admission() {
        // limit fits one prefill peak plus ~2 retained sessions: later
        // requests must wait for earlier ones to finish, never reject
        let mut s = sched(None);
        s.opts.kv_mem_limit = Some(s.projected_bytes(200) + 2 * s.retained_bytes(200));
        for _ in 0..4 {
            s.submit(req(200, 6)).unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 4, "deferred requests must still finish");
        for (_, r) in &done {
            assert_eq!(r.status, FinishStatus::Completed, "deferral must not reject");
        }
    }

    #[test]
    fn tiering_spills_under_pressure_and_completes_all() {
        // one prefill peak plus ~1 retained session fits; the rest must be
        // rescued by spilling idle sessions' layers to the warm tier
        // instead of deferring forever
        let mut s = sched(None);
        let limit = s.projected_bytes(200) + s.retained_bytes(200) * 5 / 4;
        s.opts.kv_mem_limit = Some(limit);
        for _ in 0..4 {
            s.submit(req(200, 6)).unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 4);
        for (_, r) in &done {
            assert_eq!(r.status, FinishStatus::Completed, "{:?}", r.error);
        }
        let m = &s.engine.metrics;
        assert!(m.spills > 0, "memory pressure must trigger spills");
        assert!(m.prefetches > 0, "spilled sessions must prefetch before decode");
        assert!(
            m.peak_hot_kv_bytes <= limit,
            "hot tier exceeded the limit: {} > {limit}",
            m.peak_hot_kv_bytes
        );
        assert!(m.peak_warm_kv_bytes > 0);
        assert_eq!(s.tier.warm_bytes(), 0, "retired sessions must leave no warm residue");
        assert_eq!(m.warm_kv_bytes, 0);
    }

    #[test]
    fn tiering_off_reverts_to_deferral() {
        let mut s = sched(None);
        s.opts.kv_mem_limit = Some(s.projected_bytes(200) + s.retained_bytes(200) * 5 / 4);
        s.opts.tiering = false;
        for _ in 0..4 {
            s.submit(req(200, 6)).unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 4, "deferred requests must still finish");
        let m = &s.engine.metrics;
        assert_eq!(m.spills, 0, "tiering off must never spill");
        assert_eq!(m.prefetches, 0);
        assert!(m.requests_deferred > 0, "the old defer path must engage");
    }

    #[test]
    fn decode_round_issues_one_dispatch_per_layer_for_a_bucket_group() {
        let mut s = sched(None);
        for _ in 0..4 {
            s.submit(req(100, 8)).unwrap();
        }
        let batch = s.admit();
        s.prefill_batch(batch).unwrap();
        assert_eq!(s.active_count(), 4);
        let before = s.engine.metrics.decode_dispatches_total();
        let stepped = s.decode_round();
        assert_eq!(stepped, 4);
        let n_layers = s.engine.config().n_layers as u64;
        assert_eq!(
            s.engine.metrics.decode_dispatches_total() - before,
            n_layers,
            "4 same-bucket sessions must cost one dispatch per layer, not per session"
        );
        assert!((s.engine.metrics.batch_occupancy() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn batched_decode_off_dispatches_per_session() {
        let mut s = sched(None);
        s.opts.batched_decode = false;
        for _ in 0..4 {
            s.submit(req(100, 8)).unwrap();
        }
        let batch = s.admit();
        s.prefill_batch(batch).unwrap();
        let before = s.engine.metrics.decode_dispatches_total();
        s.decode_round();
        let n_layers = s.engine.config().n_layers as u64;
        assert_eq!(s.engine.metrics.decode_dispatches_total() - before, 4 * n_layers);
        assert!((s.engine.metrics.batch_occupancy() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn batched_and_serial_rounds_produce_identical_results() {
        let run = |batched: bool| {
            let mut s = sched(None);
            s.opts.batched_decode = batched;
            for i in 0..5 {
                // mixed buckets: three short, two long
                let n = if i % 2 == 0 { 100 } else { 300 };
                s.submit(req(n, 6)).unwrap();
            }
            let mut done = s.run_to_completion().unwrap();
            done.sort_by_key(|(id, _)| *id);
            done
        };
        let serial = run(false);
        let batched = run(true);
        assert_eq!(serial.len(), batched.len());
        for ((ids, rs), (idb, rb)) in serial.iter().zip(&batched) {
            assert_eq!(ids, idb);
            assert_eq!(rs.tokens, rb.tokens, "id {ids}: tokens must be bit-identical");
            assert_eq!(rs.status, rb.status);
            assert_eq!(rs.kv_bytes_after_prefill, rb.kv_bytes_after_prefill);
        }
    }

    #[test]
    fn worker_width_does_not_change_results() {
        // the inline smoke version of tests/sharded_decode.rs: same mixed
        // workload, widths 1 vs 3, identical outputs
        let run = |workers: usize| {
            let mut s = sched_with_workers(None, workers);
            for i in 0..6 {
                let n = if i % 2 == 0 { 100 } else { 300 };
                s.submit(req(n, 6)).unwrap();
            }
            let mut done = s.run_to_completion().unwrap();
            done.sort_by_key(|(id, _)| *id);
            done
        };
        let one = run(1);
        let three = run(3);
        assert_eq!(one.len(), three.len());
        for ((ida, ra), (idb, rb)) in one.iter().zip(&three) {
            assert_eq!(ida, idb);
            assert_eq!(ra.tokens, rb.tokens, "id {ida}: tokens must be bit-identical");
            assert_eq!(ra.kv_bytes_after_prefill, rb.kv_bytes_after_prefill);
        }
    }

    #[test]
    fn worker_and_tier_gauges_populate() {
        let mut s = sched_with_workers(Some(210_000), 2);
        for _ in 0..4 {
            s.submit(req(200, 6)).unwrap();
        }
        s.run_to_completion().unwrap();
        let m = &s.engine.metrics;
        assert!(m.worker_rounds > 0, "fan-out rounds must be recorded");
        assert_eq!(m.workers, 2);
        assert!(m.worker_utilization() >= 0.0);
        assert!(!m.worker_busy_secs.is_empty());
        assert!(m.spills > 0, "workload must exercise the tier thread");
        // after a sync barrier the tier thread has drained its queues
        s.tier.sync();
        let snap = s.tier.thread_snapshot();
        assert_eq!(snap.spill_queue_depth, 0);
        assert_eq!(snap.prefetch_queue_depth, 0);
        assert!(snap.busy_secs >= 0.0);
    }

    #[test]
    fn rejects_oversized() {
        // chunking pinned off: with it on, over-bucket prompts are
        // servable (`over_bucket_prompt_served_via_chunks`) and this
        // rejection no longer applies
        let mut s = sched_chunked(None, None, None);
        assert!(matches!(
            s.submit(req(1 << 20, 1)),
            Err(SubmitError::PromptTooLong { .. })
        ));
    }

    #[test]
    fn same_bucket_prefills_admitted_as_group() {
        // 4 requests in the same shape bucket, room for all: one admission
        // round (the first tick) must bring in the whole group.
        let mut s = sched(None);
        for _ in 0..4 {
            s.submit(req(100, 8)).unwrap();
        }
        s.tick().unwrap();
        assert_eq!(s.active_count(), 4, "pop_batch group must be admitted together");
        assert_eq!(s.pending_count(), 0);
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 4);
    }

    #[test]
    fn parallel_prefill_matches_sequential() {
        // same admitted batch, workers 1 vs 4: identical sessions + results
        let run = |workers: usize| {
            let mut s = sched_with_workers(None, workers);
            for _ in 0..4 {
                s.submit(req(100, 4)).unwrap();
            }
            let mut done = s.run_to_completion().unwrap();
            done.sort_by_key(|(id, _)| *id);
            done
        };
        let seq = run(1);
        let par = run(4);
        for ((ida, ra), (idb, rb)) in seq.iter().zip(&par) {
            assert_eq!(ida, idb);
            assert_eq!(ra.tokens, rb.tokens);
            assert_eq!(ra.kv_bytes_after_prefill, rb.kv_bytes_after_prefill);
            assert_eq!(ra.budgets, rb.budgets);
        }
    }

    #[test]
    fn oversized_request_is_rejected_not_livelocked() {
        // Regression: a request whose projected KV alone exceeds the limit
        // used to be requeued forever, spinning run_to_completion.
        let mut s = sched(Some(1_000));
        // bypass the submit-time guard to exercise the admission-time one
        s.queue.push(req(200, 4)).unwrap();
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].1.status, FinishStatus::Rejected);
        assert!(done[0].1.error.as_deref().unwrap().contains("kv_mem_limit"));
        assert_eq!(s.engine.metrics.requests_rejected, 1);
    }

    #[test]
    fn submit_rejects_impossible_requests_upfront() {
        let mut s = sched(Some(1_000));
        assert!(matches!(
            s.submit(req(200, 4)),
            Err(SubmitError::OverMemoryLimit { .. })
        ));
    }

    #[test]
    fn backpressure_knob_sheds_load() {
        let mut s = sched(None);
        s.opts.max_queue_wait_secs = Some(0.0);
        s.submit(req(100, 4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        // oldest has now waited > 0.0s -> new submissions are shed
        assert!(matches!(
            s.submit(req(100, 4)),
            Err(SubmitError::QueueSaturated { .. })
        ));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn tick_report_streams_the_exact_final_token_sequence() {
        let mut s = sched(None);
        let id = s.submit(req(100, 5)).unwrap();
        let mut streamed = Vec::new();
        let mut done = Vec::new();
        while s.has_work() {
            let rep = s.tick().unwrap();
            assert!(rep.worked);
            streamed.extend(rep.tokens.iter().filter(|(i, _)| *i == id).map(|(_, t)| *t));
            done.extend(rep.finished);
        }
        assert_eq!(done.len(), 1);
        let r = &done[0].1;
        assert_eq!(r.status, FinishStatus::Completed);
        assert_eq!(streamed, r.tokens, "per-tick stream must equal the final result");
    }

    #[test]
    fn drain_queue_rejecting_parks_queued_but_drains_active() {
        let mut s = sched(None);
        let a = s.submit(req(100, 6)).unwrap();
        s.tick().unwrap(); // admits + prefills `a`
        let b = s.submit(req(100, 6)).unwrap();
        assert_eq!(s.drain_queue_rejecting("server shutting down"), 1);
        assert_eq!(s.pending_count(), 0);
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let ra = &done.iter().find(|(id, _)| *id == a).unwrap().1;
        let rb = &done.iter().find(|(id, _)| *id == b).unwrap().1;
        assert_eq!(ra.status, FinishStatus::Completed, "in-flight work must drain");
        assert_eq!(ra.tokens.len(), 6);
        assert_eq!(rb.status, FinishStatus::Rejected);
        assert!(rb.error.as_deref().unwrap().contains("shutting down"));
    }

    #[test]
    fn metrics_snapshot_is_cheap_and_carries_inflight_gauges() {
        let mut s = sched(None);
        s.submit(req(100, 8)).unwrap();
        s.submit(req(400, 8)).unwrap();
        s.tick().unwrap(); // admits the 128-bucket head; the 512 stays queued
        let snap = s.metrics_snapshot();
        assert_eq!(snap.active_sessions, 1);
        assert_eq!(snap.queued_requests, 1);
        s.run_to_completion().unwrap();
        // the snapshot is an independent copy, not a live view
        assert_eq!(snap.metrics.requests_finished, 0);
        assert_eq!(s.engine.metrics.requests_finished, 2);
    }

    #[test]
    fn chunked_scheduling_matches_monolithic_results() {
        let run = |chunk: Option<usize>, budget: Option<usize>| {
            let mut s = sched_chunked(chunk, budget, None);
            for i in 0..4 {
                let n = if i % 2 == 0 { 100 } else { 300 };
                s.submit(req(n, 6)).unwrap();
            }
            let mut done = s.run_to_completion().unwrap();
            done.sort_by_key(|(id, _)| *id);
            done
        };
        let mono = run(None, None);
        for (chunk, budget) in [(Some(96), None), (Some(96), Some(64)), (Some(17), Some(200))] {
            let chunked = run(chunk, budget);
            assert_eq!(mono.len(), chunked.len());
            for ((ida, ra), (idb, rb)) in mono.iter().zip(&chunked) {
                assert_eq!(ida, idb);
                assert_eq!(
                    ra.tokens, rb.tokens,
                    "id {ida}: chunked ({chunk:?}/{budget:?}) tokens must be bit-identical"
                );
                assert_eq!(ra.budgets, rb.budgets);
                assert_eq!(ra.kv_bytes_after_prefill, rb.kv_bytes_after_prefill);
                assert_eq!(ra.status, rb.status);
            }
        }
    }

    #[test]
    fn budgeted_chunked_prefill_interleaves_decode() {
        let mut s = sched_chunked(Some(32), Some(64), None);
        s.opts.prefill_every = 1;
        let a = s.submit(req(100, 40)).unwrap();
        while s.active.iter().all(|x| x.id != a) {
            s.tick().unwrap();
        }
        // B's 600-token prompt is 2400 tokens of prefill work: at 64 per
        // tick it spans dozens of ticks, during every one of which A must
        // still emit a token (the decode round runs before prefill work).
        let b = s.submit(req(600, 4)).unwrap();
        let mut done = Vec::new();
        let mut overlapped = 0;
        while s.has_work() {
            let a_active = s.active.iter().any(|x| x.id == a);
            let b_prefilling = s.prefilling.iter().any(|x| x.id == b);
            let rep = s.tick().unwrap();
            if a_active && b_prefilling {
                overlapped += 1;
                assert!(
                    rep.tokens.iter().any(|(id, _)| *id == a),
                    "decode session stalled behind a chunked prefill"
                );
            }
            done.extend(rep.finished);
        }
        assert!(overlapped >= 5, "expected many overlapped ticks, got {overlapped}");
        assert_eq!(done.len(), 2);
        for (id, r) in &done {
            assert_eq!(r.status, FinishStatus::Completed, "{id}: {:?}", r.error);
            assert_eq!(r.tokens.len(), if *id == a { 40 } else { 4 });
        }
    }

    #[test]
    fn over_bucket_prompt_served_via_chunks() {
        // shrink the prefill ladder so a 600-token prompt exceeds every
        // bucket: monolithic submission rejects it, chunked serving runs it
        let mut mock = MockBackend::new(MockBackend::default_config());
        mock.buckets_prefill = vec![64, 128, 256];
        let engine =
            Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        let mut s = Scheduler::new(
            engine,
            SchedulerOptions { prefill_chunk: Some(128), ..Default::default() },
        );
        let id = s.submit(req(600, 4)).unwrap();
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0, id);
        assert_eq!(done[0].1.status, FinishStatus::Completed, "{:?}", done[0].1.error);
        assert_eq!(done[0].1.tokens.len(), 4);

        let mut mock2 = MockBackend::new(MockBackend::default_config());
        mock2.buckets_prefill = vec![64, 128, 256];
        let engine2 =
            Engine::new(mock2, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        let mut s2 = Scheduler::new(
            engine2,
            SchedulerOptions { prefill_chunk: None, ..Default::default() },
        );
        assert!(matches!(s2.submit(req(600, 4)), Err(SubmitError::PromptTooLong { .. })));
    }

    #[test]
    fn cancel_mid_chunked_prefill() {
        let mut s = sched_chunked(Some(32), Some(32), None);
        s.opts.prefill_every = 1;
        let id = s.submit(req(300, 4)).unwrap();
        s.tick().unwrap(); // admit + begin + one budgeted advance
        assert_eq!(s.prefilling_count(), 1);
        assert!(s.cancel(id));
        assert_eq!(s.prefilling_count(), 0);
        assert!(!s.has_work());
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].1.status, FinishStatus::Canceled);
        assert!(done[0].1.tokens.is_empty());
    }

    #[test]
    fn budgeted_chunked_prefill_respects_memory_accounting() {
        // tight limit: mid-prefill sessions must reserve their projected
        // bytes so admission cannot over-commit, and everything completes
        let mut s = sched_chunked(Some(64), Some(128), None);
        s.opts.kv_mem_limit = Some(s.projected_bytes(200) + 2 * s.retained_bytes(200));
        for _ in 0..4 {
            s.submit(req(200, 6)).unwrap();
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 4);
        for (_, r) in &done {
            assert_eq!(r.status, FinishStatus::Completed, "{:?}", r.error);
        }
    }

    #[test]
    fn cancel_queued_and_unknown() {
        let mut s = sched(None);
        let id = s.submit(req(100, 4)).unwrap();
        assert!(s.cancel(id));
        assert!(!s.cancel(id), "double-cancel must be a no-op");
        assert!(!s.cancel(9999));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].1.status, FinishStatus::Canceled);
        assert!(done[0].1.tokens.is_empty());
    }

    #[test]
    fn stream_chunk_batching_reduces_dispatches() {
        // two identical prompts admitted together stay in lockstep for the
        // whole streaming prefill, so every advance round covers both
        // sessions through ONE batched backend dispatch
        let mut s = sched_chunked(Some(64), Some(64), None);
        s.opts.prefill_stream_evict = true;
        s.opts.prefill_every = 1;
        s.submit(req(200, 4)).unwrap();
        s.submit(req(200, 4)).unwrap();
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        for (_, r) in &done {
            assert_eq!(r.status, FinishStatus::Completed, "{:?}", r.error);
            assert_eq!(r.tokens.len(), 4);
        }
        let m = &s.engine.metrics;
        assert!(m.prefill_chunk_batches > 0, "streaming advances must be counted");
        assert_eq!(
            m.prefill_chunk_batch_sessions,
            2 * m.prefill_chunk_batches,
            "lockstep pair must share every round"
        );
        // chunk-major advances fan each lockstep pass over the layers: one
        // batched dispatch per layer per group round (L = 4 on the mock)
        assert_eq!(
            m.prefill_chunk_batch_dispatches,
            4 * m.prefill_chunk_batches,
            "each chunk-major lockstep round must cost one dispatch per layer"
        );
        assert!(
            m.prefill_chunk_batch_dispatches < 4 * m.prefill_chunk_batch_sessions,
            "batching must reduce dispatches below one-per-layer-per-session"
        );
        assert!((m.prefill_chunk_batch_occupancy() - 2.0).abs() < 1e-9);
        // the bounded-transient gauge saw the stream's peak carry
        let cap = s.engine.worker().stream_evict_cap(200, 64).unwrap();
        let col_bytes = 2 * 4 * 16 * 4; // 2 (K+V) · hk · dh · f32
        assert!(m.peak_prefill_transient_bytes > 0);
        assert!(m.peak_prefill_transient_bytes <= cap * col_bytes);
    }

    #[test]
    fn cancel_mid_stream_prefill_releases_carry() {
        let mut s = sched_chunked(Some(64), Some(64), None);
        s.opts.prefill_stream_evict = true;
        s.opts.prefill_every = 1;
        let id = s.submit(req(600, 4)).unwrap();
        s.tick().unwrap(); // admit + begin + one budgeted stream advance
        assert_eq!(s.prefilling_count(), 1);
        let st = s.prefilling[0].prefill.as_ref().expect("mid-prefill state");
        assert!(st.stream.is_some(), "session must be on the streaming path");
        assert!(s.cancel(id));
        assert_eq!(s.prefilling_count(), 0);
        // the carry and any partial caches are gone immediately: both tier
        // gauges read empty without waiting for another tick
        assert_eq!(s.engine.metrics.hot_kv_bytes, 0);
        assert_eq!(s.engine.metrics.warm_kv_bytes, 0);
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].1.status, FinishStatus::Canceled);
        assert_eq!(done[0].1.kv_bytes_after_prefill, 0, "no half-built cache in the result");
        assert!(done[0].1.tokens.is_empty());
    }

    #[test]
    fn stream_bounds_projected_admission_bytes() {
        let mut s = sched_chunked(Some(64), None, None);
        let plain = s.projected_bytes(2048);
        s.opts.prefill_stream_evict = true;
        let streamed = s.projected_bytes(2048);
        assert!(streamed < plain, "streamed {streamed} must undercut plain {plain}");
        // chunk-major (the streaming default) prices the whole working set
        // flat: doubling the prompt moves neither the bounded lanes nor the
        // one-chunk hidden rows, and the retained budget is saturated
        assert_eq!(
            s.projected_bytes(4096),
            streamed,
            "chunk-major projection must be prompt-length-independent"
        );
        // Q8 carries undercut the f32 lanes even after paying for the
        // shared dequantization scratch
        s.engine.opts.carry_q8 = true;
        let q8 = s.projected_bytes(2048);
        assert!(q8 < streamed, "q8 {q8} must undercut f32 lanes {streamed}");
        s.engine.opts.carry_q8 = false;
        // layer-major keeps O(prompt) hidden rows: cheaper than plain (one
        // bounded lane instead of an O(prompt) layer) but not flat
        s.engine.opts.stream_layer_major = true;
        let lm_2k = s.projected_bytes(2048);
        let lm_4k = s.projected_bytes(4096);
        assert!(lm_2k < plain);
        assert!(lm_4k > lm_2k, "layer-major hidden rows must grow with the prompt");
        assert!(
            lm_4k - lm_2k >= 2048 * 2 * 128 * 4, // 2 hidden f32 rows · d_model
            "growth must be dominated by the x/x_next rows"
        );
    }
}
