//! JSON-lines TCP front-end over the scheduler.
//!
//! Protocol (one JSON value per line):
//!   request:  {"prompt": [int, ...], "max_new_tokens": int}
//!             or {"text": "...", "max_new_tokens": int} (byte-level)
//!   batch:    [request, request, ...] — submitted together, admitted by
//!             shape bucket through the scheduler's batched prefill path;
//!             the reply is one JSON array of responses in submission order
//!   response: {"id": n, "status": "completed"|"rejected"|"canceled"|
//!              "failed", "tokens": [...], "text": "...", "prefill_ms": f,
//!              "decode_ms": f, "kv_bytes": n} (plus "error" when not ok;
//!              "id" is null for requests refused at submit time)
//!   control:  {"cmd": "metrics"} | {"cmd": "cancel", "id": n}
//!             | {"cmd": "shutdown"}
//!
//! The server accepts connections on the caller's thread and serves
//! line-by-line — concurrency across requests happens in the scheduler
//! (whose decode/prefill work fans out over the engine worker pool and
//! whose tier I/O runs on a background thread), not across sockets.
//! Because each line is driven to completion before the next is read,
//! `cancel` over this transport only ever sees already-finished ids (it
//! replies {"ok": false}); it is wired for embedders driving the scheduler
//! directly and for the async front-end planned in ROADMAP "Open items".

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::Result;

use super::engine::{Engine, FinishStatus, GenerateRequest, GenerateResult};
use super::scheduler::{Scheduler, SchedulerOptions};
use crate::model::backend::ModelBackend;
use crate::util::json::{self, Json};

pub struct Server<B: ModelBackend> {
    pub sched: Scheduler<B>,
}

impl<B: ModelBackend> Server<B> {
    pub fn new(engine: Engine<B>) -> Server<B> {
        Server::with_options(engine, SchedulerOptions::default())
    }

    pub fn with_options(engine: Engine<B>, opts: SchedulerOptions) -> Server<B> {
        Server { sched: Scheduler::new(engine, opts) }
    }

    /// Parse one request line. Exposed for tests.
    pub fn parse_request(&self, line: &str) -> Result<ParsedLine> {
        let j = Json::parse(line)?;
        if let Some(batch) = j.as_arr() {
            let reqs: Result<Vec<GenerateRequest>> =
                batch.iter().map(request_from_json).collect();
            return Ok(ParsedLine::Batch(reqs?));
        }
        if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
            let id = j.get("id").and_then(|v| v.as_usize()).map(|v| v as u64);
            return Ok(ParsedLine::Command(cmd.to_string(), id));
        }
        Ok(ParsedLine::Request(request_from_json(&j)?))
    }

    /// Serve one batch of requests through the scheduler and render one
    /// response per request, in submission order. Exposed for tests.
    pub fn handle_batch(&mut self, reqs: &[GenerateRequest]) -> Vec<Json> {
        // submission-order slot for every request: either an id to wait for
        // or an immediate submit-error response
        let mut slots: Vec<Result<u64, Json>> = Vec::with_capacity(reqs.len());
        for req in reqs {
            match self.sched.submit(req.clone()) {
                Ok(id) => slots.push(Ok(id)),
                // refused before an id was assigned -> "id": null
                Err(e) => slots.push(Err(Json::obj(vec![
                    ("id", Json::Null),
                    ("status", Json::str("rejected")),
                    ("error", Json::str(format!("{e}"))),
                ]))),
            }
        }
        let (finished, engine_err) = match self.sched.run_to_completion() {
            Ok(f) => (f, None),
            // Defensive: the scheduler currently parks every engine error as
            // a Failed result, so this arm should be unreachable — but if a
            // future step does propagate, drain what finished and keep the
            // submit-time rejections intact.
            Err(e) => (self.sched.take_finished(), Some(format!("{e:#}"))),
        };
        slots
            .into_iter()
            .map(|slot| match slot {
                Err(resp) => resp,
                Ok(id) => finished
                    .iter()
                    .find(|(fid, _)| *fid == id)
                    .map(|(_, r)| result_to_json(r))
                    .unwrap_or_else(|| {
                        let detail = engine_err
                            .clone()
                            .unwrap_or_else(|| format!("result lost for id {id}"));
                        Json::obj(vec![
                            ("id", Json::num(id as f64)),
                            ("status", Json::str("failed")),
                            ("error", Json::str(detail)),
                        ])
                    }),
            })
            .collect()
    }

    fn metrics_json(&self) -> Json {
        let m = &self.sched.engine.metrics;
        Json::obj(vec![
            ("requests", Json::num(m.requests_finished as f64)),
            ("rejected", Json::num(m.requests_rejected as f64)),
            ("canceled", Json::num(m.requests_canceled as f64)),
            ("failed", Json::num(m.requests_failed as f64)),
            ("tokens", Json::num(m.tokens_generated as f64)),
            ("ttft_ms_mean", Json::num(m.mean_ttft_ms())),
            ("ttft_ms_p99", Json::num(m.p99_ttft_ms())),
            ("queue_wait_ms_mean", Json::num(m.mean_queue_wait_ms())),
            ("prefill_ms_mean", Json::num(m.mean_prefill_ms())),
            ("decode_ms_mean", Json::num(m.mean_decode_ms())),
            ("decode_ms_p99", Json::num(m.p99_decode_ms())),
            ("decode_tok_s", Json::num(m.decode_tok_per_sec())),
            ("peak_kv_mb", Json::num(m.peak_kv_bytes as f64 / 1e6)),
            ("admission_rounds", Json::num(m.admission_rounds as f64)),
            ("decode_steps", Json::num(m.decode_steps as f64)),
            // batched decode execution: groups run, mean sessions per group,
            // and backend dispatch counts keyed by capacity bucket
            ("decode_batches", Json::num(m.decode_batches as f64)),
            ("batch_occupancy", Json::num(m.batch_occupancy())),
            ("decode_dispatches_total", Json::num(m.decode_dispatches_total() as f64)),
            (
                "decode_dispatches",
                Json::Obj(
                    m.decode_dispatches
                        .iter()
                        .map(|(bucket, n)| (bucket.to_string(), Json::num(*n as f64)))
                        .collect(),
                ),
            ),
            // per-tier state: hot is what kv_mem_limit bounds; warm holds
            // Q8-spilled layer caches
            ("deferred", Json::num(m.requests_deferred as f64)),
            ("hot_kv_mb", Json::num(m.hot_kv_bytes as f64 / 1e6)),
            ("peak_hot_kv_mb", Json::num(m.peak_hot_kv_bytes as f64 / 1e6)),
            ("warm_kv_mb", Json::num(m.warm_kv_bytes as f64 / 1e6)),
            ("peak_warm_kv_mb", Json::num(m.peak_warm_kv_bytes as f64 / 1e6)),
            ("spills", Json::num(m.spills as f64)),
            ("prefetches", Json::num(m.prefetches as f64)),
            ("spilled_mb", Json::num(m.spilled_bytes as f64 / 1e6)),
            ("prefetched_mb", Json::num(m.prefetched_bytes as f64 / 1e6)),
            ("spill_ms_mean", Json::num(m.mean_spill_ms())),
            ("prefetch_ms_mean", Json::num(m.mean_prefetch_ms())),
            // worker pool: width, per-worker cumulative busy time, and the
            // mean fraction of the pool kept busy during fan-outs
            ("workers", Json::num(m.workers as f64)),
            ("worker_utilization", Json::num(m.worker_utilization())),
            ("worker_rounds", Json::num(m.worker_rounds as f64)),
            (
                "worker_busy_secs",
                Json::Arr(m.worker_busy_secs.iter().map(|&b| Json::num(b)).collect()),
            ),
            // tier thread: command-queue backlogs (sampled at tick end),
            // their observed peak, and background quantize/dequantize time
            ("tier_spill_queue_depth", Json::num(m.tier_spill_queue_depth as f64)),
            ("tier_prefetch_queue_depth", Json::num(m.tier_prefetch_queue_depth as f64)),
            ("tier_queue_depth_peak", Json::num(m.tier_queue_depth_peak as f64)),
            ("tier_staged_mb", Json::num(m.tier_staged_bytes as f64 / 1e6)),
            ("peak_tier_staged_mb", Json::num(m.peak_tier_staged_bytes as f64 / 1e6)),
            ("tier_busy_ms", Json::num(m.tier_busy_secs * 1e3)),
            ("report", Json::str(m.report())),
        ])
    }

    fn handle_conn(&mut self, stream: TcpStream) -> Result<bool> {
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = match self.parse_request(&line) {
                Ok(ParsedLine::Command(cmd, _)) if cmd == "shutdown" => {
                    writeln!(
                        writer,
                        "{}",
                        json::to_string(&Json::obj(vec![("ok", Json::Bool(true))]))
                    )?;
                    return Ok(true);
                }
                Ok(ParsedLine::Command(cmd, _)) if cmd == "metrics" => {
                    json::to_string(&Json::obj(vec![("metrics", self.metrics_json())]))
                }
                Ok(ParsedLine::Command(cmd, id)) if cmd == "cancel" => match id {
                    Some(id) => {
                        let ok = self.sched.cancel(id);
                        json::to_string(&Json::obj(vec![("ok", Json::Bool(ok))]))
                    }
                    None => json::to_string(&Json::obj(vec![(
                        "error",
                        Json::str("cancel needs an 'id'"),
                    )])),
                },
                Ok(ParsedLine::Command(cmd, _)) => json::to_string(&Json::obj(vec![(
                    "error",
                    Json::str(format!("unknown cmd {cmd}")),
                )])),
                Ok(ParsedLine::Request(req)) => {
                    let resps = self.handle_batch(std::slice::from_ref(&req));
                    json::to_string(&resps[0])
                }
                Ok(ParsedLine::Batch(reqs)) => {
                    json::to_string(&Json::Arr(self.handle_batch(&reqs)))
                }
                Err(e) => json::to_string(&Json::obj(vec![("error", Json::str(format!("{e:#}")))])),
            };
            writeln!(writer, "{reply}")?;
        }
        Ok(false)
    }

    /// Blocking accept loop; returns after a shutdown command.
    pub fn serve(&mut self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("[lava] serving on {addr}");
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    if self.handle_conn(s)? {
                        break;
                    }
                }
                Err(e) => eprintln!("[lava] accept error: {e}"),
            }
        }
        Ok(())
    }
}

fn request_from_json(j: &Json) -> Result<GenerateRequest> {
    let max_new = j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(32);
    let prompt: Vec<i32> = if let Some(arr) = j.get("prompt").and_then(|v| v.as_arr()) {
        arr.iter().filter_map(|x| x.as_f64().map(|f| f as i32)).collect()
    } else if let Some(text) = j.get("text").and_then(|v| v.as_str()) {
        text.bytes().map(|b| b as i32).collect()
    } else {
        anyhow::bail!("request needs 'prompt' or 'text'");
    };
    Ok(GenerateRequest { prompt, max_new_tokens: max_new })
}

fn status_str(s: FinishStatus) -> &'static str {
    match s {
        FinishStatus::Completed => "completed",
        FinishStatus::Rejected => "rejected",
        FinishStatus::Canceled => "canceled",
        FinishStatus::Failed => "failed",
    }
}

fn result_to_json(r: &GenerateResult) -> Json {
    let text: String = r
        .tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8 as char)
        .collect();
    let mut pairs = vec![
        ("id", Json::num(r.id as f64)),
        ("status", Json::str(status_str(r.status))),
        ("tokens", Json::Arr(r.tokens.iter().map(|&t| Json::num(t as f64)).collect())),
        ("text", Json::str(text)),
        ("prefill_ms", Json::num(r.prefill_secs * 1e3)),
        ("decode_ms", Json::num(r.decode_secs * 1e3)),
        ("kv_bytes", Json::num(r.kv_bytes_after_prefill as f64)),
    ];
    if let Some(e) = &r.error {
        pairs.push(("error", Json::str(e.clone())));
    }
    Json::obj(pairs)
}

pub enum ParsedLine {
    Request(GenerateRequest),
    Batch(Vec<GenerateRequest>),
    Command(String, Option<u64>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Policy;
    use crate::coordinator::engine::EngineOptions;
    use crate::model::backend::MockBackend;

    fn server() -> Server<MockBackend> {
        let mock = MockBackend::new(MockBackend::default_config());
        Server::new(Engine::new(
            mock,
            EngineOptions::new(Policy::by_name("lava").unwrap(), 24),
        ))
    }

    #[test]
    fn parses_prompt_and_text() {
        let s = server();
        match s.parse_request(r#"{"prompt": [1,2,3], "max_new_tokens": 5}"#).unwrap() {
            ParsedLine::Request(r) => {
                assert_eq!(r.prompt, vec![1, 2, 3]);
                assert_eq!(r.max_new_tokens, 5);
            }
            _ => panic!(),
        }
        match s.parse_request(r#"{"text": "AB"}"#).unwrap() {
            ParsedLine::Request(r) => {
                assert_eq!(r.prompt, vec![65, 66]);
                assert_eq!(r.max_new_tokens, 32);
            }
            _ => panic!(),
        }
        match s.parse_request(r#"{"cmd": "metrics"}"#).unwrap() {
            ParsedLine::Command(c, _) => assert_eq!(c, "metrics"),
            _ => panic!(),
        }
        match s.parse_request(r#"{"cmd": "cancel", "id": 7}"#).unwrap() {
            ParsedLine::Command(c, id) => {
                assert_eq!(c, "cancel");
                assert_eq!(id, Some(7));
            }
            _ => panic!(),
        }
        match s
            .parse_request(r#"[{"prompt": [1,2], "max_new_tokens": 2}, {"text": "A"}]"#)
            .unwrap()
        {
            ParsedLine::Batch(rs) => assert_eq!(rs.len(), 2),
            _ => panic!(),
        }
        assert!(s.parse_request(r#"{"nope": 1}"#).is_err());
    }

    #[test]
    fn batch_replies_in_submission_order_with_ids() {
        let mut s = server();
        let reqs: Vec<GenerateRequest> = (0..3)
            .map(|i| GenerateRequest {
                prompt: (0..100).map(|t| (t % 250) as i32).collect(),
                max_new_tokens: i + 1,
            })
            .collect();
        let resps = s.handle_batch(&reqs);
        assert_eq!(resps.len(), 3);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.get("status").unwrap().as_str().unwrap(), "completed");
            assert_eq!(
                r.get("tokens").unwrap().as_arr().unwrap().len(),
                i + 1,
                "response {i} must map back to its submission"
            );
            assert_eq!(r.get("id").unwrap().as_usize().unwrap(), i + 1);
        }
    }

    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{BufRead, BufReader, Write};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let addr_s = format!("{addr}");
        let handle = std::thread::spawn(move || {
            let mut srv = server();
            srv.serve(&addr_s).unwrap();
        });
        // retry-connect until the server binds
        let mut conn = None;
        for _ in 0..100 {
            if let Ok(c) = std::net::TcpStream::connect(addr) {
                conn = Some(c);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let mut c = conn.expect("connect");
        let prompt: Vec<String> = (0..64).map(|i| format!("{}", i % 250)).collect();
        writeln!(c, "{{\"prompt\": [{}], \"max_new_tokens\": 3}}", prompt.join(","))
            .unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("status").unwrap().as_str().unwrap(), "completed");
        assert!(j.get("id").unwrap().as_usize().unwrap() >= 1);

        // a batch line gets an array reply, in submission order
        writeln!(
            c,
            "[{{\"prompt\": [{p}], \"max_new_tokens\": 1}}, {{\"prompt\": [{p}], \"max_new_tokens\": 2}}]",
            p = prompt.join(",")
        )
        .unwrap();
        let mut line_b = String::new();
        reader.read_line(&mut line_b).unwrap();
        let jb = Json::parse(line_b.trim()).unwrap();
        let arr = jb.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("tokens").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(arr[1].get("tokens").unwrap().as_arr().unwrap().len(), 2);

        // a same-bucket batch that decodes together exercises the grouped
        // decode path end-to-end (occupancy > 1 in the metrics below)
        writeln!(
            c,
            "[{{\"prompt\": [{p}], \"max_new_tokens\": 4}}, {{\"prompt\": [{p}], \"max_new_tokens\": 4}}]",
            p = prompt.join(",")
        )
        .unwrap();
        let mut line_g = String::new();
        reader.read_line(&mut line_g).unwrap();
        let jg = Json::parse(line_g.trim()).unwrap();
        assert_eq!(jg.as_arr().unwrap().len(), 2);

        // structured metrics reply
        writeln!(c, "{{\"cmd\": \"metrics\"}}").unwrap();
        let mut line_m = String::new();
        reader.read_line(&mut line_m).unwrap();
        let jm = Json::parse(line_m.trim()).unwrap();
        let m = jm.get("metrics").unwrap();
        assert_eq!(m.get("requests").unwrap().as_usize().unwrap(), 5);
        assert!(m.get("ttft_ms_mean").unwrap().as_f64().unwrap() >= 0.0);
        // per-tier keys are always present (zero without memory pressure)
        assert_eq!(m.get("spills").unwrap().as_usize().unwrap(), 0);
        assert_eq!(m.get("prefetches").unwrap().as_usize().unwrap(), 0);
        assert!(m.get("peak_hot_kv_mb").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(m.get("warm_kv_mb").unwrap().as_f64().unwrap(), 0.0);
        // batched decode gauges: the two-request batch line decodes as one
        // bucket group, so occupancy lands in (1, 2] and per-bucket dispatch
        // counts are populated
        assert!(m.get("batch_occupancy").unwrap().as_f64().unwrap() > 1.0);
        assert!(m.get("decode_dispatches_total").unwrap().as_f64().unwrap() > 0.0);
        assert!(m.get("decode_dispatches").unwrap().as_obj().unwrap().len() == 1);
        // worker-pool + tier-thread gauges are always present
        assert!(m.get("workers").unwrap().as_f64().unwrap() >= 1.0);
        assert!(m.get("worker_utilization").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(m.get("tier_spill_queue_depth").unwrap().as_usize().unwrap(), 0);
        assert_eq!(m.get("tier_prefetch_queue_depth").unwrap().as_usize().unwrap(), 0);
        assert!(m.get("tier_busy_ms").unwrap().as_f64().unwrap() >= 0.0);

        writeln!(c, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        handle.join().unwrap();
    }
}
