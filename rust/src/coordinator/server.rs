//! JSON-lines TCP front-end.
//!
//! Protocol (one JSON object per line):
//!   request:  {"prompt": [int, ...], "max_new_tokens": int}
//!             or {"text": "...", "max_new_tokens": int} (byte-level)
//!   response: {"tokens": [...], "text": "...", "prefill_ms": f,
//!              "decode_ms": f, "kv_bytes": n}
//!   control:  {"cmd": "metrics"} | {"cmd": "shutdown"}
//!
//! The engine is single-threaded (one CPU core, one PJRT client); the server
//! accepts connections on the caller's thread and serves requests in order —
//! concurrency across requests happens in the scheduler, not across sockets.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::Result;

use super::engine::{Engine, GenerateRequest};
use crate::model::backend::ModelBackend;
use crate::util::json::{self, Json};

pub struct Server<B: ModelBackend> {
    pub engine: Engine<B>,
}

impl<B: ModelBackend> Server<B> {
    pub fn new(engine: Engine<B>) -> Server<B> {
        Server { engine }
    }

    /// Parse one request line. Exposed for tests.
    pub fn parse_request(&self, line: &str) -> Result<ParsedLine> {
        let j = Json::parse(line)?;
        if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
            return Ok(ParsedLine::Command(cmd.to_string()));
        }
        let max_new = j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(32);
        let prompt: Vec<i32> = if let Some(arr) = j.get("prompt").and_then(|v| v.as_arr()) {
            arr.iter().filter_map(|x| x.as_f64().map(|f| f as i32)).collect()
        } else if let Some(text) = j.get("text").and_then(|v| v.as_str()) {
            text.bytes().map(|b| b as i32).collect()
        } else {
            anyhow::bail!("request needs 'prompt' or 'text'");
        };
        Ok(ParsedLine::Request(GenerateRequest { prompt, max_new_tokens: max_new }))
    }

    /// Serve one request and render the response line. Exposed for tests.
    pub fn handle_request(&mut self, req: &GenerateRequest) -> String {
        match self.engine.generate(req) {
            Ok(r) => {
                let text: String = r
                    .tokens
                    .iter()
                    .filter(|&&t| (0..256).contains(&t))
                    .map(|&t| t as u8 as char)
                    .collect();
                json::to_string(&Json::obj(vec![
                    ("tokens", Json::Arr(r.tokens.iter().map(|&t| Json::num(t as f64)).collect())),
                    ("text", Json::str(text)),
                    ("prefill_ms", Json::num(r.prefill_secs * 1e3)),
                    ("decode_ms", Json::num(r.decode_secs * 1e3)),
                    ("kv_bytes", Json::num(r.kv_bytes_after_prefill as f64)),
                ]))
            }
            Err(e) => json::to_string(&Json::obj(vec![("error", Json::str(format!("{e:#}")))])),
        }
    }

    fn handle_conn(&mut self, stream: TcpStream) -> Result<bool> {
        let peer = stream.peer_addr().ok();
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = match self.parse_request(&line) {
                Ok(ParsedLine::Command(cmd)) if cmd == "shutdown" => {
                    writeln!(writer, "{}", json::to_string(&Json::obj(vec![("ok", Json::Bool(true))])))?;
                    return Ok(true);
                }
                Ok(ParsedLine::Command(cmd)) if cmd == "metrics" => json::to_string(&Json::obj(
                    vec![("metrics", Json::str(self.engine.metrics.report()))],
                )),
                Ok(ParsedLine::Command(cmd)) => {
                    json::to_string(&Json::obj(vec![("error", Json::str(format!("unknown cmd {cmd}")))]))
                }
                Ok(ParsedLine::Request(req)) => self.handle_request(&req),
                Err(e) => json::to_string(&Json::obj(vec![("error", Json::str(format!("{e:#}")))])),
            };
            writeln!(writer, "{reply}")?;
        }
        let _ = peer;
        Ok(false)
    }

    /// Blocking accept loop; returns after a shutdown command.
    pub fn serve(&mut self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("[lava] serving on {addr}");
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    if self.handle_conn(s)? {
                        break;
                    }
                }
                Err(e) => eprintln!("[lava] accept error: {e}"),
            }
        }
        Ok(())
    }
}

pub enum ParsedLine {
    Request(GenerateRequest),
    Command(String),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Policy;
    use crate::coordinator::engine::EngineOptions;
    use crate::model::backend::MockBackend;

    fn server() -> Server<MockBackend> {
        let mock = MockBackend::new(MockBackend::default_config());
        Server::new(Engine::new(
            mock,
            EngineOptions::new(Policy::by_name("lava").unwrap(), 24),
        ))
    }

    #[test]
    fn parses_prompt_and_text() {
        let s = server();
        match s.parse_request(r#"{"prompt": [1,2,3], "max_new_tokens": 5}"#).unwrap() {
            ParsedLine::Request(r) => {
                assert_eq!(r.prompt, vec![1, 2, 3]);
                assert_eq!(r.max_new_tokens, 5);
            }
            _ => panic!(),
        }
        match s.parse_request(r#"{"text": "AB"}"#).unwrap() {
            ParsedLine::Request(r) => {
                assert_eq!(r.prompt, vec![65, 66]);
                assert_eq!(r.max_new_tokens, 32);
            }
            _ => panic!(),
        }
        match s.parse_request(r#"{"cmd": "metrics"}"#).unwrap() {
            ParsedLine::Command(c) => assert_eq!(c, "metrics"),
            _ => panic!(),
        }
        assert!(s.parse_request(r#"{"nope": 1}"#).is_err());
    }

    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{BufRead, BufReader, Write};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let addr_s = format!("{addr}");
        let handle = std::thread::spawn(move || {
            let mut srv = server();
            srv.serve(&addr_s).unwrap();
        });
        // retry-connect until the server binds
        let mut conn = None;
        for _ in 0..100 {
            if let Ok(c) = std::net::TcpStream::connect(addr) {
                conn = Some(c);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let mut c = conn.expect("connect");
        let prompt: Vec<String> = (0..64).map(|i| format!("{}", i % 250)).collect();
        writeln!(c, "{{\"prompt\": [{}], \"max_new_tokens\": 3}}", prompt.join(","))
            .unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        writeln!(c, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        handle.join().unwrap();
    }
}
