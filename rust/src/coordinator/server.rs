//! JSON-lines TCP front-end over the continuous serving loop.
//!
//! Protocol (one JSON value per line):
//!   request:  {"prompt": [int, ...], "max_new_tokens": int}
//!             or {"text": "...", "max_new_tokens": int} (byte-level);
//!             add "stream": true to receive per-token lines
//!   batch:    [request, request, ...] — submitted atomically, so
//!             same-shape-bucket members prefill (and decode) as one group;
//!             the reply is one JSON array of responses in submission order
//!   token:    {"id": n, "token": int, "index": n} — one line per generated
//!             token for requests that set "stream": true, in production
//!             order ("index" is the token's 0-based position in the
//!             output); the final response object still follows and
//!             terminates the stream
//!   response: {"id": n, "status": "completed"|"rejected"|"canceled"|
//!              "failed", "tokens": [...], "text": "...", "prefill_ms": f,
//!              "decode_ms": f, "kv_bytes": n} (plus "error" when not ok;
//!              "id" is null for requests refused at submit time)
//!   control:  {"cmd": "metrics"} | {"cmd": "cancel", "id": n}
//!             | {"cmd": "shutdown"}
//!
//! [`Server::serve`] is an acceptor: every connection gets a reader thread
//! (parses lines, submits to the shared serving loop) and a writer thread
//! (serializes token lines, responses, and command replies onto the
//! socket), all feeding one scheduler owned by the serving-loop thread
//! (see [`super::serve_loop`]). Consequences for clients:
//!
//! * **Connections progress concurrently.** A short request on one
//!   connection completes while a long generation on another is still
//!   decoding; requests from all connections share admission, batching,
//!   and the memory budget.
//! * **Responses on a pipelined connection are matched by id**, not by
//!   line order: a later line's reply may arrive first. Batch replies stay
//!   one array in submission order.
//! * **`cancel` works mid-flight, from any connection.** The scheduler
//!   cancels the session at the next tick boundary, releasing its hot and
//!   warm bytes; the submitting connection still receives the terminal
//!   (canceled, partial-output) response.
//! * **`metrics` never stops the world** — it returns a snapshot copied
//!   between ticks, with in-flight gauges (`active_sessions`,
//!   `queued_requests`, `streamed_tokens`).
//! * **`shutdown` drains.** In-flight sessions run to completion (their
//!   responses are delivered), queued-but-unadmitted requests are
//!   rejected, new submissions are refused; the `{"ok": true}` reply is
//!   sent only after the drain finishes, then the acceptor exits.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::Result;

use super::engine::{Engine, FinishStatus, GenerateRequest, GenerateResult};
use super::metrics::MetricsSnapshot;
use super::scheduler::{Scheduler, SchedulerOptions};
use super::serve_loop::{self, Event, ServeHandle, SubmitItem};
use crate::model::backend::ModelBackend;
use crate::util::json::{self, Json};

pub struct Server<B: ModelBackend> {
    pub sched: Scheduler<B>,
}

impl<B: ModelBackend> Server<B> {
    pub fn new(engine: Engine<B>) -> Server<B> {
        Server::with_options(engine, SchedulerOptions::default())
    }

    pub fn with_options(engine: Engine<B>, opts: SchedulerOptions) -> Server<B> {
        Server { sched: Scheduler::new(engine, opts) }
    }

    /// Parse one request line. Exposed for tests.
    pub fn parse_request(&self, line: &str) -> Result<ParsedLine> {
        parse_line(line)
    }

    /// Drive one batch of requests through the owned scheduler directly
    /// (no serving thread) and render one response per request, in
    /// submission order. The embedder/batch entry point; the TCP path
    /// goes through [`Server::serve`] instead.
    pub fn handle_batch(&mut self, reqs: &[GenerateRequest]) -> Vec<Json> {
        // submission-order slot for every request: either an id to wait for
        // or an immediate submit-error response
        let mut slots: Vec<Result<u64, Json>> = Vec::with_capacity(reqs.len());
        for req in reqs {
            match self.sched.submit(req.clone()) {
                Ok(id) => slots.push(Ok(id)),
                Err(e) => slots.push(Err(submit_error_json(&e))),
            }
        }
        let (finished, engine_err) = match self.sched.run_to_completion() {
            Ok(f) => (f, None),
            // Defensive: the scheduler currently parks every engine error as
            // a Failed result, so this arm should be unreachable — but if a
            // future step does propagate, drain what finished and keep the
            // submit-time rejections intact.
            Err(e) => (self.sched.take_finished(), Some(format!("{e:#}"))),
        };
        slots
            .into_iter()
            .map(|slot| match slot {
                Err(resp) => resp,
                Ok(id) => finished
                    .iter()
                    .find(|(fid, _)| *fid == id)
                    .map(|(_, r)| result_to_json(r))
                    .unwrap_or_else(|| {
                        let detail = engine_err
                            .clone()
                            .unwrap_or_else(|| format!("result lost for id {id}"));
                        Json::obj(vec![
                            ("id", Json::num(id as f64)),
                            ("status", Json::str("failed")),
                            ("error", Json::str(detail)),
                        ])
                    }),
            })
            .collect()
    }
}

impl<B: ModelBackend + 'static> Server<B> {
    /// Bind `addr` and serve until a shutdown command drains the loop.
    pub fn serve(self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("[lava] serving on {addr}");
        self.serve_on(listener)
    }

    /// Accept loop over an already-bound listener: moves the scheduler onto
    /// the serving-loop thread, then spawns one reader/writer thread pair
    /// per connection, all submitting into the shared loop.
    pub fn serve_on(self, listener: TcpListener) -> Result<()> {
        let local_addr = listener.local_addr()?;
        let handle = serve_loop::spawn(self.sched);
        let stop = Arc::new(AtomicBool::new(false));
        for stream in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let handle = handle.clone();
                    let stop = Arc::clone(&stop);
                    let _ = std::thread::Builder::new()
                        .name("lava-conn".to_string())
                        .spawn(move || conn_loop(s, handle, stop, local_addr));
                }
                Err(e) => eprintln!("[lava] accept error: {e}"),
            }
        }
        Ok(())
    }
}

/// Per-connection reader: parse lines, submit requests (registering their
/// reply slots with the writer), answer control commands. The paired
/// writer thread owns the socket's write half so token lines, responses,
/// and command replies never interleave mid-line.
fn conn_loop(stream: TcpStream, handle: ServeHandle, stop: Arc<AtomicBool>, local: SocketAddr) {
    let Ok(write_half) = stream.try_clone() else { return };
    let (wtx, wrx) = channel::<ConnMsg>();
    let writer = match std::thread::Builder::new()
        .name("lava-conn-writer".to_string())
        .spawn(move || writer_loop(write_half, wrx))
    {
        Ok(h) => h,
        Err(e) => {
            eprintln!("[lava] spawn writer: {e}");
            return;
        }
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line) {
            Ok(ParsedLine::Command(cmd, _)) if cmd == "shutdown" => {
                // blocks until in-flight sessions drain (terminal responses
                // have been dispatched to their connections' writers)
                handle.shutdown();
                let _ = wtx.send(ConnMsg::Raw(json::to_string(&Json::obj(vec![(
                    "ok",
                    Json::Bool(true),
                )]))));
                stop.store(true, Ordering::SeqCst);
                // wake the acceptor so serve() observes the stop flag
                let _ = TcpStream::connect(local);
                break;
            }
            Ok(ParsedLine::Command(cmd, _)) if cmd == "metrics" => {
                let reply = match handle.metrics() {
                    Some(snap) => Json::obj(vec![("metrics", metrics_json(&snap))]),
                    None => Json::obj(vec![("error", Json::str("server shutting down"))]),
                };
                let _ = wtx.send(ConnMsg::Raw(json::to_string(&reply)));
            }
            Ok(ParsedLine::Command(cmd, id)) if cmd == "cancel" => {
                let reply = match id {
                    Some(id) => Json::obj(vec![("ok", Json::Bool(handle.cancel(id)))]),
                    None => Json::obj(vec![("error", Json::str("cancel needs an 'id'"))]),
                };
                let _ = wtx.send(ConnMsg::Raw(json::to_string(&reply)));
            }
            Ok(ParsedLine::Command(cmd, _)) => {
                let _ = wtx.send(ConnMsg::Raw(json::to_string(&Json::obj(vec![(
                    "error",
                    Json::str(format!("unknown cmd {cmd}")),
                )]))));
            }
            Ok(ParsedLine::Request(req, stream_tokens)) => {
                let slots = submit_group(&handle, &wtx, vec![(req, stream_tokens)]);
                let _ = wtx.send(ConnMsg::Group { slots, batch: false });
            }
            Ok(ParsedLine::Batch(reqs)) => {
                let slots = submit_group(&handle, &wtx, reqs);
                let _ = wtx.send(ConnMsg::Group { slots, batch: true });
            }
            Err(e) => {
                let _ = wtx.send(ConnMsg::Raw(json::to_string(&Json::obj(vec![(
                    "error",
                    Json::str(format!("{e:#}")),
                )]))));
            }
        }
    }
    let _ = wtx.send(ConnMsg::Close);
    let _ = writer.join();
}

/// Submit one line's requests as an atomic group; each request's events
/// flow to this connection's writer. Returns the reply slot per request:
/// an id to await, or an immediate rejection response.
fn submit_group(
    handle: &ServeHandle,
    wtx: &Sender<ConnMsg>,
    reqs: Vec<(GenerateRequest, bool)>,
) -> Vec<Slot> {
    let items: Vec<SubmitItem> = reqs
        .into_iter()
        .map(|(req, stream)| {
            let tx = wtx.clone();
            SubmitItem {
                req,
                stream,
                sink: Box::new(move |ev| {
                    // the writer going away must not poison the serving loop
                    let _ = tx.send(ConnMsg::Event(ev));
                }),
            }
        })
        .collect();
    handle
        .submit_many(items)
        .into_iter()
        .map(|res| match res {
            Ok(id) => Slot::Wait(id),
            Err(e) => Slot::Ready(submit_error_json(&e)),
        })
        .collect()
}

/// What the reader and the serving loop hand the writer thread.
enum ConnMsg {
    /// An immediate reply line (command replies, parse errors).
    Raw(String),
    /// One request line's pending reply slots, in submission order.
    Group { slots: Vec<Slot>, batch: bool },
    /// A serving-loop event for one of this connection's requests.
    Event(Event),
    /// Reader finished; flush and exit.
    Close,
}

enum Slot {
    Ready(Json),
    Wait(u64),
}

struct PendingGroup {
    slots: Vec<Slot>,
    batch: bool,
}

impl PendingGroup {
    fn waits_on(&self, id: u64) -> bool {
        self.slots.iter().any(|s| matches!(s, Slot::Wait(w) if *w == id))
    }

    fn fill(&mut self, id: u64, json: Json) {
        if let Some(i) =
            self.slots.iter().position(|s| matches!(s, Slot::Wait(w) if *w == id))
        {
            self.slots[i] = Slot::Ready(json);
        }
    }

    fn complete(&self) -> bool {
        self.slots.iter().all(|s| matches!(s, Slot::Ready(_)))
    }

    /// One reply line: the bare response for a single request, an array in
    /// submission order for a batch line.
    fn render(self) -> Json {
        let PendingGroup { slots, batch } = self;
        let mut items: Vec<Json> = slots
            .into_iter()
            .map(|s| match s {
                Slot::Ready(j) => j,
                Slot::Wait(_) => Json::Null,
            })
            .collect();
        if batch {
            Json::Arr(items)
        } else {
            items.pop().unwrap_or(Json::Null)
        }
    }
}

/// Connection writer: the single owner of the socket's write half. Token
/// events stream out immediately; terminal results fill their group's slot
/// and the group is written once every slot is ready. Results that arrive
/// before their group registration (the serving loop races the reader's
/// Group message) wait in a stash.
fn writer_loop(mut out: TcpStream, rx: Receiver<ConnMsg>) {
    let mut pending: Vec<PendingGroup> = Vec::new();
    let mut stash: HashMap<u64, Json> = HashMap::new();
    for msg in rx {
        let ok = match msg {
            ConnMsg::Raw(line) => writeln!(out, "{line}").is_ok(),
            ConnMsg::Group { mut slots, batch } => {
                for slot in &mut slots {
                    if let Slot::Wait(id) = slot {
                        if let Some(j) = stash.remove(id) {
                            *slot = Slot::Ready(j);
                        }
                    }
                }
                let group = PendingGroup { slots, batch };
                if group.complete() {
                    writeln!(out, "{}", json::to_string(&group.render())).is_ok()
                } else {
                    pending.push(group);
                    true
                }
            }
            ConnMsg::Event(Event::Token { id, token, index }) => writeln!(
                out,
                "{}",
                json::to_string(&Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("token", Json::num(token as f64)),
                    ("index", Json::num(index as f64)),
                ]))
            )
            .is_ok(),
            ConnMsg::Event(Event::Finished { id, result }) => {
                let rendered = result_to_json(&result);
                match pending.iter().position(|g| g.waits_on(id)) {
                    Some(gi) => {
                        pending[gi].fill(id, rendered);
                        if pending[gi].complete() {
                            let group = pending.remove(gi);
                            writeln!(out, "{}", json::to_string(&group.render())).is_ok()
                        } else {
                            true
                        }
                    }
                    None => {
                        stash.insert(id, rendered);
                        true
                    }
                }
            }
            ConnMsg::Close => break,
        };
        if !ok {
            break;
        }
    }
}

fn metrics_json(snap: &MetricsSnapshot) -> Json {
    let m = &snap.metrics;
    Json::obj(vec![
        ("requests", Json::num(m.requests_finished as f64)),
        ("rejected", Json::num(m.requests_rejected as f64)),
        ("canceled", Json::num(m.requests_canceled as f64)),
        ("failed", Json::num(m.requests_failed as f64)),
        ("tokens", Json::num(m.tokens_generated as f64)),
        // in-flight gauges: live state at snapshot time, plus tokens
        // pushed to streaming subscribers so far
        ("active_sessions", Json::num(snap.active_sessions as f64)),
        ("queued_requests", Json::num(snap.queued_requests as f64)),
        ("streamed_tokens", Json::num(m.streamed_tokens as f64)),
        ("ttft_ms_mean", Json::num(m.mean_ttft_ms())),
        ("ttft_ms_p99", Json::num(m.p99_ttft_ms())),
        ("queue_wait_ms_mean", Json::num(m.mean_queue_wait_ms())),
        ("prefill_ms_mean", Json::num(m.mean_prefill_ms())),
        ("decode_ms_mean", Json::num(m.mean_decode_ms())),
        ("decode_ms_p99", Json::num(m.p99_decode_ms())),
        ("decode_tok_s", Json::num(m.decode_tok_per_sec())),
        ("peak_kv_mb", Json::num(m.peak_kv_bytes as f64 / 1e6)),
        ("admission_rounds", Json::num(m.admission_rounds as f64)),
        ("decode_steps", Json::num(m.decode_steps as f64)),
        // batched decode execution: groups run, mean sessions per group,
        // and backend dispatch counts keyed by capacity bucket
        ("decode_batches", Json::num(m.decode_batches as f64)),
        ("batch_occupancy", Json::num(m.batch_occupancy())),
        ("decode_dispatches_total", Json::num(m.decode_dispatches_total() as f64)),
        (
            "decode_dispatches",
            Json::Obj(
                m.decode_dispatches
                    .iter()
                    .map(|(bucket, n)| (bucket.to_string(), Json::num(*n as f64)))
                    .collect(),
            ),
        ),
        // prefill bucket waste: padded tokens across all prefill
        // dispatches, overall utilization (valid / dispatched), and the
        // per-bucket dispatch/valid/padded breakdown — the gauges that
        // make the chunked-prefill win measurable
        ("prefill_padded_tokens", Json::num(m.prefill_padded_tokens as f64)),
        ("prefill_bucket_util", Json::num(m.prefill_bucket_utilization())),
        (
            "prefill_fills",
            Json::Obj(
                m.prefill_fills
                    .iter()
                    .map(|(bucket, f)| {
                        (
                            bucket.to_string(),
                            Json::obj(vec![
                                ("dispatches", Json::num(f.dispatches as f64)),
                                ("valid_tokens", Json::num(f.valid_tokens as f64)),
                                ("padded_tokens", Json::num(f.padded_tokens as f64)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
        // streaming prefill compression: last/peak carry transient (bounded
        // by the working cap under `prefill_stream_evict`, O(prompt)
        // otherwise) and the cross-session chunk-batching counters
        ("prefill_transient_mb", Json::num(m.prefill_transient_bytes as f64 / 1e6)),
        (
            "peak_prefill_transient_mb",
            Json::num(m.peak_prefill_transient_bytes as f64 / 1e6),
        ),
        // the full prefill resident set (carries + panels + hidden rows):
        // flat in prompt length under chunk-major streaming
        ("prefill_resident_mb", Json::num(m.prefill_resident_bytes as f64 / 1e6)),
        (
            "peak_prefill_resident_mb",
            Json::num(m.peak_prefill_resident_bytes as f64 / 1e6),
        ),
        ("prefill_chunk_batches", Json::num(m.prefill_chunk_batches as f64)),
        ("prefill_chunk_occupancy", Json::num(m.prefill_chunk_batch_occupancy())),
        (
            "prefill_chunk_dispatches",
            Json::num(m.prefill_chunk_batch_dispatches as f64),
        ),
        // per-tier state: hot is what kv_mem_limit bounds; warm holds
        // Q8-spilled layer caches
        ("deferred", Json::num(m.requests_deferred as f64)),
        ("hot_kv_mb", Json::num(m.hot_kv_bytes as f64 / 1e6)),
        ("peak_hot_kv_mb", Json::num(m.peak_hot_kv_bytes as f64 / 1e6)),
        ("warm_kv_mb", Json::num(m.warm_kv_bytes as f64 / 1e6)),
        ("peak_warm_kv_mb", Json::num(m.peak_warm_kv_bytes as f64 / 1e6)),
        ("spills", Json::num(m.spills as f64)),
        ("prefetches", Json::num(m.prefetches as f64)),
        ("spilled_mb", Json::num(m.spilled_bytes as f64 / 1e6)),
        ("prefetched_mb", Json::num(m.prefetched_bytes as f64 / 1e6)),
        ("spill_ms_mean", Json::num(m.mean_spill_ms())),
        ("prefetch_ms_mean", Json::num(m.mean_prefetch_ms())),
        // worker pool: width, per-worker cumulative busy time, and the
        // mean fraction of the pool kept busy during fan-outs
        ("workers", Json::num(m.workers as f64)),
        ("worker_utilization", Json::num(m.worker_utilization())),
        ("worker_rounds", Json::num(m.worker_rounds as f64)),
        (
            "worker_busy_secs",
            Json::Arr(m.worker_busy_secs.iter().map(|&b| Json::num(b)).collect()),
        ),
        // persistent pool: units pulled per slot (work-stealing balance),
        // deepest injector queue, lifetime park/unpark churn, and the
        // mean per-round dispatch overhead the spawn-free path shrinks
        (
            "worker_units",
            Json::Arr(m.worker_units.iter().map(|&n| Json::num(n as f64)).collect()),
        ),
        ("pool_queue_depth_peak", Json::num(m.pool_queue_depth_peak as f64)),
        ("pool_parks", Json::num(m.pool_parks as f64)),
        ("pool_unparks", Json::num(m.pool_unparks as f64)),
        ("pool_dispatch_ms_mean", Json::num(m.mean_dispatch_overhead_ms())),
        // tier thread: command-queue backlogs (sampled at tick end),
        // their observed peak, and background quantize/dequantize time
        ("tier_spill_queue_depth", Json::num(m.tier_spill_queue_depth as f64)),
        ("tier_prefetch_queue_depth", Json::num(m.tier_prefetch_queue_depth as f64)),
        ("tier_queue_depth_peak", Json::num(m.tier_queue_depth_peak as f64)),
        ("tier_staged_mb", Json::num(m.tier_staged_bytes as f64 / 1e6)),
        ("peak_tier_staged_mb", Json::num(m.peak_tier_staged_bytes as f64 / 1e6)),
        ("tier_busy_ms", Json::num(m.tier_busy_secs * 1e3)),
        ("report", Json::str(m.report())),
    ])
}

/// Parse one protocol line into a request (+ stream flag), a batch, or a
/// control command.
pub fn parse_line(line: &str) -> Result<ParsedLine> {
    let j = Json::parse(line)?;
    if let Some(batch) = j.as_arr() {
        let reqs: Result<Vec<(GenerateRequest, bool)>> =
            batch.iter().map(request_from_json).collect();
        return Ok(ParsedLine::Batch(reqs?));
    }
    if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
        let id = j.get("id").and_then(|v| v.as_usize()).map(|v| v as u64);
        return Ok(ParsedLine::Command(cmd.to_string(), id));
    }
    let (req, stream) = request_from_json(&j)?;
    Ok(ParsedLine::Request(req, stream))
}

fn request_from_json(j: &Json) -> Result<(GenerateRequest, bool)> {
    let max_new = j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(32);
    let stream = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
    let prompt: Vec<i32> = if let Some(arr) = j.get("prompt").and_then(|v| v.as_arr()) {
        arr.iter().filter_map(|x| x.as_f64().map(|f| f as i32)).collect()
    } else if let Some(text) = j.get("text").and_then(|v| v.as_str()) {
        text.bytes().map(|b| b as i32).collect()
    } else {
        anyhow::bail!("request needs 'prompt' or 'text'");
    };
    Ok((GenerateRequest { prompt, max_new_tokens: max_new }, stream))
}

/// Response for a request refused before an id was assigned.
fn submit_error_json(e: &impl std::fmt::Display) -> Json {
    Json::obj(vec![
        ("id", Json::Null),
        ("status", Json::str("rejected")),
        ("error", Json::str(format!("{e}"))),
    ])
}

fn status_str(s: FinishStatus) -> &'static str {
    match s {
        FinishStatus::Completed => "completed",
        FinishStatus::Rejected => "rejected",
        FinishStatus::Canceled => "canceled",
        FinishStatus::Failed => "failed",
    }
}

fn result_to_json(r: &GenerateResult) -> Json {
    let text: String = r
        .tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8 as char)
        .collect();
    let mut pairs = vec![
        ("id", Json::num(r.id as f64)),
        ("status", Json::str(status_str(r.status))),
        ("tokens", Json::Arr(r.tokens.iter().map(|&t| Json::num(t as f64)).collect())),
        ("text", Json::str(text)),
        ("prefill_ms", Json::num(r.prefill_secs * 1e3)),
        ("decode_ms", Json::num(r.decode_secs * 1e3)),
        ("kv_bytes", Json::num(r.kv_bytes_after_prefill as f64)),
    ];
    if let Some(e) = &r.error {
        pairs.push(("error", Json::str(e.clone())));
    }
    Json::obj(pairs)
}

pub enum ParsedLine {
    /// A single request and whether it opted into per-token streaming.
    Request(GenerateRequest, bool),
    /// A batch line: requests with their stream flags, submission order.
    Batch(Vec<(GenerateRequest, bool)>),
    Command(String, Option<u64>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Policy;
    use crate::coordinator::engine::EngineOptions;
    use crate::model::backend::MockBackend;

    fn server() -> Server<MockBackend> {
        let mock = MockBackend::new(MockBackend::default_config());
        Server::new(Engine::new(
            mock,
            EngineOptions::new(Policy::by_name("lava").unwrap(), 24),
        ))
    }

    #[test]
    fn parses_prompt_and_text() {
        let s = server();
        match s.parse_request(r#"{"prompt": [1,2,3], "max_new_tokens": 5}"#).unwrap() {
            ParsedLine::Request(r, stream) => {
                assert_eq!(r.prompt, vec![1, 2, 3]);
                assert_eq!(r.max_new_tokens, 5);
                assert!(!stream, "stream defaults to off");
            }
            _ => panic!(),
        }
        match s.parse_request(r#"{"text": "AB", "stream": true}"#).unwrap() {
            ParsedLine::Request(r, stream) => {
                assert_eq!(r.prompt, vec![65, 66]);
                assert_eq!(r.max_new_tokens, 32);
                assert!(stream);
            }
            _ => panic!(),
        }
        match s.parse_request(r#"{"cmd": "metrics"}"#).unwrap() {
            ParsedLine::Command(c, _) => assert_eq!(c, "metrics"),
            _ => panic!(),
        }
        match s.parse_request(r#"{"cmd": "cancel", "id": 7}"#).unwrap() {
            ParsedLine::Command(c, id) => {
                assert_eq!(c, "cancel");
                assert_eq!(id, Some(7));
            }
            _ => panic!(),
        }
        match s
            .parse_request(
                r#"[{"prompt": [1,2], "max_new_tokens": 2}, {"text": "A", "stream": true}]"#,
            )
            .unwrap()
        {
            ParsedLine::Batch(rs) => {
                assert_eq!(rs.len(), 2);
                assert!(!rs[0].1);
                assert!(rs[1].1, "per-request stream flags in a batch");
            }
            _ => panic!(),
        }
        assert!(s.parse_request(r#"{"nope": 1}"#).is_err());
    }

    #[test]
    fn batch_replies_in_submission_order_with_ids() {
        let mut s = server();
        let reqs: Vec<GenerateRequest> = (0..3)
            .map(|i| GenerateRequest {
                prompt: (0..100).map(|t| (t % 250) as i32).collect(),
                max_new_tokens: i + 1,
            })
            .collect();
        let resps = s.handle_batch(&reqs);
        assert_eq!(resps.len(), 3);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.get("status").unwrap().as_str().unwrap(), "completed");
            assert_eq!(
                r.get("tokens").unwrap().as_arr().unwrap().len(),
                i + 1,
                "response {i} must map back to its submission"
            );
            assert_eq!(r.get("id").unwrap().as_usize().unwrap(), i + 1);
        }
    }

    #[test]
    fn end_to_end_over_tcp() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            server().serve_on(listener).unwrap();
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        let prompt: Vec<String> = (0..64).map(|i| format!("{}", i % 250)).collect();
        writeln!(c, "{{\"prompt\": [{}], \"max_new_tokens\": 3}}", prompt.join(","))
            .unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("status").unwrap().as_str().unwrap(), "completed");
        assert!(j.get("id").unwrap().as_usize().unwrap() >= 1);

        // a batch line gets an array reply, in submission order
        writeln!(
            c,
            "[{{\"prompt\": [{p}], \"max_new_tokens\": 1}}, {{\"prompt\": [{p}], \"max_new_tokens\": 2}}]",
            p = prompt.join(",")
        )
        .unwrap();
        let mut line_b = String::new();
        reader.read_line(&mut line_b).unwrap();
        let jb = Json::parse(line_b.trim()).unwrap();
        let arr = jb.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("tokens").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(arr[1].get("tokens").unwrap().as_arr().unwrap().len(), 2);

        // a same-bucket batch that decodes together exercises the grouped
        // decode path end-to-end (occupancy > 1 in the metrics below)
        writeln!(
            c,
            "[{{\"prompt\": [{p}], \"max_new_tokens\": 4}}, {{\"prompt\": [{p}], \"max_new_tokens\": 4}}]",
            p = prompt.join(",")
        )
        .unwrap();
        let mut line_g = String::new();
        reader.read_line(&mut line_g).unwrap();
        let jg = Json::parse(line_g.trim()).unwrap();
        assert_eq!(jg.as_arr().unwrap().len(), 2);

        // a streamed request: one token line per generated token, indexed
        // 0.., then the terminal response with the same tokens
        writeln!(
            c,
            "{{\"prompt\": [{p}], \"max_new_tokens\": 3, \"stream\": true}}",
            p = prompt.join(",")
        )
        .unwrap();
        let mut streamed = Vec::new();
        let terminal = loop {
            let mut l = String::new();
            reader.read_line(&mut l).unwrap();
            let v = Json::parse(l.trim()).unwrap();
            if v.get("status").is_some() {
                break v;
            }
            assert_eq!(v.get("index").unwrap().as_usize().unwrap(), streamed.len());
            streamed.push(v.get("token").unwrap().as_f64().unwrap() as i32);
        };
        let final_tokens: Vec<i32> = terminal
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(streamed, final_tokens, "stream must equal the final token list");

        // structured metrics reply
        writeln!(c, "{{\"cmd\": \"metrics\"}}").unwrap();
        let mut line_m = String::new();
        reader.read_line(&mut line_m).unwrap();
        let jm = Json::parse(line_m.trim()).unwrap();
        let m = jm.get("metrics").unwrap();
        assert_eq!(m.get("requests").unwrap().as_usize().unwrap(), 6);
        assert!(m.get("ttft_ms_mean").unwrap().as_f64().unwrap() >= 0.0);
        // in-flight gauges: everything retired by now, 3 tokens streamed
        assert_eq!(m.get("active_sessions").unwrap().as_usize().unwrap(), 0);
        assert_eq!(m.get("queued_requests").unwrap().as_usize().unwrap(), 0);
        assert_eq!(m.get("streamed_tokens").unwrap().as_usize().unwrap(), 3);
        // per-tier keys are always present (zero without memory pressure)
        assert_eq!(m.get("spills").unwrap().as_usize().unwrap(), 0);
        assert_eq!(m.get("prefetches").unwrap().as_usize().unwrap(), 0);
        assert!(m.get("peak_hot_kv_mb").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(m.get("warm_kv_mb").unwrap().as_f64().unwrap(), 0.0);
        // batched decode gauges: the two-request batch line decodes as one
        // bucket group, so occupancy lands in (1, 2] and per-bucket dispatch
        // counts are populated
        assert!(m.get("batch_occupancy").unwrap().as_f64().unwrap() > 1.0);
        assert!(m.get("decode_dispatches_total").unwrap().as_f64().unwrap() > 0.0);
        assert!(m.get("decode_dispatches").unwrap().as_obj().unwrap().len() == 1);
        // worker-pool + tier-thread gauges are always present
        assert!(m.get("workers").unwrap().as_f64().unwrap() >= 1.0);
        assert!(m.get("worker_utilization").unwrap().as_f64().unwrap() >= 0.0);
        // persistent-pool gauges are present even when the serving loop
        // never fanned out (all zero then)
        assert!(m.get("worker_units").unwrap().as_arr().is_some());
        assert!(m.get("pool_queue_depth_peak").unwrap().as_f64().unwrap() >= 0.0);
        assert!(m.get("pool_parks").unwrap().as_f64().unwrap() >= 0.0);
        assert!(m.get("pool_unparks").unwrap().as_f64().unwrap() >= 0.0);
        assert!(m.get("pool_dispatch_ms_mean").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(m.get("tier_spill_queue_depth").unwrap().as_usize().unwrap(), 0);
        assert_eq!(m.get("tier_prefetch_queue_depth").unwrap().as_usize().unwrap(), 0);
        assert!(m.get("tier_busy_ms").unwrap().as_f64().unwrap() >= 0.0);

        writeln!(c, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        let js = Json::parse(line2.trim()).unwrap();
        assert_eq!(js.get("ok").unwrap().as_bool(), Some(true));
        handle.join().unwrap();
    }
}
