//! The serving engine: layer-wise prefill with cascading compression
//! (Algorithm 2) + the serial and batched decode paths, generic over the
//! model backend.
//!
//! Prefill of an n-token prompt, with total cache budget 𝔹:
//!   1. embed host-side, pick the shape bucket;
//!   2. for each layer l: run `layer_prefill_{N}`, score the layer's cache
//!      entries under the configured policy (Algorithm 1), and
//!        - static layer budgets (uniform/pyramid): evict once to B_l;
//!        - dynamic layer budgets (LAVa entropy / CAKE): recompute the
//!          budget split over layers 0..=l from the accumulated layer
//!          weights and *recompress* earlier layers with their stored
//!          scores (window entries are pinned at +inf) — Algorithm 2;
//!   3. final-layer hidden state -> logits -> first generated token.
//!
//! Peak memory therefore never exceeds (retained caches) + (one
//! uncompressed layer), which is exactly the property Fig. 3 measures.
//!
//! ## Chunked prefill: carry-in K/V, incremental observations
//!
//! The monolithic path above rounds the whole prompt up to one prefill
//! bucket and holds the scheduler for its full duration. The chunked path
//! ([`EngineWorker::begin_chunked_prefill`] /
//! [`EngineWorker::advance_chunked_prefill`]) splits the same work into a
//! resumable state machine ([`super::session::ChunkedPrefill`], phase
//! `Prefilling { next_chunk }`) the scheduler can advance a few chunks at a
//! time between decode rounds. The loop is layer-outer / chunk-inner:
//!
//!   1. each chunk embeds into a *tight* chunk bucket and dispatches
//!      `layer_prefill_chunked` with the layer's **carry-in K/V** — the
//!      `[Hk, n_obs, dh]` accumulation of all prior chunks' keys/values
//!      (`n_obs` = the monolithic prefill bucket), which the backend
//!      attends over (rows ≥ the chunk's start are never read);
//!   2. the chunk's observation contributions accumulate additively:
//!      window-attention rows land whole in the chunk owning their query
//!      position, acc-attention/value-norm columns in the chunk owning the
//!      position — so when the last chunk lands, `LayerObs` is
//!      *bit-identical* to the monolithic `layer_prefill` output;
//!   3. layer completion then runs the exact same code as the monolithic
//!      path (`compress_prefilled_layer`: Algorithm 1 scoring, Eq. 7
//!      entropy weights, the Algorithm 2 recompression cascade), yielding
//!      identical tokens, budgets, and keep-sets at every chunk size.
//!
//! The carry K/V is the layer's uncompressed cache and stays O(prompt);
//! what chunking buys is tight dispatch shapes (a 4 097-token prompt no
//! longer pays for the 8 192 bucket on every layer), prompts longer than
//! the largest prefill bucket (`n_obs` falls back to the exact prompt
//! length), and — via the scheduler interleaving — decode rounds that are
//! no longer head-of-line-blocked by long prompts.
//!
//! ## Streaming eviction: bounded carry, mid-prefill compression
//!
//! The streaming-evict mode ([`EngineWorker::begin_chunked_prefill_stream`],
//! gated by the scheduler's `prefill_stream_evict`) additionally bounds the
//! carry itself. The carry is a *compacted* column space at a fixed working
//! cap (`[Hk, cap, dh]`, cap = budget union + one chunk bucket + window,
//! rounded up to a backend-supported cap): live columns are packed at the
//! front in ascending position order with `col_pos` mapping them back to
//! absolute prompt positions. The per-chunk state machine becomes:
//!
//!   1. dispatch `layer_prefill_chunked_evict` with the compacted carry and
//!      the position map; the backend reports observation panels over the
//!      *compact* columns (mass at carry columns is **added**, the chunk's
//!      own columns append);
//!   2. after each non-final chunk, if the live columns exceed the budget
//!      union, run Algorithm 1 over the tokens seen so far — the trailing
//!      observation window (the still-unscored suffix) is position-pinned
//!      by `select_prefill` — and compact every panel plus the carry K/V
//!      down to the per-head keep-set union;
//!   3. the final chunk of a layer skips the pre-evict and runs the same
//!      compression cascade as the plain path over the surviving columns
//!      (`compress_streamed_layer`): Eq. 7 weights, the Algorithm 2
//!      resplit, and a cache load that rewrites slot positions from
//!      `col_pos`.
//!
//! The per-layer transient is therefore retained caches + at most `cap`
//! carry columns — flat in prompt length, unlike the plain chunked carry.
//! The trade: results are *not* bit-identical to the monolithic pass (a
//! mid-prefill eviction cannot see future tokens), which is why the mode is
//! opt-in and the gate-off path stays byte-for-byte untouched.
//! Cross-session chunk batching rides on the same geometry: sessions whose
//! next dispatch shares a lockstep key (layer, chunk cursor, chunk shape,
//! cap) advance through one `layer_prefill_chunked_evict_batched` call
//! ([`EngineWorker::advance_stream_group`]).
//!
//! ## Chunk-major streaming: the whole resident set goes flat
//!
//! Layer-major streaming bounds the *carry*, but still holds the full
//! prompt's hidden rows (`x`/`x_next`, 2·n·d floats) across all layers, so
//! total prefill RSS stays O(prompt). The streaming **default** is
//! therefore chunk-major ([`EngineWorker::advance_chunk_major`], opt out
//! via `stream_layer_major` / `LAVA_STREAM_LAYER_MAJOR`): each chunk flows
//! through all L layers in one pass, with one bounded carry lane per layer
//! ([`super::session::StreamLayer`]). The memory model becomes
//!
//!   * hidden rows: one chunk bucket in, one chunk bucket out — never the
//!     prompt (`finish_chunked` keeps only the last row for the logits);
//!   * carries + panels: L lanes × `cap` columns, each compacted after
//!     every non-final pass exactly as layer-major does per layer;
//!   * so the *entire* prefill resident set (`prefill_resident_bytes`) is
//!     flat in prompt length — admission can price million-token prompts
//!     at the same fixed cost as short ones.
//!
//! Because mid-stream evictions use the constant budget union (never the
//! evolving per-layer budgets) and the final pass compresses lanes in
//! ascending layer order, the compression call sequence is *identical* to
//! layer-major: tokens, budgets, and keep-sets match between the two orders.
//! With `carry_q8` / `LAVA_CARRY_Q8` on, lanes additionally hold their
//! columns as Q8 codes + scales ([`crate::kvcache::Q8Carry`], the warm
//! tier's block layout) between passes — roughly halving the lane bytes —
//! dequantizing into the executing worker's dequant arena
//! ([`super::pool::WorkerScratch`]) at dispatch and re-quantizing only the
//! columns the chunk landed or the cascade moved.
//!
//! ## Decode: gather → one dispatch per layer → scatter
//!
//! [`EngineWorker::decode_step_batch`] advances B sessions sharing a
//! capacity bucket (equal [`Session::capacity_signature`]) by one token
//! each:
//!
//!   1. **gather** — embed each session's last token host-side and pack the
//!      rows into one [B, d] residual-stream tensor;
//!   2. **dispatch** — per layer, issue a single
//!      `layer_decode_batched_{M}x{B}` call over the packed input and a
//!      zero-copy [`crate::kvcache::BatchDecodeView`] of the B caches
//!      (L dispatches per round instead of B·L);
//!   3. **scatter** — split the per-session attention rows back out and run
//!      each cache's score update / append / decode-eviction independently
//!      (LAVa's layer-level scores keep per-session eviction state
//!      independent, so batching the forward pass changes nothing else).
//!
//! [`EngineWorker::decode_step`] is the serial form (one session, one
//! `layer_decode_{M}` per layer). Both paths share the same scatter helper
//! and must stay *bit-identical* per session — `tests/batched_decode.rs`
//! enforces it for every decode-evicting and static policy.
//!
//! ## Engine front vs. engine workers
//!
//! [`Engine`] is the scheduler-facing front: it owns the backend, the
//! options, the [`Metrics`] sink, and the session-id counter. All the
//! *compute* — prefill, serial decode, batched decode — lives on
//! [`EngineWorker`], a `Copy` view (`&backend`, `&options`) that needs only
//! `&self`, so N workers can run different capacity-bucket groups (or
//! different prefills) concurrently against one shared backend
//! ([`crate::model::backend::ModelBackend`] is `Send + Sync`). A worker
//! returns a [`StepReport`]/[`PrefillReport`] of everything it observed;
//! the serving thread merges reports into [`Metrics`] in plan order, so
//! metric totals are independent of worker interleaving. Every dispatching
//! worker method takes a [`WorkerContext`] — the executing pool worker's
//! persistent identity: its pinned backend device slot (bound lazily, once
//! per context, via `ModelBackend::bind_device`) and its reusable scratch
//! arenas (score buffers, Q8 dequant tensors), which replace the old
//! per-session scratch allocations. The `&mut self` methods on [`Engine`]
//! are the single-threaded composition of the two (compute + absorb),
//! running on the engine's own serving-thread context — the canonical
//! serial path.

use anyhow::{anyhow, bail, Result};

use super::metrics::Metrics;
use super::pool::WorkerContext;
use super::session::{ChunkedPrefill, Phase, Session, StreamLayer, StreamPrefill};
use crate::compress::score::ScoreScratch;
use crate::compress::select::{select_prefill, select_recompress, KeepSet};
use crate::compress::{alloc, score, LayerAlloc, LayerObs, Policy, ScoreKind};
use crate::kvcache::tier::Residency;
use crate::kvcache::HotStore;
use crate::model::backend::{ChunkEvictOut, ChunkEvictReq, ModelBackend};
use crate::model::ModelConfig;
use crate::runtime::{Runtime, Tensor};

#[derive(Debug, Clone)]
pub struct EngineOptions {
    pub policy: Policy,
    /// Per-kv-head, per-layer entry budget b; 𝔹 = b * H_k * L. The paper's
    /// "𝔹 = 128HL" rows correspond to b = 128 (we scale b with context).
    pub budget_per_head: usize,
    /// Default generation length when the request does not specify one.
    pub max_new_tokens: usize,
    /// Pool kernel for score smoothing (paper: 7).
    pub pool_kernel: usize,
    /// Use the fused L1 lava_score artifact when available.
    pub use_fused_score: bool,
    /// Keep the PR 8 layer-major streaming order (one carry lane reset
    /// between layers, O(prompt) hidden rows) instead of the chunk-major
    /// default. Env: `LAVA_STREAM_LAYER_MAJOR`. Off by default — chunk-major
    /// makes the whole prefill resident set flat in prompt length.
    pub stream_layer_major: bool,
    /// Q8-quantize the compacted carries between chunk-major streaming
    /// dispatches (reuses the warm tier's block quantization; roughly halves
    /// the bounded lane bytes). Env: `LAVA_CARRY_Q8`. No effect on the
    /// layer-major or non-streaming paths.
    pub carry_q8: bool,
}

impl EngineOptions {
    pub fn new(policy: Policy, budget_per_head: usize) -> EngineOptions {
        EngineOptions {
            policy,
            budget_per_head,
            max_new_tokens: 32,
            pool_kernel: 7,
            use_fused_score: true,
            stream_layer_major: env_flag("LAVA_STREAM_LAYER_MAJOR"),
            carry_q8: env_flag("LAVA_CARRY_Q8"),
        }
    }
}

/// Boolean env knob: unset or `0` = off, any other parsable integer = on.
/// Unparsable values warn and stay off (never silently change behavior).
fn env_flag(name: &str) -> bool {
    match std::env::var(name) {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) => n != 0,
            Err(_) => {
                eprintln!("warning: {name}={v} is not an integer; treating as off");
                false
            }
        },
        Err(_) => false,
    }
}

#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// How a request's lifecycle ended. Non-`Completed` results carry whatever
/// was generated before the cut (empty for admission rejections).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishStatus {
    Completed,
    /// Refused by admission control (can never fit, invalid prompt, ...).
    Rejected,
    /// Cut short by an explicit cancel.
    Canceled,
    /// The engine errored mid-flight (prefill or decode); other sessions
    /// are unaffected.
    Failed,
}

#[derive(Debug, Clone)]
pub struct GenerateResult {
    /// The id handed out at submission; stable through deferral/requeue.
    pub id: u64,
    pub status: FinishStatus,
    /// Rejection/cancellation detail (None on the happy path).
    pub error: Option<String>,
    pub tokens: Vec<i32>,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub kv_bytes_after_prefill: usize,
    pub peak_kv_bytes: usize,
    pub budgets: Vec<usize>,
}

/// Everything one worker-side decode step observed, merged into [`Metrics`]
/// on the serving thread (via [`Engine::absorb_step`]) so workers never
/// contend on the metrics sink.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// Next token per session, in batch order.
    pub tokens: Vec<i32>,
    /// Backend decode dispatches as (capacity bucket, count), one entry per
    /// layer, in layer order.
    pub dispatches: Vec<(usize, u64)>,
    /// Per-session hot KV bytes after the step, in batch order.
    pub kv_after: Vec<usize>,
    /// Sessions this execution covered (1 = the serial path).
    pub sessions: usize,
}

/// What one worker-side prefill observed (merged by [`Engine::absorb_prefill`]).
#[derive(Debug, Clone)]
pub struct PrefillReport {
    /// First generated token.
    pub token: i32,
    /// Peak transient bytes: retained caches + one uncompressed layer.
    pub peak_transient: usize,
    /// Live KV bytes after compression settled.
    pub live_after: usize,
    /// One `(prefill bucket, valid tokens)` pair per backend prefill
    /// dispatch (monolithic: L entries at the prompt bucket; chunked: one
    /// per chunk per layer at the tight chunk bucket) — feeds the
    /// bucket-waste gauges.
    pub bucket_fills: Vec<(usize, usize)>,
    /// Peak bytes of the uncompressed carry K/V alone (no retained caches):
    /// O(prompt) on the monolithic/plain-chunked paths, bounded by the
    /// working cap under streaming eviction — feeds the
    /// `prefill_transient_bytes` gauge the bounded-transient claim is
    /// measured on.
    pub carry_peak_bytes: usize,
    /// Peak prefill *resident* bytes over and above the retained caches:
    /// carry K/V (f32 tensors or Q8 codes + scales at their allocated
    /// width), observation panels, and hidden-state rows — the full working
    /// set `carry_peak_bytes` undercounts. Flat in prompt length on the
    /// chunk-major streaming path, O(prompt) everywhere else; feeds the
    /// `prefill_resident_bytes` gauge admission pricing mirrors.
    pub resident_peak_bytes: usize,
}

/// Shareable, `Copy` compute view of the engine: backend + options, no
/// metrics, no id counter. Everything here takes `&self`, so the worker
/// pool can run many of these concurrently over disjoint sessions. Each
/// method returns a report for the serving thread to merge.
pub struct EngineWorker<'a, B: ModelBackend> {
    pub backend: &'a B,
    pub opts: &'a EngineOptions,
}

// manual impls: deriving would demand `B: Clone`/`B: Copy`, but the worker
// only holds references, which are Copy for any `B`
#[allow(clippy::expl_impl_clone_on_copy)]
impl<B: ModelBackend> Clone for EngineWorker<'_, B> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<B: ModelBackend> Copy for EngineWorker<'_, B> {}

pub struct Engine<B: ModelBackend> {
    pub backend: B,
    pub opts: EngineOptions,
    pub metrics: Metrics,
    next_id: u64,
    /// Serving-thread worker context for the `&mut self` serial wrappers:
    /// slot 0, the same slot the pool's serial arms use, so standalone
    /// engine use gets the identical scratch reuse and device binding.
    serial_ctx: WorkerContext,
}

impl<B: ModelBackend> Engine<B> {
    pub fn new(backend: B, opts: EngineOptions) -> Engine<B> {
        Engine {
            backend,
            opts,
            metrics: Metrics::new(),
            next_id: 0,
            serial_ctx: WorkerContext::new(0),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        self.backend.config()
    }

    /// The shareable compute view this engine's workers run on.
    pub fn worker(&self) -> EngineWorker<'_, B> {
        EngineWorker { backend: &self.backend, opts: &self.opts }
    }

    /// Session with an engine-issued id (standalone `generate`/bench use).
    /// Delegates to [`Engine::new_session_with_id`] so there is exactly one
    /// construction path.
    pub fn new_session(&mut self, req: &GenerateRequest) -> Session {
        self.new_session_with_id(self.next_id + 1, req)
    }

    /// Session with a caller-supplied id: the scheduler threads the id the
    /// batcher handed out at submission all the way to the result, so one id
    /// names the request end-to-end. The engine's own counter advances past
    /// every id it sees here, so a later `new_session` can never silently
    /// reuse a batcher-issued id.
    pub fn new_session_with_id(&mut self, id: u64, req: &GenerateRequest) -> Session {
        self.next_id = self.next_id.max(id);
        Session::new(id, req.prompt.clone(), req.max_new_tokens)
    }

    /// Merge one worker decode report into the metrics sink. Totals are
    /// identical to the old inline observation: dispatch counts add, peaks
    /// max, and the live gauge lands on the last session of the report.
    pub fn absorb_step(&mut self, report: &StepReport) {
        for &(m, n) in &report.dispatches {
            self.metrics.observe_decode_dispatches(m, n);
        }
        for &kv in &report.kv_after {
            self.metrics.observe_kv(kv);
        }
        self.metrics.observe_decode_batch(report.sessions);
    }

    /// Merge one worker prefill report into the metrics sink.
    pub fn absorb_prefill(&mut self, report: &PrefillReport) {
        self.metrics.observe_transient(report.peak_transient);
        self.metrics.observe_prefill_transient(report.carry_peak_bytes);
        self.metrics.observe_prefill_resident(report.resident_peak_bytes);
        self.metrics.observe_kv(report.live_after);
        for &(bucket, valid) in &report.bucket_fills {
            self.metrics.observe_prefill_fill(bucket, valid);
        }
    }

    /// Run prefill under the configured policy (Algorithms 1 + 2).
    pub fn prefill(&mut self, sess: &mut Session) -> Result<i32> {
        let worker = EngineWorker { backend: &self.backend, opts: &self.opts };
        let report = worker.prefill(&mut self.serial_ctx, sess)?;
        self.absorb_prefill(&report);
        Ok(report.token)
    }

    /// Chunked prefill driven to completion in one call (tests/bench use;
    /// the scheduler drives `begin`/`advance` incrementally across ticks).
    /// Bit-identical to [`Engine::prefill`] at every chunk size.
    pub fn prefill_chunked(&mut self, sess: &mut Session, chunk: usize) -> Result<i32> {
        self.worker().begin_chunked_prefill(sess, chunk)?;
        let worker = EngineWorker { backend: &self.backend, opts: &self.opts };
        let (_, report) = worker.advance_chunked_prefill(&mut self.serial_ctx, sess, None)?;
        let report =
            report.ok_or_else(|| anyhow!("unbounded advance must complete the prefill"))?;
        self.absorb_prefill(&report);
        Ok(report.token)
    }

    /// Streaming-eviction chunked prefill driven to completion (tests/bench
    /// use). Unlike [`Engine::prefill_chunked`] this is *not* bit-identical
    /// to the monolithic pass — mid-prefill eviction scores only the tokens
    /// seen so far — but the carry transient stays bounded by the working
    /// cap regardless of prompt length.
    pub fn prefill_chunked_stream(&mut self, sess: &mut Session, chunk: usize) -> Result<i32> {
        self.worker().begin_chunked_prefill_stream(sess, chunk)?;
        let worker = EngineWorker { backend: &self.backend, opts: &self.opts };
        let (_, report) = worker.advance_chunked_prefill(&mut self.serial_ctx, sess, None)?;
        let report =
            report.ok_or_else(|| anyhow!("unbounded advance must complete the prefill"))?;
        self.absorb_prefill(&report);
        Ok(report.token)
    }

    /// One decode step: feed the last generated token, produce the next.
    /// Residency boundary: the engine only ever sees hot caches — a session
    /// with warm layers must be prefetched by the tier manager first.
    pub fn decode_step(&mut self, sess: &mut Session) -> Result<i32> {
        let worker = EngineWorker { backend: &self.backend, opts: &self.opts };
        let report = worker.decode_step(&mut self.serial_ctx, sess)?;
        self.absorb_step(&report);
        Ok(report.tokens[0])
    }

    /// One decode step for B sessions sharing a capacity bucket; see
    /// [`EngineWorker::decode_step_batch`]. Produces tokens, scores, and
    /// cache contents bit-identical to looping [`Engine::decode_step`].
    ///
    /// Fails as a unit: an error leaves the batch partially advanced, so
    /// callers must treat the whole group as failed (the scheduler retires
    /// every member), exactly as a serial decode error fails its session.
    pub fn decode_step_batch(&mut self, sessions: &mut [Session]) -> Result<Vec<i32>> {
        if sessions.is_empty() {
            return Ok(vec![]);
        }
        let worker = EngineWorker { backend: &self.backend, opts: &self.opts };
        let report = worker.decode_step_batch(&mut self.serial_ctx, sessions)?;
        self.absorb_step(&report);
        Ok(report.tokens)
    }

    /// Convenience: full generate loop for one request.
    pub fn generate(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
        let mut sess = self.new_session(req);
        self.prefill(&mut sess)?;
        let kv_after = sess.kv_bytes();
        while !sess.is_done() {
            self.decode_step(&mut sess)?;
        }
        self.metrics
            .finish_request(sess.prefill_secs, sess.decode_secs, sess.generated.len());
        Ok(GenerateResult {
            id: sess.id,
            status: FinishStatus::Completed,
            error: None,
            tokens: sess.generated.clone(),
            prefill_secs: sess.prefill_secs,
            decode_secs: sess.decode_secs,
            kv_bytes_after_prefill: kv_after,
            peak_kv_bytes: self.metrics.peak_kv_bytes,
            budgets: sess.budgets.clone(),
        })
    }

    /// Prefill-only entry used by benches that inspect caches/budgets.
    pub fn prefill_only(&mut self, prompt: &[i32]) -> Result<(Session, i32)> {
        let req = GenerateRequest { prompt: prompt.to_vec(), max_new_tokens: 1 };
        let mut sess = self.new_session(&req);
        let tok = self.prefill(&mut sess)?;
        Ok((sess, tok))
    }
}

impl<B: ModelBackend> EngineWorker<'_, B> {
    pub fn config(&self) -> &ModelConfig {
        self.backend.config()
    }

    /// Bind the backend device pinned to this worker context, once per
    /// context lifetime. Every entry point that touches the backend calls
    /// this first, so a freshly spawned (or scoped-oracle) worker binds
    /// before its first dispatch and never again afterwards.
    fn ensure_device(&self, ctx: &mut WorkerContext) {
        if !ctx.device_bound {
            self.backend.bind_device(ctx.device_slot);
            ctx.device_bound = true;
        }
    }

    fn total_budget(&self) -> usize {
        let cfg = self.backend.config();
        self.opts.budget_per_head * cfg.n_kv_heads * cfg.n_layers
    }

    /// Compute policy scores for one prefilled layer -> [Hk][length].
    /// Takes the observations + values directly so the monolithic and
    /// chunked paths (which assemble them differently) share one scorer.
    fn layer_scores(&self, obs: &LayerObs, v: &Tensor) -> Result<Vec<Vec<f32>>> {
        let p = &self.opts.policy;
        if p.score == ScoreKind::Lava && self.opts.use_fused_score {
            if let Some(s) = self.backend.fused_lava_score(&obs.win_attn, v, obs.length)? {
                return Ok(s);
            }
        }
        Ok(score::kv_head_scores(p.score, p.group_reduce, obs, self.opts.pool_kernel))
    }

    /// Dynamic-allocation weight for one layer (LAVa Eq. 7 or CAKE Eq. 23).
    fn layer_weight(&self, scores: &[Vec<f32>], obs: &LayerObs) -> f64 {
        match self.opts.policy.layer_alloc {
            LayerAlloc::Entropy => alloc::lava_layer_entropy(scores),
            LayerAlloc::CakeHv { g1, g2 } => {
                let (h, v) = alloc::cake_hv(obs);
                alloc::cake_preference(h, v, g1, g2)
            }
            _ => 1.0,
        }
    }

    /// Static per-layer budgets for non-dynamic allocators.
    fn static_budgets(&self, floor: usize) -> Vec<usize> {
        let cfg = self.backend.config();
        let total = self.total_budget();
        match self.opts.policy.layer_alloc {
            LayerAlloc::Uniform => alloc::proportional(&vec![1.0; cfg.n_layers], total, floor),
            LayerAlloc::Pyramid { beta } => alloc::pyramid(total, cfg.n_layers, beta, floor),
            _ => alloc::proportional(&vec![1.0; cfg.n_layers], total, floor),
        }
    }

    /// Capacity bucket for a layer cache: worst-case per-head occupancy
    /// (flat allocation can give one head nearly the whole layer budget)
    /// plus generation headroom.
    fn capacity_for(&self, budget: usize, length: usize, max_new: usize) -> Result<usize> {
        let per_head_worst = budget.min(length);
        let need = per_head_worst + max_new + 1;
        Runtime::pick_bucket(self.backend.decode_buckets(), need)
            .ok_or_else(|| anyhow!("no decode bucket >= {need}"))
    }

    /// Score + compress one fully-observed prefill layer: Algorithm 1 keep
    /// selection, the dynamic budget resplit (Eq. 7 / CAKE), the cache load,
    /// and the Algorithm 2 recompression cascade over earlier layers.
    /// Shared verbatim by the monolithic and chunked prefill paths so the
    /// two are bit-identical by construction.
    #[allow(clippy::too_many_arguments)]
    fn compress_prefilled_layer(
        &self,
        sess: &mut Session,
        l: usize,
        k: &Tensor,
        v: &Tensor,
        obs: &LayerObs,
        n: usize,
        budgets: &mut [usize],
        weights: &mut Vec<f64>,
        floor: usize,
    ) -> Result<()> {
        let cfg = self.backend.config();
        let full = self.opts.policy.full_cache;
        let dynamic = self.opts.policy.dynamic_layer();
        let keepset: KeepSet = if full {
            KeepSet {
                keep: (0..cfg.n_kv_heads).map(|_| (0..n).collect()).collect(),
                scores: (0..cfg.n_kv_heads).map(|_| vec![f32::MAX; n]).collect(),
            }
        } else {
            let scores = self.layer_scores(obs, v)?;
            if dynamic {
                weights.push(self.layer_weight(&scores, obs));
                let total = self.total_budget();
                let split = alloc::proportional(weights, total, floor);
                budgets[..=l].copy_from_slice(&split);
            }
            select_prefill(&scores, n, budgets[l], cfg.window, self.opts.policy.head_alloc)
        };

        let capacity = self.capacity_for(
            if full { n * cfg.n_kv_heads } else { budgets[l] },
            n,
            sess.max_new_tokens,
        )?;
        let mut cache = HotStore::new(cfg.n_kv_heads, cfg.d_head, capacity);
        cache.load_from_prefill(k, v, &keepset.keep, &keepset.scores);
        sess.caches.push(cache);
        sess.residency.push(Residency::Hot);

        // Algorithm 2: recompress earlier layers to their shrunken budgets.
        if dynamic {
            recompress_earlier(
                &mut sess.caches[..l],
                budgets,
                cfg.n_kv_heads,
                self.opts.policy.head_alloc,
            );
        }
        Ok(())
    }

    /// Run prefill under the configured policy (Algorithms 1 + 2). Pure
    /// compute: metrics observations come back in the report.
    pub fn prefill(&self, ctx: &mut WorkerContext, sess: &mut Session) -> Result<PrefillReport> {
        self.ensure_device(ctx);
        let t0 = std::time::Instant::now();
        let cfg = self.backend.config().clone();
        let n = sess.prompt.len();
        let w = cfg.window;
        if n < w + 1 {
            bail!("prompt length {n} must exceed the window {w}");
        }
        let bucket = Runtime::pick_bucket(self.backend.prefill_buckets(), n)
            .ok_or_else(|| anyhow!("prompt length {n} exceeds the largest prefill bucket"))?;
        sess.phase = Phase::Prefilling { next_chunk: 0 };

        let mut x = self.backend.embed(&sess.prompt, bucket)?;
        let floor = cfg.n_kv_heads * w;
        let full = self.opts.policy.full_cache;
        let dynamic = self.opts.policy.dynamic_layer();
        let mut budgets = if full {
            vec![n * cfg.n_kv_heads; cfg.n_layers]
        } else if dynamic {
            vec![0; cfg.n_layers]
        } else {
            self.static_budgets(floor)
        };
        let mut weights: Vec<f64> = Vec::with_capacity(cfg.n_layers);
        let uncompressed_layer_bytes = 2 * cfg.n_kv_heads * n * cfg.d_head * 4;
        let mut peak_transient = 0usize;
        let mut bucket_fills = Vec::with_capacity(cfg.n_layers);

        for l in 0..cfg.n_layers {
            let out = self.backend.layer_prefill(l, &x, n)?;

            // transient peak: retained caches + this uncompressed layer
            let retained: usize = sess.caches.iter().map(|c| c.live_bytes()).sum();
            peak_transient = peak_transient.max(retained + uncompressed_layer_bytes);
            bucket_fills.push((bucket, n));

            self.compress_prefilled_layer(
                sess,
                l,
                &out.k,
                &out.v,
                &out.obs,
                n,
                &mut budgets,
                &mut weights,
                floor,
            )?;

            x = out.x_out;
        }

        sess.budgets = budgets;
        let live: usize = sess.caches.iter().map(|c| c.live_bytes()).sum();

        // next-token logits from the prompt's last position
        let d = cfg.d_model;
        let xf = x.as_f32()?;
        let x_last = Tensor::f32(xf[(n - 1) * d..n * d].to_vec(), &[1, d]);
        let logits = self.backend.logits(&x_last)?;
        let tok = argmax(&logits);
        sess.generated.push(tok);
        sess.next_pos = n;
        sess.phase = Phase::Decoding;
        sess.prefill_secs = t0.elapsed().as_secs_f64();
        // monolithic resident set: one uncompressed layer of K/V, the
        // observation panels (win + acc + vnorm) at the prompt bucket, and
        // the hidden rows (layer input + output) — all O(prompt)
        let resident_peak = uncompressed_layer_bytes
            + (cfg.n_heads * cfg.window + cfg.n_heads + cfg.n_kv_heads) * bucket * 4
            + 2 * bucket * d * 4;
        Ok(PrefillReport {
            token: tok,
            peak_transient,
            live_after: live,
            bucket_fills,
            carry_peak_bytes: uncompressed_layer_bytes,
            resident_peak_bytes: resident_peak,
        })
    }

    /// Tight prefill bucket for one chunk of `chunk_len` tokens (falls back
    /// to the exact length when even the smallest bucket is exceeded — only
    /// possible with over-bucket chunk sizes).
    fn chunk_bucket(&self, chunk_len: usize) -> usize {
        Runtime::pick_bucket(self.backend.prefill_buckets(), chunk_len).unwrap_or(chunk_len)
    }

    /// Whether the backend can serve every chunk shape a chunked prefill of
    /// this prompt would dispatch (the scheduler's per-chunk fallback: when
    /// false, the prompt routes to the monolithic path instead).
    pub fn chunked_prefill_supported(&self, prompt_len: usize, chunk: usize) -> bool {
        if chunk == 0 || prompt_len == 0 {
            return false;
        }
        let n_obs = Runtime::pick_bucket(self.backend.prefill_buckets(), prompt_len)
            .unwrap_or(prompt_len);
        // at most two distinct chunk shapes: the full chunk and the tail
        let full = chunk.min(prompt_len);
        let tail = prompt_len % chunk;
        let mut shapes = vec![self.chunk_bucket(full)];
        if tail != 0 && prompt_len > chunk {
            shapes.push(self.chunk_bucket(tail));
        }
        shapes
            .iter()
            .all(|&cb| self.backend.supports_chunked_prefill(cb, n_obs))
    }

    /// Install the resumable chunked-prefill state machine on the session
    /// (phase `Prefilling { next_chunk: 0 }`). The actual compute happens in
    /// [`EngineWorker::advance_chunked_prefill`] calls.
    pub fn begin_chunked_prefill(&self, sess: &mut Session, chunk: usize) -> Result<()> {
        self.begin_chunked_inner(sess, chunk, None)
    }

    /// Streaming-eviction variant: the carry is allocated at the fixed
    /// working cap from [`EngineWorker::stream_evict_cap`] and compacted
    /// after every non-final chunk, so the per-layer transient is bounded
    /// regardless of prompt length. Results are *not* bit-identical to the
    /// monolithic pass — mid-prefill eviction sees only the tokens so far.
    pub fn begin_chunked_prefill_stream(&self, sess: &mut Session, chunk: usize) -> Result<()> {
        let cap = self.stream_evict_cap(sess.prompt.len(), chunk).ok_or_else(|| {
            anyhow!(
                "streaming eviction unsupported for prompt {} at chunk {chunk}",
                sess.prompt.len()
            )
        })?;
        self.begin_chunked_inner(sess, chunk, Some(cap))
    }

    fn begin_chunked_inner(
        &self,
        sess: &mut Session,
        chunk: usize,
        stream_cap: Option<usize>,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let cfg = self.backend.config();
        let n = sess.prompt.len();
        let w = cfg.window;
        if n < w + 1 {
            bail!("prompt length {n} must exceed the window {w}");
        }
        if chunk == 0 {
            bail!("prefill chunk size must be >= 1");
        }
        let (h, hk, dh, d) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model);
        // observation width: the monolithic bucket, or the exact prompt
        // length for prompts beyond the largest bucket (servable only here)
        let n_obs =
            Runtime::pick_bucket(self.backend.prefill_buckets(), n).unwrap_or(n);
        let floor = hk * w;
        let budgets = if self.opts.policy.full_cache {
            vec![n * hk; cfg.n_layers]
        } else if self.opts.policy.dynamic_layer() {
            vec![0; cfg.n_layers]
        } else {
            self.static_budgets(floor)
        };
        // streaming mode: per-layer carry lanes at the working cap, panels
        // on the lanes. Chunk-major (the streaming default) keeps one lane
        // per model layer plus one chunk of hidden rows; layer-major keeps a
        // single lane reset between layers plus O(prompt) hidden rows.
        let chunk_major = stream_cap.is_some() && !self.opts.stream_layer_major;
        let q8 = chunk_major && self.opts.carry_q8;
        let stream = stream_cap.map(|cap| {
            let lanes = if chunk_major { cfg.n_layers } else { 1 };
            Box::new(StreamPrefill::new(cap, chunk_major, lanes, hk, dh, q8))
        });
        let (win, acc, vnorm) = if stream.is_some() {
            (Vec::new(), Vec::new(), Vec::new())
        } else {
            (vec![0.0; h * w * n_obs], vec![0.0; h * n_obs], vec![0.0; hk * n_obs])
        };
        // hidden rows: chunk-major embeds per chunk (one chunk bucket of
        // rows, never the prompt), everything else embeds the prompt here
        let (x, x_next) = if chunk_major {
            (Vec::new(), Vec::new())
        } else {
            (self.backend.embed(&sess.prompt, n)?.into_f32()?, vec![0.0; n * d])
        };
        // stream lanes own their carries; the shared fields stay zero-width
        let carry_w = if stream.is_some() { 0 } else { n_obs };
        sess.phase = Phase::Prefilling { next_chunk: 0 };
        sess.prefill = Some(Box::new(ChunkedPrefill {
            chunk,
            n_obs,
            n_chunks: n.div_ceil(chunk),
            layer: 0,
            chunk_idx: 0,
            x,
            x_next,
            carry_k: Tensor::zeros(&[hk, carry_w, dh]),
            carry_v: Tensor::zeros(&[hk, carry_w, dh]),
            win,
            acc,
            vnorm,
            weights: Vec::with_capacity(cfg.n_layers),
            budgets,
            peak_transient: 0,
            peak_resident: 0,
            stream,
            bucket_fills: Vec::new(),
            wait_secs: 0.0,
            enqueued_at: sess.queued_at,
        }));
        sess.prefill_secs += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Advance a chunked prefill by up to `max_tokens` tokens of work (one
    /// chunk through one layer = `chunk_len` tokens; the whole prefill is
    /// `n_chunks * n_layers` dispatches). At least one chunk is dispatched
    /// per call so progress is guaranteed even under a tiny budget; `None`
    /// runs to completion. Returns the tokens actually advanced plus the
    /// final [`PrefillReport`] once the prompt's first token exists.
    pub fn advance_chunked_prefill(
        &self,
        ctx: &mut WorkerContext,
        sess: &mut Session,
        max_tokens: Option<usize>,
    ) -> Result<(usize, Option<PrefillReport>)> {
        let t0 = std::time::Instant::now();
        self.ensure_device(ctx);
        let cfg = self.backend.config().clone();
        let (h, hk, w, dh, d) =
            (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head, cfg.d_model);
        let n = sess.prompt.len();
        let floor = hk * w;
        let uncompressed_layer_bytes = 2 * hk * n * dh * 4;
        let mut st = sess
            .prefill
            .take()
            .ok_or_else(|| anyhow!("advance_chunked_prefill before begin (session {})", sess.id))?;
        let stream_mode = st.stream.as_ref().map(|sv| sv.chunk_major);
        if let Some(chunk_major) = stream_mode {
            return if chunk_major {
                self.advance_chunk_major(ctx, sess, st, max_tokens, t0)
            } else {
                self.advance_stream_prefill(ctx, sess, st, max_tokens, t0)
            };
        }
        let mut worked = 0usize;
        let mut finished = false;

        while st.layer < cfg.n_layers {
            if let Some(budget) = max_tokens {
                if worked >= budget {
                    break;
                }
            }
            let start = st.chunk_idx * st.chunk;
            let chunk_len = st.chunk.min(n - start);
            let c_bucket = self.chunk_bucket(chunk_len);
            let mut xc = vec![0.0f32; c_bucket * d];
            xc[..chunk_len * d].copy_from_slice(&st.x[start * d..(start + chunk_len) * d]);
            let x_chunk = Tensor::f32(xc, &[c_bucket, d]);
            let out = self.backend.layer_prefill_chunked(
                st.layer,
                &x_chunk,
                &st.carry_k,
                &st.carry_v,
                start,
                chunk_len,
                n,
            )?;

            // scatter the chunk's K/V rows into the carry
            {
                let cb = out.k.shape[1];
                let kc = out.k.as_f32()?;
                let vc = out.v.as_f32()?;
                let ck = st.carry_k.as_f32_mut()?;
                let cv = st.carry_v.as_f32_mut()?;
                for kv in 0..hk {
                    let dst = (kv * st.n_obs + start) * dh;
                    let src = kv * cb * dh;
                    ck[dst..dst + chunk_len * dh]
                        .copy_from_slice(&kc[src..src + chunk_len * dh]);
                    cv[dst..dst + chunk_len * dh]
                        .copy_from_slice(&vc[src..src + chunk_len * dh]);
                }
            }
            // accumulate observations: owned window rows land whole,
            // acc/vnorm contributions add (zero outside the chunk's columns)
            for (r, row) in &out.win_rows {
                for hh in 0..h {
                    st.win[(hh * w + r) * st.n_obs..(hh * w + r + 1) * st.n_obs]
                        .copy_from_slice(&row[hh * st.n_obs..(hh + 1) * st.n_obs]);
                }
            }
            for (dst, src) in st.acc.iter_mut().zip(&out.acc) {
                *dst += src;
            }
            for (dst, src) in st.vnorm.iter_mut().zip(&out.vnorm) {
                *dst += src;
            }
            let xo = out.x_out.as_f32()?;
            st.x_next[start * d..(start + chunk_len) * d].copy_from_slice(&xo[..chunk_len * d]);
            st.bucket_fills.push((c_bucket, chunk_len));
            // full resident set: hidden rows (both layers), the O(prompt)
            // carry K/V, and the observation panels
            st.peak_resident = st.peak_resident.max(
                (st.x.len() + st.x_next.len()) * 4
                    + 2 * hk * st.n_obs * dh * 4
                    + (st.win.len() + st.acc.len() + st.vnorm.len()) * 4,
            );
            worked += chunk_len;
            st.chunk_idx += 1;

            if st.chunk_idx == st.n_chunks {
                // layer complete: transient peak exactly as the monolithic
                // path observes it (retained earlier layers + this carry)
                let retained: usize = sess.caches.iter().map(|c| c.live_bytes()).sum();
                st.peak_transient = st.peak_transient.max(retained + uncompressed_layer_bytes);
                let l = st.layer;
                let obs = LayerObs {
                    win_attn: Tensor::f32(std::mem::take(&mut st.win), &[h, w, st.n_obs]),
                    acc_attn: Tensor::f32(std::mem::take(&mut st.acc), &[h, st.n_obs]),
                    vnorm: Tensor::f32(std::mem::take(&mut st.vnorm), &[hk, st.n_obs]),
                    length: n,
                };
                let mut budgets = std::mem::take(&mut st.budgets);
                let mut weights = std::mem::take(&mut st.weights);
                self.compress_prefilled_layer(
                    sess,
                    l,
                    &st.carry_k,
                    &st.carry_v,
                    &obs,
                    n,
                    &mut budgets,
                    &mut weights,
                    floor,
                )?;
                st.budgets = budgets;
                st.weights = weights;
                st.layer += 1;
                st.chunk_idx = 0;
                std::mem::swap(&mut st.x, &mut st.x_next);
                if st.layer < cfg.n_layers {
                    // reuse the panel allocations for the next layer: the
                    // observation tensors hand their Vecs back once scoring
                    // is done, so steady-state layer advances allocate no
                    // panel-sized buffers (the carry needs no reset — the
                    // next layer rewrites every row before it is readable)
                    st.win = obs.win_attn.into_f32()?;
                    st.win.fill(0.0);
                    st.acc = obs.acc_attn.into_f32()?;
                    st.acc.fill(0.0);
                    st.vnorm = obs.vnorm.into_f32()?;
                    st.vnorm.fill(0.0);
                } else {
                    finished = true;
                    break;
                }
            }
        }

        if !finished {
            sess.phase = Phase::Prefilling { next_chunk: st.chunk_idx };
            sess.prefill = Some(st);
            sess.prefill_secs += t0.elapsed().as_secs_f64();
            return Ok((worked, None));
        }

        let report = self.finish_chunked(sess, &mut st)?;
        sess.prefill_secs += t0.elapsed().as_secs_f64();
        Ok((worked, Some(report)))
    }

    /// Shared epilogue for every chunked path once all layers are
    /// compressed: budgets move to the session, the last hidden row becomes
    /// the first token, and the report carries the transient peaks. The
    /// caller drops `st` (the state machine is done).
    fn finish_chunked(&self, sess: &mut Session, st: &mut ChunkedPrefill) -> Result<PrefillReport> {
        let cfg = self.backend.config();
        let (hk, dh, d) = (cfg.n_kv_heads, cfg.d_head, cfg.d_model);
        let n = sess.prompt.len();
        sess.budgets = std::mem::take(&mut st.budgets);
        let live: usize = sess.caches.iter().map(|c| c.live_bytes()).sum();
        // the prompt's final hidden row is the tail of `x`: the full
        // [n, d] rows on the layer-major paths, exactly one [d] row on the
        // chunk-major path (the last O(prompt) buffer it no longer holds)
        let x_last = Tensor::f32(st.x[st.x.len() - d..].to_vec(), &[1, d]);
        let logits = self.backend.logits(&x_last)?;
        let tok = argmax(&logits);
        sess.generated.push(tok);
        sess.next_pos = n;
        sess.phase = Phase::Decoding;
        let carry_cols = st.stream.as_ref().map_or(n, |sv| sv.max_live_cols);
        Ok(PrefillReport {
            token: tok,
            peak_transient: st.peak_transient,
            live_after: live,
            bucket_fills: std::mem::take(&mut st.bucket_fills),
            carry_peak_bytes: 2 * hk * carry_cols * dh * 4,
            resident_peak_bytes: st.peak_resident,
        })
    }

    /// Streaming eviction's working-cap requirement: the worst-case keep-set
    /// union after a mid-prefill evict (every kv head keeping a disjoint
    /// budget, never less than the pinned window), plus one full chunk
    /// bucket of fresh columns, plus window slack.
    fn stream_cap_required(&self, prompt_len: usize, chunk: usize) -> usize {
        let cfg = self.backend.config();
        let union = cfg.n_kv_heads * self.opts.budget_per_head.max(cfg.window);
        union + self.chunk_bucket(chunk.min(prompt_len)) + cfg.window
    }

    /// Working cap for a streaming-evict prefill of this prompt: the exact
    /// requirement when the backend serves it (mock), else the smallest
    /// prefill bucket above it the backend lowered evict artifacts for
    /// (PJRT). None when no supported cap exists or the policy keeps the
    /// full cache (nothing may be evicted mid-stream) — callers fall back
    /// to the plain chunked or monolithic path.
    pub fn stream_evict_cap(&self, prompt_len: usize, chunk: usize) -> Option<usize> {
        if chunk == 0 || prompt_len == 0 || self.opts.policy.full_cache {
            return None;
        }
        let need = self.stream_cap_required(prompt_len, chunk);
        let full = chunk.min(prompt_len);
        let tail = prompt_len % chunk;
        let mut shapes = vec![self.chunk_bucket(full)];
        if tail != 0 && prompt_len > chunk {
            let tb = self.chunk_bucket(tail);
            if !shapes.contains(&tb) {
                shapes.push(tb);
            }
        }
        let mut caps: Vec<usize> = vec![need];
        caps.extend(self.backend.prefill_buckets().iter().copied().filter(|&b| b > need));
        caps.sort_unstable();
        caps.dedup();
        caps.into_iter()
            .find(|&cap| shapes.iter().all(|&cb| self.backend.supports_chunked_evict(cb, cap)))
    }

    /// Lockstep shape of a mid-stream session's next dispatch: (layer,
    /// chunk cursor, chunk size, chunk length, working cap). Sessions
    /// sharing a key can advance through one batched backend call
    /// ([`EngineWorker::advance_stream_group`]). None for sessions not on
    /// the streaming path.
    pub fn stream_lockstep_key(
        &self,
        sess: &Session,
    ) -> Option<(usize, usize, usize, usize, usize)> {
        let st = sess.prefill.as_ref()?;
        let sv = st.stream.as_ref()?;
        let start = st.chunk_idx * st.chunk;
        let chunk_len = st.chunk.min(sess.prompt.len() - start);
        Some((st.layer, st.chunk_idx, st.chunk, chunk_len, sv.cap))
    }

    /// Streaming-eviction advance: the same budgeted loop as
    /// [`EngineWorker::advance_chunked_prefill`], but every dispatch is a
    /// `layer_prefill_chunked_evict` against the compacted carry and each
    /// non-final chunk is followed by a mid-prefill eviction bounding the
    /// live columns to the working cap.
    fn advance_stream_prefill(
        &self,
        ctx: &mut WorkerContext,
        sess: &mut Session,
        mut st: Box<ChunkedPrefill>,
        max_tokens: Option<usize>,
        t0: std::time::Instant,
    ) -> Result<(usize, Option<PrefillReport>)> {
        let cfg = self.backend.config().clone();
        let d = cfg.d_model;
        let n = sess.prompt.len();
        // layer-major lanes are never Q8, so the dequant slot is zero-width
        let (score, slots) =
            ctx.scratch.score_and_dequant(1, &[cfg.n_kv_heads, 0, cfg.d_head]);
        let kv = &mut slots[0];
        let mut worked = 0usize;
        let mut finished = false;
        while st.layer < cfg.n_layers {
            if let Some(budget) = max_tokens {
                if worked >= budget {
                    break;
                }
            }
            let start = st.chunk_idx * st.chunk;
            let chunk_len = st.chunk.min(n - start);
            let c_bucket = self.chunk_bucket(chunk_len);
            let (x_chunk, carry_pos) = stream_chunk_inputs(&st, start, chunk_len, c_bucket, d);
            let out = {
                let lane = &st.stream.as_ref().expect("stream state").layers[0];
                self.backend.layer_prefill_chunked_evict(
                    st.layer,
                    &ChunkEvictReq {
                        x_chunk: &x_chunk,
                        carry_k: &lane.carry_k,
                        carry_v: &lane.carry_v,
                        carry_pos: &carry_pos,
                        start,
                        chunk_len,
                        total_len: n,
                        n_obs: st.n_obs,
                    },
                )?
            };
            worked += chunk_len;
            self.consume_stream_chunk(
                sess,
                &mut st,
                out,
                start,
                chunk_len,
                c_bucket,
                &mut *score,
                &mut *kv,
            )?;
            if st.layer == cfg.n_layers {
                finished = true;
                break;
            }
        }
        if !finished {
            sess.phase = Phase::Prefilling { next_chunk: st.chunk_idx };
            sess.prefill = Some(st);
            sess.prefill_secs += t0.elapsed().as_secs_f64();
            return Ok((worked, None));
        }
        let report = self.finish_chunked(sess, &mut st)?;
        sess.prefill_secs += t0.elapsed().as_secs_f64();
        Ok((worked, Some(report)))
    }

    /// Chunk-major streaming advance (the streaming default): each chunk
    /// flows through all L layers in one pass, one bounded carry lane per
    /// layer. The hidden rows never exceed one chunk bucket (`x_chunk` in,
    /// `x_out` back for the next layer), so with all L lanes capped the
    /// whole prefill working set is flat in prompt length. The final pass
    /// compresses the lanes in ascending layer order — the exact call
    /// sequence the layer-major path runs — so tokens, budgets, and
    /// keep-sets are identical between the two orders.
    ///
    /// A pass is atomic: the `max_tokens` budget is checked between passes
    /// only, so one call may overshoot by up to `chunk_len * n_layers`
    /// tokens of work (progress is still guaranteed under a tiny budget).
    fn advance_chunk_major(
        &self,
        ctx: &mut WorkerContext,
        sess: &mut Session,
        mut st: Box<ChunkedPrefill>,
        max_tokens: Option<usize>,
        t0: std::time::Instant,
    ) -> Result<(usize, Option<PrefillReport>)> {
        let cfg = self.backend.config().clone();
        let d = cfg.d_model;
        let (hk, dh) = (cfg.n_kv_heads, cfg.d_head);
        let n = sess.prompt.len();
        // Q8 lanes dequantize into the worker's dequant slot at dispatch;
        // f32 lanes never touch it (zero-width allocation)
        let (q8, cap) = {
            let sv = st.stream.as_ref().expect("stream state");
            (sv.q8(), sv.cap)
        };
        let shape = if q8 { [hk, cap, dh] } else { [hk, 0, dh] };
        let (score, slots) = ctx.scratch.score_and_dequant(1, &shape);
        let kv = &mut slots[0];
        let mut worked = 0usize;
        let mut finished = false;
        while st.chunk_idx < st.n_chunks {
            if let Some(budget) = max_tokens {
                if worked >= budget {
                    break;
                }
            }
            let start = st.chunk_idx * st.chunk;
            let chunk_len = st.chunk.min(n - start);
            let c_bucket = self.chunk_bucket(chunk_len);
            let is_final = st.chunk_idx + 1 == st.n_chunks;
            let mut x_chunk =
                self.backend.embed(&sess.prompt[start..start + chunk_len], c_bucket)?;
            for l in 0..cfg.n_layers {
                let carry_pos = self.stream_dispatch_carry(&st, l, &mut *kv)?;
                let out = {
                    let sv = st.stream.as_ref().expect("stream state");
                    let lane = &sv.layers[l];
                    // Q8 lanes were dequantized into the worker's dequant
                    // slot by stream_dispatch_carry; f32 lanes dispatch in
                    // place
                    let (ck, cv) = if lane.q8.is_some() {
                        (&kv.0, &kv.1)
                    } else {
                        (&lane.carry_k, &lane.carry_v)
                    };
                    self.backend.layer_prefill_chunked_evict(
                        l,
                        &ChunkEvictReq {
                            x_chunk: &x_chunk,
                            carry_k: ck,
                            carry_v: cv,
                            carry_pos: &carry_pos,
                            start,
                            chunk_len,
                            total_len: n,
                            n_obs: st.n_obs,
                        },
                    )?
                };
                worked += chunk_len;
                self.consume_stream_lane(
                    sess,
                    &mut st,
                    l,
                    l,
                    is_final,
                    &out,
                    start,
                    chunk_len,
                    c_bucket,
                    &mut *score,
                    &mut *kv,
                )?;
                x_chunk = out.x_out;
            }
            st.chunk_idx += 1;
            if is_final {
                // keep only the prompt's last hidden row for the logits —
                // the O(prompt) `x`/`x_next` buffers never exist here
                st.x = x_chunk.as_f32()?[(chunk_len - 1) * d..chunk_len * d].to_vec();
                finished = true;
                break;
            }
        }
        if !finished {
            sess.phase = Phase::Prefilling { next_chunk: st.chunk_idx };
            sess.prefill = Some(st);
            sess.prefill_secs += t0.elapsed().as_secs_f64();
            return Ok((worked, None));
        }
        let report = self.finish_chunked(sess, &mut st)?;
        sess.prefill_secs += t0.elapsed().as_secs_f64();
        Ok((worked, Some(report)))
    }

    /// Advance every session in `group` by exactly one streaming-evict
    /// chunk through a single batched backend call (cross-session chunk
    /// batching). All sessions must share a
    /// [`EngineWorker::stream_lockstep_key`]; per-session results are
    /// identical to serial advances — batching only changes how many
    /// dispatches the backend sees. Returns each session's
    /// `(tokens worked, completion report)` in group order plus the real
    /// backend dispatch count. Fails as a unit: an error tears down every
    /// member's prefill state, so callers retire the whole group (exactly
    /// like a batched decode error).
    pub fn advance_stream_group(
        &self,
        ctx: &mut WorkerContext,
        group: &mut [Session],
    ) -> Result<(Vec<(usize, Option<PrefillReport>)>, usize)> {
        if group.is_empty() {
            return Ok((Vec::new(), 0));
        }
        let t0 = std::time::Instant::now();
        self.ensure_device(ctx);
        // chunk-major groups advance one full pass (all L layers of the
        // next chunk) through L batched dispatches; layer-major groups
        // advance one (layer, chunk) dispatch as before
        let chunk_major = group[0]
            .prefill
            .as_ref()
            .and_then(|st| st.stream.as_ref())
            .map_or(false, |sv| sv.chunk_major);
        if chunk_major {
            return self.advance_chunk_major_group(ctx, group, t0);
        }
        let cfg = self.backend.config().clone();
        let d = cfg.d_model;
        // one zero-width dequant slot, shared sequentially by the group's
        // consume calls (layer-major lanes are never Q8)
        let (score, slots) =
            ctx.scratch.score_and_dequant(1, &[cfg.n_kv_heads, 0, cfg.d_head]);
        let kv = &mut slots[0];
        let mut sts: Vec<Box<ChunkedPrefill>> = Vec::with_capacity(group.len());
        for sess in group.iter_mut() {
            sts.push(sess.prefill.take().ok_or_else(|| {
                anyhow!("advance_stream_group on session {} without prefill state", sess.id)
            })?);
        }
        let (layer, chunk_idx) = (sts[0].layer, sts[0].chunk_idx);
        // per-session owned inputs (the requests below borrow them)
        let mut inputs: Vec<(Tensor, Vec<i32>, usize, usize, usize)> =
            Vec::with_capacity(group.len());
        for (sess, st) in group.iter().zip(&sts) {
            let lockstep = st.stream.as_ref().map_or(false, |sv| !sv.chunk_major)
                && st.layer == layer
                && st.chunk_idx == chunk_idx;
            if !lockstep {
                bail!("advance_stream_group over sessions out of lockstep");
            }
            let n = sess.prompt.len();
            let start = st.chunk_idx * st.chunk;
            let chunk_len = st.chunk.min(n - start);
            let c_bucket = self.chunk_bucket(chunk_len);
            let (x_chunk, carry_pos) = stream_chunk_inputs(st, start, chunk_len, c_bucket, d);
            inputs.push((x_chunk, carry_pos, start, chunk_len, c_bucket));
        }
        let (outs, dispatches) = {
            let reqs: Vec<ChunkEvictReq> = sts
                .iter()
                .zip(group.iter())
                .zip(&inputs)
                .map(|((st, sess), (x_chunk, carry_pos, start, chunk_len, _))| {
                    let lane = &st.stream.as_ref().expect("stream state").layers[0];
                    ChunkEvictReq {
                        x_chunk,
                        carry_k: &lane.carry_k,
                        carry_v: &lane.carry_v,
                        carry_pos,
                        start: *start,
                        chunk_len: *chunk_len,
                        total_len: sess.prompt.len(),
                        n_obs: st.n_obs,
                    }
                })
                .collect();
            self.backend.layer_prefill_chunked_evict_batched(layer, &reqs)?
        };
        if outs.len() != group.len() {
            bail!("batched evict returned {} outputs for {} sessions", outs.len(), group.len());
        }
        let mut results = Vec::with_capacity(group.len());
        for (i, ((sess, mut st), out)) in group.iter_mut().zip(sts).zip(outs).enumerate() {
            let (start, chunk_len, c_bucket) = (inputs[i].2, inputs[i].3, inputs[i].4);
            self.consume_stream_chunk(
                sess,
                &mut st,
                out,
                start,
                chunk_len,
                c_bucket,
                &mut *score,
                &mut *kv,
            )?;
            if st.layer == cfg.n_layers {
                let report = self.finish_chunked(sess, &mut st)?;
                results.push((chunk_len, Some(report)));
            } else {
                sess.phase = Phase::Prefilling { next_chunk: st.chunk_idx };
                sess.prefill = Some(st);
                results.push((chunk_len, None));
            }
        }
        let secs = t0.elapsed().as_secs_f64() / group.len() as f64;
        for sess in group.iter_mut() {
            sess.prefill_secs += secs;
        }
        Ok((results, dispatches))
    }

    /// Chunk-major form of [`EngineWorker::advance_stream_group`]: every
    /// session advances one full pass (its next chunk through all L layers)
    /// via L batched backend dispatches — per-layer, the sessions' lane
    /// dispatches share one `layer_prefill_chunked_evict_batched` call.
    /// Per-session results are identical to serial
    /// [`EngineWorker::advance_chunk_major`] passes. Sessions whose pass was
    /// their last finish here; the rest reinstall their state machines.
    fn advance_chunk_major_group(
        &self,
        ctx: &mut WorkerContext,
        group: &mut [Session],
        t0: std::time::Instant,
    ) -> Result<(Vec<(usize, Option<PrefillReport>)>, usize)> {
        let cfg = self.backend.config().clone();
        let d = cfg.d_model;
        let (hk, dh) = (cfg.n_kv_heads, cfg.d_head);
        let mut sts: Vec<Box<ChunkedPrefill>> = Vec::with_capacity(group.len());
        for sess in group.iter_mut() {
            sts.push(sess.prefill.take().ok_or_else(|| {
                anyhow!("advance_stream_group on session {} without prefill state", sess.id)
            })?);
        }
        let chunk_idx = sts[0].chunk_idx;
        // per-session pass geometry + the chunk embeds (the only hidden rows)
        let mut geom: Vec<(usize, usize, usize, bool)> = Vec::with_capacity(group.len());
        let mut xs: Vec<Tensor> = Vec::with_capacity(group.len());
        for (sess, st) in group.iter().zip(&sts) {
            let lockstep = st.stream.as_ref().map_or(false, |sv| sv.chunk_major)
                && st.chunk_idx == chunk_idx;
            if !lockstep {
                bail!("advance_stream_group over sessions out of lockstep");
            }
            let n = sess.prompt.len();
            let start = st.chunk_idx * st.chunk;
            let chunk_len = st.chunk.min(n - start);
            let c_bucket = self.chunk_bucket(chunk_len);
            let is_final = st.chunk_idx + 1 == st.n_chunks;
            geom.push((start, chunk_len, c_bucket, is_final));
            xs.push(self.backend.embed(&sess.prompt[start..start + chunk_len], c_bucket)?);
        }
        // one dequant slot per session: batched dispatches read every Q8
        // lane's dequantized columns at once, so the slots must coexist
        // (the lockstep key pins a shared cap; engine opts pin uniform Q8)
        let (q8, cap) = {
            let sv = sts[0].stream.as_ref().expect("stream state");
            (sv.q8(), sv.cap)
        };
        let shape = if q8 { [hk, cap, dh] } else { [hk, 0, dh] };
        let (score, slots) = ctx.scratch.score_and_dequant(group.len(), &shape);
        let mut total_dispatches = 0usize;
        let mut worked = vec![0usize; group.len()];
        for l in 0..cfg.n_layers {
            // per-session dispatch prep (each session gets its own dequant
            // slot, so Q8 dequantization never conflicts across the group)
            let mut carry_poss: Vec<Vec<i32>> = Vec::with_capacity(group.len());
            for (st, kv) in sts.iter().zip(slots.iter_mut()) {
                carry_poss.push(self.stream_dispatch_carry(st, l, kv)?);
            }
            let outs = {
                let slots_ro: &[(Tensor, Tensor)] = &*slots;
                let reqs: Vec<ChunkEvictReq> = sts
                    .iter()
                    .zip(group.iter())
                    .enumerate()
                    .map(|(i, (st, sess))| {
                        let lane = &st.stream.as_ref().expect("stream state").layers[l];
                        let (ck, cv) = if lane.q8.is_some() {
                            (&slots_ro[i].0, &slots_ro[i].1)
                        } else {
                            (&lane.carry_k, &lane.carry_v)
                        };
                        ChunkEvictReq {
                            x_chunk: &xs[i],
                            carry_k: ck,
                            carry_v: cv,
                            carry_pos: &carry_poss[i],
                            start: geom[i].0,
                            chunk_len: geom[i].1,
                            total_len: sess.prompt.len(),
                            n_obs: st.n_obs,
                        }
                    })
                    .collect();
                let (outs, dispatches) =
                    self.backend.layer_prefill_chunked_evict_batched(l, &reqs)?;
                total_dispatches += dispatches;
                outs
            };
            if outs.len() != group.len() {
                bail!(
                    "batched evict returned {} outputs for {} sessions",
                    outs.len(),
                    group.len()
                );
            }
            for (i, out) in outs.into_iter().enumerate() {
                let (start, chunk_len, c_bucket, is_final) = geom[i];
                self.consume_stream_lane(
                    &mut group[i],
                    &mut sts[i],
                    l,
                    l,
                    is_final,
                    &out,
                    start,
                    chunk_len,
                    c_bucket,
                    &mut *score,
                    &mut slots[i],
                )?;
                worked[i] += chunk_len;
                xs[i] = out.x_out;
            }
        }
        let mut results = Vec::with_capacity(group.len());
        for (i, (sess, mut st)) in group.iter_mut().zip(sts).enumerate() {
            let (_, chunk_len, _, is_final) = geom[i];
            st.chunk_idx += 1;
            if is_final {
                st.x = xs[i].as_f32()?[(chunk_len - 1) * d..chunk_len * d].to_vec();
                let report = self.finish_chunked(sess, &mut st)?;
                results.push((worked[i], Some(report)));
            } else {
                sess.phase = Phase::Prefilling { next_chunk: st.chunk_idx };
                sess.prefill = Some(st);
                results.push((worked[i], None));
            }
        }
        let secs = t0.elapsed().as_secs_f64() / group.len() as f64;
        for sess in group.iter_mut() {
            sess.prefill_secs += secs;
        }
        Ok((results, total_dispatches))
    }

    /// Layer-major wrapper around [`EngineWorker::consume_stream_lane`]:
    /// lane 0 carries the current layer, the full-prompt hidden rows
    /// accumulate into `x_next`, and the cursor advances layer-outer /
    /// chunk-inner exactly as PR 8 did.
    #[allow(clippy::too_many_arguments)]
    fn consume_stream_chunk(
        &self,
        sess: &mut Session,
        st: &mut ChunkedPrefill,
        out: ChunkEvictOut,
        start: usize,
        chunk_len: usize,
        c_bucket: usize,
        score: &mut ScoreScratch,
        kv: &mut (Tensor, Tensor),
    ) -> Result<()> {
        let d = self.backend.config().d_model;
        let is_final = st.chunk_idx + 1 == st.n_chunks;
        self.consume_stream_lane(
            sess, st, 0, st.layer, is_final, &out, start, chunk_len, c_bucket, score, kv,
        )?;
        let xo = out.x_out.as_f32()?;
        st.x_next[start * d..(start + chunk_len) * d].copy_from_slice(&xo[..chunk_len * d]);
        st.chunk_idx += 1;
        if is_final {
            st.layer += 1;
            st.chunk_idx = 0;
            std::mem::swap(&mut st.x, &mut st.x_next);
        }
        Ok(())
    }

    /// Fold one streaming-evict dispatch into lane `lane_idx` (serving model
    /// layer `layer`): scatter the chunk's K/V after the live carry columns
    /// — into the worker's f32 dequant slot for Q8 lanes (whose
    /// authoritative columns re-quantize below), straight into the lane's
    /// carry otherwise — merge the compact observation panels (adding at
    /// carry columns), then either evict down to the budget union (+ Q8
    /// re-quantization of the changed columns) or, on the layer's final
    /// chunk, run the layer compression and reset the lane so stale panels
    /// stop counting against the resident set. Cursor advancement is the
    /// caller's job.
    #[allow(clippy::too_many_arguments)]
    fn consume_stream_lane(
        &self,
        sess: &mut Session,
        st: &mut ChunkedPrefill,
        lane_idx: usize,
        layer: usize,
        is_final: bool,
        out: &ChunkEvictOut,
        start: usize,
        chunk_len: usize,
        c_bucket: usize,
        score: &mut ScoreScratch,
        kv: &mut (Tensor, Tensor),
    ) -> Result<()> {
        let cfg = self.backend.config();
        let (h, hk, w, dh, d) =
            (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head, cfg.d_model);
        let cap = st.stream.as_ref().expect("stream state").cap;
        let n_live = st.stream.as_ref().expect("stream state").layers[lane_idx].n_live();
        let n_cols = n_live + chunk_len;
        let m = cap + out.k.shape[1];
        let seen = start + chunk_len;
        debug_assert!(n_cols <= cap, "live columns {n_cols} overflow the cap {cap}");

        // chunk K/V land right after the live carry columns
        {
            let cb = out.k.shape[1];
            let kc = out.k.as_f32()?;
            let vc = out.v.as_f32()?;
            let sv = st.stream.as_mut().expect("stream state");
            let lane = &mut sv.layers[lane_idx];
            let (ck, cv) = if lane.q8.is_some() {
                (kv.0.as_f32_mut()?, kv.1.as_f32_mut()?)
            } else {
                (lane.carry_k.as_f32_mut()?, lane.carry_v.as_f32_mut()?)
            };
            for kv in 0..hk {
                let dst = (kv * cap + n_live) * dh;
                let src = kv * cb * dh;
                ck[dst..dst + chunk_len * dh].copy_from_slice(&kc[src..src + chunk_len * dh]);
                cv[dst..dst + chunk_len * dh].copy_from_slice(&vc[src..src + chunk_len * dh]);
            }
        }
        {
            let sv = st.stream.as_mut().expect("stream state");
            let lane = &mut sv.layers[lane_idx];
            // acc/vnorm: add at carry columns, append the chunk's columns
            let mut acc = vec![0.0f32; h * n_cols];
            for hh in 0..h {
                for j in 0..n_live {
                    acc[hh * n_cols + j] = lane.acc[hh * n_live + j] + out.acc[hh * m + j];
                }
                for r in 0..chunk_len {
                    acc[hh * n_cols + n_live + r] = out.acc[hh * m + cap + r];
                }
            }
            lane.acc = acc;
            let mut vnorm = vec![0.0f32; hk * n_cols];
            for kv in 0..hk {
                for j in 0..n_live {
                    vnorm[kv * n_cols + j] = lane.vnorm[kv * n_live + j] + out.vnorm[kv * m + j];
                }
                for r in 0..chunk_len {
                    vnorm[kv * n_cols + n_live + r] = out.vnorm[kv * m + cap + r];
                }
            }
            lane.vnorm = vnorm;
            // rolling window: drop rows that fell out, widen the survivors
            // with the chunk's (zero — future-position) columns, append the
            // chunk's owned rows compacted to the new width
            let keep_from = seen.saturating_sub(w);
            lane.win_rows.retain(|(q, _)| *q >= keep_from);
            for (_, row) in lane.win_rows.iter_mut() {
                let mut wide = vec![0.0f32; h * n_cols];
                for hh in 0..h {
                    wide[hh * n_cols..hh * n_cols + n_live]
                        .copy_from_slice(&row[hh * n_live..(hh + 1) * n_live]);
                }
                *row = wide;
            }
            for (qpos, row) in &out.win_rows {
                if *qpos < keep_from {
                    continue;
                }
                let mut compact = vec![0.0f32; h * n_cols];
                for hh in 0..h {
                    compact[hh * n_cols..hh * n_cols + n_live]
                        .copy_from_slice(&row[hh * m..hh * m + n_live]);
                    compact[hh * n_cols + n_live..hh * n_cols + n_cols]
                        .copy_from_slice(&row[hh * m + cap..hh * m + cap + chunk_len]);
                }
                lane.win_rows.push((*qpos, compact));
            }
            lane.col_pos.extend((start..seen).map(|p| p as i32));
            sv.max_live_cols = sv.max_live_cols.max(n_cols);
        }
        st.bucket_fills.push((c_bucket, chunk_len));

        // bounded transient: retained caches + every lane's live carry
        // columns — never more than L·cap, however long the prompt (the Q8
        // dequant slot is per-worker and amortized across sessions, so it
        // no longer counts here). Resident adds the allocated lanes,
        // panels, and the hidden rows: one chunk bucket (chunk-major) or
        // O(prompt) rows (layer-major).
        let retained: usize = sess.caches.iter().map(|c| c.live_bytes()).sum();
        let (live_carry, resident) = {
            let sv = st.stream.as_ref().expect("stream state");
            let live_carry: usize = sv
                .layers
                .iter()
                .map(|lane| match &lane.q8 {
                    Some(q8) => q8.live_bytes(lane.n_live()),
                    None => 2 * hk * lane.n_live() * dh * 4,
                })
                .sum();
            let lanes_alloc: usize = sv.layers.iter().map(|l| l.resident_bytes()).sum();
            let hidden = if sv.chunk_major {
                2 * c_bucket * d * 4
            } else {
                (st.x.len() + st.x_next.len()) * 4
            };
            (live_carry, lanes_alloc + hidden)
        };
        st.peak_transient = st.peak_transient.max(retained + live_carry);
        st.peak_resident = st.peak_resident.max(resident);

        if is_final {
            self.compress_streamed_layer(sess, st, lane_idx, layer, score, kv)?;
            st.stream.as_mut().expect("stream state").layers[lane_idx].reset_for_next_layer();
        } else {
            let union = hk * self.opts.budget_per_head.max(w);
            let survivors = if n_cols > union {
                self.stream_evict(st, lane_idx, union, score, kv)?
            } else {
                None
            };
            self.requant_lane(st, lane_idx, n_live, survivors, kv)?;
        }
        Ok(())
    }

    /// Prepare lane `lane_idx` for its next dispatch: Q8 lanes dequantize
    /// their live columns into the worker's f32 dequant slot `kv` (the
    /// dispatch reads the slot; its contents are only valid until another
    /// lane dequantizes into it), f32 lanes need no preparation. Returns
    /// the cap-width carry position map (-1 past the live columns).
    fn stream_dispatch_carry(
        &self,
        st: &ChunkedPrefill,
        lane_idx: usize,
        kv: &mut (Tensor, Tensor),
    ) -> Result<Vec<i32>> {
        let sv = st.stream.as_ref().expect("stream state");
        let lane = &sv.layers[lane_idx];
        let mut carry_pos = vec![-1i32; sv.cap];
        carry_pos[..lane.n_live()].copy_from_slice(&lane.col_pos);
        if let Some(q8) = &lane.q8 {
            q8.dequantize_cols(lane.n_live(), kv.0.as_f32_mut()?, kv.1.as_f32_mut()?);
        }
        Ok(carry_pos)
    }

    /// Bring a Q8 lane's authoritative codes back in sync after a chunk
    /// landed (and possibly evicted): surviving pre-existing columns move
    /// their codes with [`crate::kvcache::Q8Carry::copy_col`] (no fresh
    /// quantization, so no added drift), chunk-appended survivors quantize
    /// from the compacted f32 columns in the worker's dequant slot `kv`.
    /// `survivors` is the eviction's ascending keep list (None = nothing
    /// evicted, only the appended columns are new). No-op for f32 lanes.
    fn requant_lane(
        &self,
        st: &mut ChunkedPrefill,
        lane_idx: usize,
        n_live_pre: usize,
        survivors: Option<Vec<usize>>,
        kv: &(Tensor, Tensor),
    ) -> Result<()> {
        let sv = st.stream.as_mut().expect("stream state");
        let lane = &mut sv.layers[lane_idx];
        if lane.q8.is_none() {
            return Ok(());
        }
        let n_cols = lane.n_live();
        let sk = kv.0.as_f32()?;
        let svv = kv.1.as_f32()?;
        let q8 = lane.q8.as_mut().expect("q8 lane");
        match survivors {
            None => q8.quantize_cols(n_live_pre, n_cols, sk, svv),
            Some(surv) => {
                debug_assert_eq!(surv.len(), n_cols, "survivor list must match live columns");
                // ascending dst with dst <= surv[dst]: copies move codes
                // leftward and fresh quantizations write below every source
                // still to be read, so a single in-place pass is safe
                for (dst, &src) in surv.iter().enumerate() {
                    if src < n_live_pre {
                        q8.copy_col(dst, src);
                    } else {
                        q8.quantize_cols(dst, dst + 1, sk, svv);
                    }
                }
            }
        }
        Ok(())
    }

    /// Mid-prefill eviction on lane `lane_idx`: score the live columns
    /// (Algorithm 1 over the tokens seen so far — the trailing observation
    /// window is the suffix [`select_prefill`] pins), then compact every
    /// panel plus the carry K/V down to the keep-set union. Columns stay in
    /// ascending-position order, so the pinned suffix is exactly the
    /// trailing w positions. Q8 lanes compact the worker's f32 dequant slot
    /// `kv` (their authoritative f32 view at this point); the caller
    /// re-quantizes from it via [`EngineWorker::requant_lane`]. Returns the
    /// ascending survivor list when columns were dropped, `None` when the
    /// keep-set covered everything.
    fn stream_evict(
        &self,
        st: &mut ChunkedPrefill,
        lane_idx: usize,
        union_budget: usize,
        scratch: &mut ScoreScratch,
        kv: &mut (Tensor, Tensor),
    ) -> Result<Option<Vec<usize>>> {
        let cfg = self.backend.config();
        let (h, hk, w, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head);
        let cap = st.stream.as_ref().expect("stream state").cap;
        let survivors: Vec<usize> = {
            let lane = &st.stream.as_ref().expect("stream state").layers[lane_idx];
            let n_cols = lane.n_live();
            let obs = stream_obs(lane, h, hk, w);
            let p = &self.opts.policy;
            let scores = score::kv_head_scores_with(
                p.score,
                p.group_reduce,
                &obs,
                self.opts.pool_kernel,
                scratch,
            );
            let keepset = select_prefill(&scores, n_cols, union_budget, w, p.head_alloc);
            let mut live = vec![false; n_cols];
            for keep in &keepset.keep {
                for &j in keep {
                    live[j] = true;
                }
            }
            (0..n_cols).filter(|&j| live[j]).collect()
        };
        let sv = st.stream.as_mut().expect("stream state");
        let lane = &mut sv.layers[lane_idx];
        let n_cols = lane.n_live();
        if survivors.len() == n_cols {
            return Ok(None);
        }
        let ns = survivors.len();
        lane.col_pos = survivors.iter().map(|&j| lane.col_pos[j]).collect();
        let mut acc = vec![0.0f32; h * ns];
        for hh in 0..h {
            for (dst, &src) in survivors.iter().enumerate() {
                acc[hh * ns + dst] = lane.acc[hh * n_cols + src];
            }
        }
        lane.acc = acc;
        let mut vnorm = vec![0.0f32; hk * ns];
        for kv in 0..hk {
            for (dst, &src) in survivors.iter().enumerate() {
                vnorm[kv * ns + dst] = lane.vnorm[kv * n_cols + src];
            }
        }
        lane.vnorm = vnorm;
        for (_, row) in lane.win_rows.iter_mut() {
            let mut compact = vec![0.0f32; h * ns];
            for hh in 0..h {
                for (dst, &src) in survivors.iter().enumerate() {
                    compact[hh * ns + dst] = row[hh * n_cols + src];
                }
            }
            *row = compact;
        }
        // gather the surviving K/V rows forward; survivors ascend, so every
        // copy moves a row to an index <= its source and ranges never overlap
        let (ck, cv) = if lane.q8.is_some() {
            (kv.0.as_f32_mut()?, kv.1.as_f32_mut()?)
        } else {
            (lane.carry_k.as_f32_mut()?, lane.carry_v.as_f32_mut()?)
        };
        for kv in 0..hk {
            let base = kv * cap * dh;
            for (dst, &src) in survivors.iter().enumerate() {
                if dst == src {
                    continue;
                }
                ck.copy_within(base + src * dh..base + (src + 1) * dh, base + dst * dh);
                cv.copy_within(base + src * dh..base + (src + 1) * dh, base + dst * dh);
            }
        }
        Ok(Some(survivors))
    }

    /// Final-chunk layer compression on the streamed path: the same
    /// Algorithm 1 selection, Eq. 7 / CAKE weights, and Algorithm 2 cascade
    /// as [`EngineWorker::compress_prefilled_layer`], but over the compact
    /// survivor columns (scores run host-side — the fused artifact's bucket
    /// shapes do not apply to compacted carries) with slot positions
    /// rewritten from the column-position map. Q8 lanes load from the
    /// worker's f32 dequant slot `kv`, which holds their authoritative
    /// columns after the final chunk's scatter (no re-quantization happens
    /// on the final chunk, so nothing round-trips one extra time).
    fn compress_streamed_layer(
        &self,
        sess: &mut Session,
        st: &mut ChunkedPrefill,
        lane_idx: usize,
        l: usize,
        scratch: &mut ScoreScratch,
        kv: &(Tensor, Tensor),
    ) -> Result<()> {
        let cfg = self.backend.config();
        let (h, hk, w) = (cfg.n_heads, cfg.n_kv_heads, cfg.window);
        let floor = hk * w;
        let dynamic = self.opts.policy.dynamic_layer();
        let (scores, obs, col_pos) = {
            let lane = &st.stream.as_ref().expect("stream state").layers[lane_idx];
            let obs = stream_obs(lane, h, hk, w);
            let p = &self.opts.policy;
            let scores = score::kv_head_scores_with(
                p.score,
                p.group_reduce,
                &obs,
                self.opts.pool_kernel,
                scratch,
            );
            (scores, obs, lane.col_pos.clone())
        };
        let n_cols = col_pos.len();
        if dynamic {
            st.weights.push(self.layer_weight(&scores, &obs));
            let total = self.total_budget();
            let split = alloc::proportional(&st.weights, total, floor);
            st.budgets[..=l].copy_from_slice(&split);
        }
        let keepset =
            select_prefill(&scores, n_cols, st.budgets[l], w, self.opts.policy.head_alloc);
        let capacity = self.capacity_for(st.budgets[l], n_cols, sess.max_new_tokens)?;
        let mut cache = HotStore::new(hk, cfg.d_head, capacity);
        {
            let lane = &st.stream.as_ref().expect("stream state").layers[lane_idx];
            let (ck, cv) = if lane.q8.is_some() {
                (&kv.0, &kv.1)
            } else {
                (&lane.carry_k, &lane.carry_v)
            };
            cache.load_from_prefill_at(ck, cv, &keepset.keep, &keepset.scores, &col_pos);
        }
        sess.caches.push(cache);
        sess.residency.push(Residency::Hot);
        if dynamic {
            recompress_earlier(
                &mut sess.caches[..l],
                &st.budgets,
                hk,
                self.opts.policy.head_alloc,
            );
        }
        Ok(())
    }

    /// One serial decode step: feed the last generated token, produce the
    /// next. Residency boundary: workers only ever see hot caches — a
    /// session with warm layers must be prefetched by the tier side first.
    pub fn decode_step(&self, ctx: &mut WorkerContext, sess: &mut Session) -> Result<StepReport> {
        self.ensure_device(ctx);
        if !sess.is_fully_hot() {
            bail!(
                "decode_step on session {} with non-resident layers (prefetch before decode)",
                sess.id
            );
        }
        let t0 = std::time::Instant::now();
        let cfg = self.backend.config().clone();
        let tok = *sess.generated.last().ok_or_else(|| anyhow!("decode before prefill"))?;
        let pos = sess.next_pos;
        let d = cfg.d_model;
        let emb = self.backend.embed(&[tok], 1)?;
        let mut x = Tensor::f32(emb.as_f32()?[..d].to_vec(), &[1, d]);
        let mut dispatches = Vec::with_capacity(cfg.n_layers);

        for l in 0..cfg.n_layers {
            let out = self.backend.layer_decode(l, &x, &sess.caches[l], pos)?;
            let cache = &mut sess.caches[l];
            self.scatter_decode_out(cache, &out.attn, &out.k_new, &out.v_new, pos, l)?;
            dispatches.push((sess.caches[l].capacity(), 1));
            x = out.x_out;
        }

        let logits = self.backend.logits(&x)?;
        let next = argmax(&logits);
        sess.generated.push(next);
        sess.next_pos += 1;
        sess.decode_secs += t0.elapsed().as_secs_f64();
        if sess.is_done() {
            sess.phase = Phase::Finished;
        }
        Ok(StepReport {
            tokens: vec![next],
            dispatches,
            kv_after: vec![sess.kv_bytes()],
            sessions: 1,
        })
    }

    /// One decode step for B sessions sharing a capacity bucket: gather the
    /// last tokens into one [B, d] input, issue a single
    /// `layer_decode_batched` dispatch per layer, then scatter each
    /// session's attention row back into its own score update / append /
    /// eviction. Produces tokens, scores, and cache contents bit-identical
    /// to looping [`EngineWorker::decode_step`] over the same sessions.
    pub fn decode_step_batch(
        &self,
        ctx: &mut WorkerContext,
        sessions: &mut [Session],
    ) -> Result<StepReport> {
        if sessions.is_empty() {
            return Ok(StepReport {
                tokens: vec![],
                dispatches: vec![],
                kv_after: vec![],
                sessions: 0,
            });
        }
        self.ensure_device(ctx);
        let sig = sessions[0].capacity_signature();
        for sess in sessions.iter() {
            if !sess.is_fully_hot() {
                bail!(
                    "decode_step_batch on session {} with non-resident layers \
                     (prefetch before decode)",
                    sess.id
                );
            }
            if !sess.matches_capacity_signature(&sig) {
                bail!("decode_step_batch: session {} is in a different capacity bucket", sess.id);
            }
        }
        let t0 = std::time::Instant::now();
        let cfg = self.backend.config().clone();
        let b = sessions.len();
        let d = cfg.d_model;

        // gather: one packed residual-stream input for the whole batch
        let mut xs = vec![0.0f32; b * d];
        let mut positions = Vec::with_capacity(b);
        for (i, sess) in sessions.iter().enumerate() {
            let tok = *sess.generated.last().ok_or_else(|| anyhow!("decode before prefill"))?;
            let emb = self.backend.embed(&[tok], 1)?;
            xs[i * d..(i + 1) * d].copy_from_slice(&emb.as_f32()?[..d]);
            positions.push(sess.next_pos);
        }
        let mut x = Tensor::f32(xs, &[b, d]);
        let mut dispatches = Vec::with_capacity(cfg.n_layers);

        for l in 0..cfg.n_layers {
            // one dispatch per (layer, capacity bucket) for the whole group
            let out = {
                let caches: Vec<&HotStore> = sessions.iter().map(|s| &s.caches[l]).collect();
                self.backend.layer_decode_batched(l, &x, &caches, &positions)?
            };
            dispatches.push((sig[l], out.dispatches as u64));
            // scatter: per-session cache maintenance stays independent
            for (i, sess) in sessions.iter_mut().enumerate() {
                let cache = &mut sess.caches[l];
                self.scatter_decode_out(
                    cache,
                    &out.attn[i],
                    &out.k_new[i],
                    &out.v_new[i],
                    positions[i],
                    l,
                )?;
            }
            x = out.x_out;
        }

        // per-session logits + bookkeeping (same order as the serial loop)
        let xf = x.as_f32()?;
        let mut next_tokens = Vec::with_capacity(b);
        let mut kv_after = Vec::with_capacity(b);
        for (i, sess) in sessions.iter_mut().enumerate() {
            let xi = Tensor::f32(xf[i * d..(i + 1) * d].to_vec(), &[1, d]);
            let logits = self.backend.logits(&xi)?;
            let next = argmax(&logits);
            sess.generated.push(next);
            sess.next_pos += 1;
            kv_after.push(sess.kv_bytes());
            if sess.is_done() {
                sess.phase = Phase::Finished;
            }
            next_tokens.push(next);
        }
        let per_session_secs = t0.elapsed().as_secs_f64() / b as f64;
        for sess in sessions.iter_mut() {
            sess.decode_secs += per_session_secs;
        }
        Ok(StepReport { tokens: next_tokens, dispatches, kv_after, sessions: b })
    }

    /// Scatter one session's layer-decode outputs back into its cache:
    /// decode-time score maintenance, append, and over-budget eviction.
    /// Shared verbatim by [`EngineWorker::decode_step`] and
    /// [`EngineWorker::decode_step_batch`] so the two paths stay
    /// bit-identical.
    fn scatter_decode_out(
        &self,
        cache: &mut HotStore,
        attn: &Tensor,
        k_new: &[f32],
        v_new: &[f32],
        pos: usize,
        layer: usize,
    ) -> Result<()> {
        let policy = &self.opts.policy;
        let cfg = self.backend.config();
        let maintain = policy.decode_evict && !policy.full_cache;
        if maintain {
            update_decode_scores(cache, attn, cfg, policy.score);
        }
        if !cache.append(k_new, v_new, pos as i32, decode_entry_score(policy)) {
            bail!("layer {layer} cache overflow at pos {pos}");
        }
        if maintain {
            evict_decode_overflow(cache, self.opts.budget_per_head, pos, cfg.window);
        }
        Ok(())
    }
}

/// Build one layer-major streaming dispatch's owned inputs: the chunk rows
/// padded to the chunk bucket (sliced from the full-prompt hidden buffer)
/// and lane 0's cap-width carry position map (-1 past the live columns).
/// Chunk-major passes build these per-lane inline instead.
fn stream_chunk_inputs(
    st: &ChunkedPrefill,
    start: usize,
    chunk_len: usize,
    c_bucket: usize,
    d: usize,
) -> (Tensor, Vec<i32>) {
    let sv = st.stream.as_ref().expect("stream_chunk_inputs on a non-stream prefill");
    let lane = &sv.layers[0];
    let mut xc = vec![0.0f32; c_bucket * d];
    xc[..chunk_len * d].copy_from_slice(&st.x[start * d..(start + chunk_len) * d]);
    let mut carry_pos = vec![-1i32; sv.cap];
    carry_pos[..lane.n_live()].copy_from_slice(&lane.col_pos);
    (Tensor::f32(xc, &[c_bucket, d]), carry_pos)
}

/// Assemble a scoring [`LayerObs`] over one lane's compact column space: the
/// last w query rows in ascending qpos order (exactly the monolithic
/// window-row layout) plus the accumulated acc/vnorm panels.
fn stream_obs(lane: &StreamLayer, h: usize, hk: usize, w: usize) -> LayerObs {
    let n_cols = lane.n_live();
    debug_assert_eq!(lane.win_rows.len(), w, "scoring before the observation window filled");
    let mut win = vec![0.0f32; h * w * n_cols];
    for (r, (_, row)) in lane.win_rows.iter().enumerate() {
        for hh in 0..h {
            win[(hh * w + r) * n_cols..(hh * w + r + 1) * n_cols]
                .copy_from_slice(&row[hh * n_cols..(hh + 1) * n_cols]);
        }
    }
    LayerObs {
        win_attn: Tensor::f32(win, &[h, w, n_cols]),
        acc_attn: Tensor::f32(lane.acc.clone(), &[h, n_cols]),
        vnorm: Tensor::f32(lane.vnorm.clone(), &[hk, n_cols]),
        length: n_cols,
    }
}

/// Cascade recompression work is per-layer independent (each layer reuses
/// its own stored scores), so fan it out across scoped threads once there is
/// enough live cache to be worth a spawn; tiny prompts stay serial.
const RECOMPRESS_PAR_MIN_ENTRIES: usize = 8192;

fn recompress_earlier(
    caches: &mut [HotStore],
    budgets: &[usize],
    n_kv_heads: usize,
    head_alloc: crate::compress::HeadAlloc,
) {
    let shrink_one = |(l2, cache): (usize, &mut HotStore)| {
        if cache.total_entries() > budgets[l2] {
            let stored: Vec<&[f32]> = (0..n_kv_heads).map(|h| cache.head_scores(h)).collect();
            let keep = select_recompress(&stored, budgets[l2], head_alloc);
            cache.re_evict(&keep);
        }
    };
    let live: usize = caches.iter().map(|c| c.total_entries()).sum();
    if caches.len() > 1 && live >= RECOMPRESS_PAR_MIN_ENTRIES {
        crate::util::par::scoped_for_each(caches.iter_mut().enumerate(), shrink_one);
    } else {
        for item in caches.iter_mut().enumerate() {
            shrink_one(item);
        }
    }
}

pub fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

/// Initial stored score for freshly decoded entries.
fn decode_entry_score(policy: &Policy) -> f32 {
    if policy.decode_evict {
        0.0 // will accumulate from decode attention
    } else {
        // non-decode-evicting policies never re-rank decoded tokens
        f32::MAX
    }
}

/// H2O/TOVA decode-time score maintenance from the decode attention row.
fn update_decode_scores(
    cache: &mut HotStore,
    attn: &Tensor,
    cfg: &ModelConfig,
    kind: ScoreKind,
) {
    let m1 = attn.shape[1]; // capacity + 1
    let a = attn.as_f32().expect("attn");
    let group = cfg.group_size();
    for kv in 0..cfg.n_kv_heads {
        // fully pinned heads (full-cache loads, recompression windows) have
        // nothing to maintain — skip the per-entry group reduction outright
        if cache.head_scores(kv).iter().all(|&s| s == f32::MAX) {
            continue;
        }
        let len = cache.head_len(kv);
        for i in 0..len {
            let s = cache.score(kv, i);
            if s == f32::MAX {
                continue; // pinned entry: its score is never replaced
            }
            // mean over the q-heads of this group
            let mut mass = 0.0;
            for g in 0..group {
                mass += a[(kv * group + g) * m1 + i];
            }
            mass /= group as f32;
            let new = match kind {
                ScoreKind::Tova => mass, // replace with last-token attention
                _ => s + mass,           // H2O: accumulate
            };
            cache.set_score(kv, i, new);
        }
    }
}

/// Evict the lowest-scored non-recent entries of each over-budget head,
/// with all of a head's victims selected in one pass (the old form rescanned
/// the entire head per victim inside a `while` loop — O(len²) when decode
/// pushes a head far over budget, e.g. right after a budget shrink).
fn evict_decode_overflow(cache: &mut HotStore, per_head_budget: usize, pos: usize, window: usize) {
    let hk = cache.n_kv_heads();
    for h in 0..hk {
        let len = cache.head_len(h);
        let over = len.saturating_sub(per_head_budget);
        if over == 0 {
            continue;
        }
        // candidates: entries outside the protected recent window
        let mut candidates: Vec<(f32, usize)> = (0..len)
            .filter(|&i| {
                let p = cache.position(h, i).max(0) as usize;
                pos.saturating_sub(p) > window
            })
            .map(|i| (cache.score(h, i), i))
            .collect();
        // lowest score first, ties broken by slot order — the same victims
        // the old scan-per-victim selection produced
        candidates.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        candidates.truncate(over);
        // remove back-to-front so earlier slot indices stay valid
        let mut victims: Vec<usize> = candidates.into_iter().map(|(_, i)| i).collect();
        victims.sort_unstable_by(|a, b| b.cmp(a));
        for i in victims {
            cache.remove_one(h, i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::backend::MockBackend;

    fn engine(policy: &str, budget: usize) -> Engine<MockBackend> {
        let mut mock = MockBackend::new(MockBackend::default_config());
        mock.hot_positions = vec![40, 41, 42];
        Engine::new(mock, EngineOptions::new(Policy::by_name(policy).unwrap(), budget))
    }

    fn prompt(n: usize) -> Vec<i32> {
        (0..n).map(|i| (i % 256) as i32).collect()
    }

    #[test]
    fn full_cache_keeps_everything() {
        let mut e = engine("full", 32);
        let (sess, _) = e.prefill_only(&prompt(100)).unwrap();
        for c in &sess.caches {
            assert_eq!(c.total_entries(), 4 * 100);
        }
    }

    #[test]
    fn budgets_respected_static() {
        for name in ["snapkv", "ada-snapkv", "pyramidkv", "h2o", "tova", "vatp", "streaming"] {
            let mut e = engine(name, 32);
            let (sess, _) = e.prefill_only(&prompt(200)).unwrap();
            let total: usize = sess.caches.iter().map(|c| c.total_entries()).sum();
            let budget_total = 32 * 4 * 4;
            assert!(total <= budget_total, "{name}: {total} > {budget_total}");
            // fully used modulo per-head/per-layer integer rounding
            // (fixed head budgets divide each layer's budget by Hk)
            assert!(
                budget_total - total <= 4 * 4,
                "{name} must use its budget: {total} of {budget_total}"
            );
        }
    }

    #[test]
    fn budgets_respected_dynamic() {
        for name in ["lava", "cake", "lava-nohead"] {
            let mut e = engine(name, 32);
            let (sess, _) = e.prefill_only(&prompt(200)).unwrap();
            let total: usize = sess.caches.iter().map(|c| c.total_entries()).sum();
            let budget_total = 32 * 4 * 4;
            assert!(total <= budget_total, "{name}: {total} > {budget_total}");
            assert!(sess.budgets.iter().sum::<usize>() == budget_total);
            // every layer keeps at least its protected window
            for c in &sess.caches {
                for h in 0..4 {
                    assert!(c.head_len(h) >= 16, "{name}: window evicted");
                }
            }
        }
    }

    #[test]
    fn lava_budgets_vary_by_layer() {
        let mut e = engine("lava", 48);
        let (sess, _) = e.prefill_only(&prompt(256)).unwrap();
        // entropy-based budgets should not be exactly uniform for the mock's
        // structured attention (layers see identical stats in the mock, so
        // allow equality but require sums to match)
        assert_eq!(sess.budgets.iter().sum::<usize>(), 48 * 4 * 4);
    }

    #[test]
    fn hot_positions_survive_compression() {
        let mut e = engine("lava", 24);
        let (sess, _) = e.prefill_only(&prompt(200)).unwrap();
        for (l, c) in sess.caches.iter().enumerate() {
            for h in 0..4 {
                let kept: Vec<i32> = (0..c.head_len(h)).map(|i| c.position(h, i)).collect();
                assert!(
                    kept.contains(&40) || kept.contains(&41) || kept.contains(&42),
                    "layer {l} head {h} lost all hot positions: {kept:?}"
                );
            }
        }
    }

    #[test]
    fn generate_runs_to_length() {
        let mut e = engine("lava", 32);
        let r = e
            .generate(&GenerateRequest { prompt: prompt(120), max_new_tokens: 8 })
            .unwrap();
        assert_eq!(r.tokens.len(), 8);
        assert!(r.kv_bytes_after_prefill > 0);
        assert!(r.peak_kv_bytes >= r.kv_bytes_after_prefill);
    }

    #[test]
    fn decode_evict_bounds_h2o() {
        let mut e = engine("h2o", 24);
        let req = GenerateRequest { prompt: prompt(150), max_new_tokens: 20 };
        let mut sess = e.new_session(&req);
        e.prefill(&mut sess).unwrap();
        for _ in 0..20 {
            if sess.is_done() {
                break;
            }
            e.decode_step(&mut sess).unwrap();
        }
        for c in &sess.caches {
            for h in 0..4 {
                assert!(c.head_len(h) <= 24, "h2o decode must stay within budget");
            }
        }
    }

    #[test]
    fn snapkv_grows_during_decode() {
        let mut e = engine("snapkv", 24);
        let req = GenerateRequest { prompt: prompt(150), max_new_tokens: 10 };
        let mut sess = e.new_session(&req);
        e.prefill(&mut sess).unwrap();
        let before = sess.total_entries();
        for _ in 0..10 {
            if sess.is_done() {
                break;
            }
            e.decode_step(&mut sess).unwrap();
        }
        assert!(sess.total_entries() > before, "snapkv keeps decoded tokens");
    }

    #[test]
    fn decode_refuses_non_resident_session() {
        let mut e = engine("lava", 24);
        let req = GenerateRequest { prompt: prompt(100), max_new_tokens: 4 };
        let mut sess = e.new_session(&req);
        e.prefill(&mut sess).unwrap();
        sess.residency[0] = Residency::Warm;
        let err = e.decode_step(&mut sess);
        assert!(err.is_err(), "engine must refuse spilled (warm) layers");
        sess.residency[0] = Residency::Hot;
        e.decode_step(&mut sess).unwrap();
    }

    #[test]
    fn session_ids_never_collide_with_caller_supplied_ids() {
        let mut e = engine("lava", 24);
        let req = GenerateRequest { prompt: prompt(100), max_new_tokens: 1 };
        let a = e.new_session(&req);
        assert_eq!(a.id, 1);
        // a batcher-style caller hands out id 7; the engine counter must
        // advance past it instead of re-issuing 2..=7 later
        let b = e.new_session_with_id(7, &req);
        assert_eq!(b.id, 7);
        let c = e.new_session(&req);
        assert_eq!(c.id, 8);
    }

    #[test]
    fn decode_step_batch_rejects_mixed_buckets_and_warm_layers() {
        let mut e = engine("lava", 24);
        let mk = |e: &mut Engine<MockBackend>, n: usize| {
            let req = GenerateRequest { prompt: prompt(n), max_new_tokens: 4 };
            let mut s = e.new_session(&req);
            e.prefill(&mut s).unwrap();
            s
        };
        let s1 = mk(&mut e, 100);
        let mut s2 = mk(&mut e, 100);
        // force a different capacity signature on s2
        s2.caches[0] = crate::kvcache::HotStore::new(4, 16, 4096);
        let mut pair = [s1, s2];
        assert!(e.decode_step_batch(&mut pair).is_err(), "mixed buckets must bail");

        let s3 = mk(&mut e, 100);
        let mut s4 = mk(&mut e, 100);
        s4.residency[0] = Residency::Warm;
        let mut pair = [s3, s4];
        assert!(e.decode_step_batch(&mut pair).is_err(), "warm layers must bail");

        let mut empty: [Session; 0] = [];
        assert_eq!(e.decode_step_batch(&mut empty).unwrap(), vec![]);
    }

    #[test]
    fn decode_step_batch_matches_serial_tokens() {
        let mut serial = engine("h2o", 24);
        let mut batched = engine("h2o", 24);
        let reqs: Vec<GenerateRequest> = (0..3)
            .map(|i| GenerateRequest {
                prompt: (0..100).map(|t| ((t * (i + 3)) % 251) as i32).collect(),
                max_new_tokens: 6,
            })
            .collect();
        let mut ss: Vec<Session> = reqs.iter().map(|r| serial.new_session(r)).collect();
        let mut bs: Vec<Session> = reqs.iter().map(|r| batched.new_session(r)).collect();
        for (a, b) in ss.iter_mut().zip(bs.iter_mut()) {
            serial.prefill(a).unwrap();
            batched.prefill(b).unwrap();
        }
        for _ in 0..5 {
            let serial_toks: Vec<i32> =
                ss.iter_mut().map(|s| serial.decode_step(s).unwrap()).collect();
            let batch_toks = batched.decode_step_batch(&mut bs).unwrap();
            assert_eq!(serial_toks, batch_toks);
        }
        // dispatch accounting: 5 rounds × 4 layers, one dispatch per layer
        assert_eq!(batched.metrics.decode_dispatches_total(), 20);
        assert_eq!(serial.metrics.decode_dispatches_total(), 60);
        assert!((batched.metrics.batch_occupancy() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn worker_view_matches_engine_front() {
        // the &self worker path must be the same math as the &mut engine
        // path — same tokens, same dispatch totals reported for absorption
        let mut via_engine = engine("lava", 24);
        let mut via_worker = engine("lava", 24);
        let req = GenerateRequest { prompt: prompt(120), max_new_tokens: 5 };
        let mut a = via_engine.new_session(&req);
        via_engine.prefill(&mut a).unwrap();
        let mut b = via_worker.new_session(&req);
        let mut ctx = WorkerContext::new(0);
        let pre = via_worker.worker().prefill(&mut ctx, &mut b).unwrap();
        via_worker.absorb_prefill(&pre);
        assert_eq!(a.generated, b.generated, "prefill token");
        for _ in 0..4 {
            let t1 = via_engine.decode_step(&mut a).unwrap();
            let report = via_worker.worker().decode_step(&mut ctx, &mut b).unwrap();
            via_worker.absorb_step(&report);
            assert_eq!(vec![t1], report.tokens);
        }
        assert_eq!(a.generated, b.generated);
        assert_eq!(
            via_engine.metrics.decode_dispatches_total(),
            via_worker.metrics.decode_dispatches_total()
        );
        assert_eq!(via_engine.metrics.peak_kv_bytes, via_worker.metrics.peak_kv_bytes);
        assert_eq!(via_engine.metrics.decode_batches, via_worker.metrics.decode_batches);
    }

    #[test]
    fn short_prompt_rejected() {
        let mut e = engine("lava", 32);
        assert!(e.prefill_only(&prompt(8)).is_err());
        let mut e = engine("lava", 32);
        let req = GenerateRequest { prompt: prompt(8), max_new_tokens: 1 };
        let mut s = e.new_session(&req);
        assert!(e.worker().begin_chunked_prefill(&mut s, 64).is_err());
    }

    /// Per-layer cache fingerprint: (capacity, per-head kept (position,
    /// score) pairs) — the keep-set identity the chunked path must preserve.
    fn cache_fingerprint(sess: &Session) -> Vec<(usize, Vec<Vec<(i32, f32)>>)> {
        sess.caches
            .iter()
            .map(|c| {
                let heads = (0..c.n_kv_heads())
                    .map(|h| (0..c.head_len(h)).map(|i| (c.position(h, i), c.score(h, i))).collect())
                    .collect();
                (c.capacity(), heads)
            })
            .collect()
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_monolithic() {
        for name in ["lava", "h2o", "snapkv", "full"] {
            let mut mono = engine(name, 24);
            let req = GenerateRequest { prompt: prompt(200), max_new_tokens: 6 };
            let mut ms = mono.new_session(&req);
            mono.prefill(&mut ms).unwrap();
            // 256 = one chunk (>= prompt), 96 = misaligned tail, 17 = tiny
            for chunk in [256usize, 96, 17] {
                let mut e = engine(name, 24);
                let mut s = e.new_session(&req);
                e.prefill_chunked(&mut s, chunk).unwrap();
                assert!(s.prefill.is_none(), "state machine must be torn down");
                assert_eq!(s.generated, ms.generated, "{name}/{chunk}: first token");
                assert_eq!(s.budgets, ms.budgets, "{name}/{chunk}: budgets");
                assert_eq!(
                    cache_fingerprint(&s),
                    cache_fingerprint(&ms),
                    "{name}/{chunk}: keep-sets"
                );
                // and decode stays in lockstep on the compressed caches
                for _ in 0..5 {
                    let a = mono.decode_step(&mut ms).unwrap();
                    let b = e.decode_step(&mut s).unwrap();
                    assert_eq!(a, b, "{name}/{chunk}: decode token");
                }
                // rewind the monolithic session for the next chunk size
                let mut fresh = mono.new_session(&req);
                mono.prefill(&mut fresh).unwrap();
                ms = fresh;
            }
        }
    }

    #[test]
    fn chunked_prefill_advances_incrementally_under_budget() {
        let mut e = engine("lava", 24);
        let req = GenerateRequest { prompt: prompt(150), max_new_tokens: 2 };
        let mut s = e.new_session(&req);
        let w = e.worker();
        let mut ctx = WorkerContext::new(0);
        w.begin_chunked_prefill(&mut s, 32).unwrap();
        assert_eq!(s.phase, Phase::Prefilling { next_chunk: 0 });
        let mut advances = 0;
        let report = loop {
            let (tokens, report) = w.advance_chunked_prefill(&mut ctx, &mut s, Some(64)).unwrap();
            advances += 1;
            assert!(tokens > 0, "every advance makes progress");
            assert!(tokens <= 64, "budget respected (one-chunk overshoot only)");
            if let Some(r) = report {
                break r;
            }
            assert!(matches!(s.phase, Phase::Prefilling { .. }));
        };
        // 150 tokens × 4 layers = 600 token-dispatches at ≤ 64/advance
        assert!(advances >= 600 / 64, "prefill spanned multiple advances: {advances}");
        assert_eq!(s.phase, Phase::Decoding);
        assert_eq!(report.bucket_fills.len(), 5 * 4, "5 chunks × 4 layers");
        // the mock's smallest prefill bucket is 128, so every 32-token
        // chunk dispatches at bucket 128 with <= 32 valid rows
        assert!(report.bucket_fills.iter().all(|&(b, v)| b == 128 && v <= 32));

        // identical to the monolithic run
        let mut mono = engine("lava", 24);
        let mut ms = mono.new_session(&req);
        let mr = mono.worker().prefill(&mut WorkerContext::new(0), &mut ms).unwrap();
        assert_eq!(report.token, mr.token);
        assert_eq!(report.peak_transient, mr.peak_transient);
        assert_eq!(report.live_after, mr.live_after);
        assert_eq!(s.budgets, ms.budgets);
    }

    #[test]
    fn chunked_prefill_serves_over_bucket_prompts() {
        let mut mock = MockBackend::new(MockBackend::default_config());
        mock.hot_positions = vec![40, 41, 42];
        mock.buckets_prefill = vec![64, 128, 256];
        let mut e = Engine::new(mock, EngineOptions::new(Policy::by_name("lava").unwrap(), 24));
        let req = GenerateRequest { prompt: prompt(600), max_new_tokens: 4 };
        // monolithic: rejected (no bucket >= 600)
        let mut ms = e.new_session(&req);
        assert!(e.prefill(&mut ms).is_err());
        // chunked: n_obs falls back to the exact prompt length
        assert!(e.worker().chunked_prefill_supported(600, 128));
        let mut s = e.new_session(&req);
        e.prefill_chunked(&mut s, 128).unwrap();
        assert_eq!(s.generated.len(), 1);
        assert_eq!(s.budgets.iter().sum::<usize>(), 24 * 4 * 4);
        while !s.is_done() {
            e.decode_step(&mut s).unwrap();
        }
        assert_eq!(s.generated.len(), 4);
    }

    #[test]
    fn streaming_keeps_sinks_and_recency() {
        let mut e = engine("streaming", 24);
        let (sess, _) = e.prefill_only(&prompt(200)).unwrap();
        let c = &sess.caches[0];
        for h in 0..4 {
            let kept: Vec<i32> = (0..c.head_len(h)).map(|i| c.position(h, i)).collect();
            for s in 0..4 {
                assert!(kept.contains(&(s as i32)), "sink {s} must be kept: {kept:?}");
            }
            assert!(kept.contains(&199));
        }
    }

    #[test]
    fn stream_prefill_bounds_carry_transient() {
        let run = |n: usize, stream: bool| {
            let mut e = engine("lava", 24);
            let req = GenerateRequest { prompt: prompt(n), max_new_tokens: 3 };
            let mut s = e.new_session(&req);
            let w = e.worker();
            let mut ctx = WorkerContext::new(0);
            if stream {
                w.begin_chunked_prefill_stream(&mut s, 64).unwrap();
            } else {
                w.begin_chunked_prefill(&mut s, 64).unwrap();
            }
            let (_, report) = w.advance_chunked_prefill(&mut ctx, &mut s, None).unwrap();
            (e, s, report.expect("unbounded advance completes"))
        };
        // working cap = Hk*max(b, w) + chunk bucket + w = 96 + 128 + 16 = 240
        // columns; one column is 2 (K+V) * Hk(4) * dh(16) * 4 = 512 bytes
        let cap_bytes = 512 * 240;
        let n_layers = 4;
        let (mut e256, mut s256, r256) = run(256, true);
        let (_, s1024, r1024) = run(1024, true);
        for (s, r) in [(&s256, &r256), (&s1024, &r1024)] {
            assert!(
                r.carry_peak_bytes <= cap_bytes,
                "carry {} exceeds the working cap {cap_bytes}",
                r.carry_peak_bytes
            );
            // chunk-major holds all L bounded lanes live at once
            assert!(r.peak_transient <= n_layers * cap_bytes + r.live_after);
            assert_eq!(s.budgets.iter().sum::<usize>(), 24 * 4 * 4);
            assert_eq!(s.generated.len(), 1);
            assert!(s.prefill.is_none(), "state machine must be torn down");
        }
        // the headline claim: the *full* resident set (lanes + panels +
        // hidden rows) stays flat as the prompt quadruples — panel live
        // widths wobble a little between runs, nothing more
        assert!(r256.resident_peak_bytes > 0);
        assert!(
            r1024.resident_peak_bytes <= r256.resident_peak_bytes * 11 / 10,
            "chunk-major resident set must stay flat: {} at n=256 vs {} at n=1024",
            r256.resident_peak_bytes,
            r1024.resident_peak_bytes
        );
        // the plain chunked carry is O(prompt): 512 bytes per prompt column
        let (_, _, p256) = run(256, false);
        let (_, _, p1024) = run(1024, false);
        assert_eq!(p256.carry_peak_bytes, 512 * 256);
        assert_eq!(p1024.carry_peak_bytes, 512 * 1024);
        assert!(
            r1024.carry_peak_bytes < p1024.carry_peak_bytes / 4,
            "stream transient must stay flat while the plain carry grows linearly"
        );
        // ... and so is the plain resident set (hidden rows dominate)
        assert!(
            p1024.resident_peak_bytes > p256.resident_peak_bytes * 3,
            "plain chunked resident set must grow linearly: {} vs {}",
            p256.resident_peak_bytes,
            p1024.resident_peak_bytes
        );
        // the streamed session decodes normally on its compacted caches
        for _ in 0..2 {
            e256.decode_step(&mut s256).unwrap();
        }
        assert_eq!(s256.generated.len(), 3);
    }

    #[test]
    fn stream_prefill_group_advance_matches_serial() {
        let req = GenerateRequest { prompt: prompt(300), max_new_tokens: 4 };
        let mut solo_e = engine("lava", 24);
        let mut solo = solo_e.new_session(&req);
        solo_e.prefill_chunked_stream(&mut solo, 96).unwrap();

        let mut e = engine("lava", 24);
        let a = {
            let mut s = e.new_session(&req);
            e.worker().begin_chunked_prefill_stream(&mut s, 96).unwrap();
            s
        };
        let b = {
            let mut s = e.new_session(&req);
            e.worker().begin_chunked_prefill_stream(&mut s, 96).unwrap();
            s
        };
        let w = e.worker();
        let mut ctx = WorkerContext::new(0);
        let mut group = vec![a, b];
        loop {
            let ka = w.stream_lockstep_key(&group[0]);
            let kb = w.stream_lockstep_key(&group[1]);
            assert_eq!(ka, kb, "identical prompts stay in lockstep");
            let (res, dispatches) = w.advance_stream_group(&mut ctx, &mut group).unwrap();
            // chunk-major groups advance a full pass: one batched dispatch
            // per layer instead of one per (layer, chunk) step
            assert_eq!(dispatches, 4, "one backend dispatch per layer per lockstep group");
            assert_eq!(res.len(), 2);
            let done = res.iter().filter(|(_, r)| r.is_some()).count();
            assert!(done == 0 || done == 2, "identical sessions finish together");
            if done == 2 {
                for (_, r) in &res {
                    let r = r.as_ref().unwrap();
                    assert_eq!(r.token, solo.generated[0]);
                    assert!(r.carry_peak_bytes > 0);
                }
                break;
            }
        }
        for s in &group {
            assert_eq!(s.generated, solo.generated, "grouped token diverged from serial");
            assert_eq!(s.budgets, solo.budgets, "grouped budgets diverged from serial");
            assert_eq!(
                cache_fingerprint(s),
                cache_fingerprint(&solo),
                "grouped keep-sets diverged from serial"
            );
        }
    }

    /// Satellite 3: streamed keep-sets must stay close to the monolithic
    /// selection on retrieval workloads. Documented floor: at chunk sizes
    /// 64/96/128 the streamed run must agree with the monolithic keep-set
    /// on at least 50% of kept positions (mid-prefill eviction cannot see
    /// future queries, so exact agreement is impossible by design).
    #[test]
    fn stream_keep_sets_overlap_monolithic_on_retrieval_workloads() {
        use crate::util::rng::Rng;
        use crate::workloads::{needle_at_depth, needle_qa, ruler};

        fn keep_positions(sess: &Session) -> Vec<Vec<Vec<i32>>> {
            sess.caches
                .iter()
                .map(|c| {
                    (0..c.n_kv_heads())
                        .map(|h| {
                            let mut p: Vec<i32> =
                                (0..c.head_len(h)).map(|i| c.position(h, i)).collect();
                            p.sort_unstable();
                            p
                        })
                        .collect()
                })
                .collect()
        }

        let mut rng = Rng::new(7);
        let instances = vec![
            needle_at_depth(&mut rng, 320, 0.25, 8),
            needle_at_depth(&mut rng, 320, 0.75, 8),
            needle_qa(&mut rng, 320, 8),
            ruler::multi_hop(&mut rng, 320),
        ];
        for chunk in [64usize, 96, 128] {
            let (mut hits, mut total) = (0usize, 0usize);
            for inst in &instances {
                let req =
                    GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 1 };
                let mut me = engine("lava", 24);
                let mut ms = me.new_session(&req);
                me.prefill(&mut ms).unwrap();
                let mut se = engine("lava", 24);
                let mut ss = se.new_session(&req);
                se.prefill_chunked_stream(&mut ss, chunk).unwrap();
                let mk = keep_positions(&ms);
                let sk = keep_positions(&ss);
                for (lm, ls) in mk.iter().zip(&sk) {
                    for (hm, hs) in lm.iter().zip(ls) {
                        total += hm.len();
                        hits += hm.iter().filter(|p| hs.binary_search(p).is_ok()).count();
                    }
                }
            }
            let overlap = hits as f64 / total as f64;
            assert!(
                overlap >= 0.5,
                "chunk {chunk}: streamed keep-set overlap {overlap:.3} below the 0.5 floor"
            );
        }
    }

    #[test]
    fn chunk_major_matches_layer_major_stream() {
        // the two streaming orders run the identical compression call
        // sequence (mid-stream evictions use the constant budget union and
        // the final pass compresses lanes in ascending layer order), so
        // tokens, budgets, and keep-sets must match exactly
        for chunk in [64usize, 96, 128] {
            let req = GenerateRequest { prompt: prompt(300), max_new_tokens: 4 };
            let mut cm = engine("lava", 24);
            cm.opts.stream_layer_major = false;
            cm.opts.carry_q8 = false;
            let mut cs = cm.new_session(&req);
            cm.prefill_chunked_stream(&mut cs, chunk).unwrap();
            let mut lm = engine("lava", 24);
            lm.opts.stream_layer_major = true;
            lm.opts.carry_q8 = false;
            let mut ls = lm.new_session(&req);
            lm.prefill_chunked_stream(&mut ls, chunk).unwrap();
            assert_eq!(cs.generated, ls.generated, "chunk {chunk}: first token");
            assert_eq!(cs.budgets, ls.budgets, "chunk {chunk}: budgets");
            assert_eq!(
                cache_fingerprint(&cs),
                cache_fingerprint(&ls),
                "chunk {chunk}: keep-sets"
            );
            for _ in 0..3 {
                let a = cm.decode_step(&mut cs).unwrap();
                let b = lm.decode_step(&mut ls).unwrap();
                assert_eq!(a, b, "chunk {chunk}: decode token");
            }
        }
    }

    /// Satellite 3: Q8 carries must not disturb the streamed keep-set
    /// selection. On the mock backend the observation panels are functions
    /// of positions only, so this is a plumbing guard (quantize → dequantize
    /// → evict → requantize must not corrupt column bookkeeping) with a
    /// 0.99 overlap floor rather than an accuracy measurement — accuracy is
    /// covered by the Q8 round-trip tolerance property tests in
    /// `kvcache::warm`.
    #[test]
    fn q8_carries_preserve_stream_keep_sets() {
        use crate::util::rng::Rng;
        use crate::workloads::{needle_at_depth, needle_qa, ruler};

        fn keep_positions(sess: &Session) -> Vec<Vec<Vec<i32>>> {
            sess.caches
                .iter()
                .map(|c| {
                    (0..c.n_kv_heads())
                        .map(|h| {
                            let mut p: Vec<i32> =
                                (0..c.head_len(h)).map(|i| c.position(h, i)).collect();
                            p.sort_unstable();
                            p
                        })
                        .collect()
                })
                .collect()
        }

        let mut rng = Rng::new(13);
        let instances = vec![
            needle_at_depth(&mut rng, 320, 0.25, 8),
            needle_at_depth(&mut rng, 320, 0.75, 8),
            needle_qa(&mut rng, 320, 8),
            ruler::multi_hop(&mut rng, 320),
        ];
        for chunk in [64usize, 96, 128] {
            let (mut hits, mut total) = (0usize, 0usize);
            for inst in &instances {
                let req =
                    GenerateRequest { prompt: inst.prompt.clone(), max_new_tokens: 1 };
                let mut fe = engine("lava", 24);
                fe.opts.stream_layer_major = false;
                fe.opts.carry_q8 = false;
                let mut fs = fe.new_session(&req);
                fe.prefill_chunked_stream(&mut fs, chunk).unwrap();
                let mut qe = engine("lava", 24);
                qe.opts.stream_layer_major = false;
                qe.opts.carry_q8 = true;
                let mut qs = qe.new_session(&req);
                qe.prefill_chunked_stream(&mut qs, chunk).unwrap();
                assert_eq!(fs.budgets, qs.budgets, "chunk {chunk}: Q8 changed budgets");
                let fk = keep_positions(&fs);
                let qk = keep_positions(&qs);
                for (lf, lq) in fk.iter().zip(&qk) {
                    for (hf, hq) in lf.iter().zip(lq) {
                        total += hf.len();
                        hits += hf.iter().filter(|p| hq.binary_search(p).is_ok()).count();
                    }
                }
            }
            let overlap = hits as f64 / total as f64;
            assert!(
                overlap >= 0.99,
                "chunk {chunk}: Q8 keep-set overlap {overlap:.4} below the 0.99 floor"
            );
        }
    }

    #[test]
    fn stream_cap_routing() {
        let e = engine("lava", 24);
        let w = e.worker();
        // 4*max(24,16) + 128 + 16
        assert_eq!(w.stream_evict_cap(256, 64), Some(240));
        assert_eq!(w.stream_evict_cap(0, 64), None);
        assert_eq!(w.stream_evict_cap(256, 0), None);
        // full-cache policies must never evict mid-stream
        let full = engine("full", 24);
        assert_eq!(full.worker().stream_evict_cap(256, 64), None);
        // non-stream sessions expose no lockstep key
        let req = GenerateRequest { prompt: prompt(200), max_new_tokens: 1 };
        let mut e2 = engine("lava", 24);
        let mut s = e2.new_session(&req);
        e2.worker().begin_chunked_prefill(&mut s, 64).unwrap();
        assert!(e2.worker().stream_lockstep_key(&s).is_none());
    }
}
