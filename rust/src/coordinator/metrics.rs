//! Serving metrics: the quantities the paper's efficiency evaluation (§5.3,
//! Fig. 3) reports — decode latency and peak KV memory — plus the usual
//! serving counters.

use std::time::Instant;

use crate::util::stats;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_finished: u64,
    pub tokens_generated: u64,
    pub prefill_secs: Vec<f64>,
    /// Per-token decode latencies (seconds).
    pub decode_secs: Vec<f64>,
    /// Peak live KV bytes observed (incl. the transient uncompressed layer
    /// during prefill — the paper's "memory peak").
    pub peak_kv_bytes: usize,
    /// Current live KV bytes.
    pub live_kv_bytes: usize,
    started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { started: Some(Instant::now()), ..Default::default() }
    }

    pub fn observe_kv(&mut self, live: usize) {
        self.live_kv_bytes = live;
        self.peak_kv_bytes = self.peak_kv_bytes.max(live);
    }

    /// Record a transient high-water mark (prefill holds one uncompressed
    /// layer on top of the retained caches).
    pub fn observe_transient(&mut self, bytes: usize) {
        self.peak_kv_bytes = self.peak_kv_bytes.max(bytes);
    }

    pub fn finish_request(&mut self, prefill_secs: f64, decode_secs: f64, tokens: usize) {
        self.requests_finished += 1;
        self.tokens_generated += tokens as u64;
        self.prefill_secs.push(prefill_secs);
        if tokens > 0 {
            self.decode_secs.push(decode_secs / tokens as f64);
        }
    }

    pub fn mean_decode_ms(&self) -> f64 {
        stats::mean(&self.decode_secs) * 1e3
    }

    pub fn p99_decode_ms(&self) -> f64 {
        stats::percentile(&self.decode_secs, 99.0) * 1e3
    }

    pub fn mean_prefill_ms(&self) -> f64 {
        stats::mean(&self.prefill_secs) * 1e3
    }

    pub fn throughput_tok_per_sec(&self) -> f64 {
        match self.started {
            Some(t0) => self.tokens_generated as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} prefill_ms(mean)={:.2} decode_ms(mean)={:.3} \
             decode_ms(p99)={:.3} peak_kv_mb={:.2} throughput_tok_s={:.1}",
            self.requests_finished,
            self.tokens_generated,
            self.mean_prefill_ms(),
            self.mean_decode_ms(),
            self.p99_decode_ms(),
            self.peak_kv_bytes as f64 / 1e6,
            self.throughput_tok_per_sec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracking() {
        let mut m = Metrics::new();
        m.observe_kv(100);
        m.observe_kv(50);
        m.observe_transient(500);
        m.observe_kv(80);
        assert_eq!(m.peak_kv_bytes, 500);
        assert_eq!(m.live_kv_bytes, 80);
    }

    #[test]
    fn request_aggregation() {
        let mut m = Metrics::new();
        m.finish_request(0.1, 0.4, 4);
        m.finish_request(0.3, 0.2, 2);
        assert_eq!(m.requests_finished, 2);
        assert_eq!(m.tokens_generated, 6);
        assert!((m.mean_decode_ms() - 100.0).abs() < 1e-9);
        assert!((m.mean_prefill_ms() - 200.0).abs() < 1e-9);
    }
}
