//! Serving metrics: the quantities the paper's efficiency evaluation (§5.3,
//! Fig. 3) reports — decode latency and peak KV memory — plus the usual
//! serving counters.

use std::collections::BTreeMap;
use std::time::Instant;

use super::pool::RoundStats;
use crate::util::stats;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_finished: u64,
    /// Requests refused by admission control (can never fit / bad prompt).
    pub requests_rejected: u64,
    /// Requests cut short by an explicit cancel.
    pub requests_canceled: u64,
    /// Requests that died to an engine error mid-flight.
    pub requests_failed: u64,
    pub tokens_generated: u64,
    /// Tokens pushed to streaming subscribers as they were produced (one
    /// per `{"id","token","index"}` line the serving loop emitted).
    pub streamed_tokens: u64,
    pub prefill_secs: Vec<f64>,
    /// Per-token decode latencies (seconds).
    pub decode_secs: Vec<f64>,
    /// Queue wait per admitted request (submission -> prefill start).
    pub queue_wait_secs: Vec<f64>,
    /// Time-to-first-token per admitted request (queue wait + prefill).
    pub ttft_secs: Vec<f64>,
    /// Scheduler step counters.
    pub admission_rounds: u64,
    pub decode_steps: u64,
    /// Decode executions: one per `decode_step` call and one per
    /// `decode_step_batch` group (a serial step is a batch of 1), plus the
    /// sessions they covered. occupancy = sessions / batches.
    pub decode_batches: u64,
    pub decode_batch_sessions: u64,
    /// Backend decode dispatches per capacity bucket M: one entry per
    /// `layer_decode{,_batched}` call, keyed by the cache capacity it ran
    /// at. With batching, a round of S same-bucket sessions adds L here
    /// instead of S·L.
    pub decode_dispatches: BTreeMap<usize, u64>,
    /// Admission deferral events (a queued request bounced for memory and
    /// requeued; one event per request per admission round).
    pub requests_deferred: u64,
    /// Bucket-waste gauges: padding rows dispatched across all backend
    /// prefill executions (bucket − valid tokens, summed), plus per-bucket
    /// dispatch/valid/padded breakdowns. Chunked prefill shrinks these by
    /// mapping each chunk to a tight bucket instead of rounding the whole
    /// prompt up.
    pub prefill_padded_tokens: u64,
    pub prefill_fills: BTreeMap<usize, BucketFill>,
    /// Peak live KV bytes observed (incl. the transient uncompressed layer
    /// during prefill — the paper's "memory peak").
    pub peak_kv_bytes: usize,
    /// Current live KV bytes.
    pub live_kv_bytes: usize,
    /// Hot-tier bytes across all active sessions (what `kv_mem_limit`
    /// bounds once tiering is on) and their observed peak. This tracks
    /// *retained* caches; the transient uncompressed layer live during
    /// prefill is budgeted by admission and shows up in `peak_kv_bytes`
    /// (via `observe_transient`), not in this gauge.
    pub hot_kv_bytes: usize,
    pub peak_hot_kv_bytes: usize,
    /// Warm-tier (Q8 spilled) bytes and their observed peak.
    pub warm_kv_bytes: usize,
    pub peak_warm_kv_bytes: usize,
    /// Per-prefill carry transient: the largest carry K/V a single prefill
    /// held at once (last finished prefill + observed peak). On the
    /// monolithic and plain-chunked paths this is the full uncompressed
    /// layer (O(prompt)); with `prefill_stream_evict` it is bounded by the
    /// streaming working cap regardless of prompt length.
    pub prefill_transient_bytes: usize,
    pub peak_prefill_transient_bytes: usize,
    /// Per-prefill *resident* working set: carries (f32 or Q8 at allocated
    /// width), observation panels, and hidden-state rows — the full set the
    /// carry gauge above undercounts (it omits panels and hidden rows).
    /// Flat in prompt length on the chunk-major streaming path, O(prompt)
    /// on the monolithic / plain-chunked / layer-major paths; admission
    /// prices the same quantity.
    pub prefill_resident_bytes: usize,
    pub peak_prefill_resident_bytes: usize,
    /// Cross-session chunk batching: lockstep streaming-prefill rounds
    /// (`batches`), the sessions they covered, and the backend dispatches
    /// they cost. occupancy = sessions / batches; without batching,
    /// dispatches == sessions.
    pub prefill_chunk_batches: u64,
    pub prefill_chunk_batch_sessions: u64,
    pub prefill_chunk_batch_dispatches: u64,
    /// Tier transition counters: spills/prefetches, bytes moved (hot-side
    /// accounting), and cumulative transition latency. With the tier
    /// thread, these latencies are the *serving-thread* cost per
    /// transition: for a spill, taking the buffers + enqueueing; for a
    /// prefetch, the blocking fetch wait (near zero on a staging hit). The
    /// background quantize/dequantize time shows in `tier_busy_secs`.
    pub spills: u64,
    pub prefetches: u64,
    pub spilled_bytes: u64,
    pub prefetched_bytes: u64,
    pub spill_secs: f64,
    pub prefetch_secs: f64,
    /// Worker-pool gauges: configured width, cumulative busy seconds per
    /// worker slot, fan-out rounds, and cumulative fan-out wall seconds.
    /// utilization = Σ busy / (width · wall).
    pub workers: usize,
    pub worker_busy_secs: Vec<f64>,
    pub worker_rounds: u64,
    pub worker_wall_secs: f64,
    /// Persistent-pool gauges: deepest injector queue seen at round start
    /// (units submitted in one fan-out), units pulled per worker slot
    /// (work-stealing balance — skew here with even `worker_busy_secs`
    /// means the dynamic cursor is compensating for uneven unit costs),
    /// pool-lifetime park/unpark totals (sampled cumulative; high churn
    /// relative to `worker_rounds` means workers thrash between ticks),
    /// and cumulative dispatch overhead — the wall time per round not
    /// covered by the busiest worker (submit + wake + join cost, the
    /// quantity the persistent pool exists to shrink vs spawn-per-tick).
    pub pool_queue_depth_peak: usize,
    pub worker_units: Vec<u64>,
    pub pool_parks: u64,
    pub pool_unparks: u64,
    pub pool_dispatch_secs: f64,
    /// Tier-thread gauges, sampled at tick end: command-queue backlogs
    /// (spill commands not yet quantized, prefetch-ahead hints not yet
    /// staged), their observed combined peak, host-side f32 bytes parked in
    /// the prefetch-ahead staging area (current + peak — real RAM on top of
    /// hot and warm, never counted against `kv_mem_limit`), and the
    /// thread's cumulative busy seconds.
    pub tier_spill_queue_depth: usize,
    pub tier_prefetch_queue_depth: usize,
    pub tier_queue_depth_peak: usize,
    pub tier_staged_bytes: usize,
    pub peak_tier_staged_bytes: usize,
    pub tier_busy_secs: f64,
    started: Option<Instant>,
}

/// Per-prefill-bucket fill accounting: how many dispatches ran at this
/// bucket, how many of their rows were real prompt tokens, and how many
/// were padding. utilization = valid / (valid + padded).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct BucketFill {
    pub dispatches: u64,
    pub valid_tokens: u64,
    pub padded_tokens: u64,
}

/// Point-in-time copy of the serving metrics plus in-flight gauges, cheap
/// to clone across the serving loop's command channel — a `metrics` request
/// never borrows the scheduler for longer than the copy takes and never
/// stops a decode round.
#[derive(Debug, Default, Clone)]
pub struct MetricsSnapshot {
    pub metrics: Metrics,
    /// Sessions currently decoding (admitted, not yet retired).
    pub active_sessions: usize,
    /// Requests waiting in the admission queue.
    pub queued_requests: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { started: Some(Instant::now()), ..Default::default() }
    }

    pub fn observe_kv(&mut self, live: usize) {
        self.live_kv_bytes = live;
        self.peak_kv_bytes = self.peak_kv_bytes.max(live);
    }

    /// Record a transient high-water mark (prefill holds one uncompressed
    /// layer on top of the retained caches).
    pub fn observe_transient(&mut self, bytes: usize) {
        self.peak_kv_bytes = self.peak_kv_bytes.max(bytes);
    }

    /// Record one admission: how long the request queued and its TTFT.
    pub fn observe_admission(&mut self, queue_wait_secs: f64, ttft_secs: f64) {
        self.queue_wait_secs.push(queue_wait_secs);
        self.ttft_secs.push(ttft_secs);
    }

    /// Record current hot-tier bytes (sum of resident caches across active
    /// sessions — the quantity `kv_mem_limit` bounds under tiering).
    pub fn observe_hot(&mut self, hot: usize) {
        self.hot_kv_bytes = hot;
        self.peak_hot_kv_bytes = self.peak_hot_kv_bytes.max(hot);
    }

    /// Record current warm-tier bytes.
    pub fn observe_warm(&mut self, warm: usize) {
        self.warm_kv_bytes = warm;
        self.peak_warm_kv_bytes = self.peak_warm_kv_bytes.max(warm);
    }

    /// Record one finished prefill's peak carry K/V bytes (bounded under
    /// streaming eviction, O(prompt) otherwise).
    pub fn observe_prefill_transient(&mut self, bytes: usize) {
        self.prefill_transient_bytes = bytes;
        self.peak_prefill_transient_bytes = self.peak_prefill_transient_bytes.max(bytes);
    }

    /// Record one finished prefill's peak resident working set (carries +
    /// observation panels + hidden rows — everything over the retained
    /// caches). Flat under chunk-major streaming, O(prompt) otherwise.
    pub fn observe_prefill_resident(&mut self, bytes: usize) {
        self.prefill_resident_bytes = bytes;
        self.peak_prefill_resident_bytes = self.peak_prefill_resident_bytes.max(bytes);
    }

    /// Record one lockstep streaming-prefill group advance covering
    /// `sessions` sessions at `dispatches` backend calls (1 when the
    /// backend batched the whole group).
    pub fn observe_prefill_chunk_batch(&mut self, sessions: usize, dispatches: usize) {
        self.prefill_chunk_batches += 1;
        self.prefill_chunk_batch_sessions += sessions as u64;
        self.prefill_chunk_batch_dispatches += dispatches as u64;
    }

    /// Mean sessions advanced per lockstep streaming-prefill round (0 when
    /// none ran; > 1 means cross-session chunk batching is amortizing
    /// dispatches).
    pub fn prefill_chunk_batch_occupancy(&self) -> f64 {
        if self.prefill_chunk_batches > 0 {
            self.prefill_chunk_batch_sessions as f64 / self.prefill_chunk_batches as f64
        } else {
            0.0
        }
    }

    /// Record one hot→warm spill: hot bytes freed and transition latency.
    pub fn observe_spill(&mut self, bytes: usize, secs: f64) {
        self.spills += 1;
        self.spilled_bytes += bytes as u64;
        self.spill_secs += secs;
    }

    /// Record one warm→hot prefetch: hot bytes restored and latency.
    pub fn observe_prefetch(&mut self, bytes: usize, secs: f64) {
        self.prefetches += 1;
        self.prefetched_bytes += bytes as u64;
        self.prefetch_secs += secs;
    }

    /// Record one admission deferral event.
    pub fn observe_deferral(&mut self) {
        self.requests_deferred += 1;
    }

    /// Record one backend prefill dispatch at `bucket` with `valid` real
    /// prompt rows (the rest of the bucket was padding).
    pub fn observe_prefill_fill(&mut self, bucket: usize, valid: usize) {
        let padded = bucket.saturating_sub(valid) as u64;
        let e = self.prefill_fills.entry(bucket).or_default();
        e.dispatches += 1;
        e.valid_tokens += valid as u64;
        e.padded_tokens += padded;
        self.prefill_padded_tokens += padded;
    }

    /// Fraction of dispatched prefill rows that were real prompt tokens
    /// (1.0 = no bucket waste; 0 when no prefill ran yet).
    pub fn prefill_bucket_utilization(&self) -> f64 {
        let valid: u64 = self.prefill_fills.values().map(|f| f.valid_tokens).sum();
        let total = valid + self.prefill_padded_tokens;
        if total == 0 {
            return 0.0;
        }
        valid as f64 / total as f64
    }

    /// Record one worker-pool fan-out from the pool's per-round stats: the
    /// pool width, each worker slot's busy seconds and pulled-unit count
    /// (may be fewer entries than `workers` on the scoped path when there
    /// were fewer units), the round's queue depth and wall/dispatch
    /// seconds, and the pool-lifetime park/unpark totals (cumulative —
    /// stored, not summed).
    pub fn observe_worker_round(&mut self, workers: usize, stats: &RoundStats) {
        self.workers = self.workers.max(workers);
        if self.worker_busy_secs.len() < stats.busy_secs.len() {
            self.worker_busy_secs.resize(stats.busy_secs.len(), 0.0);
        }
        for (slot, &b) in stats.busy_secs.iter().enumerate() {
            self.worker_busy_secs[slot] += b;
        }
        if self.worker_units.len() < stats.pulled.len() {
            self.worker_units.resize(stats.pulled.len(), 0);
        }
        for (slot, &n) in stats.pulled.iter().enumerate() {
            self.worker_units[slot] += n;
        }
        self.worker_rounds += 1;
        self.worker_wall_secs += stats.wall_secs;
        self.pool_queue_depth_peak = self.pool_queue_depth_peak.max(stats.queued_units);
        self.pool_parks = self.pool_parks.max(stats.parks);
        self.pool_unparks = self.pool_unparks.max(stats.unparks);
        self.pool_dispatch_secs += stats.dispatch_secs;
    }

    /// Mean fraction of the pool kept busy during fan-outs (1.0 = every
    /// worker busy for the whole round; low values mean units were too few
    /// or too skewed to fill the pool).
    pub fn worker_utilization(&self) -> f64 {
        if self.workers == 0 || self.worker_wall_secs <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.worker_busy_secs.iter().sum();
        busy / (self.workers as f64 * self.worker_wall_secs)
    }

    /// Mean dispatch overhead per fan-out round in milliseconds: the wall
    /// time not covered by the busiest worker (submit + wake + join). 0
    /// when no fan-outs ran.
    pub fn mean_dispatch_overhead_ms(&self) -> f64 {
        if self.worker_rounds > 0 {
            self.pool_dispatch_secs / self.worker_rounds as f64 * 1e3
        } else {
            0.0
        }
    }

    /// Record a sample of the tier thread's queue/busy/staging gauges.
    pub fn observe_tier_thread(
        &mut self,
        spill_q: usize,
        prefetch_q: usize,
        staged_bytes: usize,
        busy_secs: f64,
    ) {
        self.tier_spill_queue_depth = spill_q;
        self.tier_prefetch_queue_depth = prefetch_q;
        self.tier_queue_depth_peak = self.tier_queue_depth_peak.max(spill_q + prefetch_q);
        self.tier_staged_bytes = staged_bytes;
        self.peak_tier_staged_bytes = self.peak_tier_staged_bytes.max(staged_bytes);
        self.tier_busy_secs = busy_secs;
    }

    /// Record one decode execution covering `sessions` sessions (1 = the
    /// serial path; >= 2 = one batched `decode_step_batch` group).
    pub fn observe_decode_batch(&mut self, sessions: usize) {
        self.decode_batches += 1;
        self.decode_batch_sessions += sessions as u64;
    }

    /// Record `n` backend decode dispatches at capacity bucket `m` (n > 1
    /// when a backend chunked one batched call onto several lowered
    /// executables — the gauge counts real launches, not API calls).
    pub fn observe_decode_dispatches(&mut self, m: usize, n: u64) {
        *self.decode_dispatches.entry(m).or_insert(0) += n;
    }

    /// Mean sessions advanced per decode execution (1.0 = fully serial;
    /// higher means the scheduler is amortizing dispatches across a batch).
    pub fn batch_occupancy(&self) -> f64 {
        if self.decode_batches > 0 {
            self.decode_batch_sessions as f64 / self.decode_batches as f64
        } else {
            0.0
        }
    }

    /// Total backend decode dispatches across all capacity buckets.
    pub fn decode_dispatches_total(&self) -> u64 {
        self.decode_dispatches.values().sum()
    }

    pub fn finish_request(&mut self, prefill_secs: f64, decode_secs: f64, tokens: usize) {
        self.requests_finished += 1;
        self.tokens_generated += tokens as u64;
        self.prefill_secs.push(prefill_secs);
        if tokens > 0 {
            self.decode_secs.push(decode_secs / tokens as f64);
        }
    }

    pub fn mean_decode_ms(&self) -> f64 {
        stats::mean(&self.decode_secs) * 1e3
    }

    pub fn p99_decode_ms(&self) -> f64 {
        stats::percentile(&self.decode_secs, 99.0) * 1e3
    }

    pub fn mean_prefill_ms(&self) -> f64 {
        stats::mean(&self.prefill_secs) * 1e3
    }

    pub fn throughput_tok_per_sec(&self) -> f64 {
        match self.started {
            Some(t0) => self.tokens_generated as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    pub fn mean_ttft_ms(&self) -> f64 {
        stats::mean(&self.ttft_secs) * 1e3
    }

    pub fn p99_ttft_ms(&self) -> f64 {
        stats::percentile(&self.ttft_secs, 99.0) * 1e3
    }

    pub fn mean_queue_wait_ms(&self) -> f64 {
        stats::mean(&self.queue_wait_secs) * 1e3
    }

    /// Steady-state decode speed: tokens per second of decode wall time
    /// (1 / mean per-token decode latency).
    pub fn decode_tok_per_sec(&self) -> f64 {
        let mean = stats::mean(&self.decode_secs);
        if mean > 0.0 {
            1.0 / mean
        } else {
            0.0
        }
    }

    /// Mean hot→warm spill latency in milliseconds (0 when no spills).
    pub fn mean_spill_ms(&self) -> f64 {
        if self.spills > 0 {
            self.spill_secs / self.spills as f64 * 1e3
        } else {
            0.0
        }
    }

    /// Mean warm→hot prefetch latency in milliseconds (0 when none).
    pub fn mean_prefetch_ms(&self) -> f64 {
        if self.prefetches > 0 {
            self.prefetch_secs / self.prefetches as f64 * 1e3
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let worker_busy: Vec<String> =
            self.worker_busy_secs.iter().map(|b| format!("{:.3}", b * 1e3)).collect();
        let worker_units: Vec<String> =
            self.worker_units.iter().map(|n| n.to_string()).collect();
        format!(
            "requests={} rejected={} canceled={} failed={} deferred={} tokens={} \
             streamed={} ttft_ms(mean)={:.2} queue_wait_ms(mean)={:.2} prefill_ms(mean)={:.2} \
             decode_ms(mean)={:.3} decode_ms(p99)={:.3} decode_tok_s={:.1} peak_kv_mb={:.2} \
             hot_kv_mb(peak)={:.2} warm_kv_mb(peak)={:.2} spills={} prefetches={} \
             spilled_mb={:.2} prefetched_mb={:.2} \
             spill_ms(mean)={:.3} prefetch_ms(mean)={:.3} \
             throughput_tok_s={:.1} admission_rounds={} decode_steps={} \
             decode_batches={} batch_occupancy={:.2} decode_dispatches={} \
             prefill_padded_tokens={} prefill_bucket_util={:.2} \
             prefill_transient_mb(peak)={:.2} prefill_resident_mb(peak)={:.2} \
             prefill_chunk_batches={} \
             prefill_chunk_occupancy={:.2} prefill_chunk_dispatches={} \
             workers={} worker_util={:.2} worker_busy_ms=[{}] \
             worker_units=[{}] pool_q_peak={} pool_parks={} pool_unparks={} \
             pool_dispatch_ms(mean)={:.3} \
             tier_spill_q={} tier_prefetch_q={} tier_q_peak={} \
             tier_staged_mb(peak)={:.2} tier_busy_ms={:.3}",
            self.requests_finished,
            self.requests_rejected,
            self.requests_canceled,
            self.requests_failed,
            self.requests_deferred,
            self.tokens_generated,
            self.streamed_tokens,
            self.mean_ttft_ms(),
            self.mean_queue_wait_ms(),
            self.mean_prefill_ms(),
            self.mean_decode_ms(),
            self.p99_decode_ms(),
            self.decode_tok_per_sec(),
            self.peak_kv_bytes as f64 / 1e6,
            self.peak_hot_kv_bytes as f64 / 1e6,
            self.peak_warm_kv_bytes as f64 / 1e6,
            self.spills,
            self.prefetches,
            self.spilled_bytes as f64 / 1e6,
            self.prefetched_bytes as f64 / 1e6,
            self.mean_spill_ms(),
            self.mean_prefetch_ms(),
            self.throughput_tok_per_sec(),
            self.admission_rounds,
            self.decode_steps,
            self.decode_batches,
            self.batch_occupancy(),
            self.decode_dispatches_total(),
            self.prefill_padded_tokens,
            self.prefill_bucket_utilization(),
            self.peak_prefill_transient_bytes as f64 / 1e6,
            self.peak_prefill_resident_bytes as f64 / 1e6,
            self.prefill_chunk_batches,
            self.prefill_chunk_batch_occupancy(),
            self.prefill_chunk_batch_dispatches,
            self.workers,
            self.worker_utilization(),
            worker_busy.join(","),
            worker_units.join(","),
            self.pool_queue_depth_peak,
            self.pool_parks,
            self.pool_unparks,
            self.mean_dispatch_overhead_ms(),
            self.tier_spill_queue_depth,
            self.tier_prefetch_queue_depth,
            self.tier_queue_depth_peak,
            self.peak_tier_staged_bytes as f64 / 1e6,
            self.tier_busy_secs * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracking() {
        let mut m = Metrics::new();
        m.observe_kv(100);
        m.observe_kv(50);
        m.observe_transient(500);
        m.observe_kv(80);
        assert_eq!(m.peak_kv_bytes, 500);
        assert_eq!(m.live_kv_bytes, 80);
    }

    #[test]
    fn request_aggregation() {
        let mut m = Metrics::new();
        m.finish_request(0.1, 0.4, 4);
        m.finish_request(0.3, 0.2, 2);
        assert_eq!(m.requests_finished, 2);
        assert_eq!(m.tokens_generated, 6);
        assert!((m.mean_decode_ms() - 100.0).abs() < 1e-9);
        assert!((m.mean_prefill_ms() - 200.0).abs() < 1e-9);
        // mean per-token decode latency is 100 ms -> 10 tok/s
        assert!((m.decode_tok_per_sec() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn tier_accounting() {
        let mut m = Metrics::new();
        m.observe_hot(100);
        m.observe_hot(40);
        m.observe_warm(30);
        m.observe_warm(10);
        assert_eq!(m.hot_kv_bytes, 40);
        assert_eq!(m.peak_hot_kv_bytes, 100);
        assert_eq!(m.warm_kv_bytes, 10);
        assert_eq!(m.peak_warm_kv_bytes, 30);
        m.observe_spill(64, 0.002);
        m.observe_spill(32, 0.004);
        m.observe_prefetch(64, 0.001);
        m.observe_deferral();
        assert_eq!(m.spills, 2);
        assert_eq!(m.spilled_bytes, 96);
        assert_eq!(m.prefetches, 1);
        assert_eq!(m.prefetched_bytes, 64);
        assert_eq!(m.requests_deferred, 1);
        assert!((m.mean_spill_ms() - 3.0).abs() < 1e-9);
        assert!((m.mean_prefetch_ms() - 1.0).abs() < 1e-9);
        assert!(m.report().contains("spills=2"));
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        assert_eq!(m.batch_occupancy(), 0.0);
        m.observe_decode_batch(4);
        m.observe_decode_batch(1);
        m.observe_decode_batch(1);
        assert_eq!(m.decode_batches, 3);
        assert_eq!(m.decode_batch_sessions, 6);
        assert!((m.batch_occupancy() - 2.0).abs() < 1e-9);
        m.observe_decode_dispatches(128, 1);
        m.observe_decode_dispatches(128, 1);
        m.observe_decode_dispatches(256, 1);
        assert_eq!(m.decode_dispatches.get(&128), Some(&2));
        assert_eq!(m.decode_dispatches.get(&256), Some(&1));
        assert_eq!(m.decode_dispatches_total(), 3);
        assert!(m.report().contains("batch_occupancy=2.00"));
    }

    #[test]
    fn worker_and_tier_thread_gauges() {
        let mut m = Metrics::new();
        assert_eq!(m.worker_utilization(), 0.0, "no rounds yet");
        assert_eq!(m.mean_dispatch_overhead_ms(), 0.0, "no rounds yet");
        // two rounds on a width-2 pool: one balanced with skewed pulls,
        // one where a single slot did all the work
        m.observe_worker_round(
            2,
            &RoundStats {
                busy_secs: vec![0.5, 0.5],
                wall_secs: 1.0,
                pulled: vec![3, 1],
                queued_units: 4,
                parks: 2,
                unparks: 2,
                dispatch_secs: 0.5,
            },
        );
        m.observe_worker_round(
            2,
            &RoundStats {
                busy_secs: vec![1.0],
                wall_secs: 1.0,
                pulled: vec![1],
                queued_units: 1,
                parks: 4,
                unparks: 4,
                dispatch_secs: 0.0,
            },
        );
        assert_eq!(m.workers, 2);
        assert_eq!(m.worker_rounds, 2);
        assert_eq!(m.worker_busy_secs, vec![1.5, 0.5]);
        // Σbusy = 2.0 over width 2 × wall 2.0 = 0.5
        assert!((m.worker_utilization() - 0.5).abs() < 1e-9);
        assert_eq!(m.worker_units, vec![4, 1], "pulled counts accumulate per slot");
        assert_eq!(m.pool_queue_depth_peak, 4, "peak holds the deepest submit");
        assert_eq!(m.pool_parks, 4, "park totals are cumulative samples");
        assert_eq!(m.pool_unparks, 4);
        // 0.5 s of overhead over 2 rounds = 250 ms mean
        assert!((m.mean_dispatch_overhead_ms() - 250.0).abs() < 1e-9);

        m.observe_tier_thread(3, 2, 4096, 0.25);
        m.observe_tier_thread(1, 0, 1024, 0.5);
        assert_eq!(m.tier_spill_queue_depth, 1);
        assert_eq!(m.tier_prefetch_queue_depth, 0);
        assert_eq!(m.tier_queue_depth_peak, 5, "peak holds the worst sample");
        assert_eq!(m.tier_staged_bytes, 1024);
        assert_eq!(m.peak_tier_staged_bytes, 4096, "staging peak holds the worst sample");
        assert!((m.tier_busy_secs - 0.5).abs() < 1e-12);
        let report = m.report();
        assert!(report.contains("workers=2"));
        assert!(report.contains("worker_util=0.50"));
        assert!(report.contains("worker_units=[4,1]"));
        assert!(report.contains("pool_q_peak=4"));
        assert!(report.contains("pool_parks=4"));
        assert!(report.contains("pool_dispatch_ms(mean)=250.000"));
        assert!(report.contains("tier_q_peak=5"));
    }

    #[test]
    fn prefill_fill_accounting() {
        let mut m = Metrics::new();
        assert_eq!(m.prefill_bucket_utilization(), 0.0, "no prefill yet");
        // a monolithic 100-token prefill at bucket 128, 2 layers
        m.observe_prefill_fill(128, 100);
        m.observe_prefill_fill(128, 100);
        // a chunked dispatch at a tight 32 bucket, full
        m.observe_prefill_fill(32, 32);
        assert_eq!(m.prefill_padded_tokens, 56);
        let f = m.prefill_fills.get(&128).unwrap();
        assert_eq!(f.dispatches, 2);
        assert_eq!(f.valid_tokens, 200);
        assert_eq!(f.padded_tokens, 56);
        assert_eq!(m.prefill_fills.get(&32).unwrap().padded_tokens, 0);
        let util = m.prefill_bucket_utilization();
        assert!((util - 232.0 / 288.0).abs() < 1e-9, "{util}");
        assert!(m.report().contains("prefill_padded_tokens=56"));
    }

    #[test]
    fn prefill_stream_accounting() {
        let mut m = Metrics::new();
        assert_eq!(m.prefill_chunk_batch_occupancy(), 0.0, "no rounds yet");
        m.observe_prefill_transient(4096);
        m.observe_prefill_transient(1024);
        assert_eq!(m.prefill_transient_bytes, 1024, "gauge tracks the last prefill");
        assert_eq!(m.peak_prefill_transient_bytes, 4096, "peak holds the worst");
        m.observe_prefill_resident(8192);
        m.observe_prefill_resident(2048);
        assert_eq!(m.prefill_resident_bytes, 2048, "resident gauge tracks the last prefill");
        assert_eq!(m.peak_prefill_resident_bytes, 8192, "resident peak holds the worst");
        assert!(m.report().contains("prefill_resident_mb(peak)=0.01"));
        // two lockstep rounds: a batched pair (1 dispatch) and a singleton
        m.observe_prefill_chunk_batch(2, 1);
        m.observe_prefill_chunk_batch(1, 1);
        assert_eq!(m.prefill_chunk_batches, 2);
        assert_eq!(m.prefill_chunk_batch_sessions, 3);
        assert_eq!(m.prefill_chunk_batch_dispatches, 2);
        assert!((m.prefill_chunk_batch_occupancy() - 1.5).abs() < 1e-9);
        let report = m.report();
        assert!(report.contains("prefill_chunk_batches=2"));
        assert!(report.contains("prefill_chunk_occupancy=1.50"));
        assert!(report.contains("prefill_chunk_dispatches=2"));
    }

    #[test]
    fn admission_aggregation() {
        let mut m = Metrics::new();
        m.observe_admission(0.010, 0.050);
        m.observe_admission(0.030, 0.070);
        assert!((m.mean_queue_wait_ms() - 20.0).abs() < 1e-9);
        assert!((m.mean_ttft_ms() - 60.0).abs() < 1e-9);
        assert!(m.p99_ttft_ms() >= m.mean_ttft_ms());
    }
}
