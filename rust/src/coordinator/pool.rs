//! Engine worker pool: ordered fan-out of per-round work units.
//!
//! The scheduler plans a decode round (or a prefill batch) into independent
//! units — capacity-bucket session groups, single sessions, queued
//! prefills — and hands the whole plan to [`WorkerPool::run`], which fans
//! the units out over up to N scoped worker threads via
//! [`crate::util::par::scoped_map_timed`] and returns the results **in
//! plan order**. Because planning is done entirely on the serving thread
//! before the fan-out, results (tokens, evictions, spill decisions) are
//! bit-identical at every worker count; only wall time changes. The pool
//! also reports per-worker busy time per round, which the scheduler folds
//! into the utilization gauges.
//!
//! Workers are scoped threads, not a persistent pool: spawn cost (~tens of
//! microseconds) is far below a decode round's dispatch work, and scoped
//! lifetimes let units borrow the shared backend with no `Arc`/channel
//! machinery. `workers == 1` (or a single unit) short-circuits to a serial
//! loop on the caller's thread — the escape hatch CI uses to flush out
//! nondeterminism.

use crate::util::par;

/// Per-round fan-out statistics.
#[derive(Debug, Clone)]
pub struct RoundStats {
    /// Busy seconds per worker actually spawned (one entry on the serial
    /// fallback).
    pub busy_secs: Vec<f64>,
    /// Wall seconds the fan-out took end to end.
    pub wall_secs: f64,
}

/// Fixed-width pool of engine workers (width chosen at scheduler build).
#[derive(Debug, Clone)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool { workers: workers.max(1) }
    }

    /// Configured width.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f` over every unit, fanning out across the pool; results come
    /// back in unit order. `f` must be independent per unit (each unit is
    /// owned by exactly one worker).
    pub fn run<T, R, F>(&self, units: Vec<T>, f: F) -> (Vec<R>, RoundStats)
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let t0 = std::time::Instant::now();
        let (results, busy_secs) = par::scoped_map_timed(units, f, self.workers);
        (results, RoundStats { busy_secs, wall_secs: t0.elapsed().as_secs_f64() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_stay_in_plan_order() {
        for width in [1usize, 2, 4, 9] {
            let pool = WorkerPool::new(width);
            assert_eq!(pool.workers(), width);
            let units: Vec<usize> = (0..23).collect();
            let (out, stats) = pool.run(units, |u| u * u);
            assert_eq!(out, (0..23).map(|u| u * u).collect::<Vec<_>>(), "width {width}");
            assert!(!stats.busy_secs.is_empty());
            assert!(stats.busy_secs.len() <= width);
            assert!(stats.wall_secs >= 0.0);
        }
    }

    #[test]
    fn zero_width_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let (out, stats) = pool.run(vec![1, 2, 3], |u| u + 1);
        assert_eq!(out, vec![2, 3, 4]);
        assert_eq!(stats.busy_secs.len(), 1, "serial fallback");
    }
}
