//! Persistent engine worker pool: spawn-free round dispatch with dynamic
//! unit scheduling.
//!
//! The scheduler plans a tick's work — capacity-bucket decode groups,
//! queued prefills, lockstep stream groups — into independent units and
//! hands each plan to [`WorkerPool::run`]. The pool's N worker threads are
//! spawned **once** at scheduler build and live until drop; a round is
//! submitted by publishing the plan behind a shared *injector* (an atomic
//! cursor over the unit list) and waking the parked workers. Each worker
//! pulls the next unscheduled unit whenever it finishes one, so a heavy
//! unit no longer strands its statically-assigned neighbors on an idle
//! worker: load balancing is dynamic, replacing the contiguous-chunk
//! sharding of the scoped dispatcher. Results are written into pre-sized
//! per-unit slots by index, so [`WorkerPool::run`] still returns them **in
//! plan order** — planning happens entirely on the serving thread before
//! the fan-out, so tokens, evictions, and spill decisions stay
//! bit-identical at every width and in both dispatch modes; only wall time
//! changes.
//!
//! Submit → injector → worker-context → slot-writeback flow:
//!
//! ```text
//!  run(units, f)
//!    │ publish Round{units, result slots} + bump epoch ── unpark workers
//!    ▼
//!  injector: AtomicUsize cursor over 0..n_units
//!    │ worker w: idx = cursor.fetch_add(1)  (pull when free)
//!    ▼
//!  WorkerContext w: stable id, pinned device slot, scratch arenas
//!    │ catch_unwind(f(&mut ctx, unit[idx]))
//!    ▼
//!  results[idx] = Ok(R) | Err(panic message)   (slot writeback, plan order)
//! ```
//!
//! Each worker owns a [`WorkerContext`]: a stable worker id, a backend
//! device slot bound once per thread (`ModelBackend::bind_device`, so a
//! PJRT backend can pin one accelerator per worker), and reusable scratch
//! arenas ([`WorkerScratch`]) — per-round score buffers and Q8
//! dequantization tensors that used to be allocated per session now live
//! for the worker's lifetime.
//!
//! A panicking unit is caught ([`std::panic::catch_unwind`]) and surfaced
//! as that unit's `Err(message)`; the other units of the round and the
//! worker threads themselves are unaffected, so one poisoned session can
//! no longer abort the serve loop.
//!
//! `LAVA_POOL=scoped` ([`PoolMode::Scoped`]) keeps the legacy scoped
//! dispatcher — a fresh `std::thread::scope` fan-out per round through
//! [`crate::util::par::scoped_map_timed`]'s static contiguous chunking —
//! as the bit-equivalence oracle the fingerprint tests compare against.
//! `workers == 1` (or a single-unit round) short-circuits to a serial loop
//! on the caller's thread using the pool's serving-thread context — the
//! escape hatch CI uses to flush out nondeterminism.
//!
//! Shutdown: dropping the pool flags the gate and joins every worker. A
//! round is only ever in flight while `run` is on the stack (submission is
//! synchronous), so there are no queued units to drain at drop time — the
//! drop-joins test asserts no thread (or shared state) leaks.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::compress::score::ScoreScratch;
use crate::runtime::Tensor;
use crate::util::par;

/// How one unit of a round ended: the closure's value, or the message of
/// the panic that killed it (contained to this unit).
pub type UnitResult<R> = std::result::Result<R, String>;

/// Reusable per-worker scratch arenas. Living on the worker (not the
/// session) turns the decode/stream hot-path scratch allocations into
/// amortized, per-worker buffers: any session a worker picks up reuses
/// them. Contents are *stale* between units by design — every consumer
/// confines its reads to the columns it just wrote (the Q8 carry masks
/// dead columns with position -1), exactly as the old per-session scratch
/// already tolerated stale tails after eviction compaction.
#[derive(Debug, Default)]
pub struct WorkerScratch {
    /// Score-pipeline row buffers (`kv_head_scores_with` serial scoring).
    pub score: ScoreScratch,
    /// Q8 carry dequantization tensors, one (K, V) pair per lockstep group
    /// member — a chunk-major group dequantizes every member's carry
    /// before one batched dispatch borrows them all simultaneously.
    dequant: Vec<(Tensor, Tensor)>,
}

impl WorkerScratch {
    /// Hand out the first `n` dequant pairs, each guaranteed to have
    /// exactly `shape` (backends read `Tensor::shape`, so a larger-than-
    /// needed buffer is not an option). Same-shape slots keep their
    /// allocation (and stale contents); a shape change reallocates that
    /// slot zeroed.
    pub fn dequant_slots(&mut self, n: usize, shape: &[usize]) -> &mut [(Tensor, Tensor)] {
        while self.dequant.len() < n {
            self.dequant.push((Tensor::zeros(shape), Tensor::zeros(shape)));
        }
        for pair in self.dequant[..n].iter_mut() {
            if pair.0.shape != shape {
                pair.0 = Tensor::zeros(shape);
            }
            if pair.1.shape != shape {
                pair.1 = Tensor::zeros(shape);
            }
        }
        &mut self.dequant[..n]
    }

    /// Split borrow for the stream hot path: the score buffers and `n`
    /// dequant pairs (shaped as in [`WorkerScratch::dequant_slots`]) at
    /// once — eviction scoring and Q8 carry staging happen inside the same
    /// per-lane loop.
    pub fn score_and_dequant(
        &mut self,
        n: usize,
        shape: &[usize],
    ) -> (&mut ScoreScratch, &mut [(Tensor, Tensor)]) {
        self.dequant_slots(n, shape);
        (&mut self.score, &mut self.dequant[..n])
    }
}

/// Per-worker state that survives across rounds: identity, device
/// binding, and scratch. One lives on each persistent worker thread, one
/// on the pool for the serving thread's serial arms, and the scoped
/// oracle fabricates a throwaway one per unit.
#[derive(Debug)]
pub struct WorkerContext {
    /// Stable worker slot (0-based; the serving-thread context is 0).
    pub worker_id: usize,
    /// Backend device slot this worker pins (`worker_id`; backends map it
    /// onto their device count, e.g. `slot % device_count()`).
    pub device_slot: usize,
    /// Whether `ModelBackend::bind_device` ran on this context's thread
    /// yet (the engine binds lazily before the first dispatch).
    pub device_bound: bool,
    /// Reusable hot-path buffers.
    pub scratch: WorkerScratch,
}

impl WorkerContext {
    pub fn new(worker_id: usize) -> WorkerContext {
        WorkerContext {
            worker_id,
            device_slot: worker_id,
            device_bound: false,
            scratch: WorkerScratch::default(),
        }
    }
}

/// Which dispatcher [`WorkerPool::run`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolMode {
    /// Long-lived workers + injector cursor (the default).
    Persistent,
    /// Legacy per-round `std::thread::scope` fan-out with static
    /// contiguous chunking — the bit-equivalence oracle (`LAVA_POOL=scoped`).
    Scoped,
}

impl PoolMode {
    /// `LAVA_POOL` override (CI runs the suite once more with `scoped`).
    /// Unset or `persistent` selects the persistent pool; an unrecognized
    /// value warns and keeps the default rather than silently changing
    /// the dispatcher.
    pub fn from_env() -> PoolMode {
        match std::env::var("LAVA_POOL") {
            Ok(v) if v.trim().eq_ignore_ascii_case("scoped") => PoolMode::Scoped,
            Ok(v) if v.trim().is_empty() || v.trim().eq_ignore_ascii_case("persistent") => {
                PoolMode::Persistent
            }
            Ok(v) => {
                eprintln!("[lava] ignoring invalid LAVA_POOL={v:?}; using the persistent pool");
                PoolMode::Persistent
            }
            Err(_) => PoolMode::Persistent,
        }
    }
}

/// Per-round fan-out statistics.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// Busy seconds per worker slot (one entry per pool slot in
    /// persistent mode, per spawned worker in scoped mode, one entry on
    /// the serial fallback).
    pub busy_secs: Vec<f64>,
    /// Wall seconds the fan-out took end to end.
    pub wall_secs: f64,
    /// Units each worker slot pulled from the injector this round
    /// (empty in scoped mode — static chunks are not pulls).
    pub pulled: Vec<u64>,
    /// Injector depth at submit (= units in the plan).
    pub queued_units: usize,
    /// Pool-lifetime worker park events (cumulative; 0 in scoped mode).
    pub parks: u64,
    /// Pool-lifetime worker unpark events (cumulative; 0 in scoped mode).
    pub unparks: u64,
    /// Dispatch overhead: wall seconds beyond the critical-path worker's
    /// busy time (`wall - max(busy)`, clamped at 0). Spawn-free rounds
    /// shrink this; the serving bench sweeps it scoped-vs-persistent.
    pub dispatch_secs: f64,
}

/// Type-erased view of one round the workers execute through.
trait RoundRunner: Sync {
    fn run_unit(&self, ctx: &mut WorkerContext, idx: usize);
}

/// One submitted round: the closure plus per-unit pickup and writeback
/// slots. Unit `idx` is taken (once) and its result written back by
/// whichever worker pulled `idx` off the injector.
struct Round<'a, T, R, F> {
    f: &'a F,
    units: Vec<Mutex<Option<T>>>,
    results: Vec<Mutex<Option<UnitResult<R>>>>,
}

impl<'a, T, R, F> Round<'a, T, R, F>
where
    T: Send,
    R: Send,
    F: Fn(&mut WorkerContext, T) -> R + Sync,
{
    fn new(f: &'a F, units: Vec<T>) -> Round<'a, T, R, F> {
        Round {
            f,
            results: units.iter().map(|_| Mutex::new(None)).collect(),
            units: units.into_iter().map(|u| Mutex::new(Some(u))).collect(),
        }
    }

    fn into_results(self) -> Vec<UnitResult<R>> {
        self.results
            .into_iter()
            .map(|m| m.into_inner().expect("result slot lock").expect("unit result missing"))
            .collect()
    }
}

impl<T, R, F> RoundRunner for Round<'_, T, R, F>
where
    T: Send,
    R: Send,
    F: Fn(&mut WorkerContext, T) -> R + Sync,
{
    fn run_unit(&self, ctx: &mut WorkerContext, idx: usize) {
        let unit =
            self.units[idx].lock().expect("unit slot lock").take().expect("unit taken twice");
        let out = catch_unwind(AssertUnwindSafe(|| (self.f)(ctx, unit))).map_err(panic_message);
        *self.results[idx].lock().expect("result slot lock") = Some(out);
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

/// Lifetime-erased pointer to the current round. Sound because `run`
/// blocks until every worker has exited the round (`in_round == 0`), so
/// workers never dereference it after `run` returns and drops the round.
#[derive(Clone, Copy)]
struct RunnerPtr(*const (dyn RoundRunner + 'static));
// SAFETY: the pointee is Sync (RoundRunner: Sync) and its lifetime is
// managed by the run/in_round protocol above.
unsafe impl Send for RunnerPtr {}
unsafe impl Sync for RunnerPtr {}

#[derive(Clone, Copy)]
struct Job {
    runner: RunnerPtr,
    n_units: usize,
}

/// Condvar-protected submission state.
struct Gate {
    /// Bumped per submit; a worker joins a job only when the epoch moved
    /// past the last one it ran (prevents re-entering a finished round).
    epoch: u64,
    job: Option<Job>,
    /// Workers currently inside the round (joined, not yet exited). `run`
    /// waits for 0 before collecting results and resetting the injector.
    in_round: usize,
    shutdown: bool,
}

struct PoolShared {
    gate: Mutex<Gate>,
    /// Workers park here between rounds.
    work_cv: Condvar,
    /// `run` waits here for round completion.
    done_cv: Condvar,
    /// The injector: next unscheduled unit index of the current round.
    cursor: AtomicUsize,
    /// Units finished so far in the current round.
    completed: AtomicUsize,
    parks: AtomicU64,
    unparks: AtomicU64,
    /// Per-worker units pulled this round (reset at submit).
    pulled_round: Vec<AtomicU64>,
    /// Per-worker busy nanoseconds this round (reset at submit).
    busy_round_nanos: Vec<AtomicU64>,
}

fn worker_loop(shared: &PoolShared, id: usize) {
    let mut ctx = WorkerContext::new(id);
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut gate = shared.gate.lock().expect("pool gate");
            loop {
                if gate.shutdown {
                    return;
                }
                match gate.job {
                    Some(job) if gate.epoch != last_epoch => {
                        last_epoch = gate.epoch;
                        gate.in_round += 1;
                        break job;
                    }
                    _ => {
                        shared.parks.fetch_add(1, Ordering::Relaxed);
                        gate = shared.work_cv.wait(gate).expect("pool gate");
                        shared.unparks.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        };
        loop {
            let idx = shared.cursor.fetch_add(1, Ordering::SeqCst);
            if idx >= job.n_units {
                break;
            }
            shared.pulled_round[id].fetch_add(1, Ordering::Relaxed);
            let t0 = Instant::now();
            // SAFETY: idx < n_units is handed out exactly n_units times and
            // `run` holds the Round alive until in_round drops to 0, which
            // this worker only allows after leaving this loop.
            unsafe { (*job.runner.0).run_unit(&mut ctx, idx) };
            shared.busy_round_nanos[id]
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            shared.completed.fetch_add(1, Ordering::SeqCst);
        }
        let mut gate = shared.gate.lock().expect("pool gate");
        gate.in_round -= 1;
        drop(gate);
        shared.done_cv.notify_all();
    }
}

/// Fixed-width pool of engine workers (width chosen at scheduler build).
pub struct WorkerPool {
    workers: usize,
    mode: PoolMode,
    /// Present only for a multi-worker persistent pool.
    shared: Option<Arc<PoolShared>>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes concurrent `run` calls in persistent mode (one round in
    /// flight at a time — the injector/slot state is single-round).
    round_lock: Mutex<()>,
    /// The serving thread's context: serial fallbacks and the scheduler's
    /// sequential arms run with it, getting the same scratch reuse and
    /// device binding as pool workers.
    serial_ctx: Mutex<WorkerContext>,
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("mode", &self.mode)
            .field("live_workers", &self.handles.len())
            .finish()
    }
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool::with_mode(workers, PoolMode::from_env())
    }

    pub fn with_mode(workers: usize, mode: PoolMode) -> WorkerPool {
        let workers = workers.max(1);
        let mut pool = WorkerPool {
            workers,
            mode,
            shared: None,
            handles: Vec::new(),
            round_lock: Mutex::new(()),
            serial_ctx: Mutex::new(WorkerContext::new(0)),
        };
        if mode == PoolMode::Persistent && workers > 1 {
            let shared = Arc::new(PoolShared {
                gate: Mutex::new(Gate { epoch: 0, job: None, in_round: 0, shutdown: false }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
                cursor: AtomicUsize::new(0),
                completed: AtomicUsize::new(0),
                parks: AtomicU64::new(0),
                unparks: AtomicU64::new(0),
                pulled_round: (0..workers).map(|_| AtomicU64::new(0)).collect(),
                busy_round_nanos: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            });
            for id in 0..workers {
                let sh = Arc::clone(&shared);
                let handle = std::thread::Builder::new()
                    .name(format!("lava-worker-{id}"))
                    .spawn(move || worker_loop(&sh, id))
                    .expect("spawn pool worker");
                pool.handles.push(handle);
            }
            pool.shared = Some(shared);
        }
        pool
    }

    /// Configured width.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Active dispatcher.
    pub fn mode(&self) -> PoolMode {
        self.mode
    }

    /// Live persistent worker threads (0 in scoped mode / at width 1).
    pub fn live_workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f` with the serving-thread worker context (the one serial
    /// arms and width-1 rounds use).
    pub fn with_serial_ctx<R>(&self, f: impl FnOnce(&mut WorkerContext) -> R) -> R {
        let mut guard = self.serial_ctx.lock().expect("serial context");
        let ctx: &mut WorkerContext = &mut guard;
        f(ctx)
    }

    /// Run `f` over every unit, fanning out across the pool; results come
    /// back in unit order. `f` must be independent per unit (each unit is
    /// owned by exactly one worker). A unit that panics yields
    /// `Err(message)` in its slot; the rest of the round completes and
    /// the pool keeps serving.
    pub fn run<T, R, F>(&self, units: Vec<T>, f: F) -> (Vec<UnitResult<R>>, RoundStats)
    where
        T: Send,
        R: Send,
        F: Fn(&mut WorkerContext, T) -> R + Sync,
    {
        match self.mode {
            PoolMode::Scoped => self.run_scoped(units, f),
            PoolMode::Persistent if self.shared.is_none() || units.len() <= 1 => {
                self.run_serial(units, f)
            }
            PoolMode::Persistent => self.run_persistent(units, f),
        }
    }

    fn run_serial<T, R, F>(&self, units: Vec<T>, f: F) -> (Vec<UnitResult<R>>, RoundStats)
    where
        T: Send,
        R: Send,
        F: Fn(&mut WorkerContext, T) -> R + Sync,
    {
        let n = units.len();
        let t0 = Instant::now();
        let mut guard = self.serial_ctx.lock().expect("serial context");
        let ctx: &mut WorkerContext = &mut guard;
        let results: Vec<UnitResult<R>> = units
            .into_iter()
            .map(|u| catch_unwind(AssertUnwindSafe(|| f(&mut *ctx, u))).map_err(panic_message))
            .collect();
        drop(guard);
        let wall = t0.elapsed().as_secs_f64();
        let (parks, unparks) = self.lifetime_parks();
        let stats = RoundStats {
            busy_secs: vec![wall],
            wall_secs: wall,
            pulled: vec![n as u64],
            queued_units: n,
            parks,
            unparks,
            dispatch_secs: 0.0,
        };
        (results, stats)
    }

    fn run_scoped<T, R, F>(&self, units: Vec<T>, f: F) -> (Vec<UnitResult<R>>, RoundStats)
    where
        T: Send,
        R: Send,
        F: Fn(&mut WorkerContext, T) -> R + Sync,
    {
        let n = units.len();
        let t0 = Instant::now();
        let (results, busy_secs) = par::scoped_map_timed(
            units,
            |u| {
                // the oracle has no persistent workers: a throwaway context
                // per unit (slot 0 — scoped threads process several units,
                // and device pinning is per-thread consistency)
                let mut ctx = WorkerContext::new(0);
                catch_unwind(AssertUnwindSafe(|| f(&mut ctx, u))).map_err(panic_message)
            },
            self.workers,
        );
        let wall = t0.elapsed().as_secs_f64();
        let max_busy = busy_secs.iter().cloned().fold(0.0f64, f64::max);
        let stats = RoundStats {
            busy_secs,
            wall_secs: wall,
            pulled: vec![],
            queued_units: n,
            parks: 0,
            unparks: 0,
            dispatch_secs: (wall - max_busy).max(0.0),
        };
        (results, stats)
    }

    fn run_persistent<T, R, F>(&self, units: Vec<T>, f: F) -> (Vec<UnitResult<R>>, RoundStats)
    where
        T: Send,
        R: Send,
        F: Fn(&mut WorkerContext, T) -> R + Sync,
    {
        let shared = self.shared.as_ref().expect("persistent pool state");
        let _round = self.round_lock.lock().expect("round lock");
        let n = units.len();
        let round = Round::new(&f, units);
        let runner: *const (dyn RoundRunner + '_) = &round;
        // SAFETY: lifetime erasure only — the wait below keeps `round`
        // alive past the last worker dereference.
        #[allow(clippy::useless_transmute)] // only the region changes
        let ptr = RunnerPtr(unsafe {
            std::mem::transmute::<
                *const (dyn RoundRunner + '_),
                *const (dyn RoundRunner + 'static),
            >(runner)
        });
        for a in &shared.pulled_round {
            a.store(0, Ordering::Relaxed);
        }
        for a in &shared.busy_round_nanos {
            a.store(0, Ordering::Relaxed);
        }
        shared.cursor.store(0, Ordering::SeqCst);
        shared.completed.store(0, Ordering::SeqCst);
        let t0 = Instant::now();
        {
            let mut gate = shared.gate.lock().expect("pool gate");
            gate.epoch += 1;
            gate.job = Some(Job { runner: ptr, n_units: n });
            shared.work_cv.notify_all();
        }
        {
            let mut gate = shared.gate.lock().expect("pool gate");
            while gate.in_round > 0 || shared.completed.load(Ordering::SeqCst) < n {
                gate = shared.done_cv.wait(gate).expect("pool gate");
            }
            // late wakers must park, not re-join a dead round
            gate.job = None;
        }
        let wall = t0.elapsed().as_secs_f64();
        let busy_secs: Vec<f64> = shared
            .busy_round_nanos
            .iter()
            .map(|a| a.load(Ordering::Relaxed) as f64 * 1e-9)
            .collect();
        let pulled: Vec<u64> =
            shared.pulled_round.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let max_busy = busy_secs.iter().cloned().fold(0.0f64, f64::max);
        let stats = RoundStats {
            busy_secs,
            wall_secs: wall,
            pulled,
            queued_units: n,
            parks: shared.parks.load(Ordering::Relaxed),
            unparks: shared.unparks.load(Ordering::Relaxed),
            dispatch_secs: (wall - max_busy).max(0.0),
        };
        (round.into_results(), stats)
    }

    fn lifetime_parks(&self) -> (u64, u64) {
        match &self.shared {
            Some(s) => (s.parks.load(Ordering::Relaxed), s.unparks.load(Ordering::Relaxed)),
            None => (0, 0),
        }
    }

    #[cfg(test)]
    fn shared_weak(&self) -> Option<std::sync::Weak<PoolShared>> {
        self.shared.as_ref().map(Arc::downgrade)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(shared) = self.shared.take() {
            {
                let mut gate = shared.gate.lock().expect("pool gate");
                gate.shutdown = true;
            }
            shared.work_cv.notify_all();
            for h in self.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MODES: [PoolMode; 2] = [PoolMode::Persistent, PoolMode::Scoped];

    #[test]
    fn results_stay_in_plan_order() {
        for mode in MODES {
            for width in [1usize, 2, 4, 9] {
                let pool = WorkerPool::with_mode(width, mode);
                assert_eq!(pool.workers(), width);
                let units: Vec<usize> = (0..23).collect();
                let (out, stats) = pool.run(units, |_ctx, u| u * u);
                let got: Vec<usize> = out.into_iter().map(|r| r.expect("no panics")).collect();
                assert_eq!(
                    got,
                    (0..23).map(|u| u * u).collect::<Vec<_>>(),
                    "{mode:?} width {width}"
                );
                assert_eq!(stats.queued_units, 23);
                assert!(!stats.busy_secs.is_empty());
                assert!(stats.wall_secs >= 0.0);
                if mode == PoolMode::Persistent && width > 1 {
                    assert_eq!(stats.busy_secs.len(), width);
                    assert_eq!(stats.pulled.len(), width);
                    assert_eq!(stats.pulled.iter().sum::<u64>(), 23, "every unit pulled once");
                }
            }
        }
    }

    #[test]
    fn zero_width_clamps_to_one() {
        let pool = WorkerPool::with_mode(0, PoolMode::Persistent);
        assert_eq!(pool.workers(), 1);
        assert_eq!(pool.live_workers(), 0, "width 1 runs serial, no threads");
        let (out, stats) = pool.run(vec![1, 2, 3], |_ctx, u| u + 1);
        let got: Vec<i32> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, vec![2, 3, 4]);
        assert_eq!(stats.busy_secs.len(), 1, "serial fallback");
    }

    #[test]
    fn panicking_unit_fails_alone_and_pool_keeps_serving() {
        for mode in MODES {
            for width in [1usize, 3] {
                let pool = WorkerPool::with_mode(width, mode);
                let units: Vec<usize> = (0..8).collect();
                let (out, _) = pool.run(units, |_ctx, u| {
                    if u == 5 {
                        panic!("poisoned unit {u}");
                    }
                    u + 1
                });
                for (i, r) in out.iter().enumerate() {
                    if i == 5 {
                        let msg = r.as_ref().expect_err("unit 5 must fail");
                        assert!(msg.contains("poisoned unit 5"), "{mode:?}: got {msg:?}");
                    } else {
                        assert_eq!(*r.as_ref().expect("healthy unit"), i + 1, "{mode:?}");
                    }
                }
                // the same pool (same threads, same contexts) keeps serving
                let (out, _) = pool.run(vec![10usize, 20], |_ctx, u| u * 2);
                let got: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
                assert_eq!(got, vec![20, 40], "{mode:?} width {width}");
            }
        }
    }

    #[test]
    fn worker_ids_stay_within_width() {
        let pool = WorkerPool::with_mode(4, PoolMode::Persistent);
        let (out, _) = pool.run((0..32).collect::<Vec<usize>>(), |ctx, _u| ctx.worker_id);
        for r in out {
            assert!(r.unwrap() < 4);
        }
    }

    #[test]
    fn serial_context_scratch_is_reused_across_rounds() {
        let pool = WorkerPool::with_mode(1, PoolMode::Persistent);
        let grab = |pool: &WorkerPool| -> usize {
            let (out, _) = pool.run(vec![()], |ctx: &mut WorkerContext, ()| {
                let slots = ctx.scratch.dequant_slots(2, &[2, 3, 4]);
                slots[1].0.as_f32().expect("f32 scratch").as_ptr() as usize
            });
            out.into_iter().next().unwrap().unwrap()
        };
        assert_eq!(grab(&pool), grab(&pool), "same allocation across rounds");
    }

    #[test]
    fn dequant_slots_keep_shape_exact() {
        let mut ws = WorkerScratch::default();
        let slots = ws.dequant_slots(2, &[1, 2, 2]);
        assert_eq!(slots.len(), 2);
        assert_eq!(slots[0].0.shape, vec![1, 2, 2]);
        slots[0].0.as_f32_mut().unwrap()[0] = 7.0;
        let slots = ws.dequant_slots(1, &[1, 2, 2]);
        assert_eq!(slots[0].0.as_f32().unwrap()[0], 7.0, "same shape keeps the buffer");
        let slots = ws.dequant_slots(1, &[2, 2, 2]);
        assert_eq!(slots[0].0.shape, vec![2, 2, 2], "backends read the exact shape");
        assert_eq!(slots[0].0.as_f32().unwrap()[0], 0.0, "reshape reallocates zeroed");
    }

    #[test]
    fn drop_joins_workers_and_frees_shared_state() {
        let pool = WorkerPool::with_mode(4, PoolMode::Persistent);
        assert_eq!(pool.live_workers(), 4);
        let weak = pool.shared_weak().expect("persistent pool has shared state");
        let (out, _) = pool.run((0..9).collect::<Vec<usize>>(), |_ctx, u| u);
        assert_eq!(out.len(), 9);
        drop(pool);
        // every worker held an Arc clone; upgrade failing proves they all
        // exited and were joined (no leaked threads, nothing left queued)
        assert!(weak.upgrade().is_none(), "drop must join every worker");
    }

    #[test]
    fn scoped_oracle_matches_persistent_results() {
        let persistent = WorkerPool::with_mode(4, PoolMode::Persistent);
        let scoped = WorkerPool::with_mode(4, PoolMode::Scoped);
        let work = |_: &mut WorkerContext, u: usize| (u, u * 31 % 7);
        let (a, _) = persistent.run((0..17).collect::<Vec<usize>>(), work);
        let (b, _) = scoped.run((0..17).collect::<Vec<usize>>(), work);
        let a: Vec<_> = a.into_iter().map(|r| r.unwrap()).collect();
        let b: Vec<_> = b.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(a, b);
    }
}
