//! Request queue + shape-bucket batching.
//!
//! The coordinator executes one sequence per PJRT call (the artifacts are
//! single-sequence), so "batching" here is the continuous-batching form:
//! admission + interleaving decisions, plus grouping queued prefills by
//! shape bucket so executable compilation (one per bucket) is amortized and
//! cache-warm buckets are preferred.

use std::collections::VecDeque;

use crate::coordinator::engine::GenerateRequest;
use crate::runtime::Runtime;

#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub id: u64,
    pub request: GenerateRequest,
    pub bucket: usize,
    pub enqueued_at: std::time::Instant,
}

/// FIFO with bucket-aware dequeue.
#[derive(Debug, Default)]
pub struct Batcher {
    queue: VecDeque<QueuedRequest>,
    next_id: u64,
    buckets: Vec<usize>,
    /// Accept prompts beyond the largest bucket — chunked prefill can
    /// serve them; they batch under the largest bucket's id.
    allow_oversize: bool,
}

impl Batcher {
    pub fn new(prefill_buckets: &[usize]) -> Batcher {
        Batcher {
            queue: VecDeque::new(),
            next_id: 0,
            buckets: prefill_buckets.to_vec(),
            allow_oversize: false,
        }
    }

    /// Accept prompts beyond the largest bucket (the scheduler enables
    /// this whenever chunked prefill is configured).
    pub fn set_allow_oversize(&mut self, allow: bool) {
        self.allow_oversize = allow;
    }

    /// Enqueue; returns the assigned request id, or None if the prompt
    /// exceeds every bucket (and oversize admission is off).
    pub fn push(&mut self, request: GenerateRequest) -> Option<u64> {
        let bucket = match Runtime::pick_bucket(&self.buckets, request.prompt.len()) {
            Some(b) => b,
            None if self.allow_oversize => self.buckets.last().copied()?,
            None => return None,
        };
        self.next_id += 1;
        let id = self.next_id;
        self.queue.push_back(QueuedRequest {
            id,
            request,
            bucket,
            enqueued_at: std::time::Instant::now(),
        });
        Some(id)
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Pop the oldest request.
    pub fn pop(&mut self) -> Option<QueuedRequest> {
        self.queue.pop_front()
    }

    /// Pop the oldest request in `bucket` (compile-warm preference), falling
    /// back to plain FIFO.
    pub fn pop_preferring(&mut self, bucket: usize) -> Option<QueuedRequest> {
        if let Some(idx) = self.queue.iter().position(|q| q.bucket == bucket) {
            return self.queue.remove(idx);
        }
        self.pop()
    }

    /// Take up to `k` oldest requests sharing one bucket (a prefill batch).
    pub fn pop_batch(&mut self, k: usize) -> Vec<QueuedRequest> {
        let Some(first) = self.pop() else { return vec![] };
        let bucket = first.bucket;
        let mut out = vec![first];
        out.extend(self.pop_matching(bucket, k.saturating_sub(1)));
        out
    }

    /// `pop_batch`, but seeded by `pop_preferring(bucket)`: the batch grows
    /// around the oldest request of the preferred (compile-warm) bucket,
    /// falling back to the plain FIFO head when that bucket has no work.
    pub fn pop_batch_preferring(&mut self, bucket: usize, k: usize) -> Vec<QueuedRequest> {
        let Some(first) = self.pop_preferring(bucket) else { return vec![] };
        let bucket = first.bucket;
        let mut out = vec![first];
        out.extend(self.pop_matching(bucket, k.saturating_sub(1)));
        out
    }

    /// Shape bucket of the queue's oldest request.
    pub fn front_bucket(&self) -> Option<usize> {
        self.queue.front().map(|q| q.bucket)
    }

    /// Take up to `k` oldest requests from one specific bucket (used to grow
    /// a batch around a `pop_preferring` hit).
    pub fn pop_matching(&mut self, bucket: usize, k: usize) -> Vec<QueuedRequest> {
        let mut out = Vec::new();
        while out.len() < k {
            match self.queue.iter().position(|q| q.bucket == bucket) {
                Some(idx) => out.push(self.queue.remove(idx).unwrap()),
                None => break,
            }
        }
        out
    }

    /// Put a popped request back without losing its identity or its place:
    /// ids are assigned in arrival order, so inserting by id restores exact
    /// FIFO position (admission deferral must not reorder or re-id).
    pub fn requeue(&mut self, q: QueuedRequest) {
        let idx = self.queue.iter().position(|r| r.id > q.id).unwrap_or(self.queue.len());
        self.queue.insert(idx, q);
    }

    /// Take every queued request, in FIFO order (shutdown: the serving loop
    /// parks each one with a rejection result instead of admitting it).
    pub fn drain(&mut self) -> Vec<QueuedRequest> {
        std::mem::take(&mut self.queue).into_iter().collect()
    }

    /// Remove a queued request by id (cancellation before admission).
    pub fn remove(&mut self, id: u64) -> Option<QueuedRequest> {
        let idx = self.queue.iter().position(|q| q.id == id)?;
        self.queue.remove(idx)
    }

    /// True if any queued request maps to `bucket`.
    pub fn has_bucket(&self, bucket: usize) -> bool {
        self.queue.iter().any(|q| q.bucket == bucket)
    }

    /// Oldest queue wait in seconds (for backpressure / SLO decisions).
    pub fn oldest_wait_secs(&self) -> f64 {
        self.queue
            .front()
            .map(|q| q.enqueued_at.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(n: usize) -> GenerateRequest {
        GenerateRequest { prompt: vec![0; n], max_new_tokens: 4 }
    }

    #[test]
    fn assigns_buckets() {
        let mut b = Batcher::new(&[128, 256, 512]);
        let id = b.push(req(100)).unwrap();
        assert_eq!(id, 1);
        assert_eq!(b.queue[0].bucket, 128);
        assert!(b.push(req(4000)).is_none(), "oversized prompt rejected");
    }

    #[test]
    fn oversize_allowed_lands_in_largest_bucket() {
        let mut b = Batcher::new(&[128, 256, 512]);
        b.set_allow_oversize(true);
        let id = b.push(req(4000)).unwrap();
        let q = b.remove(id).unwrap();
        assert_eq!(q.bucket, 512, "oversize prompts batch under the largest bucket");
        b.set_allow_oversize(false);
        assert!(b.push(req(4000)).is_none(), "flag off restores the rejection");
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(&[128, 256]);
        b.push(req(10));
        b.push(req(200));
        b.push(req(20));
        assert_eq!(b.pop().unwrap().id, 1);
        assert_eq!(b.pop().unwrap().id, 2);
        assert_eq!(b.pop().unwrap().id, 3);
        assert!(b.pop().is_none());
    }

    #[test]
    fn bucket_preference() {
        let mut b = Batcher::new(&[128, 256]);
        b.push(req(200)); // bucket 256
        b.push(req(10));  // bucket 128
        let got = b.pop_preferring(128).unwrap();
        assert_eq!(got.id, 2);
        // falls back to FIFO when no match
        let got2 = b.pop_preferring(128).unwrap();
        assert_eq!(got2.id, 1);
    }

    #[test]
    fn batch_same_bucket() {
        let mut b = Batcher::new(&[128, 256]);
        b.push(req(10));
        b.push(req(200));
        b.push(req(30));
        b.push(req(40));
        let batch = b.pop_batch(3);
        assert_eq!(batch.iter().map(|q| q.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn requeue_restores_fifo_position_and_id() {
        let mut b = Batcher::new(&[128, 256]);
        b.push(req(10)); // id 1, bucket 128
        b.push(req(200)); // id 2, bucket 256
        b.push(req(30)); // id 3, bucket 128
        let q = b.pop().unwrap();
        assert_eq!(q.id, 1);
        b.requeue(q);
        assert_eq!(
            b.queue.iter().map(|q| q.id).collect::<Vec<_>>(),
            vec![1, 2, 3],
            "requeue must restore exact FIFO order with the original id"
        );
        // a mid-queue pop requeues back to its slot, not the front
        let q2 = b.pop_preferring(256).unwrap();
        assert_eq!(q2.id, 2);
        b.requeue(q2);
        assert_eq!(b.queue.iter().map(|q| q.id).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn remove_by_id() {
        let mut b = Batcher::new(&[128]);
        b.push(req(10));
        b.push(req(20));
        assert_eq!(b.remove(1).unwrap().id, 1);
        assert!(b.remove(1).is_none());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn pop_matching_only_takes_bucket() {
        let mut b = Batcher::new(&[128, 256]);
        b.push(req(200)); // id 1, bucket 256
        b.push(req(10)); // id 2, bucket 128
        b.push(req(20)); // id 3, bucket 128
        let got = b.pop_matching(128, 5);
        assert_eq!(got.iter().map(|q| q.id).collect::<Vec<_>>(), vec![2, 3]);
        assert!(b.has_bucket(256));
        assert!(!b.has_bucket(128));
    }
}
