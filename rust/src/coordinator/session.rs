//! Per-request serving state.

use crate::kvcache::tier::Residency;
use crate::kvcache::HotStore;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefilling,
    Decoding,
    Finished,
}

/// One in-flight request: prompt, per-layer compressed caches, generation.
pub struct Session {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub phase: Phase,
    /// One cache per layer (created during prefill). A spilled layer's slot
    /// holds an empty zero-capacity store; the real data lives in the tier
    /// manager's warm blocks until prefetch swaps it back in.
    pub caches: Vec<HotStore>,
    /// Per-layer residency, maintained by the scheduler's tier transitions;
    /// the engine asserts all-Hot at the decode boundary.
    pub residency: Vec<Residency>,
    /// Per-layer entry budgets decided at prefill (Algorithm 2 output).
    /// Doubles as the layer weight for spill ordering: LAVa's entropy
    /// allocation gives low-weight layers small budgets, so lowest-budget
    /// layers spill first.
    pub budgets: Vec<usize>,
    pub generated: Vec<i32>,
    /// Absolute position of the next token to decode.
    pub next_pos: usize,
    /// Timing (seconds, from request arrival).
    pub queued_at: std::time::Instant,
    pub prefill_secs: f64,
    pub decode_secs: f64,
}

impl Session {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Session {
        Session {
            id,
            prompt,
            max_new_tokens,
            phase: Phase::Queued,
            caches: Vec::new(),
            residency: Vec::new(),
            budgets: Vec::new(),
            generated: Vec::new(),
            next_pos: 0,
            queued_at: std::time::Instant::now(),
            prefill_secs: 0.0,
            decode_secs: 0.0,
        }
    }

    /// Live *hot* KV bytes across all layers (spilled layers hold an empty
    /// hot store, so they contribute zero — their bytes are warm-tier).
    pub fn kv_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.live_bytes()).sum()
    }

    /// Hot bytes one decode step appends across all layers (one K+V entry
    /// per kv head per layer) — the headroom the scheduler reserves before
    /// letting this session step under a hot-tier limit.
    pub fn step_growth_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.step_growth_bytes()).sum()
    }

    /// True when every layer is hot-resident (decodable by the engine).
    pub fn is_fully_hot(&self) -> bool {
        self.residency.iter().all(|r| *r == Residency::Hot)
    }

    /// Per-layer hot-cache capacities — the shape key batched decode groups
    /// by: one `layer_decode_batched` dispatch at layer l serves only
    /// sessions whose layer-l caches share a capacity bucket, for every l.
    pub fn capacity_signature(&self) -> Vec<usize> {
        self.caches.iter().map(|c| c.capacity()).collect()
    }

    /// Allocation-free signature comparison for the per-round grouping hot
    /// path (also true only when the layer counts match).
    pub fn matches_capacity_signature(&self, sig: &[usize]) -> bool {
        self.caches.iter().map(|c| c.capacity()).eq(sig.iter().copied())
    }

    pub fn total_entries(&self) -> usize {
        self.caches.iter().map(|c| c.total_entries()).sum()
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Finished || self.generated.len() >= self.max_new_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_flags() {
        let mut s = Session::new(1, vec![1, 2, 3], 4);
        assert_eq!(s.phase, Phase::Queued);
        assert!(!s.is_done());
        s.generated = vec![0; 4];
        assert!(s.is_done());
    }

    #[test]
    fn kv_accounting_empty() {
        let s = Session::new(2, vec![1], 1);
        assert_eq!(s.kv_bytes(), 0);
        assert_eq!(s.total_entries(), 0);
    }

    #[test]
    fn capacity_signature_tracks_layers() {
        let mut s = Session::new(4, vec![1, 2], 1);
        assert!(s.capacity_signature().is_empty());
        s.caches.push(HotStore::new(2, 4, 128));
        s.caches.push(HotStore::new(2, 4, 256));
        assert_eq!(s.capacity_signature(), vec![128, 256]);
        assert!(s.matches_capacity_signature(&[128, 256]));
        assert!(!s.matches_capacity_signature(&[128]));
        assert!(!s.matches_capacity_signature(&[128, 512]));
    }

    #[test]
    fn residency_tracking() {
        let mut s = Session::new(3, vec![1, 2], 1);
        assert!(s.is_fully_hot(), "no layers yet is trivially hot");
        s.residency = vec![Residency::Hot, Residency::Warm];
        assert!(!s.is_fully_hot());
        s.residency[1] = Residency::Hot;
        assert!(s.is_fully_hot());
    }
}
