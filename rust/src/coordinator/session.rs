//! Per-request serving state.

use crate::kvcache::tier::Residency;
use crate::kvcache::{HotStore, Q8Carry};
use crate::runtime::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Queued,
    /// Mid-prefill. `next_chunk` is the chunk cursor within the current
    /// layer of the resumable chunked state machine (always 0 on the
    /// monolithic path, which enters and leaves this phase in one call).
    Prefilling { next_chunk: usize },
    Decoding,
    Finished,
}

/// Resumable chunked-prefill state: everything the engine needs to pick the
/// prefill back up mid-layer on a later tick. The loop is layer-outer /
/// chunk-inner: layer `layer` has consumed chunks `[0, chunk_idx)`, earlier
/// layers are already compressed into `Session::caches`, and later layers
/// have not started. When the last chunk of a layer lands, the accumulated
/// observations (`win`/`acc`/`vnorm`) and carry K/V are exactly the
/// monolithic `layer_prefill` outputs, so scoring, Eq. 7 entropy weights,
/// and the Algorithm 2 recompression cascade run unchanged — bit-identical
/// tokens, budgets, and keep-sets to the one-shot path.
///
/// Memory note: on the plain chunked path the carry K/V is the layer's
/// uncompressed cache and stays O(prompt) — what chunking shrinks is the
/// *dispatch* working set (each backend call touches one chunk-bucket of
/// rows, not the full prompt bucket) and the head-of-line time between
/// decode rounds. With streaming eviction (`stream` is Some) the carry is
/// additionally *compacted* after every non-final chunk, so each layer's
/// lane is bounded by the fixed working cap (layer budget + one chunk +
/// window) regardless of prompt length. The streaming default is
/// **chunk-major**: each chunk flows through all L layers in one pass, so
/// all L lanes are live at once (`L · cap` columns, still flat in prompt
/// length) while the hidden-state rows shrink from O(prompt) to one chunk
/// bucket — *nothing* in the prefill resident set grows with the prompt.
/// The legacy layer-major order (`stream_layer_major`) keeps one lane but
/// holds O(prompt) hidden rows across layers; Q8 carries (`carry_q8`)
/// halve the chunk-major lane bytes again between dispatches.
pub struct ChunkedPrefill {
    /// Configured chunk size in tokens.
    pub chunk: usize,
    /// Observation bucket: the monolithic prefill bucket for this prompt
    /// (or the exact prompt length when it exceeds every bucket). All
    /// accumulated observation tensors are padded to this width so the
    /// completed layer is indistinguishable from a monolithic pass.
    pub n_obs: usize,
    pub n_chunks: usize,
    /// Current layer (0-based; == n_layers means done).
    pub layer: usize,
    /// Next chunk within the current layer.
    pub chunk_idx: usize,
    /// Current layer's input rows, valid tokens only ([n, d] flattened).
    pub x: Vec<f32>,
    /// Accumulating layer output rows ([n, d] flattened).
    pub x_next: Vec<f32>,
    /// Carry-in K/V for the current layer: [Hk, n_obs, dh]. Rows for
    /// positions >= chunk_idx * chunk are unspecified (stale from the
    /// previous layer) — backends only read rows < the chunk's start.
    pub carry_k: Tensor,
    pub carry_v: Tensor,
    /// Accumulated window-attention panel [H * w * n_obs].
    pub win: Vec<f32>,
    /// Accumulated column attention mass [H * n_obs].
    pub acc: Vec<f32>,
    /// Accumulated per-token value norms [Hk * n_obs].
    pub vnorm: Vec<f32>,
    /// Dynamic-allocation layer weights gathered so far (Eq. 7 / CAKE).
    pub weights: Vec<f64>,
    /// Per-layer budgets (updated by the Algorithm 2 cascade as layers
    /// complete; moved into `Session::budgets` at the end).
    pub budgets: Vec<usize>,
    pub peak_transient: usize,
    /// Peak prefill *resident* bytes: the full working set over and above
    /// the retained compressed caches — carry K/V (or Q8 codes + scales),
    /// observation panels, and hidden-state rows. This is what admission
    /// prices and what the flat-in-prompt-length claim is asserted on;
    /// `peak_transient` above tracks only the carry K/V (kept for the PR 8
    /// gauge's continuity).
    pub peak_resident: usize,
    /// Streaming-eviction state (Some only in `prefill_stream_evict` mode).
    /// When set, the carries and compacted panels live in per-layer lanes
    /// here and the `carry_k`/`carry_v`/`win`/`acc`/`vnorm` fields above
    /// stay empty.
    pub stream: Option<Box<StreamPrefill>>,
    /// Per-dispatch (chunk bucket, valid tokens) pairs for the bucket-waste
    /// gauges, reported with the final `PrefillReport`.
    pub bucket_fills: Vec<(usize, usize)>,
    /// Queue wait at admission (seconds) — the TTFT baseline.
    pub wait_secs: f64,
    /// When the request was enqueued; TTFT = this → first token, which for
    /// an interleaved chunked prefill includes the decode rounds between
    /// advances.
    pub enqueued_at: std::time::Instant,
}

/// One layer's streaming-eviction lane: the compacted carry K/V plus the
/// observation panels for that layer's live columns. Layer-major streaming
/// uses a single lane reset between layers; chunk-major streaming keeps one
/// lane per layer live for the whole prefill (each bounded at `cap`
/// columns, so the total stays flat in prompt length).
pub struct StreamLayer {
    /// Absolute prompt position of each live carry column, strictly
    /// ascending; its length is the live column count.
    pub col_pos: Vec<i32>,
    /// Compacted accumulated-attention panel `[H * live_cols]`. Backends
    /// report per-chunk mass at carry columns too, so carry entries are
    /// *added to*, never overwritten.
    pub acc: Vec<f32>,
    /// Compacted per-column value norms `[Hk * live_cols]`.
    pub vnorm: Vec<f32>,
    /// Rolling observation window: `(absolute qpos, [H * live_cols] row)`
    /// for the last `min(w, seen)` query positions, ascending by qpos.
    /// Rows for evicted columns are compacted along with everything else.
    pub win_rows: Vec<(usize, Vec<f32>)>,
    /// f32 carry K/V `[Hk, cap, dh]` — the authoritative inter-chunk
    /// representation unless `q8` is set, in which case these are
    /// zero-width `[Hk, 0, dh]` and the lane's columns live quantized.
    pub carry_k: Tensor,
    pub carry_v: Tensor,
    /// Q8-quantized carry (chunk-major only, `carry_q8`): between chunk
    /// passes the compacted columns are held as int8 codes + per-(head,
    /// column) scales; at dispatch they dequantize into the executing
    /// worker's dequant arena
    /// ([`WorkerScratch`](crate::coordinator::pool::WorkerScratch)), so the
    /// f32 working pair is per-worker, not per-session.
    pub q8: Option<Q8Carry>,
}

impl StreamLayer {
    pub fn new_f32(n_kv_heads: usize, cap: usize, d_head: usize) -> StreamLayer {
        StreamLayer {
            col_pos: Vec::new(),
            acc: Vec::new(),
            vnorm: Vec::new(),
            win_rows: Vec::new(),
            carry_k: Tensor::zeros(&[n_kv_heads, cap, d_head]),
            carry_v: Tensor::zeros(&[n_kv_heads, cap, d_head]),
            q8: None,
        }
    }

    pub fn new_q8(n_kv_heads: usize, cap: usize, d_head: usize) -> StreamLayer {
        StreamLayer {
            col_pos: Vec::new(),
            acc: Vec::new(),
            vnorm: Vec::new(),
            win_rows: Vec::new(),
            carry_k: Tensor::zeros(&[n_kv_heads, 0, d_head]),
            carry_v: Tensor::zeros(&[n_kv_heads, 0, d_head]),
            q8: Some(Q8Carry::new(n_kv_heads, d_head, cap)),
        }
    }

    /// Live column count (also the panel width).
    pub fn n_live(&self) -> usize {
        self.col_pos.len()
    }

    /// Reset the per-layer accumulators for the next layer (layer-major
    /// reuse; the carry tensors need no reset — live columns are rewritten
    /// from scratch). Chunk-major calls this after the lane's layer is
    /// compressed so stale panels stop counting against the resident set.
    pub fn reset_for_next_layer(&mut self) {
        self.col_pos.clear();
        self.acc.clear();
        self.vnorm.clear();
        self.win_rows.clear();
    }

    /// Allocated bytes this lane holds between dispatches: carry K/V (f32
    /// tensors or Q8 codes + scales) plus the live observation panels.
    pub fn resident_bytes(&self) -> usize {
        let carry = match &self.q8 {
            Some(q8) => q8.allocated_bytes(),
            None => (self.carry_k.shape.iter().product::<usize>()
                + self.carry_v.shape.iter().product::<usize>())
                * 4,
        };
        let panels = (self.acc.len() + self.vnorm.len() + self.col_pos.len()) * 4
            + self
                .win_rows
                .iter()
                .map(|(_, row)| 16 + row.len() * 4)
                .sum::<usize>();
        carry + panels
    }
}

/// Streaming-eviction prefill state layered on [`ChunkedPrefill`] when
/// `prefill_stream_evict` is on. Columns are kept in ascending
/// absolute-position order; after each non-final chunk the engine scores a
/// lane's live columns (trailing observation window pinned) and compacts
/// every panel plus the carry K/V down to the per-head budget union, so no
/// lane ever exceeds `cap` columns.
pub struct StreamPrefill {
    /// Fixed working cap in columns: each lane's carry is `[Hk, cap, dh]`
    /// and every dispatch is a `layer_prefill_chunked_evict` at this cap
    /// (cap >= budget-union + chunk bucket + window by construction).
    pub cap: usize,
    /// Chunk-major order (the default): each chunk runs through all L
    /// layers in one pass, `layers` holds one lane per model layer, and the
    /// hidden rows are one chunk wide. False = legacy layer-major order:
    /// one lane in `layers`, reset between layers, O(prompt) hidden rows.
    pub chunk_major: bool,
    /// Per-layer lanes (length = n_layers when chunk-major, else 1).
    pub layers: Vec<StreamLayer>,
    /// Peak live columns in any one lane across the whole prefill — drives
    /// the bounded carry-transient gauge (flat in prompt length, unlike the
    /// plain chunked carry).
    pub max_live_cols: usize,
}

impl StreamPrefill {
    pub fn new(
        cap: usize,
        chunk_major: bool,
        n_lanes: usize,
        n_kv_heads: usize,
        d_head: usize,
        q8: bool,
    ) -> StreamPrefill {
        let layers = (0..n_lanes)
            .map(|_| {
                if q8 {
                    StreamLayer::new_q8(n_kv_heads, cap, d_head)
                } else {
                    StreamLayer::new_f32(n_kv_heads, cap, d_head)
                }
            })
            .collect();
        StreamPrefill { cap, chunk_major, layers, max_live_cols: 0 }
    }

    /// Whether the lanes hold Q8 carries (the executing worker then sizes
    /// its dequant arena at `[Hk, cap, dh]` per lane member at dispatch).
    pub fn q8(&self) -> bool {
        self.layers.first().is_some_and(|l| l.q8.is_some())
    }
}

/// One in-flight request: prompt, per-layer compressed caches, generation.
pub struct Session {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub phase: Phase,
    /// One cache per layer (created during prefill). A spilled layer's slot
    /// holds an empty zero-capacity store; the real data lives in the tier
    /// manager's warm blocks until prefetch swaps it back in.
    pub caches: Vec<HotStore>,
    /// Per-layer residency, maintained by the scheduler's tier transitions;
    /// the engine asserts all-Hot at the decode boundary.
    pub residency: Vec<Residency>,
    /// Per-layer entry budgets decided at prefill (Algorithm 2 output).
    /// Doubles as the layer weight for spill ordering: LAVa's entropy
    /// allocation gives low-weight layers small budgets, so lowest-budget
    /// layers spill first.
    pub budgets: Vec<usize>,
    pub generated: Vec<i32>,
    /// Resumable chunked-prefill state (Some only while `phase` is
    /// `Prefilling` on the chunked path; boxed — it is fat and most
    /// sessions never carry it).
    pub prefill: Option<Box<ChunkedPrefill>>,
    /// Absolute position of the next token to decode.
    pub next_pos: usize,
    /// Timing (seconds, from request arrival).
    pub queued_at: std::time::Instant,
    pub prefill_secs: f64,
    pub decode_secs: f64,
}

impl Session {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Session {
        Session {
            id,
            prompt,
            max_new_tokens,
            phase: Phase::Queued,
            caches: Vec::new(),
            residency: Vec::new(),
            budgets: Vec::new(),
            generated: Vec::new(),
            prefill: None,
            next_pos: 0,
            queued_at: std::time::Instant::now(),
            prefill_secs: 0.0,
            decode_secs: 0.0,
        }
    }

    /// Live *hot* KV bytes across all layers (spilled layers hold an empty
    /// hot store, so they contribute zero — their bytes are warm-tier).
    pub fn kv_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.live_bytes()).sum()
    }

    /// Hot bytes one decode step appends across all layers (one K+V entry
    /// per kv head per layer) — the headroom the scheduler reserves before
    /// letting this session step under a hot-tier limit.
    pub fn step_growth_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.step_growth_bytes()).sum()
    }

    /// True when every layer is hot-resident (decodable by the engine).
    pub fn is_fully_hot(&self) -> bool {
        self.residency.iter().all(|r| *r == Residency::Hot)
    }

    /// Per-layer hot-cache capacities — the shape key batched decode groups
    /// by: one `layer_decode_batched` dispatch at layer l serves only
    /// sessions whose layer-l caches share a capacity bucket, for every l.
    pub fn capacity_signature(&self) -> Vec<usize> {
        self.caches.iter().map(|c| c.capacity()).collect()
    }

    /// Allocation-free signature comparison for the per-round grouping hot
    /// path (also true only when the layer counts match).
    pub fn matches_capacity_signature(&self, sig: &[usize]) -> bool {
        self.caches.iter().map(|c| c.capacity()).eq(sig.iter().copied())
    }

    pub fn total_entries(&self) -> usize {
        self.caches.iter().map(|c| c.total_entries()).sum()
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Finished || self.generated.len() >= self.max_new_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_flags() {
        let mut s = Session::new(1, vec![1, 2, 3], 4);
        assert_eq!(s.phase, Phase::Queued);
        assert!(!s.is_done());
        s.generated = vec![0; 4];
        assert!(s.is_done());
    }

    #[test]
    fn kv_accounting_empty() {
        let s = Session::new(2, vec![1], 1);
        assert_eq!(s.kv_bytes(), 0);
        assert_eq!(s.total_entries(), 0);
    }

    #[test]
    fn capacity_signature_tracks_layers() {
        let mut s = Session::new(4, vec![1, 2], 1);
        assert!(s.capacity_signature().is_empty());
        s.caches.push(HotStore::new(2, 4, 128));
        s.caches.push(HotStore::new(2, 4, 256));
        assert_eq!(s.capacity_signature(), vec![128, 256]);
        assert!(s.matches_capacity_signature(&[128, 256]));
        assert!(!s.matches_capacity_signature(&[128]));
        assert!(!s.matches_capacity_signature(&[128, 512]));
    }

    #[test]
    fn residency_tracking() {
        let mut s = Session::new(3, vec![1, 2], 1);
        assert!(s.is_fully_hot(), "no layers yet is trivially hot");
        s.residency = vec![Residency::Hot, Residency::Warm];
        assert!(!s.is_fully_hot());
        s.residency[1] = Residency::Hot;
        assert!(s.is_fully_hot());
    }
}
