//! L3 coordinator: the serving side of the paper.
//!
//! * [`engine`] — layer-wise prefill with cascading compression
//!   (Algorithm 2), the decode loop, and per-policy budget handling.
//! * [`session`] — per-request state: token ids, per-layer caches, metrics.
//! * [`scheduler`] — continuous-batching scheduler: admission control by
//!   KV-memory budget, prefill/decode interleaving, fairness.
//! * [`batcher`] — request queue + grouping by shape bucket.
//! * [`server`] — JSON-lines TCP front-end over the engine.
//! * [`metrics`] — latency/memory counters (the quantities Fig. 3 plots).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod session;

pub use engine::{Engine, EngineOptions, FinishStatus, GenerateRequest, GenerateResult};
pub use scheduler::{Scheduler, SchedulerOptions, SubmitError};
