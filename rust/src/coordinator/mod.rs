//! L3 coordinator: the serving side of the paper.
//!
//! * [`engine`] — layer-wise prefill with cascading compression
//!   (Algorithm 2), the serial + batched decode paths, and per-policy
//!   budget handling.
//! * [`session`] — per-request state: token ids, per-layer caches, metrics.
//! * [`scheduler`] — continuous-batching scheduler: admission control by
//!   KV-memory budget, prefill/decode interleaving, fairness, hot/warm
//!   tiering, and capacity-bucket decode grouping.
//! * [`batcher`] — request queue + grouping by shape bucket.
//! * [`server`] — JSON-lines TCP front-end over the engine.
//! * [`metrics`] — latency/memory counters (the quantities Fig. 3 plots),
//!   plus serving gauges: tier traffic, batch occupancy, per-bucket decode
//!   dispatches.
//!
//! ## Batched decode data flow
//!
//! Each `decode_round` advances every active session by one token with as
//! few backend dispatches as the active set allows:
//!
//! 1. **group** — fully-hot sessions sharing a capacity signature (equal
//!    per-layer cache capacities) are packed into bucket groups; sessions
//!    with spilled layers are prefetched and stepped on the serial path so
//!    they never block a group.
//! 2. **gather** — per group, the engine embeds each member's last token
//!    host-side into one [B, d] residual-stream tensor.
//! 3. **dispatch** — per layer, one `layer_decode_batched_{M}x{B}` call
//!    executes over a zero-copy packed view of the B caches: L dispatches
//!    per group per round instead of B·L.
//! 4. **scatter** — each session's attention row feeds its own cache
//!    maintenance (score update, append, decode eviction) independently;
//!    LAVa's layer-level scoring keeps eviction state per-session, so the
//!    batched and serial paths are bit-identical per session.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod session;

pub use engine::{Engine, EngineOptions, FinishStatus, GenerateRequest, GenerateResult};
pub use scheduler::{Scheduler, SchedulerOptions, SubmitError};
