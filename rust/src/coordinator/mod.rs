//! L3 coordinator: the serving side of the paper.
//!
//! * [`engine`] — the engine front (`Engine`: backend + options + metrics +
//!   session ids) and the shareable `EngineWorker` compute view: layer-wise
//!   prefill with cascading compression (Algorithm 2), the serial + batched
//!   decode paths, and per-policy budget handling.
//! * [`pool`] — `WorkerPool`: persistent worker threads fed per-tick unit
//!   plans through an injector (spawn-free fan-out, dynamic work stealing),
//!   each owning a `WorkerContext` (stable id, pinned device slot, reusable
//!   scratch); `LAVA_POOL=scoped` keeps the legacy per-round
//!   `thread::scope` dispatcher as a bit-equivalence oracle.
//! * [`session`] — per-request state: token ids, per-layer caches, metrics.
//! * [`scheduler`] — continuous-batching scheduler: admission control by
//!   KV-memory budget, prefill/decode interleaving, fairness, hot/warm
//!   tiering, capacity-bucket decode grouping, and the round planner that
//!   feeds the pool.
//! * [`batcher`] — request queue + grouping by shape bucket.
//! * [`serve_loop`] — the continuous serving loop: a dedicated thread owns
//!   the scheduler, drains a submit-queue of commands, and drives one
//!   [`scheduler::Scheduler::tick`] at a time, pushing per-token and
//!   terminal events to subscriber sinks.
//! * [`server`] — JSON-lines TCP front-end over the serving loop.
//! * [`metrics`] — latency/memory counters (the quantities Fig. 3 plots),
//!   plus serving gauges: tier traffic, batch occupancy, per-bucket decode
//!   dispatches, worker utilization, pool queue depth / per-worker pulled
//!   units / park churn / dispatch overhead, tier-thread queue depths,
//!   in-flight session/queue gauges, and streamed-token counts.
//!
//! ## Serving architecture: acceptor → command channel → serving thread → pool
//!
//! ```text
//!  TCP clients ──► acceptor (Server::serve)
//!                    │ one reader + one writer thread per connection
//!                    ▼
//!  ServeHandle ──► command channel ──► serving thread (serve_loop)
//!   submit/cancel/metrics/shutdown        │ owns the Scheduler
//!                                         │ tick(): admit → prefill →
//!                                         ▼         decode round
//!                                   WorkerPool fan-out + tier thread
//! ```
//!
//! Connection readers parse protocol lines and submit into the shared loop
//! through a cloneable [`serve_loop::ServeHandle`]; each request's events
//! (per-token lines for `"stream": true` subscribers, then the terminal
//! result) flow back to that connection's writer thread, so responses from
//! many interleaved requests never corrupt each other mid-line. The
//! serving thread alternates command handling with single scheduler ticks:
//! cancels land at the next tick boundary (releasing hot + warm bytes),
//! `metrics` replies with a [`metrics::MetricsSnapshot`] copy instead of
//! stopping the world, and `shutdown` drains in-flight sessions while
//! rejecting queued and new work. `Scheduler::run_to_completion` remains a
//! thin loop over `tick()` for embedders and benches that drive the
//! scheduler directly.
//!
//! ## Scheduler → pool → worker data flow
//!
//! Each `decode_round` advances every active session by one token in two
//! phases:
//!
//! 1. **Plan** (serving thread; deterministic, worker-count independent) —
//!    fully-hot sessions sharing a capacity signature (equal per-layer
//!    cache capacities) are packed into bucket-group units; with
//!    `batched_decode` off they become singleton units. Sessions with
//!    spilled layers go to a *sequential arm* instead, and every spilled
//!    layer gets a prefetch-ahead hint (see below). Under a hot-tier limit
//!    the planner reserves one-step append headroom for the whole parallel
//!    stage, spilling victims from the sequential arm (demoting units when
//!    that cannot cover).
//! 2. **Run** — the planned units are *submitted* to the persistent
//!    [`pool::WorkerPool`]: the round lands in an injector (an atomic
//!    cursor over the unit list) and the parked workers are woken. Each
//!    worker pulls the next un-taken unit index off the injector —
//!    dynamic scheduling, so a slow unit never strands the rest of the
//!    plan behind it — and advances it through an `EngineWorker`
//!    (`&backend`, `&options`) with its own long-lived
//!    [`pool::WorkerContext`]: a stable worker id, a backend device slot
//!    bound once per thread (`ModelBackend::bind_device`), and reusable
//!    scoring/dequant scratch. A decode unit gathers last tokens → one
//!    `layer_decode_batched_{M}x{B}` dispatch per layer → scatters into
//!    per-session score update/append/eviction — returning a
//!    `StepReport`. Every result is written into a pre-sized slot at the
//!    unit's *plan index* (a panicked unit writes `Err` and the pool
//!    keeps serving; the scheduler fails that request and moves on), so
//!    the serving thread merges reports in plan order and tokens,
//!    evictions, and metric totals are bit-identical at any worker count
//!    and in both pool modes. Prefill batches and streaming lockstep
//!    groups submit to the same pool; single-session arms run through the
//!    pool's serial context (`with_serial_ctx`, worker slot 0). The
//!    sequential arm then steps in order: tier fetch (blocking only on
//!    staging misses), per-session decode, victim spills as needed.
//!
//! ## Tier-thread handoff protocol
//!
//! The scheduler's `TierClient` keeps all residency bookkeeping and byte
//! accounting synchronously on the serving thread — decisions never wait on
//! I/O — while the Q8 quantize/dequantize runs on a background tier thread
//! processing commands FIFO:
//!
//! * **spill** takes the hot buffers immediately (hot accounting drops at
//!   the decision point) and enqueues the quantization;
//! * **prefetch-ahead** hints dequantize into a staging map while decode
//!   runs — issued at round planning for this round's sequential arm and at
//!   round end for next round's spilled sessions (double buffering);
//! * **fetch** is the only blocking call, right before a session's step,
//!   and usually returns a staged store instantly;
//! * **drop** releases a retired session's blocks and staged stores.
//!
//! FIFO processing makes the handoff race-free: a fetch enqueued after a
//! spill of the same (session, layer) always observes the block.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pool;
pub mod scheduler;
pub mod serve_loop;
pub mod server;
pub mod session;

pub use engine::{
    Engine, EngineOptions, EngineWorker, FinishStatus, GenerateRequest, GenerateResult,
    PrefillReport, StepReport,
};
pub use metrics::MetricsSnapshot;
pub use pool::{PoolMode, WorkerContext, WorkerPool};
pub use scheduler::{Scheduler, SchedulerOptions, SubmitError, TickReport};
pub use serve_loop::{Event, ServeHandle, SubmitItem};
