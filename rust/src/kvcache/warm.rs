//! Warm tier: spilled layer caches as Q8-quantized host blocks.
//!
//! A [`WarmBlock`] is the dehydrated form of a [`HotStore`]: only the live
//! compact prefix of each head is kept (no padding), K/V rows are quantized
//! to symmetric int8 with one f32 scale per (head, entry) row — "scale-per-
//! head blockwise", block = one entry's `d_head` values — while positions,
//! scores, head lengths, and the original hot capacity are preserved
//! exactly, so rehydration restores a hot cache the decode path can keep
//! appending into.
//!
//! ## Round-trip tolerance contract
//!
//! For a quantization block with max-abs value `m`, every dehydrate →
//! rehydrate round trip satisfies `|x - x'| <= q8_tolerance(m)` (scale =
//! m/127, rounding error <= scale/2). Because the block max itself
//! quantizes to ±127 exactly, the scale is a fixed point of the round trip:
//! repeated spill/prefetch cycles of an unchanged layer do not accumulate
//! additional error beyond the first trip.

use super::hot::HotStore;
use super::KvTierStore;

/// Quantization levels of symmetric int8 (zero-point 0).
pub const Q8_LEVELS: f32 = 127.0;

/// Max absolute round-trip error for one quantization block whose max-abs
/// input value is `block_max_abs` — the documented Q8 tolerance. The
/// rounding bound is scale/2 = max/254; the extra relative term absorbs
/// f32 arithmetic error in the quantize/dequantize pair itself.
pub fn q8_tolerance(block_max_abs: f32) -> f32 {
    block_max_abs / (2.0 * Q8_LEVELS) + block_max_abs * 1e-5 + 1e-6
}

/// Quantize one block (an entry's `d_head` row) into a preallocated code
/// slice of the same length; returns the scale. Allocation-free so both the
/// spill path and the streaming-prefill Q8 carry can run it per-row in hot
/// loops without growing a `Vec` per block.
pub fn quantize_block_into(src: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), out.len(), "code slice must match the block");
    let max = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max == 0.0 {
        out.fill(0);
        return 0.0;
    }
    let scale = max / Q8_LEVELS;
    for (o, &x) in out.iter_mut().zip(src) {
        *o = (x / scale).round().clamp(-Q8_LEVELS, Q8_LEVELS) as i8;
    }
    scale
}

/// Dequantize one block into a preallocated f32 slice (the inverse of
/// [`quantize_block_into`], same allocation-free contract).
pub fn dequantize_block_into(codes: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len(), "output slice must match the block");
    for (o, &q) in out.iter_mut().zip(codes) {
        *o = scale * q as f32;
    }
}

/// One spilled layer cache. Entries are stored compactly in head order:
/// head 0's `head_len[0]` entries, then head 1's, and so on.
#[derive(Debug, Clone)]
pub struct WarmBlock {
    n_kv_heads: usize,
    d_head: usize,
    /// Hot capacity to restore on rehydration (decode headroom survives the
    /// round trip).
    capacity: usize,
    head_len: Vec<usize>,
    k_q: Vec<i8>,
    v_q: Vec<i8>,
    /// One scale per live entry row, K and V separately.
    k_scales: Vec<f32>,
    v_scales: Vec<f32>,
    positions: Vec<i32>,
    scores: Vec<f32>,
    /// Hot live bytes this block rehydrates to (prefetch sizing).
    hot_live_bytes: usize,
}

impl WarmBlock {
    /// Dehydrate a hot cache into a Q8 warm block (the hot cache is not
    /// modified; the tier manager owns the replace-with-empty step).
    pub fn from_hot(hot: &HotStore) -> WarmBlock {
        let hk = hot.n_kv_heads();
        let dh = hot.d_head();
        let total = hot.total_entries();
        let mut block = WarmBlock {
            n_kv_heads: hk,
            d_head: dh,
            capacity: hot.capacity(),
            head_len: (0..hk).map(|h| hot.head_len(h)).collect(),
            // pre-sized code buffers: each entry quantizes straight into its
            // slice (no per-block push growth on the spill hot loop)
            k_q: vec![0i8; total * dh],
            v_q: vec![0i8; total * dh],
            k_scales: Vec::with_capacity(total),
            v_scales: Vec::with_capacity(total),
            positions: Vec::with_capacity(total),
            scores: Vec::with_capacity(total),
            hot_live_bytes: hot.live_bytes(),
        };
        let mut entry = 0usize;
        for h in 0..hk {
            for i in 0..hot.head_len(h) {
                let codes = entry * dh..(entry + 1) * dh;
                block
                    .k_scales
                    .push(quantize_block_into(hot.key(h, i), &mut block.k_q[codes.clone()]));
                block
                    .v_scales
                    .push(quantize_block_into(hot.value(h, i), &mut block.v_q[codes]));
                block.positions.push(hot.position(h, i));
                block.scores.push(hot.score(h, i));
                entry += 1;
            }
        }
        debug_assert_eq!(
            block.warm_bytes(),
            projected_warm_bytes(hot.total_entries(), dh, hk),
            "projected_warm_bytes drifted from the real block layout"
        );
        block
    }

    /// Rehydrate into a hot cache with the original capacity, head lengths,
    /// positions, and scores; K/V within the Q8 tolerance.
    pub fn to_hot(&self) -> HotStore {
        let dh = self.d_head;
        let mut hot = HotStore::new(self.n_kv_heads, dh, self.capacity);
        let mut krow = vec![0.0f32; dh];
        let mut vrow = vec![0.0f32; dh];
        let mut entry = 0usize;
        for h in 0..self.n_kv_heads {
            for _ in 0..self.head_len[h] {
                let codes = entry * dh..(entry + 1) * dh;
                dequantize_block_into(&self.k_q[codes.clone()], self.k_scales[entry], &mut krow);
                dequantize_block_into(&self.v_q[codes], self.v_scales[entry], &mut vrow);
                hot.push_entry(h, &krow, &vrow, self.positions[entry], self.scores[entry]);
                entry += 1;
            }
        }
        hot
    }

    /// Hot live bytes this block rehydrates to (what prefetch must fit
    /// under the hot-tier limit).
    pub fn hot_live_bytes(&self) -> usize {
        self.hot_live_bytes
    }

    /// Warm-tier bytes this block occupies: int8 codes + f32 scales +
    /// positions + scores + head lengths.
    pub fn warm_bytes(&self) -> usize {
        self.k_q.len()
            + self.v_q.len()
            + (self.k_scales.len() + self.v_scales.len() + self.scores.len()) * 4
            + self.positions.len() * 4
            + self.head_len.len() * 8
    }
}

/// Warm bytes a hot cache with this shape dehydrates to, computable without
/// quantizing: per entry, 2·d_head int8 codes + two f32 scales + one f32
/// score + one i32 position, plus 8 B of head-length metadata per kv head.
/// The tier *client* charges this synchronously at the spill decision while
/// the actual quantization runs on the tier thread; `WarmBlock::from_hot`
/// debug-asserts the two agree.
pub fn projected_warm_bytes(total_entries: usize, d_head: usize, n_kv_heads: usize) -> usize {
    total_entries * (2 * d_head + 16) + n_kv_heads * 8
}

/// Q8-quantized compacted carry for chunk-major streaming prefill: between
/// chunk passes each layer's live carry columns are held as int8 codes plus
/// one f32 scale per (kv head, column) K/V row — the same blockwise layout
/// and [`q8_tolerance`] contract as [`WarmBlock`] — instead of f32 rows.
/// Codes live in fixed `[Hk, cap, dh]`-shaped buffers (flat in prompt
/// length); the engine dequantizes the live columns into a shared f32
/// scratch at dispatch and re-quantizes only the columns a chunk appended.
/// Columns that survive a mid-prefill eviction move with
/// [`Q8Carry::copy_col`] — codes and scales verbatim, so repeated evict
/// cascades never compound quantization error (the block max is a fixed
/// point of the round trip, as documented above).
#[derive(Debug, Clone)]
pub struct Q8Carry {
    n_kv_heads: usize,
    d_head: usize,
    cap: usize,
    /// `[Hk * cap * dh]` codes, column-major within each head like the f32
    /// carry tensors they mirror.
    k_q: Vec<i8>,
    v_q: Vec<i8>,
    /// `[Hk * cap]` scales, one per (head, column) row.
    k_scales: Vec<f32>,
    v_scales: Vec<f32>,
}

impl Q8Carry {
    pub fn new(n_kv_heads: usize, d_head: usize, cap: usize) -> Q8Carry {
        Q8Carry {
            n_kv_heads,
            d_head,
            cap,
            k_q: vec![0i8; n_kv_heads * cap * d_head],
            v_q: vec![0i8; n_kv_heads * cap * d_head],
            k_scales: vec![0.0; n_kv_heads * cap],
            v_scales: vec![0.0; n_kv_heads * cap],
        }
    }

    /// Quantize columns `[col0, col1)` of an `[Hk, cap, dh]` f32 carry pair
    /// into this block (every kv head).
    pub fn quantize_cols(&mut self, col0: usize, col1: usize, k: &[f32], v: &[f32]) {
        let (dh, cap) = (self.d_head, self.cap);
        debug_assert!(col1 <= cap, "columns {col1} overflow the cap {cap}");
        for kv in 0..self.n_kv_heads {
            for col in col0..col1 {
                let row = (kv * cap + col) * dh;
                self.k_scales[kv * cap + col] =
                    quantize_block_into(&k[row..row + dh], &mut self.k_q[row..row + dh]);
                self.v_scales[kv * cap + col] =
                    quantize_block_into(&v[row..row + dh], &mut self.v_q[row..row + dh]);
            }
        }
    }

    /// Dequantize the first `n_live` columns into an `[Hk, cap, dh]` f32
    /// carry pair (the dispatch scratch); columns past `n_live` are left
    /// untouched — contractually unread by the backend.
    pub fn dequantize_cols(&self, n_live: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        let (dh, cap) = (self.d_head, self.cap);
        debug_assert!(n_live <= cap, "live columns {n_live} overflow the cap {cap}");
        for kv in 0..self.n_kv_heads {
            for col in 0..n_live {
                let row = (kv * cap + col) * dh;
                dequantize_block_into(
                    &self.k_q[row..row + dh],
                    self.k_scales[kv * cap + col],
                    &mut k_out[row..row + dh],
                );
                dequantize_block_into(
                    &self.v_q[row..row + dh],
                    self.v_scales[kv * cap + col],
                    &mut v_out[row..row + dh],
                );
            }
        }
    }

    /// Move one column's codes and scales (every kv head) from `src` to
    /// `dst` — exact, no re-quantization. Eviction compaction calls this for
    /// ascending `dst <= src`, so moves never clobber a yet-unread source.
    pub fn copy_col(&mut self, dst: usize, src: usize) {
        if dst == src {
            return;
        }
        let (dh, cap) = (self.d_head, self.cap);
        for kv in 0..self.n_kv_heads {
            let s = (kv * cap + src) * dh;
            let d = (kv * cap + dst) * dh;
            self.k_q.copy_within(s..s + dh, d);
            self.v_q.copy_within(s..s + dh, d);
            self.k_scales[kv * cap + dst] = self.k_scales[kv * cap + src];
            self.v_scales[kv * cap + dst] = self.v_scales[kv * cap + src];
        }
    }

    /// Q8 bytes held for `n_live` columns: K+V codes plus f32 scales.
    pub fn live_bytes(&self, n_live: usize) -> usize {
        2 * self.n_kv_heads * n_live * (self.d_head + 4)
    }

    /// Bytes of the fixed-cap buffers (what actually stays resident).
    pub fn allocated_bytes(&self) -> usize {
        self.live_bytes(self.cap)
    }
}

impl KvTierStore for WarmBlock {
    fn n_kv_heads(&self) -> usize {
        self.n_kv_heads
    }

    fn d_head(&self) -> usize {
        self.d_head
    }

    fn total_entries(&self) -> usize {
        self.head_len.iter().sum()
    }

    fn tier_bytes(&self) -> usize {
        self.warm_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_hot(rng: &mut Rng) -> HotStore {
        let hk = 1 + rng.below(4);
        let dh = 2 + rng.below(6);
        let cap = 8 + rng.below(24);
        let n = 4 + rng.below(cap - 2);
        let kdata: Vec<f32> = (0..hk * n * dh).map(|_| rng.normal() as f32).collect();
        let vdata: Vec<f32> = (0..hk * n * dh).map(|_| rng.normal() as f32).collect();
        let k = Tensor::f32(kdata, &[hk, n, dh]);
        let v = Tensor::f32(vdata, &[hk, n, dh]);
        let mut keeps = Vec::new();
        let mut scs = Vec::new();
        for _ in 0..hk {
            let cnt = 1 + rng.below(n);
            let idx = rng.sample_indices(n, cnt);
            scs.push(idx.iter().map(|_| rng.f32()).collect::<Vec<_>>());
            keeps.push(idx);
        }
        let mut c = HotStore::new(hk, dh, cap);
        c.load_from_prefill(&k, &v, &keeps, &scs);

        // random op sequence so round trips are exercised on post-eviction,
        // post-append states, not just fresh prefill loads
        for step in 0..12 {
            match rng.below(3) {
                0 => {
                    let kn: Vec<f32> = (0..hk * dh).map(|_| rng.f32()).collect();
                    let vn: Vec<f32> = (0..hk * dh).map(|_| rng.f32()).collect();
                    c.append(&kn, &vn, (n + step) as i32, rng.f32());
                }
                1 => {
                    let mut keep = Vec::new();
                    for h in 0..hk {
                        let l = c.head_len(h);
                        keep.push(if l == 0 {
                            vec![]
                        } else {
                            rng.sample_indices(l, 1 + rng.below(l))
                        });
                    }
                    c.re_evict(&keep);
                }
                _ => {
                    let h = rng.below(hk);
                    if c.head_len(h) > 0 {
                        let idx = rng.below(c.head_len(h));
                        c.remove_one(h, idx);
                    }
                }
            }
        }
        c
    }

    fn max_abs(xs: &[f32]) -> f32 {
        xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    #[test]
    fn prop_spill_prefetch_round_trip() {
        prop::check(60, |rng| {
            let hot = random_hot(rng);
            let block = WarmBlock::from_hot(&hot);
            let back = block.to_hot();

            prop::assert_prop(
                back.check_invariants().is_ok(),
                "rehydrated invariants",
                &back.total_entries(),
            )?;
            prop::assert_prop(
                back.capacity() == hot.capacity(),
                "capacity preserved",
                &(back.capacity(), hot.capacity()),
            )?;
            prop::assert_prop(
                block.hot_live_bytes() == hot.live_bytes(),
                "hot byte accounting",
                &(block.hot_live_bytes(), hot.live_bytes()),
            )?;
            for h in 0..hot.n_kv_heads() {
                prop::assert_prop(
                    back.head_len(h) == hot.head_len(h),
                    "head_len preserved",
                    &(h, back.head_len(h), hot.head_len(h)),
                )?;
                for i in 0..hot.head_len(h) {
                    let pos_ok = back.position(h, i) == hot.position(h, i);
                    prop::assert_prop(pos_ok, "positions exact", &(h, i))?;
                    let score_ok = back.score(h, i) == hot.score(h, i);
                    prop::assert_prop(score_ok, "scores exact", &(h, i))?;
                    let ktol = q8_tolerance(max_abs(hot.key(h, i)));
                    let vtol = q8_tolerance(max_abs(hot.value(h, i)));
                    for j in 0..hot.d_head() {
                        let kd = (back.key(h, i)[j] - hot.key(h, i)[j]).abs();
                        let vd = (back.value(h, i)[j] - hot.value(h, i)[j]).abs();
                        prop::assert_prop(kd <= ktol, "K within Q8 tol", &(h, i, j, kd, ktol))?;
                        prop::assert_prop(vd <= vtol, "V within Q8 tol", &(h, i, j, vd, vtol))?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_q8_carry_round_trip() {
        // the streaming-prefill carry form obeys the same tolerance contract
        // as warm blocks: one trip within q8_tolerance per row, survivor
        // moves exact, and re-quantizing a dequantized column reproduces it
        prop::check(60, |rng| {
            let hk = 1 + rng.below(4);
            let dh = 2 + rng.below(14);
            let cap = 8 + rng.below(56);
            let n_live = 1 + rng.below(cap);
            let k: Vec<f32> = (0..hk * cap * dh).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..hk * cap * dh).map(|_| rng.normal() as f32).collect();
            let mut q8 = Q8Carry::new(hk, dh, cap);
            q8.quantize_cols(0, n_live, &k, &v);
            let mut k1 = vec![0.0f32; hk * cap * dh];
            let mut v1 = vec![0.0f32; hk * cap * dh];
            q8.dequantize_cols(n_live, &mut k1, &mut v1);
            for kv in 0..hk {
                for col in 0..n_live {
                    let row = (kv * cap + col) * dh;
                    let ktol = q8_tolerance(max_abs(&k[row..row + dh]));
                    let vtol = q8_tolerance(max_abs(&v[row..row + dh]));
                    for j in row..row + dh {
                        prop::assert_prop(
                            (k1[j] - k[j]).abs() <= ktol,
                            "K within Q8 tol",
                            &(kv, col, k[j], k1[j], ktol),
                        )?;
                        prop::assert_prop(
                            (v1[j] - v[j]).abs() <= vtol,
                            "V within Q8 tol",
                            &(kv, col, v[j], v1[j], vtol),
                        )?;
                    }
                }
            }
            // survivor compaction: moving the last live column to the front
            // is bitwise (codes and scales copy verbatim)
            let mut moved = q8.clone();
            moved.copy_col(0, n_live - 1);
            let mut k2 = vec![0.0f32; hk * cap * dh];
            let mut v2 = vec![0.0f32; hk * cap * dh];
            moved.dequantize_cols(n_live, &mut k2, &mut v2);
            for kv in 0..hk {
                let src = (kv * cap + n_live - 1) * dh;
                let dst = kv * cap * dh;
                for j in 0..dh {
                    prop::assert_prop(
                        k2[dst + j] == k1[src + j] && v2[dst + j] == v1[src + j],
                        "copy_col exact",
                        &(kv, j),
                    )?;
                }
            }
            // idempotence: a second quantize of the dequantized columns is a
            // fixed point up to float-product noise (far below one step)
            let mut again = Q8Carry::new(hk, dh, cap);
            again.quantize_cols(0, n_live, &k1, &v1);
            let mut k3 = vec![0.0f32; hk * cap * dh];
            let mut v3 = vec![0.0f32; hk * cap * dh];
            again.dequantize_cols(n_live, &mut k3, &mut v3);
            for kv in 0..hk {
                for col in 0..n_live {
                    let row = (kv * cap + col) * dh;
                    for j in row..row + dh {
                        let drift_ok = (k3[j] - k1[j]).abs() <= k1[j].abs() * 1e-5 + 1e-6
                            && (v3[j] - v1[j]).abs() <= v1[j].abs() * 1e-5 + 1e-6;
                        prop::assert_prop(drift_ok, "round trips do not drift", &(kv, col))?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn repeated_round_trips_do_not_drift() {
        // quantizing an already-dequantized row reproduces the same codes
        // (the block max is a fixed point), so only float-product noise —
        // a few ulps, far below one quantization step — may remain
        let mut rng = Rng::new(11);
        let hot = random_hot(&mut rng);
        let once = WarmBlock::from_hot(&hot).to_hot();
        let twice = WarmBlock::from_hot(&once).to_hot();
        for h in 0..hot.n_kv_heads() {
            for i in 0..hot.head_len(h) {
                for j in 0..hot.d_head() {
                    let a = once.key(h, i)[j];
                    let b = twice.key(h, i)[j];
                    assert!((a - b).abs() <= a.abs() * 1e-5 + 1e-6, "K drift: {a} vs {b}");
                    let a = once.value(h, i)[j];
                    let b = twice.value(h, i)[j];
                    assert!((a - b).abs() <= a.abs() * 1e-5 + 1e-6, "V drift: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn warm_is_smaller_than_hot() {
        // model-shaped dims (d_head 16): per entry, Q8 stores 2*dh codes +
        // 8 B scales + 8 B position/score vs 2*dh*4 B live f32 in hot
        let mut rng = Rng::new(7);
        let mut hot = HotStore::new(4, 16, 32);
        for p in 0..20 {
            let kn: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let vn: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            hot.append(&kn, &vn, p, rng.f32());
        }
        let block = WarmBlock::from_hot(&hot);
        assert!(
            block.warm_bytes() < hot.live_bytes(),
            "warm {} must beat hot live {}",
            block.warm_bytes(),
            hot.live_bytes()
        );
        assert!(block.warm_bytes() < hot.allocated_bytes());
        assert_eq!(block.total_entries(), hot.total_entries());
    }

    #[test]
    fn projected_warm_bytes_matches_real_blocks() {
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            let hot = random_hot(&mut rng);
            let block = WarmBlock::from_hot(&hot);
            assert_eq!(
                block.warm_bytes(),
                projected_warm_bytes(hot.total_entries(), hot.d_head(), hot.n_kv_heads()),
                "client-side projection must match the quantized block"
            );
        }
    }

    #[test]
    fn zero_block_is_exact() {
        let mut hot = HotStore::new(1, 4, 4);
        hot.push_entry(0, &[0.0; 4], &[0.0; 4], 0, 0.5);
        let back = WarmBlock::from_hot(&hot).to_hot();
        assert_eq!(back.key(0, 0), &[0.0; 4]);
        assert_eq!(back.value(0, 0), &[0.0; 4]);
    }

    #[test]
    fn rehydrated_cache_accepts_appends() {
        let mut hot = HotStore::new(2, 2, 6);
        hot.append(&[1.0, -2.0, 0.5, 3.0], &[0.1, 0.2, 0.3, 0.4], 0, 1.0);
        let mut back = WarmBlock::from_hot(&hot).to_hot();
        assert!(back.append(&[1.0; 4], &[2.0; 4], 1, 0.5), "capacity must survive");
        assert_eq!(back.head_len(0), 2);
        back.check_invariants().unwrap();
    }
}
