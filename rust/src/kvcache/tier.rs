//! Tier management: per-session, per-layer residency between the hot and
//! warm stores, with the Q8 quantize/dequantize work on a background thread.
//!
//! ## Residency state machine
//!
//! Every (session, layer) cache is in exactly one of two states:
//!
//! ```text
//!            spill (quantize to Q8, hot buffer replaced by empty)
//!   Hot ───────────────────────────────────────────────────────▶ Warm
//!    ▲                                                            │
//!    └────────────────────────────────────────────────────────────┘
//!            prefetch (dequantize into a fresh HotStore)
//! ```
//!
//! * `Hot` — the layer lives in a [`HotStore`]; the engine may decode
//!   against it. Its bytes count against `kv_mem_limit`.
//! * `Warm` — the layer lives in a [`WarmBlock`] owned by the tier side;
//!   the engine must never see it. Its (smaller, Q8) bytes count against
//!   the warm-tier accounting only.
//!
//! ## Two halves: client and thread
//!
//! [`TierClient`] lives on the serving thread and owns the *decisions and
//! accounting*: which (session, layer) pairs are warm, their exact hot and
//! warm byte sizes (warm sizes are projected from the cache shape via
//! [`super::warm::projected_warm_bytes`], which equals the real block size),
//! and the residency bookkeeping the scheduler's spill/prefetch policy
//! reads. Every client query is answered synchronously from this local
//! state, so scheduling decisions are deterministic — independent of what
//! the background thread has gotten around to.
//!
//! The *data movement* — Q8 quantization on spill, dequantization on
//! prefetch — runs on a dedicated tier thread owning a [`TierManager`].
//! The handoff protocol:
//!
//! * **Spill** — the client takes the hot buffers
//!   ([`HotStore::take_for_spill`]), charges the projected warm bytes, and
//!   enqueues the store; the thread quantizes it into a warm block later.
//! * **Prefetch-ahead** — a hint: the thread dequantizes the block into a
//!   *staging* map but the layer stays Warm to the client; issued by the
//!   scheduler for next-round sessions so rehydration overlaps decode
//!   (double buffering). Staged stores are host-side f32 duplicates of
//!   warm blocks — bounded by the hinted sessions' pending hot bytes and
//!   surfaced via the `staged_bytes` gauge; they never count against the
//!   hot-tier limit, which models serving memory.
//! * **Fetch** — the blocking transition Warm→Hot: the client sends a
//!   reply channel; the thread answers with the staged store (hit: the
//!   dequantization already happened under the previous round's decode) or
//!   dequantizes on the spot (miss). Commands are processed FIFO, so a
//!   fetch always observes the spill that preceded it.
//! * **Drop** — retire/cancel: releases the session's warm blocks and any
//!   staged stores.
//!
//! [`TierManager`] remains the synchronous storage core (the thread's state;
//! also usable directly by tests and single-threaded embedders).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::hot::HotStore;
use super::warm::{projected_warm_bytes, WarmBlock};

/// Which tier a (session, layer) cache currently lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    Hot,
    Warm,
}

/// Owns all warm blocks, keyed by (session id, layer).
#[derive(Debug, Default)]
pub struct TierManager {
    warm: HashMap<(u64, usize), WarmBlock>,
    warm_bytes: usize,
}

impl TierManager {
    pub fn new() -> TierManager {
        TierManager::default()
    }

    /// Current warm-tier bytes across all sessions.
    pub fn warm_bytes(&self) -> usize {
        self.warm_bytes
    }

    /// Number of spilled layers across all sessions.
    pub fn spilled_count(&self) -> usize {
        self.warm.len()
    }

    /// Layers of `session` currently in the warm tier, ascending.
    pub fn spilled_layers(&self, session: u64) -> Vec<usize> {
        let mut layers = Vec::new();
        for (s, layer) in self.warm.keys() {
            if *s == session {
                layers.push(*layer);
            }
        }
        layers.sort_unstable();
        layers
    }

    /// Hot bytes that prefetching all of `session`'s spilled layers would
    /// re-occupy (the scheduler's make-room target).
    pub fn pending_hot_bytes(&self, session: u64) -> usize {
        let mut bytes = 0;
        for ((s, _), block) in &self.warm {
            if *s == session {
                bytes += block.hot_live_bytes();
            }
        }
        bytes
    }

    /// Spill one layer: dehydrate `cache` into the warm tier and leave an
    /// empty zero-capacity hot store behind (so the session's hot byte
    /// accounting drops to zero for this layer). Returns the hot live bytes
    /// freed.
    pub fn spill(&mut self, session: u64, layer: usize, cache: &mut HotStore) -> usize {
        debug_assert!(
            !self.warm.contains_key(&(session, layer)),
            "layer {layer} of session {session} spilled twice"
        );
        let block = WarmBlock::from_hot(cache);
        let freed = cache.live_bytes();
        self.warm_bytes += block.warm_bytes();
        *cache = HotStore::new(cache.n_kv_heads(), cache.d_head(), 0);
        self.warm.insert((session, layer), block);
        freed
    }

    /// Prefetch one spilled layer back: rehydrate into a fresh hot store.
    /// Returns `None` if the layer is not in the warm tier.
    pub fn prefetch(&mut self, session: u64, layer: usize) -> Option<HotStore> {
        let block = self.warm.remove(&(session, layer))?;
        self.warm_bytes -= block.warm_bytes();
        Some(block.to_hot())
    }

    /// Drop every warm block of a retiring/canceled session; returns the
    /// warm bytes released.
    pub fn drop_session(&mut self, session: u64) -> usize {
        let mut keys = Vec::new();
        for key in self.warm.keys() {
            if key.0 == session {
                keys.push(*key);
            }
        }
        let mut released = 0;
        for key in keys {
            if let Some(block) = self.warm.remove(&key) {
                released += block.warm_bytes();
            }
        }
        self.warm_bytes -= released;
        released
    }
}

// ------------------------------------------------------------ tier thread

/// Commands the serving thread hands to the tier thread. FIFO processing is
/// the consistency contract: a `Fetch` enqueued after a `Spill` of the same
/// (session, layer) always finds the block.
enum TierCmd {
    Spill { session: u64, layer: usize, hot: HotStore },
    PrefetchAhead { session: u64, layer: usize },
    Fetch { session: u64, layer: usize, reply: Sender<Option<HotStore>> },
    Drop { session: u64 },
    Sync { reply: Sender<()> },
    Shutdown,
}

/// Gauges shared between the client and the tier thread. Queue depths are
/// incremented by the client at enqueue and decremented by the thread after
/// processing, so a sampled value is the true backlog at that instant.
#[derive(Debug, Default)]
pub struct TierThreadStats {
    spill_queue: AtomicUsize,
    prefetch_queue: AtomicUsize,
    /// f32 bytes held in the prefetch-ahead staging area. Staged stores are
    /// *host-side duplicates* on top of warm blocks — they are not hot-tier
    /// bytes (the limit models serving memory) but they are real RAM,
    /// bounded by the pending hot bytes of the hinted sessions, so they get
    /// their own gauge instead of hiding.
    staged_bytes: AtomicUsize,
    busy_nanos: AtomicU64,
}

/// One sampled view of the tier thread's gauges.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierThreadSnapshot {
    /// Spills enqueued but not yet quantized.
    pub spill_queue_depth: usize,
    /// Prefetch-ahead hints enqueued but not yet staged.
    pub prefetch_queue_depth: usize,
    /// Host-side f32 bytes currently parked in the staging area.
    pub staged_bytes: usize,
    /// Cumulative seconds the tier thread spent quantizing/dequantizing.
    pub busy_secs: f64,
}

fn run_tier_thread(rx: Receiver<TierCmd>, stats: Arc<TierThreadStats>) {
    let mut mgr = TierManager::new();
    // completed prefetch-aheads, waiting for the blocking fetch
    let mut staged: HashMap<(u64, usize), HotStore> = HashMap::new();
    while let Ok(cmd) = rx.recv() {
        let t0 = Instant::now();
        match cmd {
            TierCmd::Spill { session, layer, mut hot } => {
                mgr.spill(session, layer, &mut hot);
                stats.spill_queue.fetch_sub(1, Ordering::SeqCst);
            }
            TierCmd::PrefetchAhead { session, layer } => {
                if !staged.contains_key(&(session, layer)) {
                    if let Some(hot) = mgr.prefetch(session, layer) {
                        stats.staged_bytes.fetch_add(hot.live_bytes(), Ordering::SeqCst);
                        staged.insert((session, layer), hot);
                    }
                }
                stats.prefetch_queue.fetch_sub(1, Ordering::SeqCst);
            }
            TierCmd::Fetch { session, layer, reply } => {
                // staging hit: the dequantization already ran under the
                // previous decode; miss: pay it now, same result either way
                let hot = match staged.remove(&(session, layer)) {
                    Some(hot) => {
                        stats.staged_bytes.fetch_sub(hot.live_bytes(), Ordering::SeqCst);
                        Some(hot)
                    }
                    None => mgr.prefetch(session, layer),
                };
                let _ = reply.send(hot);
            }
            TierCmd::Drop { session } => {
                mgr.drop_session(session);
                staged.retain(|key, hot| {
                    if key.0 == session {
                        stats.staged_bytes.fetch_sub(hot.live_bytes(), Ordering::SeqCst);
                        false
                    } else {
                        true
                    }
                });
            }
            TierCmd::Sync { reply } => {
                let _ = reply.send(());
            }
            TierCmd::Shutdown => break,
        }
        stats
            .busy_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::SeqCst);
    }
}

/// Client-side byte accounting for one spilled layer.
#[derive(Debug, Clone, Copy)]
struct SpilledInfo {
    /// Hot live bytes the layer rehydrates to.
    hot_bytes: usize,
    /// Warm bytes the quantized block occupies (projected; exact).
    warm_bytes: usize,
}

/// Serving-thread handle to the tier: synchronous residency bookkeeping +
/// asynchronous data movement. Drop-in successor of the scheduler-owned
/// [`TierManager`]: same query surface (`warm_bytes`, `spilled_layers`,
/// `pending_hot_bytes`, ...), but `spill` only *takes* the buffers (the
/// quantization runs on the tier thread) and `fetch` blocks only when the
/// prefetch-ahead staging missed.
pub struct TierClient {
    tx: Sender<TierCmd>,
    thread: Option<JoinHandle<()>>,
    stats: Arc<TierThreadStats>,
    spilled: HashMap<(u64, usize), SpilledInfo>,
    warm_bytes: usize,
}

impl Default for TierClient {
    fn default() -> Self {
        TierClient::spawn()
    }
}

impl TierClient {
    /// Start the background tier thread and the client bookkeeping.
    pub fn spawn() -> TierClient {
        let (tx, rx) = channel();
        let stats = Arc::new(TierThreadStats::default());
        let thread_stats = Arc::clone(&stats);
        let thread = std::thread::Builder::new()
            .name("lava-tier".to_string())
            .spawn(move || run_tier_thread(rx, thread_stats))
            .expect("spawn tier thread");
        TierClient {
            tx,
            thread: Some(thread),
            stats,
            spilled: HashMap::new(),
            warm_bytes: 0,
        }
    }

    /// Current warm-tier bytes across all sessions (client accounting; the
    /// projection equals the quantized block sizes exactly).
    pub fn warm_bytes(&self) -> usize {
        self.warm_bytes
    }

    /// Number of spilled layers across all sessions.
    pub fn spilled_count(&self) -> usize {
        self.spilled.len()
    }

    /// Layers of `session` currently in the warm tier, ascending.
    pub fn spilled_layers(&self, session: u64) -> Vec<usize> {
        let mut layers = Vec::new();
        for key in self.spilled.keys() {
            if key.0 == session {
                layers.push(key.1);
            }
        }
        layers.sort_unstable();
        layers
    }

    /// Hot bytes that fetching all of `session`'s spilled layers would
    /// re-occupy (the scheduler's make-room target).
    pub fn pending_hot_bytes(&self, session: u64) -> usize {
        let mut bytes = 0;
        for (key, info) in &self.spilled {
            if key.0 == session {
                bytes += info.hot_bytes;
            }
        }
        bytes
    }

    /// Spill one layer: take the hot buffers (the cache is left empty, so
    /// the session's hot accounting drops immediately) and enqueue the Q8
    /// quantization on the tier thread. Returns the hot live bytes freed.
    pub fn spill(&mut self, session: u64, layer: usize, cache: &mut HotStore) -> usize {
        debug_assert!(
            !self.spilled.contains_key(&(session, layer)),
            "layer {layer} of session {session} spilled twice"
        );
        let freed = cache.live_bytes();
        let hot = cache.take_for_spill();
        let warm = projected_warm_bytes(hot.total_entries(), hot.d_head(), hot.n_kv_heads());
        self.spilled.insert((session, layer), SpilledInfo { hot_bytes: freed, warm_bytes: warm });
        self.warm_bytes += warm;
        self.stats.spill_queue.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(TierCmd::Spill { session, layer, hot })
            .expect("tier thread alive");
        freed
    }

    /// Double-buffering hint: start dequantizing a spilled layer into the
    /// tier thread's staging area. The layer stays Warm to all client
    /// queries — only [`TierClient::fetch`] transitions it — so issuing (or
    /// skipping) hints never changes a scheduling decision, only how long
    /// the eventual fetch blocks. No-op for layers that are not spilled.
    pub fn prefetch_ahead(&self, session: u64, layer: usize) {
        if !self.spilled.contains_key(&(session, layer)) {
            return;
        }
        self.stats.prefetch_queue.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(TierCmd::PrefetchAhead { session, layer })
            .expect("tier thread alive");
    }

    /// Blocking Warm→Hot transition: returns the rehydrated store (staged
    /// by a prior [`TierClient::prefetch_ahead`], or dequantized now).
    /// `None` if the layer is not spilled.
    pub fn fetch(&mut self, session: u64, layer: usize) -> Option<HotStore> {
        let info = self.spilled.remove(&(session, layer))?;
        self.warm_bytes -= info.warm_bytes;
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(TierCmd::Fetch { session, layer, reply: reply_tx })
            .expect("tier thread alive");
        let hot = reply_rx.recv().expect("tier thread alive");
        debug_assert!(hot.is_some(), "tracked spilled layer missing on the tier thread");
        hot
    }

    /// Drop every warm block of a retiring/canceled session (including any
    /// staged prefetches); returns the warm bytes released.
    pub fn drop_session(&mut self, session: u64) -> usize {
        let mut released = 0;
        self.spilled.retain(|(s, _), info| {
            if *s == session {
                released += info.warm_bytes;
                false
            } else {
                true
            }
        });
        self.warm_bytes -= released;
        if released > 0 {
            self.tx
                .send(TierCmd::Drop { session })
                .expect("tier thread alive");
        }
        released
    }

    /// Round-trip barrier: returns once the tier thread has drained every
    /// command enqueued before this call.
    pub fn sync(&self) {
        let (reply_tx, reply_rx) = channel();
        self.tx.send(TierCmd::Sync { reply: reply_tx }).expect("tier thread alive");
        reply_rx.recv().expect("tier thread alive");
    }

    /// Sample the tier thread's queue/busy gauges.
    pub fn thread_snapshot(&self) -> TierThreadSnapshot {
        TierThreadSnapshot {
            spill_queue_depth: self.stats.spill_queue.load(Ordering::SeqCst),
            prefetch_queue_depth: self.stats.prefetch_queue.load(Ordering::SeqCst),
            staged_bytes: self.stats.staged_bytes.load(Ordering::SeqCst),
            busy_secs: self.stats.busy_nanos.load(Ordering::SeqCst) as f64 * 1e-9,
        }
    }
}

impl Drop for TierClient {
    fn drop(&mut self) {
        // a dead thread already drained the channel; ignore the send error
        let _ = self.tx.send(TierCmd::Shutdown);
        if let Some(handle) = self.thread.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hot_with_entries(entries: usize) -> HotStore {
        let mut c = HotStore::new(2, 4, entries + 4);
        for p in 0..entries {
            let x = p as f32;
            c.append(&[x, -x, 1.0, 0.5, x, x, 2.0, -1.0], &[0.25; 8], p as i32, x);
        }
        c
    }

    #[test]
    fn spill_empties_hot_and_prefetch_restores() {
        let mut tm = TierManager::new();
        let mut cache = hot_with_entries(6);
        let bytes_before = cache.live_bytes();
        let freed = tm.spill(9, 2, &mut cache);
        assert_eq!(freed, bytes_before);
        assert_eq!(cache.live_bytes(), 0, "hot side must be empty after spill");
        assert_eq!(cache.capacity(), 0);
        assert!(tm.warm_bytes() > 0);
        assert_eq!(tm.spilled_layers(9), vec![2]);
        assert_eq!(tm.pending_hot_bytes(9), bytes_before);

        let back = tm.prefetch(9, 2).expect("layer was spilled");
        assert_eq!(back.live_bytes(), bytes_before);
        assert_eq!(back.head_len(0), 6);
        back.check_invariants().unwrap();
        assert_eq!(tm.warm_bytes(), 0);
        assert!(tm.prefetch(9, 2).is_none(), "double prefetch must miss");
    }

    #[test]
    fn drop_session_releases_only_that_session() {
        let mut tm = TierManager::new();
        let mut a0 = hot_with_entries(3);
        let mut a1 = hot_with_entries(4);
        let mut b0 = hot_with_entries(5);
        tm.spill(1, 0, &mut a0);
        tm.spill(1, 1, &mut a1);
        tm.spill(2, 0, &mut b0);
        assert_eq!(tm.spilled_count(), 3);
        assert_eq!(tm.spilled_layers(1), vec![0, 1]);

        let released = tm.drop_session(1);
        assert!(released > 0);
        assert_eq!(tm.spilled_count(), 1);
        assert!(tm.spilled_layers(1).is_empty());
        assert_eq!(tm.spilled_layers(2), vec![0]);
        assert_eq!(tm.drop_session(999), 0, "unknown session is a no-op");
    }

    #[test]
    fn warm_accounting_tracks_blocks() {
        let mut tm = TierManager::new();
        let mut c0 = hot_with_entries(8);
        let mut c1 = hot_with_entries(2);
        tm.spill(5, 0, &mut c0);
        let after_one = tm.warm_bytes();
        tm.spill(5, 1, &mut c1);
        assert!(tm.warm_bytes() > after_one);
        tm.prefetch(5, 1).unwrap();
        assert_eq!(tm.warm_bytes(), after_one);
        tm.drop_session(5);
        assert_eq!(tm.warm_bytes(), 0);
    }

    #[test]
    fn client_round_trip_matches_manager() {
        // the threaded client must hand back exactly what the synchronous
        // manager would: same Q8 round trip, same accounting
        let mut mgr = TierManager::new();
        let mut via_mgr = hot_with_entries(6);
        mgr.spill(1, 0, &mut via_mgr);
        let want = mgr.prefetch(1, 0).unwrap();

        let mut client = TierClient::spawn();
        let mut cache = hot_with_entries(6);
        let bytes_before = cache.live_bytes();
        let freed = client.spill(1, 0, &mut cache);
        assert_eq!(freed, bytes_before);
        assert_eq!(cache.live_bytes(), 0);
        assert_eq!(client.spilled_layers(1), vec![0]);
        assert_eq!(client.pending_hot_bytes(1), bytes_before);
        assert_eq!(client.warm_bytes(), mgr_warm_bytes_for(bytes_before, 6));

        let back = client.fetch(1, 0).expect("spilled layer");
        assert_eq!(back.head_len(0), want.head_len(0));
        for h in 0..2 {
            for i in 0..6 {
                assert_eq!(back.key(h, i), want.key(h, i), "head {h} slot {i}");
                assert_eq!(back.value(h, i), want.value(h, i));
                assert_eq!(back.position(h, i), want.position(h, i));
                assert_eq!(back.score(h, i).to_bits(), want.score(h, i).to_bits());
            }
        }
        assert_eq!(client.warm_bytes(), 0);
        assert_eq!(client.spilled_count(), 0);
        assert!(client.fetch(1, 0).is_none(), "double fetch must miss");
    }

    fn mgr_warm_bytes_for(_hot_bytes: usize, entries: usize) -> usize {
        // 2 heads × entries each; d_head 4
        crate::kvcache::warm::projected_warm_bytes(entries * 2, 4, 2)
    }

    #[test]
    fn prefetch_ahead_stages_without_changing_residency() {
        let mut client = TierClient::spawn();
        let mut cache = hot_with_entries(5);
        client.spill(7, 3, &mut cache);
        client.prefetch_ahead(7, 3);
        client.sync();
        // still warm to every client query: the hint is invisible to policy
        assert_eq!(client.spilled_layers(7), vec![3]);
        assert!(client.warm_bytes() > 0);
        let snap = client.thread_snapshot();
        assert_eq!(snap.spill_queue_depth, 0, "sync drains the queue");
        assert_eq!(snap.prefetch_queue_depth, 0);
        assert!(snap.staged_bytes > 0, "staged f32 duplicates must be visible");
        // the staged store is what the fetch returns
        let back = client.fetch(7, 3).expect("staged layer");
        assert_eq!(back.head_len(0), 5);
        back.check_invariants().unwrap();
        assert_eq!(client.warm_bytes(), 0);
        client.sync();
        assert_eq!(client.thread_snapshot().staged_bytes, 0, "fetch empties the staging area");
        // a hint for a non-spilled layer is a no-op
        client.prefetch_ahead(7, 3);
        client.sync();
        assert_eq!(client.thread_snapshot().prefetch_queue_depth, 0);
    }

    #[test]
    fn client_drop_session_releases_everything() {
        let mut client = TierClient::spawn();
        let mut a0 = hot_with_entries(3);
        let mut a1 = hot_with_entries(4);
        let mut b0 = hot_with_entries(5);
        client.spill(1, 0, &mut a0);
        client.spill(1, 1, &mut a1);
        client.spill(2, 0, &mut b0);
        client.prefetch_ahead(1, 1); // staged entries must be dropped too
        let released = client.drop_session(1);
        assert!(released > 0);
        assert_eq!(client.spilled_count(), 1);
        assert!(client.spilled_layers(1).is_empty());
        client.sync();
        assert!(client.fetch(1, 1).is_none(), "dropped layer must be gone");
        assert_eq!(client.drop_session(999), 0, "unknown session is a no-op");
        let last = client.drop_session(2);
        assert!(last > 0);
        assert_eq!(client.warm_bytes(), 0);
    }

    #[test]
    fn thread_busy_time_accumulates() {
        let mut client = TierClient::spawn();
        let mut cache = hot_with_entries(16);
        client.spill(1, 0, &mut cache);
        client.fetch(1, 0).unwrap();
        client.sync();
        assert!(client.thread_snapshot().busy_secs > 0.0);
    }
}
