//! Tier manager: per-session, per-layer residency between the hot and warm
//! stores.
//!
//! ## Residency state machine
//!
//! Every (session, layer) cache is in exactly one of two states:
//!
//! ```text
//!            spill (quantize to Q8, hot buffer replaced by empty)
//!   Hot ───────────────────────────────────────────────────────▶ Warm
//!    ▲                                                            │
//!    └────────────────────────────────────────────────────────────┘
//!            prefetch (dequantize into a fresh HotStore)
//! ```
//!
//! * `Hot` — the layer lives in a [`HotStore`]; the engine may decode
//!   against it. Its bytes count against `kv_mem_limit`.
//! * `Warm` — the layer lives in a [`WarmBlock`] owned by this manager; the
//!   engine must never see it. Its (smaller, Q8) bytes count against the
//!   warm-tier accounting only.
//!
//! The scheduler drives all transitions: it spills idle sessions'
//! lowest-LAVa-weight layers when projected hot bytes exceed the limit, and
//! prefetches a session's spilled layers before handing it to the engine.
//! The engine itself only ever sees hot caches (and asserts so at the hot
//! path boundary). A retiring session's warm blocks are dropped here.

use std::collections::HashMap;

use super::hot::HotStore;
use super::warm::WarmBlock;

/// Which tier a (session, layer) cache currently lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    Hot,
    Warm,
}

/// Owns all warm blocks, keyed by (session id, layer).
#[derive(Debug, Default)]
pub struct TierManager {
    warm: HashMap<(u64, usize), WarmBlock>,
    warm_bytes: usize,
}

impl TierManager {
    pub fn new() -> TierManager {
        TierManager::default()
    }

    /// Current warm-tier bytes across all sessions.
    pub fn warm_bytes(&self) -> usize {
        self.warm_bytes
    }

    /// Number of spilled layers across all sessions.
    pub fn spilled_count(&self) -> usize {
        self.warm.len()
    }

    /// Layers of `session` currently in the warm tier, ascending.
    pub fn spilled_layers(&self, session: u64) -> Vec<usize> {
        let mut layers = Vec::new();
        for (s, layer) in self.warm.keys() {
            if *s == session {
                layers.push(*layer);
            }
        }
        layers.sort_unstable();
        layers
    }

    /// Hot bytes that prefetching all of `session`'s spilled layers would
    /// re-occupy (the scheduler's make-room target).
    pub fn pending_hot_bytes(&self, session: u64) -> usize {
        let mut bytes = 0;
        for ((s, _), block) in &self.warm {
            if *s == session {
                bytes += block.hot_live_bytes();
            }
        }
        bytes
    }

    /// Spill one layer: dehydrate `cache` into the warm tier and leave an
    /// empty zero-capacity hot store behind (so the session's hot byte
    /// accounting drops to zero for this layer). Returns the hot live bytes
    /// freed.
    pub fn spill(&mut self, session: u64, layer: usize, cache: &mut HotStore) -> usize {
        debug_assert!(
            !self.warm.contains_key(&(session, layer)),
            "layer {layer} of session {session} spilled twice"
        );
        let block = WarmBlock::from_hot(cache);
        let freed = cache.live_bytes();
        self.warm_bytes += block.warm_bytes();
        *cache = HotStore::new(cache.n_kv_heads(), cache.d_head(), 0);
        self.warm.insert((session, layer), block);
        freed
    }

    /// Prefetch one spilled layer back: rehydrate into a fresh hot store.
    /// Returns `None` if the layer is not in the warm tier.
    pub fn prefetch(&mut self, session: u64, layer: usize) -> Option<HotStore> {
        let block = self.warm.remove(&(session, layer))?;
        self.warm_bytes -= block.warm_bytes();
        Some(block.to_hot())
    }

    /// Drop every warm block of a retiring/canceled session; returns the
    /// warm bytes released.
    pub fn drop_session(&mut self, session: u64) -> usize {
        let mut keys = Vec::new();
        for key in self.warm.keys() {
            if key.0 == session {
                keys.push(*key);
            }
        }
        let mut released = 0;
        for key in keys {
            if let Some(block) = self.warm.remove(&key) {
                released += block.warm_bytes();
            }
        }
        self.warm_bytes -= released;
        released
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hot_with_entries(entries: usize) -> HotStore {
        let mut c = HotStore::new(2, 4, entries + 4);
        for p in 0..entries {
            let x = p as f32;
            c.append(&[x, -x, 1.0, 0.5, x, x, 2.0, -1.0], &[0.25; 8], p as i32, x);
        }
        c
    }

    #[test]
    fn spill_empties_hot_and_prefetch_restores() {
        let mut tm = TierManager::new();
        let mut cache = hot_with_entries(6);
        let bytes_before = cache.live_bytes();
        let freed = tm.spill(9, 2, &mut cache);
        assert_eq!(freed, bytes_before);
        assert_eq!(cache.live_bytes(), 0, "hot side must be empty after spill");
        assert_eq!(cache.capacity(), 0);
        assert!(tm.warm_bytes() > 0);
        assert_eq!(tm.spilled_layers(9), vec![2]);
        assert_eq!(tm.pending_hot_bytes(9), bytes_before);

        let back = tm.prefetch(9, 2).expect("layer was spilled");
        assert_eq!(back.live_bytes(), bytes_before);
        assert_eq!(back.head_len(0), 6);
        back.check_invariants().unwrap();
        assert_eq!(tm.warm_bytes(), 0);
        assert!(tm.prefetch(9, 2).is_none(), "double prefetch must miss");
    }

    #[test]
    fn drop_session_releases_only_that_session() {
        let mut tm = TierManager::new();
        let mut a0 = hot_with_entries(3);
        let mut a1 = hot_with_entries(4);
        let mut b0 = hot_with_entries(5);
        tm.spill(1, 0, &mut a0);
        tm.spill(1, 1, &mut a1);
        tm.spill(2, 0, &mut b0);
        assert_eq!(tm.spilled_count(), 3);
        assert_eq!(tm.spilled_layers(1), vec![0, 1]);

        let released = tm.drop_session(1);
        assert!(released > 0);
        assert_eq!(tm.spilled_count(), 1);
        assert!(tm.spilled_layers(1).is_empty());
        assert_eq!(tm.spilled_layers(2), vec![0]);
        assert_eq!(tm.drop_session(999), 0, "unknown session is a no-op");
    }

    #[test]
    fn warm_accounting_tracks_blocks() {
        let mut tm = TierManager::new();
        let mut c0 = hot_with_entries(8);
        let mut c1 = hot_with_entries(2);
        tm.spill(5, 0, &mut c0);
        let after_one = tm.warm_bytes();
        tm.spill(5, 1, &mut c1);
        assert!(tm.warm_bytes() > after_one);
        tm.prefetch(5, 1).unwrap();
        assert_eq!(tm.warm_bytes(), after_one);
        tm.drop_session(5);
        assert_eq!(tm.warm_bytes(), 0);
    }
}
