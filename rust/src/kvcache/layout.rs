//! Slot arithmetic for the compact-prefix cache layout, shared by the hot
//! and warm stores.
//!
//! Layout invariant ("compact prefix"): for every kv head `h`, slots
//! `[0, head_len[h])` are live and slots `[head_len[h], capacity)` are empty.
//! Heads may have different lengths — that is exactly how AdaKV/LAVa dynamic
//! head budgets materialize. The hot store keeps this layout in padded
//! buffers (what `layer_decode_{M}` consumes directly); the warm store keeps
//! only the live prefix of each head, so both tiers agree on `head_len` and
//! per-head entry order even though their physical representations differ.

/// Dimensions + per-head occupancy of one layer cache. Owns no K/V data —
/// the stores hold the buffers; this holds the addressing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotLayout {
    n_kv_heads: usize,
    d_head: usize,
    capacity: usize,
    head_len: Vec<usize>,
}

impl SlotLayout {
    pub fn new(n_kv_heads: usize, d_head: usize, capacity: usize) -> SlotLayout {
        SlotLayout { n_kv_heads, d_head, capacity, head_len: vec![0; n_kv_heads] }
    }

    pub fn n_kv_heads(&self) -> usize {
        self.n_kv_heads
    }

    pub fn d_head(&self) -> usize {
        self.d_head
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn head_len(&self, h: usize) -> usize {
        self.head_len[h]
    }

    pub fn set_head_len(&mut self, h: usize, len: usize) {
        debug_assert!(len <= self.capacity);
        self.head_len[h] = len;
    }

    pub fn head_lens(&self) -> &[usize] {
        &self.head_len
    }

    pub fn total_entries(&self) -> usize {
        self.head_len.iter().sum()
    }

    /// True if any head has no free slot left.
    pub fn any_head_full(&self) -> bool {
        self.head_len.iter().any(|&l| l >= self.capacity)
    }

    /// Offset of slot (h, i) into an [Hk, M, dh] row-major f32 buffer.
    pub fn slot(&self, h: usize, i: usize) -> usize {
        (h * self.capacity + i) * self.d_head
    }

    /// Offset of slot (h, i) into an [Hk, M] row-major scalar buffer.
    pub fn flat(&self, h: usize, i: usize) -> usize {
        h * self.capacity + i
    }

    /// Live KV bytes (K+V f32) this occupancy dehydrates to / rehydrates
    /// from — the quantity the paper's Fig. 3 tracks and the hot-tier
    /// memory limit is enforced against.
    pub fn live_bytes(&self) -> usize {
        self.total_entries() * self.d_head * 2 * 4
    }

    /// Check the compact-prefix invariant against the store's valid/position
    /// buffers ([Hk, M], 0.0/1.0 and -1-for-empty respectively).
    pub fn check(&self, valid: &[f32], positions: &[i32]) -> Result<(), String> {
        for h in 0..self.n_kv_heads {
            let l = self.head_len[h];
            if l > self.capacity {
                return Err(format!("head {h} len {l} > capacity"));
            }
            for i in 0..self.capacity {
                let live = valid[self.flat(h, i)] > 0.5;
                if (i < l) != live {
                    return Err(format!("head {h} slot {i}: valid/len mismatch"));
                }
                if !live && positions[self.flat(h, i)] != -1 {
                    return Err(format!("head {h} slot {i}: stale position"));
                }
            }
            // positions strictly increasing among live slots (eviction keeps order)
            for i in 1..l {
                if positions[self.flat(h, i)] <= positions[self.flat(h, i - 1)] {
                    return Err(format!("head {h}: positions not increasing at {i}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_arithmetic() {
        let mut l = SlotLayout::new(2, 4, 8);
        assert_eq!(l.slot(0, 0), 0);
        assert_eq!(l.slot(0, 3), 12);
        assert_eq!(l.slot(1, 0), 32);
        assert_eq!(l.flat(1, 2), 10);
        assert_eq!(l.total_entries(), 0);
        l.set_head_len(0, 3);
        l.set_head_len(1, 1);
        assert_eq!(l.total_entries(), 4);
        // 4 entries * 4 dh * 2 (K+V) * 4 bytes
        assert_eq!(l.live_bytes(), 128);
        assert!(!l.any_head_full());
        l.set_head_len(1, 8);
        assert!(l.any_head_full());
    }

    #[test]
    fn check_catches_violations() {
        let mut l = SlotLayout::new(1, 2, 4);
        l.set_head_len(0, 2);
        let ok_valid = vec![1.0, 1.0, 0.0, 0.0];
        let ok_pos = vec![3, 7, -1, -1];
        assert!(l.check(&ok_valid, &ok_pos).is_ok());
        // valid bit past the prefix
        assert!(l.check(&[1.0, 1.0, 1.0, 0.0], &ok_pos).is_err());
        // stale position in an empty slot
        assert!(l.check(&ok_valid, &[3, 7, 9, -1]).is_err());
        // positions out of order
        assert!(l.check(&ok_valid, &[7, 3, -1, -1]).is_err());
    }
}
