//! Hot tier: per-layer, per-kv-head ragged caches over fixed-capacity padded
//! f32 buffers — the exact layout `layer_decode_{M}` consumes, so handing a
//! hot cache to the decode path costs zero copies.
//!
//! The K/V/valid buffers live inside [`Tensor`]s so [`HotStore::decode_tensors`]
//! can hand out *borrowed views*: steady-state decode does no full-buffer
//! clone per step (it used to clone K, V, and valid on every decode call).
//! [`HotStore::batch_decode_tensors`] extends the same zero-copy contract to
//! batched decode: B same-capacity caches packed as one logical [B, …]
//! [`BatchDecodeView`] for a single `layer_decode_batched_{M}x{B}` dispatch.
//!
//! Each entry carries its original token position (RoPE phases are baked
//! into cached keys, but analysis/debug and recency-based policies need
//! positions) and its eviction score (Algorithm 2 recompresses lower layers
//! *using the same scores* with shrinking budgets).

use crate::runtime::Tensor;

use super::layout::SlotLayout;
use super::KvTierStore;

#[derive(Debug, Clone)]
pub struct HotStore {
    layout: SlotLayout,
    /// [Hk, M, dh] row-major
    k: Tensor,
    v: Tensor,
    /// [Hk, M] 0.0/1.0
    valid: Tensor,
    /// [Hk, M] original positions (-1 for empty)
    positions: Vec<i32>,
    /// [Hk, M] eviction scores of live entries (0 for empty)
    scores: Vec<f32>,
}

impl HotStore {
    pub fn new(n_kv_heads: usize, d_head: usize, capacity: usize) -> HotStore {
        HotStore {
            layout: SlotLayout::new(n_kv_heads, d_head, capacity),
            k: Tensor::zeros(&[n_kv_heads, capacity, d_head]),
            v: Tensor::zeros(&[n_kv_heads, capacity, d_head]),
            valid: Tensor::zeros(&[n_kv_heads, capacity]),
            positions: vec![-1; n_kv_heads * capacity],
            scores: vec![0.0; n_kv_heads * capacity],
        }
    }

    pub fn n_kv_heads(&self) -> usize {
        self.layout.n_kv_heads()
    }

    pub fn d_head(&self) -> usize {
        self.layout.d_head()
    }

    pub fn capacity(&self) -> usize {
        self.layout.capacity()
    }

    pub fn head_len(&self, h: usize) -> usize {
        self.layout.head_len(h)
    }

    pub fn total_entries(&self) -> usize {
        self.layout.total_entries()
    }

    /// Live KV bytes (K+V f32), the quantity the paper's Fig. 3 tracks.
    pub fn live_bytes(&self) -> usize {
        self.layout.live_bytes()
    }

    /// Allocated bytes (padded buffers).
    pub fn allocated_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// Hot bytes one decoded token appends to this cache (K+V f32 across
    /// all kv heads) — the per-layer growth the scheduler reserves headroom
    /// for before a decode step.
    pub fn step_growth_bytes(&self) -> usize {
        self.layout.n_kv_heads() * self.layout.d_head() * 2 * 4
    }

    fn kbuf(&self) -> &[f32] {
        self.k.as_f32().expect("hot K buffer is f32")
    }

    fn vbuf(&self) -> &[f32] {
        self.v.as_f32().expect("hot V buffer is f32")
    }

    pub fn key(&self, h: usize, i: usize) -> &[f32] {
        let s = self.layout.slot(h, i);
        &self.kbuf()[s..s + self.layout.d_head()]
    }

    pub fn value(&self, h: usize, i: usize) -> &[f32] {
        let s = self.layout.slot(h, i);
        &self.vbuf()[s..s + self.layout.d_head()]
    }

    pub fn position(&self, h: usize, i: usize) -> i32 {
        self.positions[self.layout.flat(h, i)]
    }

    pub fn score(&self, h: usize, i: usize) -> f32 {
        self.scores[self.layout.flat(h, i)]
    }

    pub fn set_score(&mut self, h: usize, i: usize, s: f32) {
        let at = self.layout.flat(h, i);
        self.scores[at] = s;
    }

    /// Scores of live entries for one head.
    pub fn head_scores(&self, h: usize) -> &[f32] {
        let start = self.layout.flat(h, 0);
        &self.scores[start..start + self.layout.head_len(h)]
    }

    /// Ingest a prefill cache: gather `keep[h]` (sorted original indices
    /// into the [0, length) token axis) from k/v tensors [Hk, N, dh],
    /// recording per-entry `scores[h]` (aligned with keep lists).
    pub fn load_from_prefill(
        &mut self,
        k_full: &Tensor,
        v_full: &Tensor,
        keep: &[Vec<usize>],
        entry_scores: &[Vec<f32>],
    ) {
        assert_eq!(keep.len(), self.layout.n_kv_heads());
        let n = k_full.shape[1];
        let dh = self.layout.d_head();
        let cap = self.layout.capacity();
        let kf = k_full.as_f32().expect("k tensor");
        let vf = v_full.as_f32().expect("v tensor");
        let k = self.k.as_f32_mut().expect("hot K buffer is f32");
        let v = self.v.as_f32_mut().expect("hot V buffer is f32");
        let valid = self.valid.as_f32_mut().expect("hot valid buffer is f32");
        for h in 0..self.layout.n_kv_heads() {
            assert!(keep[h].len() <= cap, "keep exceeds capacity");
            assert_eq!(keep[h].len(), entry_scores[h].len());
            for (dst, (&src, &sc)) in keep[h].iter().zip(&entry_scores[h]).enumerate() {
                let from = (h * n + src) * dh;
                let to = self.layout.slot(h, dst);
                k[to..to + dh].copy_from_slice(&kf[from..from + dh]);
                v[to..to + dh].copy_from_slice(&vf[from..from + dh]);
                valid[self.layout.flat(h, dst)] = 1.0;
                self.positions[self.layout.flat(h, dst)] = src as i32;
                self.scores[self.layout.flat(h, dst)] = sc;
            }
            self.layout.set_head_len(h, keep[h].len());
            // zero the tail (fresh cache is already zero, but re-loading must clear)
            for i in keep[h].len()..cap {
                valid[self.layout.flat(h, i)] = 0.0;
                self.positions[self.layout.flat(h, i)] = -1;
                self.scores[self.layout.flat(h, i)] = 0.0;
            }
        }
    }

    /// Ingest a *compacted* prefill cache (streaming eviction): `keep[h]`
    /// indexes compact columns of the k/v tensors, and `col_pos` maps each
    /// compact column to its absolute prompt position (what recency-aware
    /// decode scoring and analysis read back out).
    pub fn load_from_prefill_at(
        &mut self,
        k_full: &Tensor,
        v_full: &Tensor,
        keep: &[Vec<usize>],
        entry_scores: &[Vec<f32>],
        col_pos: &[i32],
    ) {
        self.load_from_prefill(k_full, v_full, keep, entry_scores);
        for h in 0..self.layout.n_kv_heads() {
            for (dst, &src) in keep[h].iter().enumerate() {
                self.positions[self.layout.flat(h, dst)] = col_pos[src];
            }
        }
    }

    /// Algorithm 2 recompression: keep only `keep[h]` (sorted indices into
    /// the *current compact slots* of head h); compact in place.
    pub fn re_evict(&mut self, keep: &[Vec<usize>]) {
        assert_eq!(keep.len(), self.layout.n_kv_heads());
        let dh = self.layout.d_head();
        let k = self.k.as_f32_mut().expect("hot K buffer is f32");
        let v = self.v.as_f32_mut().expect("hot V buffer is f32");
        let valid = self.valid.as_f32_mut().expect("hot valid buffer is f32");
        for h in 0..self.layout.n_kv_heads() {
            debug_assert!(keep[h].windows(2).all(|w| w[0] < w[1]), "keep must be sorted");
            for (dst, &src) in keep[h].iter().enumerate() {
                assert!(src < self.layout.head_len(h), "re_evict index out of range");
                if dst != src {
                    let from = self.layout.slot(h, src);
                    let to = self.layout.slot(h, dst);
                    // non-overlapping guaranteed because dst <= src
                    k.copy_within(from..from + dh, to);
                    v.copy_within(from..from + dh, to);
                    self.positions[self.layout.flat(h, dst)] =
                        self.positions[self.layout.flat(h, src)];
                    self.scores[self.layout.flat(h, dst)] =
                        self.scores[self.layout.flat(h, src)];
                }
            }
            let new_len = keep[h].len();
            for i in new_len..self.layout.head_len(h) {
                valid[self.layout.flat(h, i)] = 0.0;
                self.positions[self.layout.flat(h, i)] = -1;
                self.scores[self.layout.flat(h, i)] = 0.0;
                let s = self.layout.slot(h, i);
                k[s..s + dh].fill(0.0);
                v[s..s + dh].fill(0.0);
            }
            self.layout.set_head_len(h, new_len);
        }
    }

    /// Append one decoded token's K/V (k_new, v_new: [Hk, dh]) at `pos`.
    /// Returns false (and appends nothing) if any head is full.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], pos: i32, score: f32) -> bool {
        assert_eq!(k_new.len(), self.layout.n_kv_heads() * self.layout.d_head());
        if self.layout.any_head_full() {
            return false;
        }
        let dh = self.layout.d_head();
        let k = self.k.as_f32_mut().expect("hot K buffer is f32");
        let v = self.v.as_f32_mut().expect("hot V buffer is f32");
        let valid = self.valid.as_f32_mut().expect("hot valid buffer is f32");
        for h in 0..self.layout.n_kv_heads() {
            let i = self.layout.head_len(h);
            let to = self.layout.slot(h, i);
            k[to..to + dh].copy_from_slice(&k_new[h * dh..(h + 1) * dh]);
            v[to..to + dh].copy_from_slice(&v_new[h * dh..(h + 1) * dh]);
            valid[self.layout.flat(h, i)] = 1.0;
            self.positions[self.layout.flat(h, i)] = pos;
            self.scores[self.layout.flat(h, i)] = score;
            self.layout.set_head_len(h, i + 1);
        }
        true
    }

    /// Append one entry to head `h` only (warm-tier rehydration and tests).
    /// The caller must preserve per-head position ordering.
    pub fn push_entry(&mut self, h: usize, key: &[f32], value: &[f32], pos: i32, score: f32) {
        let dh = self.layout.d_head();
        assert_eq!(key.len(), dh);
        assert_eq!(value.len(), dh);
        let i = self.layout.head_len(h);
        assert!(i < self.layout.capacity(), "push_entry on full head {h}");
        let to = self.layout.slot(h, i);
        let k = self.k.as_f32_mut().expect("hot K buffer is f32");
        let v = self.v.as_f32_mut().expect("hot V buffer is f32");
        let valid = self.valid.as_f32_mut().expect("hot valid buffer is f32");
        k[to..to + dh].copy_from_slice(key);
        v[to..to + dh].copy_from_slice(value);
        valid[self.layout.flat(h, i)] = 1.0;
        self.positions[self.layout.flat(h, i)] = pos;
        self.scores[self.layout.flat(h, i)] = score;
        self.layout.set_head_len(h, i + 1);
    }

    /// Remove exactly one entry from head `h` (by compact-slot index),
    /// shifting only that head's suffix left by one slot. This is the
    /// decode-eviction hot path: O(live entries of one head), not a full
    /// per-head keep-list rebuild across every head.
    pub fn remove_one(&mut self, h: usize, idx: usize) {
        let len = self.layout.head_len(h);
        assert!(idx < len);
        let dh = self.layout.d_head();
        let last = len - 1;
        let k = self.k.as_f32_mut().expect("hot K buffer is f32");
        let v = self.v.as_f32_mut().expect("hot V buffer is f32");
        let valid = self.valid.as_f32_mut().expect("hot valid buffer is f32");
        if idx < last {
            // shift the suffix (idx+1..len) left by one slot; the head's
            // slots are contiguous, so one copy_within per buffer suffices
            let from = self.layout.slot(h, idx + 1);
            let to = self.layout.slot(h, idx);
            let end = self.layout.slot(h, len);
            k.copy_within(from..end, to);
            v.copy_within(from..end, to);
            let ffrom = self.layout.flat(h, idx + 1);
            let fto = self.layout.flat(h, idx);
            let fend = self.layout.flat(h, len);
            self.positions.copy_within(ffrom..fend, fto);
            self.scores.copy_within(ffrom..fend, fto);
        }
        // clear the vacated last slot
        let s = self.layout.slot(h, last);
        k[s..s + dh].fill(0.0);
        v[s..s + dh].fill(0.0);
        valid[self.layout.flat(h, last)] = 0.0;
        self.positions[self.layout.flat(h, last)] = -1;
        self.scores[self.layout.flat(h, last)] = 0.0;
        self.layout.set_head_len(h, last);
    }

    /// Hand the full store to the spill path, leaving an empty
    /// zero-capacity store behind: the session's hot byte accounting drops
    /// to zero for this layer immediately, while the Q8 quantization of the
    /// taken buffers happens off the serving thread.
    pub fn take_for_spill(&mut self) -> HotStore {
        let (hk, dh) = (self.n_kv_heads(), self.d_head());
        std::mem::replace(self, HotStore::new(hk, dh, 0))
    }

    /// Decode-input tensors: K [Hk,M,dh], V [Hk,M,dh], valid [Hk,M] —
    /// borrowed views of the live buffers; steady-state decode copies
    /// nothing.
    pub fn decode_tensors(&self) -> (&Tensor, &Tensor, &Tensor) {
        (&self.k, &self.v, &self.valid)
    }

    /// Pack B same-shape caches into one logical [B, …] batched decode view.
    /// The view *borrows* every cache's K/V/valid buffers (no copies); a
    /// backend that needs physically contiguous [B, …] staging buffers (the
    /// PJRT upload boundary) materializes them from the view with
    /// [`BatchDecodeView::pack_k`] and friends. Panics if the caches disagree
    /// on heads, head dim, or capacity — callers group by capacity bucket
    /// before packing.
    pub fn batch_decode_tensors<'a>(caches: &[&'a HotStore]) -> BatchDecodeView<'a> {
        assert!(!caches.is_empty(), "batch_decode_tensors needs at least one cache");
        let (hk, dh, cap) = (caches[0].n_kv_heads(), caches[0].d_head(), caches[0].capacity());
        let mut k = Vec::with_capacity(caches.len());
        let mut v = Vec::with_capacity(caches.len());
        let mut valid = Vec::with_capacity(caches.len());
        for c in caches {
            assert_eq!(c.n_kv_heads(), hk, "batched caches must share n_kv_heads");
            assert_eq!(c.d_head(), dh, "batched caches must share d_head");
            assert_eq!(c.capacity(), cap, "batched caches must share capacity");
            let (ck, cv, cvalid) = c.decode_tensors();
            k.push(ck);
            v.push(cv);
            valid.push(cvalid);
        }
        BatchDecodeView { k, v, valid, n_kv_heads: hk, d_head: dh, capacity: cap }
    }

    /// Check the compact-prefix invariant (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let valid = self.valid.as_f32().expect("hot valid buffer is f32");
        self.layout.check(valid, &self.positions)
    }
}

/// Borrowed, batch-packed decode input: B same-shape caches presented as one
/// logical K [B, Hk, M, dh] / V [B, Hk, M, dh] / valid [B, Hk, M]. Each entry
/// is a borrow of the owning [`HotStore`]'s live buffer, so building the view
/// costs nothing per decode step; only backends that must hand the runtime a
/// single contiguous buffer (PJRT upload) pay one gather via `pack_*`.
pub struct BatchDecodeView<'a> {
    /// Per-session K tensors, each [Hk, M, dh].
    pub k: Vec<&'a Tensor>,
    /// Per-session V tensors, each [Hk, M, dh].
    pub v: Vec<&'a Tensor>,
    /// Per-session valid tensors, each [Hk, M].
    pub valid: Vec<&'a Tensor>,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub capacity: usize,
}

impl BatchDecodeView<'_> {
    pub fn batch_size(&self) -> usize {
        self.k.len()
    }

    fn pack(parts: &[&Tensor], shape: &[usize]) -> Tensor {
        let per: usize = shape[1..].iter().product();
        let mut out = Vec::with_capacity(shape[0] * per);
        for t in parts {
            out.extend_from_slice(t.as_f32().expect("hot buffers are f32"));
        }
        Tensor::f32(out, shape)
    }

    /// Materialize the contiguous K staging tensor [B, Hk, M, dh].
    pub fn pack_k(&self) -> Tensor {
        let (b, hk, m, dh) = (self.batch_size(), self.n_kv_heads, self.capacity, self.d_head);
        Self::pack(&self.k, &[b, hk, m, dh])
    }

    /// Materialize the contiguous V staging tensor [B, Hk, M, dh].
    pub fn pack_v(&self) -> Tensor {
        let (b, hk, m, dh) = (self.batch_size(), self.n_kv_heads, self.capacity, self.d_head);
        Self::pack(&self.v, &[b, hk, m, dh])
    }

    /// Materialize the contiguous valid staging tensor [B, Hk, M].
    pub fn pack_valid(&self) -> Tensor {
        let (b, hk, m) = (self.batch_size(), self.n_kv_heads, self.capacity);
        Self::pack(&self.valid, &[b, hk, m])
    }
}

impl KvTierStore for HotStore {
    fn n_kv_heads(&self) -> usize {
        self.layout.n_kv_heads()
    }

    fn d_head(&self) -> usize {
        self.layout.d_head()
    }

    fn total_entries(&self) -> usize {
        self.layout.total_entries()
    }

    /// Hot-tier residency cost: live K/V bytes (what `kv_mem_limit` bounds).
    fn tier_bytes(&self) -> usize {
        self.live_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn mk_prefill(hk: usize, n: usize, dh: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let data = |rng: &mut Rng| -> Vec<f32> {
            (0..hk * n * dh).map(|_| rng.normal() as f32).collect()
        };
        (
            Tensor::f32(data(&mut rng), &[hk, n, dh]),
            Tensor::f32(data(&mut rng), &[hk, n, dh]),
        )
    }

    #[test]
    fn load_and_layout() {
        let (k, v) = mk_prefill(2, 10, 4, 0);
        let mut c = HotStore::new(2, 4, 16);
        let keep = vec![vec![1, 3, 7], vec![0, 9]];
        let scores = vec![vec![0.3, 0.2, 0.9], vec![0.1, 0.5]];
        c.load_from_prefill(&k, &v, &keep, &scores);
        assert_eq!(c.head_len(0), 3);
        assert_eq!(c.head_len(1), 2);
        assert_eq!(c.total_entries(), 5);
        c.check_invariants().unwrap();
        // content: head 0 slot 1 == original token 3
        let kf = k.as_f32().unwrap();
        assert_eq!(c.key(0, 1), &kf[3 * 4..3 * 4 + 4]);
        assert_eq!(c.position(0, 2), 7);
        assert_eq!(c.score(1, 1), 0.5);
    }

    #[test]
    fn load_at_rewrites_positions() {
        let (k, v) = mk_prefill(2, 10, 4, 3);
        let mut c = HotStore::new(2, 4, 16);
        let keep = vec![vec![0, 2, 5], vec![1, 9]];
        let scores = vec![vec![0.3, 0.2, 0.9], vec![0.1, 0.5]];
        // compact column j holds absolute position 3j
        let col_pos: Vec<i32> = (0..10).map(|j| 3 * j).collect();
        c.load_from_prefill_at(&k, &v, &keep, &scores, &col_pos);
        assert_eq!(c.head_len(0), 3);
        c.check_invariants().unwrap();
        // content gathered by compact index, positions mapped to absolute
        let kf = k.as_f32().unwrap();
        assert_eq!(c.key(0, 1), &kf[2 * 4..2 * 4 + 4]);
        assert_eq!(c.position(0, 1), 6);
        assert_eq!(c.position(0, 2), 15);
        assert_eq!(c.position(1, 1), 27);
        assert_eq!(c.score(1, 1), 0.5);
    }

    #[test]
    fn re_evict_compacts() {
        let (k, v) = mk_prefill(2, 12, 4, 1);
        let mut c = HotStore::new(2, 4, 16);
        let keep = vec![(0..12).collect::<Vec<_>>(), (0..12).collect()];
        let scores = vec![vec![1.0; 12], vec![1.0; 12]];
        c.load_from_prefill(&k, &v, &keep, &scores);
        c.re_evict(&[vec![0, 5, 11], vec![2, 3]]);
        assert_eq!(c.head_len(0), 3);
        assert_eq!(c.head_len(1), 2);
        c.check_invariants().unwrap();
        assert_eq!(c.position(0, 1), 5);
        assert_eq!(c.position(1, 0), 2);
        let kf = k.as_f32().unwrap();
        assert_eq!(c.key(0, 2), &kf[11 * 4..11 * 4 + 4]);
    }

    #[test]
    fn append_and_overflow() {
        let mut c = HotStore::new(2, 2, 3);
        let k_new = vec![1.0, 2.0, 3.0, 4.0];
        let v_new = vec![5.0, 6.0, 7.0, 8.0];
        assert!(c.append(&k_new, &v_new, 0, 0.5));
        assert!(c.append(&k_new, &v_new, 1, 0.5));
        assert!(c.append(&k_new, &v_new, 2, 0.5));
        assert!(!c.append(&k_new, &v_new, 3, 0.5), "must refuse when full");
        assert_eq!(c.total_entries(), 6);
        c.check_invariants().unwrap();
        assert_eq!(c.key(1, 0), &[3.0, 4.0]);
    }

    #[test]
    fn remove_one_keeps_others() {
        let mut c = HotStore::new(1, 2, 8);
        for p in 0..5 {
            c.append(&[p as f32, 0.0], &[0.0, p as f32], p, p as f32);
        }
        c.remove_one(0, 2);
        assert_eq!(c.head_len(0), 4);
        assert_eq!(
            (0..4).map(|i| c.position(0, i)).collect::<Vec<_>>(),
            vec![0, 1, 3, 4]
        );
        c.check_invariants().unwrap();
    }

    #[test]
    fn remove_one_touches_only_the_affected_head() {
        let mut c = HotStore::new(2, 2, 8);
        for p in 0..5 {
            c.append(&[p as f32, 1.0, 10.0 + p as f32, 2.0], &[0.5; 4], p, p as f32);
        }
        let other_before: Vec<Vec<f32>> = (0..5).map(|i| c.key(1, i).to_vec()).collect();
        c.remove_one(0, 0);
        c.remove_one(0, 3); // former last entry now at index 3
        assert_eq!(c.head_len(0), 3);
        assert_eq!(c.head_len(1), 5, "other head's length untouched");
        for (i, want) in other_before.iter().enumerate() {
            assert_eq!(c.key(1, i), &want[..], "other head's data untouched");
        }
        assert_eq!(
            (0..3).map(|i| c.position(0, i)).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        c.check_invariants().unwrap();
    }

    #[test]
    fn remove_one_last_entry() {
        let mut c = HotStore::new(1, 2, 4);
        c.append(&[1.0, 2.0], &[3.0, 4.0], 0, 0.1);
        c.remove_one(0, 0);
        assert_eq!(c.head_len(0), 0);
        c.check_invariants().unwrap();
        assert!(c.append(&[5.0, 6.0], &[7.0, 8.0], 1, 0.2));
        assert_eq!(c.key(0, 0), &[5.0, 6.0]);
    }

    #[test]
    fn push_entry_fills_one_head() {
        let mut c = HotStore::new(2, 2, 4);
        c.push_entry(0, &[1.0, 2.0], &[3.0, 4.0], 5, 0.7);
        c.push_entry(0, &[5.0, 6.0], &[7.0, 8.0], 9, 0.9);
        assert_eq!(c.head_len(0), 2);
        assert_eq!(c.head_len(1), 0);
        assert_eq!(c.position(0, 1), 9);
        assert_eq!(c.value(0, 0), &[3.0, 4.0]);
        c.check_invariants().unwrap();
    }

    #[test]
    fn take_for_spill_leaves_empty_store() {
        let mut c = HotStore::new(2, 4, 8);
        c.append(&[1.0; 8], &[2.0; 8], 0, 0.5);
        let taken = c.take_for_spill();
        assert_eq!(taken.total_entries(), 2);
        assert_eq!(taken.capacity(), 8);
        assert_eq!(c.live_bytes(), 0, "left-behind store holds nothing");
        assert_eq!(c.capacity(), 0);
        assert_eq!(c.n_kv_heads(), 2);
        assert_eq!(c.d_head(), 4);
        c.check_invariants().unwrap();
    }

    #[test]
    fn decode_tensor_shapes() {
        let mut c = HotStore::new(2, 4, 8);
        c.append(&vec![0.5; 8], &vec![0.25; 8], 0, 1.0);
        let (k, v, valid) = c.decode_tensors();
        assert_eq!(k.shape, vec![2, 8, 4]);
        assert_eq!(v.shape, vec![2, 8, 4]);
        assert_eq!(valid.shape, vec![2, 8]);
        assert_eq!(valid.as_f32().unwrap()[0], 1.0);
        assert_eq!(valid.as_f32().unwrap()[1], 0.0);
    }

    #[test]
    fn prop_random_op_sequences_keep_invariants() {
        prop::check(60, |rng| {
            let hk = 1 + rng.below(4);
            let dh = 2 + rng.below(6);
            let cap = 8 + rng.below(24);
            let n = 4 + rng.below(cap - 2);
            let (k, v) = mk_prefill(hk, n, dh, rng.next_u64());
            let mut c = HotStore::new(hk, dh, cap);
            // random initial keeps
            let mut keeps = Vec::new();
            let mut scs = Vec::new();
            for _ in 0..hk {
                let cnt = 1 + rng.below(n);
                let idx = rng.sample_indices(n, cnt);
                scs.push(idx.iter().map(|_| rng.f32()).collect::<Vec<_>>());
                keeps.push(idx);
            }
            c.load_from_prefill(&k, &v, &keeps, &scs);
            prop::assert_prop(c.check_invariants().is_ok(), "after load", &c.total_entries())?;

            for step in 0..20 {
                match rng.below(3) {
                    0 => {
                        // append if room
                        let kn: Vec<f32> = (0..hk * dh).map(|_| rng.f32()).collect();
                        let vn: Vec<f32> = (0..hk * dh).map(|_| rng.f32()).collect();
                        let pos = (n + step) as i32;
                        c.append(&kn, &vn, pos, rng.f32());
                    }
                    1 => {
                        // random re-evict (subset per head)
                        let mut keep = Vec::new();
                        for h in 0..hk {
                            let l = c.head_len(h);
                            let cnt = if l == 0 { 0 } else { 1 + rng.below(l) };
                            keep.push(if l == 0 {
                                vec![]
                            } else {
                                rng.sample_indices(l, cnt)
                            });
                        }
                        c.re_evict(&keep);
                    }
                    _ => {
                        let h = rng.below(hk);
                        if c.head_len(h) > 0 {
                            let idx = rng.below(c.head_len(h));
                            c.remove_one(h, idx);
                        }
                    }
                }
                if let Err(e) = c.check_invariants() {
                    return Err(prop::CaseFailure { message: e });
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_view_borrows_and_packs() {
        let mut a = HotStore::new(2, 4, 8);
        let mut b = HotStore::new(2, 4, 8);
        a.append(&vec![1.0; 8], &vec![2.0; 8], 0, 0.5);
        b.append(&vec![3.0; 8], &vec![4.0; 8], 0, 0.5);
        b.append(&vec![5.0; 8], &vec![6.0; 8], 1, 0.5);
        let view = HotStore::batch_decode_tensors(&[&a, &b]);
        assert_eq!(view.batch_size(), 2);
        assert_eq!(view.capacity, 8);
        // entries borrow the live buffers: view.k[0] is a's K tensor
        let (ak, _, _) = a.decode_tensors();
        assert!(std::ptr::eq(view.k[0], ak), "view must borrow, not copy");
        let k = view.pack_k();
        assert_eq!(k.shape, vec![2, 2, 8, 4]);
        let kf = k.as_f32().unwrap();
        assert_eq!(kf[0], 1.0, "session 0 head 0 slot 0");
        assert_eq!(kf[2 * 8 * 4], 3.0, "session 1 head 0 slot 0");
        let valid = view.pack_valid();
        assert_eq!(valid.shape, vec![2, 2, 8]);
        let vf = valid.as_f32().unwrap();
        assert_eq!(&vf[0..2], &[1.0, 0.0], "session 0 head 0 occupancy");
        assert_eq!(&vf[16..19], &[1.0, 1.0, 0.0], "session 1 head 0 occupancy");
        assert_eq!(view.pack_v().shape, vec![2, 2, 8, 4]);
    }

    #[test]
    #[should_panic(expected = "share capacity")]
    fn batch_view_rejects_mixed_capacity() {
        let a = HotStore::new(2, 4, 8);
        let b = HotStore::new(2, 4, 16);
        HotStore::batch_decode_tensors(&[&a, &b]);
    }

    #[test]
    fn memory_accounting() {
        let mut c = HotStore::new(2, 4, 8);
        assert_eq!(c.live_bytes(), 0);
        c.append(&vec![0.0; 8], &vec![0.0; 8], 0, 0.0);
        // 2 heads * 1 entry * 4 dh * 2 (K+V) * 4 bytes
        assert_eq!(c.live_bytes(), 64);
        assert_eq!(c.step_growth_bytes(), 64, "one decode step appends one entry per head");
        assert_eq!(c.allocated_bytes(), 2 * 8 * 4 * 2 * 4);
    }
}
