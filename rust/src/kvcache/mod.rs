//! KV cache manager: per-layer, per-kv-head ragged caches over fixed-capacity
//! padded buffers (the layout `layer_decode_{M}` consumes directly).
//!
//! Layout invariant ("compact prefix"): for every kv head `h`, slots
//! `[0, head_len[h])` are live and slots `[head_len[h], capacity)` are zeroed
//! with `valid == 0`. Eviction compacts in place; decode appends at
//! `head_len[h]`. Heads may have different lengths — that is exactly how
//! AdaKV/LAVa dynamic head budgets materialize.
//!
//! Each entry carries its original token position (RoPE phases are baked
//! into cached keys, but analysis/debug and recency-based policies need
//! positions) and its eviction score (Algorithm 2 recompresses lower layers
//! *using the same scores* with shrinking budgets).

use crate::runtime::Tensor;

#[derive(Debug, Clone)]
pub struct LayerCache {
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub capacity: usize,
    /// [Hk, M, dh] row-major
    k: Vec<f32>,
    v: Vec<f32>,
    /// [Hk, M] 0.0/1.0
    valid: Vec<f32>,
    /// [Hk, M] original positions (-1 for empty)
    positions: Vec<i32>,
    /// [Hk, M] eviction scores of live entries (0 for empty)
    scores: Vec<f32>,
    head_len: Vec<usize>,
}

impl LayerCache {
    pub fn new(n_kv_heads: usize, d_head: usize, capacity: usize) -> LayerCache {
        LayerCache {
            n_kv_heads,
            d_head,
            capacity,
            k: vec![0.0; n_kv_heads * capacity * d_head],
            v: vec![0.0; n_kv_heads * capacity * d_head],
            valid: vec![0.0; n_kv_heads * capacity],
            positions: vec![-1; n_kv_heads * capacity],
            scores: vec![0.0; n_kv_heads * capacity],
            head_len: vec![0; n_kv_heads],
        }
    }

    pub fn head_len(&self, h: usize) -> usize {
        self.head_len[h]
    }

    pub fn total_entries(&self) -> usize {
        self.head_len.iter().sum()
    }

    /// Live KV bytes (K+V f32), the quantity the paper's Fig. 3 tracks.
    pub fn live_bytes(&self) -> usize {
        self.total_entries() * self.d_head * 2 * 4
    }

    /// Allocated bytes (padded buffers).
    pub fn allocated_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    fn slot(&self, h: usize, i: usize) -> usize {
        (h * self.capacity + i) * self.d_head
    }

    pub fn key(&self, h: usize, i: usize) -> &[f32] {
        let s = self.slot(h, i);
        &self.k[s..s + self.d_head]
    }

    pub fn value(&self, h: usize, i: usize) -> &[f32] {
        let s = self.slot(h, i);
        &self.v[s..s + self.d_head]
    }

    pub fn position(&self, h: usize, i: usize) -> i32 {
        self.positions[h * self.capacity + i]
    }

    pub fn score(&self, h: usize, i: usize) -> f32 {
        self.scores[h * self.capacity + i]
    }

    pub fn set_score(&mut self, h: usize, i: usize, s: f32) {
        self.scores[h * self.capacity + i] = s;
    }

    /// Scores of live entries for one head.
    pub fn head_scores(&self, h: usize) -> &[f32] {
        &self.scores[h * self.capacity..h * self.capacity + self.head_len[h]]
    }

    /// Ingest a prefill cache: gather `keep[h]` (sorted original indices
    /// into the [0, length) token axis) from k/v tensors [Hk, N, dh],
    /// recording per-entry `scores[h]` (aligned with keep lists).
    pub fn load_from_prefill(
        &mut self,
        k_full: &Tensor,
        v_full: &Tensor,
        keep: &[Vec<usize>],
        entry_scores: &[Vec<f32>],
    ) {
        assert_eq!(keep.len(), self.n_kv_heads);
        let n = k_full.shape[1];
        let dh = self.d_head;
        let kf = k_full.as_f32().expect("k tensor");
        let vf = v_full.as_f32().expect("v tensor");
        for h in 0..self.n_kv_heads {
            assert!(keep[h].len() <= self.capacity, "keep exceeds capacity");
            assert_eq!(keep[h].len(), entry_scores[h].len());
            for (dst, (&src, &sc)) in keep[h].iter().zip(&entry_scores[h]).enumerate() {
                let from = (h * n + src) * dh;
                let to = self.slot(h, dst);
                self.k[to..to + dh].copy_from_slice(&kf[from..from + dh]);
                self.v[to..to + dh].copy_from_slice(&vf[from..from + dh]);
                self.valid[h * self.capacity + dst] = 1.0;
                self.positions[h * self.capacity + dst] = src as i32;
                self.scores[h * self.capacity + dst] = sc;
            }
            self.head_len[h] = keep[h].len();
            // zero the tail (fresh cache is already zero, but re-loading must clear)
            for i in keep[h].len()..self.capacity {
                self.valid[h * self.capacity + i] = 0.0;
                self.positions[h * self.capacity + i] = -1;
                self.scores[h * self.capacity + i] = 0.0;
            }
        }
    }

    /// Algorithm 2 recompression: keep only `keep[h]` (sorted indices into
    /// the *current compact slots* of head h); compact in place.
    pub fn re_evict(&mut self, keep: &[Vec<usize>]) {
        assert_eq!(keep.len(), self.n_kv_heads);
        let dh = self.d_head;
        for h in 0..self.n_kv_heads {
            debug_assert!(keep[h].windows(2).all(|w| w[0] < w[1]), "keep must be sorted");
            for (dst, &src) in keep[h].iter().enumerate() {
                assert!(src < self.head_len[h], "re_evict index out of range");
                if dst != src {
                    let from = self.slot(h, src);
                    let to = self.slot(h, dst);
                    // non-overlapping guaranteed because dst <= src
                    self.k.copy_within(from..from + dh, to);
                    self.v.copy_within(from..from + dh, to);
                    self.positions[h * self.capacity + dst] =
                        self.positions[h * self.capacity + src];
                    self.scores[h * self.capacity + dst] =
                        self.scores[h * self.capacity + src];
                }
            }
            let new_len = keep[h].len();
            for i in new_len..self.head_len[h] {
                self.valid[h * self.capacity + i] = 0.0;
                self.positions[h * self.capacity + i] = -1;
                self.scores[h * self.capacity + i] = 0.0;
                let s = self.slot(h, i);
                self.k[s..s + dh].fill(0.0);
                self.v[s..s + dh].fill(0.0);
            }
            self.head_len[h] = new_len;
        }
    }

    /// Append one decoded token's K/V (k_new, v_new: [Hk, dh]) at `pos`.
    /// Returns false (and appends nothing) if any head is full.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], pos: i32, score: f32) -> bool {
        assert_eq!(k_new.len(), self.n_kv_heads * self.d_head);
        if self.head_len.iter().any(|&l| l >= self.capacity) {
            return false;
        }
        let dh = self.d_head;
        for h in 0..self.n_kv_heads {
            let i = self.head_len[h];
            let to = self.slot(h, i);
            self.k[to..to + dh].copy_from_slice(&k_new[h * dh..(h + 1) * dh]);
            self.v[to..to + dh].copy_from_slice(&v_new[h * dh..(h + 1) * dh]);
            self.valid[h * self.capacity + i] = 1.0;
            self.positions[h * self.capacity + i] = pos;
            self.scores[h * self.capacity + i] = score;
            self.head_len[h] += 1;
        }
        true
    }

    /// Remove exactly one entry from head `h` (by compact-slot index).
    pub fn remove_one(&mut self, h: usize, idx: usize) {
        assert!(idx < self.head_len[h]);
        let keep: Vec<usize> = (0..self.head_len[h]).filter(|&i| i != idx).collect();
        let mut all: Vec<Vec<usize>> = (0..self.n_kv_heads)
            .map(|hh| (0..self.head_len[hh]).collect())
            .collect();
        all[h] = keep;
        self.re_evict(&all);
    }

    /// Decode-input tensors: K [Hk,M,dh], V [Hk,M,dh], valid [Hk,M].
    pub fn decode_tensors(&self) -> (Tensor, Tensor, Tensor) {
        let shape_kv = [self.n_kv_heads, self.capacity, self.d_head];
        (
            Tensor::f32(self.k.clone(), &shape_kv),
            Tensor::f32(self.v.clone(), &shape_kv),
            Tensor::f32(self.valid.clone(), &[self.n_kv_heads, self.capacity]),
        )
    }

    /// Check the compact-prefix invariant (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        for h in 0..self.n_kv_heads {
            let l = self.head_len[h];
            if l > self.capacity {
                return Err(format!("head {h} len {l} > capacity"));
            }
            for i in 0..self.capacity {
                let live = self.valid[h * self.capacity + i] > 0.5;
                if (i < l) != live {
                    return Err(format!("head {h} slot {i}: valid/len mismatch"));
                }
                if !live && self.positions[h * self.capacity + i] != -1 {
                    return Err(format!("head {h} slot {i}: stale position"));
                }
            }
            // positions strictly increasing among live slots (eviction keeps order)
            for i in 1..l {
                if self.positions[h * self.capacity + i]
                    <= self.positions[h * self.capacity + i - 1]
                {
                    return Err(format!("head {h}: positions not increasing at {i}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn mk_prefill(hk: usize, n: usize, dh: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let data = |rng: &mut Rng| -> Vec<f32> {
            (0..hk * n * dh).map(|_| rng.normal() as f32).collect()
        };
        (
            Tensor::f32(data(&mut rng), &[hk, n, dh]),
            Tensor::f32(data(&mut rng), &[hk, n, dh]),
        )
    }

    #[test]
    fn load_and_layout() {
        let (k, v) = mk_prefill(2, 10, 4, 0);
        let mut c = LayerCache::new(2, 4, 16);
        let keep = vec![vec![1, 3, 7], vec![0, 9]];
        let scores = vec![vec![0.3, 0.2, 0.9], vec![0.1, 0.5]];
        c.load_from_prefill(&k, &v, &keep, &scores);
        assert_eq!(c.head_len(0), 3);
        assert_eq!(c.head_len(1), 2);
        assert_eq!(c.total_entries(), 5);
        c.check_invariants().unwrap();
        // content: head 0 slot 1 == original token 3
        let kf = k.as_f32().unwrap();
        assert_eq!(c.key(0, 1), &kf[(0 * 10 + 3) * 4..(0 * 10 + 3) * 4 + 4]);
        assert_eq!(c.position(0, 2), 7);
        assert_eq!(c.score(1, 1), 0.5);
    }

    #[test]
    fn re_evict_compacts() {
        let (k, v) = mk_prefill(2, 12, 4, 1);
        let mut c = LayerCache::new(2, 4, 16);
        let keep = vec![(0..12).collect::<Vec<_>>(), (0..12).collect()];
        let scores = vec![vec![1.0; 12], vec![1.0; 12]];
        c.load_from_prefill(&k, &v, &keep, &scores);
        c.re_evict(&[vec![0, 5, 11], vec![2, 3]]);
        assert_eq!(c.head_len(0), 3);
        assert_eq!(c.head_len(1), 2);
        c.check_invariants().unwrap();
        assert_eq!(c.position(0, 1), 5);
        assert_eq!(c.position(1, 0), 2);
        let kf = k.as_f32().unwrap();
        assert_eq!(c.key(0, 2), &kf[(0 * 12 + 11) * 4..(0 * 12 + 11) * 4 + 4]);
    }

    #[test]
    fn append_and_overflow() {
        let mut c = LayerCache::new(2, 2, 3);
        let k_new = vec![1.0, 2.0, 3.0, 4.0];
        let v_new = vec![5.0, 6.0, 7.0, 8.0];
        assert!(c.append(&k_new, &v_new, 0, 0.5));
        assert!(c.append(&k_new, &v_new, 1, 0.5));
        assert!(c.append(&k_new, &v_new, 2, 0.5));
        assert!(!c.append(&k_new, &v_new, 3, 0.5), "must refuse when full");
        assert_eq!(c.total_entries(), 6);
        c.check_invariants().unwrap();
        assert_eq!(c.key(1, 0), &[3.0, 4.0]);
    }

    #[test]
    fn remove_one_keeps_others() {
        let mut c = LayerCache::new(1, 2, 8);
        for p in 0..5 {
            c.append(&[p as f32, 0.0], &[0.0, p as f32], p, p as f32);
        }
        c.remove_one(0, 2);
        assert_eq!(c.head_len(0), 4);
        assert_eq!(
            (0..4).map(|i| c.position(0, i)).collect::<Vec<_>>(),
            vec![0, 1, 3, 4]
        );
        c.check_invariants().unwrap();
    }

    #[test]
    fn decode_tensor_shapes() {
        let mut c = LayerCache::new(2, 4, 8);
        c.append(&vec![0.5; 8], &vec![0.25; 8], 0, 1.0);
        let (k, v, valid) = c.decode_tensors();
        assert_eq!(k.shape, vec![2, 8, 4]);
        assert_eq!(v.shape, vec![2, 8, 4]);
        assert_eq!(valid.shape, vec![2, 8]);
        assert_eq!(valid.as_f32().unwrap()[0], 1.0);
        assert_eq!(valid.as_f32().unwrap()[1], 0.0);
    }

    #[test]
    fn prop_random_op_sequences_keep_invariants() {
        prop::check(60, |rng| {
            let hk = 1 + rng.below(4);
            let dh = 2 + rng.below(6);
            let cap = 8 + rng.below(24);
            let n = 4 + rng.below(cap - 2);
            let (k, v) = mk_prefill(hk, n, dh, rng.next_u64());
            let mut c = LayerCache::new(hk, dh, cap);
            // random initial keeps
            let mut keeps = Vec::new();
            let mut scs = Vec::new();
            for _ in 0..hk {
                let cnt = 1 + rng.below(n);
                let idx = rng.sample_indices(n, cnt);
                scs.push(idx.iter().map(|_| rng.f32()).collect::<Vec<_>>());
                keeps.push(idx);
            }
            c.load_from_prefill(&k, &v, &keeps, &scs);
            prop::assert_prop(c.check_invariants().is_ok(), "after load", &c.head_len)?;

            for step in 0..20 {
                match rng.below(3) {
                    0 => {
                        // append if room
                        let kn: Vec<f32> = (0..hk * dh).map(|_| rng.f32()).collect();
                        let vn: Vec<f32> = (0..hk * dh).map(|_| rng.f32()).collect();
                        let pos = (n + step) as i32;
                        c.append(&kn, &vn, pos, rng.f32());
                    }
                    1 => {
                        // random re-evict (subset per head)
                        let mut keep = Vec::new();
                        for h in 0..hk {
                            let l = c.head_len(h);
                            let cnt = if l == 0 { 0 } else { 1 + rng.below(l) };
                            keep.push(if l == 0 {
                                vec![]
                            } else {
                                rng.sample_indices(l, cnt)
                            });
                        }
                        c.re_evict(&keep);
                    }
                    _ => {
                        let h = rng.below(hk);
                        if c.head_len(h) > 0 {
                            let idx = rng.below(c.head_len(h));
                            c.remove_one(h, idx);
                        }
                    }
                }
                if let Err(e) = c.check_invariants() {
                    return Err(prop::CaseFailure { message: e });
                }
            }
            Ok(())
        });
    }

    #[test]
    fn memory_accounting() {
        let mut c = LayerCache::new(2, 4, 8);
        assert_eq!(c.live_bytes(), 0);
        c.append(&vec![0.0; 8], &vec![0.0; 8], 0, 0.0);
        // 2 heads * 1 entry * 4 dh * 2 (K+V) * 4 bytes
        assert_eq!(c.live_bytes(), 64);
        assert_eq!(c.allocated_bytes(), 2 * 8 * 4 * 2 * 4);
    }
}
