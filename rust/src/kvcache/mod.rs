//! Tiered KV store: pluggable hot/warm storage for per-layer caches.
//!
//! The monolithic `LayerCache` is split into four modules:
//!
//! * [`layout`] — the slot/compact-prefix addressing both tiers agree on:
//!   per-head lengths, slot arithmetic, and the layout invariant checker.
//! * [`hot`] — [`HotStore`], the serving representation: fixed-capacity
//!   padded f32 buffers in exactly the shape `layer_decode_{M}` consumes,
//!   handed to the decode path as borrowed tensor views (zero copies).
//! * [`warm`] — [`WarmBlock`], the spilled representation: the live compact
//!   prefix only, Q8-quantized (scale-per-head blockwise) with a documented
//!   round-trip tolerance ([`warm::q8_tolerance`]); positions, scores, and
//!   head lengths survive exactly.
//! * [`tier`] — the tier side, split in two: [`TierClient`] (serving-thread
//!   handle owning the per-session, per-layer [`Residency`] bookkeeping and
//!   exact byte accounting, so every scheduling decision is synchronous and
//!   deterministic) and a background tier thread owning a [`TierManager`]
//!   (the warm blocks) that does the Q8 quantize/dequantize off the serving
//!   path, with a prefetch-ahead staging area for double-buffered
//!   rehydration. The scheduler drives spills (idle sessions'
//!   lowest-LAVa-weight layers first, when projected hot bytes exceed
//!   `kv_mem_limit`) and fetches (a session's spilled layers rehydrate
//!   before its next decode round); the engine only ever sees hot caches
//!   and asserts residency at the hot path boundary.
//!
//! `kv_mem_limit` bounds the *hot* tier only: under memory pressure the
//! scheduler spills instead of deferring, so far more sessions stay
//! admitted. This is the structural seam for the later SSD tier (ROADMAP).

pub mod hot;
pub mod layout;
pub mod tier;
pub mod warm;

pub use hot::{BatchDecodeView, HotStore};
pub use layout::SlotLayout;
pub use tier::{Residency, TierClient, TierManager, TierThreadSnapshot};
pub use warm::{projected_warm_bytes, q8_tolerance, Q8Carry, WarmBlock};

/// Historical name of the hot store, kept so call sites and docs that speak
/// "layer cache" keep compiling; new code should say [`HotStore`].
pub type LayerCache = HotStore;

/// Common surface of the tiered representations. `tier_bytes` is the cost
/// of a store *in its own tier*: live f32 bytes for hot (what
/// `kv_mem_limit` bounds), quantized block bytes for warm.
pub trait KvTierStore {
    fn n_kv_heads(&self) -> usize;
    fn d_head(&self) -> usize;
    fn total_entries(&self) -> usize;
    fn tier_bytes(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_store_surface_is_consistent() {
        let mut hot = HotStore::new(2, 4, 8);
        hot.append(&[0.5; 8], &[0.25; 8], 0, 1.0);
        let warm = WarmBlock::from_hot(&hot);
        let (h, w): (&dyn KvTierStore, &dyn KvTierStore) = (&hot, &warm);
        assert_eq!(h.n_kv_heads(), w.n_kv_heads());
        assert_eq!(h.d_head(), w.d_head());
        assert_eq!(h.total_entries(), w.total_entries());
        assert_eq!(h.tier_bytes(), hot.live_bytes());
        assert_eq!(w.tier_bytes(), warm.warm_bytes());
        assert!(w.tier_bytes() < h.tier_bytes() * 2, "warm must not inflate");
    }
}
