//! Figure 5
//!
//!   cargo run --release --bin bench_winrate -- [--mock] [--ctx 256]
//!       [--budgets 24,32,48,64] [--per-task 3] [--out results/bench_winrate.jsonl]

use anyhow::Result;
use lava::bench::{driver, experiments};
use lava::util::cli::Args;
use lava::with_engine;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let p = driver::params_from_args(&args);
    with_engine!(args, |engine| {
        let t = experiments::figure5(&mut engine, &p)?;
        driver::emit(&args, &[t]);
        Ok(())
    })
}
