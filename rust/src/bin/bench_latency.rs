//! Figure 3: decode latency (ms/token) and peak KV memory vs context
//! length, full cache vs compressed policies.
//!
//! Two regimes:
//!   * real model (default): context lengths within the artifact buckets;
//!   * --mock: coordinator-only scaling to paper-scale contexts (128k) —
//!     isolates the L3 overhead the way the paper's Fig. 3 isolates
//!     FlashAttention + cache handling.
//!
//!   cargo run --release --bin bench_latency -- [--mock]
//!       [--ctx-lens 128,256,512,1024,2048] [--budget 32] [--out-tokens 16]

use anyhow::Result;
use lava::bench::{driver, experiments};
use lava::util::cli::Args;
use lava::with_engine;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let default_ctx: Vec<usize> = if args.bool("mock") {
        vec![1024, 4096, 16384, 65536, 131072]
    } else {
        vec![128, 256, 512, 1024, 2048]
    };
    let ctx_lens = args.usize_list_or("ctx-lens", &default_ctx);
    let budget = args.usize_or("budget", 32);
    let out_tokens = args.usize_or("out-tokens", 16);
    let policies = args.str_list_or(
        "policies",
        &["full", "snapkv", "ada-snapkv", "cake", "lava"],
    );
    let seed = args.usize_or("seed", 0) as u64;
    with_engine!(args, |engine| {
        let (lat, mem) =
            experiments::figure3(&mut engine, &ctx_lens, &policies, budget, out_tokens, seed)?;
        driver::emit(&args, &[lat, mem]);
        Ok(())
    })
}
