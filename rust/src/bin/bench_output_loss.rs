//! Table 14: exact layer attention output loss ||y - ŷ||_1 (Lemma 1) at
//! the first and last layers, AdaKV score vs LAVa score. Model-faithful —
//! no scale substitution — so this is the repo's strongest direct check of
//! Theorem 1's claim that LAVa's bound is tighter in practice.
//!
//! Needs the real artifacts (W^O weights); no --mock mode.
//!
//!   cargo run --release --bin bench_output_loss -- [--ctx 256] [--budget 16]
//!       [--per-task 3] [--out results/output_loss.jsonl]

use anyhow::Result;
use lava::bench::{driver, experiments};
use lava::model::{Manifest, Weights};
use lava::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let p = driver::params_from_args(&args);
    let budget = args.usize_or("budget", 16);
    let dir = args.str_or("artifacts", "artifacts");
    let manifest = Manifest::load(&dir)?;
    let weights = Weights::load(&manifest)?;
    let wo_idx = manifest
        .layer_weight_order
        .iter()
        .position(|w| w == "wo")
        .expect("wo in layer weights");
    let wo_per_layer: Vec<_> = weights.layers.iter().map(|lw| lw[wo_idx].clone()).collect();

    let mut engine = driver::pjrt_engine(&args)?;
    let t = experiments::table14(&mut engine, &wo_per_layer, &p, budget)?;
    driver::emit(&args, &[t]);
    Ok(())
}
