//! Table 2 + Figure 2: the LongBench-proxy grid — every policy x budget,
//! per-task scores and extraction/generation category averages.
//!
//!   cargo run --release --bin bench_longbench -- [--mock] [--ctx 256]
//!       [--budgets 24,32,48,64] [--per-task 3] [--out results/longbench.jsonl]

use anyhow::Result;
use lava::bench::{driver, experiments};
use lava::util::cli::Args;
use lava::with_engine;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let p = driver::params_from_args(&args);
    with_engine!(args, |engine| {
        let (tables, results) = experiments::table2(&mut engine, &p)?;
        driver::emit(&args, &tables);
        let fig2 = experiments::figure2(&results, &p.budgets, &p.policies);
        driver::emit(&args, &[fig2]);
        println!("{}", engine.metrics.report());
        Ok(())
    })
}
