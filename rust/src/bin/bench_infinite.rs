//! Table 12: InfiniteBench-proxy — the longest contexts the buckets allow
//! (Sum / MC / Dia proxies; see DESIGN.md §3).
//!
//!   cargo run --release --bin bench_infinite -- [--mock] [--ctx 2048]
//!       [--budget 48] [--per-task 2] [--out results/infinite.jsonl]

use anyhow::Result;
use lava::bench::{driver, experiments};
use lava::util::cli::Args;
use lava::with_engine;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let p = driver::params_from_args(&args);
    let ctx = args.usize_or("ctx", 2048);
    let budget = args.usize_or("budget", 48);
    with_engine!(args, |engine| {
        let t = experiments::table12(&mut engine, &p, ctx, budget)?;
        driver::emit(&args, &[t]);
        Ok(())
    })
}
