//! Table 9: Needle-In-A-Haystack — context x depth grid, averaged, per
//! policy at small and large budgets.
//!
//!   cargo run --release --bin bench_niah -- [--mock] [--ctx-lens 128,256,512]
//!       [--budgets 24,64] [--per-task 2] [--out results/niah.jsonl]

use anyhow::Result;
use lava::bench::{driver, experiments};
use lava::util::cli::Args;
use lava::with_engine;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let p = driver::params_from_args(&args);
    let ctx_lens = args.usize_list_or("ctx-lens", &[128, 256, 512]);
    with_engine!(args, |engine| {
        let t = experiments::table9(&mut engine, &p, &ctx_lens)?;
        driver::emit(&args, &[t]);
        Ok(())
    })
}
