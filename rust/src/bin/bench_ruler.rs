//! Table 11: Ruler-proxy — multi-key / multi-hop / kv retrieval at several
//! context lengths (paper: 4k/8k/16k; here the ~16x scale-down).
//!
//!   cargo run --release --bin bench_ruler -- [--mock] [--ctx-lens 256,512,1024]
//!       [--budget 32] [--per-task 2] [--out results/ruler.jsonl]

use anyhow::Result;
use lava::bench::{driver, experiments};
use lava::util::cli::Args;
use lava::with_engine;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let p = driver::params_from_args(&args);
    let ctx_lens = args.usize_list_or("ctx-lens", &[256, 512, 1024]);
    let budget = args.usize_or("budget", 32);
    with_engine!(args, |engine| {
        let t = experiments::table11(&mut engine, &p, &ctx_lens, budget)?;
        driver::emit(&args, &[t]);
        Ok(())
    })
}
