//! `ModelBackend`: the engine's view of the model.
//!
//! Two implementations:
//! * [`PjrtBackend`] — the real path: executes the AOT-compiled HLO-text
//!   artifacts through PJRT, with weights resident on the device.
//! * [`MockBackend`] — a deterministic synthetic model used by unit tests
//!   and by the large-N latency scaling benches (Fig. 3 beyond the real
//!   model's bucket range), producing peaked attention at configurable
//!   positions so eviction policies have structure to react to.
//!
//! Token embedding is a row lookup; the engine does it host-side from the
//! `tok_emb` weights (cheaper than a PJRT call), so `embed_{N}` artifacts
//! exist only for parity tests.
//!
//! Decode has two entrypoints: `layer_decode` (one session) and
//! `layer_decode_batched` (B sessions sharing a capacity bucket, one
//! dispatch). The batched form must be bit-identical to looping the serial
//! form — the engine treats the two paths as interchangeable and the
//! `batched_decode` equivalence suite enforces it per backend.

use std::sync::Mutex;

use anyhow::{anyhow, Result};

use super::{Manifest, ModelConfig, Weights};
use crate::compress::LayerObs;
use crate::kvcache::HotStore;
use crate::runtime::{Arg, Runtime, Tensor};
use crate::util::rng::Rng;

/// Output of one layer's prefill pass.
pub struct PrefillOut {
    pub x_out: Tensor, // [N, d]
    pub k: Tensor,     // [Hk, N, dh]
    pub v: Tensor,     // [Hk, N, dh]
    pub obs: LayerObs,
}

/// Output of one *chunk* of a layer's prefill pass (chunked prefill).
///
/// The chunk covers absolute positions `[start, start + chunk_len)` of a
/// prompt whose completed layer is observed at width `n_obs` (the monolithic
/// prefill bucket). K/V come back chunk-sized; observation contributions come
/// back at full `n_obs` width so the engine can accumulate them additively —
/// after the last chunk the accumulated tensors must be bit-identical to one
/// monolithic [`ModelBackend::layer_prefill`] call at bucket `n_obs`.
pub struct ChunkPrefillOut {
    pub x_out: Tensor, // [C, d] (rows >= chunk_len are padding)
    pub k: Tensor,     // [Hk, C, dh]
    pub v: Tensor,     // [Hk, C, dh]
    /// Completed window-attention rows *owned* by this chunk: `(r, row)`
    /// where row r's query position `length - w + r` falls inside the chunk
    /// and `row` is the full `[H * n_obs]` normalized distribution. Each of
    /// the w rows is owned by exactly one chunk.
    pub win_rows: Vec<(usize, Vec<f32>)>,
    /// Additive accumulated-attention contribution `[H * n_obs]` (zero
    /// outside the columns this chunk contributes to).
    pub acc: Vec<f32>,
    /// Additive value-norm contribution `[Hk * n_obs]`.
    pub vnorm: Vec<f32>,
}

/// One chunk of a layer's prefill against a *compacted* carry (streaming
/// eviction). Unlike [`ChunkPrefillOut`]'s full-width carry, the carry here
/// holds only the surviving columns, packed at the front of a fixed working
/// cap, with `carry_pos` mapping each column to its absolute prompt position.
#[derive(Clone, Copy)]
pub struct ChunkEvictReq<'a> {
    pub x_chunk: &'a Tensor, // [C, d] (rows >= chunk_len are padding)
    /// Compacted carry K/V at the working cap `[Hk, cap, dh]`; live columns
    /// are packed at the front, rows >= the live count are unspecified.
    ///
    /// Under chunk-major streaming with Q8 carries these borrow the
    /// session's *shared dequantization scratch*, valid only for the
    /// duration of this call and overwritten when the next lane dispatches
    /// — backends must not retain references past the call. Q8 lanes round
    /// trip within `kvcache::q8_tolerance` of the f32 values a layer-major
    /// run would carry; f32 lanes are bit-exact.
    pub carry_k: &'a Tensor,
    pub carry_v: &'a Tensor,
    /// Absolute prompt position of each carry column (`cap` entries,
    /// strictly ascending, all `< start`), then `-1` padding.
    pub carry_pos: &'a [i32],
    pub start: usize,
    pub chunk_len: usize,
    pub total_len: usize,
    /// Monolithic observation bucket the prompt would have used. The real
    /// model ignores it; the mock hashes against it so streamed scores at
    /// surviving columns rank exactly like the one-shot pass.
    pub n_obs: usize,
}

/// Output of a streaming-evict chunk. Observation panels come back at the
/// *compact* width `m = cap + C`: column `j < cap` is carry column `j`
/// (absolute position `carry_pos[j]`), column `cap + r` is chunk row `r`
/// (absolute position `start + r`). Dead columns contribute zeros.
pub struct ChunkEvictOut {
    pub x_out: Tensor, // [C, d]
    pub k: Tensor,     // [Hk, C, dh]
    pub v: Tensor,     // [Hk, C, dh]
    /// Window-attention rows owned by this chunk, keyed by *absolute* query
    /// position: `(qpos, row)` where `row` is `[H * m]` over compact columns.
    /// Covers `qpos` in `[max(start, seen - w), seen)`, `seen = start +
    /// chunk_len` — the rows the rolling observation window still needs.
    pub win_rows: Vec<(usize, Vec<f32>)>,
    /// Additive accumulated-attention contribution `[H * m]`.
    pub acc: Vec<f32>,
    /// Additive value-norm contribution `[Hk * m]`.
    pub vnorm: Vec<f32>,
}

/// Output of one layer's decode step.
pub struct DecodeOut {
    pub x_out: Tensor,  // [1, d]
    pub k_new: Vec<f32>, // [Hk*dh]
    pub v_new: Vec<f32>,
    /// [H, M+1] attention over cache slots; column M is the new token.
    pub attn: Tensor,
}

/// Output of one layer's decode step over a batch of B sessions sharing one
/// capacity bucket. The residual stream stays packed ([B, d] in, [B, d] out);
/// per-session K/V/attn come back unpacked because the engine scatters them
/// into B independent caches anyway.
pub struct DecodeBatchOut {
    pub x_out: Tensor, // [B, d]
    /// Per-session new K rows, each [Hk*dh].
    pub k_new: Vec<Vec<f32>>,
    pub v_new: Vec<Vec<f32>>,
    /// Per-session attention [H, M+1]; column M is the new token.
    pub attn: Vec<Tensor>,
    /// How many real backend executions served this call: 1 for a fully
    /// vectorized implementation, B for the per-session fallback, in
    /// between when a PJRT batch is chunked onto the lowered artifact
    /// sizes. Feeds the per-bucket dispatch gauge truthfully.
    pub dispatches: usize,
}

/// `Send + Sync` is part of the contract: every dispatch entry point takes
/// `&self`, and the engine worker pool shares one backend across N scoped
/// worker threads (per-bucket decode groups and prefill batch members run
/// concurrently). The PJRT runtime serializes its executable cache behind
/// mutexes; the mock backend is plain data.
pub trait ModelBackend: Send + Sync {
    fn config(&self) -> &ModelConfig;
    fn prefill_buckets(&self) -> &[usize];
    fn decode_buckets(&self) -> &[usize];

    /// Host-side token embedding: ids -> [n, d] (padded to `bucket` rows).
    fn embed(&self, ids: &[i32], bucket: usize) -> Result<Tensor>;

    fn layer_prefill(&self, layer: usize, x: &Tensor, length: usize) -> Result<PrefillOut>;

    /// One chunk of a layer's prefill: `x_chunk` is the chunk's residual
    /// stream padded to a *tight* chunk bucket `[C, d]`, `carry_k`/`carry_v`
    /// are the layer's K/V accumulated from prior chunks at observation width
    /// `[Hk, n_obs, dh]` (rows >= `start` are unspecified and must not be
    /// read). The chunk covers absolute positions `[start, start+chunk_len)`
    /// of a `total_len`-token prompt. Accumulating every chunk's output must
    /// reproduce the monolithic [`ModelBackend::layer_prefill`] at bucket
    /// `n_obs` exactly — the chunked-prefill equivalence suite holds each
    /// backend to it. Default: unsupported (the engine falls back to the
    /// monolithic path when [`ModelBackend::supports_chunked_prefill`] says
    /// no).
    #[allow(unused_variables)]
    fn layer_prefill_chunked(
        &self,
        layer: usize,
        x_chunk: &Tensor,
        carry_k: &Tensor,
        carry_v: &Tensor,
        start: usize,
        chunk_len: usize,
        total_len: usize,
    ) -> Result<ChunkPrefillOut> {
        Err(anyhow!("backend has no chunked prefill implementation"))
    }

    /// Whether [`ModelBackend::layer_prefill_chunked`] can serve a chunk of
    /// bucket `chunk_bucket` against a carry of width `n_obs` (for PJRT this
    /// asks the artifact set for `layer_prefill_chunked_{C}x{N}`; the
    /// per-chunk fallback routes unsupported prompts to the monolithic path).
    fn supports_chunked_prefill(&self, _chunk_bucket: usize, _n_obs: usize) -> bool {
        false
    }

    /// One chunk of a layer's prefill against a compacted carry (streaming
    /// eviction, see [`ChunkEvictReq`]). Default: unsupported — the engine
    /// only takes this path when [`ModelBackend::supports_chunked_evict`]
    /// says yes for the chunk bucket / cap pair.
    #[allow(unused_variables)]
    fn layer_prefill_chunked_evict(
        &self,
        layer: usize,
        req: &ChunkEvictReq,
    ) -> Result<ChunkEvictOut> {
        Err(anyhow!("backend has no streaming-evict chunked prefill implementation"))
    }

    /// Whether [`ModelBackend::layer_prefill_chunked_evict`] can serve a
    /// chunk of bucket `chunk_bucket` against a compacted carry of width
    /// `cap` (for PJRT this asks the artifact set for
    /// `layer_prefill_chunked_evict_{C}x{cap}`).
    fn supports_chunked_evict(&self, _chunk_bucket: usize, _cap: usize) -> bool {
        false
    }

    /// Streaming-evict chunks for B sessions sharing one (chunk bucket, cap)
    /// shape, one logical dispatch. Returns the per-session outputs in
    /// request order plus how many real backend executions served the call
    /// (feeds the prefill dispatch gauge truthfully, like
    /// [`DecodeBatchOut::dispatches`]). This default loops the serial form;
    /// backends with a vectorized path override it.
    fn layer_prefill_chunked_evict_batched(
        &self,
        layer: usize,
        reqs: &[ChunkEvictReq],
    ) -> Result<(Vec<ChunkEvictOut>, usize)> {
        let mut outs = Vec::with_capacity(reqs.len());
        for req in reqs {
            outs.push(self.layer_prefill_chunked_evict(layer, req)?);
        }
        Ok((outs, reqs.len()))
    }

    /// Decode is a hot-tier-only operation: the cache handed in here is
    /// always a resident [`HotStore`] (the tier manager prefetches warm
    /// layers before the engine reaches this boundary).
    fn layer_decode(
        &self,
        layer: usize,
        x: &Tensor,
        cache: &HotStore,
        pos: usize,
    ) -> Result<DecodeOut>;

    /// One layer's decode step for B sessions sharing a capacity bucket:
    /// `xs` is the packed [B, d] residual stream, `caches[i]` / `positions[i]`
    /// belong to session i. Implementations must be bit-identical to calling
    /// [`ModelBackend::layer_decode`] per session — the engine's batched and
    /// serial decode paths are interchangeable, and the equivalence suite
    /// holds every backend to it. This default does exactly that loop;
    /// backends with a real batched dispatch override it.
    fn layer_decode_batched(
        &self,
        layer: usize,
        xs: &Tensor,
        caches: &[&HotStore],
        positions: &[usize],
    ) -> Result<DecodeBatchOut> {
        let b = caches.len();
        if xs.shape != [b, self.config().d_model] || positions.len() != b {
            return Err(anyhow!(
                "layer_decode_batched: xs {:?} / {} caches / {} positions disagree",
                xs.shape,
                b,
                positions.len()
            ));
        }
        let d = self.config().d_model;
        let xf = xs.as_f32()?;
        let mut x_out = vec![0.0f32; b * d];
        let mut k_new = Vec::with_capacity(b);
        let mut v_new = Vec::with_capacity(b);
        let mut attn = Vec::with_capacity(b);
        for i in 0..b {
            let xi = Tensor::f32(xf[i * d..(i + 1) * d].to_vec(), &[1, d]);
            let out = self.layer_decode(layer, &xi, caches[i], positions[i])?;
            x_out[i * d..(i + 1) * d].copy_from_slice(&out.x_out.as_f32()?[..d]);
            k_new.push(out.k_new);
            v_new.push(out.v_new);
            attn.push(out.attn);
        }
        Ok(DecodeBatchOut {
            x_out: Tensor::f32(x_out, &[b, d]),
            k_new,
            v_new,
            attn,
            dispatches: b,
        })
    }

    fn logits(&self, x: &Tensor) -> Result<Vec<f32>>;

    /// Optional fused LAVa scoring fast path (the L1 Pallas kernel artifact);
    /// `None` -> the engine computes scores host-side.
    fn fused_lava_score(
        &self,
        _win_attn: &Tensor,
        _v: &Tensor,
        _length: usize,
    ) -> Result<Option<Vec<Vec<f32>>>> {
        Ok(None)
    }

    /// Distinct accelerator device slots persistent-pool workers can pin
    /// (1 = one shared device). Workers bind their stable `worker_id` as
    /// the slot; backends map it onto this count (`slot % device_count()`).
    fn device_count(&self) -> usize {
        1
    }

    /// Pin the calling thread to device slot `slot % device_count()`.
    /// The engine calls this lazily, once per [`WorkerContext`] before its
    /// first dispatch, so a PJRT backend can bind one device per pool
    /// worker. Contract: the pool never asks one thread to bind two
    /// different slots (a worker's slot is stable for its lifetime);
    /// re-binding the same slot must be a no-op. Default: no-op for
    /// single-device backends.
    ///
    /// [`WorkerContext`]: crate::coordinator::pool::WorkerContext
    fn bind_device(&self, _slot: usize) {}
}

// ---------------------------------------------------------------- PJRT

pub struct PjrtBackend {
    pub runtime: Runtime,
    cfg: ModelConfig,
    buckets_prefill: Vec<usize>,
    buckets_decode: Vec<usize>,
    /// Batch sizes B with a lowered `layer_decode_batched_{M}x{B}` artifact
    /// (ascending; empty on pre-batching artifact sets).
    buckets_decode_batch: Vec<usize>,
    weights_host: Weights,
    // device-resident weights
    layer_bufs: Vec<Vec<xla::PjRtBuffer>>,
    ln_f_buf: xla::PjRtBuffer,
    unembed_buf: xla::PjRtBuffer,
    /// Use the fused lava_score_{N} artifact when available.
    pub use_fused_score: bool,
}

impl PjrtBackend {
    pub fn load(artifact_dir: &str) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifact_dir)?;
        let weights = Weights::load(&manifest)?;
        let runtime = Runtime::new(artifact_dir)?;
        let mut layer_bufs = Vec::with_capacity(manifest.model.n_layers);
        for lw in &weights.layers {
            let mut bufs = Vec::with_capacity(lw.len());
            for t in lw {
                bufs.push(runtime.upload(t)?);
            }
            layer_bufs.push(bufs);
        }
        let ln_f_buf = runtime.upload(&weights.ln_f)?;
        let unembed_buf = runtime.upload(&weights.unembed)?;
        Ok(PjrtBackend {
            runtime,
            cfg: manifest.model.clone(),
            buckets_prefill: manifest.buckets.prefill.clone(),
            buckets_decode: manifest.buckets.decode.clone(),
            buckets_decode_batch: manifest.buckets.decode_batch.clone(),
            weights_host: weights,
            layer_bufs,
            ln_f_buf,
            unembed_buf,
            use_fused_score: true,
        })
    }

    fn layer_args<'a>(&'a self, layer: usize) -> Vec<Arg<'a>> {
        self.layer_bufs[layer].iter().map(Arg::Device).collect()
    }
}

impl ModelBackend for PjrtBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets_prefill
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets_decode
    }

    fn embed(&self, ids: &[i32], bucket: usize) -> Result<Tensor> {
        let d = self.cfg.d_model;
        let emb = self.weights_host.tok_emb.as_f32()?;
        let mut x = vec![0.0f32; bucket * d];
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            x[i * d..(i + 1) * d].copy_from_slice(&emb[id * d..(id + 1) * d]);
        }
        // padding rows embed PAD (keeps parity with the python reference)
        let pad = self.cfg.pad_id as usize;
        for i in ids.len()..bucket {
            x[i * d..(i + 1) * d].copy_from_slice(&emb[pad * d..(pad + 1) * d]);
        }
        Ok(Tensor::f32(x, &[bucket, d]))
    }

    fn layer_prefill(&self, layer: usize, x: &Tensor, length: usize) -> Result<PrefillOut> {
        let n = x.shape[0];
        let name = format!("layer_prefill_{n}");
        let len_t = Tensor::scalar_i32(length as i32);
        let mut args: Vec<Arg> = vec![Arg::Host(x), Arg::Host(&len_t)];
        args.extend(self.layer_args(layer));
        let mut out = self.runtime.execute(&name, &args)?;
        if out.len() != 6 {
            return Err(anyhow!("{name}: expected 6 outputs, got {}", out.len()));
        }
        let vnorm = out.pop().unwrap();
        let acc_attn = out.pop().unwrap();
        let win_attn = out.pop().unwrap();
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let x_out = out.pop().unwrap();
        Ok(PrefillOut {
            x_out,
            k,
            v,
            obs: LayerObs { win_attn, acc_attn, vnorm, length },
        })
    }

    /// Chunked prefill through the `layer_prefill_chunked_{C}x{N}` artifacts:
    /// the artifact computes the chunk's attention over carry + chunk keys
    /// and returns the full-width observation contributions (window panel
    /// with non-owned rows zeroed, which we convert to owned rows here).
    fn layer_prefill_chunked(
        &self,
        layer: usize,
        x_chunk: &Tensor,
        carry_k: &Tensor,
        carry_v: &Tensor,
        start: usize,
        chunk_len: usize,
        total_len: usize,
    ) -> Result<ChunkPrefillOut> {
        let c = x_chunk.shape[0];
        let n = carry_k.shape[1];
        let name = format!("layer_prefill_chunked_{c}x{n}");
        let meta = Tensor::i32(vec![start as i32, chunk_len as i32, total_len as i32], &[3]);
        let mut args: Vec<Arg> = vec![
            Arg::Host(x_chunk),
            Arg::Host(carry_k),
            Arg::Host(carry_v),
            Arg::Host(&meta),
        ];
        args.extend(self.layer_args(layer));
        let mut out = self.runtime.execute(&name, &args)?;
        if out.len() != 6 {
            return Err(anyhow!("{name}: expected 6 outputs, got {}", out.len()));
        }
        let vnorm = out.pop().unwrap().into_f32()?;
        let acc = out.pop().unwrap().into_f32()?;
        let win_panel = out.pop().unwrap().into_f32()?; // [H, w, n], non-owned rows zero
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let x_out = out.pop().unwrap();
        let (h, w) = (self.cfg.n_heads, self.cfg.window);
        let mut win_rows = Vec::new();
        for r in 0..w {
            let qpos = total_len - w + r;
            if qpos < start || qpos >= start + chunk_len {
                continue;
            }
            let mut row = vec![0.0f32; h * n];
            for hh in 0..h {
                row[hh * n..(hh + 1) * n]
                    .copy_from_slice(&win_panel[(hh * w + r) * n..(hh * w + r + 1) * n]);
            }
            win_rows.push((r, row));
        }
        Ok(ChunkPrefillOut { x_out, k, v, win_rows, acc, vnorm })
    }

    fn supports_chunked_prefill(&self, chunk_bucket: usize, n_obs: usize) -> bool {
        self.runtime
            .has_artifact(&format!("layer_prefill_chunked_{chunk_bucket}x{n_obs}"))
    }

    /// Streaming-evict chunks through the
    /// `layer_prefill_chunked_evict_{C}x{cap}` artifacts: the artifact takes
    /// the compacted carry plus its position map and returns compact-width
    /// observation panels (`cap + C` columns); the window panel row `r`
    /// holds query position `start + chunk_len - w + r`, with rows owned by
    /// earlier chunks zeroed, which we convert to owned rows here.
    fn layer_prefill_chunked_evict(
        &self,
        layer: usize,
        req: &ChunkEvictReq,
    ) -> Result<ChunkEvictOut> {
        let c = req.x_chunk.shape[0];
        let cap = req.carry_k.shape[1];
        let name = format!("layer_prefill_chunked_evict_{c}x{cap}");
        let n_live = req.carry_pos.iter().take_while(|&&p| p >= 0).count();
        let pos_t = Tensor::i32(req.carry_pos.to_vec(), &[cap]);
        let meta = Tensor::i32(
            vec![req.start as i32, req.chunk_len as i32, req.total_len as i32, n_live as i32],
            &[4],
        );
        let mut args: Vec<Arg> = vec![
            Arg::Host(req.x_chunk),
            Arg::Host(req.carry_k),
            Arg::Host(req.carry_v),
            Arg::Host(&pos_t),
            Arg::Host(&meta),
        ];
        args.extend(self.layer_args(layer));
        let mut out = self.runtime.execute(&name, &args)?;
        if out.len() != 6 {
            return Err(anyhow!("{name}: expected 6 outputs, got {}", out.len()));
        }
        let vnorm = out.pop().unwrap().into_f32()?;
        let acc = out.pop().unwrap().into_f32()?;
        let win_panel = out.pop().unwrap().into_f32()?; // [H, w, cap+c]
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let x_out = out.pop().unwrap();
        let (h, w) = (self.cfg.n_heads, self.cfg.window);
        let m = cap + c;
        let seen = req.start + req.chunk_len;
        let mut win_rows = Vec::new();
        for r in 0..w {
            let q = seen as i64 - w as i64 + r as i64;
            if q < req.start as i64 {
                continue;
            }
            let mut row = vec![0.0f32; h * m];
            for hh in 0..h {
                row[hh * m..(hh + 1) * m]
                    .copy_from_slice(&win_panel[(hh * w + r) * m..(hh * w + r + 1) * m]);
            }
            win_rows.push((q as usize, row));
        }
        Ok(ChunkEvictOut { x_out, k, v, win_rows, acc, vnorm })
    }

    fn supports_chunked_evict(&self, chunk_bucket: usize, cap: usize) -> bool {
        self.runtime
            .has_artifact(&format!("layer_prefill_chunked_evict_{chunk_bucket}x{cap}"))
    }

    fn layer_decode(
        &self,
        layer: usize,
        x: &Tensor,
        cache: &HotStore,
        pos: usize,
    ) -> Result<DecodeOut> {
        let m = cache.capacity();
        let name = format!("layer_decode_{m}");
        // borrowed views: no K/V/valid buffer copies on the decode hot path
        let (k, v, valid) = cache.decode_tensors();
        let pos_t = Tensor::scalar_i32(pos as i32);
        let mut args: Vec<Arg> =
            vec![Arg::Host(x), Arg::Host(k), Arg::Host(v), Arg::Host(valid), Arg::Host(&pos_t)];
        args.extend(self.layer_args(layer));
        let mut out = self.runtime.execute(&name, &args)?;
        if out.len() != 4 {
            return Err(anyhow!("{name}: expected 4 outputs, got {}", out.len()));
        }
        let attn = out.pop().unwrap();
        let v_new = out.pop().unwrap().into_f32()?;
        let k_new = out.pop().unwrap().into_f32()?;
        let x_out = out.pop().unwrap();
        Ok(DecodeOut { x_out, k_new, v_new, attn })
    }

    /// Batched decode through the `layer_decode_batched_{M}x{B}` artifacts:
    /// the batch is chunked greedily onto the largest lowered batch size that
    /// fits, and any remainder (or a pre-batching artifact set) falls back to
    /// per-session `layer_decode_{M}` calls.
    fn layer_decode_batched(
        &self,
        layer: usize,
        xs: &Tensor,
        caches: &[&HotStore],
        positions: &[usize],
    ) -> Result<DecodeBatchOut> {
        let b = caches.len();
        let d = self.cfg.d_model;
        if b == 0 || xs.shape != [b, d] || positions.len() != b {
            return Err(anyhow!(
                "layer_decode_batched: xs {:?} / {} caches / {} positions disagree",
                xs.shape,
                b,
                positions.len()
            ));
        }
        let m = caches[0].capacity();
        if caches.iter().any(|c| c.capacity() != m) {
            return Err(anyhow!("layer_decode_batched: caches must share one capacity bucket"));
        }
        let xf = xs.as_f32()?;
        let mut x_out = vec![0.0f32; b * d];
        let mut k_new = Vec::with_capacity(b);
        let mut v_new = Vec::with_capacity(b);
        let mut attn = Vec::with_capacity(b);
        let mut dispatches = 0;
        let mut i = 0;
        while i < b {
            let step = match self.batched_artifact_size(m, b - i) {
                Some(bb) => {
                    let xc = Tensor::f32(xf[i * d..(i + bb) * d].to_vec(), &[bb, d]);
                    let out = self.decode_batched_exec(
                        layer,
                        &xc,
                        &caches[i..i + bb],
                        &positions[i..i + bb],
                    )?;
                    x_out[i * d..(i + bb) * d].copy_from_slice(&out.x_out.as_f32()?[..bb * d]);
                    k_new.extend(out.k_new);
                    v_new.extend(out.v_new);
                    attn.extend(out.attn);
                    bb
                }
                None => {
                    let xi = Tensor::f32(xf[i * d..(i + 1) * d].to_vec(), &[1, d]);
                    let out = self.layer_decode(layer, &xi, caches[i], positions[i])?;
                    x_out[i * d..(i + 1) * d].copy_from_slice(&out.x_out.as_f32()?[..d]);
                    k_new.push(out.k_new);
                    v_new.push(out.v_new);
                    attn.push(out.attn);
                    1
                }
            };
            dispatches += 1;
            i += step;
        }
        Ok(DecodeBatchOut { x_out: Tensor::f32(x_out, &[b, d]), k_new, v_new, attn, dispatches })
    }

    fn logits(&self, x: &Tensor) -> Result<Vec<f32>> {
        let out = self.runtime.execute(
            "logits",
            &[Arg::Host(x), Arg::Device(&self.ln_f_buf), Arg::Device(&self.unembed_buf)],
        )?;
        out.into_iter()
            .next()
            .ok_or_else(|| anyhow!("logits: no output"))?
            .into_f32()
    }

    fn fused_lava_score(
        &self,
        win_attn: &Tensor,
        v: &Tensor,
        length: usize,
    ) -> Result<Option<Vec<Vec<f32>>>> {
        if !self.use_fused_score {
            return Ok(None);
        }
        let n = win_attn.shape[2];
        let name = format!("lava_score_{n}");
        if !self.runtime.has_artifact(&name) {
            return Ok(None);
        }
        self.lava_score_artifact(win_attn, v, length).map(Some)
    }
}

impl PjrtBackend {
    /// Largest lowered decode batch size usable for `rest` more sessions at
    /// capacity bucket `m` (None when no batched artifact applies).
    fn batched_artifact_size(&self, m: usize, rest: usize) -> Option<usize> {
        self.buckets_decode_batch
            .iter()
            .rev()
            .copied()
            .find(|&bb| {
                bb > 1
                    && bb <= rest
                    && self.runtime.has_artifact(&format!("layer_decode_batched_{m}x{bb}"))
            })
    }

    /// One `layer_decode_batched_{M}x{B}` dispatch over exactly B sessions.
    fn decode_batched_exec(
        &self,
        layer: usize,
        xs: &Tensor,
        caches: &[&HotStore],
        positions: &[usize],
    ) -> Result<DecodeBatchOut> {
        let bb = caches.len();
        let m = caches[0].capacity();
        let name = format!("layer_decode_batched_{m}x{bb}");
        let view = HotStore::batch_decode_tensors(caches);
        // the one gather on this path: the runtime needs contiguous [B, …]
        // buffers at the upload boundary (same cost class as the upload)
        let k = view.pack_k();
        let v = view.pack_v();
        let valid = view.pack_valid();
        let pos_t = Tensor::i32(positions.iter().map(|&p| p as i32).collect(), &[bb]);
        let mut args: Vec<Arg> =
            vec![Arg::Host(xs), Arg::Host(&k), Arg::Host(&v), Arg::Host(&valid), Arg::Host(&pos_t)];
        args.extend(self.layer_args(layer));
        let mut out = self.runtime.execute(&name, &args)?;
        if out.len() != 4 {
            return Err(anyhow!("{name}: expected 4 outputs, got {}", out.len()));
        }
        let attn_all = out.pop().unwrap().into_f32()?; // [B, H, M+1]
        let v_new_all = out.pop().unwrap().into_f32()?; // [B, Hk, dh]
        let k_new_all = out.pop().unwrap().into_f32()?;
        let x_out = out.pop().unwrap();
        let h = self.cfg.n_heads;
        let hkdh = self.cfg.n_kv_heads * self.cfg.d_head;
        let m1 = m + 1;
        let mut k_new = Vec::with_capacity(bb);
        let mut v_new = Vec::with_capacity(bb);
        let mut attn = Vec::with_capacity(bb);
        for i in 0..bb {
            k_new.push(k_new_all[i * hkdh..(i + 1) * hkdh].to_vec());
            v_new.push(v_new_all[i * hkdh..(i + 1) * hkdh].to_vec());
            attn.push(Tensor::f32(attn_all[i * h * m1..(i + 1) * h * m1].to_vec(), &[h, m1]));
        }
        Ok(DecodeBatchOut { x_out, k_new, v_new, attn, dispatches: 1 })
    }

    /// Fused LAVa scoring through the L1 Pallas kernel artifact.
    pub fn lava_score_artifact(
        &self,
        win_attn: &Tensor,
        v: &Tensor,
        length: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let n = win_attn.shape[2];
        let name = format!("lava_score_{n}");
        let len_t = Tensor::scalar_i32(length as i32);
        let out = self
            .runtime
            .execute(&name, &[Arg::Host(win_attn), Arg::Host(v), Arg::Host(&len_t)])?;
        let scores = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("lava_score: no output"))?;
        let hk = scores.shape[0];
        let data = scores.into_f32()?;
        Ok((0..hk).map(|h| data[h * n..h * n + length].to_vec()).collect())
    }
}

// ---------------------------------------------------------------- mock

/// Deterministic synthetic model. Attention is peaked at `hot_positions`
/// (plus a local-recency component), values have per-position norms, and
/// hidden states are cheap hashes — enough structure for every policy and
/// scheduler test, at ~zero cost, any context length.
pub struct MockBackend {
    cfg: ModelConfig,
    /// Public so tests can shrink the bucket ladder (e.g. to exercise
    /// over-largest-bucket admission without megatoken prompts).
    pub buckets_prefill: Vec<usize>,
    pub buckets_decode: Vec<usize>,
    pub hot_positions: Vec<usize>,
    pub seed: u64,
    /// Mock accelerator slots ([`ModelBackend::device_count`]): two, so a
    /// multi-worker pool exercises a non-trivial `slot -> device` mapping.
    pub mock_devices: usize,
    /// `thread -> device` recorded by [`ModelBackend::bind_device`]. The
    /// mock *asserts* pinning: a thread that re-binds a different device
    /// than it already holds panics (the pool contract is one stable slot
    /// per worker thread).
    bindings: Mutex<Vec<(std::thread::ThreadId, usize)>>,
    /// Test poison knob: panic inside `embed` when the ids contain this
    /// token — exercises the pool's panic containment on the prefill path.
    pub panic_on_embed_token: Option<i32>,
    /// Test poison knob: panic inside the decode core at this position —
    /// exercises panic containment on the decode path (only the session
    /// whose decode crosses the position is poisoned).
    pub panic_at_decode_pos: Option<usize>,
}

impl MockBackend {
    pub fn new(cfg: ModelConfig) -> MockBackend {
        MockBackend {
            cfg,
            buckets_prefill: vec![128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 131072, 262144],
            buckets_decode: vec![128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 131072, 262144],
            hot_positions: vec![],
            seed: 0,
            mock_devices: 2,
            bindings: Mutex::new(Vec::new()),
            panic_on_embed_token: None,
            panic_at_decode_pos: None,
        }
    }

    /// The `(thread, device)` bindings recorded so far (tests assert the
    /// pool pinned every worker and stayed within `device_count`).
    pub fn device_bindings(&self) -> Vec<(std::thread::ThreadId, usize)> {
        self.bindings.lock().expect("mock bindings").clone()
    }

    /// Default config mirroring the build-time python model.
    pub fn default_config() -> ModelConfig {
        ModelConfig {
            vocab_size: 260,
            n_layers: 4,
            n_heads: 8,
            n_kv_heads: 4,
            d_model: 128,
            d_head: 16,
            d_ff: 256,
            window: 16,
            max_seq_len: 131072,
            bos_id: 256,
            sep_id: 257,
            query_id: 258,
            pad_id: 259,
        }
    }

    fn h01(&self, a: u64, b: u64, c: u64) -> f32 {
        let mut r = Rng::new(self.seed ^ a.wrapping_mul(0x9E37).wrapping_add(b) ^ (c << 32));
        r.f32()
    }

    /// Core decode math for one session: attention row [H*(M+1)] plus the new
    /// K/V rows. Shared by the serial and batched entrypoints so the two are
    /// bit-identical by construction.
    fn decode_core(
        &self,
        layer: usize,
        cache: &HotStore,
        pos: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        if self.panic_at_decode_pos == Some(pos) {
            panic!("mock poison: decode at position {pos}");
        }
        let cfg = &self.cfg;
        let (h, hk, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head);
        let m = cache.capacity();
        let l64 = layer as u64;
        let mut attn = vec![0.0f32; h * (m + 1)];
        for hh in 0..h {
            let kv = hh / (h / hk);
            let live = cache.head_len(kv);
            let mut sum = 0.0f32;
            for i in 0..live {
                let p = cache.position(kv, i).max(0) as usize;
                let mut a = 0.05 + self.h01(l64 + hh as u64, p as u64, 7);
                if pos.saturating_sub(p) < 8 {
                    a += 1.0;
                }
                if self.hot_positions.contains(&p) {
                    a += 6.0;
                }
                attn[hh * (m + 1) + i] = a;
                sum += a;
            }
            attn[hh * (m + 1) + m] = 1.0; // self
            sum += 1.0;
            for i in 0..=m {
                attn[hh * (m + 1) + i] /= sum;
            }
        }
        let k_new: Vec<f32> =
            (0..hk * dh).map(|i| self.h01(l64 * 91, (pos * 64 + i) as u64, 8) - 0.5).collect();
        let v_new: Vec<f32> =
            (0..hk * dh).map(|i| self.h01(l64 * 93, (pos * 64 + i) as u64, 9) - 0.5).collect();
        (attn, k_new, v_new)
    }
}

impl ModelBackend for MockBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets_prefill
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets_decode
    }

    fn embed(&self, ids: &[i32], bucket: usize) -> Result<Tensor> {
        if let Some(poison) = self.panic_on_embed_token {
            if ids.contains(&poison) {
                panic!("mock poison: embed saw token {poison}");
            }
        }
        let d = self.cfg.d_model;
        let mut x = vec![0.0f32; bucket * d];
        for (i, &id) in ids.iter().enumerate() {
            for j in 0..d {
                x[i * d + j] = self.h01(id as u64, j as u64, 1) - 0.5;
            }
        }
        Ok(Tensor::f32(x, &[bucket, d]))
    }

    fn layer_prefill(&self, layer: usize, x: &Tensor, length: usize) -> Result<PrefillOut> {
        let cfg = &self.cfg;
        let n = x.shape[0];
        let (h, hk, w, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head);
        let l64 = layer as u64;

        let mut win = vec![0.0f32; h * w * n];
        for hh in 0..h {
            for r in 0..w {
                let qpos = length - w + r;
                let mut sum = 0.0f32;
                for i in 0..=qpos {
                    let mut a = 0.02 + self.h01(l64 * 131 + hh as u64, (r * n + i) as u64, 2);
                    // recency bump + hot positions (head-dependent strength)
                    if qpos - i < 8 {
                        a += 1.0;
                    }
                    if self.hot_positions.contains(&i) {
                        a += 6.0 * (1.0 + (hh as f32 * 0.5)); // heads differ -> dynamic budgets matter
                    }
                    win[(hh * w + r) * n + i] = a;
                    sum += a;
                }
                for i in 0..=qpos {
                    win[(hh * w + r) * n + i] /= sum;
                }
            }
        }
        let mut acc = vec![0.0f32; h * n];
        for hh in 0..h {
            for i in 0..length {
                let base = self.h01(l64 * 37 + hh as u64, i as u64, 3);
                let hot = if self.hot_positions.contains(&i) { 4.0 } else { 0.0 };
                acc[hh * n + i] = base + hot + (length - i) as f32 * 0.01;
            }
        }
        let mut vn = vec![0.0f32; hk * n];
        for kv in 0..hk {
            for i in 0..length {
                vn[kv * n + i] = 0.5 + self.h01(l64 * 57 + kv as u64, i as u64, 4);
            }
        }
        let kdata: Vec<f32> = (0..hk * n * dh)
            .map(|i| self.h01(l64 * 71, i as u64, 5) - 0.5)
            .collect();
        let vdata: Vec<f32> = (0..hk * n * dh)
            .map(|i| self.h01(l64 * 83, i as u64, 6) - 0.5)
            .collect();
        Ok(PrefillOut {
            x_out: x.clone(),
            k: Tensor::f32(kdata, &[hk, n, dh]),
            v: Tensor::f32(vdata, &[hk, n, dh]),
            obs: LayerObs {
                win_attn: Tensor::f32(win, &[h, w, n]),
                acc_attn: Tensor::f32(acc, &[h, n]),
                vnorm: Tensor::f32(vn, &[hk, n]),
                length,
            },
        })
    }

    /// Vectorized chunked prefill. Every hash is indexed exactly as the
    /// monolithic [`MockBackend::layer_prefill`] at bucket `n_obs` (read off
    /// the carry width), so accumulating the chunks is bit-identical to the
    /// one-shot pass: window rows are emitted whole by the chunk owning
    /// their query position, acc/vnorm columns by the chunk owning the
    /// position, and K/V rows use the monolithic flat index
    /// `(kv * n_obs + pos) * dh + j`.
    fn layer_prefill_chunked(
        &self,
        layer: usize,
        x_chunk: &Tensor,
        carry_k: &Tensor,
        _carry_v: &Tensor,
        start: usize,
        chunk_len: usize,
        total_len: usize,
    ) -> Result<ChunkPrefillOut> {
        let cfg = &self.cfg;
        let (h, hk, w, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head);
        let c = x_chunk.shape[0];
        let n = carry_k.shape[1]; // observation width = monolithic bucket
        if chunk_len == 0 || chunk_len > c || start + chunk_len > total_len || total_len > n {
            return Err(anyhow!(
                "layer_prefill_chunked: chunk [{start}, {}) of {total_len} (bucket {c}, obs {n}) is malformed",
                start + chunk_len
            ));
        }
        let l64 = layer as u64;

        let mut win_rows = Vec::new();
        for r in 0..w {
            let qpos = total_len - w + r;
            if qpos < start || qpos >= start + chunk_len {
                continue;
            }
            let mut row = vec![0.0f32; h * n];
            for hh in 0..h {
                let mut sum = 0.0f32;
                for i in 0..=qpos {
                    let mut a = 0.02 + self.h01(l64 * 131 + hh as u64, (r * n + i) as u64, 2);
                    if qpos - i < 8 {
                        a += 1.0;
                    }
                    if self.hot_positions.contains(&i) {
                        a += 6.0 * (1.0 + (hh as f32 * 0.5));
                    }
                    row[hh * n + i] = a;
                    sum += a;
                }
                for i in 0..=qpos {
                    row[hh * n + i] /= sum;
                }
            }
            win_rows.push((r, row));
        }
        let mut acc = vec![0.0f32; h * n];
        for hh in 0..h {
            for i in start..start + chunk_len {
                let base = self.h01(l64 * 37 + hh as u64, i as u64, 3);
                let hot = if self.hot_positions.contains(&i) { 4.0 } else { 0.0 };
                acc[hh * n + i] = base + hot + (total_len - i) as f32 * 0.01;
            }
        }
        let mut vn = vec![0.0f32; hk * n];
        for kv in 0..hk {
            for i in start..start + chunk_len {
                vn[kv * n + i] = 0.5 + self.h01(l64 * 57 + kv as u64, i as u64, 4);
            }
        }
        let mut kdata = vec![0.0f32; hk * c * dh];
        let mut vdata = vec![0.0f32; hk * c * dh];
        for kv in 0..hk {
            for row in 0..chunk_len {
                for j in 0..dh {
                    let flat = (kv * n + start + row) * dh + j;
                    kdata[(kv * c + row) * dh + j] = self.h01(l64 * 71, flat as u64, 5) - 0.5;
                    vdata[(kv * c + row) * dh + j] = self.h01(l64 * 83, flat as u64, 6) - 0.5;
                }
            }
        }
        Ok(ChunkPrefillOut {
            x_out: x_chunk.clone(),
            k: Tensor::f32(kdata, &[hk, c, dh]),
            v: Tensor::f32(vdata, &[hk, c, dh]),
            win_rows,
            acc,
            vnorm: vn,
        })
    }

    fn supports_chunked_prefill(&self, _chunk_bucket: usize, _n_obs: usize) -> bool {
        true
    }

    /// Streaming-evict chunk. K/V and the final observation window hash
    /// against the *absolute* position at the monolithic bucket `n_obs`, so
    /// surviving columns score exactly as they would in the one-shot pass;
    /// mid-stream window rows (query positions before `total_len - w`) only
    /// exist in streaming mode and get their own collision-free hash keys.
    fn layer_prefill_chunked_evict(
        &self,
        layer: usize,
        req: &ChunkEvictReq,
    ) -> Result<ChunkEvictOut> {
        let cfg = &self.cfg;
        let (h, hk, w, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head);
        let c = req.x_chunk.shape[0];
        let cap = req.carry_k.shape[1];
        let (start, chunk_len) = (req.start, req.chunk_len);
        let (total_len, n_obs) = (req.total_len, req.n_obs);
        if chunk_len == 0 || chunk_len > c || start + chunk_len > total_len || total_len > n_obs {
            return Err(anyhow!(
                "layer_prefill_chunked_evict: chunk [{start}, {}) of {total_len} (bucket {c}, obs {n_obs}) is malformed",
                start + chunk_len
            ));
        }
        if req.carry_pos.len() != cap {
            return Err(anyhow!(
                "layer_prefill_chunked_evict: carry_pos has {} entries for cap {cap}",
                req.carry_pos.len()
            ));
        }
        let mut n_live = 0usize;
        let mut prev = -1i64;
        for &p in req.carry_pos {
            if p < 0 {
                break;
            }
            if i64::from(p) <= prev || p as usize >= start {
                return Err(anyhow!(
                    "layer_prefill_chunked_evict: carry_pos must ascend strictly below {start}"
                ));
            }
            prev = i64::from(p);
            n_live += 1;
        }
        if req.carry_pos[n_live..].iter().any(|&p| p >= 0) {
            return Err(anyhow!(
                "layer_prefill_chunked_evict: live carry columns must be packed at the front"
            ));
        }
        let l64 = layer as u64;
        let m = cap + c;
        let seen = start + chunk_len;
        let final_base = total_len.saturating_sub(w);
        // absolute position of compact column j, None when dead/padding
        let col_pos = |j: usize| -> Option<usize> {
            if j < cap {
                (j < n_live).then(|| req.carry_pos[j] as usize)
            } else {
                (j - cap < chunk_len).then(|| start + (j - cap))
            }
        };

        let mut win_rows = Vec::new();
        for qpos in seen.saturating_sub(w).max(start)..seen {
            let mut row = vec![0.0f32; h * m];
            for hh in 0..h {
                let mut sum = 0.0f32;
                for j in 0..m {
                    let Some(i) = col_pos(j) else { continue };
                    if i > qpos {
                        continue;
                    }
                    let key = if qpos >= final_base {
                        (qpos - final_base) * n_obs + i // monolithic row key
                    } else {
                        (w + qpos) * n_obs + i
                    };
                    let mut a = 0.02 + self.h01(l64 * 131 + hh as u64, key as u64, 2);
                    if qpos - i < 8 {
                        a += 1.0;
                    }
                    if self.hot_positions.contains(&i) {
                        a += 6.0 * (1.0 + (hh as f32 * 0.5));
                    }
                    row[hh * m + j] = a;
                    sum += a;
                }
                for j in 0..m {
                    row[hh * m + j] /= sum;
                }
            }
            win_rows.push((qpos, row));
        }
        let mut acc = vec![0.0f32; h * m];
        for hh in 0..h {
            for r in 0..chunk_len {
                let i = start + r;
                let base = self.h01(l64 * 37 + hh as u64, i as u64, 3);
                let hot = if self.hot_positions.contains(&i) { 4.0 } else { 0.0 };
                acc[hh * m + cap + r] = base + hot + (total_len - i) as f32 * 0.01;
            }
        }
        let mut vn = vec![0.0f32; hk * m];
        for kv in 0..hk {
            for r in 0..chunk_len {
                let i = start + r;
                vn[kv * m + cap + r] = 0.5 + self.h01(l64 * 57 + kv as u64, i as u64, 4);
            }
        }
        let mut kdata = vec![0.0f32; hk * c * dh];
        let mut vdata = vec![0.0f32; hk * c * dh];
        for kv in 0..hk {
            for row in 0..chunk_len {
                for j in 0..dh {
                    let flat = (kv * n_obs + start + row) * dh + j;
                    kdata[(kv * c + row) * dh + j] = self.h01(l64 * 71, flat as u64, 5) - 0.5;
                    vdata[(kv * c + row) * dh + j] = self.h01(l64 * 83, flat as u64, 6) - 0.5;
                }
            }
        }
        Ok(ChunkEvictOut {
            x_out: req.x_chunk.clone(),
            k: Tensor::f32(kdata, &[hk, c, dh]),
            v: Tensor::f32(vdata, &[hk, c, dh]),
            win_rows,
            acc,
            vnorm: vn,
        })
    }

    fn supports_chunked_evict(&self, _chunk_bucket: usize, _cap: usize) -> bool {
        true
    }

    /// Vectorized in spirit: the mock serves any same-shape batch in one
    /// logical dispatch, like its batched decode path.
    fn layer_prefill_chunked_evict_batched(
        &self,
        layer: usize,
        reqs: &[ChunkEvictReq],
    ) -> Result<(Vec<ChunkEvictOut>, usize)> {
        let mut outs = Vec::with_capacity(reqs.len());
        for req in reqs {
            outs.push(self.layer_prefill_chunked_evict(layer, req)?);
        }
        Ok((outs, if reqs.is_empty() { 0 } else { 1 }))
    }

    fn layer_decode(
        &self,
        layer: usize,
        x: &Tensor,
        cache: &HotStore,
        pos: usize,
    ) -> Result<DecodeOut> {
        let h = self.cfg.n_heads;
        let m = cache.capacity();
        let (attn, k_new, v_new) = self.decode_core(layer, cache, pos);
        Ok(DecodeOut {
            x_out: x.clone(),
            k_new,
            v_new,
            attn: Tensor::f32(attn, &[h, m + 1]),
        })
    }

    /// Vectorized batched decode: one pass over the batch with a single
    /// packed residual-stream clone, instead of B per-session [1, d] slices
    /// and clones per layer.
    fn layer_decode_batched(
        &self,
        layer: usize,
        xs: &Tensor,
        caches: &[&HotStore],
        positions: &[usize],
    ) -> Result<DecodeBatchOut> {
        let b = caches.len();
        if b == 0 || xs.shape != [b, self.cfg.d_model] || positions.len() != b {
            return Err(anyhow!(
                "layer_decode_batched: xs {:?} / {} caches / {} positions disagree",
                xs.shape,
                b,
                positions.len()
            ));
        }
        let h = self.cfg.n_heads;
        let m = caches[0].capacity();
        if caches.iter().any(|c| c.capacity() != m) {
            return Err(anyhow!("layer_decode_batched: caches must share one capacity bucket"));
        }
        let mut k_new = Vec::with_capacity(b);
        let mut v_new = Vec::with_capacity(b);
        let mut attn = Vec::with_capacity(b);
        for (cache, &pos) in caches.iter().zip(positions) {
            let (a, k, v) = self.decode_core(layer, cache, pos);
            attn.push(Tensor::f32(a, &[h, m + 1]));
            k_new.push(k);
            v_new.push(v);
        }
        Ok(DecodeBatchOut { x_out: xs.clone(), k_new, v_new, attn, dispatches: 1 })
    }

    fn logits(&self, _x: &Tensor) -> Result<Vec<f32>> {
        let mut v = vec![0.0f32; self.cfg.vocab_size];
        for (i, o) in v.iter_mut().enumerate() {
            *o = self.h01(999, i as u64, 10);
        }
        Ok(v)
    }

    fn device_count(&self) -> usize {
        self.mock_devices.max(1)
    }

    fn bind_device(&self, slot: usize) {
        let dev = slot % self.device_count();
        let tid = std::thread::current().id();
        let mut bindings = self.bindings.lock().expect("mock bindings");
        match bindings.iter().find(|(t, _)| *t == tid) {
            Some((_, prev)) => assert_eq!(
                *prev, dev,
                "worker thread rebound from device {prev} to {dev}: per-worker pinning violated"
            ),
            None => bindings.push((tid, dev)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_prefill_shapes_and_distributions() {
        let mut b = MockBackend::new(MockBackend::default_config());
        b.hot_positions = vec![10];
        let x = b.embed(&[1, 2, 3], 128).unwrap();
        assert_eq!(x.shape, vec![128, 128]);
        let out = b.layer_prefill(0, &x, 100).unwrap();
        assert_eq!(out.k.shape, vec![4, 128, 16]);
        assert_eq!(out.obs.win_attn.shape, vec![8, 16, 128]);
        // window rows are distributions
        let win = out.obs.win_attn.as_f32().unwrap();
        let s: f32 = win[0..128].iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
        // hot position carries extra mass
        let hot = win[10];
        let cold = win[30];
        assert!(hot > cold);
    }

    #[test]
    fn mock_chunked_prefill_accumulates_to_monolithic() {
        let mut b = MockBackend::new(MockBackend::default_config());
        b.hot_positions = vec![10, 40];
        b.seed = 7;
        let cfg = b.cfg.clone();
        let (h, hk, w, dh, d) = (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head, cfg.d_model);
        let length = 100;
        let bucket = 128;
        let ids: Vec<i32> = (0..length as i32).map(|t| t % 250).collect();
        let x = b.embed(&ids, bucket).unwrap();
        for layer in [0, 2] {
            let mono = b.layer_prefill(layer, &x, length).unwrap();
            for chunk in [128usize, 48, 17] {
                let mut win = vec![0.0f32; h * w * bucket];
                let mut acc = vec![0.0f32; h * bucket];
                let mut vn = vec![0.0f32; hk * bucket];
                let mut carry_k = vec![0.0f32; hk * bucket * dh];
                let mut carry_v = vec![0.0f32; hk * bucket * dh];
                let xf = x.as_f32().unwrap();
                let mut start = 0;
                let mut rows_seen = 0;
                while start < length {
                    let clen = chunk.min(length - start);
                    let mut xc = vec![0.0f32; chunk * d];
                    xc[..clen * d].copy_from_slice(&xf[start * d..(start + clen) * d]);
                    let carry_kt = Tensor::f32(carry_k.clone(), &[hk, bucket, dh]);
                    let carry_vt = Tensor::f32(carry_v.clone(), &[hk, bucket, dh]);
                    let out = b
                        .layer_prefill_chunked(
                            layer,
                            &Tensor::f32(xc, &[chunk, d]),
                            &carry_kt,
                            &carry_vt,
                            start,
                            clen,
                            length,
                        )
                        .unwrap();
                    for (r, row) in &out.win_rows {
                        rows_seen += 1;
                        for hh in 0..h {
                            win[(hh * w + r) * bucket..(hh * w + r + 1) * bucket]
                                .copy_from_slice(&row[hh * bucket..(hh + 1) * bucket]);
                        }
                    }
                    for (dst, src) in acc.iter_mut().zip(&out.acc) {
                        *dst += src;
                    }
                    for (dst, src) in vn.iter_mut().zip(&out.vnorm) {
                        *dst += src;
                    }
                    let kc = out.k.as_f32().unwrap();
                    let vc = out.v.as_f32().unwrap();
                    for kv in 0..hk {
                        for row in 0..clen {
                            let dst = (kv * bucket + start + row) * dh;
                            let src = (kv * chunk + row) * dh;
                            carry_k[dst..dst + dh].copy_from_slice(&kc[src..src + dh]);
                            carry_v[dst..dst + dh].copy_from_slice(&vc[src..src + dh]);
                        }
                    }
                    start += clen;
                }
                assert_eq!(rows_seen, w, "chunk {chunk}: every window row owned exactly once");
                assert_eq!(win, mono.obs.win_attn.as_f32().unwrap(), "chunk {chunk} win");
                assert_eq!(acc, mono.obs.acc_attn.as_f32().unwrap(), "chunk {chunk} acc");
                assert_eq!(vn, mono.obs.vnorm.as_f32().unwrap(), "chunk {chunk} vnorm");
                // K/V only defined on valid positions (monolithic also hashes
                // padding rows; chunked leaves them untouched)
                let mk = mono.k.as_f32().unwrap();
                let mv = mono.v.as_f32().unwrap();
                for kv in 0..hk {
                    let a = (kv * bucket) * dh;
                    let z = (kv * bucket + length) * dh;
                    assert_eq!(&carry_k[a..z], &mk[a..z], "chunk {chunk} k head {kv}");
                    assert_eq!(&carry_v[a..z], &mv[a..z], "chunk {chunk} v head {kv}");
                }
            }
        }
        // malformed chunk geometry is rejected
        let ck = Tensor::zeros(&[hk, bucket, dh]);
        let xz = Tensor::zeros(&[16, d]);
        assert!(b.layer_prefill_chunked(0, &xz, &ck, &ck, 120, 16, 100).is_err());
        assert!(b.layer_prefill_chunked(0, &xz, &ck, &ck, 0, 32, 100).is_err());
    }

    #[test]
    fn mock_evict_chunked_full_carry_matches_monolithic() {
        let mut b = MockBackend::new(MockBackend::default_config());
        b.hot_positions = vec![10, 40];
        b.seed = 7;
        let cfg = b.cfg.clone();
        let (h, hk, w, dh, d) = (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head, cfg.d_model);
        let length = 100;
        let bucket = 128; // monolithic observation bucket == working cap
        let cap = 128;
        let layer = 1;
        let ids: Vec<i32> = (0..length as i32).map(|t| t % 250).collect();
        let x = b.embed(&ids, bucket).unwrap();
        let xf = x.as_f32().unwrap();
        let mono = b.layer_prefill(layer, &x, length).unwrap();
        let mono_win = mono.obs.win_attn.as_f32().unwrap();
        for chunk in [48usize, 17] {
            let mut carry_k = vec![0.0f32; hk * cap * dh];
            let mut carry_v = vec![0.0f32; hk * cap * dh];
            let mut acc = vec![0.0f32; h * bucket];
            let mut vn = vec![0.0f32; hk * bucket];
            let mut rows: std::collections::HashMap<usize, Vec<f32>> = Default::default();
            let mut start = 0;
            while start < length {
                let clen = chunk.min(length - start);
                let m = cap + chunk;
                let mut xc = vec![0.0f32; chunk * d];
                xc[..clen * d].copy_from_slice(&xf[start * d..(start + clen) * d]);
                let xct = Tensor::f32(xc, &[chunk, d]);
                let ckt = Tensor::f32(carry_k.clone(), &[hk, cap, dh]);
                let cvt = Tensor::f32(carry_v.clone(), &[hk, cap, dh]);
                // full dense carry: identity compaction, nothing evicted
                let mut pos: Vec<i32> = (0..start as i32).collect();
                pos.resize(cap, -1);
                let req = ChunkEvictReq {
                    x_chunk: &xct,
                    carry_k: &ckt,
                    carry_v: &cvt,
                    carry_pos: &pos,
                    start,
                    chunk_len: clen,
                    total_len: length,
                    n_obs: bucket,
                };
                let out = b.layer_prefill_chunked_evict(layer, &req).unwrap();
                for (qpos, row) in &out.win_rows {
                    // remap compact columns to absolute positions
                    let mut abs_row = vec![0.0f32; h * bucket];
                    for hh in 0..h {
                        for j in 0..m {
                            let i = if j < cap {
                                if j < start {
                                    j
                                } else {
                                    continue;
                                }
                            } else if j - cap < clen {
                                start + (j - cap)
                            } else {
                                continue;
                            };
                            abs_row[hh * bucket + i] = row[hh * m + j];
                        }
                    }
                    assert!(rows.insert(*qpos, abs_row).is_none(), "row {qpos} owned once");
                }
                for hh in 0..h {
                    for r in 0..clen {
                        acc[hh * bucket + start + r] += out.acc[hh * m + cap + r];
                    }
                }
                for kv in 0..hk {
                    for r in 0..clen {
                        vn[kv * bucket + start + r] += out.vnorm[kv * m + cap + r];
                    }
                }
                let kc = out.k.as_f32().unwrap();
                let vc = out.v.as_f32().unwrap();
                for kv in 0..hk {
                    for row in 0..clen {
                        let dst = (kv * cap + start + row) * dh;
                        let src = (kv * chunk + row) * dh;
                        carry_k[dst..dst + dh].copy_from_slice(&kc[src..src + dh]);
                        carry_v[dst..dst + dh].copy_from_slice(&vc[src..src + dh]);
                    }
                }
                start += clen;
            }
            assert_eq!(acc, mono.obs.acc_attn.as_f32().unwrap(), "chunk {chunk} acc");
            assert_eq!(vn, mono.obs.vnorm.as_f32().unwrap(), "chunk {chunk} vnorm");
            let mk = mono.k.as_f32().unwrap();
            for kv in 0..hk {
                let a = (kv * bucket) * dh;
                let z = (kv * bucket + length) * dh;
                assert_eq!(&carry_k[a..z], &mk[a..z], "chunk {chunk} k head {kv}");
            }
            // every final-window row is owned by some chunk and, with the
            // full carry, is bit-identical to the monolithic row
            for r in 0..w {
                let qpos = length - w + r;
                let got = rows.get(&qpos).unwrap_or_else(|| panic!("missing row {qpos}"));
                for hh in 0..h {
                    assert_eq!(
                        &got[hh * bucket..hh * bucket + length],
                        &mono_win[(hh * w + r) * bucket..(hh * w + r) * bucket + length],
                        "chunk {chunk} final row {r} head {hh}"
                    );
                }
            }
        }
    }

    #[test]
    fn mock_evict_chunked_compacted_carry_preserves_ranking() {
        let mut b = MockBackend::new(MockBackend::default_config());
        b.hot_positions = vec![10, 40];
        b.seed = 7;
        let cfg = b.cfg.clone();
        let (h, hk, dh, d, w) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model, cfg.window);
        let length = 100;
        let bucket = 128;
        let cap = 64;
        let layer = 2;
        let ids: Vec<i32> = (0..length as i32).map(|t| t % 250).collect();
        let x = b.embed(&ids, bucket).unwrap();
        let xf = x.as_f32().unwrap();
        let mono = b.layer_prefill(layer, &x, length).unwrap();
        let mono_win = mono.obs.win_attn.as_f32().unwrap();
        // last chunk [96, 100) against a compacted carry of the even
        // positions below 96: survivor scores keep monolithic ratios
        let start = 96;
        let clen = length - start;
        let chunk = 48;
        let m = cap + chunk;
        let survivors: Vec<usize> = (0..start).step_by(2).collect();
        let mut pos: Vec<i32> = survivors.iter().map(|&p| p as i32).collect();
        pos.resize(cap, -1);
        let mk = mono.k.as_f32().unwrap();
        let mv = mono.v.as_f32().unwrap();
        let mut carry_k = vec![0.0f32; hk * cap * dh];
        let mut carry_v = vec![0.0f32; hk * cap * dh];
        for kv in 0..hk {
            for (j, &p) in survivors.iter().enumerate() {
                let dst = (kv * cap + j) * dh;
                let src = (kv * bucket + p) * dh;
                carry_k[dst..dst + dh].copy_from_slice(&mk[src..src + dh]);
                carry_v[dst..dst + dh].copy_from_slice(&mv[src..src + dh]);
            }
        }
        let mut xc = vec![0.0f32; chunk * d];
        xc[..clen * d].copy_from_slice(&xf[start * d..(start + clen) * d]);
        let xct = Tensor::f32(xc, &[chunk, d]);
        let ckt = Tensor::f32(carry_k, &[hk, cap, dh]);
        let cvt = Tensor::f32(carry_v, &[hk, cap, dh]);
        let req = ChunkEvictReq {
            x_chunk: &xct,
            carry_k: &ckt,
            carry_v: &cvt,
            carry_pos: &pos,
            start,
            chunk_len: clen,
            total_len: length,
            n_obs: bucket,
        };
        let out = b.layer_prefill_chunked_evict(layer, &req).unwrap();
        assert_eq!(out.win_rows.len(), clen);
        for (qpos, row) in &out.win_rows {
            let r = qpos - (length - w);
            for hh in 0..h {
                let base_s = row[hh * m]; // survivor column 0 = position 0
                let base_m = mono_win[(hh * w + r) * bucket];
                for (j, &p) in survivors.iter().enumerate() {
                    let rs = row[hh * m + j] / base_s;
                    let rm = mono_win[(hh * w + r) * bucket + p] / base_m;
                    assert!(
                        (rs - rm).abs() <= 1e-3 * rm.abs().max(1.0),
                        "row {qpos} head {hh} survivor {p}: {rs} vs {rm}"
                    );
                }
            }
        }
        // malformed carry maps are rejected
        let bad_order: Vec<i32> =
            [4i32, 2].iter().copied().chain(std::iter::repeat(-1)).take(cap).collect();
        let req_bad = ChunkEvictReq { carry_pos: &bad_order, ..req };
        assert!(b.layer_prefill_chunked_evict(layer, &req_bad).is_err());
        let too_high: Vec<i32> =
            [0i32, 97].iter().copied().chain(std::iter::repeat(-1)).take(cap).collect();
        let req_bad = ChunkEvictReq { carry_pos: &too_high, ..req };
        assert!(b.layer_prefill_chunked_evict(layer, &req_bad).is_err());
        let hole: Vec<i32> = [0i32, -1, 5].iter().copied().chain(std::iter::repeat(-1)).take(cap).collect();
        let req_bad = ChunkEvictReq { carry_pos: &hole, ..req };
        assert!(b.layer_prefill_chunked_evict(layer, &req_bad).is_err());
        let short = vec![0i32; cap - 1];
        let req_bad = ChunkEvictReq { carry_pos: &short, ..req };
        assert!(b.layer_prefill_chunked_evict(layer, &req_bad).is_err());
    }

    #[test]
    fn mock_batched_decode_matches_serial() {
        let mut b = MockBackend::new(MockBackend::default_config());
        b.hot_positions = vec![3];
        b.seed = 11;
        let d = b.cfg.d_model;
        // two caches with different contents, same capacity bucket
        let mut c0 = crate::kvcache::HotStore::new(4, 16, 32);
        let mut c1 = crate::kvcache::HotStore::new(4, 16, 32);
        for p in 0..9 {
            c0.append(&vec![0.1; 64], &vec![0.1; 64], p, 0.5);
        }
        for p in 0..5 {
            c1.append(&vec![0.2; 64], &vec![0.2; 64], p, 0.5);
        }
        let xs: Vec<f32> = (0..2 * d).map(|i| i as f32 * 0.01).collect();
        let xst = Tensor::f32(xs.clone(), &[2, d]);
        let batched = b.layer_decode_batched(1, &xst, &[&c0, &c1], &[9, 5]).unwrap();
        assert_eq!(batched.dispatches, 1, "the mock path is fully vectorized");
        for (i, (cache, pos)) in [(&c0, 9usize), (&c1, 5usize)].iter().enumerate() {
            let xi = Tensor::f32(xs[i * d..(i + 1) * d].to_vec(), &[1, d]);
            let serial = b.layer_decode(1, &xi, cache, *pos).unwrap();
            assert_eq!(batched.attn[i], serial.attn, "session {i} attn");
            assert_eq!(batched.k_new[i], serial.k_new, "session {i} k_new");
            assert_eq!(batched.v_new[i], serial.v_new, "session {i} v_new");
            assert_eq!(
                &batched.x_out.as_f32().unwrap()[i * d..(i + 1) * d],
                &serial.x_out.as_f32().unwrap()[..d],
                "session {i} x_out row"
            );
        }
        // shape/arity/capacity mismatches are rejected, not panicked on
        assert!(b.layer_decode_batched(1, &xst, &[&c0], &[9]).is_err());
        assert!(b.layer_decode_batched(1, &xst, &[&c0, &c1], &[9]).is_err());
        let c2 = crate::kvcache::HotStore::new(4, 16, 64);
        assert!(b.layer_decode_batched(1, &xst, &[&c0, &c2], &[9, 5]).is_err());
    }

    #[test]
    fn mock_decode_attends_to_hot() {
        let mut b = MockBackend::new(MockBackend::default_config());
        b.hot_positions = vec![5];
        let mut cache = crate::kvcache::HotStore::new(4, 16, 32);
        for p in 0..10 {
            cache.append(&vec![0.1; 64], &vec![0.1; 64], p, 0.5);
        }
        let x = Tensor::zeros(&[1, 128]);
        let out = b.layer_decode(0, &x, &cache, 10).unwrap();
        assert_eq!(out.attn.shape, vec![8, 33]);
        let attn = out.attn.as_f32().unwrap();
        assert!(attn[5] > attn[8], "hot position should dominate");
    }
}
