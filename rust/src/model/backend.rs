//! `ModelBackend`: the engine's view of the model.
//!
//! Two implementations:
//! * [`PjrtBackend`] — the real path: executes the AOT-compiled HLO-text
//!   artifacts through PJRT, with weights resident on the device.
//! * [`MockBackend`] — a deterministic synthetic model used by unit tests
//!   and by the large-N latency scaling benches (Fig. 3 beyond the real
//!   model's bucket range), producing peaked attention at configurable
//!   positions so eviction policies have structure to react to.
//!
//! Token embedding is a row lookup; the engine does it host-side from the
//! `tok_emb` weights (cheaper than a PJRT call), so `embed_{N}` artifacts
//! exist only for parity tests.

use anyhow::{anyhow, Result};

use super::{Manifest, ModelConfig, Weights};
use crate::compress::LayerObs;
use crate::kvcache::HotStore;
use crate::runtime::{Arg, Runtime, Tensor};
use crate::util::rng::Rng;

/// Output of one layer's prefill pass.
pub struct PrefillOut {
    pub x_out: Tensor, // [N, d]
    pub k: Tensor,     // [Hk, N, dh]
    pub v: Tensor,     // [Hk, N, dh]
    pub obs: LayerObs,
}

/// Output of one layer's decode step.
pub struct DecodeOut {
    pub x_out: Tensor,  // [1, d]
    pub k_new: Vec<f32>, // [Hk*dh]
    pub v_new: Vec<f32>,
    /// [H, M+1] attention over cache slots; column M is the new token.
    pub attn: Tensor,
}

pub trait ModelBackend {
    fn config(&self) -> &ModelConfig;
    fn prefill_buckets(&self) -> &[usize];
    fn decode_buckets(&self) -> &[usize];

    /// Host-side token embedding: ids -> [n, d] (padded to `bucket` rows).
    fn embed(&self, ids: &[i32], bucket: usize) -> Result<Tensor>;

    fn layer_prefill(&self, layer: usize, x: &Tensor, length: usize) -> Result<PrefillOut>;

    /// Decode is a hot-tier-only operation: the cache handed in here is
    /// always a resident [`HotStore`] (the tier manager prefetches warm
    /// layers before the engine reaches this boundary).
    fn layer_decode(
        &self,
        layer: usize,
        x: &Tensor,
        cache: &HotStore,
        pos: usize,
    ) -> Result<DecodeOut>;

    fn logits(&self, x: &Tensor) -> Result<Vec<f32>>;

    /// Optional fused LAVa scoring fast path (the L1 Pallas kernel artifact);
    /// `None` -> the engine computes scores host-side.
    fn fused_lava_score(
        &self,
        _win_attn: &Tensor,
        _v: &Tensor,
        _length: usize,
    ) -> Result<Option<Vec<Vec<f32>>>> {
        Ok(None)
    }
}

// ---------------------------------------------------------------- PJRT

pub struct PjrtBackend {
    pub runtime: Runtime,
    cfg: ModelConfig,
    buckets_prefill: Vec<usize>,
    buckets_decode: Vec<usize>,
    weights_host: Weights,
    // device-resident weights
    layer_bufs: Vec<Vec<xla::PjRtBuffer>>,
    ln_f_buf: xla::PjRtBuffer,
    unembed_buf: xla::PjRtBuffer,
    /// Use the fused lava_score_{N} artifact when available.
    pub use_fused_score: bool,
}

impl PjrtBackend {
    pub fn load(artifact_dir: &str) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifact_dir)?;
        let weights = Weights::load(&manifest)?;
        let runtime = Runtime::new(artifact_dir)?;
        let mut layer_bufs = Vec::with_capacity(manifest.model.n_layers);
        for lw in &weights.layers {
            let mut bufs = Vec::with_capacity(lw.len());
            for t in lw {
                bufs.push(runtime.upload(t)?);
            }
            layer_bufs.push(bufs);
        }
        let ln_f_buf = runtime.upload(&weights.ln_f)?;
        let unembed_buf = runtime.upload(&weights.unembed)?;
        Ok(PjrtBackend {
            runtime,
            cfg: manifest.model.clone(),
            buckets_prefill: manifest.buckets.prefill.clone(),
            buckets_decode: manifest.buckets.decode.clone(),
            weights_host: weights,
            layer_bufs,
            ln_f_buf,
            unembed_buf,
            use_fused_score: true,
        })
    }

    fn layer_args<'a>(&'a self, layer: usize) -> Vec<Arg<'a>> {
        self.layer_bufs[layer].iter().map(Arg::Device).collect()
    }
}

impl ModelBackend for PjrtBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets_prefill
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets_decode
    }

    fn embed(&self, ids: &[i32], bucket: usize) -> Result<Tensor> {
        let d = self.cfg.d_model;
        let emb = self.weights_host.tok_emb.as_f32()?;
        let mut x = vec![0.0f32; bucket * d];
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            x[i * d..(i + 1) * d].copy_from_slice(&emb[id * d..(id + 1) * d]);
        }
        // padding rows embed PAD (keeps parity with the python reference)
        let pad = self.cfg.pad_id as usize;
        for i in ids.len()..bucket {
            x[i * d..(i + 1) * d].copy_from_slice(&emb[pad * d..(pad + 1) * d]);
        }
        Ok(Tensor::f32(x, &[bucket, d]))
    }

    fn layer_prefill(&self, layer: usize, x: &Tensor, length: usize) -> Result<PrefillOut> {
        let n = x.shape[0];
        let name = format!("layer_prefill_{n}");
        let len_t = Tensor::scalar_i32(length as i32);
        let mut args: Vec<Arg> = vec![Arg::Host(x), Arg::Host(&len_t)];
        args.extend(self.layer_args(layer));
        let mut out = self.runtime.execute(&name, &args)?;
        if out.len() != 6 {
            return Err(anyhow!("{name}: expected 6 outputs, got {}", out.len()));
        }
        let vnorm = out.pop().unwrap();
        let acc_attn = out.pop().unwrap();
        let win_attn = out.pop().unwrap();
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let x_out = out.pop().unwrap();
        Ok(PrefillOut {
            x_out,
            k,
            v,
            obs: LayerObs { win_attn, acc_attn, vnorm, length },
        })
    }

    fn layer_decode(
        &self,
        layer: usize,
        x: &Tensor,
        cache: &HotStore,
        pos: usize,
    ) -> Result<DecodeOut> {
        let m = cache.capacity();
        let name = format!("layer_decode_{m}");
        // borrowed views: no K/V/valid buffer copies on the decode hot path
        let (k, v, valid) = cache.decode_tensors();
        let pos_t = Tensor::scalar_i32(pos as i32);
        let mut args: Vec<Arg> =
            vec![Arg::Host(x), Arg::Host(k), Arg::Host(v), Arg::Host(valid), Arg::Host(&pos_t)];
        args.extend(self.layer_args(layer));
        let mut out = self.runtime.execute(&name, &args)?;
        if out.len() != 4 {
            return Err(anyhow!("{name}: expected 4 outputs, got {}", out.len()));
        }
        let attn = out.pop().unwrap();
        let v_new = out.pop().unwrap().into_f32()?;
        let k_new = out.pop().unwrap().into_f32()?;
        let x_out = out.pop().unwrap();
        Ok(DecodeOut { x_out, k_new, v_new, attn })
    }

    fn logits(&self, x: &Tensor) -> Result<Vec<f32>> {
        let out = self.runtime.execute(
            "logits",
            &[Arg::Host(x), Arg::Device(&self.ln_f_buf), Arg::Device(&self.unembed_buf)],
        )?;
        out.into_iter()
            .next()
            .ok_or_else(|| anyhow!("logits: no output"))?
            .into_f32()
    }

    fn fused_lava_score(
        &self,
        win_attn: &Tensor,
        v: &Tensor,
        length: usize,
    ) -> Result<Option<Vec<Vec<f32>>>> {
        if !self.use_fused_score {
            return Ok(None);
        }
        let n = win_attn.shape[2];
        let name = format!("lava_score_{n}");
        if !self.runtime.has_artifact(&name) {
            return Ok(None);
        }
        self.lava_score_artifact(win_attn, v, length).map(Some)
    }
}

impl PjrtBackend {
    /// Fused LAVa scoring through the L1 Pallas kernel artifact.
    pub fn lava_score_artifact(
        &self,
        win_attn: &Tensor,
        v: &Tensor,
        length: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let n = win_attn.shape[2];
        let name = format!("lava_score_{n}");
        let len_t = Tensor::scalar_i32(length as i32);
        let out = self
            .runtime
            .execute(&name, &[Arg::Host(win_attn), Arg::Host(v), Arg::Host(&len_t)])?;
        let scores = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("lava_score: no output"))?;
        let hk = scores.shape[0];
        let data = scores.into_f32()?;
        Ok((0..hk).map(|h| data[h * n..h * n + length].to_vec()).collect())
    }
}

// ---------------------------------------------------------------- mock

/// Deterministic synthetic model. Attention is peaked at `hot_positions`
/// (plus a local-recency component), values have per-position norms, and
/// hidden states are cheap hashes — enough structure for every policy and
/// scheduler test, at ~zero cost, any context length.
pub struct MockBackend {
    cfg: ModelConfig,
    buckets_prefill: Vec<usize>,
    buckets_decode: Vec<usize>,
    pub hot_positions: Vec<usize>,
    pub seed: u64,
}

impl MockBackend {
    pub fn new(cfg: ModelConfig) -> MockBackend {
        MockBackend {
            cfg,
            buckets_prefill: vec![128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 131072, 262144],
            buckets_decode: vec![128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 131072, 262144],
            hot_positions: vec![],
            seed: 0,
        }
    }

    /// Default config mirroring the build-time python model.
    pub fn default_config() -> ModelConfig {
        ModelConfig {
            vocab_size: 260,
            n_layers: 4,
            n_heads: 8,
            n_kv_heads: 4,
            d_model: 128,
            d_head: 16,
            d_ff: 256,
            window: 16,
            max_seq_len: 131072,
            bos_id: 256,
            sep_id: 257,
            query_id: 258,
            pad_id: 259,
        }
    }

    fn h01(&self, a: u64, b: u64, c: u64) -> f32 {
        let mut r = Rng::new(self.seed ^ a.wrapping_mul(0x9E37).wrapping_add(b) ^ (c << 32));
        r.f32()
    }
}

impl ModelBackend for MockBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets_prefill
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets_decode
    }

    fn embed(&self, ids: &[i32], bucket: usize) -> Result<Tensor> {
        let d = self.cfg.d_model;
        let mut x = vec![0.0f32; bucket * d];
        for (i, &id) in ids.iter().enumerate() {
            for j in 0..d {
                x[i * d + j] = self.h01(id as u64, j as u64, 1) - 0.5;
            }
        }
        Ok(Tensor::f32(x, &[bucket, d]))
    }

    fn layer_prefill(&self, layer: usize, x: &Tensor, length: usize) -> Result<PrefillOut> {
        let cfg = &self.cfg;
        let n = x.shape[0];
        let (h, hk, w, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.window, cfg.d_head);
        let l64 = layer as u64;

        let mut win = vec![0.0f32; h * w * n];
        for hh in 0..h {
            for r in 0..w {
                let qpos = length - w + r;
                let mut sum = 0.0f32;
                for i in 0..=qpos {
                    let mut a = 0.02 + self.h01(l64 * 131 + hh as u64, (r * n + i) as u64, 2);
                    // recency bump + hot positions (head-dependent strength)
                    if qpos - i < 8 {
                        a += 1.0;
                    }
                    if self.hot_positions.contains(&i) {
                        a += 6.0 * (1.0 + (hh as f32 * 0.5)); // heads differ -> dynamic budgets matter
                    }
                    win[(hh * w + r) * n + i] = a;
                    sum += a;
                }
                for i in 0..=qpos {
                    win[(hh * w + r) * n + i] /= sum;
                }
            }
        }
        let mut acc = vec![0.0f32; h * n];
        for hh in 0..h {
            for i in 0..length {
                let base = self.h01(l64 * 37 + hh as u64, i as u64, 3);
                let hot = if self.hot_positions.contains(&i) { 4.0 } else { 0.0 };
                acc[hh * n + i] = base + hot + (length - i) as f32 * 0.01;
            }
        }
        let mut vn = vec![0.0f32; hk * n];
        for kv in 0..hk {
            for i in 0..length {
                vn[kv * n + i] = 0.5 + self.h01(l64 * 57 + kv as u64, i as u64, 4);
            }
        }
        let kdata: Vec<f32> = (0..hk * n * dh)
            .map(|i| self.h01(l64 * 71, i as u64, 5) - 0.5)
            .collect();
        let vdata: Vec<f32> = (0..hk * n * dh)
            .map(|i| self.h01(l64 * 83, i as u64, 6) - 0.5)
            .collect();
        Ok(PrefillOut {
            x_out: x.clone(),
            k: Tensor::f32(kdata, &[hk, n, dh]),
            v: Tensor::f32(vdata, &[hk, n, dh]),
            obs: LayerObs {
                win_attn: Tensor::f32(win, &[h, w, n]),
                acc_attn: Tensor::f32(acc, &[h, n]),
                vnorm: Tensor::f32(vn, &[hk, n]),
                length,
            },
        })
    }

    fn layer_decode(
        &self,
        layer: usize,
        x: &Tensor,
        cache: &HotStore,
        pos: usize,
    ) -> Result<DecodeOut> {
        let cfg = &self.cfg;
        let (h, hk, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head);
        let m = cache.capacity();
        let l64 = layer as u64;
        let mut attn = vec![0.0f32; h * (m + 1)];
        for hh in 0..h {
            let kv = hh / (h / hk);
            let live = cache.head_len(kv);
            let mut sum = 0.0f32;
            for i in 0..live {
                let p = cache.position(kv, i).max(0) as usize;
                let mut a = 0.05 + self.h01(l64 + hh as u64, p as u64, 7);
                if pos.saturating_sub(p) < 8 {
                    a += 1.0;
                }
                if self.hot_positions.contains(&p) {
                    a += 6.0;
                }
                attn[hh * (m + 1) + i] = a;
                sum += a;
            }
            attn[hh * (m + 1) + m] = 1.0; // self
            sum += 1.0;
            for i in 0..=m {
                attn[hh * (m + 1) + i] /= sum;
            }
        }
        let k_new: Vec<f32> =
            (0..hk * dh).map(|i| self.h01(l64 * 91, (pos * 64 + i) as u64, 8) - 0.5).collect();
        let v_new: Vec<f32> =
            (0..hk * dh).map(|i| self.h01(l64 * 93, (pos * 64 + i) as u64, 9) - 0.5).collect();
        Ok(DecodeOut {
            x_out: x.clone(),
            k_new,
            v_new,
            attn: Tensor::f32(attn, &[h, m + 1]),
        })
    }

    fn logits(&self, _x: &Tensor) -> Result<Vec<f32>> {
        let mut v = vec![0.0f32; self.cfg.vocab_size];
        for (i, o) in v.iter_mut().enumerate() {
            *o = self.h01(999, i as u64, 10);
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_prefill_shapes_and_distributions() {
        let mut b = MockBackend::new(MockBackend::default_config());
        b.hot_positions = vec![10];
        let x = b.embed(&[1, 2, 3], 128).unwrap();
        assert_eq!(x.shape, vec![128, 128]);
        let out = b.layer_prefill(0, &x, 100).unwrap();
        assert_eq!(out.k.shape, vec![4, 128, 16]);
        assert_eq!(out.obs.win_attn.shape, vec![8, 16, 128]);
        // window rows are distributions
        let win = out.obs.win_attn.as_f32().unwrap();
        let s: f32 = win[0..128].iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
        // hot position carries extra mass
        let hot = win[10];
        let cold = win[30];
        assert!(hot > cold);
    }

    #[test]
    fn mock_decode_attends_to_hot() {
        let mut b = MockBackend::new(MockBackend::default_config());
        b.hot_positions = vec![5];
        let mut cache = crate::kvcache::HotStore::new(4, 16, 32);
        for p in 0..10 {
            cache.append(&vec![0.1; 64], &vec![0.1; 64], p, 0.5);
        }
        let x = Tensor::zeros(&[1, 128]);
        let out = b.layer_decode(0, &x, &cache, 10).unwrap();
        assert_eq!(out.attn.shape, vec![8, 33]);
        let attn = out.attn.as_f32().unwrap();
        assert!(attn[5] > attn[8], "hot position should dominate");
    }
}
