//! Model configuration + weights, loaded from `artifacts/manifest.json` and
//! the raw `.bin` blobs emitted by `python/compile/aot.py`. Nothing here is
//! hard-coded to the build-time python config — swap the artifacts and the
//! coordinator follows.

pub mod backend;

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::Tensor;
use crate::util::json::Json;

/// Model hyperparameters (mirrors python/compile/config.py::ModelConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_model: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub window: usize,
    pub max_seq_len: usize,
    pub bos_id: i32,
    pub sep_id: i32,
    pub query_id: i32,
    pub pad_id: i32,
}

impl ModelConfig {
    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Bytes per cached token per layer (K + V, f32).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_kv_heads * self.d_head * 4
    }
}

/// Shape-bucket configuration (mirrors ArtifactConfig).
#[derive(Debug, Clone)]
pub struct BucketConfig {
    pub prefill: Vec<usize>,
    pub decode: Vec<usize>,
    /// Batch sizes B lowered as `layer_decode_batched_{M}x{B}` artifacts,
    /// ascending. Empty for artifact sets predating batched decode — the
    /// backend then falls back to per-session dispatches.
    pub decode_batch: Vec<usize>,
    pub pool_kernel: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelConfig,
    pub buckets: BucketConfig,
    pub layer_weight_order: Vec<String>,
    pub weight_shapes: HashMap<String, Vec<usize>>,
    pub weight_files: HashMap<String, PathBuf>,
    pub dir: PathBuf,
}

fn req_usize(j: &Json, path: &str) -> Result<usize> {
    j.path(path)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("manifest missing {path}"))
}

impl Manifest {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let model = ModelConfig {
            vocab_size: req_usize(&j, "model.vocab_size")?,
            n_layers: req_usize(&j, "model.n_layers")?,
            n_heads: req_usize(&j, "model.n_heads")?,
            n_kv_heads: req_usize(&j, "model.n_kv_heads")?,
            d_model: req_usize(&j, "model.d_model")?,
            d_head: req_usize(&j, "model.d_head")?,
            d_ff: req_usize(&j, "model.d_ff")?,
            window: req_usize(&j, "model.window")?,
            max_seq_len: req_usize(&j, "model.max_seq_len")?,
            bos_id: req_usize(&j, "model.bos_id")? as i32,
            sep_id: req_usize(&j, "model.sep_id")? as i32,
            query_id: req_usize(&j, "model.query_id")? as i32,
            pad_id: req_usize(&j, "model.pad_id")? as i32,
        };
        if model.n_heads % model.n_kv_heads != 0 {
            bail!("n_heads must be a multiple of n_kv_heads");
        }

        let buckets = BucketConfig {
            prefill: j
                .path("artifacts.prefill_buckets")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            decode: j
                .path("artifacts.decode_buckets")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            decode_batch: j
                .path("artifacts.decode_batch_sizes")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            pool_kernel: req_usize(&j, "artifacts.pool_kernel")?,
        };

        let layer_weight_order = j
            .get("layer_weight_order")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_default();

        let mut weight_shapes = HashMap::new();
        let mut weight_files = HashMap::new();
        for w in j
            .get("weights")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing weights"))?
        {
            let name = w
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("weight missing name"))?
                .to_string();
            let shape: Vec<usize> = w
                .get("shape")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("weight missing shape"))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect();
            let file = w
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("weight missing file"))?;
            weight_shapes.insert(name.clone(), shape);
            weight_files.insert(name, dir.join(file));
        }

        Ok(Manifest { model, buckets, layer_weight_order, weight_shapes, weight_files, dir })
    }
}

/// All model weights as host tensors, in manifest order.
#[derive(Debug)]
pub struct Weights {
    pub tok_emb: Tensor,
    pub ln_f: Tensor,
    pub unembed: Tensor,
    /// layers[l][w] in `layer_weight_order`.
    pub layers: Vec<Vec<Tensor>>,
}

fn read_bin_f32(path: &Path, shape: &[usize]) -> Result<Tensor> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let n: usize = shape.iter().product();
    if bytes.len() != n * 4 {
        bail!("{}: expected {} bytes, got {}", path.display(), n * 4, bytes.len());
    }
    let mut data = vec![0f32; n];
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    Ok(Tensor::f32(data, shape))
}

impl Weights {
    pub fn load(manifest: &Manifest) -> Result<Weights> {
        let get = |name: &str| -> Result<Tensor> {
            let shape = manifest
                .weight_shapes
                .get(name)
                .ok_or_else(|| anyhow!("weight {name} not in manifest"))?;
            let file = manifest.weight_files.get(name).unwrap();
            read_bin_f32(file, shape)
        };
        let mut layers = Vec::with_capacity(manifest.model.n_layers);
        for li in 0..manifest.model.n_layers {
            let mut lw = Vec::with_capacity(manifest.layer_weight_order.len());
            for wname in &manifest.layer_weight_order {
                lw.push(get(&format!("layers.{li}.{wname}"))?);
            }
            layers.push(lw);
        }
        Ok(Weights {
            tok_emb: get("tok_emb")?,
            ln_f: get("ln_f")?,
            unembed: get("unembed")?,
            layers,
        })
    }

    pub fn total_bytes(&self) -> usize {
        self.tok_emb.nbytes()
            + self.ln_f.nbytes()
            + self.unembed.nbytes()
            + self.layers.iter().flatten().map(|t| t.nbytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_manifest_json() -> String {
        r#"{
          "model": {"vocab_size": 260, "n_layers": 2, "d_model": 8,
                    "n_heads": 4, "n_kv_heads": 2, "d_head": 2, "d_ff": 16,
                    "rope_base": 10000.0, "window": 4, "max_seq_len": 64,
                    "bos_id": 256, "sep_id": 257, "query_id": 258,
                    "pad_id": 259, "group_size": 2},
          "artifacts": {"prefill_buckets": [16, 32], "decode_buckets": [32],
                        "decode_batch_sizes": [2, 4], "pool_kernel": 7},
          "layer_weight_order": ["ln1", "wq"],
          "weights": [
            {"name": "tok_emb", "file": "weights/tok_emb.bin", "shape": [4, 2]},
            {"name": "ln_f", "file": "weights/ln_f.bin", "shape": [8]},
            {"name": "unembed", "file": "weights/unembed.bin", "shape": [2, 2]},
            {"name": "layers.0.ln1", "file": "weights/l0ln1.bin", "shape": [8]},
            {"name": "layers.0.wq", "file": "weights/l0wq.bin", "shape": [2, 4]},
            {"name": "layers.1.ln1", "file": "weights/l1ln1.bin", "shape": [8]},
            {"name": "layers.1.wq", "file": "weights/l1wq.bin", "shape": [2, 4]}
          ]
        }"#
        .to_string()
    }

    fn write_demo(dir: &Path) {
        fs::create_dir_all(dir.join("weights")).unwrap();
        fs::write(dir.join("manifest.json"), demo_manifest_json()).unwrap();
        let files = [
            ("weights/tok_emb.bin", 8),
            ("weights/ln_f.bin", 8),
            ("weights/unembed.bin", 4),
            ("weights/l0ln1.bin", 8),
            ("weights/l0wq.bin", 8),
            ("weights/l1ln1.bin", 8),
            ("weights/l1wq.bin", 8),
        ];
        for (f, n) in files {
            let data: Vec<u8> = (0..n).flat_map(|i| (i as f32).to_le_bytes()).collect();
            fs::write(dir.join(f), data).unwrap();
        }
    }

    #[test]
    fn manifest_and_weights_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lava_test_{}", std::process::id()));
        write_demo(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.n_layers, 2);
        assert_eq!(m.model.group_size(), 2);
        assert_eq!(m.buckets.prefill, vec![16, 32]);
        assert_eq!(m.buckets.decode_batch, vec![2, 4]);
        let w = Weights::load(&m).unwrap();
        assert_eq!(w.layers.len(), 2);
        assert_eq!(w.layers[0].len(), 2);
        assert_eq!(w.tok_emb.shape, vec![4, 2]);
        assert_eq!(w.tok_emb.as_f32().unwrap()[3], 3.0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kv_bytes_accounting() {
        let dir = std::env::temp_dir().join(format!("lava_test2_{}", std::process::id()));
        write_demo(&dir);
        let m = Manifest::load(&dir).unwrap();
        // 2 kv heads * d_head 2 * 2 (K+V) * 4 bytes
        assert_eq!(m.model.kv_bytes_per_token(), 32);
        fs::remove_dir_all(&dir).ok();
    }
}
