//! `lava` — the serving launcher.
//!
//! Subcommands:
//!   serve     --addr 127.0.0.1:7171 --policy lava --budget 32
//!   generate  --text "..." (or --prompt 1,2,3) --max-new 16
//!   bench     --policy lava --budget 32 --ctx 256 --per-task 3   (quick suite)
//!   info      print manifest / artifact / platform details
//!
//! All subcommands take --artifacts <dir> (default ./artifacts) and run the
//! AOT-compiled model through PJRT; python is never invoked.

use anyhow::{bail, Result};

use lava::bench::eval;
use lava::compress::Policy;
use lava::coordinator::engine::{Engine, EngineOptions, GenerateRequest};
use lava::coordinator::server::Server;
use lava::model::backend::PjrtBackend;
use lava::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: lava <serve|generate|bench|info> [--artifacts DIR] [--policy NAME] \
         [--budget N] [--addr HOST:PORT] [--text STR | --prompt a,b,c] [--max-new N]\n\
         policies: {}",
        Policy::all_names().join(", ")
    );
    std::process::exit(2);
}

fn build_engine(args: &Args) -> Result<Engine<PjrtBackend>> {
    let dir = args.str_or("artifacts", "artifacts");
    let policy_name = args.str_or("policy", "lava");
    let Some(policy) = Policy::by_name(&policy_name) else {
        bail!("unknown policy {policy_name}; known: {}", Policy::all_names().join(", "));
    };
    let budget = args.usize_or("budget", 32);
    let backend = PjrtBackend::load(&dir)?;
    let mut opts = EngineOptions::new(policy, budget);
    opts.max_new_tokens = args.usize_or("max-new", 32);
    Ok(Engine::new(backend, opts))
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let cmd = args.positional().first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "serve" => {
            let engine = build_engine(&args)?;
            let addr = args.str_or("addr", "127.0.0.1:7171");
            Server::new(engine).serve(&addr)?;
        }
        "generate" => {
            let mut engine = build_engine(&args)?;
            let prompt: Vec<i32> = if let Some(t) = args.get("text") {
                t.bytes().map(|b| b as i32).collect()
            } else if let Some(p) = args.get("prompt") {
                p.split(',').filter_map(|s| s.trim().parse().ok()).collect()
            } else {
                bail!("generate needs --text or --prompt");
            };
            let max_new = args.usize_or("max-new", 32);
            let r = engine.generate(&GenerateRequest { prompt, max_new_tokens: max_new })?;
            println!("tokens: {:?}", r.tokens);
            let text: String = r
                .tokens
                .iter()
                .filter(|&&t| (0..256).contains(&t))
                .map(|&t| t as u8 as char)
                .collect();
            println!("text:   {text:?}");
            println!(
                "prefill {:.1} ms, decode {:.1} ms, kv {:.1} KiB, budgets {:?}",
                r.prefill_secs * 1e3,
                r.decode_secs * 1e3,
                r.kv_bytes_after_prefill as f64 / 1024.0,
                r.budgets
            );
        }
        "bench" => {
            let mut engine = build_engine(&args)?;
            let policy = args.str_or("policy", "lava");
            let budget = args.usize_or("budget", 32);
            let ctx = args.usize_or("ctx", 256);
            let per_task = args.usize_or("per-task", 2);
            let r = eval::run_suite(&mut engine, &policy, budget, ctx, per_task, 0)?;
            println!("policy={policy} budget={budget} ctx={ctx}");
            for (task, score) in &r.per_task {
                println!("  {task:<20} {score:.3}");
            }
            println!(
                "  extraction={:.3} generation={:.3} overall={:.3}",
                r.extraction_avg, r.generation_avg, r.overall_avg
            );
            println!("{}", engine.metrics.report());
        }
        "info" => {
            let dir = args.str_or("artifacts", "artifacts");
            let manifest = lava::model::Manifest::load(&dir)?;
            let backend = PjrtBackend::load(&dir)?;
            println!("platform:        {}", backend.runtime.platform());
            println!("model:           {:?}", manifest.model);
            println!("prefill buckets: {:?}", manifest.buckets.prefill);
            println!("decode buckets:  {:?}", manifest.buckets.decode);
            println!("weights:         {} tensors", manifest.weight_shapes.len());
        }
        _ => usage(),
    }
    Ok(())
}
