"""L2: the GQA transformer, written as the per-entrypoint jax functions the
rust coordinator executes through PJRT.

Entrypoints (each AOT-lowered to HLO text by aot.py, one per shape bucket):

  embed(ids[N], tok_emb)                      -> x[N, d]
  layer_prefill(x[N,d], length, <layer w>)    -> x_out, K, V, win_attn,
                                                 acc_attn, vnorm
  lava_score_ep(win_attn, V, length)          -> scores[Hk, N]   (fused path)
  layer_decode(x[1,d], K[Hk,M,dh], V, valid, pos, <layer w>)
                                              -> x_out, k_new, v_new, attn
  layer_decode_batched(x[B,d], K[B,Hk,M,dh], V, valid, pos[B], <layer w>)
                                              -> x_out, k_new, v_new, attn
                                                 (B packed sessions, one call)
  logits(x[1,d], ln_f, unembed)               -> p[vocab]

Weights are *runtime inputs*, so one compiled `layer_prefill` executable
serves every layer — the rust side binds each layer's weight literals.

Layer-wise prefill (one PJRT call per layer) is exactly what Algorithm 2
needs: the coordinator evicts layer l's cache (and recompresses layers < l)
before layer l+1 runs, so peak memory never holds two uncompressed layers.

The same module also provides full_forward() — a plain-jnp batched forward
used only by train.py at build time — and reference_prefill(), the oracle
for the composed entrypoints.
"""

import jax
import jax.numpy as jnp

from .config import MODEL, ARTIFACTS
from .kernels.flash_attention import flash_attention
from .kernels.window_attention import window_attention
from .kernels.lava_score import lava_score
from .kernels import ref

NEG_INF = -1e30

# Per-layer weight tensors, in the argument order used by every entrypoint
# and recorded in the manifest for the rust loader.
LAYER_WEIGHT_NAMES = ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------

def rms_norm(x, g, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, positions, base=MODEL.rope_base):
    """Rotary embedding. x: [..., T, d_h], positions: [T] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(h, lw, n):
    """Project + head-split + RoPE. h: [N, d]. Returns q[H,N,dh], k,v[Hk,N,dh]."""
    cfg = MODEL
    q = (h @ lw["wq"]).reshape(n, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = (h @ lw["wk"]).reshape(n, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    v = (h @ lw["wv"]).reshape(n, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    pos = jnp.arange(n, dtype=jnp.int32)
    return rope(q, pos), rope(k, pos), v


def _ffn(x, lw):
    h = rms_norm(x, lw["ln2"])
    return x + jax.nn.silu(h @ lw["w1"]) @ lw["w2"]


# --------------------------------------------------------------------------
# entrypoints (AOT-lowered)
# --------------------------------------------------------------------------

def embed(ids, tok_emb):
    """ids: [N] int32 -> x: [N, d]."""
    return tok_emb[ids]


def layer_prefill(x, length, ln1, wq, wk, wv, wo, ln2, w1, w2, *, interpret=True):
    """One transformer layer over the whole (padded) prompt.

    Args:
      x: [N, d] layer input.  length: [1] int32 valid-token count (>= window).

    Returns:
      x_out    [N, d]      layer output (input to layer l+1)
      k, v     [Hk, N, dh] the layer's KV cache (keys post-RoPE)
      win_attn [H, w, N]   recent-window attention (observation pass)
      acc_attn [H, N]      accumulated column attention mass (H2O score)
      vnorm    [Hk, N]     per-token value L1 norms
    """
    cfg = MODEL
    lw = dict(ln1=ln1, wq=wq, wk=wk, wv=wv, wo=wo, ln2=ln2, w1=w1, w2=w2)
    n = x.shape[0]
    h = rms_norm(x, ln1)
    q, k, v = _qkv(h, lw, n)

    o, acc_attn = flash_attention(q, k, v, length, interpret=interpret)
    attn_out = o.transpose(1, 0, 2).reshape(n, cfg.n_heads * cfg.d_head) @ wo
    x = x + attn_out
    x_out = _ffn(x, lw)

    start = jnp.maximum(length[0] - cfg.window, 0)
    qw = jax.lax.dynamic_slice(q, (0, start, 0), (cfg.n_heads, cfg.window, cfg.d_head))
    win_attn = window_attention(qw, k, length, cfg.window, interpret=interpret)
    vnorm = jnp.sum(jnp.abs(v), axis=-1)
    return x_out, k, v, win_attn, acc_attn, vnorm


def layer_prefill_chunked(x_chunk, carry_k, carry_v, meta,
                          ln1, wq, wk, wv, wo, ln2, w1, w2):
    """One transformer layer over one *chunk* of a prompt's prefill.

    Chunked prefill splits a prompt into fixed-size chunks; each chunk
    attends over the K/V carried in from prior chunks plus its own, so
    accumulating every chunk's outputs reproduces `layer_prefill` at bucket
    N exactly (the masks below are the monolithic ones, rewritten around
    absolute positions).

    Args:
      x_chunk: [C, d] residual-stream rows for absolute positions
               [start, start+C) (rows >= chunk_len are padding).
      carry_k, carry_v: [Hk, N, dh] accumulated K/V (post-RoPE keys) from
               prior chunks; rows >= start are unspecified and never read.
      meta:    [3] int32 = (start, chunk_len, total_len).

    Returns:
      x_out    [C, d]      chunk rows of the layer output
      k, v     [Hk, C, dh] the chunk's KV rows (keys post-RoPE)
      win_attn [H, w, N]   window rows whose query position falls in this
                           chunk (full normalized distributions; other rows
                           exactly zero, so the rust side can accumulate
                           panels additively)
      acc_attn [H, N]      additive column-mass contribution of this
                           chunk's valid query rows
      vnorm    [Hk, N]     value L1 norms at this chunk's columns, 0 elsewhere
    """
    cfg = MODEL
    lw = dict(ln1=ln1, wq=wq, wk=wk, wv=wv, wo=wo, ln2=ln2, w1=w1, w2=w2)
    c = x_chunk.shape[0]
    n = carry_k.shape[1]
    start, chunk_len, total = meta[0], meta[1], meta[2]

    h = rms_norm(x_chunk, ln1)
    q = (h @ wq).reshape(c, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = (h @ wk).reshape(c, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    v = (h @ wv).reshape(c, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    pos = start + jnp.arange(c, dtype=jnp.int32)
    q = rope(q, pos)
    k = rope(k, pos)

    # scatter the chunk's K/V over the carry at absolute positions — a
    # gather + where, not dynamic_update_slice: start + C may run past N
    # for a tail chunk and DUS would clamp the start index backwards
    j = jnp.arange(n, dtype=jnp.int32)
    use_chunk = (j >= start) & (j < start + chunk_len)
    idx = jnp.clip(j - start, 0, c - 1)
    k_full = jnp.where(use_chunk[None, :, None], k[:, idx, :], carry_k)
    v_full = jnp.where(use_chunk[None, :, None], v[:, idx, :], carry_v)

    g = cfg.group_size
    kk = jnp.repeat(k_full, g, axis=0)                       # [H, N, dh]
    vv = jnp.repeat(v_full, g, axis=0)

    # same mask as the monolithic flash_attention (col <= row & col <
    # length), with the query row index made absolute
    scores = jnp.einsum("hqd,hkd->hqk", q, kk) / jnp.sqrt(
        jnp.float32(cfg.d_head)
    )                                                        # [H, C, N]
    qpos = pos[None, :, None]
    col = j[None, None, :]
    mask = (col <= qpos) & (col < total)
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(scores - m), 0.0)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)           # [H, C, N]

    o = jnp.einsum("hqk,hkd->hqd", probs, vv)
    attn_out = o.transpose(1, 0, 2).reshape(c, cfg.n_heads * cfg.d_head) @ wo
    x_out = _ffn(x_chunk + attn_out, lw)

    # H2O column mass: this chunk's valid query rows only (padding rows of a
    # tail chunk fall outside [start, start+chunk_len) and contribute 0)
    row_valid = jnp.arange(c)[None, :, None] < chunk_len
    acc_attn = jnp.sum(jnp.where(row_valid, probs, 0.0), axis=1)

    # window panel: row r belongs to query position total - w + r; rows this
    # chunk owns carry its already-normalized probability row, others are 0
    w = cfg.window
    wpos = total - w + jnp.arange(w, dtype=jnp.int32)
    owned = ((wpos >= start) & (wpos < start + chunk_len)).astype(jnp.float32)
    widx = jnp.clip(wpos - start, 0, c - 1)
    win_attn = probs[:, widx, :] * owned[None, :, None]      # [H, w, N]

    vnorm_chunk = jnp.sum(jnp.abs(v), axis=-1)               # [Hk, C]
    vnorm = jnp.where(use_chunk[None, :], vnorm_chunk[:, idx], 0.0)

    return x_out, k, v, win_attn, acc_attn, vnorm


def layer_prefill_chunked_evict(x_chunk, carry_k, carry_v, carry_pos, meta,
                                ln1, wq, wk, wv, wo, ln2, w1, w2):
    """One chunk of a layer's prefill against a *compacted* carry.

    Streaming eviction keeps only the surviving K/V columns between chunks,
    packed at the front of a fixed working cap; `carry_pos` maps each carry
    column to its absolute prompt position (-1 = dead/padding). The chunk
    attends over [carry columns, own rows], so observation panels come back
    at the compact width m = cap + C: column j < cap is carry column j,
    column cap + r is chunk row r (absolute position start + r).

    Args:
      x_chunk:  [C, d] residual-stream rows for positions [start, start+C).
      carry_k, carry_v: [Hk, cap, dh] compacted carry (post-RoPE keys);
                columns >= the live count are never read.
      carry_pos: [cap] int32 absolute positions, live columns packed at the
                front in ascending order, then -1 padding.
      meta:     [4] int32 = (start, chunk_len, total_len, n_live); n_live is
                informational — masking derives from carry_pos directly.

    Returns:
      x_out    [C, d]       chunk rows of the layer output
      k, v     [Hk, C, dh]  the chunk's KV rows (keys post-RoPE)
      win_attn [H, w, m]    window panel; row r holds query position
                            start + chunk_len - w + r, rows owned by earlier
                            chunks exactly zero
      acc_attn [H, m]       additive column-mass contribution of this
                            chunk's valid query rows
      vnorm    [Hk, m]      value L1 norms at this chunk's columns, 0 on
                            carry columns (their norms were accumulated by
                            the chunk that owned them)
    """
    cfg = MODEL
    lw = dict(ln1=ln1, wq=wq, wk=wk, wv=wv, wo=wo, ln2=ln2, w1=w1, w2=w2)
    c = x_chunk.shape[0]
    cap = carry_k.shape[1]
    start, chunk_len, total = meta[0], meta[1], meta[2]

    h = rms_norm(x_chunk, ln1)
    q = (h @ wq).reshape(c, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = (h @ wk).reshape(c, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    v = (h @ wv).reshape(c, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    pos = start + jnp.arange(c, dtype=jnp.int32)
    q = rope(q, pos)
    k = rope(k, pos)

    # compact key space: carry columns first, then the chunk's own rows
    k_all = jnp.concatenate([carry_k, k], axis=1)            # [Hk, m, dh]
    v_all = jnp.concatenate([carry_v, v], axis=1)
    pos_all = jnp.concatenate([carry_pos, pos])              # [m]
    live = jnp.concatenate(
        [carry_pos >= 0, jnp.arange(c, dtype=jnp.int32) < chunk_len]
    )                                                        # [m] bool

    g = cfg.group_size
    kk = jnp.repeat(k_all, g, axis=0)                        # [H, m, dh]
    vv = jnp.repeat(v_all, g, axis=0)

    scores = jnp.einsum("hqd,hkd->hqk", q, kk) / jnp.sqrt(
        jnp.float32(cfg.d_head)
    )                                                        # [H, C, m]
    qpos = pos[None, :, None]
    mask = live[None, None, :] & (pos_all[None, None, :] <= qpos)
    scores = jnp.where(mask, scores, NEG_INF)
    mx = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(scores - mx), 0.0)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)           # [H, C, m]

    o = jnp.einsum("hqk,hkd->hqd", probs, vv)
    attn_out = o.transpose(1, 0, 2).reshape(c, cfg.n_heads * cfg.d_head) @ wo
    x_out = _ffn(x_chunk + attn_out, lw)

    row_valid = jnp.arange(c)[None, :, None] < chunk_len
    acc_attn = jnp.sum(jnp.where(row_valid, probs, 0.0), axis=1)  # [H, m]

    # rolling window panel: row r belongs to query position seen - w + r
    # (seen = start + chunk_len); rows whose query falls before this chunk
    # are owned by an earlier chunk and come back zero
    w = cfg.window
    wpos = start + chunk_len - w + jnp.arange(w, dtype=jnp.int32)
    owned = (wpos >= start).astype(jnp.float32)
    widx = jnp.clip(wpos - start, 0, c - 1)
    win_attn = probs[:, widx, :] * owned[None, :, None]      # [H, w, m]

    vnorm_chunk = jnp.sum(jnp.abs(v), axis=-1)               # [Hk, C]
    vnorm_chunk = jnp.where(
        jnp.arange(c)[None, :] < chunk_len, vnorm_chunk, 0.0
    )
    vnorm = jnp.concatenate(
        [jnp.zeros((cfg.n_kv_heads, cap), vnorm_chunk.dtype), vnorm_chunk],
        axis=1,
    )                                                        # [Hk, m]

    return x_out, k, v, win_attn, acc_attn, vnorm


def lava_score_ep(win_attn, v, length, *, interpret=True):
    """Fused LAVa scoring fast path (kernels/lava_score.py)."""
    return lava_score(
        win_attn, v, length, MODEL.group_size, ARTIFACTS.pool_kernel,
        interpret=interpret,
    )


def layer_decode(x, k_cache, v_cache, valid, pos, ln1, wq, wk, wv, wo, ln2, w1, w2):
    """One transformer layer for a single decode step.

    Args:
      x:       [1, d] current residual stream input.
      k_cache: [Hk, M, dh] (post-RoPE keys), v_cache: [Hk, M, dh].
      valid:   [Hk, M] f32 {0,1} — per-kv-head ragged occupancy (AdaKV-style
               dynamic head budgets leave different lengths per head).
      pos:     [1] int32 absolute position of the new token (RoPE phase).

    Returns:
      x_out [1, d];  k_new, v_new [Hk, dh];  attn [H, M+1] (col M = self).
    """
    cfg = MODEL
    lw = dict(ln1=ln1, wq=wq, wk=wk, wv=wv, wo=wo, ln2=ln2, w1=w1, w2=w2)
    h = rms_norm(x, ln1)
    q = (h @ wq).reshape(1, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = (h @ wk).reshape(1, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    v = (h @ wv).reshape(1, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    q = rope(q, pos)
    k = rope(k, pos)

    k_full = jnp.concatenate([k_cache, k], axis=1)         # [Hk, M+1, dh]
    v_full = jnp.concatenate([v_cache, v], axis=1)
    valid_full = jnp.concatenate(
        [valid, jnp.ones((cfg.n_kv_heads, 1), valid.dtype)], axis=1
    )

    g = cfg.group_size
    kk = jnp.repeat(k_full, g, axis=0)                     # [H, M+1, dh]
    vv = jnp.repeat(v_full, g, axis=0)
    mask = jnp.repeat(valid_full, g, axis=0) > 0.5         # [H, M+1]

    scores = jnp.einsum("hqd,hkd->hqk", q, kk)[:, 0] / jnp.sqrt(
        jnp.float32(cfg.d_head)
    )                                                      # [H, M+1]
    scores = jnp.where(mask, scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1) * mask
    o = jnp.einsum("hk,hkd->hd", attn, vv).reshape(1, cfg.n_heads * cfg.d_head)
    x = x + o @ wo
    x_out = _ffn(x, lw)
    return x_out, k[:, 0], v[:, 0], attn


def layer_decode_batched(x, k_cache, v_cache, valid, pos,
                         ln1, wq, wk, wv, wo, ln2, w1, w2):
    """One transformer layer for a decode step over B packed sessions.

    vmap of layer_decode over the leading batch axis, with the layer weights
    broadcast: each session's math is exactly the single-session entrypoint,
    so the batched artifact is bit-compatible with looping layer_decode_{M}.

    Args:
      x:       [B, d];  k_cache, v_cache: [B, Hk, M, dh];  valid: [B, Hk, M];
      pos:     [B] int32 per-session absolute positions.

    Returns:
      x_out [B, d];  k_new, v_new [B, Hk, dh];  attn [B, H, M+1].
    """
    def one(xi, ki, vi, vali, pi):
        return layer_decode(xi[None, :], ki, vi, vali, pi[None],
                            ln1, wq, wk, wv, wo, ln2, w1, w2)

    x_out, k_new, v_new, attn = jax.vmap(one)(x, k_cache, v_cache, valid, pos)
    return x_out[:, 0], k_new, v_new, attn


def logits(x, ln_f, unembed):
    """x: [1, d] -> next-token logits [vocab]."""
    return (rms_norm(x, ln_f) @ unembed)[0]


# --------------------------------------------------------------------------
# training-only forward (plain jnp, batched) + init
# --------------------------------------------------------------------------

def init_params(key, cfg=MODEL):
    """Scaled-normal init; returns the full parameter pytree."""
    keys = jax.random.split(key, 2 + cfg.n_layers)

    def dense(k, fan_in, fan_out):
        return jax.random.normal(k, (fan_in, fan_out), jnp.float32) / jnp.sqrt(
            jnp.float32(fan_in)
        )

    params = {
        "tok_emb": jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32
        ) * 0.02,
        "ln_f": jnp.ones(cfg.d_model),
        "unembed": dense(keys[1], cfg.d_model, cfg.vocab_size),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + li], 6)
        params["layers"].append(
            {
                "ln1": jnp.ones(cfg.d_model),
                "wq": dense(ks[0], cfg.d_model, cfg.n_heads * cfg.d_head),
                "wk": dense(ks[1], cfg.d_model, cfg.n_kv_heads * cfg.d_head),
                "wv": dense(ks[2], cfg.d_model, cfg.n_kv_heads * cfg.d_head),
                "wo": dense(ks[3], cfg.n_heads * cfg.d_head, cfg.d_model),
                "ln2": jnp.ones(cfg.d_model),
                "w1": dense(ks[4], cfg.d_model, cfg.d_ff),
                "w2": dense(ks[5], cfg.d_ff, cfg.d_model),
            }
        )
    return params


def full_forward(params, ids, cfg=MODEL):
    """Batched training forward. ids: [B, T] int32 -> logits [B, T, vocab]."""
    b, t = ids.shape
    x = params["tok_emb"][ids]
    pos = jnp.arange(t, dtype=jnp.int32)
    rows = jnp.arange(t)[:, None]
    cols = jnp.arange(t)[None, :]
    causal = cols <= rows

    for lw in params["layers"]:
        h = rms_norm(x, lw["ln1"])
        q = (h @ lw["wq"]).reshape(b, t, cfg.n_heads, cfg.d_head)
        k = (h @ lw["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
        v = (h @ lw["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
        q = rope(q.transpose(0, 2, 1, 3).reshape(-1, t, cfg.d_head), pos)
        k = rope(k.transpose(0, 2, 1, 3).reshape(-1, t, cfg.d_head), pos)
        q = q.reshape(b, cfg.n_heads, t, cfg.d_head)
        k = k.reshape(b, cfg.n_kv_heads, t, cfg.d_head)
        v = v.transpose(0, 2, 1, 3)
        kk = jnp.repeat(k, cfg.group_size, axis=1)
        vv = jnp.repeat(v, cfg.group_size, axis=1)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kk) / jnp.sqrt(
            jnp.float32(cfg.d_head)
        )
        scores = jnp.where(causal[None, None], scores, NEG_INF)
        a = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", a, vv)
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.n_heads * cfg.d_head)
        x = x + o @ lw["wo"]
        h2 = rms_norm(x, lw["ln2"])
        x = x + jax.nn.silu(h2 @ lw["w1"]) @ lw["w2"]

    return rms_norm(x, params["ln_f"]) @ params["unembed"]


# --------------------------------------------------------------------------
# reference single-sequence forward — the oracle for the composed
# entrypoints and for Table 14 (layer attention output loss).
# --------------------------------------------------------------------------

def reference_prefill(params, ids, cfg=MODEL):
    """Runs all layers (plain jnp, unpadded), returning per-layer internals.

    Returns (per_layer, next_logits) where per_layer[l] has keys
    x_in, q, k, v, win_attn, acc_attn, vnorm, x_out.
    """
    n = ids.shape[0]
    x = params["tok_emb"][ids]
    per_layer = []
    for lw in params["layers"]:
        h = rms_norm(x, lw["ln1"])
        q, k, v = _qkv(h, lw, n)
        o, acc = ref.causal_attention_ref(q, k, v, n)
        attn_out = (
            o.transpose(1, 0, 2).reshape(n, cfg.n_heads * cfg.d_head) @ lw["wo"]
        )
        x_mid = x + attn_out
        x_out = _ffn(x_mid, lw)
        qw = q[:, n - cfg.window:]
        win = ref.window_attention_ref(qw, k, n, cfg.window)
        vnorm = jnp.sum(jnp.abs(v), axis=-1)
        per_layer.append(
            dict(x_in=x, q=q, k=k, v=v, win_attn=win, acc_attn=acc,
                 vnorm=vnorm, x_out=x_out)
        )
        x = x_out
    next_logits = rms_norm(x[-1:], params["ln_f"]) @ params["unembed"]
    return per_layer, next_logits[0]
