"""Build-time trainer for the tiny GQA transformer.

Trains on the synthetic long-context mixture (data.py) so the model develops
peaked, retrieval-style attention — a prerequisite for KV-eviction quality
comparisons to mean anything (see DESIGN.md §3). Runs once inside
`make artifacts`; the result is cached in artifacts/weights.npz.

Hand-rolled Adam (optax is not in the image). Single CPU core: defaults are
sized for a ~3-5 minute run; override with LAVA_TRAIN_STEPS / LAVA_TRAIN_*.
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .config import MODEL
from .model import full_forward, init_params

DEFAULTS = dict(steps=1600, batch=8, seq_len=160, lr=3e-3, warmup=30, seed=0)

# Training lengths, sampled per-step (interleaved, never phased — a phased
# curriculum catastrophically forgets short-context skills). Batch sizes
# keep tokens/step roughly constant. Benchmarks use contexts <= ~512, a
# ~16x scale-down of the paper's 8k-32k (DESIGN.md §3).
LENGTH_MIX = [(128, 12), (160, 10), (192, 8), (256, 6)]

# Fraction of steps spent in the fixed-geometry bootstrap phase (T=160 only).
# Induction heads in a model this small only emerge with a consistent copy
# geometry; once formed, the mixed-length phase (which still includes T=160)
# generalizes them without forgetting. Both observations are empirical from
# build-time runs logged in artifacts/train_log.json.
BOOTSTRAP_FRAC = 0.4
BOOTSTRAP = (160, 8)


def _env_int(name, default):
    return int(os.environ.get(name, default))


def loss_fn(params, ids, mask):
    lg = full_forward(params, ids)
    logp = jax.nn.log_softmax(lg[:, :-1], axis=-1)
    tgt = ids[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:].astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def clip_global_norm(grads, max_norm=1.0):
    """Global-norm gradient clipping — without it training exhibits
    catastrophic post-breakthrough loss spikes (5.5 -> 0.3 -> 5.5)."""
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return dict(m=zeros, v=jax.tree.map(jnp.zeros_like, params), t=jnp.zeros(()))


def adam_update(grads, state, params, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
    new_params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new_params, dict(m=m, v=v, t=t)


def train(steps=None, lr=None, seed=None, log_every=25, log=None):
    steps = steps or _env_int("LAVA_TRAIN_STEPS", DEFAULTS["steps"])
    lr = lr or float(os.environ.get("LAVA_TRAIN_LR", DEFAULTS["lr"]))
    seed = seed if seed is not None else DEFAULTS["seed"]
    warmup = DEFAULTS["warmup"]

    rng = np.random.default_rng(seed)
    params = init_params(jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, ids, mask, lr_t):
        loss, grads = jax.value_and_grad(loss_fn)(params, ids, mask)
        grads = clip_global_norm(grads)
        params, opt = adam_update(grads, opt, params, lr_t)
        return params, opt, loss

    history = []
    t0 = time.time()
    boot_steps = int(steps * BOOTSTRAP_FRAC)
    for it in range(steps):
        if it < boot_steps:
            seq_len, bsz = BOOTSTRAP
            mix = data.MIX_BOOT
        else:
            seq_len, bsz = LENGTH_MIX[rng.integers(0, len(LENGTH_MIX))]
            mix = data.MIX
        ids, mask = data.batch(rng, bsz, seq_len, mix)
        # linear warmup; piecewise decay. Full lr is needed only until the
        # induction breakthrough (~step 300-500 at T=160); after that the
        # landscape is cliff-ridden and lr must drop hard or the run
        # diverges (loss > ln V), clipping or not.
        warm = min(1.0, (it + 1) / warmup)
        frac = it / max(1, steps)
        decay = 1.0 if frac < 0.35 else (0.25 if frac < 0.6 else 0.08)
        lr_t = lr * warm * decay
        params, opt, loss = step(params, opt, jnp.array(ids), jnp.array(mask), lr_t)
        if it % log_every == 0 or it == steps - 1:
            history.append(dict(step=it, loss=float(loss), seq_len=seq_len,
                                elapsed=round(time.time() - t0, 1)))
            msg = (f"step {it:4d} T={seq_len:4d} loss {float(loss):.4f} "
                   f"({time.time()-t0:.0f}s)")
            (log or print)(msg)
    return params, history


def eval_retrieval(params, n_batches=4, seq_len=256, seed=123):
    """Held-out needle accuracy: fraction of needle bytes predicted exactly."""
    rng = np.random.default_rng(seed)
    hits = total = 0
    for _ in range(n_batches):
        toks, mask = data.gen_needle(rng, seq_len)
        lg = full_forward(params, jnp.array(toks[None], jnp.int32))[0]
        pred = np.argmax(np.asarray(lg[:-1]), axis=-1)
        tgt = toks[1:]
        m = mask[1:]
        hits += int((pred[m] == tgt[m]).sum())
        total += int(m.sum())
    return hits / max(total, 1)


def eval_sweep(params, lengths=(128, 256, 384, 512), n_batches=4):
    """Needle accuracy at several context lengths (length-generalization)."""
    return {int(t): round(eval_retrieval(params, n_batches, t), 3)
            for t in lengths}


def save(params, path):
    flat = {}
    flat["tok_emb"] = np.asarray(params["tok_emb"])
    flat["ln_f"] = np.asarray(params["ln_f"])
    flat["unembed"] = np.asarray(params["unembed"])
    for li, lw in enumerate(params["layers"]):
        for k, vv in lw.items():
            flat[f"layers.{li}.{k}"] = np.asarray(vv)
    np.savez(path, **flat)


def load(path):
    z = np.load(path)
    params = {
        "tok_emb": jnp.array(z["tok_emb"]),
        "ln_f": jnp.array(z["ln_f"]),
        "unembed": jnp.array(z["unembed"]),
        "layers": [],
    }
    for li in range(MODEL.n_layers):
        params["layers"].append(
            {k: jnp.array(z[f"layers.{li}.{k}"])
             for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")}
        )
    return params


def load_or_train(cache_path, log_path=None):
    """Returns trained params, training + caching as needed."""
    if os.path.exists(cache_path):
        print(f"[train] using cached weights {cache_path}")
        return load(cache_path)
    params, history = train()
    accs = eval_sweep(params)
    print(f"[train] held-out needle byte accuracy by length: {accs}")
    save(params, cache_path)
    if log_path:
        with open(log_path, "w") as f:
            json.dump({"history": history, "needle_acc": accs,
                       "config": DEFAULTS, "length_mix": LENGTH_MIX}, f,
                      indent=2)
    return params


if __name__ == "__main__":
    p, h = train()
    print("needle acc:", eval_retrieval(p))
