"""L1 Pallas kernel: fused LAVa score (the paper's compute hot-spot).

Fuses the whole of Definition 1 + the GQA rule (§4.3) + maxpool smoothing
(App. D) into one kernel so only the [Hk, N] score row ever leaves fast
memory:

    window-attn mean over w  ->  x max_k ||V[k]||_1  ->  per-head maxpool(7)
    ->  GQA group-max        ->  scores [Hk, N]

SnapKV-style reference implementations materialize the [H, w, N] panel in
HBM and run four separate elementwise/reduction launches; on TPU the fusion
keeps VMEM traffic at (g*w*N + N*d_h) reads + N writes per kv head.

Schedule: grid = (Hk,); each step owns one GQA group: the group's window
attention panel [g, w, N] and the kv head's value tile [N, d_h].
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _maxpool_same(x, kernel):
    """Same-padding max pool along the last axis via shifted maxima."""
    half = kernel // 2
    out = x
    for off in range(1, half + 1):
        left = jnp.concatenate(
            [jnp.full(x.shape[:-1] + (off,), NEG_INF, x.dtype), x[..., :-off]],
            axis=-1,
        )
        right = jnp.concatenate(
            [x[..., off:], jnp.full(x.shape[:-1] + (off,), NEG_INF, x.dtype)],
            axis=-1,
        )
        out = jnp.maximum(out, jnp.maximum(left, right))
    return out


def _kernel(length_ref, attn_ref, v_ref, out_ref, *, pool_kernel):
    length = length_ref[0]
    attn = attn_ref[...]                  # [g, w, N]  group's window attention
    v = v_ref[0]                          # [N, d_h]
    g, w, n = attn.shape

    valid = jax.lax.broadcasted_iota(jnp.int32, (n,), 0) < length

    a_mean = jnp.mean(attn, axis=1)                        # [g, N]
    vnorm = jnp.sum(jnp.abs(v), axis=-1)                   # [N]
    vbar = jnp.max(jnp.where(valid, vnorm, 0.0))           # scalar
    s = a_mean * vbar                                      # [g, N]
    s = _maxpool_same(s, pool_kernel)                      # per-head smoothing
    s = jnp.max(s, axis=0)                                 # GQA group-max [N]
    out_ref[0] = jnp.where(valid, s, 0.0)


@functools.partial(jax.jit, static_argnames=("group", "pool_kernel", "interpret"))
def lava_score(win_attn, v, length, group, pool_kernel=7, interpret=True):
    """Fused LAVa scores.

    Args:
      win_attn: [H, w, N] recent-window attention (window_attention output).
      v:        [Hk, N, d_h] value cache.
      length:   [1] int32.
      group:    GQA group size (H // Hk).

    Returns scores [Hk, N]; positions >= length are 0.
    """
    h, w, n = win_attn.shape
    hk, n2, dh = v.shape
    assert n == n2 and h == hk * group
    return pl.pallas_call(
        functools.partial(_kernel, pool_kernel=pool_kernel),
        grid=(hk,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((group, w, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hk, n), jnp.float32),
        interpret=interpret,
    )(length, win_attn, v)
