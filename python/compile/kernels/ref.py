"""Pure-jnp oracles for the Pallas kernels.

Everything here is the *semantic definition*; the Pallas kernels in
flash_attention.py / window_attention.py / lava_score.py must match these to
within float tolerance (enforced by python/tests/).

Shape conventions (single sequence; batching lives in the rust coordinator):
  q        [H,  N, d_h]   query heads
  k, v     [Hk, N, d_h]   kv heads (GQA, group size g = H // Hk)
  length   scalar int32   number of valid tokens (<= N); rows/cols >= length
                          are padding and must not contribute.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """[Hk, N, d] -> [Hk*group, N, d] by repeating each kv head `group` times."""
    return jnp.repeat(x, group, axis=0)


def causal_attention_ref(q, k, v, length):
    """Full causal attention + accumulated column attention mass.

    Returns:
      o        [H, N, d_h]  attention output
      acc_attn [H, N]       sum_{j < length} A[j, i]  (H2O's accumulated score)
    """
    h, n, dh = q.shape
    g = h // k.shape[0]
    kk, vv = repeat_kv(k, g), repeat_kv(v, g)
    scores = jnp.einsum("hqd,hkd->hqk", q, kk) / jnp.sqrt(jnp.float32(dh))
    rows = jnp.arange(n)[:, None]
    cols = jnp.arange(n)[None, :]
    mask = (cols <= rows) & (cols < length)
    scores = jnp.where(mask[None], scores, NEG_INF)
    a = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", a, vv)
    row_valid = (jnp.arange(n) < length).astype(a.dtype)
    acc = jnp.einsum("hqk,q->hk", a, row_valid)
    return o, acc


def window_attention_ref(qw, k, length, window):
    """Attention probabilities of the last `window` valid queries over all keys.

    qw is the already-sliced (and RoPE-rotated) query block for positions
    [length - window, length); requires length >= window (enforced upstream).

    Returns A_win [H, window, N]; columns >= length are exactly 0.
    """
    h, w, dh = qw.shape
    g = h // k.shape[0]
    kk = repeat_kv(k, g)
    n = k.shape[1]
    scores = jnp.einsum("hqd,hkd->hqk", qw, kk) / jnp.sqrt(jnp.float32(dh))
    qpos = length - window + jnp.arange(w)[:, None]      # [w, 1]
    cols = jnp.arange(n)[None, :]
    mask = (cols <= qpos) & (cols < length)
    scores = jnp.where(mask[None], scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1) * mask[None]


def maxpool1d_ref(x, kernel):
    """Same-padding max pool along the last axis (paper App. D, kernel=7)."""
    half = kernel // 2
    out = x
    for off in range(1, half + 1):
        left = jnp.concatenate(
            [jnp.full(x.shape[:-1] + (off,), NEG_INF, x.dtype), x[..., :-off]],
            axis=-1,
        )
        right = jnp.concatenate(
            [x[..., off:], jnp.full(x.shape[:-1] + (off,), NEG_INF, x.dtype)],
            axis=-1,
        )
        out = jnp.maximum(out, jnp.maximum(left, right))
    return out


def lava_score_ref(win_attn, v, length, group, pool_kernel):
    """Fused LAVa score (Definition 1 + GQA group-max + maxpool smoothing).

    s_{l,h}[i] = (max_k ||V[k]||_1 / w) * sum_{j in window} A^j[i]
    per-head maxpool(pool_kernel), then group-max over the GQA group.

    Returns scores [Hk, N]; positions >= length are 0.
    """
    h, w, n = win_attn.shape
    hk = h // group
    a_mean = jnp.mean(win_attn, axis=1)                    # [H, N]
    vnorm = jnp.sum(jnp.abs(v), axis=-1)                   # [Hk, N]
    valid = jnp.arange(n) < length
    vbar = jnp.max(jnp.where(valid[None], vnorm, 0.0), axis=-1)   # [Hk]
    s = a_mean * jnp.repeat(vbar, group)[:, None]          # [H, N]
    s = maxpool1d_ref(s, pool_kernel)
    s = jnp.max(s.reshape(hk, group, n), axis=1)           # [Hk, N]
    return jnp.where(valid[None], s, 0.0)
