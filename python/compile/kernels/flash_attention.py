"""L1 Pallas kernel: tiled causal GQA attention for prefill.

TPU rethink of the paper's FlashAttention-2 substrate (DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks + shared memory we
express the HBM->VMEM schedule with a Pallas grid + BlockSpecs:

  grid = (H, N // BLOCK_Q)
    - each step owns one query panel q[h, iq*BQ:(iq+1)*BQ, :] in VMEM,
    - K/V for the head's GQA group are streamed in BLOCK_K-sized tiles,
    - the score panel [BLOCK_Q, N] lives in VMEM scratch (<= 32x2048 f32 =
      256 KiB, far under the ~16 MiB VMEM budget), so softmax is a single
      in-register pass and the [N, N] matrix never exists in HBM,
    - QK^T and PV are MXU-shaped matmuls.

Besides the attention output, the kernel accumulates H2O's column attention
mass acc[h, i] = sum_{j<length} A[j, i] across grid steps for free (the
output block for `acc` is revisited by every iq step of a head and
accumulated in place) — this is what lets the rust side implement H2O/TOVA
without a second pass over the cache.

Must run with interpret=True on this image (CPU PJRT cannot execute Mosaic
custom-calls); the lowered HLO is what the rust runtime executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

BLOCK_Q = 32
BLOCK_K = 128


def _kernel(length_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, *, block_k, n):
    h_idx = pl.program_id(0)  # noqa: F841  (kept for grid readability)
    iq = pl.program_id(1)
    length = length_ref[0]

    q = q_ref[0]                       # [BQ, dh]
    bq, dh = q.shape
    nk = n // block_k

    row = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, n), 1)
    mask = (col <= row) & (col < length)

    # Score panel in VMEM scratch semantics: built tile-by-tile, kept local.
    def score_tile(jk, acc):
        k_tile = jax.lax.dynamic_slice(k_ref[0], (jk * block_k, 0), (block_k, dh))
        s = jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32)
        return jax.lax.dynamic_update_slice(acc, s, (0, jk * block_k))

    scores = jax.lax.fori_loop(
        0, nk, score_tile, jnp.zeros((bq, n), jnp.float32)
    ) / jnp.sqrt(jnp.float32(dh))
    scores = jnp.where(mask, scores, NEG_INF)

    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    probs = p / l                                    # [BQ, N]

    # PV contraction, streamed over the same K tiles.
    def pv_tile(jk, acc):
        v_tile = jax.lax.dynamic_slice(v_ref[0], (jk * block_k, 0), (block_k, dh))
        p_tile = jax.lax.dynamic_slice(probs, (0, jk * block_k), (bq, block_k))
        return acc + jnp.dot(p_tile, v_tile, preferred_element_type=jnp.float32)

    o_ref[0] = jax.lax.fori_loop(0, nk, pv_tile, jnp.zeros((bq, dh), jnp.float32))

    # Column-mass accumulation (H2O score), only over valid query rows.
    row_valid = (iq * bq + jnp.arange(bq)) < length
    colsum = jnp.sum(jnp.where(row_valid[:, None], probs, 0.0), axis=0)  # [N]

    @pl.when(iq == 0)
    def _init():
        acc_ref[0] = jnp.zeros_like(acc_ref[0])

    acc_ref[0] += colsum


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_attention(q, k, v, length, interpret=True):
    """Tiled causal attention.

    Args:
      q: [H, N, d_h] RoPE-rotated queries.
      k: [Hk, N, d_h] RoPE-rotated keys.
      v: [Hk, N, d_h] values.
      length: [1] int32, number of valid tokens.

    Returns:
      o:   [H, N, d_h]
      acc: [H, N] accumulated column attention mass over valid rows.
    """
    h, n, dh = q.shape
    hk = k.shape[0]
    g = h // hk
    block_q = min(BLOCK_Q, n)
    block_k = min(BLOCK_K, n)
    assert n % block_q == 0 and n % block_k == 0

    grid = (h, n // block_q)
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda hh, iq: (0,)),
            pl.BlockSpec((1, block_q, dh), lambda hh, iq: (hh, iq, 0)),
            pl.BlockSpec((1, n, dh), lambda hh, iq: (hh // g, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda hh, iq: (hh // g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda hh, iq: (hh, iq, 0)),
            pl.BlockSpec((1, n), lambda hh, iq: (hh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, n, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, n), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k, v)
