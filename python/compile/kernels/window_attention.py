"""L1 Pallas kernel: recent-window attention recompute (the observation pass).

SnapKV/LAVa score cache entries by how much the last `w` queries attend to
them (Definition 1). FlashAttention never materializes those probability
rows, so — exactly as in the paper's complexity analysis (App. D, the
O(H N w d_h) term) — we recompute them in a second, much cheaper pass.

Schedule: grid = (H,); per head the [w, N] probability panel is computed in
one VMEM-resident block (w=32, N<=2048 -> 256 KiB f32). K is streamed from
the head's GQA group slot. Columns >= length are exactly zero so downstream
scoring can treat the panel as dense.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(length_ref, qw_ref, k_ref, out_ref, *, window, n):
    length = length_ref[0]
    qw = qw_ref[0]                                   # [w, dh]
    k = k_ref[0]                                     # [n, dh]
    dh = qw.shape[-1]

    scores = jnp.dot(qw, k.T, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))

    qpos = length - window + jax.lax.broadcasted_iota(jnp.int32, (window, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (window, n), 1)
    mask = (col <= qpos) & (col < length)
    scores = jnp.where(mask, scores, NEG_INF)

    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    out_ref[0] = p / jnp.sum(p, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def window_attention(qw, k, length, window, interpret=True):
    """Attention probabilities of the last `window` queries over all keys.

    Args:
      qw: [H, w, d_h] RoPE-rotated queries for positions [length-w, length).
      k:  [Hk, N, d_h] RoPE-rotated keys.
      length: [1] int32.

    Returns A_win [H, w, N] with zero mass on columns >= length.
    """
    h, w, dh = qw.shape
    assert w == window
    hk, n, _ = k.shape
    g = h // hk
    return pl.pallas_call(
        functools.partial(_kernel, window=window, n=n),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda hh: (0,)),
            pl.BlockSpec((1, w, dh), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda hh: (hh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w, n), lambda hh: (hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, n), jnp.float32),
        interpret=interpret,
    )(length, qw, k)
