"""Model + artifact configuration shared by the whole build path.

This is the single source of truth for the tiny GQA transformer used to
exercise LAVa. The same values are serialized into artifacts/manifest.json so
the rust coordinator never hard-codes them.

The model is deliberately small (~1M params): the image is a single CPU core
and the model is trained at `make artifacts` time on synthetic long-context
tasks (see train.py + DESIGN.md §3 for why a *trained* model is required for
eviction-quality comparisons to be meaningful).
"""

from dataclasses import dataclass, asdict, field
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 260          # 256 bytes + BOS/SEP/QUERY/PAD
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 8               # query heads
    n_kv_heads: int = 4            # GQA: group size = n_heads / n_kv_heads
    d_head: int = 16
    d_ff: int = 256
    rope_base: float = 10000.0
    window: int = 16               # recent-window w (SnapKV/LAVa observation;
                                   # also the never-evicted suffix). Scaled
                                   # with the ~16x context scale-down.
    max_seq_len: int = 4096

    # Token ids of the specials.
    bos_id: int = 256
    sep_id: int = 257
    query_id: int = 258
    pad_id: int = 259

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads


@dataclass(frozen=True)
class ArtifactConfig:
    # Static-shape buckets for prefill/embed (token dimension N).
    prefill_buckets: List[int] = field(
        default_factory=lambda: [128, 256, 512, 1024, 2048]
    )
    # Static-shape buckets for decode cache capacity (slot dimension M).
    decode_buckets: List[int] = field(
        default_factory=lambda: [128, 256, 512, 1024, 2048, 4096]
    )
    # Batch sizes B lowered as layer_decode_batched_{M}x{B}: one dispatch
    # advances B same-capacity-bucket sessions. The rust scheduler chunks a
    # decode group greedily onto the largest fitting B and serves any
    # remainder with the per-session layer_decode_{M} artifacts.
    decode_batch_sizes: List[int] = field(
        default_factory=lambda: [2, 4, 8]
    )
    # Chunk widths C lowered as layer_prefill_chunked_{C}x{N} for every
    # prefill bucket N >= C: one prompt chunk (padded to C) attends over
    # the K/V carried in from prior chunks at observation width N. The
    # rust engine rounds its configured `prefill_chunk` up to one of
    # these (tail chunks may land on a smaller one), falling back to the
    # monolithic layer_prefill_{N} artifact when no pair fits.
    prefill_chunk_sizes: List[int] = field(
        default_factory=lambda: [128, 256]
    )
    # Compacted-carry working caps lowered as
    # layer_prefill_chunked_evict_{C}x{cap} for every chunk size C < cap:
    # streaming eviction bounds carry-in K/V at <= cap columns regardless of
    # prompt length (layer budget + chunk + window, rounded up to a cap).
    prefill_evict_caps: List[int] = field(
        default_factory=lambda: [256, 512]
    )
    pool_kernel: int = 7           # maxpool smoothing width (paper App. D)


MODEL = ModelConfig()
ARTIFACTS = ArtifactConfig()


def manifest_dict() -> dict:
    d = asdict(MODEL)
    d["group_size"] = MODEL.group_size
    return {"model": d, "artifacts": asdict(ARTIFACTS)}
