"""AOT export: lower every entrypoint to HLO *text* + dump weights + manifest.

HLO text (NOT serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction
ids; `HloModuleProto::from_text_file` re-parses and reassigns ids cleanly
(see /opt/xla-example/README.md).

Outputs under artifacts/:
  manifest.json            model config + entrypoint arg specs + file index
  weights.npz              training cache
  weights/<name>.bin       raw little-endian f32 blobs, one per tensor
  <entry>_<bucket>.hlo.txt lowered modules

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import numpy as np
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train
from .config import MODEL, ARTIFACTS, manifest_dict

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def layer_weight_specs(cfg=MODEL):
    d, hq, hk, dh, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.d_head, cfg.d_ff)
    return [
        ("ln1", (d,)),
        ("wq", (d, hq * dh)),
        ("wk", (d, hk * dh)),
        ("wv", (d, hk * dh)),
        ("wo", (hq * dh, d)),
        ("ln2", (d,)),
        ("w1", (d, ff)),
        ("w2", (ff, d)),
    ]


def write_weights(params, out_dir):
    """One raw LE f32 .bin per tensor + index entries for the manifest."""
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)
    index = []

    def dump(name, arr):
        arr = np.asarray(arr, np.float32)
        fname = f"weights/{name}.bin"
        arr.tofile(os.path.join(out_dir, fname))
        index.append({"name": name, "file": fname, "shape": list(arr.shape)})

    dump("tok_emb", params["tok_emb"])
    dump("ln_f", params["ln_f"])
    dump("unembed", params["unembed"])
    for li, lw in enumerate(params["layers"]):
        for k, _ in layer_weight_specs():
            dump(f"layers.{li}.{k}", lw[k])
    return index


def build(out_dir, skip_existing=True):
    os.makedirs(out_dir, exist_ok=True)
    cfg = MODEL
    params = train.load_or_train(
        os.path.join(out_dir, "weights.npz"),
        log_path=os.path.join(out_dir, "train_log.json"),
    )
    weight_index = write_weights(params, out_dir)

    lw = layer_weight_specs()
    lw_sds = [sds(s) for _, s in lw]
    d, hq, hk, dh, w = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                        cfg.d_head, cfg.window)

    entrypoints = {}
    jobs = []

    def add(name, fn, args, arg_names, outs):
        entrypoints[name] = {"args": arg_names, "outputs": outs}
        jobs.append((name, fn, args))

    for n in ARTIFACTS.prefill_buckets:
        add(
            f"embed_{n}",
            M.embed,
            [sds((n,), I32), sds((cfg.vocab_size, d))],
            ["ids", "tok_emb"],
            ["x"],
        )
        add(
            f"layer_prefill_{n}",
            functools.partial(M.layer_prefill, interpret=True),
            [sds((n, d)), sds((1,), I32)] + lw_sds,
            ["x", "length"] + [k for k, _ in lw],
            ["x_out", "k", "v", "win_attn", "acc_attn", "vnorm"],
        )
        add(
            f"lava_score_{n}",
            functools.partial(M.lava_score_ep, interpret=True),
            [sds((hq, w, n)), sds((hk, n, dh)), sds((1,), I32)],
            ["win_attn", "v", "length"],
            ["scores"],
        )
        # chunked prefill: one chunk of C rows against carry-in K/V at
        # observation width N, meta = (start, chunk_len, total_len)
        for c in ARTIFACTS.prefill_chunk_sizes:
            if c > n:
                continue
            add(
                f"layer_prefill_chunked_{c}x{n}",
                M.layer_prefill_chunked,
                [sds((c, d)), sds((hk, n, dh)), sds((hk, n, dh)),
                 sds((3,), I32)] + lw_sds,
                ["x_chunk", "carry_k", "carry_v", "meta"]
                + [k for k, _ in lw],
                ["x_out", "k", "v", "win_attn", "acc_attn", "vnorm"],
            )
    # streaming-evict chunked prefill: one chunk of C rows against a
    # compacted carry at working cap, meta = (start, chunk_len, total_len,
    # n_live), carry_pos maps carry columns to absolute positions
    for c in ARTIFACTS.prefill_chunk_sizes:
        for cap in ARTIFACTS.prefill_evict_caps:
            if c >= cap:
                continue
            add(
                f"layer_prefill_chunked_evict_{c}x{cap}",
                M.layer_prefill_chunked_evict,
                [sds((c, d)), sds((hk, cap, dh)), sds((hk, cap, dh)),
                 sds((cap,), I32), sds((4,), I32)] + lw_sds,
                ["x_chunk", "carry_k", "carry_v", "carry_pos", "meta"]
                + [k for k, _ in lw],
                ["x_out", "k", "v", "win_attn", "acc_attn", "vnorm"],
            )
    for m in ARTIFACTS.decode_buckets:
        add(
            f"layer_decode_{m}",
            M.layer_decode,
            [sds((1, d)), sds((hk, m, dh)), sds((hk, m, dh)),
             sds((hk, m)), sds((1,), I32)] + lw_sds,
            ["x", "k_cache", "v_cache", "valid", "pos"] + [k for k, _ in lw],
            ["x_out", "k_new", "v_new", "attn"],
        )
        for b in ARTIFACTS.decode_batch_sizes:
            add(
                f"layer_decode_batched_{m}x{b}",
                M.layer_decode_batched,
                [sds((b, d)), sds((b, hk, m, dh)), sds((b, hk, m, dh)),
                 sds((b, hk, m)), sds((b,), I32)] + lw_sds,
                ["x", "k_cache", "v_cache", "valid", "pos"]
                + [k for k, _ in lw],
                ["x_out", "k_new", "v_new", "attn"],
            )
    add(
        "logits",
        M.logits,
        [sds((1, d)), sds((d,)), sds((d, cfg.vocab_size))],
        ["x", "ln_f", "unembed"],
        ["p"],
    )

    for name, fn, args in jobs:
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        if skip_existing and os.path.exists(path):
            print(f"[aot] keep  {name}")
            continue
        nchars = lower_to_file(fn, args, path)
        print(f"[aot] wrote {name} ({nchars} chars)")

    manifest = manifest_dict()
    manifest["weights"] = weight_index
    manifest["entrypoints"] = entrypoints
    manifest["layer_weight_order"] = [k for k, _ in lw]
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest + {len(jobs)} entrypoints -> {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the .hlo.txt already exists")
    args = ap.parse_args()
    build(args.out, skip_existing=not args.force)


if __name__ == "__main__":
    main()
