"""Synthetic long-context task generators (build-time training data).

Four byte-level task families chosen so the trained model develops the
attention structure LAVa's evaluation depends on (induction/retrieval heads
that attend far back, plus local heads):

  needle   filler ... [SEP] key val*4 [SEP] filler [QUERY] key -> val*4
  kv       k k v v [SEP] ... pairs ... [QUERY] k k -> v v      (extraction)
  motif    a short motif repeated to fill the context; predict its
           continuation (periodic induction; generation-flavoured)
  copy     [BOS] payload(<=64) [SEP] filler [SEP2=QUERY] payload (generation)

The same generators are re-implemented in rust/src/workloads/ to drive the
benchmark suite; python only uses them for training. Lengths are interleaved
per step (never phased) — a phased curriculum catastrophically forgets.
"""

import numpy as np

from .config import MODEL

BOS, SEP, QUERY, PAD = MODEL.bos_id, MODEL.sep_id, MODEL.query_id, MODEL.pad_id
BYTES = 256


def _fill(rng, n):
    return rng.integers(0, BYTES, size=n)


def gen_needle(rng, seq_len, needle_len=4):
    """Random filler with an embedded [SEP] key val* [SEP]; query at the end."""
    key = rng.integers(0, BYTES)
    val = rng.integers(0, BYTES, size=needle_len)
    needle = np.concatenate([[SEP, key], val, [SEP]])
    tail = np.concatenate([[QUERY, key], val])
    n_fill = seq_len - len(needle) - len(tail) - 1
    depth = rng.integers(0, max(1, n_fill))
    toks = np.concatenate(
        [[BOS], _fill(rng, depth), needle, _fill(rng, n_fill - depth), tail]
    )
    mask = np.zeros(len(toks), bool)
    mask[-needle_len:] = True
    return toks, mask


def gen_kv(rng, seq_len):
    """k k v v [SEP] pairs, then [QUERY] k k -> v v."""
    n_pairs = max(1, (seq_len - 6) // 5)
    keys = rng.integers(0, BYTES, size=(n_pairs, 2))
    vals = rng.integers(0, BYTES, size=(n_pairs, 2))
    body = []
    for i in range(n_pairs):
        body.extend(keys[i])
        body.extend(vals[i])
        body.append(SEP)
    qi = rng.integers(0, n_pairs)
    toks = np.concatenate([[BOS], body, [QUERY], keys[qi], vals[qi]])
    mask = np.zeros(len(toks), bool)
    mask[-2:] = True
    return toks, mask


def gen_motif(rng, seq_len, min_p=8, max_p=16):
    """Periodic sequence; supervise the last two periods only.

    Supervision must stay SPARSE: densely supervising every motif position
    makes this task dominate the batch gradient and blocks the induction
    breakthrough entirely (verified empirically at build time: echo-only
    reaches loss 0.004 in 300 steps; +dense-motif stalls at 5.4)."""
    p = int(rng.integers(min_p, max_p + 1))
    motif = _fill(rng, p)
    reps = (seq_len - 1) // p + 1
    body = np.tile(motif, reps)[: seq_len - 1]
    toks = np.concatenate([[BOS], body])
    mask = np.zeros(len(toks), bool)
    mask[-2 * p:] = True
    return toks, mask


def gen_copy(rng, seq_len, max_payload=64):
    """[BOS] payload [SEP] filler [QUERY] payload ; loss on the echo."""
    m = int(min(max_payload, max(4, (seq_len - 3) // 3)))
    payload = _fill(rng, m)
    n_fill = seq_len - 2 * m - 3
    toks = np.concatenate(
        [[BOS], payload, [SEP], _fill(rng, max(0, n_fill)), [QUERY], payload]
    )
    mask = np.zeros(len(toks), bool)
    mask[-m:] = True
    return toks, mask


def gen_echo(rng, seq_len):
    """[BOS] payload [SEP] payload — dense copy with a RANDOM payload
    length. The copy distance must vary per sample: with fixed geometry the
    model learns a degenerate fixed-offset attention solution that collapses
    catastrophically the moment any other sequence length appears (observed
    at build time)."""
    m = (seq_len - 2) // 2
    payload = _fill(rng, m)
    toks = np.concatenate([[BOS], payload, [SEP], payload])
    mask = np.zeros(len(toks), bool)
    mask[m + 2:] = True
    return toks, mask


GENERATORS = (gen_needle, gen_kv, gen_motif, gen_copy, gen_echo)

# Bootstrap mixture (no motif): the echo task's dense half-sequence copy is
# what triggers induction-head formation. Main mixture then adds motif.
MIX_BOOT = [(gen_echo, 0.4), (gen_kv, 0.25), (gen_needle, 0.25), (gen_copy, 0.1)]
MIX = [(gen_echo, 0.3), (gen_kv, 0.2), (gen_needle, 0.2), (gen_copy, 0.1),
       (gen_motif, 0.2)]


def batch(rng, batch_size, seq_len, mix=None):
    """Mixture batch, padded to seq_len. Returns ids [B,T] i32, mask [B,T]."""
    mix = mix or MIX
    ids = np.full((batch_size, seq_len), PAD, np.int32)
    mask = np.zeros((batch_size, seq_len), bool)
    gens = [g for g, _ in mix]
    probs = np.array([p for _, p in mix])
    probs = probs / probs.sum()
    for b in range(batch_size):
        gen = gens[rng.choice(len(gens), p=probs)]
        toks, m = gen(rng, seq_len)
        toks, m = toks[:seq_len], m[:seq_len]
        ids[b, : len(toks)] = toks
        mask[b, : len(m)] = m
    return ids, mask
